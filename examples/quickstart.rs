//! Quickstart: the whole system in one page.
//!
//! 1. pretrain the dense mini ResNet on the synthetic corpus,
//! 2. decompose it in closed form (SVD + Tucker2, Eq. 1-6),
//! 3. run Algorithm 1 (rank optimization) on its biggest layer,
//! 4. fine-tune the decomposed model with sequential freezing (Algorithm 2),
//! 5. compare train/infer throughput and accuracy.
//!
//! Run: `cargo run --release --example quickstart`
//! (needs `make artifacts` first; takes a few minutes on one CPU core)

use anyhow::Result;
use lrta::coordinator::{
    decompose_checkpoint, ensure_pretrained, LrSchedule, TrainConfig, Trainer,
};
use lrta::devmodel::DeviceProfile;
use lrta::freeze::FreezeMode;
use lrta::lrd::LayerShape;
use lrta::rankopt::{optimize_rank, ModelTimer, RankOptConfig};
use lrta::runtime::{Manifest, Runtime};

fn main() -> Result<()> {
    let manifest = Manifest::load("artifacts/manifest.json")?;
    let rt = Runtime::cpu()?;
    println!("platform: {}\n", rt.platform());

    // --- 1. pretrain the dense model (cached across runs) ---------------
    println!("[1/5] pretraining dense resnet_mini ...");
    let dense = ensure_pretrained(&rt, &manifest, "resnet_mini", 2, 1024, 0)?;

    // --- 2. closed-form decomposition ------------------------------------
    println!("\n[2/5] decomposing (vanilla LRD, 2x) ...");
    let cfg = manifest.config("resnet_mini", "lrd")?;
    let outcome = decompose_checkpoint(&dense, cfg)?;
    println!(
        "    {} layers decomposed in {:.2}s, reconstruction error {:.3}",
        outcome.layers_decomposed, outcome.secs, outcome.total_reconstruction_err
    );

    // --- 3. Algorithm 1 on a representative layer -------------------------
    println!("\n[3/5] rank optimization for [128,128,3,3] on simulated V100 ...");
    let ropt = optimize_rank(
        &mut ModelTimer(DeviceProfile::v100()),
        LayerShape::conv(128, 128, 3),
        &RankOptConfig { m: 8 * 16 * 16, ..Default::default() },
    )?;
    println!(
        "    Eq.5 rank {} -> optimal {} ({:.2}x faster than vanilla; keep original: {})",
        ropt.r_nominal,
        ropt.r_opt,
        ropt.speedup_vs_nominal(),
        ropt.use_original
    );

    // --- 4. fine-tune with sequential freezing ---------------------------
    println!("\n[4/5] fine-tuning with sequential freezing (Algorithm 2) ...");
    let train_cfg = TrainConfig {
        model: "resnet_mini".into(),
        variant: "lrd".into(),
        freeze: FreezeMode::Sequential,
        epochs: 4,
        lr: LrSchedule::Fixed(1e-3),
        train_size: 1024,
        test_size: 256,
        seed: 0,
        verbose: true,
        resident: true,
        pipelined: true,
    };
    let mut trainer = Trainer::new(&rt, &manifest, train_cfg, outcome.params)?;
    let record = trainer.run()?;

    // --- 5. summary -------------------------------------------------------
    println!("\n[5/5] summary");
    println!("    final test accuracy : {:.3}", record.final_test_acc());
    println!("    median step time    : {:.1} ms", record.median_step_secs() * 1e3);
    println!("    inference throughput: {:.0} fps", trainer.infer_fps(5)?);
    println!("\nquickstart OK");
    Ok(())
}
