//! Fig.-2 driver: step time vs decomposition rank for the paper's layer
//! ([512, 512, 3, 3], Tucker2, 2x→3x compression band), on every timing
//! backend: simulated V100, simulated Ascend-910, simulated TPU-v4, and
//! *measured* PJRT-CPU (builder-constructed computations, strided to keep
//! compile count sane).
//!
//! Emits `results/fig2_<backend>.csv` with columns rank,time_ms,ratio,delta
//! and prints the chosen optimal rank per backend — the platform-agnostic
//! claim of the paper, demonstrated.
//!
//! Run: `cargo run --release --example rankopt_sweep`
//! Env: LRTA_PJRT=0 to skip the measured sweep; LRTA_M (default 1568)

use anyhow::Result;
use lrta::devmodel::DeviceProfile;
use lrta::lrd::LayerShape;
use lrta::rankopt::{optimize_rank, LayerTimer, ModelTimer, PjrtTimer, RankOptConfig, RankOptResult};
use lrta::runtime::Runtime;
use lrta::util::bench::write_report;

fn dump(result: &RankOptResult, path: &str) {
    let mut csv = String::from("rank,time_ms,ratio,delta_ms\n");
    for (i, p) in result.sweep.iter().enumerate() {
        let delta = if i == 0 { 0.0 } else { result.delta[i - 1] * 1e3 };
        csv.push_str(&format!("{},{:.6},{:.4},{:.6}\n", p.r, p.t * 1e3, p.ratio, delta));
    }
    write_report(path, &csv);
}

fn report(result: &RankOptResult) {
    println!(
        "  backend {:<14} R={} Rmin={} -> R_opt={}  t_lrd={:.4}ms t_opt={:.4}ms ({:.2}x)  dense={:.4}ms use_original={}",
        result.backend,
        result.r_nominal,
        result.r_min,
        result.r_opt,
        result.t_nominal * 1e3,
        result.t_opt * 1e3,
        result.speedup_vs_nominal(),
        result.t_dense * 1e3,
        result.use_original,
    );
}

fn main() -> Result<()> {
    let m = std::env::var("LRTA_M").ok().and_then(|v| v.parse().ok()).unwrap_or(1568);
    let shape = LayerShape::conv(512, 512, 3); // the paper's Fig. 2 layer
    println!("Fig. 2 sweep: conv [512,512,3,3], Tucker2, alpha 2 -> 3 band, m={m}\n");

    // simulated backends: exhaustive stride-1 sweep like the paper
    for dev in [DeviceProfile::v100(), DeviceProfile::ascend910(), DeviceProfile::tpu_v4()] {
        let name = dev.name;
        let mut timer = ModelTimer(dev);
        let cfg = RankOptConfig { m, ..Default::default() };
        let result = optimize_rank(&mut timer, shape, &cfg)?;
        report(&result);
        dump(&result, &format!("results/fig2_{name}.csv"));
    }

    // measured backend: PJRT CPU, stride 8 (each rank = one compile + runs)
    if std::env::var("LRTA_PJRT").map(|v| v != "0").unwrap_or(true) {
        println!("\nmeasured PJRT sweep (stride 8; ~1 min) ...");
        let rt = Runtime::cpu()?;
        let mut timer = PjrtTimer::new(&rt);
        let cfg = RankOptConfig { m: m.min(784), stride: 8, ..Default::default() };
        let result = optimize_rank(&mut timer, shape, &cfg)?;
        report(&result);
        dump(&result, "results/fig2_pjrt_cpu.csv");
        println!("  ({} measured points, backend {})", result.sweep.len(), timer.backend());
    }

    println!("\nCSV curves in results/fig2_*.csv (plot rank vs time_ms for the staircase)");
    Ok(())
}
