//! End-to-end training driver — the repository's Fig.-3 experiment and the
//! system-prompt's "full workload" validation run.
//!
//! Pretrains the dense mini ResNet, decomposes it, then fine-tunes THREE
//! ways on the synthetic CIFAR-scale corpus:
//!   - no freezing (vanilla LRD),
//!   - regular freezing   (fixed pattern, paper §2.2),
//!   - sequential freezing (Algorithm 2, alternating per epoch),
//! logging the full loss/accuracy curves to `results/fig3_curves/*.csv`
//! and printing the convergence comparison the paper makes
//! ("sequential reaches the target accuracy epochs earlier").
//!
//! Fine-tuning steps run through the device-resident engine by default
//! (`lrta::train`: params/momenta uploaded once, steps chained
//! buffer-to-buffer, pattern a↔b swaps re-bound in place) with the
//! overlapped pipeline on top (double-buffered batch uploads, on-device
//! epoch metrics, side-thread eval); set `LRTA_RESIDENT=0` for the
//! host-literal round-trip baseline or `LRTA_PIPELINED=0` for the serial
//! resident loop.
//!
//! Setting `LRTA_REPLICAS=N` (N > 1) fine-tunes data-parallel instead: N
//! engine replicas — one PJRT client and resident state each — step on
//! disjoint batch shards and average their trainable parameters at the
//! buffer level every `LRTA_AVG_EVERY` steps (0 = epoch boundaries only).
//! Replicas honor `LRTA_PIPELINED` the same way the single-engine run
//! does (each replica drives the overlapped epoch loop with the barrier
//! hooked in per step), and `LRTA_SYNC_COMPRESS` picks the barrier wire
//! codec: `exact` (default, lossless XOR deltas) or `q8` (int8-quantized
//! deltas, lossy).
//!
//! Run: `cargo run --release --example train_cifar_seqfreeze`
//! Env:  LRTA_EPOCHS (default 10), LRTA_TRAIN (default 1024),
//!       LRTA_RESIDENT (default 1), LRTA_PIPELINED (default 1),
//!       LRTA_REPLICAS (default 1), LRTA_AVG_EVERY (default 0),
//!       LRTA_SYNC_COMPRESS (default exact)

use anyhow::Result;
use lrta::coordinator::{
    decompose_checkpoint, ensure_pretrained, LrSchedule, TrainConfig, Trainer,
};
use lrta::faults;
use lrta::freeze::FreezeMode;
use lrta::metrics::RunRecord;
use lrta::runtime::{Manifest, Runtime};
use lrta::train::{run_replicas, ReplicaConfig, SyncCompress};
use lrta::util::bench::write_report;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> Result<()> {
    let epochs = env_usize("LRTA_EPOCHS", 10);
    let train_size = env_usize("LRTA_TRAIN", 1024);
    let env_on = |key: &str| {
        std::env::var(key)
            .map(|v| !matches!(v.trim(), "0" | "false" | "no" | "off"))
            .unwrap_or(true)
    };
    let resident = env_on("LRTA_RESIDENT");
    let pipelined = env_on("LRTA_PIPELINED");
    let replicas = env_usize("LRTA_REPLICAS", 1);
    let avg_every = env_usize("LRTA_AVG_EVERY", 0);
    let compress = std::env::var("LRTA_SYNC_COMPRESS")
        .map(|v| SyncCompress::parse(&v).expect("LRTA_SYNC_COMPRESS must be exact|f32|q8|int8"))
        .unwrap_or_default();

    // chaos harness: LRTA_FAULTS installs a deterministic fault plan (the
    // CI chaos smoke drives replica eviction through this)
    if faults::install_from_env()? {
        println!("fault plan installed from LRTA_FAULTS");
    }

    let manifest = Manifest::load("artifacts/manifest.json")?;
    let rt = Runtime::cpu()?;

    println!("== pretraining dense resnet_mini ==");
    let dense = ensure_pretrained(&rt, &manifest, "resnet_mini", 2, train_size, 0)?;

    let cfg = manifest.config("resnet_mini", "lrd")?;
    let decomposed = decompose_checkpoint(&dense, cfg)?;
    println!(
        "decomposed {} layers (err {:.3})\n",
        decomposed.layers_decomposed, decomposed.total_reconstruction_err
    );

    let mut records: Vec<(&str, RunRecord)> = Vec::new();
    for (label, mode) in [
        ("nofreeze", FreezeMode::None),
        ("regular", FreezeMode::Regular),
        ("sequential", FreezeMode::Sequential),
    ] {
        println!(
            "== fine-tune with {label} freezing ({epochs} epochs, {} steps) ==",
            if replicas > 1 {
                "replica data-parallel"
            } else if resident && pipelined {
                "pipelined buffer-chained"
            } else if resident {
                "buffer-chained"
            } else {
                "literal round-trip"
            }
        );
        let cfg = TrainConfig {
            model: "resnet_mini".into(),
            variant: "lrd".into(),
            freeze: mode,
            epochs,
            lr: LrSchedule::Fixed(1e-3),
            train_size,
            test_size: 256,
            seed: 0,
            verbose: true,
            resident,
            pipelined,
        };
        let record = if replicas > 1 {
            let rcfg = ReplicaConfig { replicas, avg_every, compress, ..Default::default() };
            let run = run_replicas(&manifest, &cfg, &rcfg, &decomposed.params)?;
            for r in &run.reports {
                println!(
                    "   replica {} ({} driver): {} initial uploads + {} averaging uploads \
                     ({} unaccounted), {} demux fallbacks",
                    r.replica,
                    r.driver(),
                    r.initial_param_uploads,
                    r.avg_slot_uploads,
                    r.unaccounted_uploads(),
                    r.demux_fallbacks
                );
                println!(
                    "      barrier [{}]: {} B exchanged of {} B full ({} B frozen-skipped, \
                     {} B saved by delta)",
                    compress.label(),
                    r.avg_bytes_exchanged,
                    r.avg_bytes_full,
                    r.avg_bytes_skipped,
                    r.avg_bytes_saved_by_delta()
                );
            }
            if run.record.degraded() {
                for ev in &run.record.evictions {
                    println!(
                        "   evicted replica {} at event {} ({} survived): {}",
                        ev.replica, ev.event, ev.survivors, ev.reason
                    );
                }
            }
            run.record
        } else {
            let mut trainer = Trainer::new(&rt, &manifest, cfg, decomposed.params.clone())?;
            let record = trainer.run()?;
            if let Some(report) = trainer.residency_report() {
                println!("   {report}");
            }
            record
        };
        write_report(&format!("results/fig3_curves/{label}.csv"), &record.curve_csv());
        records.push((label, record));
        println!();
    }

    // --- the paper's Fig.-3 comparison -----------------------------------
    println!("== convergence comparison (paper Fig. 3) ==");
    let best_final = records
        .iter()
        .map(|(_, r)| r.final_test_acc())
        .fold(f64::NAN, f64::max);
    let target = (best_final * 0.95).min(0.95);
    for (label, r) in &records {
        let reach = r
            .epochs_to_reach(target)
            .map(|e| e.to_string())
            .unwrap_or_else(|| "never".into());
        println!(
            "  {label:<11} final={:.4} best={:.4} reaches {:.3} at epoch {}  (median step {:.0} ms)",
            r.final_test_acc(),
            r.best_test_acc(),
            target,
            reach,
            r.median_step_secs() * 1e3,
        );
    }
    let seq = records.iter().find(|(l, _)| *l == "sequential").unwrap();
    let reg = records.iter().find(|(l, _)| *l == "regular").unwrap();
    match (seq.1.epochs_to_reach(target), reg.1.epochs_to_reach(target)) {
        (Some(s), Some(r)) if s < r => {
            println!("\nsequential converges {} epochs earlier than regular — matches Fig. 3", r - s)
        }
        (Some(_), None) => println!("\nregular never reaches the target — sequential wins"),
        _ => println!("\n(convergence order varies at this tiny scale — see results/fig3_curves)"),
    }
    if faults::armed() {
        println!("faults: {} injected", faults::fired());
    }
    Ok(())
}
