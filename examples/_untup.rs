fn main() -> anyhow::Result<()> {
    let client = xla::PjRtClient::cpu()?;
    let proto = xla::HloModuleProto::from_text_file("/tmp/untupled.hlo.txt")?;
    let exe = client.compile(&xla::XlaComputation::from_proto(&proto))?;
    let x = xla::Literal::vec1(&[1f32, 2., 3., 4.]).reshape(&[2, 2])?;
    let y = xla::Literal::vec1(&[5f32, 6., 7., 8.]).reshape(&[2, 2])?;
    let out = exe.execute::<xla::Literal>(&[x, y])?;
    println!("devices={} outputs={}", out.len(), out[0].len());
    for (i, b) in out[0].iter().enumerate() {
        let l = b.to_literal_sync()?;
        println!("out{} = {:?}", i, l.to_vec::<f32>()?);
    }
    // feed an output buffer back as an input (device-resident round trip)
    let out2 = exe.execute_b(&[&out[0][0], &out[0][1]])?;
    let l = out2[0][0].to_literal_sync()?;
    println!("roundtrip out0 = {:?}", l.to_vec::<f32>()?);
    Ok(())
}
