//! End-to-end storage smoke: the whole train→checkpoint→serve pipeline
//! through one in-process object store — what `lrta train --data-store
//! mem: --store mem:` followed by `lrta serve --swap-store mem:` does,
//! driven as a library so CI can assert the invariants, not just the exit
//! code.
//!
//! The pipeline:
//!
//!   1. publish the synthetic corpus as content-addressed chunks into a
//!      shared `mem:` store (and republish it to show dedupe: the second
//!      publish uploads zero bytes);
//!   2. fine-tune the low-rank model for 2 epochs **streaming batches
//!      from the store**, uploading each epoch's checkpoint back into it
//!      from the async writer;
//!   3. run the identical fine-tune from RAM and assert the streamed
//!      trajectory is bit-identical (the refactor's central pin);
//!   4. start a serve router and warm-swap the final uploaded checkpoint
//!      out of the same store, then answer a request with it.
//!
//! Run:  `cargo run --release --example storage_pipeline`
//! Env:  LRTA_MODEL (default resnet_mini), LRTA_SMOKE_TRAIN (corpus size,
//!       default 256), LRTA_SMOKE_EPOCHS (default 2)

use anyhow::{ensure, Result};
use lrta::checkpoint;
use lrta::coordinator::{decompose_checkpoint, LrSchedule, TrainConfig, Trainer};
use lrta::data::{publish, DataSource, Dataset, StreamingProvider, IMAGE_ELEMS};
use lrta::freeze::FreezeMode;
use lrta::runtime::{Manifest, Runtime};
use lrta::serve::{Server, ServerConfig, VariantSpec};
use lrta::storage;
use lrta::train::Prefetcher;
use std::sync::Arc;
use std::time::Duration;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> Result<()> {
    let model = std::env::var("LRTA_MODEL").unwrap_or_else(|_| "resnet_mini".into());
    let train_size = env_usize("LRTA_SMOKE_TRAIN", 256);
    let epochs = env_usize("LRTA_SMOKE_EPOCHS", 2);

    let manifest = Manifest::load("artifacts/manifest.json")?;
    let rt = Runtime::cpu()?;
    let dense = checkpoint::load(manifest.init_checkpoint(&model)?)?;
    let params = decompose_checkpoint(&dense, manifest.config(&model, "lrd")?)?.params;

    // --- 1. publish the corpus through the storage boundary ---------------
    let store = storage::open("mem:smoke")?;
    let cfg = TrainConfig {
        model: model.clone(),
        variant: "lrd".into(),
        freeze: FreezeMode::Sequential,
        epochs,
        lr: LrSchedule::Fixed(5e-3),
        train_size,
        test_size: 128,
        seed: 0,
        verbose: false,
        resident: true,
        pipelined: true,
    };
    let corpus = Dataset::synthetic(cfg.train_size, cfg.seed);
    let stats = publish(&store, "data", &corpus, lrta::data::stream::DEFAULT_SAMPLES_PER_CHUNK)?;
    println!(
        "published: {} samples, {} chunks, {} B uploaded",
        stats.samples, stats.chunks_total, stats.bytes_written
    );
    let again = publish(&store, "data", &corpus, lrta::data::stream::DEFAULT_SAMPLES_PER_CHUNK)?;
    ensure!(again.chunks_written == 0, "republish must dedupe every chunk");
    println!("republished: {} B uploaded, {} B deduped", again.bytes_written, again.bytes_deduped);

    // --- 2. streamed fine-tune, checkpoints uploaded to the store ---------
    let provider = Arc::new(StreamingProvider::open(Arc::clone(&store), "data")?);
    let mut streamed = Trainer::new(&rt, &manifest, cfg.clone(), params.clone())?;
    streamed.train_from(DataSource::streamed(Arc::clone(&provider)));
    streamed.checkpoint_epochs_to_store(Arc::clone(&store), "ckpts");
    let stream_rec = streamed.run()?;

    // --- 3. the in-memory twin must match bit for bit ----------------------
    let mut inmem = Trainer::new(&rt, &manifest, cfg, params.clone())?;
    let mem_rec = inmem.run()?;
    ensure!(mem_rec.epochs.len() == stream_rec.epochs.len());
    for (m, s) in mem_rec.epochs.iter().zip(&stream_rec.epochs) {
        ensure!(
            m.loss.to_bits() == s.loss.to_bits()
                && m.test_acc.to_bits() == s.test_acc.to_bits(),
            "epoch {}: streamed trajectory diverged (loss {} vs {})",
            m.epoch,
            m.loss,
            s.loss
        );
    }
    println!("streamed == in-memory: {} epochs bit-identical", mem_rec.epochs.len());

    // --- 4. serve: warm-swap the uploaded checkpoint out of the store ------
    let uploads = store.list("ckpts/")?;
    ensure!(uploads.len() == epochs, "expected {epochs} uploaded checkpoints: {uploads:?}");
    let final_key = uploads.last().unwrap().clone();

    let scfg = ServerConfig { max_wait: Duration::from_millis(20), ..Default::default() };
    let server =
        Server::start(&manifest, vec![VariantSpec::new(&model, "lrd", params)], &scfg)?;
    server
        .swap_variant_from_store(&model, "lrd", store.as_ref(), &final_key)
        .map_err(|e| anyhow::anyhow!("swap from store: {e}"))?;

    // one request through the swapped weights proves the router serves them
    let probe = {
        let mut pf = Prefetcher::start_streaming(provider, 1, 1, lrta::data::Shard::full());
        pf.next_batch().expect("one probe batch").0
    };
    ensure!(probe.len() == IMAGE_ELEMS);
    let resp = server
        .submit(&model, "lrd", probe)
        .map_err(|e| anyhow::anyhow!("submit: {e}"))?
        .wait(Duration::from_secs(120))
        .map_err(|e| anyhow::anyhow!("infer: {e}"))?;
    ensure!(!resp.logits.is_empty() && resp.logits.iter().all(|v| v.is_finite()));
    server.shutdown();

    let m = store.metrics();
    println!(
        "store traffic: {} gets / {} B down, {} puts / {} B up ({} objects resident)",
        m.get_ops.get(),
        m.get_bytes.get(),
        m.put_ops.get(),
        m.put_bytes.get(),
        store.list("")?.len()
    );
    println!("swapped {final_key} from the store and served with it — storage pipeline OK");
    Ok(())
}
