//! Inference serving driver (the Table-1 "Infer Speed" columns) on top of
//! the `lrta::serve` subsystem.
//!
//! Registers the `orig` / `lrd` / `rankopt` checkpoints of one model as
//! router variants — each engine keeps its parameters **device-resident**
//! (uploaded once, not per request) — and drives a synthetic closed-loop
//! load generator with configurable concurrency through each variant.
//! Freezing does not appear here on purpose: the paper's point is that
//! freezing accelerates *training only*.
//!
//! The old per-request parameter round-trip
//! (`literal_to_tensor` → `tensor_to_literal` per request) is gone; pass
//! `--reupload` (or `LRTA_REUPLOAD=1`) to restore it as a measurable
//! baseline.
//!
//! Run:  `cargo run --release --example serve_infer [-- --flags]`
//! Args: --model M --requests N --concurrency C --max-wait-ms X
//!       --spot-check N --reupload --burst --no-pipeline --shards N
//!       --classes SPEC --degrade SPEC --hedge-ms D  (QoS; same grammar
//!       as `lrta serve`, see rust/src/serve/qos.rs)
//! Env fallbacks: LRTA_MODEL, LRTA_REQUESTS, LRTA_CONCURRENCY,
//!       LRTA_REUPLOAD, LRTA_PIPELINED, LRTA_SHARDS, LRTA_CLASSES,
//!       LRTA_DEGRADE, LRTA_HEDGE_MS

use anyhow::Result;
use lrta::checkpoint;
use lrta::data::Dataset;
use lrta::faults;
use lrta::runtime::Manifest;
use lrta::serve::{self, Class, HedgeConfig, QosConfig, Server, ServerConfig, VariantSpec};
use lrta::util::bench::{fmt_delta_pct, table, write_report};
use lrta::util::cli::Args;
use std::time::Duration;

fn env_or(key: &str, default: &str) -> String {
    std::env::var(key).unwrap_or_else(|_| default.to_string())
}

fn main() -> Result<()> {
    let args = Args::from_env(&[
        "model", "requests", "concurrency", "max-wait-ms", "spot-check", "reupload", "burst",
        "no-pipeline", "shards", "classes", "degrade", "hedge-ms",
    ])
    .map_err(|e| anyhow::anyhow!("{e}"))?;
    let model = args.str_or("model", &env_or("LRTA_MODEL", "resnet_mini"));
    let requests = args.usize_or(
        "requests",
        env_or("LRTA_REQUESTS", "256").parse().unwrap_or(256),
    );
    let concurrency = args.usize_or(
        "concurrency",
        env_or("LRTA_CONCURRENCY", "32").parse().unwrap_or(32),
    );
    let reupload =
        args.bool_or("reupload", false) || env_or("LRTA_REUPLOAD", "0") == "1";
    let burst = args.bool_or("burst", false);
    let shards = args
        .usize_or("shards", env_or("LRTA_SHARDS", "1").parse().unwrap_or(1))
        .max(1);

    // QoS: flag wins over env, empty env counts as unset; the specs reuse
    // the `lrta serve` grammar so one string works in both drivers
    let flag_or_env = |key: &str, env: &str| -> Option<String> {
        args.get(key)
            .map(str::to_string)
            .or_else(|| std::env::var(env).ok().filter(|s| !s.is_empty()))
    };
    let qos = match flag_or_env("classes", "LRTA_CLASSES") {
        Some(spec) => {
            let mut q = QosConfig {
                classes: QosConfig::parse_classes(&spec)?,
                ..Default::default()
            };
            if let Some(d) = flag_or_env("degrade", "LRTA_DEGRADE") {
                q.degrade = QosConfig::parse_degrade(&d)?;
            }
            if let Some(h) = flag_or_env("hedge-ms", "LRTA_HEDGE_MS") {
                let ms: f64 = h.parse().ok().filter(|v| *v > 0.0).ok_or_else(|| {
                    anyhow::anyhow!("--hedge-ms expects a positive number, got '{h}'")
                })?;
                // hedging needs a sibling shard; with --shards 1 the server
                // simply never arms a board, so this stays permissive here
                q.hedge = Some(HedgeConfig {
                    fallback: Duration::from_secs_f64(ms / 1e3),
                    ..Default::default()
                });
            }
            Some(q)
        }
        None => None,
    };

    // chaos harness: LRTA_FAULTS installs a deterministic fault plan (the
    // CI chaos smoke kills/stalls shards through this)
    if faults::install_from_env()? {
        println!("fault plan installed from LRTA_FAULTS");
    }

    let manifest = Manifest::load("artifacts/manifest.json")?;
    let dense = checkpoint::load(manifest.init_checkpoint(&model)?)?;

    let variants = ["orig", "lrd", "rankopt"];
    let mut specs = Vec::new();
    for variant in variants {
        let spec = VariantSpec::from_dense(&manifest, &model, variant, &dense)?;
        specs.push(spec.with_shards(shards));
    }
    let cfg = ServerConfig {
        max_wait: Duration::from_secs_f64(args.f64_or("max-wait-ms", 2.0) / 1e3),
        reupload,
        // streaming admission is the default; --no-pipeline (or
        // LRTA_PIPELINED=0) restores the lockstep engine loop (same env
        // truthiness as examples/train_cifar_seqfreeze.rs)
        pipelined: !args.bool_or("no-pipeline", false)
            && !matches!(
                env_or("LRTA_PIPELINED", "1").trim(),
                "0" | "false" | "no" | "off"
            ),
        spot_check: args.usize_or("spot-check", 128),
        qos: qos.clone(),
        ..Default::default()
    };
    let server = Server::start(&manifest, specs, &cfg)?;

    // request stream: pre-generated samples (the data pipeline is not what
    // we're measuring)
    let data = Dataset::synthetic(512, 99);
    let timeout = Duration::from_secs(120);

    let mut rows = vec![vec![
        "Variant".to_string(),
        "fps".to_string(),
        "Δ fps".to_string(),
        "p50 ms".to_string(),
        "p99 ms".to_string(),
        "fill %".to_string(),
        "accuracy".to_string(),
    ]];
    let mut base_fps = None;
    for variant in variants {
        let (report, class_reports) = if qos.is_some() {
            let crs = serve::classed_burst_loop(
                &server,
                &model,
                variant,
                &data,
                requests,
                &Class::ALL,
                timeout,
            );
            // fold the per-class reports into one row for the summary table
            let mut all = serve::LoadReport::default();
            for r in &crs {
                all.requests += r.requests;
                all.completed += r.completed;
                all.errors += r.errors;
                all.shed += r.shed;
                all.rejected += r.rejected;
                all.wall_secs = all.wall_secs.max(r.wall_secs);
                all.latencies.extend_from_slice(&r.latencies);
            }
            all.latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
            (all, Some(crs))
        } else if burst {
            (serve::burst_loop(&server, &model, variant, &data, requests, timeout), None)
        } else {
            (
                serve::closed_loop(
                    &server, &model, variant, &data, requests, concurrency, timeout,
                ),
                None,
            )
        };
        let snap = server.stats(&model, variant).expect("registered variant");
        if let Some(crs) = &class_reports {
            for (class, r) in Class::ALL.iter().zip(crs.iter()) {
                println!(
                    "  {variant}/{class}: {} ok / {} shed / {} errors | p99 {:.1} ms",
                    r.completed,
                    r.shed,
                    r.errors,
                    r.latency_ms(99.0)
                );
            }
            println!(
                "  {variant}: spilled={:?} hedge fired/won/cancelled {}/{}/{}",
                snap.spilled_by_class, snap.hedge_fired, snap.hedge_wins, snap.hedge_cancelled
            );
        }
        let fps = report.observed_fps();
        let delta = match base_fps {
            None => {
                base_fps = Some(fps);
                "0".to_string()
            }
            Some(base) => fmt_delta_pct(base, fps),
        };
        rows.push(vec![
            variant.to_string(),
            format!("{fps:.0}"),
            delta,
            format!("{:.1}", report.latency_ms(50.0)),
            format!("{:.1}", report.latency_ms(99.0)),
            format!("{:.0}", snap.mean_fill * 100.0),
            snap.spot_check_acc.map(|a| format!("{a:.3}")).unwrap_or_else(|| "-".into()),
        ]);
        println!(
            "{variant}: {fps:.0} fps ({} ok / {} rejected retries / {} errors, \
             {} worker death(s), {} respawn(s))",
            report.completed,
            report.rejected,
            report.errors,
            snap.worker_deaths,
            snap.respawns
        );
    }
    server.shutdown();
    if faults::armed() {
        println!("faults: {} injected", faults::fired());
    }

    let t = table(&rows);
    let mode = if reupload { "reupload-per-batch (baseline)" } else { "device-resident" };
    println!(
        "\n{model} inference serving ({requests} single-image requests per variant, \
         {mode}, {} shard(s), {}):\n{t}",
        shards,
        if burst { "burst".to_string() } else { format!("concurrency {concurrency}") }
    );
    write_report(&format!("results/serve_infer_{model}.txt"), &t);
    Ok(())
}
