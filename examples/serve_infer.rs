//! Batched-inference serving driver (the Table-1 "Infer Speed" columns).
//!
//! Loads a trained (or init) checkpoint for each variant of a model, runs a
//! stream of batched requests through the PJRT executable, and reports
//! throughput (fps) plus batch-latency percentiles — original vs vanilla
//! LRD vs rank-optimized. Freezing does not appear here on purpose: the
//! paper's point is that freezing accelerates *training only*.
//!
//! Run: `cargo run --release --example serve_infer`
//! Env: LRTA_MODEL (resnet_mini|vit_mini), LRTA_BATCHES (default 12)

use anyhow::Result;
use lrta::checkpoint;
use lrta::coordinator::{decompose_checkpoint, evaluate_with};
use lrta::data::Dataset;
use lrta::metrics::ThroughputMeter;
use lrta::runtime::{tensor_to_literal, Manifest, Runtime};
use lrta::util::bench::{fmt_delta_pct, table, write_report};

fn main() -> Result<()> {
    let model = std::env::var("LRTA_MODEL").unwrap_or_else(|_| "resnet_mini".into());
    let batches: usize =
        std::env::var("LRTA_BATCHES").ok().and_then(|v| v.parse().ok()).unwrap_or(12);

    let manifest = Manifest::load("artifacts/manifest.json")?;
    let rt = Runtime::cpu()?;
    let dense = checkpoint::load(manifest.init_checkpoint(&model)?)?;

    let mut rows = vec![vec![
        "Variant".to_string(),
        "fps".to_string(),
        "Δ fps".to_string(),
        "p50 ms".to_string(),
        "p99 ms".to_string(),
        "accuracy".to_string(),
    ]];
    let mut base_fps = None;

    for variant in ["orig", "lrd", "rankopt"] {
        let params = if variant == "orig" {
            dense.clone()
        } else {
            decompose_checkpoint(&dense, manifest.config(&model, variant)?)?.params
        };
        let meta = manifest.artifact(&format!("{model}_{variant}_infer"))?;
        let exe = rt.load_hlo(manifest.hlo_path(meta))?;

        // request stream: pre-generated batches (the data pipeline is not
        // what we're measuring)
        let eval = Dataset::synthetic(meta.batch * 2, 99);
        let mut param_lits = Vec::new();
        for slot in &meta.trainable {
            param_lits.push(tensor_to_literal(&params[&slot.name])?);
        }
        let x_dims: Vec<i64> = meta.x_shape.iter().map(|&d| d as i64).collect();
        let (xs, _) = eval.batch(0, meta.batch);

        let make_inputs = |param_lits: &[xla::Literal]| -> Result<Vec<xla::Literal>> {
            let mut v = Vec::with_capacity(param_lits.len() + 1);
            for l in param_lits {
                // re-upload params per request (serving keeps them resident;
                // see bench_perf_micro for the buffer-resident variant)
                let t = lrta::runtime::literal_to_tensor(l)?;
                v.push(tensor_to_literal(&t)?);
            }
            v.push(xla::Literal::vec1(&xs).reshape(&x_dims)?);
            Ok(v)
        };

        // warmup
        exe.run(&make_inputs(&param_lits)?)?;
        let mut meter = ThroughputMeter::new(meta.batch);
        for _ in 0..batches {
            let inputs = make_inputs(&param_lits)?;
            let t0 = std::time::Instant::now();
            exe.run(&inputs)?;
            meter.record(t0.elapsed().as_secs_f64());
        }
        let acc = evaluate_with(&exe, meta, &params, &eval)?;

        let fps = meter.fps();
        let delta = match base_fps {
            None => {
                base_fps = Some(fps);
                "0".to_string()
            }
            Some(base) => fmt_delta_pct(base, fps),
        };
        let s = meter.summary();
        rows.push(vec![
            variant.to_string(),
            format!("{fps:.0}"),
            delta,
            format!("{:.1}", s.median * 1e3),
            format!("{:.1}", s.p99 * 1e3),
            format!("{acc:.3}"),
        ]);
        println!("{variant}: {fps:.0} fps");
    }

    let t = table(&rows);
    println!("\n{model} inference serving ({} requests of batch per variant):\n{t}", batches);
    write_report(&format!("results/serve_infer_{model}.txt"), &t);
    Ok(())
}
