//! Property suite: linear algebra + LRD invariants over random inputs
//! (via the from-scratch `util::check` harness — the proptest substitute).

use lrta::linalg::{orthogonality_defect, qr, svd, svd_truncated};
use lrta::lrd::{
    compression_ratio, decomposed_params, svd_linear, svd_rank_for_compression,
    tucker2_conv, tucker_rank_eq5, tucker_rmin_eq6, LayerShape,
};
use lrta::tensor::Tensor;
use lrta::util::check::{forall, Config};
use lrta::util::rng::Rng;

fn cfg(cases: usize, seed: u64) -> Config {
    Config { cases, seed }
}

#[test]
fn prop_svd_reconstructs_at_full_rank() {
    forall(
        cfg(12, 101),
        |r: &mut Rng| {
            let m = 3 + r.below(14);
            let n = 3 + r.below(14);
            Tensor::randn(&[m, n], 1.0, r)
        },
        |a| {
            let d = svd(a);
            let k = a.shape()[0].min(a.shape()[1]);
            a.max_abs_diff(&d.reconstruct(k)) < 1e-3
        },
    );
}

#[test]
fn prop_singular_values_sorted_and_factors_orthonormal() {
    forall(
        cfg(10, 102),
        |r: &mut Rng| {
            let m = 4 + r.below(12);
            let n = 4 + r.below(12);
            Tensor::randn(&[m, n], 1.0, r)
        },
        |a| {
            let d = svd(a);
            d.s.windows(2).all(|w| w[0] >= w[1] - 1e-5)
                && d.s.iter().all(|&s| s >= 0.0)
                && orthogonality_defect(&d.u) < 1e-3
                && orthogonality_defect(&d.v) < 1e-3
        },
    );
}

#[test]
fn prop_truncation_error_monotone_in_rank() {
    forall(
        cfg(8, 103),
        |r: &mut Rng| {
            let m = 6 + r.below(10);
            let n = 6 + r.below(10);
            Tensor::randn(&[m, n], 1.0, r)
        },
        |a| {
            let k = a.shape()[0].min(a.shape()[1]);
            let mut last = f32::INFINITY;
            for r in 1..=k {
                let f = svd_truncated(a, r);
                let err = a.dist2(&f.reconstruct(r));
                if err > last + 1e-3 {
                    return false;
                }
                last = err;
            }
            true
        },
    );
}

#[test]
fn prop_qr_orthonormal_and_reconstructs() {
    forall(
        cfg(12, 104),
        |r: &mut Rng| {
            let m = 3 + r.below(20);
            let n = 3 + r.below(20);
            Tensor::randn(&[m, n], 1.0, r)
        },
        |a| {
            let (q, rr) = qr(a);
            orthogonality_defect(&q) < 1e-3 && a.max_abs_diff(&q.matmul(&rr)) < 1e-3
        },
    );
}

#[test]
fn prop_svd_linear_factor_product_params() {
    forall(
        cfg(12, 105),
        |r: &mut Rng| {
            let c = 4 + r.below(20);
            let s = 4 + r.below(20);
            let rank = 1 + r.below(c.min(s));
            (Tensor::randn(&[c, s], 1.0, r), rank)
        },
        |(w, rank)| {
            let f = svd_linear(w, *rank);
            f.a.shape() == [w.shape()[0], *rank]
                && f.b.shape() == [*rank, w.shape()[1]]
                && f.params() == w.shape()[0] * rank + rank * w.shape()[1]
        },
    );
}

#[test]
fn prop_tucker_shapes_and_error_bounded() {
    forall(
        cfg(6, 106),
        |r: &mut Rng| {
            let c = 3 + r.below(8);
            let s = 3 + r.below(8);
            let r1 = 1 + r.below(c);
            let r2 = 1 + r.below(s);
            (Tensor::randn(&[c, s, 3, 3], 1.0, r), r1, r2)
        },
        |(w, r1, r2)| {
            let f = tucker2_conv(w, *r1, *r2);
            let rec = f.reconstruct();
            // truncation error is bounded by the total energy
            rec.shape() == w.shape() && w.dist2(&rec) <= w.norm().powi(2) * 1.01
        },
    );
}

#[test]
fn prop_eq5_lands_in_compression_band() {
    forall(
        cfg(200, 107),
        |r: &mut Rng| {
            let c = 8 + r.below(512);
            let s = 8 + r.below(512);
            let k = [1usize, 3, 5][r.below(3)];
            let alpha = [1.5f64, 2.0, 3.0][r.below(3)];
            (c, s, k, alpha)
        },
        |&(c, s, k, alpha)| {
            let (r1, shape) = if k == 1 {
                (svd_rank_for_compression(c, s, alpha), LayerShape::linear(c, s))
            } else {
                (tucker_rank_eq5(c, s, k, alpha, 1.0), LayerShape::conv(c, s, k))
            };
            if r1 <= 1 {
                return true; // degenerate band: nothing to check
            }
            // floor() ⇒ achieved ratio ≥ α (slack for integer effects)
            compression_ratio(&shape, r1, r1) >= alpha * 0.9
        },
    );
}

#[test]
fn prop_eq6_strictly_tightens() {
    forall(
        cfg(200, 108),
        |r: &mut Rng| {
            let c = 32 + r.below(480);
            let s = 32 + r.below(480);
            (c, s)
        },
        |&(c, s)| {
            let r5 = tucker_rank_eq5(c, s, 3, 2.0, 1.0);
            let r6 = tucker_rmin_eq6(c, s, 3, 2.0, 1.0);
            r6 <= r5 && decomposed_params(&LayerShape::conv(c, s, 3), r6, r6)
                <= decomposed_params(&LayerShape::conv(c, s, 3), r5, r5)
        },
    );
}
