//! Integration: the device-resident training engine against the literal
//! round-trip baseline over real artifacts, and the overlapped pipeline
//! against the serial resident engine.
//!
//! Claims pinned here:
//! 1. **Trajectory equivalence** — buffer-chained stepping runs the same
//!    executables on the same batches in the same order, so the per-epoch
//!    loss / train-acc / test-acc trajectory matches the literal baseline
//!    bit-for-bit (asserted within a strict f32 tolerance), for all three
//!    freeze modes.
//! 2. **Upload-free rebinding** — a sequential-freeze run's a↔b epoch
//!    transitions re-bind the resident buffers; the engine's parameter
//!    upload count never moves past the initial upload.
//! 3. **Pipelined equivalence** — the overlapped epoch (double-buffered
//!    uploads, split dispatch/fetch, on-device metrics, side-thread eval)
//!    produces *bit-identical* parameters and metrics to the serial
//!    resident path, for all three freeze modes.
//! 4. **Host-sync budget** — the pipelined engine performs exactly one
//!    counted metric fetch per epoch (vs 2 scalars per step serially), and
//!    uploads nothing beyond the per-step x/y data, the cached lr, the
//!    accumulator masks and its per-epoch zero-reset.

use lrta::checkpoint;
use lrta::coordinator::{decompose_checkpoint, LrSchedule, TrainConfig, Trainer};
use lrta::freeze::FreezeMode;
use lrta::runtime::{Manifest, Runtime};

fn manifest() -> Option<Manifest> {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
    if !path.exists() {
        eprintln!("skipping: artifacts missing");
        return None;
    }
    Some(Manifest::load(path).unwrap())
}

fn cfg(freeze: FreezeMode, epochs: usize, resident: bool, pipelined: bool) -> TrainConfig {
    TrainConfig {
        model: "resnet_mini".into(),
        variant: "lrd".into(),
        freeze,
        epochs,
        lr: LrSchedule::Fixed(5e-3),
        train_size: 128,
        test_size: 128,
        seed: 0,
        verbose: false,
        resident,
        pipelined,
    }
}

fn lrd_params(m: &Manifest) -> lrta::checkpoint::Params {
    let dense = checkpoint::load(m.init_checkpoint("resnet_mini").unwrap()).unwrap();
    decompose_checkpoint(&dense, m.config("resnet_mini", "lrd").unwrap())
        .unwrap()
        .params
}

#[test]
fn resident_matches_literal_trajectory_for_all_freeze_modes() {
    let Some(m) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let params = lrd_params(&m);

    for mode in [FreezeMode::None, FreezeMode::Regular, FreezeMode::Sequential] {
        let mut lit = Trainer::new(&rt, &m, cfg(mode, 2, false, false), params.clone()).unwrap();
        let lit_rec = lit.run().unwrap();
        let mut res = Trainer::new(&rt, &m, cfg(mode, 2, true, false), params.clone()).unwrap();
        let res_rec = res.run().unwrap();

        assert_eq!(lit_rec.epochs.len(), res_rec.epochs.len());
        for (l, r) in lit_rec.epochs.iter().zip(&res_rec.epochs) {
            assert_eq!(l.freeze_pattern, r.freeze_pattern);
            assert!(
                (l.loss - r.loss).abs() <= 1e-6 * l.loss.abs().max(1.0),
                "{mode:?} epoch {}: loss {} vs {}",
                l.epoch,
                l.loss,
                r.loss
            );
            assert!(
                (l.train_acc - r.train_acc).abs() < 1e-9,
                "{mode:?} epoch {}: train_acc {} vs {}",
                l.epoch,
                l.train_acc,
                r.train_acc
            );
            assert!(
                (l.test_acc - r.test_acc).abs() < 1e-9,
                "{mode:?} epoch {}: test_acc {} vs {}",
                l.epoch,
                l.test_acc,
                r.test_acc
            );
        }

        // the synced-back final state matches the literal path's in-place
        // state within strict f32 tolerance
        for (name, lt) in &lit.params {
            let rt_t = &res.params[name];
            assert_eq!(lt.shape(), rt_t.shape(), "{mode:?}: shape of {name}");
            for (a, b) in lt.data().iter().zip(rt_t.data()) {
                assert!(
                    (a - b).abs() <= 1e-6 * a.abs().max(1.0),
                    "{mode:?}: param {name} diverged ({a} vs {b})"
                );
            }
        }
    }
}

#[test]
fn pipelined_matches_serial_resident_bit_for_bit() {
    let Some(m) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let params = lrd_params(&m);

    for mode in [FreezeMode::None, FreezeMode::Regular, FreezeMode::Sequential] {
        let mut serial = Trainer::new(&rt, &m, cfg(mode, 2, true, false), params.clone()).unwrap();
        let serial_rec = serial.run().unwrap();
        let mut pipe = Trainer::new(&rt, &m, cfg(mode, 2, true, true), params.clone()).unwrap();
        let pipe_rec = pipe.run().unwrap();

        // overlap is pure scheduling: same executables, same batches, same
        // order, and the on-device f32 metric accumulation performs the
        // exact IEEE adds the serial host loop performs — bit-identical
        assert_eq!(serial_rec.epochs.len(), pipe_rec.epochs.len());
        for (s, p) in serial_rec.epochs.iter().zip(&pipe_rec.epochs) {
            assert_eq!(s.freeze_pattern, p.freeze_pattern);
            assert_eq!(
                s.loss.to_bits(),
                p.loss.to_bits(),
                "{mode:?} epoch {}: loss {} vs {}",
                s.epoch,
                s.loss,
                p.loss
            );
            assert_eq!(
                s.train_acc.to_bits(),
                p.train_acc.to_bits(),
                "{mode:?} epoch {}: train_acc {} vs {}",
                s.epoch,
                s.train_acc,
                p.train_acc
            );
            assert_eq!(
                s.test_acc.to_bits(),
                p.test_acc.to_bits(),
                "{mode:?} epoch {}: test_acc {} vs {} (overlapped eval must \
                 reproduce the inline eval exactly)",
                s.epoch,
                s.test_acc,
                p.test_acc
            );
        }
        for (name, st) in &serial.params {
            let pt = &pipe.params[name];
            assert_eq!(st.shape(), pt.shape(), "{mode:?}: shape of {name}");
            assert_eq!(
                st.data(),
                pt.data(),
                "{mode:?}: param {name} diverged between serial and pipelined"
            );
        }
        for (name, st) in &serial.momenta {
            assert_eq!(
                st.data(),
                pipe.momenta[name].data(),
                "{mode:?}: momentum {name} diverged between serial and pipelined"
            );
        }
    }
}

#[test]
fn sequential_pattern_swaps_perform_zero_parameter_reuploads() {
    let Some(m) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let params = lrd_params(&m);

    // 3 epochs = patterns a, b, a — two a↔b rebinds; serial resident path
    // (the pipelined budget has its own test below)
    let mut tr =
        Trainer::new(&rt, &m, cfg(FreezeMode::Sequential, 3, true, false), params).unwrap();
    let uploads_before = tr.param_uploads().expect("resident engine active");
    assert!(uploads_before > 0, "initial state upload must be counted");
    let total_before = tr.runtime().uploads();
    let record = tr.run().unwrap();
    assert_eq!(record.epochs.len(), 3);
    assert_eq!(record.epochs[0].freeze_pattern, "a");
    assert_eq!(record.epochs[1].freeze_pattern, "b");
    assert_eq!(
        tr.param_uploads().unwrap(),
        uploads_before,
        "steps and pattern swaps must chain buffer-to-buffer: no parameter re-uploads"
    );
    assert_eq!(
        tr.runtime().demux_fallbacks(),
        0,
        "step outputs must demux into per-leaf device buffers, not host round-trips"
    );
    // the exact upload budget of the run: every host→device transfer flows
    // through Runtime::upload, so "zero parameter re-uploads" is pinned by
    // accounting for each data upload — x and y per step, one lr scalar
    // (fixed schedule, cached), x per eval batch — with nothing left over
    let epochs = 3;
    let train_batch = m.artifact("resnet_mini_lrd_train_a").unwrap().batch;
    let infer_batch = m.artifact("resnet_mini_lrd_infer").unwrap().batch;
    let steps_per_epoch = 128 / train_batch;
    let eval_batches = 128 / infer_batch;
    let lr_uploads = usize::from(steps_per_epoch > 0);
    let expected_data = epochs * steps_per_epoch * 2 + lr_uploads + epochs * eval_batches;
    assert_eq!(
        tr.runtime().uploads() - total_before,
        expected_data,
        "only per-step/eval data may cross the host boundary during a resident run"
    );
}

#[test]
fn pipelined_run_fetches_once_per_epoch_and_uploads_only_data() {
    let Some(m) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let params = lrd_params(&m);

    let epochs = 3;
    let mut tr =
        Trainer::new(&rt, &m, cfg(FreezeMode::Sequential, epochs, true, true), params).unwrap();
    let uploads_before = tr.runtime().uploads();
    let fetches_before = tr.runtime().fetches();
    let param_uploads_before = tr.param_uploads().unwrap();
    tr.run().unwrap();

    let train_batch = m.artifact("resnet_mini_lrd_train_a").unwrap().batch;
    let steps_per_epoch = 128 / train_batch;
    assert!(steps_per_epoch >= 2, "need ≥2 steps/epoch to exercise the overlap");

    // host-sync budget: the serial engine syncs 2 scalars per step; the
    // pipelined engine fetches the metrics accumulator once per epoch —
    // and nothing else on the counted channel
    assert_eq!(
        tr.runtime().fetches() - fetches_before,
        epochs,
        "pipelined training must perform exactly one counted fetch per epoch"
    );

    // upload budget: x+y per step, one lr scalar, one accumulator zero-reset
    // per epoch. Eval runs on the side worker's own client, so it adds
    // nothing here; the accumulator masks uploaded at Trainer::new (before
    // this window). Parameters never re-upload.
    let expected = epochs * steps_per_epoch * 2 + 1 + epochs;
    assert_eq!(
        tr.runtime().uploads() - uploads_before,
        expected,
        "pipelined run may upload only per-step data + lr + per-epoch metric resets"
    );
    assert_eq!(
        tr.param_uploads().unwrap(),
        param_uploads_before,
        "overlap must not break buffer-to-buffer chaining"
    );
    assert_eq!(tr.runtime().demux_fallbacks(), 0);
}

#[test]
fn epoch_checkpoints_persist_async_and_match_serial_path() {
    let Some(m) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let params = lrd_params(&m);

    let base_dir = std::env::temp_dir().join("lrta_epoch_ckpt_test");
    let _ = std::fs::remove_dir_all(&base_dir);
    let dir_pipe = base_dir.join("pipelined");
    let dir_serial = base_dir.join("serial");

    // overlapped: the eval snapshot doubles as the async checkpoint source;
    // 3 sequential epochs cross an a→b→a pattern rebind
    let epochs = 3;
    let mut pipe =
        Trainer::new(&rt, &m, cfg(FreezeMode::Sequential, epochs, true, true), params.clone())
            .unwrap();
    pipe.checkpoint_epochs_to(&dir_pipe);
    pipe.run().unwrap();

    // serial resident reference: same snapshots, written through the same
    // writer but with no overlap to hide behind
    let mut serial =
        Trainer::new(&rt, &m, cfg(FreezeMode::Sequential, epochs, true, false), params).unwrap();
    serial.checkpoint_epochs_to(&dir_serial);
    serial.run().unwrap();

    for e in 0..epochs {
        let name = format!("epoch_{e:03}.bin");
        let a = std::fs::read(dir_pipe.join(&name)).unwrap_or_else(|err| {
            panic!("pipelined run must have written {name}: {err}")
        });
        let b = std::fs::read(dir_serial.join(&name)).unwrap_or_else(|err| {
            panic!("serial run must have written {name}: {err}")
        });
        assert_eq!(
            a, b,
            "epoch {e}: async (pipelined) checkpoint must be byte-identical to the \
             serial path's"
        );
    }

    // the last epoch's checkpoint is exactly the run's final state
    let last = checkpoint::load(dir_pipe.join(format!("epoch_{:03}.bin", epochs - 1))).unwrap();
    assert_eq!(last.len(), pipe.params.len());
    for (name, t) in &pipe.params {
        assert_eq!(last[name].shape(), t.shape(), "shape of {name}");
        assert_eq!(
            last[name].data(),
            t.data(),
            "checkpoint of {name} must equal the synced final parameters"
        );
    }
}

#[test]
fn registry_snapshot_matches_runtime_counters_exactly() {
    let Some(m) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let reg = lrta::obs::Registry::new();
    rt.register_metrics(&reg, &[]).unwrap();
    let params = lrd_params(&m);

    let mut tr =
        Trainer::new(&rt, &m, cfg(FreezeMode::Sequential, 2, true, true), params).unwrap();
    let tracer = lrta::obs::Tracer::enabled();
    tr.set_tracer(tracer.clone());
    tr.run().unwrap();

    // the registry indexes the SAME atomics the runtime increments, so the
    // snapshot must equal the hand-rolled accessors bit-for-bit — no
    // tolerance, no double bookkeeping
    let snap = reg.snapshot();
    assert_eq!(snap.scalar("runtime", "uploads", &[]), Some(rt.uploads() as u64));
    assert_eq!(snap.scalar("runtime", "fetches", &[]), Some(rt.fetches() as u64));
    assert_eq!(
        snap.scalar("runtime", "demux_fallbacks", &[]),
        Some(rt.demux_fallbacks() as u64)
    );
    // and identically through the Prometheus text round-trip
    let parsed = lrta::obs::parse_prometheus(&snap.prometheus_text()).unwrap();
    assert_eq!(parsed["lrta_runtime_uploads"], rt.uploads() as f64);
    assert_eq!(parsed["lrta_runtime_fetches"], rt.fetches() as f64);

    // the trace covers the pipelined train lifecycle: prefetch_wait →
    // upload → dispatch → fetch per step, freeze_swap at epoch boundaries,
    // eval on the side worker
    let names: std::collections::BTreeSet<&str> =
        tracer.events().iter().map(|e| e.name).collect();
    for expected in ["prefetch_wait", "upload", "dispatch", "fetch", "freeze_swap", "eval"] {
        assert!(names.contains(expected), "missing train span '{expected}' in {names:?}");
    }
    assert!(tracer.events().iter().all(|e| e.cat == "train"));
}

#[test]
fn infer_fps_runs_on_resident_params_for_both_paths() {
    let Some(m) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let params = lrd_params(&m);
    // engine-backed
    let tr = Trainer::new(&rt, &m, cfg(FreezeMode::None, 1, true, true), params.clone()).unwrap();
    assert!(tr.infer_fps(2).unwrap() > 0.0);
    // literal baseline: a temporary resident set is uploaded once
    let tr2 = Trainer::new(&rt, &m, cfg(FreezeMode::None, 1, false, false), params).unwrap();
    assert!(tr2.infer_fps(2).unwrap() > 0.0);
}
