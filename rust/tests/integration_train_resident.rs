//! Integration: the device-resident training engine against the literal
//! round-trip baseline over real artifacts.
//!
//! Two claims pinned here:
//! 1. **Trajectory equivalence** — buffer-chained stepping runs the same
//!    executables on the same batches in the same order, so the per-epoch
//!    loss / train-acc / test-acc trajectory matches the literal baseline
//!    bit-for-bit (asserted within a strict f32 tolerance), for all three
//!    freeze modes.
//! 2. **Upload-free rebinding** — a sequential-freeze run's a↔b epoch
//!    transitions re-bind the resident buffers; the engine's parameter
//!    upload count never moves past the initial upload.

use lrta::checkpoint;
use lrta::coordinator::{decompose_checkpoint, LrSchedule, TrainConfig, Trainer};
use lrta::freeze::FreezeMode;
use lrta::runtime::{Manifest, Runtime};

fn manifest() -> Option<Manifest> {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
    if !path.exists() {
        eprintln!("skipping: artifacts missing");
        return None;
    }
    Some(Manifest::load(path).unwrap())
}

fn cfg(freeze: FreezeMode, epochs: usize, resident: bool) -> TrainConfig {
    TrainConfig {
        model: "resnet_mini".into(),
        variant: "lrd".into(),
        freeze,
        epochs,
        lr: LrSchedule::Fixed(5e-3),
        train_size: 128,
        test_size: 128,
        seed: 0,
        verbose: false,
        resident,
    }
}

fn lrd_params(m: &Manifest) -> lrta::checkpoint::Params {
    let dense = checkpoint::load(m.init_checkpoint("resnet_mini").unwrap()).unwrap();
    decompose_checkpoint(&dense, m.config("resnet_mini", "lrd").unwrap())
        .unwrap()
        .params
}

#[test]
fn resident_matches_literal_trajectory_for_all_freeze_modes() {
    let Some(m) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let params = lrd_params(&m);

    for mode in [FreezeMode::None, FreezeMode::Regular, FreezeMode::Sequential] {
        let mut lit = Trainer::new(&rt, &m, cfg(mode, 2, false), params.clone()).unwrap();
        let lit_rec = lit.run().unwrap();
        let mut res = Trainer::new(&rt, &m, cfg(mode, 2, true), params.clone()).unwrap();
        let res_rec = res.run().unwrap();

        assert_eq!(lit_rec.epochs.len(), res_rec.epochs.len());
        for (l, r) in lit_rec.epochs.iter().zip(&res_rec.epochs) {
            assert_eq!(l.freeze_pattern, r.freeze_pattern);
            assert!(
                (l.loss - r.loss).abs() <= 1e-6 * l.loss.abs().max(1.0),
                "{mode:?} epoch {}: loss {} vs {}",
                l.epoch,
                l.loss,
                r.loss
            );
            assert!(
                (l.train_acc - r.train_acc).abs() < 1e-9,
                "{mode:?} epoch {}: train_acc {} vs {}",
                l.epoch,
                l.train_acc,
                r.train_acc
            );
            assert!(
                (l.test_acc - r.test_acc).abs() < 1e-9,
                "{mode:?} epoch {}: test_acc {} vs {}",
                l.epoch,
                l.test_acc,
                r.test_acc
            );
        }

        // the synced-back final state matches the literal path's in-place
        // state within strict f32 tolerance
        for (name, lt) in &lit.params {
            let rt_t = &res.params[name];
            assert_eq!(lt.shape(), rt_t.shape(), "{mode:?}: shape of {name}");
            for (a, b) in lt.data().iter().zip(rt_t.data()) {
                assert!(
                    (a - b).abs() <= 1e-6 * a.abs().max(1.0),
                    "{mode:?}: param {name} diverged ({a} vs {b})"
                );
            }
        }
    }
}

#[test]
fn sequential_pattern_swaps_perform_zero_parameter_reuploads() {
    let Some(m) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let params = lrd_params(&m);

    // 3 epochs = patterns a, b, a — two a↔b rebinds
    let mut tr = Trainer::new(&rt, &m, cfg(FreezeMode::Sequential, 3, true), params).unwrap();
    let uploads_before = tr.param_uploads().expect("resident engine active");
    assert!(uploads_before > 0, "initial state upload must be counted");
    let total_before = tr.runtime().uploads();
    let record = tr.run().unwrap();
    assert_eq!(record.epochs.len(), 3);
    assert_eq!(record.epochs[0].freeze_pattern, "a");
    assert_eq!(record.epochs[1].freeze_pattern, "b");
    assert_eq!(
        tr.param_uploads().unwrap(),
        uploads_before,
        "steps and pattern swaps must chain buffer-to-buffer: no parameter re-uploads"
    );
    assert_eq!(
        tr.runtime().demux_fallbacks(),
        0,
        "step outputs must demux into per-leaf device buffers, not host round-trips"
    );
    // the exact upload budget of the run: every host→device transfer flows
    // through Runtime::upload, so "zero parameter re-uploads" is pinned by
    // accounting for each data upload — x and y per step, one lr scalar
    // (fixed schedule, cached), x per eval batch — with nothing left over
    let epochs = 3;
    let train_batch = m.artifact("resnet_mini_lrd_train_a").unwrap().batch;
    let infer_batch = m.artifact("resnet_mini_lrd_infer").unwrap().batch;
    let steps_per_epoch = 128 / train_batch;
    let eval_batches = 128 / infer_batch;
    let lr_uploads = usize::from(steps_per_epoch > 0);
    let expected_data = epochs * steps_per_epoch * 2 + lr_uploads + epochs * eval_batches;
    assert_eq!(
        tr.runtime().uploads() - total_before,
        expected_data,
        "only per-step/eval data may cross the host boundary during a resident run"
    );
}

#[test]
fn infer_fps_runs_on_resident_params_for_both_paths() {
    let Some(m) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let params = lrd_params(&m);
    // engine-backed
    let tr = Trainer::new(&rt, &m, cfg(FreezeMode::None, 1, true), params.clone()).unwrap();
    assert!(tr.infer_fps(2).unwrap() > 0.0);
    // literal baseline: a temporary resident set is uploaded once
    let tr2 = Trainer::new(&rt, &m, cfg(FreezeMode::None, 1, false), params).unwrap();
    assert!(tr2.infer_fps(2).unwrap() > 0.0);
}
