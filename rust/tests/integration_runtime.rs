//! Integration: PJRT runtime against the real AOT artifacts.
//!
//! Requires `make artifacts` to have run (skips gracefully otherwise so
//! `cargo test` stays green on a fresh checkout before the build step).

use lrta::checkpoint;
use lrta::coordinator::{decompose_checkpoint, run_train_step, zero_momenta};
use lrta::data::Dataset;
use lrta::runtime::{literal_to_tensor, Manifest, Runtime};

fn manifest() -> Option<Manifest> {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
    if !path.exists() {
        eprintln!("skipping: artifacts/manifest.json missing (run `make artifacts`)");
        return None;
    }
    Some(Manifest::load(path).expect("manifest parses"))
}

#[test]
fn manifest_lists_all_variants() {
    let Some(m) = manifest() else { return };
    for model in ["resnet_mini", "vit_mini"] {
        for variant in ["orig", "lrd", "rankopt"] {
            assert!(m.artifacts.contains_key(&format!("{model}_{variant}_infer")));
            assert!(m
                .artifacts
                .contains_key(&format!("{model}_{variant}_train_none")));
        }
        for variant in ["lrd", "rankopt"] {
            for p in ["a", "b"] {
                assert!(m
                    .artifacts
                    .contains_key(&format!("{model}_{variant}_train_{p}")));
            }
        }
        assert!(m.init_checkpoint(model).unwrap().exists());
    }
}

/// Runtime-level pipeline primitives — needs a PJRT client but no AOT
/// artifacts, so it runs even on checkouts without `make artifacts`:
/// the dispatch/fetch split must hand back usable buffers, and the
/// builder-built metrics-accumulate computation must chain buffer-to-buffer
/// with exact f32 sums and exactly one counted fetch at the end.
#[test]
fn dispatch_fetch_split_and_metrics_accumulate_chain_on_device() {
    let rt = Runtime::cpu().unwrap();
    let comp = lrta::runtime::builder::metrics_accumulate_computation().unwrap();
    let acc_exe = rt.compile(&comp, "metrics_acc").unwrap();
    let e_loss = rt.upload(&xla::Literal::vec1(&[1.0f32, 0.0])).unwrap();
    let e_correct = rt.upload(&xla::Literal::vec1(&[0.0f32, 1.0])).unwrap();
    let mut acc = rt.upload(&xla::Literal::vec1(&[0.0f32, 0.0])).unwrap();

    let fetches0 = rt.fetches();
    for i in 0..5 {
        let loss = rt.upload_scalar(0.5 + i as f32).unwrap();
        let correct = rt.upload_scalar(i as f32).unwrap();
        // dispatch (non-blocking) … fetch (demux) — the split pair the
        // pipelined engines are built on
        let inflight = acc_exe
            .dispatch_buffers(&[&acc, &loss, &correct, &e_loss, &e_correct], 1)
            .unwrap();
        let mut outs = inflight.fetch(&rt).unwrap();
        assert_eq!(outs.len(), 1);
        acc = outs.swap_remove(0); // buffer-to-buffer chaining, no host sync
    }
    assert_eq!(rt.fetches(), fetches0, "accumulation must not touch the host");
    let sums = rt.fetch_f32s(&acc).unwrap();
    // integer-valued and half-integer f32 sums are exact
    assert_eq!(sums, vec![12.5, 10.0]);
    assert_eq!(rt.fetches(), fetches0 + 1, "one counted fetch for the epoch");
}

#[test]
fn infer_artifact_runs_and_is_deterministic() {
    let Some(m) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let meta = m.artifact("resnet_mini_orig_infer").unwrap();
    let exe = rt.load_hlo(m.hlo_path(meta)).unwrap();

    let params = checkpoint::load(m.init_checkpoint("resnet_mini").unwrap()).unwrap();
    let data = Dataset::synthetic(meta.batch, 42);
    let (xs, _) = data.batch(0, meta.batch);

    let run_once = || {
        let mut inputs = Vec::new();
        for slot in &meta.trainable {
            let t = &params[&slot.name];
            assert_eq!(t.shape(), &slot.shape[..], "{} shape", slot.name);
            inputs.push(lrta::runtime::tensor_to_literal(t).unwrap());
        }
        let dims: Vec<i64> = meta.x_shape.iter().map(|&d| d as i64).collect();
        inputs.push(xla::Literal::vec1(&xs).reshape(&dims).unwrap());
        let out = exe.run(&inputs).unwrap();
        literal_to_tensor(&out[0]).unwrap()
    };
    let logits1 = run_once();
    let logits2 = run_once();
    assert_eq!(logits1.shape(), &[meta.batch, 10]);
    assert_eq!(logits1, logits2, "inference must be deterministic");
    assert!(logits1.data().iter().all(|v| v.is_finite()));
}

#[test]
fn train_step_reduces_loss_and_respects_freezing() {
    let Some(m) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();

    // decompose the init checkpoint for the lrd variant
    let dense = checkpoint::load(m.init_checkpoint("resnet_mini").unwrap()).unwrap();
    let cfg = m.config("resnet_mini", "lrd").unwrap();
    let outcome = decompose_checkpoint(&dense, cfg).unwrap();
    let mut params = outcome.params;
    let mut momenta = zero_momenta(&params);
    assert!(outcome.layers_decomposed > 5);

    let meta = m.artifact("resnet_mini_lrd_train_a").unwrap();
    let exe = rt.load_hlo(m.hlo_path(meta)).unwrap();

    let frozen_before: Vec<_> = meta
        .frozen
        .iter()
        .map(|s| (s.name.clone(), params[&s.name].clone()))
        .collect();

    let data = Dataset::synthetic(meta.batch * 4, 7);
    let mut losses = Vec::new();
    for step in 0..8 {
        let (xs, ys) = data.batch((step % 4) * meta.batch, meta.batch);
        let (loss, correct) =
            run_train_step(&exe, meta, &mut params, &mut momenta, &xs, &ys, 0.05).unwrap();
        assert!(loss.is_finite());
        assert!(correct >= 0.0 && correct <= meta.batch as f32);
        losses.push(loss as f64);
    }
    // training on repeated batches must make progress
    assert!(
        losses.last().unwrap() < &(losses[0] * 0.95),
        "losses {losses:?}"
    );
    // frozen factors are bit-identical after training
    for (name, before) in frozen_before {
        assert_eq!(params[&name], before, "frozen param {name} changed");
    }
}

#[test]
fn pattern_b_trains_the_complement() {
    let Some(m) = manifest() else { return };
    let a = m.artifact("resnet_mini_lrd_train_a").unwrap();
    let b = m.artifact("resnet_mini_lrd_train_b").unwrap();
    let a_frozen: std::collections::BTreeSet<_> =
        a.frozen.iter().map(|s| s.name.clone()).collect();
    let b_frozen: std::collections::BTreeSet<_> =
        b.frozen.iter().map(|s| s.name.clone()).collect();
    assert!(!a_frozen.is_empty() && !b_frozen.is_empty());
    assert!(a_frozen.is_disjoint(&b_frozen), "patterns must not overlap");
    // every factor frozen somewhere is trainable in the other pattern
    for name in &a_frozen {
        assert!(b.trainable.iter().any(|s| &s.name == name), "{name}");
    }
    // pattern-frozen artifacts expose fewer trainables than the full step
    let full = m.artifact("resnet_mini_lrd_train_none").unwrap();
    assert!(a.trainable.len() < full.trainable.len());
    assert!(b.trainable.len() < full.trainable.len());
    assert!(full.frozen.is_empty());
}

#[test]
fn decomposed_params_match_manifest_shapes() {
    let Some(m) = manifest() else { return };
    for model in ["resnet_mini", "vit_mini"] {
        let dense = checkpoint::load(m.init_checkpoint(model).unwrap()).unwrap();
        for variant in ["lrd", "rankopt"] {
            let cfg = m.config(model, variant).unwrap();
            let params = decompose_checkpoint(&dense, cfg).unwrap().params;
            let meta = m.artifact(&format!("{model}_{variant}_infer")).unwrap();
            for slot in &meta.trainable {
                let t = params
                    .get(&slot.name)
                    .unwrap_or_else(|| panic!("{model}/{variant}: missing {}", slot.name));
                assert_eq!(
                    t.shape(),
                    &slot.shape[..],
                    "{model}/{variant}: {} shape mismatch",
                    slot.name
                );
            }
        }
    }
}
