//! Chaos integration: deterministic fault injection against the real
//! artifacts, driving the supervision machinery end to end.
//!
//! Claims pinned here:
//! 1. **Bounded failure** — with eviction off, an injected mid-epoch
//!    replica panic aborts the whole run with the panic's own message in
//!    bounded time (regression for the silent averaging-barrier deadlock:
//!    the survivor used to block forever on a contribution that would
//!    never arrive).
//! 2. **Survivor-only averaging** — an injected panic (or stall past the
//!    barrier deadline) evicts exactly the faulted replica, the run
//!    completes degraded on the survivor, and — on identical shards — the
//!    surviving trajectory and final state are *bit-for-bit* the
//!    single-engine run: a one-member mean is the member itself, so
//!    eviction must not move a single bit.
//! 3. **Coordinator fold-state fallback** — evicting replica 0 (the state
//!    reporter) still yields the exact final state: the coordinator's own
//!    `MeanState` after the last closed barrier *is* the survivors'
//!    resident state.
//! 4. **Serve supervision** — an injected worker panic mid-batch strands
//!    zero requests (every admitted request gets exactly one terminal
//!    answer), the supervisor respawns the worker warm, and the respawned
//!    shard's logits are bit-identical to the pre-death generation.
//! 5. **Bounded swap ack** — a stalled swap acknowledgement surfaces as a
//!    timeout error instead of wedging `swap_variant`, and the shard keeps
//!    serving.
//! 6. **Hedged tails** — a dispatch stalled past the hedge budget is
//!    re-dispatched on the sibling shard; the first answer wins
//!    bit-identically to a direct run, the loser is cancelled, and no
//!    request is lost or double-replied.
//! 7. **Storage put failure** — an injected `storage_put` error under the
//!    async checkpoint writer fails the drain with the fault's own
//!    message, in bounded time: no wedged worker, no silently-dropped
//!    checkpoint.
//! 8. **Storage get stall** — an injected `storage_get` stall under a
//!    streamed corpus is absorbed by the prefetcher's fetch-ahead window:
//!    every batch arrives, bit-identical to the unstalled run.
//!
//! The fault plan is process-global, so every test serializes on a local
//! mutex and installs/clears its plan under an RAII guard.

use lrta::checkpoint;
use lrta::coordinator::{decompose_checkpoint, LrSchedule, TrainConfig, Trainer};
use lrta::data::{publish, Dataset, Shard, StreamingProvider, IMAGE_ELEMS};
use lrta::faults;
use lrta::freeze::FreezeMode;
use lrta::runtime::{literal_to_tensor, tensor_to_literal, Manifest, Runtime};
use lrta::serve::{HedgeConfig, QosConfig, Server, ServerConfig, ServeError, VariantSpec};
use lrta::storage::{MemObject, Storage};
use lrta::train::{
    run_replicas, CheckpointWriter, MomentumPolicy, Prefetcher, ReplicaConfig, SyncCompress,
};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Serializes the tests: the installed fault plan is process-global.
static LOCK: Mutex<()> = Mutex::new(());

/// Install a plan for the duration of one test; clears it even when an
/// assertion unwinds, so a failing test cannot leak directives into the
/// next one.
struct PlanGuard;

impl Drop for PlanGuard {
    fn drop(&mut self) {
        faults::clear();
    }
}

fn arm(spec: &str) -> PlanGuard {
    faults::install(faults::Plan::parse(spec).expect("test fault spec parses"));
    PlanGuard
}

fn lock() -> std::sync::MutexGuard<'static, ()> {
    // a previous test's assertion failure must not poison the whole suite
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn manifest() -> Option<Manifest> {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
    if !path.exists() {
        eprintln!("skipping: artifacts missing");
        return None;
    }
    Some(Manifest::load(path).unwrap())
}

fn cfg(epochs: usize) -> TrainConfig {
    TrainConfig {
        model: "resnet_mini".into(),
        variant: "lrd".into(),
        freeze: FreezeMode::Sequential,
        epochs,
        lr: LrSchedule::Fixed(5e-3),
        train_size: 128,
        test_size: 128,
        seed: 0,
        verbose: false,
        resident: true,
        pipelined: false,
    }
}

fn lrd_params(m: &Manifest) -> checkpoint::Params {
    let dense = checkpoint::load(m.init_checkpoint("resnet_mini").unwrap()).unwrap();
    decompose_checkpoint(&dense, m.config("resnet_mini", "lrd").unwrap()).unwrap().params
}

/// Steps per epoch of the test config (epoch 0 compiles pattern `a`).
fn steps_per_epoch(m: &Manifest) -> usize {
    128 / m.artifact("resnet_mini_lrd_train_a").unwrap().batch
}

/// The identical-shard eviction rig: 2 replicas, per-step averaging, so a
/// one-member barrier mean is the survivor's own state bit-for-bit.
fn eviction_rcfg() -> ReplicaConfig {
    ReplicaConfig {
        replicas: 2,
        avg_every: 1,
        momenta: MomentumPolicy::Average,
        compress: SyncCompress::Exact,
        identical_shards: true,
        ..Default::default()
    }
}

#[test]
fn plans_arm_and_clear_globally() {
    let _g = lock();
    faults::clear();
    assert!(!faults::armed(), "no plan installed must mean disarmed seams");
    faults::install(faults::Plan::parse("").unwrap());
    assert!(!faults::armed(), "an empty plan must disarm, not arm");
    {
        let _plan = arm("dispatch@nowhere:panic");
        assert!(faults::armed());
        assert_eq!(faults::fired(), 0, "nothing hit the seam yet");
    }
    assert!(!faults::armed(), "the guard must clear the plan on drop");
}

/// Satellite regression: before the `catch_unwind` → [`Died`] report, a
/// replica panicking mid-epoch left the survivor blocked forever inside
/// the averaging barrier. With eviction off the run must now abort with
/// the panic's own message — quickly, not after a test-harness timeout.
#[test]
fn replica_panic_with_eviction_off_fails_in_bounded_time() {
    let _g = lock();
    let Some(m) = manifest() else { return };
    let params = lrd_params(&m);
    let _plan = arm("barrier_send@replica1:panic@step2");

    let rcfg = ReplicaConfig { evict: false, ..eviction_rcfg() };
    let t0 = Instant::now();
    let err = run_replicas(&m, &cfg(2), &rcfg, &params)
        .err()
        .expect("a replica panic with --no-evict must abort the run");
    let elapsed = t0.elapsed();
    let msg = format!("{err:#}");
    assert!(msg.contains("replica 1"), "error must name the dead replica: {msg}");
    assert!(msg.contains("injected fault"), "error must carry the panic payload: {msg}");
    assert!(
        elapsed < Duration::from_secs(120),
        "abort took {elapsed:?} — the barrier deadlock is back"
    );
    assert_eq!(faults::fired(), 1);
}

#[test]
fn injected_panic_evicts_replica_and_survivor_finishes_bit_for_bit() {
    let _g = lock();
    let Some(m) = manifest() else { return };
    let params = lrd_params(&m);
    assert!(steps_per_epoch(&m) >= 2, "need ≥2 steps/epoch to die mid-run");

    // reference first: the single-engine serial trajectory (no faults)
    let epochs = 2;
    let rt = Runtime::cpu().unwrap();
    let mut base = Trainer::new(&rt, &m, cfg(epochs), params.clone()).unwrap();
    let base_rec = base.run().unwrap();

    // kill replica 1 at its second averaging barrier (epoch 0, step 2)
    let _plan = arm("barrier_send@replica1:panic@step2");
    let run = run_replicas(&m, &cfg(epochs), &eviction_rcfg(), &params)
        .expect("supervised run must survive one replica death");

    assert!(run.record.degraded());
    assert_eq!(run.record.evictions.len(), 1);
    let ev = &run.record.evictions[0];
    assert_eq!(ev.replica, 1);
    assert_eq!(ev.survivors, 1);
    assert!(ev.reason.contains("injected fault"), "reason: {}", ev.reason);
    // the heartbeat trail dates the death: epoch 0, step 2 (the hook
    // beats before the barrier that killed it)
    assert_eq!((ev.last_epoch, ev.last_step), (0, 2));
    assert_eq!(faults::fired(), 1);
    // only the survivor reports
    assert_eq!(run.reports.len(), 1);
    assert_eq!(run.reports[0].replica, 0);

    // identical shards: a one-member mean is the member itself, so the
    // degraded run must be the single-engine run bit-for-bit
    assert_eq!(base_rec.epochs.len(), run.record.epochs.len());
    for (b, r) in base_rec.epochs.iter().zip(&run.record.epochs) {
        assert_eq!(b.loss.to_bits(), r.loss.to_bits(), "epoch {}: loss", b.epoch);
        assert_eq!(b.train_acc.to_bits(), r.train_acc.to_bits(), "epoch {}", b.epoch);
        assert_eq!(b.test_acc.to_bits(), r.test_acc.to_bits(), "epoch {}", b.epoch);
    }
    for (name, t) in &base.params {
        assert_eq!(t.data(), run.params[name].data(), "param {name} moved under eviction");
    }
    for (name, t) in &base.momenta {
        assert_eq!(t.data(), run.momenta[name].data(), "momentum {name} moved under eviction");
    }
}

/// A replica that stalls past the barrier deadline is evicted as a
/// straggler — same survivor-only close, same bit-for-bit trajectory —
/// and its late zombie contribution is discarded, not folded in.
#[test]
fn stalled_replica_misses_deadline_and_is_evicted() {
    let _g = lock();
    let Some(m) = manifest() else { return };
    let params = lrd_params(&m);

    let epochs = 2;
    let rt = Runtime::cpu().unwrap();
    let mut base = Trainer::new(&rt, &m, cfg(epochs), params.clone()).unwrap();
    let base_rec = base.run().unwrap();

    // replica 1 naps 2s at its second barrier send; the coordinator's
    // 250ms deadline diagnoses it long before the contribution lands
    let _plan = arm("barrier_send@replica1:stall(2s)@step2");
    let rcfg =
        ReplicaConfig { barrier_timeout: Duration::from_millis(250), ..eviction_rcfg() };
    let run = run_replicas(&m, &cfg(epochs), &rcfg, &params)
        .expect("a straggler eviction must not abort the run");

    assert!(run.record.degraded());
    assert_eq!(run.record.evictions.len(), 1);
    let ev = &run.record.evictions[0];
    assert_eq!(ev.replica, 1);
    assert!(ev.reason.contains("deadline"), "reason: {}", ev.reason);
    assert_eq!(faults::fired(), 1);

    // the late frame was dropped: the survivor's math is untouched
    for (b, r) in base_rec.epochs.iter().zip(&run.record.epochs) {
        assert_eq!(b.loss.to_bits(), r.loss.to_bits(), "epoch {}: loss", b.epoch);
        assert_eq!(b.train_acc.to_bits(), r.train_acc.to_bits(), "epoch {}", b.epoch);
        assert_eq!(b.test_acc.to_bits(), r.test_acc.to_bits(), "epoch {}", b.epoch);
    }
    for (name, t) in &base.params {
        assert_eq!(t.data(), run.params[name].data(), "param {name} moved under eviction");
    }
}

/// Evicting replica 0 loses both the evaluator and the state reporter.
/// The record degrades honestly (NaN test accuracy after the death) and
/// the final state comes from the coordinator's own fold state — still
/// bit-for-bit the single-engine run on identical shards.
#[test]
fn replica_zero_eviction_falls_back_to_coordinator_fold_state() {
    let _g = lock();
    let Some(m) = manifest() else { return };
    let params = lrd_params(&m);
    let steps = steps_per_epoch(&m);

    let epochs = 2;
    let rt = Runtime::cpu().unwrap();
    let mut base = Trainer::new(&rt, &m, cfg(epochs), params.clone()).unwrap();
    let base_rec = base.run().unwrap();

    // kill replica 0 at the very last averaging event of the run: the
    // final broadcast mean must still be recoverable from the coordinator
    let last_event = epochs * steps;
    let _plan = arm(&format!("barrier_send@replica0:panic@step{last_event}"));
    let run = run_replicas(&m, &cfg(epochs), &eviction_rcfg(), &params)
        .expect("losing replica 0 must degrade, not abort");

    assert!(run.record.degraded());
    assert_eq!(run.record.evictions.len(), 1);
    assert_eq!(run.record.evictions[0].replica, 0);
    assert_eq!(faults::fired(), 1);
    assert_eq!(run.reports.len(), 1);
    assert_eq!(run.reports[0].replica, 1, "only the survivor reports");

    // epoch 0 finished healthy on both replicas; the final epoch lost its
    // evaluator, so its test accuracy is honestly absent
    assert_eq!(
        base_rec.epochs[0].test_acc.to_bits(),
        run.record.epochs[0].test_acc.to_bits()
    );
    let last = &run.record.epochs[epochs - 1];
    assert!(last.test_acc.is_nan(), "the evaluator died before the last eval");
    for (b, r) in base_rec.epochs.iter().zip(&run.record.epochs) {
        assert_eq!(b.loss.to_bits(), r.loss.to_bits(), "epoch {}: loss", b.epoch);
        assert_eq!(b.train_acc.to_bits(), r.train_acc.to_bits(), "epoch {}", b.epoch);
    }
    // final state via MeanState::final_state — the exact single-engine
    // state, even though no replica downloaded and reported it
    assert_eq!(base.params.len(), run.params.len());
    for (name, t) in &base.params {
        assert_eq!(t.data(), run.params[name].data(), "fold-state param {name} diverged");
    }
    for (name, t) in &base.momenta {
        assert_eq!(t.data(), run.momenta[name].data(), "fold-state momentum {name} diverged");
    }
}

/// Submit until admitted *and* served: rides out the worker-death window,
/// where `submit` can answer `ShardDown` and an admitted request can be
/// drained with a terminal `Shutdown`/`Closed` answer.
fn serve_until_ok(server: &Server, x: &[f32]) -> Vec<f32> {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        assert!(Instant::now() < deadline, "request not served within 120s of retries");
        match server.submit("resnet_mini", "lrd", x.to_vec()) {
            Ok(p) => match p.wait(Duration::from_secs(120)) {
                Ok(r) => return r.logits,
                // stranded by the dying worker generation — resubmit
                Err(ServeError::Shutdown) | Err(ServeError::Closed) => {}
                Err(e) => panic!("unexpected terminal answer: {e:?}"),
            },
            Err(ServeError::ShardDown) | Err(ServeError::QueueFull { .. }) => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => panic!("unexpected submit error: {e:?}"),
        }
    }
}

#[test]
fn worker_panic_drains_stranded_requests_and_respawned_shard_is_bit_identical() {
    let _g = lock();
    let Some(m) = manifest() else { return };
    let params = {
        let dense = checkpoint::load(m.init_checkpoint("resnet_mini").unwrap()).unwrap();
        VariantSpec::from_dense(&m, "resnet_mini", "lrd", &dense).unwrap().params
    };
    let cfg = ServerConfig {
        max_wait: Duration::from_millis(50),
        spot_check: 0,
        ..Default::default()
    };
    let server = Server::start(
        &m,
        vec![VariantSpec::new("resnet_mini", "lrd", params)],
        &cfg,
    )
    .expect("server starts");
    let batch = server.batch_of("resnet_mini", "lrd").unwrap();
    let data = Dataset::synthetic(batch * 2, 42);
    let image = |i: usize| data.images[i * IMAGE_ELEMS..(i + 1) * IMAGE_ELEMS].to_vec();

    // generation 1 serves its first burst cleanly (no plan installed yet) —
    // the bit-identity reference for the respawned generation
    let gen1: Vec<Vec<f32>> = (0..batch).map(|i| serve_until_ok(&server, &image(i))).collect();

    // arm *now*: the very next batch dispatch — however the burst below
    // coalesces — panics mid-flight
    let _plan = arm("dispatch@shard0:panic@step1");

    // the burst triggers the panic: every admitted request must still get
    // exactly one terminal answer — served, or drained with a terminal
    // error — and nothing may hang
    let mut lost: Vec<usize> = Vec::new();
    let mut pendings = Vec::new();
    for i in batch..batch * 2 {
        match server.submit("resnet_mini", "lrd", image(i)) {
            Ok(p) => pendings.push((i, p)),
            // the death can outrun the submit loop; rejected requests are
            // simply retried after the respawn like the drained ones
            Err(ServeError::ShardDown) => lost.push(i),
            Err(e) => panic!("request {i}: unexpected submit error {e:?}"),
        }
    }
    let mut served_in_burst = 0usize;
    for (i, p) in &pendings {
        match p.wait(Duration::from_secs(120)) {
            Ok(_) => served_in_burst += 1,
            Err(ServeError::Shutdown) | Err(ServeError::Closed) => lost.push(*i),
            Err(e) => panic!("request {i}: unexpected terminal answer {e:?}"),
        }
    }
    assert_eq!(faults::fired(), 1, "exactly one injected panic");
    assert_eq!(
        served_in_burst + lost.len(),
        batch,
        "every admitted request owes exactly one terminal outcome"
    );
    assert!(!lost.is_empty(), "a mid-batch panic must strand at least one request");

    // zero end-to-end loss: the stranded inputs are resubmitted and served
    // by the respawned worker
    let retried = lost.len();
    for &i in &lost {
        serve_until_ok(&server, &image(i));
    }
    // bit-identity across the respawn: the same inputs as generation 1
    for (i, reference) in gen1.iter().enumerate() {
        let again = serve_until_ok(&server, &image(i));
        assert_eq!(&again, reference, "request {i}: respawned shard diverged bitwise");
    }

    let snap = server.stats("resnet_mini", "lrd").unwrap();
    assert_eq!(snap.worker_deaths, 1, "one injected death");
    assert_eq!(snap.respawns, 1, "one supervised respawn");
    assert_eq!(
        snap.served,
        (batch + served_in_burst + retried + batch) as u64,
        "served must count every Ok answer and nothing else"
    );
    server.shutdown();
}

/// Direct reference (same shape as integration_serve's): one executable
/// run on `xs`, already padded to the compiled batch.
fn direct_logits(
    m: &Manifest,
    variant: &str,
    params: &checkpoint::Params,
    xs: &[f32],
) -> lrta::tensor::Tensor {
    let rt = Runtime::cpu().unwrap();
    let meta = m.artifact(&format!("resnet_mini_{variant}_infer")).unwrap();
    let exe = rt.load_hlo(m.hlo_path(meta)).unwrap();
    let mut inputs = Vec::new();
    for slot in meta.trainable.iter().chain(meta.frozen.iter()) {
        inputs.push(tensor_to_literal(&params[&slot.name]).unwrap());
    }
    let dims: Vec<i64> = meta.x_shape.iter().map(|&d| d as i64).collect();
    inputs.push(xla::Literal::vec1(xs).reshape(&dims).unwrap());
    let out = exe.run(&inputs).unwrap();
    literal_to_tensor(&out[0]).unwrap()
}

/// Hedge chaos pin: a 400ms dispatch stall on shard 0 trips the hedge
/// governor — the stalled batch is re-dispatched on the sibling shard,
/// the first answer wins and is bit-identical to a direct executable run,
/// the loser is cancelled, and zero requests are lost or double-replied.
#[test]
fn stalled_dispatch_hedges_to_sibling_bit_identically() {
    let _g = lock();
    let Some(m) = manifest() else { return };
    let params = {
        let dense = checkpoint::load(m.init_checkpoint("resnet_mini").unwrap()).unwrap();
        VariantSpec::from_dense(&m, "resnet_mini", "lrd", &dense).unwrap().params
    };
    // shard 0's first dispatch naps 400ms with its batch on the hedge
    // board; the governor's 30ms fallback budget fires long before that
    let _plan = arm("dispatch@shard0:stall(400ms)@step1");
    let qos = QosConfig {
        hedge: Some(HedgeConfig {
            fallback: Duration::from_millis(30),
            ..Default::default()
        }),
        ..Default::default()
    };
    let cfg = ServerConfig {
        max_wait: Duration::from_millis(20),
        spot_check: 0,
        qos: Some(qos),
        ..Default::default()
    };
    let server = Server::start(
        &m,
        vec![VariantSpec::new("resnet_mini", "lrd", params.clone()).with_shards(2)],
        &cfg,
    )
    .expect("server starts");
    let batch = server.batch_of("resnet_mini", "lrd").unwrap();
    let n = batch * 2;
    let data = Dataset::synthetic(n, 61);
    let image = |i: usize| data.images[i * IMAGE_ELEMS..(i + 1) * IMAGE_ELEMS].to_vec();

    let pendings: Vec<_> = (0..n)
        .map(|i| server.submit("resnet_mini", "lrd", image(i)).expect("admitted"))
        .collect();
    let answers: Vec<Vec<f32>> = pendings
        .iter()
        .map(|p| p.wait(Duration::from_secs(120)).expect("served").logits)
        .collect();
    assert_eq!(faults::fired(), 1, "the stall directive fired exactly once");

    // the stalled originals resolve once the nap ends: every hedged pair
    // settles to exactly one winner and one cancelled loser
    let deadline = Instant::now() + Duration::from_secs(30);
    let snap = loop {
        let s = server.stats("resnet_mini", "lrd").unwrap();
        if s.hedge_fired >= 1 && s.hedge_cancelled == s.hedge_fired {
            break s;
        }
        assert!(
            Instant::now() < deadline,
            "hedged pairs never settled: fired={} wins={} cancelled={}",
            s.hedge_fired,
            s.hedge_wins,
            s.hedge_cancelled
        );
        std::thread::sleep(Duration::from_millis(20));
    };
    assert!(snap.hedge_wins >= 1, "the sibling's answer must beat the 400ms stall");
    assert_eq!(snap.served, n as u64, "exactly one Sent per admitted request");
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.shed, 0, "hedging must not shed anything");

    // no double replies: every response channel is spent after one answer
    for (i, p) in pendings.iter().enumerate() {
        assert!(
            p.wait(Duration::from_millis(100)).is_err(),
            "request {i} was answered twice"
        );
    }

    // bit-identity: whichever shard won each race, every answer matches
    // the direct executable run on the same image (rows are independent of
    // batch-mates, so the reference chunking is immaterial)
    for (bi, chunk) in answers.chunks(batch).enumerate() {
        let (xs, _) = data.batch(bi * batch, batch);
        let reference = direct_logits(&m, "lrd", &params, &xs);
        let classes = reference.shape()[1];
        for (i, row) in chunk.iter().enumerate() {
            assert_eq!(
                row,
                &reference.data()[i * classes..(i + 1) * classes].to_vec(),
                "request {}: hedged answer diverged from the direct run",
                bi * batch + i
            );
        }
    }
    server.shutdown();
}

#[test]
fn swap_ack_stall_times_out_without_wedging_the_router() {
    let _g = lock();
    let Some(m) = manifest() else { return };
    let params = {
        let dense = checkpoint::load(m.init_checkpoint("resnet_mini").unwrap()).unwrap();
        VariantSpec::from_dense(&m, "resnet_mini", "lrd", &dense).unwrap().params
    };
    // the first swap ack stalls 1.5s; the router's 200ms bounded wait must
    // answer instead of blocking `swap_variant` forever
    let _plan = arm("swap_ack@shard0:stall(1500ms)");
    let cfg = ServerConfig {
        max_wait: Duration::from_millis(50),
        spot_check: 0,
        swap_timeout: Duration::from_millis(200),
        ..Default::default()
    };
    let server = Server::start(
        &m,
        vec![VariantSpec::new("resnet_mini", "lrd", params.clone())],
        &cfg,
    )
    .expect("server starts");

    let t0 = Instant::now();
    // swapping in the same params keeps the math comparable either way —
    // the timeout is deliberately ambiguous about whether the swap landed
    match server.swap_variant("resnet_mini", "lrd", &params) {
        Err(ServeError::Engine(e)) => {
            assert!(e.contains("timed out"), "expected a bounded-ack timeout, got: {e}")
        }
        other => panic!("expected a swap-ack timeout, got {other:?}"),
    }
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "swap_variant must return on the bounded wait, not the stall"
    );
    assert_eq!(faults::fired(), 1);

    // the shard is merely slow, not dead: it keeps serving, and the next
    // swap (directive already spent) acknowledges cleanly
    let data = Dataset::synthetic(1, 7);
    serve_until_ok(&server, &data.images[..IMAGE_ELEMS]);
    server.swap_variant("resnet_mini", "lrd", &params).expect("post-stall swap applies");
    let snap = server.stats("resnet_mini", "lrd").unwrap();
    assert_eq!(snap.worker_deaths, 0, "a stall is not a death");
    server.shutdown();
}

/// Claim 7: a `storage_put` error under the async checkpoint writer
/// fails the drain with the injected fault's own message — the run that
/// submitted the write fails cleanly and quickly, nothing wedges, and the
/// epochs written before the fault are intact in the store.
#[test]
fn storage_put_error_fails_checkpoint_drain_cleanly() {
    let _g = lock();
    let mut rng = lrta::util::rng::Rng::new(9);
    let mut params = checkpoint::Params::new();
    params.insert("w".into(), lrta::tensor::Tensor::randn(&[4, 4], 1.0, &mut rng));

    let store: Arc<dyn Storage> = Arc::new(MemObject::new());
    // the second put (epoch 1's upload) errors; epoch 0's must land
    let _plan = arm("storage_put@mem:error@step2");
    let mut w = CheckpointWriter::spawn_to(Arc::clone(&store), "ckpts");
    w.submit(0, params.clone()).unwrap();
    w.submit(1, params.clone()).unwrap();

    let t0 = Instant::now();
    let err = w.drain().expect_err("an injected put error must fail the drain");
    let msg = format!("{err:#}");
    assert!(msg.contains("epoch 1 checkpoint failed"), "drain must name the epoch: {msg}");
    assert!(msg.contains("injected fault"), "drain must carry the fault's cause: {msg}");
    assert!(
        t0.elapsed() < Duration::from_secs(60),
        "a failed upload must fail the drain, not wedge it"
    );
    assert_eq!(faults::fired(), 1);

    // the pre-fault epoch committed; the faulted one left no object behind
    assert!(store.exists("ckpts/epoch_000.bin").unwrap());
    assert!(!store.exists("ckpts/epoch_001.bin").unwrap(), "a failed put must not commit");
}

/// Claim 8: a `storage_get` stall on a streamed corpus is absorbed by the
/// prefetcher — every batch still arrives, bit-identical to the unstalled
/// run, because fetch-ahead decouples chunk fetches from batch delivery.
#[test]
fn storage_get_stall_leaves_streamed_batches_bit_identical() {
    let _g = lock();
    faults::clear();
    let data = Dataset::synthetic(64, 3);
    let store: Arc<dyn Storage> = Arc::new(MemObject::new());
    publish(&store, "data", &data, 8).unwrap();

    // fresh provider per run: an empty chunk cache forces real gets
    let collect = || {
        let provider =
            Arc::new(StreamingProvider::open(Arc::clone(&store), "data").unwrap());
        let mut pf = Prefetcher::start_streaming(provider, 16, 42, Shard::full());
        let mut batches = Vec::new();
        while let Some(b) = pf.next_batch() {
            batches.push(b);
        }
        batches
    };

    let clean = collect();
    assert_eq!(clean.len(), 4, "64 samples / batch 16");

    // hit 1 is the provider's manifest read; hit 2 is the first chunk
    // fetch on the prefetch worker — the interesting one to stall
    let _plan = arm("storage_get@mem:stall(150ms)@step2");
    let t0 = Instant::now();
    let stalled = collect();
    assert!(
        t0.elapsed() < Duration::from_secs(60),
        "a stalled chunk fetch must delay the stream, not wedge it"
    );
    assert_eq!(faults::fired(), 1, "exactly one injected stall");

    assert_eq!(clean.len(), stalled.len(), "the stall must not drop batches");
    for (i, ((cx, cy), (sx, sy))) in clean.iter().zip(&stalled).enumerate() {
        assert_eq!(cy, sy, "batch {i}: labels");
        assert_eq!(cx.len(), sx.len(), "batch {i}: pixel count");
        for (a, b) in cx.iter().zip(sx) {
            assert_eq!(a.to_bits(), b.to_bits(), "batch {i}: pixels must be bit-identical");
        }
    }
}
