//! Integration: the serving subsystem against the real AOT artifacts.
//!
//! Serves variants of `resnet_mini` through the router and asserts
//! per-request results are bit-identical to direct `Executable::run`
//! outputs (same images, same rows, same executable — resident device
//! buffers must not change a single bit). The default server config is the
//! *pipelined* streaming-admission engine, so every test here exercises the
//! split dispatch/fetch path; `pipelined_backlog_stays_bit_identical`
//! additionally forces real overlap (multiple batches in the queue at
//! once). Requires `make artifacts` (skips gracefully otherwise, like the
//! other integration suites).

use lrta::checkpoint;
use lrta::data::{Dataset, IMAGE_ELEMS};
use lrta::runtime::{literal_to_tensor, tensor_to_literal, Manifest, Runtime};
use lrta::serve::{Class, QosConfig, Server, ServerConfig, ServeError, VariantSpec};
use lrta::tensor::Tensor;
use std::time::Duration;

const MODEL: &str = "resnet_mini";

fn manifest() -> Option<Manifest> {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
    if !path.exists() {
        eprintln!("skipping: artifacts/manifest.json missing (run `make artifacts`)");
        return None;
    }
    Some(Manifest::load(path).expect("manifest parses"))
}

fn variant_params(m: &Manifest, variant: &str) -> checkpoint::Params {
    let dense = checkpoint::load(m.init_checkpoint(MODEL).unwrap()).unwrap();
    VariantSpec::from_dense(m, MODEL, variant, &dense).unwrap().params
}

/// Direct reference: run the infer executable once on `xs` (already padded
/// to the compiled batch) and return the logits tensor.
fn direct_logits(m: &Manifest, variant: &str, params: &checkpoint::Params, xs: &[f32]) -> Tensor {
    let rt = Runtime::cpu().unwrap();
    let meta = m.artifact(&format!("{MODEL}_{variant}_infer")).unwrap();
    let exe = rt.load_hlo(m.hlo_path(meta)).unwrap();
    let mut inputs = Vec::new();
    for slot in meta.trainable.iter().chain(meta.frozen.iter()) {
        inputs.push(tensor_to_literal(&params[&slot.name]).unwrap());
    }
    let dims: Vec<i64> = meta.x_shape.iter().map(|&d| d as i64).collect();
    inputs.push(xla::Literal::vec1(xs).reshape(&dims).unwrap());
    let out = exe.run(&inputs).unwrap();
    literal_to_tensor(&out[0]).unwrap()
}

#[test]
fn router_serves_bit_identical_to_direct_run() {
    let Some(m) = manifest() else { return };
    // both checkpoint variants of the model: dense orig + decomposed lrd
    let variants = ["orig", "lrd"];
    let specs: Vec<VariantSpec> =
        variants.iter().map(|v| VariantSpec::new(MODEL, v, variant_params(&m, v))).collect();
    let cfg = ServerConfig {
        // generous: a single-threaded submitter must fill the whole batch
        max_wait: Duration::from_secs(2),
        spot_check: 0,
        ..Default::default()
    };
    let server = Server::start(&m, specs, &cfg).expect("server starts");

    for variant in variants {
        let batch = server.batch_of(MODEL, variant).unwrap();
        let data = Dataset::synthetic(batch, 42);
        let params = variant_params(&m, variant);

        // submit one request per image, in order, from one thread
        let pendings: Vec<_> = (0..batch)
            .map(|i| {
                let x = data.images[i * IMAGE_ELEMS..(i + 1) * IMAGE_ELEMS].to_vec();
                server.submit(MODEL, variant, x).expect("admitted")
            })
            .collect();
        let responses: Vec<_> = pendings
            .iter()
            .map(|p| p.wait(Duration::from_secs(120)).expect("served"))
            .collect();

        // FIFO + full coalescing: every request rode one full batch
        for r in &responses {
            assert_eq!(r.batch_fill, batch, "{variant}: batch did not coalesce fully");
        }

        // reference: the same images as one direct executable run
        let (xs, _) = data.batch(0, batch);
        let reference = direct_logits(&m, variant, &params, &xs);
        let classes = reference.shape()[1];
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(
                r.logits,
                reference.data()[i * classes..(i + 1) * classes].to_vec(),
                "{variant}: request {i} logits differ from direct run"
            );
        }

        let snap = server.stats(MODEL, variant).unwrap();
        assert_eq!(snap.served, batch as u64);
        assert_eq!(snap.errors, 0);
        assert!(snap.batches >= 1);
    }
    server.shutdown();
}

#[test]
fn partial_batch_pads_and_still_matches_direct_run() {
    let Some(m) = manifest() else { return };
    let variant = "lrd";
    let params = variant_params(&m, variant);
    let cfg = ServerConfig { max_wait: Duration::from_millis(300), ..Default::default() };
    let server = Server::start(
        &m,
        vec![VariantSpec::new(MODEL, variant, variant_params(&m, variant))],
        &cfg,
    )
    .expect("server starts");
    let batch = server.batch_of(MODEL, variant).unwrap();
    assert!(batch > 3, "test assumes a compiled batch > 3");

    let data = Dataset::synthetic(8, 7);
    let n = 3usize;
    let pendings: Vec<_> = (0..n)
        .map(|i| {
            let x = data.images[i * IMAGE_ELEMS..(i + 1) * IMAGE_ELEMS].to_vec();
            server.submit(MODEL, variant, x).expect("admitted")
        })
        .collect();
    let responses: Vec<_> =
        pendings.iter().map(|p| p.wait(Duration::from_secs(120)).expect("served")).collect();
    for r in &responses {
        assert_eq!(r.batch_fill, n, "partial batch should hold exactly the {n} requests");
    }

    // reference: same three images zero-padded to the compiled batch
    let mut xs = vec![0.0f32; batch * IMAGE_ELEMS];
    xs[..n * IMAGE_ELEMS].copy_from_slice(&data.images[..n * IMAGE_ELEMS]);
    let reference = direct_logits(&m, variant, &params, &xs);
    let classes = reference.shape()[1];
    for (i, r) in responses.iter().enumerate() {
        assert_eq!(r.logits, reference.data()[i * classes..(i + 1) * classes].to_vec());
    }

    let snap = server.stats(MODEL, variant).unwrap();
    assert_eq!(snap.served, n as u64);
    assert_eq!(snap.padded_slots, (batch - n) as u64);
    server.shutdown();
}

/// Force actual overlap: enqueue several full batches before the engine can
/// drain them, so batch N+1 is dispatched while batch N's results are still
/// in flight — and assert every row still matches a direct run bit for bit,
/// for both the pipelined engine and the serial (`pipelined: false`)
/// baseline.
#[test]
fn pipelined_backlog_stays_bit_identical() {
    let Some(m) = manifest() else { return };
    let variant = "lrd";
    let params = variant_params(&m, variant);
    let n_batches = 3usize;
    for pipelined in [true, false] {
        let cfg = ServerConfig {
            pipelined,
            // full batches ship immediately; the deadline only guards the
            // (non-occurring) partial case
            max_wait: Duration::from_secs(2),
            ..Default::default()
        };
        let server = Server::start(
            &m,
            vec![VariantSpec::new(MODEL, variant, params.clone())],
            &cfg,
        )
        .expect("server starts");
        let batch = server.batch_of(MODEL, variant).unwrap();
        let data = Dataset::synthetic(batch * n_batches, 21);

        // submit every request up front: the queue holds n_batches full
        // batches, so the engine sees backlog after each dispatch
        let pendings: Vec<_> = (0..batch * n_batches)
            .map(|i| {
                let x = data.images[i * IMAGE_ELEMS..(i + 1) * IMAGE_ELEMS].to_vec();
                server.submit(MODEL, variant, x).expect("admitted")
            })
            .collect();
        let responses: Vec<_> = pendings
            .iter()
            .map(|p| p.wait(Duration::from_secs(120)).expect("served"))
            .collect();

        for (bi, chunk) in responses.chunks(batch).enumerate() {
            let (xs, _) = data.batch(bi * batch, batch);
            let reference = direct_logits(&m, variant, &params, &xs);
            let classes = reference.shape()[1];
            for (i, r) in chunk.iter().enumerate() {
                assert_eq!(r.batch_fill, batch, "batch {bi} did not coalesce fully");
                assert_eq!(
                    r.logits,
                    reference.data()[i * classes..(i + 1) * classes].to_vec(),
                    "pipelined={pipelined}: batch {bi} request {i} diverged from direct run"
                );
            }
        }
        let snap = server.stats(MODEL, variant).unwrap();
        assert_eq!(snap.served, (batch * n_batches) as u64);
        assert_eq!(snap.errors, 0);
        assert_eq!(snap.demux_fallbacks, 0, "executions must stay buffer-to-buffer");
        assert!(
            snap.uploads > 0,
            "engine transfer counters must surface in the stats snapshot"
        );
        server.shutdown();
    }
}

#[test]
fn resident_and_reupload_paths_agree() {
    let Some(m) = manifest() else { return };
    let variant = "rankopt";
    let data = Dataset::synthetic(4, 11);
    let x = data.images[..IMAGE_ELEMS].to_vec();
    let mut outputs = Vec::new();
    for reupload in [false, true] {
        let cfg = ServerConfig {
            reupload,
            max_wait: Duration::from_millis(50),
            spot_check: 64,
            ..Default::default()
        };
        let server = Server::start(
            &m,
            vec![VariantSpec::new(MODEL, variant, variant_params(&m, variant))],
            &cfg,
        )
        .expect("server starts");
        let r = server
            .submit(MODEL, variant, x.clone())
            .expect("admitted")
            .wait(Duration::from_secs(120))
            .expect("served");
        let snap = server.stats(MODEL, variant).unwrap();
        assert!(snap.spot_check_acc.is_some(), "spot check requested but not recorded");
        outputs.push(r.logits);
        server.shutdown();
    }
    assert_eq!(outputs[0], outputs[1], "resident buffers changed the math");
}

/// The tentpole pin: sharding is a pure scale-out. The same request stream
/// submitted to a 1-shard and a 2-shard server must produce bit-identical
/// per-request logits — batches coalesce differently across shards, but
/// per-sample normalization means a request's row never depends on its
/// batch-mates, and every shard serves the same resident checkpoint.
#[test]
fn two_shards_bit_identical_to_one_shard() {
    let Some(m) = manifest() else { return };
    let variant = "rankopt";
    let params = variant_params(&m, variant);
    let cfg = ServerConfig { max_wait: Duration::from_millis(50), ..Default::default() };
    let mut per_shards: Vec<Vec<Vec<f32>>> = Vec::new();
    for shards in [1usize, 2] {
        let server = Server::start(
            &m,
            vec![VariantSpec::new(MODEL, variant, params.clone()).with_shards(shards)],
            &cfg,
        )
        .expect("server starts");
        assert_eq!(server.shards_of(MODEL, variant), Some(shards));
        let batch = server.batch_of(MODEL, variant).unwrap();
        let n = batch * 4;
        let data = Dataset::synthetic(n, 33);
        let pendings: Vec<_> = (0..n)
            .map(|i| {
                let x = data.images[i * IMAGE_ELEMS..(i + 1) * IMAGE_ELEMS].to_vec();
                server.submit(MODEL, variant, x).expect("admitted")
            })
            .collect();
        let logits: Vec<Vec<f32>> = pendings
            .iter()
            .map(|p| p.wait(Duration::from_secs(120)).expect("served").logits)
            .collect();
        let snap = server.stats(MODEL, variant).unwrap();
        assert_eq!(snap.served, n as u64);
        assert_eq!(snap.errors, 0);
        if shards > 1 {
            // the fanout must actually engage: every shard served work
            let per_shard = server.shard_stats(MODEL, variant).unwrap();
            assert_eq!(per_shard.len(), shards);
            for (i, s) in per_shard.iter().enumerate() {
                assert!(s.served > 0, "shard {i} served nothing — fanout broken");
            }
            assert_eq!(per_shard.iter().map(|s| s.served).sum::<u64>(), n as u64);
        }
        server.shutdown();
        per_shards.push(logits);
    }
    assert_eq!(
        per_shards[0], per_shards[1],
        "2-shard logits diverged from the single-engine path"
    );
}

/// SLO satellite pin: requests whose admission deadline has passed are shed
/// at pop time — answered `DeadlineExceeded`, never executed, never a panic
/// from `pop_deadline` — and the shed counter matches the late submissions
/// exactly.
#[test]
fn expired_deadline_requests_shed_at_pop() {
    let Some(m) = manifest() else { return };
    let variant = "lrd";
    let cfg = ServerConfig {
        // a deadline that has always already passed by pop time
        slo: Some(Duration::from_nanos(1)),
        max_wait: Duration::from_millis(20),
        ..Default::default()
    };
    let server = Server::start(
        &m,
        vec![VariantSpec::new(MODEL, variant, variant_params(&m, variant))],
        &cfg,
    )
    .expect("server starts");
    let batch = server.batch_of(MODEL, variant).unwrap();
    let n = batch * 2;
    let data = Dataset::synthetic(n, 5);
    let pendings: Vec<_> = (0..n)
        .map(|i| {
            let x = data.images[i * IMAGE_ELEMS..(i + 1) * IMAGE_ELEMS].to_vec();
            server.submit(MODEL, variant, x).expect("admitted")
        })
        .collect();
    for p in &pendings {
        assert_eq!(
            p.wait(Duration::from_secs(120)),
            Err(ServeError::DeadlineExceeded),
            "expired request must be shed with a terminal DeadlineExceeded"
        );
    }
    let snap = server.stats(MODEL, variant).unwrap();
    assert_eq!(snap.shed, n as u64, "shed count must match late submissions exactly");
    assert_eq!(snap.served, 0, "expired work must never execute");
    assert_eq!(snap.errors, 0, "shedding is SLO pressure, not an engine error");
    server.shutdown();
}

/// Warm-swap pin #1: after `swap_variant` returns, new requests serve the
/// *new* checkpoint's logits (uploaded beside the live set, flipped between
/// batches — the server never went down).
#[test]
fn swap_variant_flips_to_new_checkpoint() {
    let Some(m) = manifest() else { return };
    let variant = "lrd";
    let params = variant_params(&m, variant);
    // a second checkpoint with visibly different math: every tensor scaled
    let swapped: checkpoint::Params = params
        .iter()
        .map(|(k, t)| {
            let data = t.data().iter().map(|&v| v * 1.25).collect::<Vec<f32>>();
            (k.clone(), lrta::tensor::Tensor::new(t.shape(), data))
        })
        .collect();
    let cfg = ServerConfig { max_wait: Duration::from_millis(50), ..Default::default() };
    let server = Server::start(
        &m,
        vec![VariantSpec::new(MODEL, variant, params.clone())],
        &cfg,
    )
    .expect("server starts");
    let batch = server.batch_of(MODEL, variant).unwrap();
    let data = Dataset::synthetic(batch, 17);
    let (xs, _) = data.batch(0, batch);
    let submit_all = || -> Vec<Vec<f32>> {
        let pendings: Vec<_> = (0..batch)
            .map(|i| {
                let x = data.images[i * IMAGE_ELEMS..(i + 1) * IMAGE_ELEMS].to_vec();
                server.submit(MODEL, variant, x).expect("admitted")
            })
            .collect();
        pendings
            .iter()
            .map(|p| p.wait(Duration::from_secs(120)).expect("served").logits)
            .collect()
    };
    let before = submit_all();
    server.swap_variant(MODEL, variant, &swapped).expect("swap applies");
    let after = submit_all();

    let ref_before = direct_logits(&m, variant, &params, &xs);
    let ref_after = direct_logits(&m, variant, &swapped, &xs);
    let classes = ref_before.shape()[1];
    for (i, row) in before.iter().enumerate() {
        assert_eq!(row, &ref_before.data()[i * classes..(i + 1) * classes].to_vec());
    }
    for (i, row) in after.iter().enumerate() {
        assert_eq!(
            row,
            &ref_after.data()[i * classes..(i + 1) * classes].to_vec(),
            "post-swap request {i} does not serve the new checkpoint"
        );
    }
    assert_ne!(before, after, "swap had no observable effect");
    let snap = server.stats(MODEL, variant).unwrap();
    assert_eq!(snap.swaps, 1);
    assert_eq!(snap.errors, 0);

    // a swap that doesn't match the artifact is rejected shard-side and
    // the live set keeps serving
    let mut broken = swapped.clone();
    let victim = broken.keys().next().unwrap().clone();
    broken.remove(&victim);
    match server.swap_variant(MODEL, variant, &broken) {
        Err(ServeError::Engine(e)) => assert!(e.contains("missing param"), "got: {e}"),
        other => panic!("expected Engine error for a broken swap, got {other:?}"),
    }
    let still = submit_all();
    assert_eq!(still, after, "failed swap must leave the live checkpoint untouched");
    server.shutdown();
}

/// Warm-swap pin #2: swapping mid-burst on a sharded variant loses zero
/// requests — every submission gets exactly one successful answer and the
/// per-shard swap counters confirm every shard flipped.
#[test]
fn swap_mid_burst_never_drops_requests() {
    let Some(m) = manifest() else { return };
    let variant = "lrd";
    let params = variant_params(&m, variant);
    let cfg = ServerConfig { max_wait: Duration::from_millis(20), ..Default::default() };
    let server = Server::start(
        &m,
        vec![VariantSpec::new(MODEL, variant, params.clone()).with_shards(2)],
        &cfg,
    )
    .expect("server starts");
    let batch = server.batch_of(MODEL, variant).unwrap();
    let data = Dataset::synthetic(batch * 4, 29);
    let submit_burst = |lo: usize, hi: usize| -> Vec<lrta::serve::Pending> {
        (lo..hi)
            .map(|i| {
                let x = data.images[i * IMAGE_ELEMS..(i + 1) * IMAGE_ELEMS].to_vec();
                loop {
                    match server.submit(MODEL, variant, x.clone()) {
                        Ok(p) => break p,
                        Err(ServeError::QueueFull { .. }) => {
                            std::thread::sleep(Duration::from_micros(100));
                        }
                        Err(e) => panic!("unexpected submit error: {e:?}"),
                    }
                }
            })
            .collect()
    };
    // first half queues up, the swap lands between batches while the
    // engines are busy, the second half rides the swapped set — same
    // params, so every row stays comparable
    let mut pendings = submit_burst(0, batch * 2);
    server.swap_variant(MODEL, variant, &params).expect("swap under load applies");
    pendings.extend(submit_burst(batch * 2, batch * 4));
    for (i, p) in pendings.iter().enumerate() {
        let r = p.wait(Duration::from_secs(120));
        assert!(r.is_ok(), "request {i} lost across the swap: {r:?}");
    }
    let snap = server.stats(MODEL, variant).unwrap();
    assert_eq!(snap.served, (batch * 4) as u64, "swap dropped requests");
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.swaps, 2, "every shard must apply the swap exactly once");
    server.shutdown();
}

/// Observability pin: a registry attached via `ServerConfig::registry`
/// indexes the same atomics the stats sink increments, so its snapshot
/// matches the hand-rolled `server.stats` values exactly — per shard and in
/// rollup — and the attached tracer covers the full request lifecycle.
#[test]
fn registry_and_trace_match_serving_stats_exactly() {
    let Some(m) = manifest() else { return };
    let variant = "lrd";
    let reg = lrta::obs::Registry::new();
    let tracer = lrta::obs::Tracer::enabled();
    let cfg = ServerConfig {
        max_wait: Duration::from_millis(50),
        registry: Some(reg.clone()),
        tracer: tracer.clone(),
        ..Default::default()
    };
    let server = Server::start(
        &m,
        vec![VariantSpec::new(MODEL, variant, variant_params(&m, variant)).with_shards(2)],
        &cfg,
    )
    .expect("server starts");
    let batch = server.batch_of(MODEL, variant).unwrap();
    let n = batch * 4;
    let data = Dataset::synthetic(n, 13);
    let pendings: Vec<_> = (0..n)
        .map(|i| {
            let x = data.images[i * IMAGE_ELEMS..(i + 1) * IMAGE_ELEMS].to_vec();
            server.submit(MODEL, variant, x).expect("admitted")
        })
        .collect();
    for p in &pendings {
        p.wait(Duration::from_secs(120)).expect("served");
    }

    // exact match against the hand-rolled counters: same atomics, so the
    // rollup across both shard label sets equals the merged snapshot
    let snap = server.stats(MODEL, variant).unwrap();
    let rs = reg.snapshot();
    assert_eq!(rs.scalar_sum("serve", "served"), snap.served);
    assert_eq!(rs.scalar_sum("serve", "batches"), snap.batches);
    assert_eq!(rs.scalar_sum("serve", "errors"), snap.errors);
    assert_eq!(rs.scalar_sum("serve", "shed"), snap.shed);
    assert_eq!(rs.scalar_sum("serve", "padded_slots"), snap.padded_slots);
    // per-shard series carry model/variant/shard labels
    let shard0 = rs.scalar(
        "serve",
        "served",
        &[("model", MODEL), ("variant", variant), ("shard", "0")],
    );
    let shard1 = rs.scalar(
        "serve",
        "served",
        &[("model", MODEL), ("variant", variant), ("shard", "1")],
    );
    assert_eq!(shard0.unwrap() + shard1.unwrap(), snap.served);
    // the latency histogram recorded one sample per served request
    let hist_count: u64 = rs
        .entries
        .iter()
        .filter_map(|e| match (&e.key.name[..], &e.value) {
            ("latency_us", lrta::obs::SnapValue::Histogram { count, .. }) => Some(*count),
            _ => None,
        })
        .sum();
    assert_eq!(hist_count, snap.served);
    // idle server: the queue-depth gauges have drained to zero
    assert_eq!(rs.scalar_sum("serve", "queue_depth"), 0);
    // the exposition round-trips
    let parsed = lrta::obs::parse_prometheus(&rs.prometheus_text()).unwrap();
    assert!(parsed.keys().any(|k| k.starts_with("lrta_serve_served")), "{parsed:?}");

    // the trace covers the whole request lifecycle, submit → reply
    let names: std::collections::BTreeSet<&str> =
        tracer.events().iter().map(|e| e.name).collect();
    for expected in
        ["submit", "queue_wait", "coalesce", "upload", "dispatch", "fetch", "demux", "reply"]
    {
        assert!(names.contains(expected), "missing serve span '{expected}' in {names:?}");
    }
    assert!(tracer.events().iter().all(|e| e.cat == "serve"));
    server.shutdown();
}

/// Per-image reference rows: the same images as direct executable runs,
/// chunked into compiled batches (rows are independent of batch-mates, so
/// the chunking is immaterial to any single row).
fn direct_rows(
    m: &Manifest,
    variant: &str,
    params: &checkpoint::Params,
    data: &Dataset,
    n: usize,
    batch: usize,
) -> Vec<Vec<f32>> {
    // one executable load for all chunks (direct_logits reloads per call)
    let rt = Runtime::cpu().unwrap();
    let meta = m.artifact(&format!("{MODEL}_{variant}_infer")).unwrap();
    let exe = rt.load_hlo(m.hlo_path(meta)).unwrap();
    let mut inputs = Vec::new();
    for slot in meta.trainable.iter().chain(meta.frozen.iter()) {
        inputs.push(tensor_to_literal(&params[&slot.name]).unwrap());
    }
    let dims: Vec<i64> = meta.x_shape.iter().map(|&d| d as i64).collect();
    let mut rows = Vec::with_capacity(n);
    for b0 in (0..n).step_by(batch) {
        let (xs, _) = data.batch(b0, batch);
        inputs.push(xla::Literal::vec1(&xs).reshape(&dims).unwrap());
        let out = exe.run(&inputs).unwrap();
        inputs.pop();
        let t = literal_to_tensor(&out[0]).unwrap();
        let classes = t.shape()[1];
        for i in 0..batch.min(n - b0) {
            rows.push(t.data()[i * classes..(i + 1) * classes].to_vec());
        }
    }
    rows
}

/// Degrade pin #1: under SLO pressure, batch-class work spills down its
/// ladder instead of shedding — and a spilled request's answer is
/// bit-identical to a direct run of the *target* variant. Every admission
/// resolves as exactly one of: served by `orig`, served by `rankopt`
/// (spilled), or shed with `DeadlineExceeded` — counted exactly.
#[test]
fn spilled_requests_serve_the_ladder_variant_bit_identically() {
    let Some(m) = manifest() else { return };
    let mut qos = QosConfig::default();
    qos.classes[Class::Batch.index()].slo = Some(Duration::from_millis(1));
    qos.degrade.set(Class::Batch, vec!["rankopt".to_string()]);
    let cfg = ServerConfig {
        max_wait: Duration::from_millis(10),
        spot_check: 0,
        // deep queues: the whole burst is admitted up front, so the tail
        // of the backlog is guaranteed to outwait the 1ms SLO at pop time
        queue_depth: 1024,
        qos: Some(qos),
        ..Default::default()
    };
    let orig_params = variant_params(&m, "orig");
    let rank_params = variant_params(&m, "rankopt");
    let server = Server::start(
        &m,
        vec![
            VariantSpec::new(MODEL, "orig", orig_params.clone()),
            VariantSpec::new(MODEL, "rankopt", rank_params.clone()),
        ],
        &cfg,
    )
    .expect("server starts");
    let batch = server.batch_of(MODEL, "orig").unwrap();
    let n = batch * 16;
    let data = Dataset::synthetic(n, 71);

    // a batch-class burst aimed at orig only: the 1ms SLO expires queued
    // work at pop time, which must degrade to rankopt (fresh deadline),
    // not shed — rankopt sees *only* this spill flow
    let pendings: Vec<_> = (0..n)
        .map(|i| {
            let x = data.images[i * IMAGE_ELEMS..(i + 1) * IMAGE_ELEMS].to_vec();
            loop {
                match server.submit_class(MODEL, "orig", x.clone(), Class::Batch) {
                    Ok(p) => break p,
                    Err(ServeError::QueueFull { .. }) => {
                        std::thread::sleep(Duration::from_micros(200));
                    }
                    Err(e) => panic!("request {i}: unexpected submit error {e:?}"),
                }
            }
        })
        .collect();
    let ref_orig = direct_rows(&m, "orig", &orig_params, &data, n, batch);
    let ref_rank = direct_rows(&m, "rankopt", &rank_params, &data, n, batch);

    let mut served_rank = 0u64;
    let mut served_orig = 0u64;
    let mut shed_seen = 0u64;
    for (i, p) in pendings.iter().enumerate() {
        match p.wait(Duration::from_secs(120)) {
            Ok(resp) => {
                if resp.logits == ref_orig[i] {
                    served_orig += 1;
                } else if resp.logits == ref_rank[i] {
                    served_rank += 1;
                } else {
                    panic!("request {i}: logits match neither variant's direct run");
                }
            }
            Err(ServeError::DeadlineExceeded) => shed_seen += 1,
            Err(e) => panic!("request {i}: unexpected terminal answer {e:?}"),
        }
    }

    let o = server.stats(MODEL, "orig").unwrap();
    let r = server.stats(MODEL, "rankopt").unwrap();
    assert!(o.spilled >= 1, "overload must actually exercise the ladder");
    assert!(served_rank >= 1, "a spilled request must be served by the target");
    // exact accounting: spills are batch-class only, every counter splits
    // by class, and the three outcomes partition the admissions
    assert_eq!(o.spilled, o.spilled_by_class[Class::Batch.index()]);
    assert_eq!(o.shed, o.shed_by_class[Class::Batch.index()]);
    assert_eq!(o.served + o.spilled + o.shed, n as u64);
    assert_eq!(r.served + r.shed, o.spilled, "rankopt traffic is exactly the spills");
    assert_eq!(r.spilled, 0, "the ladder bottoms out at rankopt — no further descent");
    assert_eq!(served_orig, o.served, "orig-served answers must match orig's math");
    assert_eq!(served_rank, r.served, "spilled answers must match rankopt's math");
    assert_eq!(shed_seen, o.shed + r.shed, "every shed request saw DeadlineExceeded");
    assert_eq!(o.errors + r.errors, 0);
    server.shutdown();
}

/// Degrade pin #2: QoS enabled with an empty ladder must be inert — a
/// 3-class run is bit-identical to the single-class path, nothing spills
/// or hedges, and the per-class served split is exact.
#[test]
fn ladderless_qos_is_bit_identical_to_single_class_path() {
    let Some(m) = manifest() else { return };
    let variant = "lrd";
    let params = variant_params(&m, variant);
    let mut outputs: Vec<Vec<Vec<f32>>> = Vec::new();
    for classed in [false, true] {
        let cfg = ServerConfig {
            max_wait: Duration::from_millis(50),
            spot_check: 0,
            qos: classed.then(QosConfig::default),
            ..Default::default()
        };
        let server = Server::start(
            &m,
            vec![VariantSpec::new(MODEL, variant, params.clone())],
            &cfg,
        )
        .expect("server starts");
        let batch = server.batch_of(MODEL, variant).unwrap();
        let n = batch * 3;
        let data = Dataset::synthetic(n, 83);
        let pendings: Vec<_> = (0..n)
            .map(|i| {
                let x = data.images[i * IMAGE_ELEMS..(i + 1) * IMAGE_ELEMS].to_vec();
                if classed {
                    server
                        .submit_class(MODEL, variant, x, Class::ALL[i % 3])
                        .expect("admitted")
                } else {
                    server.submit(MODEL, variant, x).expect("admitted")
                }
            })
            .collect();
        let logits: Vec<Vec<f32>> = pendings
            .iter()
            .map(|p| p.wait(Duration::from_secs(120)).expect("served").logits)
            .collect();
        let snap = server.stats(MODEL, variant).unwrap();
        assert_eq!(snap.served, n as u64);
        assert_eq!(snap.shed, 0);
        assert_eq!(snap.spilled, 0, "no ladder, no spills");
        assert_eq!(snap.hedge_fired, 0, "no hedge config, no hedges");
        assert_eq!(snap.errors, 0);
        assert_eq!(
            snap.served_by_class.iter().sum::<u64>(),
            snap.served,
            "per-class served must sum to the aggregate"
        );
        if classed {
            // the 3-way cycling mix lands exactly n/3 in every class
            assert_eq!(snap.served_by_class, [(n / 3) as u64; 3]);
        }
        server.shutdown();
        outputs.push(logits);
    }
    assert_eq!(
        outputs[0], outputs[1],
        "ladderless QoS changed per-request math vs the single-class path"
    );
}

/// Registration satellite pin: a duplicate `(model, variant)` spec fails
/// startup loudly instead of silently overwriting (and leaking) the first
/// registration's workers.
#[test]
fn duplicate_registration_fails() {
    let Some(m) = manifest() else { return };
    let params = variant_params(&m, "orig");
    let err = Server::start(
        &m,
        vec![
            VariantSpec::new(MODEL, "orig", params.clone()),
            VariantSpec::new(MODEL, "orig", params),
        ],
        &ServerConfig::default(),
    )
    .err()
    .expect("duplicate registration must fail");
    assert!(err.to_string().contains("registered twice"), "got: {err}");
}

#[test]
fn router_rejects_unknown_variant_and_bad_input() {
    let Some(m) = manifest() else { return };
    let server = Server::start(
        &m,
        vec![VariantSpec::new(MODEL, "orig", variant_params(&m, "orig"))],
        &ServerConfig::default(),
    )
    .expect("server starts");
    match server.submit(MODEL, "nope", vec![0.0; IMAGE_ELEMS]) {
        Err(ServeError::UnknownVariant(k)) => assert!(k.contains("nope")),
        other => panic!("expected UnknownVariant, got {other:?}"),
    }
    match server.submit(MODEL, "orig", vec![0.0; 7]) {
        Err(ServeError::BadInput { expected, got }) => {
            assert_eq!(expected, IMAGE_ELEMS);
            assert_eq!(got, 7);
        }
        other => panic!("expected BadInput, got {other:?}"),
    }
    server.shutdown();
}
