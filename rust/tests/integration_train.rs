//! Integration: the Trainer end-to-end over real artifacts — epochs,
//! freeze-pattern swapping, state persistence, evaluation.
//!
//! Kept deliberately short (single-core CPU): 2 epochs over tiny corpora.

use lrta::checkpoint;
use lrta::coordinator::{decompose_checkpoint, LrSchedule, TrainConfig, Trainer};
use lrta::freeze::FreezeMode;
use lrta::runtime::{Manifest, Runtime};

fn manifest() -> Option<Manifest> {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
    if !path.exists() {
        eprintln!("skipping: artifacts missing");
        return None;
    }
    Some(Manifest::load(path).unwrap())
}

fn tiny_cfg(model: &str, variant: &str, freeze: FreezeMode, epochs: usize) -> TrainConfig {
    TrainConfig {
        model: model.into(),
        variant: variant.into(),
        freeze,
        epochs,
        lr: LrSchedule::Fixed(5e-3),
        train_size: 128,
        test_size: 128,
        seed: 0,
        verbose: false,
        // the resident engine is the default step path — these seed tests
        // now exercise buffer-chained stepping end to end
        resident: true,
        pipelined: true,
    }
}

#[test]
fn sequential_freezing_trains_both_factor_groups() {
    let Some(m) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let dense = checkpoint::load(m.init_checkpoint("resnet_mini").unwrap()).unwrap();
    let params = decompose_checkpoint(&dense, m.config("resnet_mini", "lrd").unwrap())
        .unwrap()
        .params;
    let initial = params.clone();

    let cfg = tiny_cfg("resnet_mini", "lrd", FreezeMode::Sequential, 2);
    let mut tr = Trainer::new(&rt, &m, cfg, params).unwrap();
    let record = tr.run().unwrap();
    assert_eq!(record.epochs.len(), 2);
    assert_eq!(record.epochs[0].freeze_pattern, "a");
    assert_eq!(record.epochs[1].freeze_pattern, "b");

    // after one a-epoch and one b-epoch, every factor of a decomposed layer
    // must have moved (sequential covers both groups)
    let meta_a = m.artifact("resnet_mini_lrd_train_a").unwrap();
    let meta_b = m.artifact("resnet_mini_lrd_train_b").unwrap();
    let mut checked = 0;
    for slot in meta_a.frozen.iter().chain(meta_b.frozen.iter()) {
        let moved = tr.params[&slot.name] != initial[&slot.name];
        assert!(moved, "factor {} never trained", slot.name);
        checked += 1;
    }
    assert!(checked >= 10, "checked {checked} factors");
}

#[test]
fn regular_freezing_keeps_group_a_factors_forever() {
    let Some(m) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let dense = checkpoint::load(m.init_checkpoint("resnet_mini").unwrap()).unwrap();
    let params = decompose_checkpoint(&dense, m.config("resnet_mini", "lrd").unwrap())
        .unwrap()
        .params;
    let initial = params.clone();

    let cfg = tiny_cfg("resnet_mini", "lrd", FreezeMode::Regular, 2);
    let mut tr = Trainer::new(&rt, &m, cfg, params).unwrap();
    let record = tr.run().unwrap();
    assert!(record.epochs.iter().all(|e| e.freeze_pattern == "a"));

    let meta_a = m.artifact("resnet_mini_lrd_train_a").unwrap();
    for slot in &meta_a.frozen {
        assert_eq!(
            tr.params[&slot.name], initial[&slot.name],
            "regular freezing must never touch {}",
            slot.name
        );
    }
}

#[test]
fn training_improves_over_initial_accuracy() {
    let Some(m) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let params = checkpoint::load(m.init_checkpoint("vit_mini").unwrap()).unwrap();
    let cfg = TrainConfig {
        lr: LrSchedule::Fixed(1e-2),
        ..tiny_cfg("vit_mini", "orig", FreezeMode::None, 2)
    };
    let mut tr = Trainer::new(&rt, &m, cfg, params).unwrap();
    let data = lrta::data::Dataset::synthetic(128, 0xDEAD_BEEF);
    let acc0 = tr.evaluate(&data).unwrap();
    let record = tr.run().unwrap();
    let acc1 = record.final_test_acc();
    assert!(
        acc1 > acc0 + 0.05 || acc1 > 0.3,
        "no learning: {acc0} -> {acc1}"
    );
    // loss decreases epoch over epoch on this easy corpus
    assert!(record.epochs[1].loss < record.epochs[0].loss * 1.05);
}

#[test]
fn momentum_state_persists_across_epochs() {
    let Some(m) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let params = checkpoint::load(m.init_checkpoint("resnet_mini").unwrap()).unwrap();
    let cfg = tiny_cfg("resnet_mini", "orig", FreezeMode::None, 1);
    let mut tr = Trainer::new(&rt, &m, cfg, params).unwrap();
    tr.run().unwrap();
    // after training, momenta are non-zero for trainable weights
    let nonzero = tr
        .momenta
        .values()
        .filter(|t| t.data().iter().any(|&v| v != 0.0))
        .count();
    assert!(nonzero > 50, "only {nonzero} nonzero momenta");
}

#[test]
fn cosine_schedule_decays_lr() {
    let s = LrSchedule::Cosine { base: 0.1, total_epochs: 30 };
    assert!(s.lr_at(29) < s.lr_at(0) * 0.02);
}

/// The storage-refactor pin: training from a streamed corpus (chunked
/// through a [`lrta::storage::MemObject`]) is *bit-identical* to training
/// from the same corpus in RAM — same per-epoch losses and accuracies,
/// same final parameters — and the epoch checkpoints the streamed run
/// uploads through the storage boundary are byte-identical to the files
/// the in-memory run writes to disk.
#[test]
fn streamed_corpus_trains_bit_identically_to_in_memory() {
    use lrta::data::{publish, DataSource, Dataset, StreamingProvider};
    use lrta::storage::{MemObject, Storage};
    use std::sync::Arc;

    let Some(m) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let dense = checkpoint::load(m.init_checkpoint("resnet_mini").unwrap()).unwrap();
    let params = decompose_checkpoint(&dense, m.config("resnet_mini", "lrd").unwrap())
        .unwrap()
        .params;
    let cfg = tiny_cfg("resnet_mini", "lrd", FreezeMode::Sequential, 2);

    // reference: the default in-memory corpus, checkpoints to local files
    let ckpt_dir = std::env::temp_dir()
        .join("lrta_streamed_pin")
        .join(std::process::id().to_string());
    let _ = std::fs::remove_dir_all(&ckpt_dir);
    let mut base = Trainer::new(&rt, &m, cfg.clone(), params.clone()).unwrap();
    base.checkpoint_epochs_to(&ckpt_dir);
    let base_rec = base.run().unwrap();

    // streamed twin: the *same* synthetic corpus published as chunks into
    // an in-process object store, checkpoints uploaded to the same store
    let store: Arc<dyn Storage> = Arc::new(MemObject::new());
    let corpus = Dataset::synthetic(cfg.train_size, cfg.seed);
    publish(&store, "data", &corpus, 32).unwrap();
    let provider = StreamingProvider::open(Arc::clone(&store), "data").unwrap();

    let mut streamed = Trainer::new(&rt, &m, cfg, params).unwrap();
    streamed.train_from(DataSource::streamed(Arc::new(provider)));
    streamed.checkpoint_epochs_to_store(Arc::clone(&store), "ckpts");
    let stream_rec = streamed.run().unwrap();

    assert_eq!(base_rec.epochs.len(), stream_rec.epochs.len());
    for (b, s) in base_rec.epochs.iter().zip(&stream_rec.epochs) {
        assert_eq!(b.loss.to_bits(), s.loss.to_bits(), "epoch {}: loss", b.epoch);
        assert_eq!(b.train_acc.to_bits(), s.train_acc.to_bits(), "epoch {}", b.epoch);
        assert_eq!(b.test_acc.to_bits(), s.test_acc.to_bits(), "epoch {}", b.epoch);
    }
    for (name, t) in &base.params {
        assert_eq!(t, &streamed.params[name], "final param {name} diverged");
    }

    // and the uploaded checkpoints are the pre-refactor file bytes
    for e in 0..base_rec.epochs.len() {
        let file = std::fs::read(ckpt_dir.join(format!("epoch_{e:03}.bin"))).unwrap();
        let object = store.get(&format!("ckpts/epoch_{e:03}.bin")).unwrap();
        assert_eq!(file, object, "epoch {e}: store upload differs from file checkpoint");
    }
    let _ = std::fs::remove_dir_all(&ckpt_dir);
}
