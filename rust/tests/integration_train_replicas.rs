//! Integration: multi-replica data-parallel training against the
//! single-engine resident baseline, over real artifacts.
//!
//! Claims pinned here:
//! 1. **Parity** — 2 replicas on *identical* shards with per-step
//!    averaging reproduce the 1-replica serial-resident trajectory
//!    bit-for-bit (loss, train-acc, test-acc, final params, final
//!    momenta): averaging N identical contributions is exact IEEE
//!    arithmetic, and everything else (batch order, executables, update
//!    math, eval) is shared with the single-engine path by construction.
//! 2. **Transfer accounting** — per replica, the parameter-upload counter
//!    moves past the initial state upload by *exactly* the documented
//!    averaging budget (`events × 2·|trainable|` under the average-momenta
//!    policy): freeze-pattern a↔b swaps and buffer-chained steps
//!    contribute zero re-uploads, and the demux fallback counter stays 0.
//! 3. **Disjoint sharding** — with real (round-robin) shards each replica
//!    steps through exactly its equal-length slice, mid-epoch cadence plus
//!    the mandatory boundary average fire the predicted number of
//!    barriers, and the combined record stays well-formed.
//! 4. **Frozen leaves ship zero bytes** — under all three freeze modes the
//!    barrier's byte counters match the sync plan priced from the manifest
//!    exactly: the full-exchange reference, the frozen-leaf savings, and
//!    the raw ceiling on the encoded exchange — and the same numbers are
//!    exported through the metrics registry under `{replica}` labels.
//! 5. **Pipelined + delta parity** — 2 replicas on the *overlapped* epoch
//!    driver exchanging XOR bit-deltas still reproduce the serial
//!    single-engine trajectory bit-for-bit (overlap is pure scheduling;
//!    the exact codec is losslessly invertible).
//! 6. **q8 smoke** — the lossy codec trains to finite metrics and lands
//!    strictly under the raw trainable byte ceiling.

use lrta::checkpoint;
use lrta::coordinator::{
    decompose_checkpoint, effective_pattern_suffix, LrSchedule, TrainConfig, Trainer,
};
use lrta::freeze::{FreezeMode, FreezeScheduler};
use lrta::obs::{Registry, Tracer};
use lrta::runtime::{Manifest, ParamSlot, Runtime};
use lrta::train::{
    run_replicas, run_replicas_traced, MomentumPolicy, ReplicaConfig, SyncCompress,
};

fn manifest() -> Option<Manifest> {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
    if !path.exists() {
        eprintln!("skipping: artifacts missing");
        return None;
    }
    Some(Manifest::load(path).unwrap())
}

fn cfg(freeze: FreezeMode, epochs: usize) -> TrainConfig {
    TrainConfig {
        model: "resnet_mini".into(),
        variant: "lrd".into(),
        freeze,
        epochs,
        lr: LrSchedule::Fixed(5e-3),
        train_size: 128,
        test_size: 128,
        seed: 0,
        verbose: false,
        // the 1-replica reference is the *serial* resident engine — the
        // replica step loop performs the same f32 metric sums in step order
        resident: true,
        pipelined: false,
    }
}

fn lrd_params(m: &Manifest) -> lrta::checkpoint::Params {
    let dense = checkpoint::load(m.init_checkpoint("resnet_mini").unwrap()).unwrap();
    decompose_checkpoint(&dense, m.config("resnet_mini", "lrd").unwrap())
        .unwrap()
        .params
}

/// Trainable-slot count of the artifact a (variant, pattern) epoch runs.
fn n_trainable(m: &Manifest, suffix: &str) -> usize {
    m.artifact(&format!("resnet_mini_lrd_train_{suffix}"))
        .unwrap()
        .trainable
        .len()
}

/// Total f32 elements across a slot list — the unit the byte plans price.
fn elems(slots: &[ParamSlot]) -> u64 {
    slots.iter().map(|s| s.shape.iter().product::<usize>() as u64).sum()
}

#[test]
fn two_replicas_identical_shards_reproduce_single_engine_bit_for_bit() {
    let Some(m) = manifest() else { return };
    let params = lrd_params(&m);

    for (mode, epochs) in [(FreezeMode::Sequential, 3), (FreezeMode::None, 2)] {
        let rt = Runtime::cpu().unwrap();
        let mut base = Trainer::new(&rt, &m, cfg(mode, epochs), params.clone()).unwrap();
        let base_rec = base.run().unwrap();

        let rcfg = ReplicaConfig {
            replicas: 2,
            avg_every: 1,
            momenta: MomentumPolicy::Average,
            compress: SyncCompress::Exact,
            identical_shards: true,
            ..Default::default()
        };
        let run = run_replicas(&m, &cfg(mode, epochs), &rcfg, &params).unwrap();

        // trajectory: bit-for-bit against the single engine
        assert_eq!(base_rec.epochs.len(), run.record.epochs.len());
        for (b, r) in base_rec.epochs.iter().zip(&run.record.epochs) {
            assert_eq!(b.freeze_pattern, r.freeze_pattern, "{mode:?} epoch {}", b.epoch);
            assert_eq!(
                b.loss.to_bits(),
                r.loss.to_bits(),
                "{mode:?} epoch {}: loss {} vs {}",
                b.epoch,
                b.loss,
                r.loss
            );
            assert_eq!(
                b.train_acc.to_bits(),
                r.train_acc.to_bits(),
                "{mode:?} epoch {}: train_acc {} vs {}",
                b.epoch,
                b.train_acc,
                r.train_acc
            );
            assert_eq!(
                b.test_acc.to_bits(),
                r.test_acc.to_bits(),
                "{mode:?} epoch {}: test_acc {} vs {} (replica 0 evaluates the \
                 averaged model with the same artifact on the same batches)",
                b.epoch,
                b.test_acc,
                r.test_acc
            );
        }
        // final state: the averaged model is the single-engine model
        assert_eq!(base.params.len(), run.params.len(), "{mode:?}");
        for (name, t) in &base.params {
            assert_eq!(t.shape(), run.params[name].shape(), "{mode:?}: shape of {name}");
            assert_eq!(
                t.data(),
                run.params[name].data(),
                "{mode:?}: param {name} diverged from the single-engine run"
            );
        }
        for (name, t) in &base.momenta {
            assert_eq!(
                t.data(),
                run.momenta[name].data(),
                "{mode:?}: momentum {name} diverged from the single-engine run"
            );
        }

        // transfer accounting: only the documented averaging traffic may
        // move the parameter-upload counters — swaps and steps add zero
        let scheduler = FreezeScheduler::new(mode);
        let suffix0 = effective_pattern_suffix("lrd", scheduler.pattern(0));
        let steps =
            128 / m.artifact(&format!("resnet_mini_lrd_train_{suffix0}")).unwrap().batch;
        assert!(steps >= 2, "need ≥2 steps/epoch to exercise the cadence");
        let expected_events = epochs * steps; // avg_every=1, boundary folded in
        let expected_slot_uploads: usize = (0..epochs)
            .map(|e| {
                let suffix = effective_pattern_suffix("lrd", scheduler.pattern(e));
                steps * 2 * n_trainable(&m, suffix) // params + momenta per event
            })
            .sum();
        assert_eq!(run.reports.len(), 2, "{mode:?}");
        for r in &run.reports {
            assert!(r.initial_param_uploads > 0, "{mode:?} replica {}", r.replica);
            assert_eq!(
                r.unaccounted_uploads(),
                0,
                "{mode:?} replica {}: steps/pattern swaps must never re-upload",
                r.replica
            );
            assert_eq!(r.avg_events, expected_events, "{mode:?} replica {}", r.replica);
            assert_eq!(
                r.avg_slot_uploads, expected_slot_uploads,
                "{mode:?} replica {}: averaging budget",
                r.replica
            );
            assert_eq!(r.demux_fallbacks, 0, "{mode:?} replica {}", r.replica);
            assert_eq!(r.batches, epochs * steps, "{mode:?} replica {}", r.replica);
        }
    }
}

#[test]
fn disjoint_shards_average_on_cadence_and_stay_buffer_chained() {
    let Some(m) = manifest() else { return };
    let params = lrd_params(&m);

    let epochs = 2;
    let rcfg = ReplicaConfig {
        replicas: 2,
        avg_every: 2,
        momenta: MomentumPolicy::Average,
        compress: SyncCompress::Exact,
        identical_shards: false,
        ..Default::default()
    };
    let run = run_replicas(&m, &cfg(FreezeMode::Sequential, epochs), &rcfg, &params).unwrap();

    let total_batches = 128 / m.artifact("resnet_mini_lrd_train_a").unwrap().batch;
    let per_replica = total_batches / 2; // round-robin equal-length shards
    assert!(per_replica >= 1, "need at least one batch per shard");
    // cadence events mid-epoch plus the mandatory boundary average
    let events_per_epoch = per_replica.div_ceil(2);
    for r in &run.reports {
        assert_eq!(r.batches, epochs * per_replica, "replica {}", r.replica);
        assert_eq!(r.avg_events, epochs * events_per_epoch, "replica {}", r.replica);
        assert_eq!(r.unaccounted_uploads(), 0, "replica {}", r.replica);
        assert_eq!(r.demux_fallbacks, 0, "replica {}", r.replica);
    }
    // the combined record is well-formed: both shards contributed
    assert_eq!(run.record.epochs.len(), epochs);
    for e in &run.record.epochs {
        assert!(e.loss.is_finite(), "epoch {}: loss {}", e.epoch, e.loss);
        assert!(
            (0.0..=1.0).contains(&e.train_acc),
            "epoch {}: train_acc {}",
            e.epoch,
            e.train_acc
        );
        assert!(
            (0.0..=1.0).contains(&e.test_acc),
            "epoch {}: test_acc {}",
            e.epoch,
            e.test_acc
        );
    }
    assert_eq!(run.record.epochs[0].freeze_pattern, "a");
    assert_eq!(run.record.epochs[1].freeze_pattern, "b");
    // the final state exists and matches the parameter universe
    assert_eq!(run.params.len(), params.len());
}

#[test]
fn momentum_reset_policy_zeroes_momenta_at_the_boundary() {
    let Some(m) = manifest() else { return };
    let params = lrd_params(&m);

    let rcfg = ReplicaConfig {
        replicas: 2,
        avg_every: 0, // boundary-only averaging
        momenta: MomentumPolicy::Reset,
        compress: SyncCompress::Exact,
        identical_shards: false,
        ..Default::default()
    };
    let run = run_replicas(&m, &cfg(FreezeMode::None, 1), &rcfg, &params).unwrap();

    let n_tr = n_trainable(&m, "none");
    for r in &run.reports {
        assert_eq!(r.avg_events, 1, "replica {}", r.replica);
        // params + zeroed momenta, once
        assert_eq!(r.avg_slot_uploads, 2 * n_tr, "replica {}", r.replica);
        assert_eq!(r.unaccounted_uploads(), 0, "replica {}", r.replica);
    }
    // after the final (boundary) reset, every trainable momentum is zero
    let meta = m.artifact("resnet_mini_lrd_train_none").unwrap();
    for slot in &meta.trainable {
        let mom = &run.momenta[&slot.name];
        assert!(
            mom.data().iter().all(|&v| v == 0.0),
            "momentum {} must be zeroed by the reset policy",
            slot.name
        );
    }
}

#[test]
fn frozen_leaves_contribute_zero_barrier_bytes_in_every_freeze_mode() {
    let Some(m) = manifest() else { return };
    let params = lrd_params(&m);

    for mode in [FreezeMode::None, FreezeMode::Regular, FreezeMode::Sequential] {
        let epochs = 2;
        let rcfg = ReplicaConfig {
            replicas: 2,
            avg_every: 0, // boundary-only: exactly one barrier per epoch
            momenta: MomentumPolicy::Average,
            compress: SyncCompress::Exact,
            identical_shards: false,
            ..Default::default()
        };
        let reg = Registry::new();
        let run = run_replicas_traced(
            &m,
            &cfg(mode, epochs),
            &rcfg,
            &params,
            Tracer::default(),
            Some(reg.clone()),
        )
        .unwrap();

        // price the run straight from the manifest: per barrier, the naive
        // exchange moves every parameter leaf plus the trainable momenta
        // (raw f32, both directions); the sync plan keeps frozen leaves
        // off the wire entirely, so "skipped" is exactly their raw size
        let scheduler = FreezeScheduler::new(mode);
        let mut expected_full = 0u64;
        let mut expected_skipped = 0u64;
        for e in 0..epochs {
            let suffix = effective_pattern_suffix("lrd", scheduler.pattern(e));
            let meta = m.artifact(&format!("resnet_mini_lrd_train_{suffix}")).unwrap();
            expected_full += (2 * elems(&meta.trainable) + elems(&meta.frozen)) * 4 * 2;
            expected_skipped += elems(&meta.frozen) * 4 * 2;
        }
        if mode == FreezeMode::None {
            assert_eq!(expected_skipped, 0, "freeze-none artifacts freeze nothing");
        } else {
            assert!(expected_skipped > 0, "{mode:?}: the LRD artifacts must freeze factors");
        }
        for r in &run.reports {
            assert_eq!(r.avg_events, epochs, "{mode:?} replica {}", r.replica);
            assert_eq!(r.avg_bytes_full, expected_full, "{mode:?} replica {}", r.replica);
            assert_eq!(
                r.avg_bytes_skipped, expected_skipped,
                "{mode:?} replica {}: frozen leaves must contribute zero wire bytes",
                r.replica
            );
            // the per-leaf raw escape caps the encoded exchange at the
            // trainable universe's raw size — and something must move
            assert!(r.avg_bytes_exchanged > 0, "{mode:?} replica {}", r.replica);
            assert!(
                r.avg_bytes_exchanged <= expected_full - expected_skipped,
                "{mode:?} replica {}: {} exchanged over the {} raw trainable ceiling",
                r.replica,
                r.avg_bytes_exchanged,
                expected_full - expected_skipped
            );
        }
        // the same accounting is exported through the metrics registry,
        // one label set per replica
        let text = reg.snapshot().prometheus_text();
        for r in &run.reports {
            for (name, v) in [
                ("exchanged", r.avg_bytes_exchanged),
                ("skipped", r.avg_bytes_skipped),
                ("full", r.avg_bytes_full),
            ] {
                let line =
                    format!("lrta_train_barrier_bytes_{name}{{replica=\"{}\"}} {v}", r.replica);
                assert!(text.contains(&line), "{mode:?}: missing '{line}' in:\n{text}");
            }
        }
    }
}

#[test]
fn pipelined_delta_replicas_reproduce_the_serial_single_engine_run() {
    let Some(m) = manifest() else { return };
    let params = lrd_params(&m);

    let epochs = 3;
    let rt = Runtime::cpu().unwrap();
    let mut base =
        Trainer::new(&rt, &m, cfg(FreezeMode::Sequential, epochs), params.clone()).unwrap();
    let base_rec = base.run().unwrap();

    // replicas on the *overlapped* driver, exchanging XOR bit-deltas: the
    // overlap is pure scheduling and the codec is losslessly invertible,
    // so the serial full-tensor trajectory must survive bit for bit
    let mut pcfg = cfg(FreezeMode::Sequential, epochs);
    pcfg.pipelined = true;
    let rcfg = ReplicaConfig {
        replicas: 2,
        avg_every: 1,
        momenta: MomentumPolicy::Average,
        compress: SyncCompress::Exact,
        identical_shards: true,
        ..Default::default()
    };
    let run = run_replicas(&m, &pcfg, &rcfg, &params).unwrap();

    assert_eq!(base_rec.epochs.len(), run.record.epochs.len());
    for (b, r) in base_rec.epochs.iter().zip(&run.record.epochs) {
        assert_eq!(b.freeze_pattern, r.freeze_pattern, "epoch {}", b.epoch);
        assert_eq!(
            b.loss.to_bits(),
            r.loss.to_bits(),
            "epoch {}: loss {} vs {}",
            b.epoch,
            b.loss,
            r.loss
        );
        assert_eq!(b.train_acc.to_bits(), r.train_acc.to_bits(), "epoch {}", b.epoch);
        assert_eq!(b.test_acc.to_bits(), r.test_acc.to_bits(), "epoch {}", b.epoch);
    }
    for (name, t) in &base.params {
        assert_eq!(t.data(), run.params[name].data(), "param {name} diverged");
    }
    for (name, t) in &base.momenta {
        assert_eq!(t.data(), run.momenta[name].data(), "momentum {name} diverged");
    }
    for r in &run.reports {
        assert_eq!(r.driver(), "pipelined", "replica {}", r.replica);
        assert_eq!(r.unaccounted_uploads(), 0, "replica {}", r.replica);
        assert_eq!(r.demux_fallbacks, 0, "replica {}", r.replica);
    }
}

#[test]
fn q8_compression_trains_to_finite_metrics_and_saves_bytes() {
    let Some(m) = manifest() else { return };
    let params = lrd_params(&m);

    let epochs = 2;
    let mut pcfg = cfg(FreezeMode::Sequential, epochs);
    pcfg.pipelined = true;
    let rcfg = ReplicaConfig {
        replicas: 2,
        avg_every: 2,
        momenta: MomentumPolicy::Average,
        compress: SyncCompress::Q8,
        identical_shards: false,
        ..Default::default()
    };
    let run = run_replicas(&m, &pcfg, &rcfg, &params).unwrap();

    assert_eq!(run.record.epochs.len(), epochs);
    for e in &run.record.epochs {
        assert!(e.loss.is_finite(), "epoch {}: loss {}", e.epoch, e.loss);
        assert!((0.0..=1.0).contains(&e.train_acc), "epoch {}: train_acc {}", e.epoch, e.train_acc);
        assert!((0.0..=1.0).contains(&e.test_acc), "epoch {}: test_acc {}", e.epoch, e.test_acc);
    }
    for r in &run.reports {
        // every multi-element trainable leaf quantizes to 4 + n bytes
        // against 4n raw, so q8 lands strictly under the raw ceiling
        assert!(r.avg_bytes_exchanged > 0, "replica {}", r.replica);
        assert!(r.avg_bytes_saved_by_delta() > 0, "replica {}: q8 saved nothing", r.replica);
        assert_eq!(r.unaccounted_uploads(), 0, "replica {}", r.replica);
    }
}
