//! Integration: multi-replica data-parallel training against the
//! single-engine resident baseline, over real artifacts.
//!
//! Claims pinned here:
//! 1. **Parity** — 2 replicas on *identical* shards with per-step
//!    averaging reproduce the 1-replica serial-resident trajectory
//!    bit-for-bit (loss, train-acc, test-acc, final params, final
//!    momenta): averaging N identical contributions is exact IEEE
//!    arithmetic, and everything else (batch order, executables, update
//!    math, eval) is shared with the single-engine path by construction.
//! 2. **Transfer accounting** — per replica, the parameter-upload counter
//!    moves past the initial state upload by *exactly* the documented
//!    averaging budget (`events × 2·|trainable|` under the average-momenta
//!    policy): freeze-pattern a↔b swaps and buffer-chained steps
//!    contribute zero re-uploads, and the demux fallback counter stays 0.
//! 3. **Disjoint sharding** — with real (round-robin) shards each replica
//!    steps through exactly its equal-length slice, mid-epoch cadence plus
//!    the mandatory boundary average fire the predicted number of
//!    barriers, and the combined record stays well-formed.

use lrta::checkpoint;
use lrta::coordinator::{
    decompose_checkpoint, effective_pattern_suffix, LrSchedule, TrainConfig, Trainer,
};
use lrta::freeze::{FreezeMode, FreezeScheduler};
use lrta::runtime::{Manifest, Runtime};
use lrta::train::{run_replicas, MomentumPolicy, ReplicaConfig};

fn manifest() -> Option<Manifest> {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
    if !path.exists() {
        eprintln!("skipping: artifacts missing");
        return None;
    }
    Some(Manifest::load(path).unwrap())
}

fn cfg(freeze: FreezeMode, epochs: usize) -> TrainConfig {
    TrainConfig {
        model: "resnet_mini".into(),
        variant: "lrd".into(),
        freeze,
        epochs,
        lr: LrSchedule::Fixed(5e-3),
        train_size: 128,
        test_size: 128,
        seed: 0,
        verbose: false,
        // the 1-replica reference is the *serial* resident engine — the
        // replica step loop performs the same f32 metric sums in step order
        resident: true,
        pipelined: false,
    }
}

fn lrd_params(m: &Manifest) -> lrta::checkpoint::Params {
    let dense = checkpoint::load(m.init_checkpoint("resnet_mini").unwrap()).unwrap();
    decompose_checkpoint(&dense, m.config("resnet_mini", "lrd").unwrap())
        .unwrap()
        .params
}

/// Trainable-slot count of the artifact a (variant, pattern) epoch runs.
fn n_trainable(m: &Manifest, suffix: &str) -> usize {
    m.artifact(&format!("resnet_mini_lrd_train_{suffix}"))
        .unwrap()
        .trainable
        .len()
}

#[test]
fn two_replicas_identical_shards_reproduce_single_engine_bit_for_bit() {
    let Some(m) = manifest() else { return };
    let params = lrd_params(&m);

    for (mode, epochs) in [(FreezeMode::Sequential, 3), (FreezeMode::None, 2)] {
        let rt = Runtime::cpu().unwrap();
        let mut base = Trainer::new(&rt, &m, cfg(mode, epochs), params.clone()).unwrap();
        let base_rec = base.run().unwrap();

        let rcfg = ReplicaConfig {
            replicas: 2,
            avg_every: 1,
            momenta: MomentumPolicy::Average,
            identical_shards: true,
        };
        let run = run_replicas(&m, &cfg(mode, epochs), &rcfg, &params).unwrap();

        // trajectory: bit-for-bit against the single engine
        assert_eq!(base_rec.epochs.len(), run.record.epochs.len());
        for (b, r) in base_rec.epochs.iter().zip(&run.record.epochs) {
            assert_eq!(b.freeze_pattern, r.freeze_pattern, "{mode:?} epoch {}", b.epoch);
            assert_eq!(
                b.loss.to_bits(),
                r.loss.to_bits(),
                "{mode:?} epoch {}: loss {} vs {}",
                b.epoch,
                b.loss,
                r.loss
            );
            assert_eq!(
                b.train_acc.to_bits(),
                r.train_acc.to_bits(),
                "{mode:?} epoch {}: train_acc {} vs {}",
                b.epoch,
                b.train_acc,
                r.train_acc
            );
            assert_eq!(
                b.test_acc.to_bits(),
                r.test_acc.to_bits(),
                "{mode:?} epoch {}: test_acc {} vs {} (replica 0 evaluates the \
                 averaged model with the same artifact on the same batches)",
                b.epoch,
                b.test_acc,
                r.test_acc
            );
        }
        // final state: the averaged model is the single-engine model
        assert_eq!(base.params.len(), run.params.len(), "{mode:?}");
        for (name, t) in &base.params {
            assert_eq!(t.shape(), run.params[name].shape(), "{mode:?}: shape of {name}");
            assert_eq!(
                t.data(),
                run.params[name].data(),
                "{mode:?}: param {name} diverged from the single-engine run"
            );
        }
        for (name, t) in &base.momenta {
            assert_eq!(
                t.data(),
                run.momenta[name].data(),
                "{mode:?}: momentum {name} diverged from the single-engine run"
            );
        }

        // transfer accounting: only the documented averaging traffic may
        // move the parameter-upload counters — swaps and steps add zero
        let scheduler = FreezeScheduler::new(mode);
        let suffix0 = effective_pattern_suffix("lrd", scheduler.pattern(0));
        let steps =
            128 / m.artifact(&format!("resnet_mini_lrd_train_{suffix0}")).unwrap().batch;
        assert!(steps >= 2, "need ≥2 steps/epoch to exercise the cadence");
        let expected_events = epochs * steps; // avg_every=1, boundary folded in
        let expected_slot_uploads: usize = (0..epochs)
            .map(|e| {
                let suffix = effective_pattern_suffix("lrd", scheduler.pattern(e));
                steps * 2 * n_trainable(&m, suffix) // params + momenta per event
            })
            .sum();
        assert_eq!(run.reports.len(), 2, "{mode:?}");
        for r in &run.reports {
            assert!(r.initial_param_uploads > 0, "{mode:?} replica {}", r.replica);
            assert_eq!(
                r.unaccounted_uploads(),
                0,
                "{mode:?} replica {}: steps/pattern swaps must never re-upload",
                r.replica
            );
            assert_eq!(r.avg_events, expected_events, "{mode:?} replica {}", r.replica);
            assert_eq!(
                r.avg_slot_uploads, expected_slot_uploads,
                "{mode:?} replica {}: averaging budget",
                r.replica
            );
            assert_eq!(r.demux_fallbacks, 0, "{mode:?} replica {}", r.replica);
            assert_eq!(r.batches, epochs * steps, "{mode:?} replica {}", r.replica);
        }
    }
}

#[test]
fn disjoint_shards_average_on_cadence_and_stay_buffer_chained() {
    let Some(m) = manifest() else { return };
    let params = lrd_params(&m);

    let epochs = 2;
    let rcfg = ReplicaConfig {
        replicas: 2,
        avg_every: 2,
        momenta: MomentumPolicy::Average,
        identical_shards: false,
    };
    let run = run_replicas(&m, &cfg(FreezeMode::Sequential, epochs), &rcfg, &params).unwrap();

    let total_batches = 128 / m.artifact("resnet_mini_lrd_train_a").unwrap().batch;
    let per_replica = total_batches / 2; // round-robin equal-length shards
    assert!(per_replica >= 1, "need at least one batch per shard");
    // cadence events mid-epoch plus the mandatory boundary average
    let events_per_epoch = per_replica.div_ceil(2);
    for r in &run.reports {
        assert_eq!(r.batches, epochs * per_replica, "replica {}", r.replica);
        assert_eq!(r.avg_events, epochs * events_per_epoch, "replica {}", r.replica);
        assert_eq!(r.unaccounted_uploads(), 0, "replica {}", r.replica);
        assert_eq!(r.demux_fallbacks, 0, "replica {}", r.replica);
    }
    // the combined record is well-formed: both shards contributed
    assert_eq!(run.record.epochs.len(), epochs);
    for e in &run.record.epochs {
        assert!(e.loss.is_finite(), "epoch {}: loss {}", e.epoch, e.loss);
        assert!(
            (0.0..=1.0).contains(&e.train_acc),
            "epoch {}: train_acc {}",
            e.epoch,
            e.train_acc
        );
        assert!(
            (0.0..=1.0).contains(&e.test_acc),
            "epoch {}: test_acc {}",
            e.epoch,
            e.test_acc
        );
    }
    assert_eq!(run.record.epochs[0].freeze_pattern, "a");
    assert_eq!(run.record.epochs[1].freeze_pattern, "b");
    // the final state exists and matches the parameter universe
    assert_eq!(run.params.len(), params.len());
}

#[test]
fn momentum_reset_policy_zeroes_momenta_at_the_boundary() {
    let Some(m) = manifest() else { return };
    let params = lrd_params(&m);

    let rcfg = ReplicaConfig {
        replicas: 2,
        avg_every: 0, // boundary-only averaging
        momenta: MomentumPolicy::Reset,
        identical_shards: false,
    };
    let run = run_replicas(&m, &cfg(FreezeMode::None, 1), &rcfg, &params).unwrap();

    let n_tr = n_trainable(&m, "none");
    for r in &run.reports {
        assert_eq!(r.avg_events, 1, "replica {}", r.replica);
        // params + zeroed momenta, once
        assert_eq!(r.avg_slot_uploads, 2 * n_tr, "replica {}", r.replica);
        assert_eq!(r.unaccounted_uploads(), 0, "replica {}", r.replica);
    }
    // after the final (boundary) reset, every trainable momentum is zero
    let meta = m.artifact("resnet_mini_lrd_train_none").unwrap();
    for slot in &meta.trainable {
        let mom = &run.momenta[&slot.name];
        assert!(
            mom.data().iter().all(|&v| v == 0.0),
            "momentum {} must be zeroed by the reset policy",
            slot.name
        );
    }
}
