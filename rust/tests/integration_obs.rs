//! Integration: the observability layer's overhead guard and export
//! contracts over real artifacts.
//!
//! Claims pinned here:
//! 1. **Zero-cost off switch** — a serve run and a train run with telemetry
//!    disabled (the default no-op tracer, no registry) produce bit-identical
//!    logits / epoch metrics / final parameters to the same run with a live
//!    registry and tracer attached. Telemetry is observation, never
//!    participation: attaching it must not change a single bit of the math,
//!    and disabling it must leave nothing behind (the no-op tracer records
//!    zero spans and never samples the clock).
//! 2. **Export validity end-to-end** — the Chrome trace document produced
//!    by a real traced run survives a parse round-trip and every event
//!    carries the complete-event contract (`"ph": "X"`, integer ts/dur/tid),
//!    and the Prometheus exposition of a live registry parses back to the
//!    same scalar values.
//!
//! Requires `make artifacts` (skips gracefully otherwise, like the other
//! integration suites).

use lrta::checkpoint;
use lrta::coordinator::{decompose_checkpoint, LrSchedule, TrainConfig, Trainer};
use lrta::data::{Dataset, IMAGE_ELEMS};
use lrta::freeze::FreezeMode;
use lrta::obs::{Registry, Tracer};
use lrta::runtime::{Manifest, Runtime};
use lrta::serve::{Server, ServerConfig, VariantSpec};
use lrta::util::json::Json;
use std::time::Duration;

const MODEL: &str = "resnet_mini";

fn manifest() -> Option<Manifest> {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
    if !path.exists() {
        eprintln!("skipping: artifacts/manifest.json missing (run `make artifacts`)");
        return None;
    }
    Some(Manifest::load(path).expect("manifest parses"))
}

fn lrd_params(m: &Manifest) -> checkpoint::Params {
    let dense = checkpoint::load(m.init_checkpoint(MODEL).unwrap()).unwrap();
    decompose_checkpoint(&dense, m.config(MODEL, "lrd").unwrap()).unwrap().params
}

/// Run the same request burst through a server and return (per-request
/// logits, final stats snapshot).
fn serve_burst(
    m: &Manifest,
    cfg: &ServerConfig,
    n_batches: usize,
) -> (Vec<Vec<f32>>, lrta::serve::StatsSnapshot) {
    let variant = "lrd";
    let server = Server::start(
        m,
        vec![VariantSpec::new(MODEL, variant, lrd_params(m))],
        cfg,
    )
    .expect("server starts");
    let batch = server.batch_of(MODEL, variant).unwrap();
    let n = batch * n_batches;
    let data = Dataset::synthetic(n, 57);
    let pendings: Vec<_> = (0..n)
        .map(|i| {
            let x = data.images[i * IMAGE_ELEMS..(i + 1) * IMAGE_ELEMS].to_vec();
            server.submit(MODEL, variant, x).expect("admitted")
        })
        .collect();
    let logits: Vec<Vec<f32>> = pendings
        .iter()
        .map(|p| p.wait(Duration::from_secs(120)).expect("served").logits)
        .collect();
    let snap = server.stats(MODEL, variant).unwrap();
    server.shutdown();
    (logits, snap)
}

/// The overhead guard, serve side: telemetry off (the default no-op tracer,
/// no registry — the pre-obs configuration) vs telemetry on (live registry
/// + tracer) over the same request stream. Logits and the accounting stats
/// must match bit for bit, and the off run must record nothing.
#[test]
fn serve_with_telemetry_is_bit_identical_to_without() {
    let Some(m) = manifest() else { return };
    // generous coalescing window: every batch fills completely in both
    // runs, so the batch/padding accounting is deterministic and comparable
    let off_tracer = Tracer::noop();
    let off_cfg = ServerConfig {
        max_wait: Duration::from_secs(2),
        tracer: off_tracer.clone(),
        ..Default::default()
    };
    let (off_logits, off_snap) = serve_burst(&m, &off_cfg, 3);

    let reg = Registry::new();
    let on_tracer = Tracer::enabled();
    let on_cfg = ServerConfig {
        max_wait: Duration::from_secs(2),
        registry: Some(reg.clone()),
        tracer: on_tracer.clone(),
        ..Default::default()
    };
    let (on_logits, on_snap) = serve_burst(&m, &on_cfg, 3);

    // observation, not participation: not a bit of the math may move
    assert_eq!(off_logits, on_logits, "attaching telemetry changed served logits");
    assert_eq!(off_snap.served, on_snap.served);
    assert_eq!(off_snap.batches, on_snap.batches);
    assert_eq!(off_snap.errors, on_snap.errors);
    assert_eq!(off_snap.shed, on_snap.shed);
    assert_eq!(off_snap.padded_slots, on_snap.padded_slots);

    // the disabled recorder left no trace of itself
    assert!(!off_tracer.is_enabled());
    assert!(off_tracer.is_empty(), "no-op tracer must record zero spans");

    // the enabled run actually recorded the lifecycle and snapshots cleanly
    assert!(!on_tracer.is_empty(), "traced run must record spans");
    assert_eq!(reg.snapshot().scalar_sum("serve", "served"), on_snap.served);
}

/// Export validity end-to-end: the Chrome trace JSON from a real serve run
/// parses, every event is a complete event with integer timestamps, and the
/// Prometheus exposition round-trips to the registry's scalar values.
#[test]
fn trace_and_metrics_exports_are_valid_end_to_end() {
    let Some(m) = manifest() else { return };
    let reg = Registry::new();
    let tracer = Tracer::enabled();
    let cfg = ServerConfig {
        max_wait: Duration::from_secs(2),
        registry: Some(reg.clone()),
        tracer: tracer.clone(),
        ..Default::default()
    };
    let (_, snap) = serve_burst(&m, &cfg, 2);
    assert!(snap.served > 0);

    // the exact document `--trace-out` writes: parse it back and hold every
    // event to the Chrome/Perfetto complete-event contract
    let doc = tracer.chrome_trace_json().emit();
    let parsed = Json::parse(&doc).expect("trace export must be valid JSON");
    let events = parsed.get("traceEvents").as_arr().expect("traceEvents array");
    assert_eq!(events.len(), tracer.len(), "export must carry every recorded span");
    assert!(!events.is_empty());
    for ev in events {
        assert_eq!(ev.get("ph").as_str(), Some("X"), "complete events only: {ev:?}");
        assert!(ev.get("name").as_str().is_some_and(|s| !s.is_empty()));
        assert_eq!(ev.get("cat").as_str(), Some("serve"));
        assert!(ev.get("ts").as_i64().is_some_and(|t| t >= 0));
        assert!(ev.get("dur").as_i64().is_some_and(|d| d >= 0));
        assert!(ev.get("pid").as_i64().is_some());
        assert!(ev.get("tid").as_i64().is_some());
    }

    // the exact text `--metrics-out` writes: parse it back and check the
    // series values against the snapshot they were rendered from
    let rs = reg.snapshot();
    let parsed = lrta::obs::parse_prometheus(&rs.prometheus_text()).unwrap();
    let served: f64 = parsed
        .iter()
        .filter(|(k, _)| k.starts_with("lrta_serve_served"))
        .map(|(_, v)| v)
        .sum();
    assert_eq!(served, snap.served as f64, "exposition diverged from snapshot");
}

/// The overhead guard, train side: a pipelined resident run with a live
/// tracer attached must reproduce the untraced run bit for bit — epoch
/// metrics and final parameters/momenta alike.
#[test]
fn train_with_tracer_is_bit_identical_to_without() {
    let Some(m) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let params = lrd_params(&m);
    let cfg = || TrainConfig {
        model: MODEL.into(),
        variant: "lrd".into(),
        freeze: FreezeMode::Sequential,
        epochs: 2,
        lr: LrSchedule::Fixed(5e-3),
        train_size: 128,
        test_size: 128,
        seed: 0,
        verbose: false,
        resident: true,
        pipelined: true,
    };

    let mut plain = Trainer::new(&rt, &m, cfg(), params.clone()).unwrap();
    let plain_rec = plain.run().unwrap();

    let mut traced = Trainer::new(&rt, &m, cfg(), params).unwrap();
    let tracer = Tracer::enabled();
    traced.set_tracer(tracer.clone());
    let traced_rec = traced.run().unwrap();

    assert!(!tracer.is_empty(), "traced run must record train spans");
    assert_eq!(plain_rec.epochs.len(), traced_rec.epochs.len());
    for (a, b) in plain_rec.epochs.iter().zip(&traced_rec.epochs) {
        assert_eq!(a.freeze_pattern, b.freeze_pattern);
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "epoch {}: loss moved", a.epoch);
        assert_eq!(a.train_acc.to_bits(), b.train_acc.to_bits(), "epoch {}", a.epoch);
        assert_eq!(a.test_acc.to_bits(), b.test_acc.to_bits(), "epoch {}", a.epoch);
    }
    for (name, t) in &plain.params {
        assert_eq!(
            t.data(),
            traced.params[name].data(),
            "param {name} diverged under tracing"
        );
    }
    for (name, t) in &plain.momenta {
        assert_eq!(
            t.data(),
            traced.momenta[name].data(),
            "momentum {name} diverged under tracing"
        );
    }
}
