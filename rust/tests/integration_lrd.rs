//! Integration: the LRD engine against the manifest configs and full-size
//! zoo shapes (no PJRT needed).

use lrta::checkpoint::{self, Params};
use lrta::coordinator::decompose_checkpoint;
use lrta::lrd::plan::RankMode;
use lrta::lrd::{compression_ratio, LayerShape};
use lrta::models::zoo::{paper_plan, resnet_full, vit_b16};
use lrta::runtime::{LayerCfg, Manifest};
use lrta::tensor::Tensor;
use lrta::util::rng::Rng;

fn manifest() -> Option<Manifest> {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
    if !path.exists() {
        eprintln!("skipping: artifacts missing");
        return None;
    }
    Some(Manifest::load(path).unwrap())
}

#[test]
fn manifest_configs_achieve_target_compression() {
    let Some(m) = manifest() else { return };
    for model in ["resnet_mini", "vit_mini"] {
        let cfg = m.config(model, "lrd").unwrap();
        let mut dense_total = 0.0;
        let mut dec_total = 0.0;
        for (name, lc) in cfg {
            match lc {
                LayerCfg::Dense => {}
                LayerCfg::Svd { rank, .. } => {
                    // cannot recover c,s from the config alone; check the
                    // rank is sane vs the artifact shapes instead
                    assert!(*rank >= 1, "{name}");
                    dec_total += 1.0;
                    dense_total += 1.0;
                }
                LayerCfg::Tucker { r1, r2, .. } => {
                    assert!(*r1 >= 1 && *r2 >= 1, "{name}");
                    dec_total += 1.0;
                    dense_total += 1.0;
                }
            }
        }
        assert!(dec_total > 0.0, "{model}: no decomposed layers");
        let _ = dense_total;
    }
}

#[test]
fn decomposition_halves_params_on_mini_models() {
    let Some(m) = manifest() else { return };
    for model in ["resnet_mini", "vit_mini"] {
        let dense = checkpoint::load(m.init_checkpoint(model).unwrap()).unwrap();
        let total = |p: &Params| p.values().map(|t| t.len()).sum::<usize>();
        let dense_n = total(&dense);
        let lrd = decompose_checkpoint(&dense, m.config(model, "lrd").unwrap()).unwrap();
        let lrd_n = total(&lrd.params);
        let ratio = dense_n as f64 / lrd_n as f64;
        // decomposable bulk compresses 2x; aux params and kept-dense layers
        // dilute (ViT keeps attention dense per the paper)
        assert!(ratio > 1.3 && ratio < 2.5, "{model}: ratio {ratio}");
    }
}

#[test]
fn rankopt_variant_not_larger_than_lrd_band() {
    let Some(m) = manifest() else { return };
    let dense = checkpoint::load(m.init_checkpoint("resnet_mini").unwrap()).unwrap();
    let lrd = decompose_checkpoint(&dense, m.config("resnet_mini", "lrd").unwrap()).unwrap();
    let ropt =
        decompose_checkpoint(&dense, m.config("resnet_mini", "rankopt").unwrap()).unwrap();
    let total = |p: &Params| p.values().map(|t| t.len()).sum::<usize>();
    // quantization snaps ranks *down* within the [α, α+1) band: the rankopt
    // model can only be equal or smaller
    assert!(total(&ropt.params) <= total(&lrd.params));
}

#[test]
fn reconstruction_error_reasonable_after_decomposition() {
    // decompose a structured (not random) weight set: errors should be a
    // small fraction of total energy since trained-like weights decay.
    let mut rng = Rng::new(77);
    let mut dense = Params::new();
    // build a low-rank-ish weight: product of two thin factors + noise
    let u = Tensor::randn(&[64, 12], 1.0, &mut rng);
    let v = Tensor::randn(&[12, 48], 1.0, &mut rng);
    let noise = Tensor::randn(&[64, 48], 0.05, &mut rng);
    dense.insert("fc.w".into(), u.matmul(&v).add(&noise));
    let mut cfg = std::collections::BTreeMap::new();
    cfg.insert("fc".to_string(), LayerCfg::Svd { rank: 12, r_min: 6 });
    let out = decompose_checkpoint(&dense, &cfg).unwrap();
    let energy = dense["fc.w"].norm().powi(2);
    assert!(
        out.total_reconstruction_err < 0.05 * energy as f64,
        "err {} energy {energy}",
        out.total_reconstruction_err
    );
}

#[test]
fn full_size_zoo_plans_compress_at_paper_scale() {
    // Paper: "the number of parameters shrinks by 2 times" for ResNets.
    for depth in [50usize, 101, 152] {
        let model = resnet_full(depth);
        let plan = paper_plan(&model, 2.0, RankMode::Vanilla);
        let ratio = plan.overall_ratio();
        assert!(
            (1.6..=2.4).contains(&ratio),
            "resnet{depth} overall ratio {ratio}"
        );
    }
    let vit = vit_b16();
    let plan = paper_plan(&vit, 2.0, RankMode::Vanilla);
    // ViT decomposes FFN+embed only -> those layers compress 2x
    for l in plan.layers.iter().filter(|l| l.decompose) {
        let r = compression_ratio(&l.shape, l.r1, l.r2);
        assert!(r >= 1.8, "{} ratio {r}", l.name);
    }
}

#[test]
fn zoo_paper_layer_is_present_with_paper_rank() {
    // The Fig. 2 layer: [512, 512, 3, 3] in ResNet-152 stage 4, rank 309.
    let model = resnet_full(152);
    let plan = paper_plan(&model, 2.0, RankMode::Vanilla);
    let l = plan
        .layers
        .iter()
        .find(|l| l.shape == LayerShape::conv(512, 512, 3))
        .expect("stage-4 3x3 conv exists");
    assert_eq!(l.r1, 309, "paper's §2.1 example rank");
}
