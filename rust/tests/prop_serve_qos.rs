//! Property suite: QoS serving invariants. Three families, matching the
//! rank-aware serving design (rust/src/serve/qos.rs):
//!
//! 1. **No starvation** — the weighted class pop never starves a class
//!    that stays backlogged: over `P` pops it gets at least
//!    `floor(P / Σw) · w_c` slots, whatever the other classes do.
//! 2. **Exact partition** — shed + spill + served counts partition the
//!    admitted requests exactly (per class and in aggregate), driven
//!    through the real batcher pop path.
//! 3. **Ladder isolation + hedge race** — a spilled request lands on a
//!    variant of *its own class's* ladder with its class preserved (never
//!    in another class's slot), and a hedged request/copy pair answers
//!    its client exactly once, whichever side wins.

use lrta::obs::Tracer;
use lrta::serve::batcher::{self, BatcherConfig, NextBatch};
use lrta::serve::qos::{self, ClassQueues, ShardQos, SpillShard};
use lrta::serve::queue::Pop;
use lrta::serve::{
    Class, Delivery, QosConfig, Request, Response, ServeError, SharedStats,
};
use lrta::util::check::{forall, Config};
use lrta::util::rng::Rng;
use std::collections::BTreeSet;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

type Rx = mpsc::Receiver<Result<Response, ServeError>>;

/// A request plus the client's receiving end. `expired = true` stamps a
/// deadline already in the past, so the batcher resolves it at pop time.
fn request(id: u64, class: Class, expired: bool) -> (Request, Rx) {
    let (tx, rx) = mpsc::channel();
    let now = Instant::now();
    let deadline = if expired { Some(now) } else { Some(now + Duration::from_secs(300)) };
    let req = Request {
        id,
        x: vec![id as f32],
        enqueued: now,
        deadline,
        tx,
        class,
        hedge: None,
        hedged_copy: false,
    };
    (req, rx)
}

// ---------------------------------------------------------------------------
// 1. weighted pop never starves a backlogged class
// ---------------------------------------------------------------------------

#[test]
fn prop_weighted_pop_never_starves_a_backlogged_class() {
    forall(
        Config { cases: 64, seed: 0x9051 },
        |r: &mut Rng| {
            let weights =
                [1 + r.below(5) as u32, 1 + r.below(5) as u32, 1 + r.below(5) as u32];
            let pops = 1 + r.below(40);
            // each class is either backlogged (enough prefill to stay
            // non-empty for every pop) or arbitrarily light
            let fills: Vec<usize> = (0..3)
                .map(|_| if r.below(2) == 0 { pops } else { r.below(pops) })
                .collect();
            (weights, pops, fills)
        },
        |(weights, pops, fills)| {
            let q = ClassQueues::multi(pops + 1, *weights);
            let mut id = 0u64;
            for class in Class::ALL {
                for _ in 0..fills[class.index()] {
                    // client end dropped on purpose; only pop order matters
                    let (req, _rx) = request(id, class, false);
                    id += 1;
                    if q.try_push(class, req).is_err() {
                        return false;
                    }
                }
            }
            let total: usize = fills.iter().sum();
            let to_pop = (*pops).min(total);
            let mut served = [0usize; 3];
            for _ in 0..to_pop {
                match q.pop_timeout(Duration::from_millis(100)) {
                    Pop::Item(req) => served[req.class.index()] += 1,
                    _ => return false, // queue must not run dry or close
                }
            }
            // fairness floor: any class that stayed backlogged the whole
            // run gets its weight's share of every full schedule cycle
            let cycle: usize = weights.iter().sum::<u32>() as usize;
            Class::ALL.iter().all(|c| {
                let i = c.index();
                fills[i] < to_pop || served[i] >= (to_pop / cycle) * weights[i] as usize
            })
        },
    );
}

// ---------------------------------------------------------------------------
// 2. shed / spill / served exactly partition admissions
// ---------------------------------------------------------------------------

/// Drain `queue` through the real batcher pop path, returning the ids it
/// shipped in batches (everything else was resolved as spill or shed).
fn drain_through_batcher(queue: &ClassQueues, stats: &SharedStats, sq: &ShardQos) -> Vec<u64> {
    let cfg = BatcherConfig {
        batch: 4,
        item_elems: 1,
        max_wait: Duration::from_millis(1),
        idle_poll: Duration::from_millis(1),
    };
    let tracer = Tracer::noop();
    let mut shipped = Vec::new();
    while !queue.is_empty() {
        match batcher::next_batch(queue, &cfg, stats, &tracer, sq) {
            NextBatch::Batch(reqs) => shipped.extend(reqs.into_iter().map(|r| r.id)),
            NextBatch::Idle => continue,
            NextBatch::Closed => break,
        }
    }
    shipped
}

#[test]
fn prop_batcher_outcomes_partition_admissions_exactly() {
    forall(
        Config { cases: 48, seed: 0xA22B },
        |r: &mut Rng| {
            let n = 1 + r.below(24);
            let reqs: Vec<(usize, bool)> =
                (0..n).map(|_| (r.below(3), r.below(2) == 0)).collect();
            let laddered: Vec<bool> = (0..3).map(|_| r.below(2) == 0).collect();
            (reqs, laddered)
        },
        |(reqs, laddered)| {
            let n = reqs.len();
            // degrade config: laddered classes spill to variant "cheap"
            let mut qcfg = QosConfig::default();
            for class in Class::ALL {
                if laddered[class.index()] {
                    qcfg.degrade.set(class, vec!["cheap".to_string()]);
                }
            }
            let table = qos::new_table();
            let target_q = Arc::new(ClassQueues::multi(n + 1, [1, 1, 1]));
            let target_stats = SharedStats::new("m", "cheap", 4);
            table.lock().unwrap().insert(
                "m/cheap".to_string(),
                vec![SpillShard { queue: target_q.clone(), stats: target_stats.clone() }],
            );
            let sq = ShardQos::new("m", "v", Arc::new(qcfg), None, table);

            let source = ClassQueues::multi(n + 1, [1, 1, 1]);
            let stats = SharedStats::new("m", "v", 4);
            let mut clients = Vec::new();
            let mut expired_by_class = [0u64; 3];
            let mut live = 0usize;
            let mut spill_ids: BTreeSet<u64> = BTreeSet::new();
            for (id, (ci, expired)) in reqs.iter().enumerate() {
                let class = Class::from_index(*ci);
                let (req, rx) = request(id as u64, class, *expired);
                if source.try_push(class, req).is_err() {
                    return false;
                }
                if *expired {
                    expired_by_class[*ci] += 1;
                    if laddered[*ci] {
                        spill_ids.insert(id as u64);
                    }
                } else {
                    live += 1;
                }
                clients.push((id as u64, class, *expired, rx));
            }

            let shipped = drain_through_batcher(&source, &stats, &sq);
            let snap = stats.snapshot(0);

            // the partition identity: every admission is exactly one of
            // shipped-to-a-batch, spilled, or shed — no loss, no double
            if shipped.len() + (snap.spilled + snap.shed) as usize != n {
                return false;
            }
            if shipped.len() != live {
                return false;
            }
            // aggregates equal their per-class splits
            if snap.shed != snap.shed_by_class.iter().sum::<u64>()
                || snap.spilled != snap.spilled_by_class.iter().sum::<u64>()
            {
                return false;
            }
            for class in Class::ALL {
                let i = class.index();
                let (want_spill, want_shed) = if laddered[i] {
                    (expired_by_class[i], 0)
                } else {
                    (0, expired_by_class[i])
                };
                if snap.spilled_by_class[i] != want_spill
                    || snap.shed_by_class[i] != want_shed
                {
                    return false;
                }
            }
            // spill target counted each landing as a normal admission
            if target_stats.snapshot(0).requests_ok != snap.spilled {
                return false;
            }
            // client-visible outcomes: shed answered DeadlineExceeded;
            // spilled work waits in the target (sender alive → Empty);
            // shipped work was handed to the "engine" (here: dropped →
            // Disconnected) without the batcher answering it
            for (_, class, expired, rx) in &clients {
                let got = rx.try_recv();
                let ok = if *expired && laddered[class.index()] {
                    matches!(got, Err(mpsc::TryRecvError::Empty))
                } else if *expired {
                    matches!(got, Ok(Err(ServeError::DeadlineExceeded)))
                } else {
                    matches!(got, Err(mpsc::TryRecvError::Disconnected))
                };
                if !ok {
                    return false;
                }
            }
            // every spilled request sits in the target under its own class
            // slot with a ladder class, never borrowing another class's
            let landed = target_q.drain();
            if landed.len() != spill_ids.len() {
                return false;
            }
            landed.iter().all(|req| {
                laddered[req.class.index()] && spill_ids.contains(&req.id)
            })
        },
    );
}

// ---------------------------------------------------------------------------
// 3. spill stays on the class's own ladder; hedge answers exactly once
// ---------------------------------------------------------------------------

#[test]
fn prop_spill_walks_only_the_own_class_ladder() {
    forall(
        Config { cases: 64, seed: 0x51AD },
        |r: &mut Rng| {
            // random per-class ladders over three candidate variants; the
            // source variant "v" may itself appear anywhere on a ladder
            let ladders: Vec<Vec<usize>> =
                (0..3).map(|_| (0..r.below(4)).map(|_| r.below(3)).collect()).collect();
            let class = r.below(3);
            (ladders, class)
        },
        |(ladders, ci)| {
            let variants = ["v", "cheap0", "cheap1"];
            let mut qcfg = QosConfig::default();
            for class in Class::ALL {
                let ladder: Vec<String> = ladders[class.index()]
                    .iter()
                    .map(|&k| variants[k].to_string())
                    .collect();
                qcfg.degrade.set(class, ladder);
            }
            let table = qos::new_table();
            let mut queues = Vec::new();
            for v in &variants[1..] {
                let q = Arc::new(ClassQueues::multi(4, [1, 1, 1]));
                table.lock().unwrap().insert(
                    format!("m/{v}"),
                    vec![SpillShard {
                        queue: q.clone(),
                        stats: SharedStats::new("m", v, 4),
                    }],
                );
                queues.push((v.to_string(), q));
            }
            let qcfg = Arc::new(qcfg);
            let sq = ShardQos::new("m", "v", qcfg.clone(), None, table);

            let class = Class::from_index(*ci);
            let (req, rx) = request(7, class, true);
            // the walk starts *after* the source's own ladder position (or
            // at the top when absent) and always skips the source itself
            let ladder = qcfg.degrade.ladder(class).to_vec();
            let start =
                ladder.iter().position(|v| v == "v").map(|p| p + 1).unwrap_or(0);
            let eligible: Vec<&String> =
                ladder[start..].iter().filter(|v| *v != "v").collect();
            match sq.spill(req) {
                Ok(()) => {
                    // landed exactly once, on the first eligible rung of
                    // *this class's* ladder, filed under its own class slot
                    let Some(first) = eligible.first() else { return false };
                    let mut hits = 0;
                    for (v, q) in &queues {
                        let in_q = q.len();
                        if in_q > 0 {
                            hits += in_q;
                            if v != *first || q.class_len(class) != in_q {
                                return false;
                            }
                        }
                    }
                    hits == 1 && rx.try_recv().is_err()
                }
                Err(req) => {
                    // no eligible rung below the source — request comes
                    // back intact for shedding
                    req.id == 7 && eligible.is_empty()
                }
            }
        },
    );
}

#[test]
fn prop_hedged_pair_answers_client_exactly_once() {
    forall(
        Config { cases: 64, seed: 0x4ED6 },
        |r: &mut Rng| (r.below(2) == 0, r.below(3)),
        |(copy_first, ci)| {
            let class = Class::from_index(*ci);
            let (orig, rx) = request(11, class, false);
            // publish installs the first-answer-wins guard and exposes the
            // governor-facing ticket — exactly the engine's dispatch path
            let board = qos::new_board();
            let mut batch = vec![orig];
            qos::publish(&board, &mut batch);
            let orig = batch.pop().expect("published request");
            let ticket = board.lock().unwrap().tickets[0].clone();
            if ticket.id != 11 {
                return false;
            }
            let copy = Request {
                id: ticket.id,
                x: ticket.x.clone(),
                enqueued: Instant::now(),
                deadline: None,
                tx: ticket.tx.clone(),
                class: ticket.class,
                hedge: Some(ticket.guard.clone()),
                hedged_copy: true,
            };
            let answer = |req: Request, tag: f32| {
                req.respond(Ok(Response {
                    logits: vec![tag],
                    latency: Duration::ZERO,
                    batch_fill: 1,
                }))
            };
            let (first, second, first_tag) = if *copy_first {
                (answer(copy, 2.0), answer(orig, 1.0), 2.0)
            } else {
                (answer(orig, 1.0), answer(copy, 2.0), 1.0)
            };
            // whichever side raced ahead wins; the loser is cancelled and
            // must not double-reply
            if first != Delivery::Sent || second != Delivery::Cancelled {
                return false;
            }
            let got = match rx.try_recv() {
                Ok(Ok(resp)) => resp.logits == vec![first_tag],
                _ => false,
            };
            got && rx.try_recv().is_err()
        },
    );
}
