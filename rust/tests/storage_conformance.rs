//! Backend-generic conformance suite for the `lrta::storage` boundary.
//!
//! Every test runs the *same* assertions against every backend —
//! [`MemObject`] and [`LocalFs`] today, a real S3/GCS backend tomorrow —
//! so the trait contract (atomic whole-object puts, typed `NotFound`,
//! sorted prefix listing, idempotent delete, exact op/byte accounting) is
//! pinned once, centrally, instead of re-derived per backend. Needs no
//! artifacts: everything here is pure library.
//!
//! Claims pinned:
//! 1. put/get round-trips arbitrary binary payloads (including empty)
//!    bit-for-bit, with exact op and byte accounting.
//! 2. `put_streaming` commits the same bytes as `put` and reports the
//!    exact count written.
//! 3. Overwrite replaces the whole object — no stale tail from a longer
//!    predecessor.
//! 4. `list(prefix)` is a plain string-prefix filter, sorted, and sees
//!    every committed key.
//! 5. A missing key is the typed [`NotFound`] shape (`is_not_found`),
//!    distinguishable from I/O failure, and names the key.
//! 6. `delete` is idempotent; `exists` agrees with `get` before and after.
//! 7. Invalid keys are rejected centrally before any backend I/O.
//! 8. Content-addressed blobs reassemble bit-for-bit through the manifest
//!    across pseudo-random sizes and contents, and re-publishing the same
//!    bytes writes zero new chunks (dedupe property).

use lrta::storage::{self, ChunkStore, LocalFs, MemObject, Storage};
use lrta::util::rng::Rng;
use std::sync::Arc;

/// Fresh instances of every backend, isolated per test (`tag`).
fn backends(tag: &str) -> Vec<Arc<dyn Storage>> {
    let dir = std::env::temp_dir()
        .join("lrta_storage_conformance")
        .join(format!("{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    vec![
        Arc::new(MemObject::new()) as Arc<dyn Storage>,
        Arc::new(LocalFs::open(dir).expect("temp LocalFs root")) as Arc<dyn Storage>,
    ]
}

/// A deterministic binary payload covering all byte values.
fn blob(seed: u64, len: usize) -> Vec<u8> {
    let mut rng = Rng::new(seed);
    (0..len).map(|_| (rng.next_u64() & 0xff) as u8).collect()
}

#[test]
fn round_trip_with_exact_accounting() {
    for store in backends("round_trip") {
        let b = store.backend();
        let payload = blob(7, 4097);
        store.put("ns/deep/obj.bin", &payload).unwrap();
        assert_eq!(store.get("ns/deep/obj.bin").unwrap(), payload, "{b}: bytes differ");

        // empty objects are legal and distinct from missing ones
        store.put("ns/empty", &[]).unwrap();
        assert_eq!(store.get("ns/empty").unwrap(), Vec::<u8>::new(), "{b}");
        assert!(store.exists("ns/empty").unwrap(), "{b}: empty object must exist");

        let m = store.metrics();
        assert_eq!(m.put_ops.get(), 2, "{b}: put ops");
        assert_eq!(m.put_bytes.get(), payload.len() as u64, "{b}: put bytes");
        assert_eq!(m.get_ops.get(), 2, "{b}: get ops");
        assert_eq!(m.get_bytes.get(), payload.len() as u64, "{b}: get bytes");
    }
}

#[test]
fn put_streaming_commits_identically_to_put() {
    for store in backends("streaming") {
        let b = store.backend();
        let payload = blob(11, 3 * 8192 + 5);
        let n = store
            .put_streaming("s/streamed", &mut std::io::Cursor::new(payload.clone()))
            .unwrap();
        assert_eq!(n, payload.len() as u64, "{b}: reported byte count");
        store.put("s/direct", &payload).unwrap();
        assert_eq!(
            store.get("s/streamed").unwrap(),
            store.get("s/direct").unwrap(),
            "{b}: streamed and direct puts must commit the same bytes"
        );
        assert_eq!(store.metrics().put_bytes.get(), 2 * n, "{b}: both paths counted");
    }
}

#[test]
fn overwrite_replaces_the_whole_object() {
    for store in backends("overwrite") {
        let b = store.backend();
        store.put("k", &blob(1, 1000)).unwrap();
        let short = blob(2, 10);
        store.put("k", &short).unwrap();
        assert_eq!(store.get("k").unwrap(), short, "{b}: stale tail survived overwrite");
    }
}

#[test]
fn list_is_sorted_prefix_filter() {
    for store in backends("list") {
        let b = store.backend();
        for key in ["b/2", "a/sub/x", "a/1", "b/1", "a/2", "top"] {
            store.put(key, key.as_bytes()).unwrap();
        }
        assert_eq!(store.list("a/").unwrap(), ["a/1", "a/2", "a/sub/x"], "{b}");
        assert_eq!(store.list("b/").unwrap(), ["b/1", "b/2"], "{b}");
        assert_eq!(store.list("nope/").unwrap(), Vec::<String>::new(), "{b}");
        assert_eq!(
            store.list("").unwrap(),
            ["a/1", "a/2", "a/sub/x", "b/1", "b/2", "top"],
            "{b}: empty prefix must list everything, sorted"
        );
    }
}

#[test]
fn missing_key_is_typed_not_found() {
    for store in backends("not_found") {
        let b = store.backend();
        let err = store.get("absent/key").unwrap_err();
        assert!(storage::is_not_found(&err), "{b}: want NotFound, got: {err:#}");
        assert!(format!("{err:#}").contains("absent/key"), "{b}: error must name the key");
        assert!(!store.exists("absent/key").unwrap(), "{b}");

        // I/O-shaped failures must NOT look like a missing key
        let bad = store.put("", &[]).unwrap_err();
        assert!(!storage::is_not_found(&bad), "{b}: validation error mistyped as NotFound");
    }
}

#[test]
fn delete_is_idempotent_and_exists_agrees() {
    for store in backends("delete") {
        let b = store.backend();
        store.put("d/obj", b"x").unwrap();
        assert!(store.exists("d/obj").unwrap(), "{b}");
        store.delete("d/obj").unwrap();
        assert!(!store.exists("d/obj").unwrap(), "{b}");
        assert!(storage::is_not_found(&store.get("d/obj").unwrap_err()), "{b}");
        store.delete("d/obj").expect("deleting an absent key must succeed");
        assert_eq!(store.metrics().delete_ops.get(), 2, "{b}: both deletes counted");
    }
}

#[test]
fn invalid_keys_rejected_before_backend_io() {
    for store in backends("bad_keys") {
        let b = store.backend();
        for bad in ["", "/abs", "a//b", "trail/", "../up", "a/./b"] {
            assert!(store.put(bad, b"x").is_err(), "{b}: put '{bad}'");
            assert!(store.get(bad).is_err(), "{b}: get '{bad}'");
            assert!(store.delete(bad).is_err(), "{b}: delete '{bad}'");
        }
        let m = store.metrics();
        assert_eq!(
            (m.put_ops.get(), m.get_ops.get(), m.delete_ops.get()),
            (0, 0, 0),
            "{b}: rejected keys must not reach backend accounting"
        );
    }
}

#[test]
fn chunked_blobs_reassemble_and_dedupe() {
    // sizes straddling every chunk boundary of a 64-byte chunk store,
    // plus empty and multi-chunk blobs
    let sizes = [0usize, 1, 63, 64, 65, 128, 1000, 4096 + 17];
    for store in backends("chunks") {
        let b = store.backend();
        let chunks = ChunkStore::with_chunk_size(Arc::clone(&store), 64);
        for (i, &len) in sizes.iter().enumerate() {
            let data = blob(100 + i as u64, len);
            let key = format!("blobs/{i}");
            let stats = chunks.put_blob(&key, &data).unwrap();
            assert_eq!(stats.bytes_total, len as u64, "{b}: blob {i}");
            assert_eq!(stats.chunks_total, len.div_ceil(64), "{b}: blob {i}");
            assert_eq!(chunks.get_blob(&key).unwrap(), data, "{b}: blob {i} reassembly");

            // property: re-publishing identical bytes uploads nothing
            let again = chunks.put_blob(&key, &data).unwrap();
            assert_eq!(again.chunks_written, 0, "{b}: blob {i} must fully dedupe");
            assert_eq!(again.bytes_deduped, len as u64, "{b}: blob {i}");
            assert_eq!(chunks.get_blob(&key).unwrap(), data, "{b}: blob {i} after dedupe");
        }

        // property: a blob sharing a prefix dedupes exactly the shared
        // whole chunks and uploads only the changed tail
        let base = blob(999, 64 * 8);
        chunks.put_blob("blobs/base", &base).unwrap();
        let mut variant = base.clone();
        let last = variant.len() - 1;
        variant[last] ^= 0xff;
        let stats = chunks.put_blob("blobs/variant", &variant).unwrap();
        assert_eq!(stats.chunks_written, 1, "{b}: only the changed tail chunk");
        assert_eq!(stats.bytes_deduped, 64 * 7, "{b}");
        assert_eq!(chunks.get_blob("blobs/variant").unwrap(), variant, "{b}");
        assert_eq!(chunks.get_blob("blobs/base").unwrap(), base, "{b}: base untouched");
    }
}
