//! Property suite: Algorithm 1 invariants across random layer shapes and
//! device profiles.

use lrta::devmodel::DeviceProfile;
use lrta::lrd::plan::snap_rank;
use lrta::lrd::LayerShape;
use lrta::rankopt::{optimize_rank, r2_of, ModelTimer, RankOptConfig};
use lrta::util::check::{forall, Config};
use lrta::util::rng::Rng;

fn cfg(cases: usize, seed: u64) -> Config {
    Config { cases, seed }
}

fn random_device(r: &mut Rng) -> DeviceProfile {
    match r.below(4) {
        0 => DeviceProfile::v100(),
        1 => DeviceProfile::ascend910(),
        2 => DeviceProfile::tpu_v4(),
        _ => DeviceProfile::cpu_sim(),
    }
}

fn random_shape(r: &mut Rng) -> LayerShape {
    if r.below(2) == 0 {
        LayerShape::linear(32 + r.below(480), 32 + r.below(480))
    } else {
        LayerShape::conv(32 + r.below(224), 32 + r.below(224), 3)
    }
}

#[test]
fn prop_ropt_within_band_and_never_worse_than_nominal() {
    forall(
        cfg(40, 301),
        |r: &mut Rng| (random_device(r), random_shape(r), 512 << r.below(4)),
        |(dev, shape, m)| {
            let mut timer = ModelTimer(dev.clone());
            let cfg = RankOptConfig { m: *m, ..Default::default() };
            let res = optimize_rank(&mut timer, *shape, &cfg).unwrap();
            res.r_opt >= res.r_min
                && res.r_opt <= res.r_nominal
                && res.t_opt <= res.t_nominal + 1e-15
                && res.speedup_vs_nominal() >= 1.0 - 1e-12
        },
    );
}

#[test]
fn prop_sweep_well_formed() {
    forall(
        cfg(30, 302),
        |r: &mut Rng| (random_device(r), random_shape(r)),
        |(dev, shape)| {
            let mut timer = ModelTimer(dev.clone());
            let res = optimize_rank(&mut timer, *shape, &Default::default()).unwrap();
            // descending ranks, stride 1, endpoints exact, delta aligned
            let ok_order = res.sweep.windows(2).all(|w| w[0].r == w[1].r + 1);
            let ok_ends = res.sweep.first().unwrap().r == res.r_nominal
                && res.sweep.last().unwrap().r == res.r_min;
            let ok_delta = res.delta.len() + 1 == res.sweep.len();
            // compression grows monotonically as rank shrinks
            let ok_ratio = res.sweep.windows(2).all(|w| w[1].ratio >= w[0].ratio - 1e-12);
            ok_order && ok_ends && ok_delta && ok_ratio
        },
    );
}

#[test]
fn prop_effective_time_is_min_of_choices() {
    // Algorithm 1's fallback: what actually runs is never slower than both
    // the dense layer and the chosen decomposition.
    forall(
        cfg(40, 303),
        |r: &mut Rng| (random_device(r), random_shape(r), 256 << r.below(5)),
        |(dev, shape, m)| {
            let mut timer = ModelTimer(dev.clone());
            let cfg = RankOptConfig { m: *m, ..Default::default() };
            let res = optimize_rank(&mut timer, *shape, &cfg).unwrap();
            let eff = res.effective_time();
            eff <= res.t_dense + 1e-15 && eff <= res.t_opt + 1e-15
        },
    );
}

#[test]
fn prop_devmodel_time_monotone_under_padding() {
    // padding to the tile never *reduces* modelled time, and aligned dims
    // are never slower than the next misaligned size up
    forall(
        cfg(200, 304),
        |r: &mut Rng| {
            let dev = random_device(r);
            let m = 64 + r.below(2048);
            let k = 16 + r.below(1024);
            let n = 16 + r.below(1024);
            (dev, m, k, n)
        },
        |(dev, m, k, n)| {
            let t = dev.matmul_time(*m, *k, *n);
            let t_up = dev.matmul_time(*m, k + 1, *n);
            // growing k by 1 can cross a tile boundary (jump up) but can
            // never make it faster... unless k+1 becomes aligned while k
            // was not (the rank-quantization effect itself)
            let k_aligned = k % dev.tile_k == 0;
            let k1_aligned = (k + 1) % dev.tile_k == 0;
            if !k_aligned && k1_aligned {
                true // alignment may legitimately speed it up
            } else {
                t_up >= t - 1e-15
            }
        },
    );
}

#[test]
fn prop_snap_rank_sound() {
    forall(
        cfg(300, 305),
        |r: &mut Rng| {
            let rank = 1 + r.below(512);
            let rmin = 1 + r.below(rank);
            let tile = [8usize, 16, 32, 64, 128][r.below(5)];
            (rank, rmin, tile)
        },
        |&(rank, rmin, tile)| {
            let s = snap_rank(rank, rmin, tile);
            s >= 1 && (s % tile == 0 || s == rank) && s <= rank + tile / 2
        },
    );
}

#[test]
fn prop_r2_of_bounds() {
    forall(
        cfg(300, 306),
        |r: &mut Rng| {
            let r1 = 1 + r.below(512);
            let beta = [0.5f64, 1.0, 2.0][r.below(3)];
            let s = 1 + r.below(1024);
            (r1, beta, s)
        },
        |&(r1, beta, s)| {
            let r2 = r2_of(r1, beta, s);
            r2 >= 1 && r2 <= s
        },
    );
}
