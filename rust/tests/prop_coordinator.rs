//! Property suite: coordinator invariants — freeze scheduling (Algorithm 2),
//! routing of epochs to artifacts, batching, and state management.

use lrta::data::{BatchIter, Dataset, IMAGE_ELEMS};
use lrta::freeze::{frozen_param_names, FreezeMode, FreezeScheduler, Pattern};
use lrta::models::Method;
use lrta::runtime::Manifest;
use lrta::util::check::{forall, Config};
use lrta::util::rng::Rng;
use std::collections::BTreeSet;

fn cfg(cases: usize, seed: u64) -> Config {
    Config { cases, seed }
}

fn random_layer_kinds(r: &mut Rng) -> Vec<(String, String)> {
    let n = 1 + r.below(12);
    (0..n)
        .map(|i| {
            let kind = if r.below(2) == 0 { "svd" } else { "tucker" };
            (format!("layer{i}"), kind.to_string())
        })
        .collect()
}

#[test]
fn prop_sequential_alternates_and_covers() {
    // Algorithm 2: consecutive epochs use complementary patterns and over
    // any window of ≥2 epochs every factor is trained at least once.
    forall(
        cfg(64, 201),
        |r: &mut Rng| (random_layer_kinds(r), 2 + r.below(20)),
        |(kinds, epochs)| {
            let s = FreezeScheduler::new(FreezeMode::Sequential);
            let all_factors: BTreeSet<String> = [Pattern::A, Pattern::B]
                .iter()
                .flat_map(|&p| frozen_param_names(kinds, p))
                .collect();
            let mut trained: BTreeSet<String> = BTreeSet::new();
            for e in 0..*epochs {
                let p = s.pattern(e);
                if e > 0 && s.pattern(e - 1) == p {
                    return false; // must alternate
                }
                let frozen: BTreeSet<String> =
                    frozen_param_names(kinds, p).into_iter().collect();
                for f in all_factors.difference(&frozen) {
                    trained.insert(f.clone());
                }
            }
            trained == all_factors
        },
    );
}

#[test]
fn prop_patterns_partition_factors() {
    // For any layer set: A-frozen and B-frozen factor sets are disjoint and
    // their union is exactly the full factor set.
    forall(
        cfg(128, 202),
        |r: &mut Rng| random_layer_kinds(r),
        |kinds| {
            let a: BTreeSet<String> = frozen_param_names(kinds, Pattern::A).into_iter().collect();
            let b: BTreeSet<String> = frozen_param_names(kinds, Pattern::B).into_iter().collect();
            let expected: BTreeSet<String> = kinds
                .iter()
                .flat_map(|(l, k)| {
                    if k == "svd" {
                        vec![format!("{l}.a"), format!("{l}.b")]
                    } else {
                        vec![format!("{l}.first"), format!("{l}.core"), format!("{l}.last")]
                    }
                })
                .collect();
            a.is_disjoint(&b) && a.union(&b).cloned().collect::<BTreeSet<_>>() == expected
        },
    );
}

#[test]
fn prop_scheduler_is_deterministic_and_mode_consistent() {
    forall(
        cfg(128, 203),
        |r: &mut Rng| {
            let mode = match r.below(3) {
                0 => FreezeMode::None,
                1 => FreezeMode::Regular,
                _ => FreezeMode::Sequential,
            };
            (mode, r.below(100))
        },
        |&(mode, epoch)| {
            let s1 = FreezeScheduler::new(mode);
            let s2 = FreezeScheduler::new(mode);
            let p = s1.pattern(epoch);
            if s2.pattern(epoch) != p {
                return false;
            }
            match mode {
                FreezeMode::None => p == Pattern::NoFreeze,
                FreezeMode::Regular => p == Pattern::A,
                FreezeMode::Sequential => {
                    (epoch % 2 == 0 && p == Pattern::A) || (epoch % 2 == 1 && p == Pattern::B)
                }
            }
        },
    );
}

#[test]
fn prop_method_to_artifact_routing_total() {
    // every (method, pattern) pair maps to a well-formed artifact name
    forall(
        cfg(64, 204),
        |r: &mut Rng| {
            let m = Method::ALL[r.below(5)];
            let e = r.below(50);
            (m, e)
        },
        |&(method, epoch)| {
            let mode = if method.uses_freezing() {
                FreezeMode::Sequential
            } else {
                FreezeMode::None
            };
            let pattern = FreezeScheduler::new(mode).pattern(epoch);
            let suffix = if method.variant() == "orig" { "none" } else { pattern.suffix() };
            let name = Manifest::name_of("resnet_mini", method.variant(), "train", suffix);
            name.starts_with("resnet_mini_")
                && name.contains(method.variant())
                && name.ends_with(suffix)
        },
    );
}

#[test]
fn prop_batch_iter_partitions_epoch() {
    // every epoch: each index appears at most once; batch shapes constant;
    // number of yielded samples = floor(n/batch)*batch.
    forall(
        cfg(24, 205),
        |r: &mut Rng| {
            let n = 16 + r.below(200);
            let batch = 1 + r.below(32);
            let seed = r.next_u64();
            (n, batch, seed)
        },
        |&(n, batch, seed)| {
            let data = Dataset::synthetic(n, 1);
            let mut count = 0usize;
            for (xs, ys) in BatchIter::new(&data, batch, seed) {
                if xs.len() != batch * IMAGE_ELEMS || ys.len() != batch {
                    return false;
                }
                count += batch;
            }
            count == (n / batch) * batch
        },
    );
}

#[test]
fn prop_dataset_batches_agree_with_storage() {
    forall(
        cfg(24, 206),
        |r: &mut Rng| (10 + r.below(50), r.below(40)),
        |&(n, start)| {
            let data = Dataset::synthetic(n, 3);
            let (xs, ys) = data.batch(start, 4);
            for i in 0..4 {
                let idx = (start + i) % n;
                if ys[i] != data.labels[idx] {
                    return false;
                }
                let expect = &data.images[idx * IMAGE_ELEMS..(idx + 1) * IMAGE_ELEMS];
                if &xs[i * IMAGE_ELEMS..(i + 1) * IMAGE_ELEMS] != expect {
                    return false;
                }
            }
            true
        },
    );
}
