//! Table 4 — ViT on the synthetic CIFAR-scale corpus, the five methods,
//! accuracy + training speed, mirroring the paper's Ascend-910 experiment:
//!
//! (a) measured `vit_mini` fine-tunes on the PJRT-CPU runtime,
//! (b) projected ViT-B/16 train/infer throughput on the simulated
//!     Ascend-910 via the device model (the paper decomposes only the
//!     embedding + per-block FFN FCs; attention stays dense).
//!
//! Env: LRTA_EPOCHS (default 3), LRTA_TRAIN (default 1024)
//! Output: results/table4.txt, results/table4_projected.txt

use lrta::coordinator::{
    decompose_checkpoint, ensure_pretrained, LrSchedule, TrainConfig, Trainer,
};
use lrta::devmodel::DeviceProfile;
use lrta::freeze::FreezeMode;
use lrta::lrd::plan::RankMode;
use lrta::models::zoo::{paper_plan, vit_b16};
use lrta::models::Method;
use lrta::runtime::{Manifest, Runtime};
use lrta::util::bench::{fmt_delta_pct, runtime_counters_json, table, write_json_section, write_report};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// See bench_table1: share of step time decomposition cannot touch
/// (attention, norms, softmax, optimizer, input pipeline — larger for a
/// ViT whose attention stays dense per the paper).
const FRAMEWORK_OVERHEAD: f64 = 0.45;

fn projected() -> String {
    let dev = DeviceProfile::ascend910();
    let model = vit_b16();
    let batch = 64;
    let mut rows = vec![vec![
        "Method".into(),
        "Train fps".into(),
        "Train Δ%".into(),
    ]];
    let ovh = FRAMEWORK_OVERHEAD * model.train_time(&dev, batch, None, None);
    let base = batch as f64 / (model.train_time(&dev, batch, None, None) + ovh);
    for method in Method::ALL {
        let plan = match method {
            Method::Original => None,
            Method::Lrd | Method::Freezing => Some(paper_plan(&model, 2.0, RankMode::Vanilla)),
            Method::RankOpt | Method::Combined => {
                Some(paper_plan(&model, 2.0, RankMode::Quantized { tile: 16 }))
            }
        };
        let freeze = if method.uses_freezing() { Some(true) } else { None };
        let fps = batch as f64 / (model.train_time(&dev, batch, plan.as_ref(), freeze) + ovh);
        rows.push(vec![
            method.label().to_string(),
            format!("{fps:.0}"),
            if method == Method::Original { "0".into() } else { fmt_delta_pct(base, fps) },
        ]);
    }
    table(&rows)
}

fn main() {
    let epochs = env_usize("LRTA_EPOCHS", 5);
    let train_size = env_usize("LRTA_TRAIN", 512);
    let model = "vit_mini";

    println!("=== Table 4 (a): projected ViT-B/16 on simulated Ascend-910 ===\n");
    let proj = projected();
    println!("{proj}");
    write_report("results/table4_projected.txt", &proj);

    println!("=== Table 4 (b): measured {model} fine-tunes ({epochs} epochs) ===\n");
    let manifest = Manifest::load("artifacts/manifest.json").expect("run `make artifacts`");
    let rt = Runtime::cpu().expect("pjrt");
    let dense = ensure_pretrained(&rt, &manifest, model, 8, train_size, 0).expect("pretrain");

    let mut rows = vec![vec![
        "Method".into(),
        "Accuracy".into(),
        "Train step (ms)".into(),
        "Speed-up %".into(),
    ]];
    let mut base_step: Option<f64> = None;

    for method in Method::ALL {
        let variant = method.variant();
        let params = if variant == "orig" {
            dense.clone()
        } else {
            decompose_checkpoint(&dense, manifest.config(model, variant).unwrap())
                .unwrap()
                .params
        };
        let cfg = TrainConfig {
            model: model.into(),
            variant: variant.into(),
            freeze: if method.uses_freezing() {
                FreezeMode::Sequential
            } else {
                FreezeMode::None
            },
            epochs,
            lr: LrSchedule::Fixed(2e-3),
            train_size,
            test_size: 512,
            seed: 0,
            verbose: false,
            resident: true,
            pipelined: true,
        };
        let mut trainer = Trainer::new(&rt, &manifest, cfg, params).expect("trainer");
        let record = trainer.run().expect("train");
        let step = record.median_step_secs();
        let base = *base_step.get_or_insert(step);
        let speedup = if method == Method::Original {
            "0".to_string()
        } else {
            fmt_delta_pct(1.0 / base, 1.0 / step)
        };
        println!(
            "  {:<10} acc {:.3} step {:.0} ms speedup {}",
            method.label(),
            record.final_test_acc(),
            step * 1e3,
            speedup
        );
        rows.push(vec![
            method.label().to_string(),
            format!("{:.3}", record.final_test_acc()),
            format!("{:.0}", step * 1e3),
            speedup,
        ]);
    }

    let t = table(&rows);
    println!("\n{t}");
    write_report("results/table4.txt", &t);
    write_json_section("results/bench_counters.json", "table4", runtime_counters_json(&rt));
    println!("table4 bench OK");
}
