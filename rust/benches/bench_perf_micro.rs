//! Performance micro-benchmarks (the §Perf instrumentation):
//!   - L3 substrates: tensor matmul GF/s, truncated SVD, Tucker2, JSON
//!     manifest parse, tensor↔literal conversion,
//!   - runtime hot path: PJRT execute overhead vs step compute — the
//!     host-literal path vs the device-resident-buffer path (the §Perf
//!     optimization), measured on the real train-step artifact.
//!
//! Output: results/perf_micro.txt

use lrta::checkpoint;
use lrta::coordinator::{run_train_step, zero_momenta};
use lrta::data::Dataset;
use lrta::linalg::svd_truncated;
use lrta::lrd::tucker2_conv;
use lrta::runtime::{tensor_to_literal, Manifest, Runtime};
use lrta::tensor::Tensor;
use lrta::util::bench::{bench, runtime_counters_json, table, write_json_section, write_report, BenchConfig};
use lrta::util::rng::Rng;

fn main() {
    let mut rows = vec![vec!["benchmark".to_string(), "median".to_string(), "notes".to_string()]];
    let cfg = BenchConfig { warmup_iters: 1, measure_iters: 5 };
    let mut rng = Rng::new(1);

    // --- substrates -------------------------------------------------------
    let a = Tensor::randn(&[512, 512], 1.0, &mut rng);
    let b = Tensor::randn(&[512, 512], 1.0, &mut rng);
    let r = bench("matmul 512^3", &cfg, || {
        std::hint::black_box(a.matmul(&b));
    });
    let gfs = 2.0 * 512f64.powi(3) / r.secs.median / 1e9;
    rows.push(vec![r.name.clone(), format!("{:.1} ms", r.median_ms()), format!("{gfs:.1} GF/s")]);

    let w = Tensor::randn(&[256, 2304], 0.05, &mut rng);
    let r = bench("svd_truncated [256,2304] r=155", &cfg, || {
        std::hint::black_box(svd_truncated(&w, 155).s.len());
    });
    rows.push(vec![r.name.clone(), format!("{:.0} ms", r.median_ms()), String::new()]);

    let w4 = Tensor::randn(&[256, 256, 3, 3], 0.05, &mut rng);
    let r = bench("tucker2 [256,256,3,3] r=155", &cfg, || {
        std::hint::black_box(tucker2_conv(&w4, 155, 155).params());
    });
    rows.push(vec![r.name.clone(), format!("{:.0} ms", r.median_ms()), String::new()]);

    let manifest_text = std::fs::read_to_string("artifacts/manifest.json").ok();
    if let Some(text) = &manifest_text {
        let r = bench("manifest JSON parse", &cfg, || {
            std::hint::black_box(
                lrta::util::json::Json::parse(text).unwrap().get("alpha").as_f64(),
            );
        });
        rows.push(vec![
            r.name.clone(),
            format!("{:.2} ms", r.median_ms()),
            format!("{} KiB", text.len() / 1024),
        ]);
    }

    let t = Tensor::randn(&[64, 32, 32, 3], 1.0, &mut rng);
    let r = bench("tensor->literal [64,32,32,3]", &cfg, || {
        std::hint::black_box(tensor_to_literal(&t).unwrap());
    });
    rows.push(vec![r.name.clone(), format!("{:.3} ms", r.median_ms()), String::new()]);

    // --- runtime hot path ---------------------------------------------------
    if let Ok(manifest) = Manifest::load("artifacts/manifest.json") {
        let rt = Runtime::cpu().expect("pjrt");
        let meta = manifest.artifact("resnet_mini_lrd_train_a").unwrap();
        let exe = rt.load_hlo(manifest.hlo_path(meta)).unwrap();
        let dense = checkpoint::load(manifest.init_checkpoint("resnet_mini").unwrap()).unwrap();
        let mut params = lrta::coordinator::decompose_checkpoint(
            &dense,
            manifest.config("resnet_mini", "lrd").unwrap(),
        )
        .unwrap()
        .params;
        let mut mom = zero_momenta(&params);
        let data = Dataset::synthetic(meta.batch, 3);
        let (xs, ys) = data.batch(0, meta.batch);

        // full step through the host-literal path (upload + run + download)
        run_train_step(&exe, meta, &mut params, &mut mom, &xs, &ys, 1e-3).unwrap();
        let r = bench("train step (host-literal path)", &cfg, || {
            run_train_step(&exe, meta, &mut params, &mut mom, &xs, &ys, 1e-3).unwrap();
        });
        let host_ms = r.median_ms();
        rows.push(vec![
            r.name.clone(),
            format!("{host_ms:.0} ms"),
            format!("{:.1} fps", meta.batch as f64 / r.secs.median),
        ]);

        // input-assembly cost alone (uploads without execution)
        let r = bench("  input assembly only", &cfg, || {
            let mut inputs: Vec<xla::Literal> = Vec::new();
            for slot in meta.trainable.iter().chain(meta.frozen.iter()) {
                inputs.push(tensor_to_literal(&params[&slot.name]).unwrap());
            }
            for slot in &meta.trainable {
                inputs.push(tensor_to_literal(&mom[&slot.name]).unwrap());
            }
            std::hint::black_box(inputs.len());
        });
        rows.push(vec![
            r.name.clone(),
            format!("{:.1} ms", r.median_ms()),
            format!("{:.1}% of step", r.median_ms() / host_ms * 100.0),
        ]);
        write_json_section(
            "results/bench_counters.json",
            "perf_micro",
            runtime_counters_json(&rt),
        );
    }

    let out = table(&rows);
    println!("{out}");
    write_report("results/perf_micro.txt", &out);
    println!("perf micro bench OK");
}
