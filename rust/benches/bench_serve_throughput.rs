//! Serving-throughput benchmark: resident-vs-reupload, batched-vs-unbatched
//! and lockstep-vs-pipelined across the `orig` / `lrd` / `rankopt` variants.
//!
//! Four serving modes per variant:
//!   1. **reupload, unbatched** — the old `serve_infer` behavior: one
//!      synchronous executable run per request with every parameter
//!      literal rebuilt and re-uploaded (host-literal path);
//!   2. **reupload, batched** — the subsystem's dynamic batcher, but the
//!      engine re-uploads parameters every batch (`reupload: true`);
//!   3. **resident, batched** — parameters uploaded once and kept
//!      device-resident, lockstep execute-then-respond (`pipelined: false`,
//!      the PR-2 behavior);
//!   4. **resident, pipelined** — the subsystem's default: streaming
//!      admission — batch N+1 coalesces/uploads/dispatches while batch N
//!      executes (split dispatch/fetch), so the device never waits on the
//!      host between batches under backlog.
//!
//! The LRD/rank-opt win the paper claims for inference only survives modes
//! 3-4: smaller resident factors mean the per-request work is just the
//! batch upload + the cheaper matmuls. Output:
//! results/serve_throughput.txt + results/serve_throughput.json and a
//! `serve` section in results/BENCH_pipeline.json (upload/demux counters
//! included per variant, from the engine stats gauges).
//!
//! Env: LRTA_MODEL (default resnet_mini), LRTA_SERVE_BENCH_REQS
//! (requests per measurement, default 4× compiled batch)

use anyhow::Result;
use lrta::checkpoint;
use lrta::data::Dataset;
use lrta::metrics::ThroughputMeter;
use lrta::runtime::{tensor_to_literal, Manifest, Runtime};
use lrta::serve::{self, Server, ServerConfig, VariantSpec};
use lrta::util::bench::{fmt_delta_pct, table, write_json_section, write_report};
use lrta::util::json::Json;
use std::time::Duration;

/// Mode 1: per-request full re-upload through the host-literal path, no
/// batching layer at all (each "request" still computes one compiled
/// batch — that is the smallest unit the artifact can run).
fn reupload_unbatched_fps(
    manifest: &Manifest,
    model: &str,
    variant: &str,
    params: &lrta::checkpoint::Params,
    reqs: usize,
) -> Result<f64> {
    let rt = Runtime::cpu()?;
    let meta = manifest.artifact(&format!("{model}_{variant}_infer"))?;
    let exe = rt.load_hlo(manifest.hlo_path(meta))?;
    let data = Dataset::synthetic(meta.batch, 99);
    let (xs, _) = data.batch(0, meta.batch);
    let x_dims: Vec<i64> = meta.x_shape.iter().map(|&d| d as i64).collect();
    let make_inputs = || -> Result<Vec<xla::Literal>> {
        let mut v = Vec::with_capacity(meta.trainable.len() + meta.frozen.len() + 1);
        for slot in meta.trainable.iter().chain(meta.frozen.iter()) {
            // the old serve_infer waste: parameters cross the host/device
            // boundary on every request
            v.push(tensor_to_literal(&params[&slot.name])?);
        }
        v.push(xla::Literal::vec1(&xs).reshape(&x_dims)?);
        Ok(v)
    };
    exe.run(&make_inputs()?)?; // warmup
    let mut meter = ThroughputMeter::new(meta.batch);
    let n = (reqs / meta.batch).max(3);
    for _ in 0..n {
        let inputs = make_inputs()?;
        meter.timed(|| exe.run(&inputs))?;
    }
    Ok(meter.fps())
}

/// Modes 2-4: burst load through the serving subsystem. Returns the
/// observed fps plus the engine's transfer-counter gauges
/// `(uploads, demux_fallbacks)`.
fn served_fps(
    manifest: &Manifest,
    model: &str,
    variant: &str,
    params: lrta::checkpoint::Params,
    reqs: usize,
    reupload: bool,
    pipelined: bool,
) -> Result<(f64, u64, u64)> {
    let cfg = ServerConfig {
        reupload,
        pipelined,
        max_wait: Duration::from_millis(5),
        ..Default::default()
    };
    let server = Server::start(
        manifest,
        vec![VariantSpec::new(model, variant, params)],
        &cfg,
    )?;
    let data = Dataset::synthetic(512, 99);
    // warmup burst, then the measured burst
    serve::burst_loop(&server, model, variant, &data, reqs / 4 + 1, Duration::from_secs(120));
    let report =
        serve::burst_loop(&server, model, variant, &data, reqs, Duration::from_secs(120));
    let snap = server.stats(model, variant).expect("registered variant");
    server.shutdown();
    Ok((report.observed_fps(), snap.uploads, snap.demux_fallbacks))
}

fn main() -> Result<()> {
    let model = std::env::var("LRTA_MODEL").unwrap_or_else(|_| "resnet_mini".into());
    let manifest = Manifest::load("artifacts/manifest.json")?;
    let dense = checkpoint::load(manifest.init_checkpoint(&model)?)?;

    let mut rows = vec![vec![
        "Variant".to_string(),
        "reupload unbatched fps".to_string(),
        "reupload batched fps".to_string(),
        "resident batched fps".to_string(),
        "pipelined fps".to_string(),
        "Δ pipelined vs resident".to_string(),
        "uploads (resident/pipelined)".to_string(),
    ]];
    let mut json_rows = Vec::new();
    let mut resident_beats_reupload = true;
    let mut pipelined_keeps_up = true;
    for variant in ["orig", "lrd", "rankopt"] {
        let params = VariantSpec::from_dense(&manifest, &model, variant, &dense)?.params;
        let batch = manifest.artifact(&format!("{model}_{variant}_infer"))?.batch;
        let reqs: usize = std::env::var("LRTA_SERVE_BENCH_REQS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(batch * 4);

        let unbatched =
            reupload_unbatched_fps(&manifest, &model, variant, &params, reqs)?;
        let (batched_reupload, _, _) =
            served_fps(&manifest, &model, variant, params.clone(), reqs, true, false)?;
        let (batched_resident, res_uploads, res_fallbacks) =
            served_fps(&manifest, &model, variant, params.clone(), reqs, false, false)?;
        let (batched_pipelined, pipe_uploads, pipe_fallbacks) =
            served_fps(&manifest, &model, variant, params, reqs, false, true)?;
        if variant != "orig" && batched_resident <= batched_reupload {
            resident_beats_reupload = false;
        }
        if batched_pipelined < 0.9 * batched_resident {
            pipelined_keeps_up = false;
        }
        println!(
            "{variant}: unbatched {unbatched:.0} | batched+reupload {batched_reupload:.0} | \
             batched+resident {batched_resident:.0} | pipelined {batched_pipelined:.0} fps \
             | uploads {res_uploads}/{pipe_uploads}"
        );
        rows.push(vec![
            variant.to_string(),
            format!("{unbatched:.0}"),
            format!("{batched_reupload:.0}"),
            format!("{batched_resident:.0}"),
            format!("{batched_pipelined:.0}"),
            fmt_delta_pct(batched_resident, batched_pipelined),
            format!("{res_uploads}/{pipe_uploads}"),
        ]);
        json_rows.push(Json::obj(vec![
            ("variant", Json::str(variant)),
            ("reupload_unbatched_fps", Json::num(unbatched)),
            ("reupload_batched_fps", Json::num(batched_reupload)),
            ("resident_batched_fps", Json::num(batched_resident)),
            ("pipelined_fps", Json::num(batched_pipelined)),
            ("uploads_resident", Json::int(res_uploads as i64)),
            ("uploads_pipelined", Json::int(pipe_uploads as i64)),
            ("demux_fallbacks_resident", Json::int(res_fallbacks as i64)),
            ("demux_fallbacks_pipelined", Json::int(pipe_fallbacks as i64)),
        ]));
    }

    let t = table(&rows);
    println!("\n{model} serving throughput:\n{t}");
    println!(
        "resident-parameter batched serving beats the re-upload baseline for \
         lrd+rankopt: {}",
        if resident_beats_reupload { "YES" } else { "NO (check machine load)" }
    );
    println!(
        "streaming admission keeps up with (or beats) the lockstep resident loop: {}",
        if pipelined_keeps_up { "YES" } else { "NO (check machine load)" }
    );
    write_report("results/serve_throughput.txt", &t);
    let section = Json::obj(vec![
        ("model", Json::str(model.as_str())),
        ("rows", Json::arr(json_rows)),
        ("pipelined_keeps_up", Json::Bool(pipelined_keeps_up)),
    ]);
    write_json_section("results/serve_throughput.json", "serve", section.clone());
    write_json_section("results/BENCH_pipeline.json", "serve", section);
    println!("serve_throughput bench OK");
    Ok(())
}
