//! Table 3 — accuracy after fine-tuning, five methods on `resnet_mini`
//! over the synthetic CIFAR-scale corpus, together with the measured
//! training speed-up (the table's last column).
//!
//! Pipeline per method (the paper's protocol at our scale):
//!   pretrained dense weights → (decompose) → fine-tune (fixed LR 1e-3,
//!   SGD momentum 0.9, weight decay 1e-4) → evaluate.
//!
//! Env: LRTA_EPOCHS (default 4), LRTA_TRAIN (default 1024)
//! Output: results/table3.txt (+ per-method curves in results/table3_curves/)

use lrta::coordinator::{
    decompose_checkpoint, ensure_pretrained, LrSchedule, TrainConfig, Trainer,
};
use lrta::freeze::FreezeMode;
use lrta::models::Method;
use lrta::runtime::{Manifest, Runtime};
use lrta::util::bench::{fmt_delta_pct, runtime_counters_json, table, write_json_section, write_report};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let epochs = env_usize("LRTA_EPOCHS", 5);
    let train_size = env_usize("LRTA_TRAIN", 512);
    let model = "resnet_mini";

    let manifest = Manifest::load("artifacts/manifest.json").expect("run `make artifacts`");
    let rt = Runtime::cpu().expect("pjrt");

    println!("=== Table 3: accuracy + training speedup, {model}, {epochs} epochs ===\n");
    let dense = ensure_pretrained(&rt, &manifest, model, 8, train_size, 0).expect("pretrain");

    let mut rows = vec![vec![
        "Method".into(),
        "Accuracy".into(),
        "Best".into(),
        "Train step (ms)".into(),
        "Speed-up %".into(),
    ]];
    let mut base_step: Option<f64> = None;

    for method in Method::ALL {
        let variant = method.variant();
        let params = if variant == "orig" {
            dense.clone()
        } else {
            decompose_checkpoint(&dense, manifest.config(model, variant).unwrap())
                .unwrap()
                .params
        };
        let cfg = TrainConfig {
            model: model.into(),
            variant: variant.into(),
            freeze: if method.uses_freezing() {
                FreezeMode::Sequential
            } else {
                FreezeMode::None
            },
            epochs,
            lr: LrSchedule::Fixed(2e-3),
            train_size,
            test_size: 512,
            seed: 0,
            verbose: false,
            resident: true,
            pipelined: true,
        };
        let mut trainer = Trainer::new(&rt, &manifest, cfg, params).expect("trainer");
        let record = trainer.run().expect("train");
        write_report(
            &format!("results/table3_curves/{}.csv", method.label().replace([' ', '.'], "")),
            &record.curve_csv(),
        );

        let step = record.median_step_secs();
        let base = *base_step.get_or_insert(step);
        // speed-up = throughput gain = base_step / step - 1 (same batch)
        let speedup = if method == Method::Original {
            "0".to_string()
        } else {
            fmt_delta_pct(1.0 / base, 1.0 / step)
        };
        println!(
            "  {:<10} acc {:.3} (best {:.3}) step {:.0} ms  speedup {}",
            method.label(),
            record.final_test_acc(),
            record.best_test_acc(),
            step * 1e3,
            speedup
        );
        rows.push(vec![
            method.label().to_string(),
            format!("{:.3}", record.final_test_acc()),
            format!("{:.3}", record.best_test_acc()),
            format!("{:.0}", step * 1e3),
            speedup,
        ]);
    }

    let t = table(&rows);
    println!("\n{t}");
    println!("shape to match (paper Table 3): accuracy ordering Original ≳ LRD ≳");
    println!("RankOpt ≳ Freezing ≳ Combined with small gaps; speed-up ordering");
    println!("Combined > RankOpt ≈ Freezing > LRD > 0.");
    write_report("results/table3.txt", &t);
    write_json_section("results/bench_counters.json", "table3", runtime_counters_json(&rt));
    println!("table3 bench OK");
}
