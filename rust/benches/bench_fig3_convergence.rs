//! Fig. 3 — fine-tuning convergence of the decomposed model under
//! *sequential* vs *regular* freezing (and no freezing as the reference):
//! test accuracy per epoch, plus the paper's headline comparison (epochs
//! needed to reach a target accuracy).
//!
//! Env: LRTA_EPOCHS (default 8), LRTA_TRAIN (default 768)
//! Output: results/fig3.txt + results/fig3_curves/*.csv

use lrta::coordinator::{
    decompose_checkpoint, ensure_pretrained, LrSchedule, TrainConfig, Trainer,
};
use lrta::freeze::FreezeMode;
use lrta::metrics::RunRecord;
use lrta::runtime::{Manifest, Runtime};
use lrta::util::bench::{runtime_counters_json, table, write_json_section, write_report};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let epochs = env_usize("LRTA_EPOCHS", 8);
    let train_size = env_usize("LRTA_TRAIN", 512);

    let manifest = Manifest::load("artifacts/manifest.json").expect("run `make artifacts`");
    let rt = Runtime::cpu().expect("pjrt");
    println!("=== Fig. 3: sequential vs regular freezing, {epochs} epochs ===\n");

    let dense = ensure_pretrained(&rt, &manifest, "resnet_mini", 8, train_size, 0)
        .expect("pretrain");
    let decomposed =
        decompose_checkpoint(&dense, manifest.config("resnet_mini", "lrd").unwrap()).unwrap();

    let mut records: Vec<(&str, RunRecord)> = Vec::new();
    for (label, mode) in [
        ("regular", FreezeMode::Regular),
        ("sequential", FreezeMode::Sequential),
    ] {
        let cfg = TrainConfig {
            model: "resnet_mini".into(),
            variant: "lrd".into(),
            freeze: mode,
            epochs,
            lr: LrSchedule::Fixed(2e-3),
            train_size,
            test_size: 256,
            seed: 0,
            verbose: true,
            resident: true,
            pipelined: true,
        };
        let mut trainer =
            Trainer::new(&rt, &manifest, cfg, decomposed.params.clone()).expect("trainer");
        let record = trainer.run().expect("train");
        write_report(&format!("results/fig3_curves/{label}.csv"), &record.curve_csv());
        records.push((label, record));
    }

    // epoch-by-epoch table (the figure, in text form)
    let mut rows = vec![vec![
        "epoch".to_string(),
        "regular acc".to_string(),
        "sequential acc".to_string(),
    ]];
    for e in 0..epochs {
        rows.push(vec![
            e.to_string(),
            format!("{:.4}", records[0].1.epochs[e].test_acc),
            format!("{:.4}", records[1].1.epochs[e].test_acc),
        ]);
    }
    let t = table(&rows);
    println!("\n{t}");

    let target = records
        .iter()
        .map(|(_, r)| r.best_test_acc())
        .fold(f64::NAN, f64::min)
        * 0.98;
    let mut summary = t.clone();
    for (label, r) in &records {
        let line = format!(
            "{label}: final {:.4}, best {:.4}, reaches {:.3} at epoch {:?}\n",
            r.final_test_acc(),
            r.best_test_acc(),
            target,
            r.epochs_to_reach(target)
        );
        print!("{line}");
        summary.push_str(&line);
    }
    println!("\nshape to match (paper Fig. 3): sequential reaches the target accuracy");
    println!("earlier and ends at-or-above regular (95.46 vs 95.27 in the paper).");
    write_report("results/fig3.txt", &summary);
    write_json_section("results/bench_counters.json", "fig3", runtime_counters_json(&rt));
    println!("fig3 bench OK");
}
