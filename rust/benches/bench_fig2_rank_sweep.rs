//! Fig. 2 — step time vs decomposition rank for conv [512,512,3,3]
//! (Tucker2, compression band 2x→3x), plus the first-derivative curve whose
//! first peak Algorithm 1 selects.
//!
//! Backends: simulated V100 / Ascend-910 / TPU-v4 (exhaustive stride-1
//! sweep, deterministic) and measured PJRT-CPU (stride 8).
//! Outputs: results/fig2_<backend>.csv and a printed summary.

use lrta::devmodel::DeviceProfile;
use lrta::lrd::LayerShape;
use lrta::rankopt::{optimize_rank, ModelTimer, PjrtTimer, RankOptConfig};
use lrta::runtime::Runtime;
use lrta::util::bench::{runtime_counters_json, table, write_json_section, write_report};
use lrta::util::stats;

fn main() {
    let shape = LayerShape::conv(512, 512, 3);
    let m = 1568; // 32 images x 7x7 positions (stage-4 geometry)
    println!("=== Fig. 2: rank sweep for [512,512,3,3] Tucker2, m={m} ===\n");

    let mut rows = vec![vec![
        "backend".to_string(),
        "R (Eq.5)".to_string(),
        "R_min (Eq.6)".to_string(),
        "R_opt".to_string(),
        "t_lrd (ms)".to_string(),
        "t_opt (ms)".to_string(),
        "speedup".to_string(),
        "staircase jump".to_string(),
    ]];

    for dev in [DeviceProfile::v100(), DeviceProfile::ascend910(), DeviceProfile::tpu_v4()] {
        let name = dev.name;
        let tile = dev.tile_n;
        let mut timer = ModelTimer(dev);
        let cfg = RankOptConfig { m, ..Default::default() };
        let r = optimize_rank(&mut timer, shape, &cfg).expect("sweep");

        // staircase check: the largest derivative peak vs the median step
        let peak = r.delta.iter().cloned().fold(0.0f64, f64::max);
        let med = stats::median(&r.sweep.iter().map(|p| p.t).collect::<Vec<_>>());
        let jump_pct = peak / med * 100.0;

        let mut csv = String::from("rank,time_ms,ratio,delta_ms\n");
        for (i, p) in r.sweep.iter().enumerate() {
            let d = if i == 0 { 0.0 } else { r.delta[i - 1] * 1e3 };
            csv.push_str(&format!("{},{:.6},{:.4},{:.6}\n", p.r, p.t * 1e3, p.ratio, d));
        }
        write_report(&format!("results/fig2_{name}.csv"), &csv);

        assert!(r.r_opt % tile == 0, "{name}: optimum must sit on the tile grid");
        assert!(peak > 0.0, "{name}: staircase must have jumps");

        rows.push(vec![
            name.to_string(),
            r.r_nominal.to_string(),
            r.r_min.to_string(),
            r.r_opt.to_string(),
            format!("{:.4}", r.t_nominal * 1e3),
            format!("{:.4}", r.t_opt * 1e3),
            format!("{:.2}x", r.speedup_vs_nominal()),
            format!("{jump_pct:.1}%"),
        ]);
    }

    // measured CPU sweep (strided — each rank is a fresh compile)
    let rt = Runtime::cpu().expect("pjrt client");
    let mut timer = PjrtTimer::new(&rt);
    let cfg = RankOptConfig { m: 784, stride: 8, ..Default::default() };
    let r = optimize_rank(&mut timer, shape, &cfg).expect("pjrt sweep");
    let mut csv = String::from("rank,time_ms,ratio\n");
    for p in &r.sweep {
        csv.push_str(&format!("{},{:.4},{:.4}\n", p.r, p.t * 1e3, p.ratio));
    }
    write_report("results/fig2_pjrt_cpu.csv", &csv);
    rows.push(vec![
        "pjrt-cpu (measured)".to_string(),
        r.r_nominal.to_string(),
        r.r_min.to_string(),
        r.r_opt.to_string(),
        format!("{:.3}", r.t_nominal * 1e3),
        format!("{:.3}", r.t_opt * 1e3),
        format!("{:.2}x", r.speedup_vs_nominal()),
        "-".to_string(),
    ]);

    let t = table(&rows);
    println!("{t}");
    write_report("results/fig2_summary.txt", &t);
    write_json_section("results/bench_counters.json", "fig2", runtime_counters_json(&rt));
    println!("fig2 bench OK — curves in results/fig2_*.csv");
}
