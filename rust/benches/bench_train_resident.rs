//! Resident-vs-roundtrip-vs-pipelined training throughput — the tentpole
//! claims of the `lrta::train` engine, per variant × freeze mode:
//!
//!   - **literal** — `run_train_step`: every parameter and momentum tensor
//!     crosses the host/device boundary on every step (the old hot loop,
//!     kept as the `--no-resident` baseline);
//!   - **resident** — `train::Engine::run_epoch`: params/momenta uploaded
//!     once, steps chained buffer-to-buffer, only the batch (`x`, `y`) and
//!     the cached `lr` scalar go up; loss/correct sync per step (2 scalars);
//!   - **pipelined** — `train::Engine::run_epoch_pipelined`: the overlapped
//!     loop on top of residency — batch N+1 uploads while step N executes
//!     (split dispatch/fetch), metrics accumulate on device and sync once
//!     per epoch.
//!
//! Sequential-freeze cases run one epoch under pattern "a", re-bind, and one
//! under "b". The bench reports host→device transfers beyond the per-step
//! x/y data (must be 0 for resident; pipelined additionally pays the
//! documented per-epoch accumulator reset), counted host fetches (2/step
//! serial vs 1/epoch pipelined), and any demux fallbacks.
//! Output: results/train_resident.txt + results/train_resident.json and a
//! `train` section in results/BENCH_pipeline.json.
//!
//! Env: LRTA_MODEL (default resnet_mini), LRTA_TRAIN_BENCH_STEPS
//! (steps per measurement per pattern, default 4)

use lrta::checkpoint;
use lrta::coordinator::{decompose_checkpoint, run_train_step, zero_momenta};
use lrta::data::Dataset;
use lrta::runtime::{ArtifactMeta, Executable, Manifest, Runtime};
use lrta::train::Engine;
use lrta::util::bench::{
    fmt_delta_pct, runtime_counters_json, table, write_json_section, write_report,
};
use lrta::util::json::Json;
use std::sync::Arc;
use std::time::Instant;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// The train executables one (variant, freeze) case steps through, in
/// schedule order: `["none"]`, or `["a", "b"]` for sequential freezing.
fn load_patterns<'m>(
    rt: &Runtime,
    manifest: &'m Manifest,
    model: &str,
    variant: &str,
    suffixes: &[&str],
) -> anyhow::Result<Vec<(Executable, &'m ArtifactMeta)>> {
    suffixes
        .iter()
        .map(|s| {
            let meta = manifest.artifact(&format!("{model}_{variant}_train_{s}"))?;
            Ok((rt.load_hlo(manifest.hlo_path(meta))?, meta))
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let model = std::env::var("LRTA_MODEL").unwrap_or_else(|_| "resnet_mini".into());
    let steps = env_usize("LRTA_TRAIN_BENCH_STEPS", 4);
    let manifest = Manifest::load("artifacts/manifest.json").expect("run `make artifacts`");
    let rt = Runtime::cpu().expect("pjrt");
    let dense = checkpoint::load(manifest.init_checkpoint(&model)?)?;

    let mut rows = vec![vec![
        "Variant".to_string(),
        "Freeze".to_string(),
        "literal fps".to_string(),
        "resident fps".to_string(),
        "pipelined fps".to_string(),
        "Δ pipelined".to_string(),
        "extra uploads".to_string(),
        "fetches (res/pipe)".to_string(),
    ]];
    let mut json_rows = Vec::new();
    let mut resident_wins_lrd = true;
    let mut pipelined_keeps_up = true;
    let mut swaps_clean = true;
    let mut metric_fetch_budget_held = true;

    for variant in ["orig", "lrd", "rankopt"] {
        let params = if variant == "orig" {
            dense.clone()
        } else {
            decompose_checkpoint(&dense, manifest.config(&model, variant)?)?.params
        };
        let cases: &[(&str, &[&str])] = if variant == "orig" {
            &[("none", &["none"])]
        } else {
            &[("none", &["none"]), ("sequential", &["a", "b"])]
        };
        for (freeze, suffixes) in cases {
            let exes = load_patterns(&rt, &manifest, &model, variant, suffixes)?;
            let batch = exes[0].1.batch;
            // one "epoch" of `steps` batches per pattern
            let data = Arc::new(Dataset::synthetic(batch * steps, 5));
            let samples = (batch * steps * exes.len()) as f64;
            let (xs, ys) = data.batch(0, batch);

            // --- literal round-trip baseline ------------------------------
            let mut p = params.clone();
            let mut mom = zero_momenta(&p);
            run_train_step(&exes[0].0, exes[0].1, &mut p, &mut mom, &xs, &ys, 1e-3)?; // warmup
            let t0 = Instant::now();
            for (exe, meta) in &exes {
                for bi in 0..steps {
                    let (bxs, bys) = data.batch(bi * batch, batch);
                    run_train_step(exe, meta, &mut p, &mut mom, &bxs, &bys, 1e-3)?;
                }
            }
            let lit_fps = samples / t0.elapsed().as_secs_f64();

            // --- resident serial engine -----------------------------------
            // warmup epoch compiles the upload executables and caches lr;
            // the a→b transition between pattern blocks is the
            // epoch-boundary rebind. Extra transfers are measured at the
            // runtime's upload channel — the measured window may contain
            // exactly the per-step x/y data uploads and nothing else.
            let mut engine = Engine::upload(&rt, &params, &zero_momenta(&params))?;
            engine.run_epoch(&exes[0].0, exes[0].1, &data, 5, 1e-3)?; // warmup
            let uploads0 = rt.uploads();
            let fetches0 = rt.fetches();
            let t0 = Instant::now();
            for (exe, meta) in &exes {
                engine.state().rebind_for(meta)?;
                engine.run_epoch(exe, meta, &data, 5, 1e-3)?;
            }
            let res_fps = samples / t0.elapsed().as_secs_f64();
            let data_uploads = exes.len() * steps * 2; // x + y per step
            let swap_uploads = rt.uploads() - uploads0 - data_uploads;
            let res_fetches = rt.fetches() - fetches0;

            // --- pipelined engine -----------------------------------------
            // same state-residency story plus overlap; the accumulator's
            // mask/zero uploads are the only transfers beyond x/y:
            // 2 masks once (lazy create in the warmup epoch) + 1 zero-reset
            // per epoch.
            let mut engine = Engine::upload(&rt, &params, &zero_momenta(&params))?;
            engine.run_epoch_pipelined(&exes[0].0, exes[0].1, &data, 5, 1e-3)?; // warmup
            let uploads0 = rt.uploads();
            let fetches0 = rt.fetches();
            let t0 = Instant::now();
            for (exe, meta) in &exes {
                engine.state().rebind_for(meta)?;
                engine.run_epoch_pipelined(exe, meta, &data, 5, 1e-3)?;
            }
            let pipe_fps = samples / t0.elapsed().as_secs_f64();
            let pipe_extra = rt.uploads() - uploads0 - data_uploads - exes.len(); // - resets
            let pipe_fetches = rt.fetches() - fetches0;

            if variant != "orig" && res_fps <= lit_fps {
                resident_wins_lrd = false;
            }
            if pipe_fps < 0.9 * res_fps {
                pipelined_keeps_up = false;
            }
            if swap_uploads != 0 || pipe_extra != 0 {
                swaps_clean = false;
            }
            // the tentpole's accounting claim: 2 scalars per step serial,
            // one metrics fetch per epoch pipelined
            if res_fetches != exes.len() * steps * 2 || pipe_fetches != exes.len() {
                metric_fetch_budget_held = false;
            }
            println!(
                "{variant:<8} {freeze:<10} literal {lit_fps:.1} | resident {res_fps:.1} | \
                 pipelined {pipe_fps:.1} fps | extra uploads {swap_uploads}+{pipe_extra} | \
                 fetches {res_fetches}/{pipe_fetches}"
            );
            rows.push(vec![
                variant.to_string(),
                freeze.to_string(),
                format!("{lit_fps:.1}"),
                format!("{res_fps:.1}"),
                format!("{pipe_fps:.1}"),
                fmt_delta_pct(res_fps, pipe_fps),
                format!("{swap_uploads}+{pipe_extra}"),
                format!("{res_fetches}/{pipe_fetches}"),
            ]);
            json_rows.push(Json::obj(vec![
                ("variant", Json::str(variant)),
                ("freeze", Json::str(*freeze)),
                ("literal_fps", Json::num(lit_fps)),
                ("resident_fps", Json::num(res_fps)),
                ("pipelined_fps", Json::num(pipe_fps)),
                ("extra_uploads_resident", Json::int(swap_uploads as i64)),
                ("extra_uploads_pipelined", Json::int(pipe_extra as i64)),
                ("fetches_resident", Json::int(res_fetches as i64)),
                ("fetches_pipelined", Json::int(pipe_fetches as i64)),
            ]));
        }
    }

    let t = table(&rows);
    println!("\n{model} training throughput (literal vs resident vs pipelined):\n{t}");
    println!(
        "buffer-chained stepping beats the literal round-trip for lrd+rankopt: {}",
        if resident_wins_lrd { "YES" } else { "NO (check machine load)" }
    );
    println!(
        "pipelined epochs keep up with (or beat) the serial resident loop: {}",
        if pipelined_keeps_up { "YES" } else { "NO (check machine load)" }
    );
    println!(
        "zero host→device transfers beyond per-step x/y data (+1 accumulator reset \
         per pipelined epoch): {}",
        if swaps_clean { "YES" } else { "NO" }
    );
    println!(
        "host-sync budget held (2 scalars/step serial, 1 fetch/epoch pipelined): {}",
        if metric_fetch_budget_held { "YES" } else { "NO" }
    );
    println!(
        "demux fallbacks (host round-trips forced by the backend): {}",
        rt.demux_fallbacks()
    );
    write_report("results/train_resident.txt", &t);
    let section = Json::obj(vec![
        ("model", Json::str(model.as_str())),
        ("steps_per_pattern", Json::int(steps as i64)),
        ("rows", Json::arr(json_rows)),
        ("runtime", runtime_counters_json(&rt)),
        ("pipelined_keeps_up", Json::Bool(pipelined_keeps_up)),
        ("fetch_budget_held", Json::Bool(metric_fetch_budget_held)),
    ]);
    write_json_section("results/train_resident.json", "train", section.clone());
    write_json_section("results/BENCH_pipeline.json", "train", section);
    println!("train_resident bench OK");
    Ok(())
}
