//! Resident-vs-roundtrip training throughput — the tentpole claim of the
//! `lrta::train` engine, per variant × freeze mode:
//!
//!   - **literal** — `run_train_step`: every parameter and momentum tensor
//!     crosses the host/device boundary on every step (the old hot loop,
//!     kept as the `--no-resident` baseline);
//!   - **resident** — `train::Engine`: params/momenta uploaded once, steps
//!     chained buffer-to-buffer, only the batch (`x`, `y`) and the cached
//!     `lr` scalar go up, only the loss/correct scalars come down.
//!
//! Sequential-freeze cases run half the steps under pattern "a", re-bind,
//! and finish under "b" — the bench reports host→device transfers beyond
//! the per-step x/y data (must be 0: swaps re-bind, steps chain) and any
//! demux fallbacks the backend forced.
//! Output: results/train_resident.txt
//!
//! Env: LRTA_MODEL (default resnet_mini), LRTA_TRAIN_BENCH_STEPS
//! (steps per measurement per pattern, default 4)

use lrta::checkpoint;
use lrta::coordinator::{decompose_checkpoint, run_train_step, zero_momenta};
use lrta::data::Dataset;
use lrta::metrics::ThroughputMeter;
use lrta::runtime::{ArtifactMeta, Executable, Manifest, Runtime};
use lrta::train::Engine;
use lrta::util::bench::{fmt_delta_pct, table, write_report};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// The train executables one (variant, freeze) case steps through, in
/// schedule order: `["none"]`, or `["a", "b"]` for sequential freezing.
fn load_patterns<'m>(
    rt: &Runtime,
    manifest: &'m Manifest,
    model: &str,
    variant: &str,
    suffixes: &[&str],
) -> anyhow::Result<Vec<(Executable, &'m ArtifactMeta)>> {
    suffixes
        .iter()
        .map(|s| {
            let meta = manifest.artifact(&format!("{model}_{variant}_train_{s}"))?;
            Ok((rt.load_hlo(manifest.hlo_path(meta))?, meta))
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let model = std::env::var("LRTA_MODEL").unwrap_or_else(|_| "resnet_mini".into());
    let steps = env_usize("LRTA_TRAIN_BENCH_STEPS", 4);
    let manifest = Manifest::load("artifacts/manifest.json").expect("run `make artifacts`");
    let rt = Runtime::cpu().expect("pjrt");
    let dense = checkpoint::load(manifest.init_checkpoint(&model)?)?;

    let mut rows = vec![vec![
        "Variant".to_string(),
        "Freeze".to_string(),
        "literal fps".to_string(),
        "resident fps".to_string(),
        "Δ resident".to_string(),
        "extra uploads".to_string(),
    ]];
    let mut resident_wins_lrd = true;
    let mut swaps_clean = true;

    for variant in ["orig", "lrd", "rankopt"] {
        let params = if variant == "orig" {
            dense.clone()
        } else {
            decompose_checkpoint(&dense, manifest.config(&model, variant)?)?.params
        };
        let cases: &[(&str, &[&str])] = if variant == "orig" {
            &[("none", &["none"])]
        } else {
            &[("none", &["none"]), ("sequential", &["a", "b"])]
        };
        for (freeze, suffixes) in cases {
            let exes = load_patterns(&rt, &manifest, &model, variant, suffixes)?;
            let batch = exes[0].1.batch;
            let data = Dataset::synthetic(batch * 2, 5);
            let (xs, ys) = data.batch(0, batch);

            // literal round-trip baseline
            let mut p = params.clone();
            let mut mom = zero_momenta(&p);
            run_train_step(&exes[0].0, exes[0].1, &mut p, &mut mom, &xs, &ys, 1e-3)?; // warmup
            let mut lit_meter = ThroughputMeter::new(batch);
            for (exe, meta) in &exes {
                for _ in 0..steps {
                    let t0 = std::time::Instant::now();
                    run_train_step(exe, meta, &mut p, &mut mom, &xs, &ys, 1e-3)?;
                    lit_meter.record(t0.elapsed().as_secs_f64());
                }
            }

            // resident buffer-chained engine; the a→b transition between
            // the pattern blocks is the epoch-boundary rebind. Extra
            // transfers are measured at the runtime's upload channel —
            // every host→device transfer flows through it, so the measured
            // window may contain exactly the x/y data uploads (the lr
            // scalar is cached at warmup) and nothing else; any swap
            // re-upload or demux fallback shows up as a surplus.
            let mut engine = Engine::upload(&rt, &params, &zero_momenta(&params))?;
            engine.step(&exes[0].0, exes[0].1, &xs, &ys, 1e-3)?; // warmup
            let uploads0 = rt.uploads();
            let mut res_meter = ThroughputMeter::new(batch);
            for (exe, meta) in &exes {
                engine.state().rebind_for(meta)?;
                for _ in 0..steps {
                    let t0 = std::time::Instant::now();
                    engine.step(exe, meta, &xs, &ys, 1e-3)?;
                    res_meter.record(t0.elapsed().as_secs_f64());
                }
            }
            let data_uploads = exes.len() * steps * 2; // x + y per step
            let swap_uploads = rt.uploads() - uploads0 - data_uploads;

            let (lit_fps, res_fps) = (lit_meter.fps(), res_meter.fps());
            if variant != "orig" && res_fps <= lit_fps {
                resident_wins_lrd = false;
            }
            if swap_uploads != 0 {
                swaps_clean = false;
            }
            println!(
                "{variant:<8} {freeze:<10} literal {lit_fps:.1} fps | resident {res_fps:.1} fps \
                 | extra uploads {swap_uploads}"
            );
            rows.push(vec![
                variant.to_string(),
                freeze.to_string(),
                format!("{lit_fps:.1}"),
                format!("{res_fps:.1}"),
                fmt_delta_pct(lit_fps, res_fps),
                format!("{swap_uploads}"),
            ]);
        }
    }

    let t = table(&rows);
    println!("\n{model} training throughput (resident vs literal round-trip):\n{t}");
    println!(
        "buffer-chained stepping beats the literal round-trip for lrd+rankopt: {}",
        if resident_wins_lrd { "YES" } else { "NO (check machine load)" }
    );
    println!(
        "resident runs performed zero host→device transfers beyond the per-step x/y data \
         (swaps re-bound, steps chained): {}",
        if swaps_clean { "YES" } else { "NO" }
    );
    println!(
        "demux fallbacks (host round-trips forced by the backend): {}",
        rt.demux_fallbacks()
    );
    write_report("results/train_resident.txt", &t);
    println!("train_resident bench OK");
    Ok(())
}
