//! Table 1 — training and inference speed (fps) with Δ% for the five
//! methods {Original, LRD, Rank Opt., Freezing, Combined}, two ways:
//!
//! (a) **paper scale, projected**: ResNet-50/101/152 on the simulated V100
//!     via the device model (deterministic; reproduces the paper's
//!     ordering and rough factors),
//! (b) **mini scale, measured**: `resnet_mini` on the real PJRT-CPU
//!     runtime — actual train steps and inference batches through the AOT
//!     artifacts.
//!
//! Outputs: results/table1_projected.txt, results/table1_measured.txt

use lrta::checkpoint;
use lrta::coordinator::{decompose_checkpoint, run_train_step, zero_momenta};
use lrta::data::Dataset;
use lrta::devmodel::DeviceProfile;
use lrta::lrd::plan::RankMode;
use lrta::metrics::ThroughputMeter;
use lrta::models::zoo::{paper_plan, resnet_full};
use lrta::models::Method;
use lrta::runtime::{tensor_to_literal, Manifest, Runtime};
use lrta::train::Engine;
use lrta::util::bench::{fmt_delta_pct, runtime_counters_json, table, write_json_section, write_report};

/// Fraction of the *dense* model's layer time spent in work decomposition
/// cannot touch (norms, activations, optimizer update, data pipeline,
/// framework dispatch). The paper's fps baselines include all of it, which
/// is what dilutes their observed gains relative to pure conv/fc math.
const FRAMEWORK_OVERHEAD: f64 = 0.45;

/// Projected (devmodel) fps for a full-size model + method.
fn projected_fps(depth: usize, method: Method, dev: &DeviceProfile, batch: usize) -> (f64, f64) {
    let model = resnet_full(depth);
    let plan = match method {
        Method::Original => None,
        Method::Lrd | Method::Freezing => Some(paper_plan(&model, 2.0, RankMode::Vanilla)),
        Method::RankOpt | Method::Combined => {
            Some(paper_plan(&model, 2.0, RankMode::Quantized { tile: 64 }))
        }
    };
    // freezing trains one factor group per epoch — pattern A as the
    // representative step (B is symmetric in cost)
    let freeze = if method.uses_freezing() { Some(true) } else { None };
    let ovh_t = FRAMEWORK_OVERHEAD * model.train_time(dev, batch, None, None);
    let ovh_i = FRAMEWORK_OVERHEAD * model.infer_time(dev, batch, None);
    let train = model.train_time(dev, batch, plan.as_ref(), freeze) + ovh_t;
    let infer = model.infer_time(dev, batch, plan.as_ref()) + ovh_i;
    (batch as f64 / train, batch as f64 / infer)
}

fn projected_table() -> String {
    let dev = DeviceProfile::v100();
    let batch = 32;
    let mut rows = vec![vec![
        "Method".into(),
        "Train fps".into(),
        "Train Δ%".into(),
        "Infer fps".into(),
        "Infer Δ%".into(),
    ]];
    for depth in [50usize, 101, 152] {
        let (base_t, base_i) = projected_fps(depth, Method::Original, &dev, batch);
        for method in Method::ALL {
            let (t, i) = projected_fps(depth, method, &dev, batch);
            let label = if method == Method::Original {
                format!("ResNet-{depth}")
            } else {
                format!("  {}", method.label())
            };
            rows.push(vec![
                label,
                format!("{t:.0}"),
                if method == Method::Original { "0".into() } else { fmt_delta_pct(base_t, t) },
                format!("{i:.0}"),
                if method == Method::Original { "0".into() } else { fmt_delta_pct(base_i, i) },
            ]);
        }
    }
    table(&rows)
}

/// Measured fps on the mini model through the real runtime.
fn measured_table(rt: &Runtime, manifest: &Manifest) -> anyhow::Result<String> {
    let model = "resnet_mini";
    let dense = checkpoint::load(manifest.init_checkpoint(model)?)?;
    let mut rows = vec![vec![
        "Method".into(),
        "Train fps".into(),
        "Train Δ%".into(),
        "Resident fps".into(),
        "Res Δ%".into(),
        "Infer fps".into(),
        "Infer Δ%".into(),
    ]];
    let mut base: Option<(f64, f64)> = None;

    for method in Method::ALL {
        let variant = method.variant();
        let params = if variant == "orig" {
            dense.clone()
        } else {
            decompose_checkpoint(&dense, manifest.config(model, variant)?)?.params
        };

        // train-step throughput: the artifact the freeze schedule actually
        // runs (pattern A for freezing methods, the full step otherwise)
        let suffix = if method.uses_freezing() { "a" } else { "none" };
        let tmeta = manifest.artifact(&format!("{model}_{variant}_train_{suffix}"))?;
        let texe = rt.load_hlo(manifest.hlo_path(tmeta))?;
        let mut p = params.clone();
        let mut mom = zero_momenta(&p);
        let data = Dataset::synthetic(tmeta.batch * 2, 5);
        let (xs, ys) = data.batch(0, tmeta.batch);
        run_train_step(&texe, tmeta, &mut p, &mut mom, &xs, &ys, 1e-3)?; // warmup
        let mut meter = ThroughputMeter::new(tmeta.batch);
        for _ in 0..4 {
            let t0 = std::time::Instant::now();
            run_train_step(&texe, tmeta, &mut p, &mut mom, &xs, &ys, 1e-3)?;
            meter.record(t0.elapsed().as_secs_f64());
        }
        let train_fps = meter.fps();

        // the same step through the buffer-chained resident engine
        // (bench_train_resident has the full variant × freeze matrix)
        let mut engine = Engine::upload(rt, &params, &zero_momenta(&params))?;
        engine.step(&texe, tmeta, &xs, &ys, 1e-3)?; // warmup
        let mut rmeter = ThroughputMeter::new(tmeta.batch);
        for _ in 0..4 {
            let t0 = std::time::Instant::now();
            engine.step(&texe, tmeta, &xs, &ys, 1e-3)?;
            rmeter.record(t0.elapsed().as_secs_f64());
        }
        let resident_fps = rmeter.fps();

        // inference throughput
        let imeta = manifest.artifact(&format!("{model}_{variant}_infer"))?;
        let iexe = rt.load_hlo(manifest.hlo_path(imeta))?;
        let idata = Dataset::synthetic(imeta.batch, 6);
        let (ix, _) = idata.batch(0, imeta.batch);
        let x_dims: Vec<i64> = imeta.x_shape.iter().map(|&d| d as i64).collect();
        let mk = || -> anyhow::Result<Vec<xla::Literal>> {
            let mut v = Vec::new();
            for slot in &imeta.trainable {
                v.push(tensor_to_literal(&params[&slot.name])?);
            }
            v.push(xla::Literal::vec1(&ix).reshape(&x_dims)?);
            Ok(v)
        };
        iexe.run(&mk()?)?; // warmup
        let mut imeter = ThroughputMeter::new(imeta.batch);
        for _ in 0..5 {
            let inputs = mk()?;
            let t0 = std::time::Instant::now();
            iexe.run(&inputs)?;
            imeter.record(t0.elapsed().as_secs_f64());
        }
        let infer_fps = imeter.fps();

        let (bt, bi) = *base.get_or_insert((train_fps, infer_fps));
        rows.push(vec![
            if method == Method::Original { format!("{model}") } else { format!("  {}", method.label()) },
            format!("{train_fps:.1}"),
            if method == Method::Original { "0".into() } else { fmt_delta_pct(bt, train_fps) },
            format!("{resident_fps:.1}"),
            fmt_delta_pct(train_fps, resident_fps),
            format!("{infer_fps:.1}"),
            if method == Method::Original { "0".into() } else { fmt_delta_pct(bi, infer_fps) },
        ]);
        println!(
            "  measured {:<10} train {train_fps:.1} fps (resident {resident_fps:.1}), infer {infer_fps:.1} fps",
            method.label()
        );
    }
    Ok(table(&rows))
}

fn main() {
    println!("=== Table 1 (a): projected ResNet-50/101/152 on simulated V100 ===\n");
    let proj = projected_table();
    println!("{proj}");
    write_report("results/table1_projected.txt", &proj);

    println!("=== Table 1 (b): measured resnet_mini on PJRT-CPU ===\n");
    let manifest = Manifest::load("artifacts/manifest.json").expect("run `make artifacts`");
    let rt = Runtime::cpu().expect("pjrt");
    let measured = measured_table(&rt, &manifest).expect("measured table");
    println!("\n{measured}");
    write_report("results/table1_measured.txt", &measured);
    write_json_section("results/bench_counters.json", "table1", runtime_counters_json(&rt));
    println!("table1 bench OK");
}
