//! Table 2 — decomposition wall-time of ResNet-50/101/152 with vanilla LRD
//! vs rank optimization vs freezing, on the *true* full-size layer shapes.
//!
//! - "Vanilla LRD" / "Freezing": real SVD/Tucker2 factorization of every
//!   decomposable layer (freezing adds zero overhead — it is just a flag,
//!   exactly the paper's point).
//! - "Rank Optimization": factorization time + the Algorithm-1 sweep cost.
//!   The sweep is *measured* on PJRT-CPU per unique layer shape (stride 16,
//!   small m — each rank is a real compile+run) and multiplied by the
//!   number of layer instances, mirroring how the paper's per-layer sweep
//!   scales with depth.
//!
//! Env: LRTA_T2_DEPTHS=50 to restrict (default "50,101,152").
//! Output: results/table2.txt

use lrta::lrd::plan::RankMode;
use lrta::lrd::{svd_linear, tucker2_conv, LayerShape};
use lrta::models::zoo::{paper_plan, resnet_full};
use lrta::rankopt::{optimize_rank, PjrtTimer, RankOptConfig};
use lrta::runtime::Runtime;
use lrta::tensor::Tensor;
use lrta::util::bench::{runtime_counters_json, table, write_json_section, write_report};
use lrta::util::rng::Rng;
use std::collections::BTreeMap;
use std::time::Instant;

fn main() {
    let depths: Vec<usize> = std::env::var("LRTA_T2_DEPTHS")
        .unwrap_or_else(|_| "50,101,152".into())
        .split(',')
        .filter_map(|d| d.trim().parse().ok())
        .collect();

    let rt = Runtime::cpu().expect("pjrt");
    let mut sweep_cache: BTreeMap<(usize, usize, usize), f64> = BTreeMap::new();
    let mut rows = vec![vec![
        "Model".into(),
        "Vanilla LRD (s)".into(),
        "Rank Optimization (s)".into(),
        "Freezing (s)".into(),
        "layers".into(),
    ]];

    for depth in depths {
        let model = resnet_full(depth);
        let plan = paper_plan(&model, 2.0, RankMode::Vanilla);
        let mut rng = Rng::new(depth as u64);

        // --- vanilla decomposition: factorize every planned layer -------
        let t0 = Instant::now();
        let mut count = 0usize;
        for lp in plan.layers.iter().filter(|l| l.decompose) {
            let s = lp.shape;
            if s.is_linear() {
                let w = Tensor::randn(&[s.c, s.s], 0.05, &mut rng);
                let f = svd_linear(&w, lp.r1);
                std::hint::black_box(f.params());
            } else {
                let w = Tensor::randn(&[s.c, s.s, s.k, s.k], 0.05, &mut rng);
                let f = tucker2_conv(&w, lp.r1, lp.r2);
                std::hint::black_box(f.params());
            }
            count += 1;
        }
        let vanilla_secs = t0.elapsed().as_secs_f64();

        // --- rank-opt sweep overhead: measured per unique shape ----------
        let mut sweep_secs = 0.0f64;
        for lp in plan.layers.iter().filter(|l| l.decompose) {
            let s = lp.shape;
            let key = (s.c, s.s, s.k);
            let per_layer = *sweep_cache.entry(key).or_insert_with(|| {
                let t0 = Instant::now();
                let mut timer = PjrtTimer { rt: &rt, warmup: 1, reps: 3 };
                let cfg = RankOptConfig { m: 392, stride: 16, ..Default::default() };
                let shape = if s.k == 1 {
                    LayerShape::linear(s.c, s.s)
                } else {
                    LayerShape::conv(s.c, s.s, s.k)
                };
                let _ = optimize_rank(&mut timer, shape, &cfg).expect("sweep");
                t0.elapsed().as_secs_f64()
            });
            sweep_secs += per_layer;
        }

        println!(
            "resnet{depth}: vanilla {vanilla_secs:.1}s, rank-opt {:.1}s, freezing {vanilla_secs:.1}s ({count} layers)",
            vanilla_secs + sweep_secs
        );
        rows.push(vec![
            format!("ResNet-{depth}"),
            format!("{vanilla_secs:.1}"),
            format!("{:.1}", vanilla_secs + sweep_secs),
            format!("{vanilla_secs:.1}"), // freezing adds no decomposition cost
            count.to_string(),
        ]);
    }

    let t = table(&rows);
    println!("\n{t}");
    println!("shape to match (paper Table 2): rank-opt > vanilla = freezing,");
    println!("all growing with depth; overhead minutes-scale vs hours of training.");
    write_report("results/table2.txt", &t);
    write_json_section("results/bench_counters.json", "table2", runtime_counters_json(&rt));
    println!("table2 bench OK");
}
