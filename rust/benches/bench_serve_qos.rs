//! Rank-aware QoS benchmark: the same overload burst with and without
//! priority classes, degrade ladders and per-class SLOs.
//!
//! The baseline drives an open-loop burst through `orig` with QoS off —
//! one class, no SLO, nothing sheds and the tail latency is whatever the
//! backlog makes it. The QoS measurement replays the identical burst as a
//! 3-class mix (`interactive:4:250, standard:2:100, batch:1:5`) with
//! `batch` and `standard` degrading to `rankopt`: interactive keeps its
//! p99 inside the SLO while the cheap classes spill down the ladder
//! instead of shedding. Output: per-class p50/p99 + spill-rate curve in
//! results/serve_qos.txt and a top-level JSON report
//! results/BENCH_serve_qos.json (uploaded as a CI artifact).
//!
//! Env: LRTA_MODEL (default resnet_mini), LRTA_SERVE_BENCH_REQS
//! (requests per measurement, default 12× compiled batch)

use anyhow::Result;
use lrta::checkpoint;
use lrta::data::Dataset;
use lrta::runtime::Manifest;
use lrta::serve::{self, Class, QosConfig, Server, ServerConfig, VariantSpec};
use lrta::util::bench::{table, write_json_section, write_report};
use lrta::util::json::Json;
use std::time::Duration;

const CLASS_SPEC: &str = "interactive:4:250,standard:2:100,batch:1:5";
const DEGRADE_SPEC: &str = "batch=rankopt,standard=rankopt";

fn start_server(
    manifest: &Manifest,
    model: &str,
    dense: &checkpoint::Params,
    qos: Option<QosConfig>,
) -> Result<Server> {
    let mut specs = Vec::new();
    for variant in ["orig", "rankopt"] {
        specs.push(VariantSpec::from_dense(manifest, model, variant, dense)?);
    }
    let cfg = ServerConfig {
        max_wait: Duration::from_millis(5),
        // deep queues: the burst is admitted up front so SLO pressure is
        // decided at pop time, not by admission control
        queue_depth: 1024,
        spot_check: 0,
        qos,
        ..Default::default()
    };
    Server::start(manifest, specs, &cfg)
}

fn main() -> Result<()> {
    let model = std::env::var("LRTA_MODEL").unwrap_or_else(|_| "resnet_mini".into());
    let manifest = Manifest::load("artifacts/manifest.json")?;
    let dense = checkpoint::load(manifest.init_checkpoint(&model)?)?;
    let batch = manifest.artifact(&Manifest::name_of(&model, "orig", "infer", "none"))?.batch;
    let reqs: usize = std::env::var("LRTA_SERVE_BENCH_REQS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(batch * 12);
    let timeout = Duration::from_secs(120);
    let data = Dataset::synthetic(512, 99);

    // baseline: the identical burst with QoS off — one implicit class
    let server = start_server(&manifest, &model, &dense, None)?;
    serve::burst_loop(&server, &model, "orig", &data, reqs / 4 + 1, timeout);
    let base = serve::burst_loop(&server, &model, "orig", &data, reqs, timeout);
    server.shutdown();
    println!(
        "baseline (qos off): {:.0} fps | p50 {:.2} ms p99 {:.2} ms | {} ok {} shed",
        base.observed_fps(),
        base.latency_ms(50.0),
        base.latency_ms(99.0),
        base.completed,
        base.shed
    );

    // QoS: weighted classes + per-class SLOs, cheap classes ladder down
    let classes = QosConfig::parse_classes(CLASS_SPEC)?;
    let qos = QosConfig {
        classes: classes.clone(),
        degrade: QosConfig::parse_degrade(DEGRADE_SPEC)?,
        hedge: None,
    };
    let server = start_server(&manifest, &model, &dense, Some(qos))?;
    let mix = Class::ALL;
    serve::classed_burst_loop(&server, &model, "orig", &data, reqs / 4 + 1, &mix, timeout);
    // counter baseline after warmup: the measured burst reports deltas
    let o0 = server.stats(&model, "orig").expect("orig registered");
    let r0 = server.stats(&model, "rankopt").expect("rankopt registered");
    let reports = serve::classed_burst_loop(&server, &model, "orig", &data, reqs, &mix, timeout);
    let o1 = server.stats(&model, "orig").expect("orig registered");
    let r1 = server.stats(&model, "rankopt").expect("rankopt registered");
    server.shutdown();

    let mut rows = vec![vec![
        "Class".to_string(),
        "reqs".to_string(),
        "ok".to_string(),
        "shed".to_string(),
        "spilled".to_string(),
        "spill %".to_string(),
        "p50 ms".to_string(),
        "p99 ms".to_string(),
        "SLO ms".to_string(),
    ]];
    let mut json_rows = Vec::new();
    for class in Class::ALL {
        let i = class.index();
        let rep = &reports[i];
        let spilled = o1.spilled_by_class[i] - o0.spilled_by_class[i];
        let spill_rate =
            if rep.requests > 0 { spilled as f64 / rep.requests as f64 } else { 0.0 };
        let slo_ms = classes[i].slo.map(|d| d.as_secs_f64() * 1e3);
        println!(
            "{class}: {} ok {} shed {} spilled ({:.0}%) | p50 {:.2} ms p99 {:.2} ms",
            rep.completed,
            rep.shed,
            spilled,
            spill_rate * 100.0,
            rep.latency_ms(50.0),
            rep.latency_ms(99.0)
        );
        rows.push(vec![
            class.to_string(),
            rep.requests.to_string(),
            rep.completed.to_string(),
            rep.shed.to_string(),
            spilled.to_string(),
            format!("{:.0}", spill_rate * 100.0),
            format!("{:.2}", rep.latency_ms(50.0)),
            format!("{:.2}", rep.latency_ms(99.0)),
            slo_ms.map(|s| format!("{s:.0}")).unwrap_or_else(|| "-".into()),
        ]);
        json_rows.push(Json::obj(vec![
            ("class", Json::str(class.label())),
            ("requests", Json::int(rep.requests as i64)),
            ("completed", Json::int(rep.completed as i64)),
            ("shed", Json::int(rep.shed as i64)),
            ("spilled", Json::int(spilled as i64)),
            ("spill_rate", Json::num(spill_rate)),
            ("fps", Json::num(rep.observed_fps())),
            ("p50_ms", Json::num(rep.latency_ms(50.0))),
            ("p99_ms", Json::num(rep.latency_ms(99.0))),
            ("slo_ms", slo_ms.map(Json::num).unwrap_or_else(|| Json::num(0.0))),
        ]));
    }

    let ladder_served = r1.served - r0.served;
    let ladder_shed = r1.shed - r0.shed;
    let t = table(&rows);
    println!(
        "\n{model} QoS overload ({reqs} requests, 3-class mix, ladder served/shed \
         {ladder_served}/{ladder_shed}):\n{t}"
    );
    write_report("results/serve_qos.txt", &t);
    write_json_section(
        "results/BENCH_serve_qos.json",
        "serve_qos",
        Json::obj(vec![
            ("model", Json::str(model.as_str())),
            ("requests", Json::int(reqs as i64)),
            ("class_spec", Json::str(CLASS_SPEC)),
            ("degrade_spec", Json::str(DEGRADE_SPEC)),
            (
                "baseline",
                Json::obj(vec![
                    ("fps", Json::num(base.observed_fps())),
                    ("completed", Json::int(base.completed as i64)),
                    ("shed", Json::int(base.shed as i64)),
                    ("p50_ms", Json::num(base.latency_ms(50.0))),
                    ("p99_ms", Json::num(base.latency_ms(99.0))),
                ]),
            ),
            ("classes", Json::arr(json_rows)),
            ("ladder_served", Json::int(ladder_served as i64)),
            ("ladder_shed", Json::int(ladder_shed as i64)),
        ]),
    );
    Ok(())
}
