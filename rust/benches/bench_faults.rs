//! Chaos-plane benchmark: what fault injection and supervised recovery
//! actually cost.
//!
//! Three measurements:
//!
//!   - **seam overhead** — ns per [`lrta::faults::hit`] call with no plan
//!     installed (the zero-cost-off contract: one relaxed atomic load and
//!     a branch) and with an armed-but-non-matching plan (the slow path a
//!     chaos run pays at every *other* seam);
//!   - **eviction recovery** — wall clock of a 2-replica fine-tune that
//!     loses replica 1 to an injected mid-epoch panic vs the same run
//!     healthy: the degraded run must finish, and the gap prices the
//!     survivor-only barrier machinery;
//!   - **respawn latency** — a serve shard killed by an injected dispatch
//!     panic: time from the first stranded submission until a respawned
//!     worker answers, plus the supervision counters.
//!
//! Output: results/faults.txt and a `faults` section in
//! results/BENCH_faults.json (uploaded as a CI artifact by the chaos
//! smoke job).
//!
//! Env: LRTA_MODEL (default resnet_mini), LRTA_FAULT_TRAIN (dataset size,
//! default 256), LRTA_FAULT_EPOCHS (default 2)

use lrta::checkpoint;
use lrta::coordinator::{decompose_checkpoint, LrSchedule, TrainConfig};
use lrta::data::{Dataset, IMAGE_ELEMS};
use lrta::faults::{self, Plan, Seam};
use lrta::freeze::FreezeMode;
use lrta::runtime::Manifest;
use lrta::serve::{Server, ServerConfig, ServeError, VariantSpec};
use lrta::train::{run_replicas, MomentumPolicy, ReplicaConfig, SyncCompress};
use lrta::util::bench::{table, write_json_section, write_report};
use lrta::util::json::Json;
use std::time::{Duration, Instant};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// ns per `hit` call over a tight loop (the caller picks the plan state).
fn seam_ns_per_hit(iters: u64) -> f64 {
    let t0 = Instant::now();
    for _ in 0..iters {
        let _ = std::hint::black_box(faults::hit(
            std::hint::black_box(Seam::Dispatch),
            std::hint::black_box("bench"),
        ));
    }
    t0.elapsed().as_nanos() as f64 / iters as f64
}

fn main() -> anyhow::Result<()> {
    let model = std::env::var("LRTA_MODEL").unwrap_or_else(|_| "resnet_mini".into());
    let train_size = env_usize("LRTA_FAULT_TRAIN", 256);
    let epochs = env_usize("LRTA_FAULT_EPOCHS", 2);
    let manifest = Manifest::load("artifacts/manifest.json").expect("run `make artifacts`");
    let dense = checkpoint::load(manifest.init_checkpoint(&model)?)?;
    let params = decompose_checkpoint(&dense, manifest.config(&model, "lrd")?)?.params;

    // --- 1. seam overhead -------------------------------------------------
    let iters = 20_000_000u64;
    faults::clear();
    let disarmed_ns = seam_ns_per_hit(iters);
    // armed, but every directive targets a seam this loop never hits
    faults::install(Plan::parse("swap_ack@nowhere:error@step999999999")?);
    let armed_miss_ns = seam_ns_per_hit(iters);
    faults::clear();
    println!(
        "seam hit: disarmed {disarmed_ns:.2} ns | armed non-matching {armed_miss_ns:.2} ns \
         ({iters} iters)"
    );

    // --- 2. train eviction recovery ---------------------------------------
    let cfg = TrainConfig {
        model: model.clone(),
        variant: "lrd".into(),
        freeze: FreezeMode::Sequential,
        epochs,
        lr: LrSchedule::Fixed(1e-3),
        train_size,
        test_size: 128,
        seed: 0,
        verbose: false,
        resident: true,
        pipelined: false,
    };
    let rcfg = ReplicaConfig {
        replicas: 2,
        avg_every: 1,
        momenta: MomentumPolicy::Average,
        compress: SyncCompress::Exact,
        identical_shards: false,
        ..Default::default()
    };

    let t0 = Instant::now();
    let healthy = run_replicas(&manifest, &cfg, &rcfg, &params)?;
    let healthy_secs = t0.elapsed().as_secs_f64();
    assert!(!healthy.record.degraded(), "healthy run must not evict");

    faults::install(Plan::parse("barrier_send@replica1:panic@step2")?);
    let t0 = Instant::now();
    let faulted = run_replicas(&manifest, &cfg, &rcfg, &params)?;
    let faulted_secs = t0.elapsed().as_secs_f64();
    let injected = faults::fired();
    faults::clear();
    assert!(faulted.record.degraded(), "the injected panic must evict");
    let survivors = faulted.record.evictions.last().map(|e| e.survivors).unwrap_or(0);
    println!(
        "eviction recovery: healthy {healthy_secs:.2}s | 1-death degraded {faulted_secs:.2}s \
         | {} eviction(s), {survivors} survivor(s), {injected} injected",
        faulted.record.evictions.len()
    );

    // --- 3. serve respawn latency -----------------------------------------
    let scfg = ServerConfig {
        max_wait: Duration::from_millis(20),
        spot_check: 0,
        ..Default::default()
    };
    let server =
        Server::start(&manifest, vec![VariantSpec::new(&model, "lrd", params.clone())], &scfg)?;
    let data = Dataset::synthetic(4, 99);
    let x = data.images[..IMAGE_ELEMS].to_vec();
    // warm the worker (first batch), then kill it on the next dispatch
    server.submit(&model, "lrd", x.clone())?.wait(Duration::from_secs(120))?;
    faults::install(Plan::parse("dispatch@shard0:panic@step1")?);
    let t0 = Instant::now();
    let deadline = t0 + Duration::from_secs(120);
    loop {
        assert!(Instant::now() < deadline, "respawn never answered");
        match server.submit(&model, "lrd", x.clone()) {
            Ok(p) => match p.wait(Duration::from_secs(120)) {
                Ok(_) => break,
                Err(ServeError::Shutdown) | Err(ServeError::Closed) => {}
                Err(e) => anyhow::bail!("unexpected terminal answer: {e:?}"),
            },
            Err(ServeError::ShardDown) | Err(ServeError::QueueFull { .. }) => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => anyhow::bail!("unexpected submit error: {e:?}"),
        }
    }
    let respawn_secs = t0.elapsed().as_secs_f64();
    faults::clear();
    let snap = server.stats(&model, "lrd").expect("registered variant");
    server.shutdown();
    println!(
        "serve respawn: {respawn_secs:.3}s death→served | {} death(s), {} respawn(s)",
        snap.worker_deaths, snap.respawns
    );

    // --- report ------------------------------------------------------------
    let rows = vec![
        vec!["measurement".to_string(), "value".to_string()],
        vec!["seam hit, disarmed".to_string(), format!("{disarmed_ns:.2} ns")],
        vec!["seam hit, armed non-matching".to_string(), format!("{armed_miss_ns:.2} ns")],
        vec!["2-replica healthy run".to_string(), format!("{healthy_secs:.2} s")],
        vec!["2-replica run, 1 death".to_string(), format!("{faulted_secs:.2} s")],
        vec!["serve death → respawned answer".to_string(), format!("{respawn_secs:.3} s")],
    ];
    let t = table(&rows);
    println!("\n{model} fault-injection + supervision costs:\n{t}");
    write_report("results/faults.txt", &t);
    let section = Json::obj(vec![
        ("model", Json::str(model.as_str())),
        ("train_size", Json::int(train_size as i64)),
        ("epochs", Json::int(epochs as i64)),
        ("seam_hit_disarmed_ns", Json::num(disarmed_ns)),
        ("seam_hit_armed_nonmatching_ns", Json::num(armed_miss_ns)),
        ("healthy_run_secs", Json::num(healthy_secs)),
        ("degraded_run_secs", Json::num(faulted_secs)),
        ("evictions", Json::int(faulted.record.evictions.len() as i64)),
        ("survivors", Json::int(survivors as i64)),
        ("train_faults_injected", Json::int(injected as i64)),
        ("serve_respawn_secs", Json::num(respawn_secs)),
        ("serve_worker_deaths", Json::int(snap.worker_deaths as i64)),
        ("serve_respawns", Json::int(snap.respawns as i64)),
    ]);
    write_json_section("results/BENCH_faults.json", "faults", section);
    println!("faults bench OK");
    Ok(())
}
