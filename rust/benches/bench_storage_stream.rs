//! Storage-boundary benchmark: what the pluggable object store and the
//! streamed corpus path actually cost, and what fetch-ahead buys back.
//!
//! Four measurements, all artifact-free (pure library, so this target
//! runs in CI without the AOT bundle):
//!
//!   - **put paths** — MB/s of `put` vs `put_streaming` for a multi-MB
//!     object on both backends (the in-process [`MemObject`] and a
//!     [`LocalFs`] under a temp dir): the streaming path must not tax the
//!     async checkpoint writer;
//!   - **chunk dedupe** — first `put_blob` of a blob vs re-publishing the
//!     identical bytes: content addressing should make the second publish
//!     pay hash + HEAD probes only, no uploads;
//!   - **batch assembly** — samples/s of the in-memory prefetcher vs the
//!     streamed prefetcher over the same published corpus — the price of
//!     the chunk/decode/cache machinery when the store itself is free;
//!   - **fetch-ahead absorption** — the streamed walk against a
//!     [`MemObject`] with injected per-op latency, fetch-ahead 0 vs the
//!     default window: overlap should hide most of the per-chunk stalls.
//!
//! Output: results/storage_stream.txt and a `storage_stream` section in
//! results/BENCH_storage.json (uploaded as a CI artifact).
//!
//! Env: LRTA_STORE_SAMPLES (corpus size, default 512), LRTA_STORE_BATCH
//! (default 32), LRTA_STORE_MB (put-path object size, default 4).

use lrta::data::{publish, Dataset, Shard, StreamingProvider};
use lrta::storage::{ChunkStore, LocalFs, MemObject, Storage};
use lrta::train::Prefetcher;
use lrta::util::bench::{
    bench_throughput, table, write_json_section, write_report, BenchConfig, BenchResult,
};
use lrta::util::json::Json;
use lrta::util::rng::Rng;
use std::sync::Arc;
use std::time::Duration;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn blob(seed: u64, len: usize) -> Vec<u8> {
    let mut rng = Rng::new(seed);
    (0..len).map(|_| (rng.next_u64() & 0xff) as u8).collect()
}

/// Drain one full streamed epoch; returns the sample count consumed.
fn drain_epoch(provider: &Arc<StreamingProvider>, batch: usize) -> usize {
    let mut pf = Prefetcher::start_streaming(Arc::clone(provider), batch, 7, Shard::full());
    let mut n = 0;
    while let Some((_, ys)) = pf.next_batch() {
        n += ys.len();
    }
    n
}

fn main() -> anyhow::Result<()> {
    let samples = env_usize("LRTA_STORE_SAMPLES", 512);
    let batch = env_usize("LRTA_STORE_BATCH", 32);
    let mb = env_usize("LRTA_STORE_MB", 4);
    let cfg = BenchConfig::default();
    let mut results: Vec<BenchResult> = Vec::new();

    // --- 1. put paths ------------------------------------------------------
    let payload = blob(1, mb * 1024 * 1024);
    let tmp = std::env::temp_dir()
        .join("lrta_bench_storage")
        .join(std::process::id().to_string());
    let _ = std::fs::remove_dir_all(&tmp);
    let backends: Vec<Arc<dyn Storage>> = vec![
        Arc::new(MemObject::new()),
        Arc::new(LocalFs::open(tmp.clone())?),
    ];
    for store in &backends {
        let b = store.backend();
        let s = Arc::clone(store);
        let p = payload.clone();
        results.push(bench_throughput(&format!("put/{b}"), &cfg, mb as f64, move || {
            s.put("bench/obj", &p).unwrap();
        }));
        let s = Arc::clone(store);
        let p = payload.clone();
        results.push(bench_throughput(
            &format!("put_streaming/{b}"),
            &cfg,
            mb as f64,
            move || {
                s.put_streaming("bench/obj_s", &mut &p[..]).unwrap();
            },
        ));
    }

    // --- 2. chunk dedupe ---------------------------------------------------
    let store: Arc<dyn Storage> = Arc::new(MemObject::new());
    let chunks = ChunkStore::new(Arc::clone(&store));
    {
        // a fresh store per iteration keeps every publish cold
        let p = payload.clone();
        results.push(bench_throughput("put_blob/first", &cfg, mb as f64, move || {
            let fresh: Arc<dyn Storage> = Arc::new(MemObject::new());
            ChunkStore::new(fresh).put_blob("bench/blob", &p).unwrap();
        }));
    }
    let stats = chunks.put_blob("bench/blob", &payload)?;
    let dedup = {
        let chunks = chunks.clone();
        let p = payload.clone();
        bench_throughput("put_blob/dedup", &cfg, mb as f64, move || {
            let s = chunks.put_blob("bench/blob", &p).unwrap();
            assert_eq!(s.chunks_written, 0, "re-publish must fully dedupe");
        })
    };
    results.push(dedup);

    // --- 3. batch assembly: memory vs streamed -----------------------------
    let corpus = Dataset::synthetic(samples, 42);
    let data = Arc::new(corpus.clone());
    let epoch_samples = (samples / batch) * batch;
    results.push(bench_throughput("batches/memory", &cfg, epoch_samples as f64, move || {
        let mut pf = Prefetcher::start(Arc::clone(&data), batch, 7);
        let mut n = 0;
        while let Some((_, ys)) = pf.next_batch() {
            n += ys.len();
        }
        assert_eq!(n, epoch_samples);
    }));

    let store: Arc<dyn Storage> = Arc::new(MemObject::new());
    let pstats = publish(&store, "data", &corpus, 64)?;
    let provider = Arc::new(StreamingProvider::open(Arc::clone(&store), "data")?);
    {
        let provider = Arc::clone(&provider);
        results.push(bench_throughput(
            "batches/streamed",
            &cfg,
            epoch_samples as f64,
            move || {
                assert_eq!(drain_epoch(&provider, batch), epoch_samples);
            },
        ));
    }

    // --- 4. fetch-ahead absorption under injected store latency ------------
    let slow = Arc::new(MemObject::with_latency(Duration::from_millis(2)));
    {
        // copy the published corpus into the slow store, latency-free
        slow.set_latency(Duration::ZERO);
        let dst: Arc<dyn Storage> = Arc::clone(&slow) as Arc<dyn Storage>;
        for key in store.list("")? {
            dst.put(&key, &store.get(&key)?)?;
        }
        slow.set_latency(Duration::from_millis(2));
    }
    let slow_store: Arc<dyn Storage> = slow as Arc<dyn Storage>;
    for (name, window) in [("latency/no_fetch_ahead", 0usize), ("latency/fetch_ahead", 2)] {
        // cache of 1 chunk: every chunk transition is a real (slow) fetch
        let p = Arc::new(
            StreamingProvider::open(Arc::clone(&slow_store), "data")?
                .with_fetch_ahead(window)
                .with_cache_chunks(1),
        );
        results.push(bench_throughput(name, &cfg, epoch_samples as f64, move || {
            assert_eq!(drain_epoch(&p, batch), epoch_samples);
        }));
    }

    // --- report ------------------------------------------------------------
    let mut rows = vec![vec![
        "case".to_string(),
        "median".to_string(),
        "throughput".to_string(),
    ]];
    for r in &results {
        let thr = match (r.name.starts_with("put") || r.name.contains("blob"), r.throughput()) {
            (true, Some(t)) => format!("{t:.1} MB/s"),
            (false, Some(t)) => format!("{t:.0} samples/s"),
            (_, None) => "-".to_string(),
        };
        rows.push(vec![r.name.clone(), format!("{:.3} ms", r.median_ms()), thr]);
    }
    let t = table(&rows);
    println!(
        "storage boundary ({samples} samples, batch {batch}, {mb} MB objects; \
         {} chunks, {} B written on first publish):\n{t}",
        stats.chunks_total, pstats.bytes_written
    );
    write_report("results/storage_stream.txt", &t);

    let mut section = vec![
        ("samples", Json::int(samples as i64)),
        ("batch", Json::int(batch as i64)),
        ("object_mb", Json::int(mb as i64)),
        ("blob_chunks", Json::int(stats.chunks_total as i64)),
        ("corpus_chunks", Json::int(pstats.chunks_total as i64)),
        ("corpus_bytes_written", Json::int(pstats.bytes_written as i64)),
    ];
    let keys: Vec<String> =
        results.iter().map(|r| format!("{}_median_secs", r.name.replace('/', "_"))).collect();
    for (k, r) in keys.iter().zip(&results) {
        section.push((k.as_str(), Json::num(r.secs.median)));
    }
    write_json_section("results/BENCH_storage.json", "storage_stream", Json::obj(section));
    let _ = std::fs::remove_dir_all(&tmp);
    println!("storage bench OK");
    Ok(())
}
