//! Data-parallel training throughput: N engine replicas on disjoint batch
//! shards (buffer-level parameter averaging) vs the single-engine resident
//! baseline — the scaling claim of `train::replica`.
//!
//! Both paths run the same fine-tune (variant `lrd`, same dataset, same
//! epochs, eval each epoch) end to end, including engine construction and
//! artifact compilation, and report samples/second over the wall clock:
//!
//!   - **baseline** — one `coordinator::Trainer` on the serial resident
//!     engine (`--no-pipeline` semantics): the 1-replica reference whose
//!     trajectory the replica path reproduces bit-for-bit on identical
//!     shards (`integration_train_replicas`);
//!   - **replicas** — `train::replica::run_replicas`: N PJRT clients, one
//!     resident state each, round-robin disjoint shards, averaging every
//!     `LRTA_AVG_EVERY` steps (0 = epoch boundaries only).
//!
//! The table carries the per-replica transfer accounting next to the fps
//! so a scaling win can't hide residency regressions: unaccounted uploads
//! (must be 0 — steps and freeze swaps never re-upload) and demux
//! fallbacks (must be 0). Output: results/train_replicas.txt and a
//! `replicas` section in results/BENCH_replicas.json (CI `train-smoke`
//! uploads it as an artifact).
//!
//! A second matrix prices the averaging barrier itself under Sequential
//! freezing: epoch driver (serial vs pipelined) crossed with the wire
//! codec (`exact` XOR-delta vs lossy `q8`). Each row reports wall-clock
//! fps next to the summed barrier bytes — exchanged vs the naive
//! every-leaf-raw reference, the frozen-leaf bytes skipped outright, and
//! what the delta encoding saved on top — plus the final test accuracy,
//! with the q8 row checked against the exact row (bounded divergence, not
//! a bit-pin: `Q8_ACC_BOUND`). Output: a `replica_sync` section in
//! results/BENCH_replica_sync.json (also a CI artifact).
//!
//! Env: LRTA_MODEL (default resnet_mini), LRTA_REPLICAS (default 2),
//! LRTA_AVG_EVERY (default 0), LRTA_REPLICA_TRAIN (dataset size, default
//! 512), LRTA_REPLICA_EPOCHS (default 2)

use lrta::checkpoint;
use lrta::coordinator::{decompose_checkpoint, LrSchedule, TrainConfig, Trainer};
use lrta::freeze::FreezeMode;
use lrta::runtime::{Manifest, Runtime};
use lrta::train::{run_replicas, MomentumPolicy, ReplicaConfig, SyncCompress};
use lrta::util::bench::{fmt_delta_pct, table, write_json_section, write_report};
use lrta::util::json::Json;
use std::time::Instant;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let model = std::env::var("LRTA_MODEL").unwrap_or_else(|_| "resnet_mini".into());
    let replicas = env_usize("LRTA_REPLICAS", 2);
    let avg_every = env_usize("LRTA_AVG_EVERY", 0);
    let train_size = env_usize("LRTA_REPLICA_TRAIN", 512);
    let epochs = env_usize("LRTA_REPLICA_EPOCHS", 2);
    let manifest = Manifest::load("artifacts/manifest.json").expect("run `make artifacts`");
    let dense = checkpoint::load(manifest.init_checkpoint(&model)?)?;
    let params = decompose_checkpoint(&dense, manifest.config(&model, "lrd")?)?.params;

    let mut rows = vec![vec![
        "Freeze".to_string(),
        "baseline fps".to_string(),
        format!("{replicas}-replica fps"),
        "Δ replicas".to_string(),
        "events/replica".to_string(),
        "unaccounted uploads".to_string(),
        "demux fallbacks".to_string(),
    ]];
    let mut json_rows = Vec::new();
    let mut residency_clean = true;

    for freeze in [FreezeMode::None, FreezeMode::Sequential] {
        let cfg = TrainConfig {
            model: model.clone(),
            variant: "lrd".into(),
            freeze,
            epochs,
            lr: LrSchedule::Fixed(1e-3),
            train_size,
            test_size: 128,
            seed: 0,
            verbose: false,
            resident: true,
            pipelined: false,
        };
        let suffix0 = if freeze == FreezeMode::None { "none" } else { "a" };
        let batch = manifest.artifact(&format!("{model}_lrd_train_{suffix0}"))?.batch;
        let total_batches = train_size / batch;

        // --- single-engine resident baseline ------------------------------
        // construction (state upload + exe compile) counts: the replica
        // path pays the same per replica inside its own timing window
        let t0 = Instant::now();
        let rt = Runtime::cpu()?;
        let mut trainer = Trainer::new(&rt, &manifest, cfg.clone(), params.clone())?;
        trainer.run()?;
        let base_secs = t0.elapsed().as_secs_f64();
        let base_samples = epochs * total_batches * batch;
        let base_fps = base_samples as f64 / base_secs;

        // --- N replicas on disjoint shards --------------------------------
        let rcfg = ReplicaConfig {
            replicas,
            avg_every,
            momenta: MomentumPolicy::Average,
            compress: SyncCompress::Exact,
            identical_shards: false,
            ..Default::default()
        };
        let t0 = Instant::now();
        let run = run_replicas(&manifest, &cfg, &rcfg, &params)?;
        let rep_secs = t0.elapsed().as_secs_f64();
        // ragged tails are dropped for equal shard lengths, so count what
        // actually ran instead of assuming the full epoch
        let rep_samples: usize =
            run.reports.iter().map(|r| r.batches).sum::<usize>() * batch;
        let rep_fps = rep_samples as f64 / rep_secs;

        let events: Vec<usize> = run.reports.iter().map(|r| r.avg_events).collect();
        let unaccounted: usize = run.reports.iter().map(|r| r.unaccounted_uploads()).sum();
        let fallbacks: usize = run.reports.iter().map(|r| r.demux_fallbacks).sum();
        if unaccounted != 0 || fallbacks != 0 {
            residency_clean = false;
        }

        println!(
            "{freeze:?}: baseline {base_fps:.1} fps | {replicas} replicas {rep_fps:.1} fps \
             (x{:.2}) | events {events:?} | unaccounted {unaccounted} | fallbacks {fallbacks}",
            rep_fps / base_fps
        );
        rows.push(vec![
            format!("{freeze:?}"),
            format!("{base_fps:.1}"),
            format!("{rep_fps:.1}"),
            fmt_delta_pct(base_fps, rep_fps),
            format!("{events:?}"),
            format!("{unaccounted}"),
            format!("{fallbacks}"),
        ]);
        json_rows.push(Json::obj(vec![
            ("freeze", Json::str(&format!("{freeze:?}"))),
            ("baseline_fps", Json::num(base_fps)),
            ("replicas_fps", Json::num(rep_fps)),
            ("scaling", Json::num(rep_fps / base_fps)),
            ("avg_events_per_replica", Json::arr(
                events.iter().map(|&e| Json::int(e as i64)).collect(),
            )),
            ("unaccounted_uploads", Json::int(unaccounted as i64)),
            ("demux_fallbacks", Json::int(fallbacks as i64)),
        ]));
    }

    let t = table(&rows);
    println!(
        "\n{model} data-parallel training ({replicas} replicas, avg-every={avg_every}):\n{t}"
    );
    println!(
        "replica runs stayed buffer-chained (0 unaccounted uploads, 0 demux fallbacks): {}",
        if residency_clean { "YES" } else { "NO" }
    );
    write_report("results/train_replicas.txt", &t);
    let section = Json::obj(vec![
        ("model", Json::str(model.as_str())),
        ("replicas", Json::int(replicas as i64)),
        ("avg_every", Json::int(avg_every as i64)),
        ("train_size", Json::int(train_size as i64)),
        ("epochs", Json::int(epochs as i64)),
        ("rows", Json::arr(json_rows)),
        ("residency_clean", Json::Bool(residency_clean)),
    ]);
    write_json_section("results/BENCH_replicas.json", "replicas", section);

    // --- sync matrix: epoch driver x wire codec (Sequential freezing) -----
    // the bandwidth story of the averaging barrier: how much the
    // frozen-aware sync plan and the delta/q8 codecs take off the wire,
    // and whether the pipelined driver holds its throughput edge with the
    // per-step barrier hooked in
    let sync_cfg = |pipelined: bool| TrainConfig {
        model: model.clone(),
        variant: "lrd".into(),
        freeze: FreezeMode::Sequential,
        epochs,
        lr: LrSchedule::Fixed(1e-3),
        train_size,
        test_size: 128,
        seed: 0,
        verbose: false,
        resident: true,
        pipelined,
    };
    let batch = manifest.artifact(&format!("{model}_lrd_train_a"))?.batch;
    // |q8 final acc - exact final acc| tolerated before the bench flags
    // drift. Loose on purpose: tiny fine-tunes are noisy and q8 is lossy
    // by design — the exactness guarantees live in the unit/integration
    // tests, this bound only catches the quantizer going off the rails.
    const Q8_ACC_BOUND: f64 = 0.15;
    let mut sync_rows = vec![vec![
        "driver+codec".to_string(),
        "fps".to_string(),
        "bytes exchanged".to_string(),
        "of full".to_string(),
        "skipped (frozen)".to_string(),
        "saved by delta".to_string(),
        "final acc".to_string(),
    ]];
    let mut sync_json = Vec::new();
    let mut exact_acc = f64::NAN;
    let mut q8_within_bound = true;
    for (label, pipelined, compress) in [
        ("serial+exact", false, SyncCompress::Exact),
        ("pipelined+exact", true, SyncCompress::Exact),
        ("pipelined+q8", true, SyncCompress::Q8),
    ] {
        let rcfg = ReplicaConfig {
            replicas,
            avg_every,
            momenta: MomentumPolicy::Average,
            compress,
            identical_shards: false,
            ..Default::default()
        };
        let t0 = Instant::now();
        let run = run_replicas(&manifest, &sync_cfg(pipelined), &rcfg, &params)?;
        let secs = t0.elapsed().as_secs_f64();
        let samples = run.reports.iter().map(|r| r.batches).sum::<usize>() * batch;
        let fps = samples as f64 / secs;
        let exchanged: u64 = run.reports.iter().map(|r| r.avg_bytes_exchanged).sum();
        let full: u64 = run.reports.iter().map(|r| r.avg_bytes_full).sum();
        let skipped: u64 = run.reports.iter().map(|r| r.avg_bytes_skipped).sum();
        let saved: u64 = run.reports.iter().map(|r| r.avg_bytes_saved_by_delta()).sum();
        let reduction = 1.0 - exchanged as f64 / full.max(1) as f64;
        let acc = run.record.final_test_acc();
        if pipelined && compress == SyncCompress::Exact {
            exact_acc = acc;
        }
        let acc_delta = if compress == SyncCompress::Q8 { (acc - exact_acc).abs() } else { 0.0 };
        if compress == SyncCompress::Q8 && acc_delta > Q8_ACC_BOUND {
            q8_within_bound = false;
            println!(
                "WARNING: q8 final acc drifted {acc_delta:.3} from exact (bound {Q8_ACC_BOUND})"
            );
        }
        println!(
            "{label}: {fps:.1} fps | {exchanged} B exchanged of {full} B full | \
             {skipped} B frozen-skipped | {saved} B saved by delta | acc {acc:.3}"
        );
        sync_rows.push(vec![
            label.to_string(),
            format!("{fps:.1}"),
            format!("{exchanged}"),
            format!("{:.1}%", 100.0 * (1.0 - reduction)),
            format!("{skipped}"),
            format!("{saved}"),
            format!("{acc:.3}"),
        ]);
        sync_json.push(Json::obj(vec![
            ("config", Json::str(label)),
            ("pipelined", Json::Bool(pipelined)),
            ("codec", Json::str(compress.label())),
            ("fps", Json::num(fps)),
            ("bytes_exchanged", Json::int(exchanged as i64)),
            ("bytes_full", Json::int(full as i64)),
            ("bytes_skipped_frozen", Json::int(skipped as i64)),
            ("bytes_saved_by_delta", Json::int(saved as i64)),
            ("wire_reduction_frac", Json::num(reduction)),
            ("final_test_acc", Json::num(acc)),
            ("acc_delta_vs_exact", Json::num(acc_delta)),
        ]));
    }

    let st = table(&sync_rows);
    println!(
        "\n{model} replica sync matrix (Sequential, {replicas} replicas, \
         avg-every={avg_every}):\n{st}"
    );
    println!(
        "q8 final acc within {Q8_ACC_BOUND} of exact: {}",
        if q8_within_bound { "YES" } else { "NO" }
    );
    let sync_section = Json::obj(vec![
        ("model", Json::str(model.as_str())),
        ("replicas", Json::int(replicas as i64)),
        ("avg_every", Json::int(avg_every as i64)),
        ("train_size", Json::int(train_size as i64)),
        ("epochs", Json::int(epochs as i64)),
        ("q8_acc_bound", Json::num(Q8_ACC_BOUND)),
        ("q8_within_bound", Json::Bool(q8_within_bound)),
        ("rows", Json::arr(sync_json)),
    ]);
    write_json_section("results/BENCH_replica_sync.json", "replica_sync", sync_section);
    println!("train_replicas bench OK");
    Ok(())
}
