//! Shard-scaling benchmark: serving throughput at 1 vs 2 shards per
//! variant (the scale-out answer to the paper's Table-1 inference claim —
//! a cheap `rankopt` variant is only as fast as the workers serving it).
//!
//! Each measurement starts a fresh [`Server`] with one variant scaled to
//! `shards` workers (own PJRT client, resident parameter set, queue and
//! stats each) and drives an open-loop burst through the router; the
//! submit thread outpaces the engines, so the fanout — shallowest queue,
//! round-robin ties — keeps every shard's batcher fed. Reported fps is the
//! burst's observed goodput. Output: results/serve_shards.txt and a
//! top-level JSON report results/BENCH_serve_shards.json (per-variant
//! 1-shard / 2-shard fps, speedup, merged transfer counters), uploaded as
//! a CI artifact by the train-smoke job.
//!
//! Env: LRTA_MODEL (default resnet_mini), LRTA_SERVE_BENCH_REQS
//! (requests per measurement, default 8× compiled batch)

use anyhow::Result;
use lrta::checkpoint;
use lrta::data::Dataset;
use lrta::runtime::Manifest;
use lrta::serve::{self, Server, ServerConfig, StatsSnapshot, VariantSpec};
use lrta::util::bench::{fmt_delta_pct, table, write_json_section, write_report};
use lrta::util::json::Json;
use std::time::Duration;

/// Burst throughput of one variant behind `shards` workers.
fn sharded_fps(
    manifest: &Manifest,
    model: &str,
    variant: &str,
    params: lrta::checkpoint::Params,
    shards: usize,
    reqs: usize,
) -> Result<(f64, StatsSnapshot)> {
    let cfg = ServerConfig { max_wait: Duration::from_millis(5), ..Default::default() };
    let spec = VariantSpec::new(model, variant, params).with_shards(shards);
    let server = Server::start(manifest, vec![spec], &cfg)?;
    let data = Dataset::synthetic(512, 99);
    // warmup burst, then the measured burst
    serve::burst_loop(&server, model, variant, &data, reqs / 4 + 1, Duration::from_secs(120));
    let report =
        serve::burst_loop(&server, model, variant, &data, reqs, Duration::from_secs(120));
    let snap = server.stats(model, variant).expect("registered variant");
    server.shutdown();
    Ok((report.observed_fps(), snap))
}

fn main() -> Result<()> {
    let model = std::env::var("LRTA_MODEL").unwrap_or_else(|_| "resnet_mini".into());
    let manifest = Manifest::load("artifacts/manifest.json")?;
    let dense = checkpoint::load(manifest.init_checkpoint(&model)?)?;

    let mut rows = vec![vec![
        "Variant".to_string(),
        "1-shard fps".to_string(),
        "2-shard fps".to_string(),
        "Δ 2 vs 1".to_string(),
        "speedup".to_string(),
        "uploads (1/2)".to_string(),
    ]];
    let mut json_rows = Vec::new();
    for variant in ["orig", "lrd", "rankopt"] {
        let params = VariantSpec::from_dense(&manifest, &model, variant, &dense)?.params;
        let batch = manifest.artifact(&Manifest::name_of(&model, variant, "infer", "none"))?.batch;
        let reqs: usize = std::env::var("LRTA_SERVE_BENCH_REQS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(batch * 8);

        let (fps1, snap1) =
            sharded_fps(&manifest, &model, variant, params.clone(), 1, reqs)?;
        let (fps2, snap2) = sharded_fps(&manifest, &model, variant, params, 2, reqs)?;
        let speedup = if fps1 > 0.0 { fps2 / fps1 } else { 0.0 };
        println!(
            "{variant}: 1 shard {fps1:.0} fps | 2 shards {fps2:.0} fps | {speedup:.2}x \
             | uploads {}/{}",
            snap1.uploads, snap2.uploads
        );
        rows.push(vec![
            variant.to_string(),
            format!("{fps1:.0}"),
            format!("{fps2:.0}"),
            fmt_delta_pct(fps1, fps2),
            format!("{speedup:.2}x"),
            format!("{}/{}", snap1.uploads, snap2.uploads),
        ]);
        json_rows.push(Json::obj(vec![
            ("variant", Json::str(variant)),
            ("requests", Json::int(reqs as i64)),
            ("fps_1_shard", Json::num(fps1)),
            ("fps_2_shards", Json::num(fps2)),
            ("speedup", Json::num(speedup)),
            ("served_1_shard", Json::int(snap1.served as i64)),
            ("served_2_shards", Json::int(snap2.served as i64)),
            ("uploads_1_shard", Json::int(snap1.uploads as i64)),
            ("uploads_2_shards", Json::int(snap2.uploads as i64)),
            ("demux_fallbacks", Json::int((snap1.demux_fallbacks + snap2.demux_fallbacks) as i64)),
        ]));
    }

    let t = table(&rows);
    println!("\n{model} shard scaling (burst load, device-resident, pipelined):\n{t}");
    write_report("results/serve_shards.txt", &t);
    write_json_section(
        "results/BENCH_serve_shards.json",
        "serve_shards",
        Json::obj(vec![("model", Json::str(model.as_str())), ("rows", Json::arr(json_rows))]),
    );
    Ok(())
}
