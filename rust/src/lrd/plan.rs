//! Model-level decomposition planning: map every decomposable layer of a
//! network to its LRD rank(s) for a target compression ratio, optionally
//! snapping ranks to hardware-friendly sizes (the paper's "rank
//! quantization"), and account for total parameters.

use super::{
    decomposed_params, svd_rank_for_compression, svd_rmin, tucker_rank_eq5,
    tucker_rmin_eq6, LayerShape,
};

/// How ranks are chosen for a decomposition plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RankMode {
    /// Vanilla LRD: Eq. (5) / the SVD closed form, no adjustment.
    Vanilla,
    /// Rank quantization: snap the Eq.-(5) rank down to the nearest multiple
    /// of the device tile width (never below the Eq.-(6) lower bound).
    /// This is the *static* form of Algorithm 1; the dynamic, measured form
    /// lives in `rankopt` and converges to the same ranks on tiled devices.
    Quantized { tile: usize },
}

/// Planned decomposition of one layer.
#[derive(Clone, Debug)]
pub struct LayerPlan {
    pub name: String,
    pub shape: LayerShape,
    /// (r1, r2); r1 == r2 == r for SVD layers.
    pub r1: usize,
    pub r2: usize,
    /// Sweep lower bound from Eq. (6).
    pub r_min: usize,
    /// If false, the layer stays dense (decomposition would not help).
    pub decompose: bool,
}

impl LayerPlan {
    pub fn dense_params(&self) -> usize {
        self.shape.dense_params()
    }
    pub fn planned_params(&self) -> usize {
        if self.decompose {
            decomposed_params(&self.shape, self.r1, self.r2)
        } else {
            self.dense_params()
        }
    }
    pub fn achieved_ratio(&self) -> f64 {
        self.dense_params() as f64 / self.planned_params() as f64
    }
}

/// Decomposition plan over a whole model.
#[derive(Clone, Debug)]
pub struct ModelPlan {
    pub layers: Vec<LayerPlan>,
    pub alpha: f64,
    pub beta: f64,
}

impl ModelPlan {
    /// Build a plan for `layers` at compression `alpha` (β = r2/r1).
    pub fn build(
        layers: &[(String, LayerShape)],
        alpha: f64,
        beta: f64,
        mode: RankMode,
    ) -> ModelPlan {
        let planned = layers
            .iter()
            .map(|(name, shape)| plan_layer(name, *shape, alpha, beta, mode))
            .collect();
        ModelPlan { layers: planned, alpha, beta }
    }

    pub fn total_dense_params(&self) -> usize {
        self.layers.iter().map(|l| l.dense_params()).sum()
    }
    pub fn total_planned_params(&self) -> usize {
        self.layers.iter().map(|l| l.planned_params()).sum()
    }
    pub fn overall_ratio(&self) -> f64 {
        self.total_dense_params() as f64 / self.total_planned_params() as f64
    }
    pub fn find(&self, name: &str) -> Option<&LayerPlan> {
        self.layers.iter().find(|l| l.name == name)
    }
}

fn plan_layer(
    name: &str,
    shape: LayerShape,
    alpha: f64,
    beta: f64,
    mode: RankMode,
) -> LayerPlan {
    // Eq. 5 can exceed the mode-rank bound for skewed layers (e.g. a
    // 3-channel stem); clamp to min(C, S)/C so the factors are well-posed
    // and python/rust agree on artifact shapes.
    let cap = if shape.is_linear() { shape.full_rank() } else { shape.c };
    let (r_nom, r_min) = if shape.is_linear() {
        (
            svd_rank_for_compression(shape.c, shape.s, alpha).min(cap),
            svd_rmin(shape.c, shape.s, alpha),
        )
    } else {
        (
            tucker_rank_eq5(shape.c, shape.s, shape.k, alpha, beta).min(cap),
            tucker_rmin_eq6(shape.c, shape.s, shape.k, alpha, beta),
        )
    };
    let r_min = r_min.min(r_nom);
    let r1 = match mode {
        RankMode::Vanilla => r_nom,
        RankMode::Quantized { tile } => snap_rank(r_nom, r_min, tile).min(cap),
    };
    let r2 = if shape.is_linear() {
        r1
    } else {
        ((r1 as f64 * beta).round() as usize).max(1).min(shape.s)
    };
    // Decomposing is only worthwhile if it actually removes parameters; tiny
    // layers (e.g. 3-channel stems, 10-way heads) often fail this test, and
    // the paper's Algorithm 1 keeps the original layer in that case.
    let decompose = decomposed_params(&shape, r1, r2) < shape.dense_params();
    LayerPlan { name: name.to_string(), shape, r1, r2, r_min, decompose }
}

/// Snap `r` down to a multiple of `tile`; refuse to cross below `r_min`
/// (which would push compression past α+1); always at least 1.
/// E.g. tile 16: 309 → 304; tile 64: 309 → 256 only if 256 ≥ r_min.
pub fn snap_rank(r: usize, r_min: usize, tile: usize) -> usize {
    assert!(tile >= 1);
    let down = (r / tile) * tile;
    if down >= r_min.max(1) && down >= 1 {
        down
    } else {
        // nearest multiple at or above r (still hardware-aligned), unless
        // that exceeds the nominal rank band badly — then keep r.
        let up = r.div_ceil(tile) * tile;
        if up <= r + tile / 2 {
            up
        } else {
            r
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resnetish() -> Vec<(String, LayerShape)> {
        vec![
            ("stem".into(), LayerShape::conv(3, 64, 3)),
            ("b1.conv1".into(), LayerShape::conv(64, 64, 3)),
            ("b2.conv1".into(), LayerShape::conv(128, 128, 3)),
            ("b3.down".into(), LayerShape::linear(128, 256)),
            ("head".into(), LayerShape::linear(256, 10)),
        ]
    }

    #[test]
    fn plan_respects_alpha_overall() {
        let plan = ModelPlan::build(&resnetish(), 2.0, 1.0, RankMode::Vanilla);
        // Decomposable bulk dominates, so overall ratio should be near 2
        // (stem and head stay dense, diluting slightly).
        let ratio = plan.overall_ratio();
        assert!(ratio > 1.5 && ratio < 2.5, "ratio {ratio}");
    }

    #[test]
    fn skewed_stem_rank_is_clamped() {
        // Eq. 5 for [3,64,3,3] gives r1=6 > C=3; the plan must clamp to the
        // multilinear rank bound so factor shapes are well-posed.
        let plan = ModelPlan::build(&resnetish(), 2.0, 1.0, RankMode::Vanilla);
        let stem = plan.find("stem").unwrap();
        assert!(stem.r1 <= 3, "stem r1 {} > C", stem.r1);
        assert!(stem.decompose, "clamped stem decomposition still pays");
    }

    #[test]
    fn degenerate_layer_stays_dense() {
        let layers = vec![("tiny".to_string(), LayerShape::linear(2, 2))];
        let plan = ModelPlan::build(&layers, 2.0, 1.0, RankMode::Vanilla);
        assert!(!plan.layers[0].decompose, "2x2 layer cannot compress");
    }

    #[test]
    fn quantized_ranks_are_tile_multiples_or_unchanged() {
        let vanilla = ModelPlan::build(&resnetish(), 2.0, 1.0, RankMode::Vanilla);
        let plan = ModelPlan::build(&resnetish(), 2.0, 1.0, RankMode::Quantized { tile: 16 });
        for (l, v) in plan.layers.iter().zip(&vanilla.layers) {
            if !l.decompose {
                continue;
            }
            // either snapped to the tile, or the band was too narrow to
            // snap (small layers) and the nominal rank is kept
            assert!(
                l.r1 % 16 == 0 || l.r1 == v.r1,
                "{} r1={} vanilla={}",
                l.name,
                l.r1,
                v.r1
            );
        }
        // the big layers do snap
        let big = plan.find("b2.conv1").unwrap();
        assert_eq!(big.r1 % 16, 0, "b2.conv1 r1={}", big.r1);
    }

    #[test]
    fn quantized_never_below_rmin() {
        for tile in [8, 16, 32, 64] {
            let plan =
                ModelPlan::build(&resnetish(), 2.0, 1.0, RankMode::Quantized { tile });
            for l in plan.layers.iter().filter(|l| l.decompose) {
                assert!(
                    l.r1 >= l.r_min || l.r1 % tile == 0,
                    "{} r1={} rmin={} tile={tile}",
                    l.name,
                    l.r1,
                    l.r_min
                );
                assert!(l.r1 >= 1);
            }
        }
    }

    #[test]
    fn snap_rank_paper_example() {
        // Fig. 2: rank 257 → 256 is the efficient choice; snapping 309 with
        // tile 16 gives 304, with r_min 242 respected.
        assert_eq!(snap_rank(309, 242, 16), 304);
        assert_eq!(snap_rank(257, 242, 256), 256);
        // snapping below r_min is refused; rounds up instead when close
        assert_eq!(snap_rank(130, 128, 128), 128);
    }

    #[test]
    fn snap_rank_degenerate() {
        // down=0 < r_min, and rounding up to 16 is too far from r=1 → keep 1.
        assert_eq!(snap_rank(1, 1, 16), 1);
        // exact multiples are stable
        assert_eq!(snap_rank(64, 32, 16), 64);
    }

    #[test]
    fn plan_params_accounting_consistent() {
        let plan = ModelPlan::build(&resnetish(), 2.0, 1.0, RankMode::Vanilla);
        let dense: usize = plan.layers.iter().map(|l| l.dense_params()).sum();
        assert_eq!(dense, plan.total_dense_params());
        assert!(plan.total_planned_params() < dense);
    }

    #[test]
    fn achieved_ratio_near_alpha_for_big_layer() {
        let layers = vec![("big".to_string(), LayerShape::conv(512, 512, 3))];
        let plan = ModelPlan::build(&layers, 2.0, 1.0, RankMode::Vanilla);
        let l = &plan.layers[0];
        assert!(l.decompose);
        let r = l.achieved_ratio();
        assert!((1.9..=2.2).contains(&r), "{r}");
    }
}
