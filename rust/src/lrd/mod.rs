//! Low-Rank Decomposition engine — the paper's Eq. (1)-(6).
//!
//! - SVD decomposition of fully connected / 1×1 convolutional layers
//!   (Eq. 1-2): `W[C,S] ≈ A[C,r] · B[r,S]` with the singular values split
//!   symmetrically (√Σ into each factor) so both halves are comparably
//!   scaled for fine-tuning.
//! - Tucker2 decomposition of k×k convolutions (Eq. 4) via HOSVD:
//!   `W[C,S,k,k] ≈ X ×₀ U ×₁ V` giving a 1×1 (C→r1), a k×k core (r1→r2)
//!   and a 1×1 (r2→S) layer.
//! - The closed-form rank formulas for a target compression ratio α
//!   (Eq. 5) and the lower-bound rank for ratio α+1 (Eq. 6).
//! - Reconstruction error (Eq. 3) and parameter accounting.

use crate::linalg::{svd_truncated, Svd};
use crate::tensor::Tensor;

pub mod plan;

/// Shape of a decomposable layer. `k == 1` means FC / 1×1 conv (SVD path);
/// `k > 1` means spatial conv (Tucker2 path). `c` = input channels,
/// `s` = output channels, matching the paper's `W ∈ R^{C×S×h×w}`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LayerShape {
    pub c: usize,
    pub s: usize,
    pub k: usize,
}

impl LayerShape {
    pub fn linear(c: usize, s: usize) -> LayerShape {
        LayerShape { c, s, k: 1 }
    }
    pub fn conv(c: usize, s: usize, k: usize) -> LayerShape {
        LayerShape { c, s, k }
    }
    /// Trainable parameters of the original (dense) layer.
    pub fn dense_params(&self) -> usize {
        self.c * self.s * self.k * self.k
    }
    /// Full rank R = min(C, S).
    pub fn full_rank(&self) -> usize {
        self.c.min(self.s)
    }
    pub fn is_linear(&self) -> bool {
        self.k == 1
    }
}

/// SVD factors of a linear layer: `w ≈ a · b`.
#[derive(Clone, Debug)]
pub struct LinearFactors {
    /// `[C, r]` — U'·√Σ'
    pub a: Tensor,
    /// `[r, S]` — √Σ'·V'ᵀ
    pub b: Tensor,
}

impl LinearFactors {
    pub fn rank(&self) -> usize {
        self.a.shape()[1]
    }
    pub fn reconstruct(&self) -> Tensor {
        self.a.matmul(&self.b)
    }
    pub fn params(&self) -> usize {
        self.a.len() + self.b.len()
    }
}

/// Tucker2 factors of a k×k conv: first 1×1, core k×k, last 1×1.
#[derive(Clone, Debug)]
pub struct TuckerFactors {
    /// `[C, r1]` — input-side factor (the first 1×1 conv's weights).
    pub first: Tensor,
    /// `[r1, r2, k, k]` — core tensor (the k×k conv's weights).
    pub core: Tensor,
    /// `[r2, S]` — output-side factor (the last 1×1 conv's weights).
    pub last: Tensor,
}

impl TuckerFactors {
    pub fn ranks(&self) -> (usize, usize) {
        (self.first.shape()[1], self.last.shape()[0])
    }
    pub fn params(&self) -> usize {
        self.first.len() + self.core.len() + self.last.len()
    }
    /// Reconstruct `W'[C,S,k,k] = X ×₀ U ×₁ V`.
    pub fn reconstruct(&self) -> Tensor {
        let (_r1, r2) = self.ranks();
        let k = self.core.shape()[2];
        let c = self.first.shape()[0];
        let s = self.last.shape()[1];
        // mode-0 product with U: [C, r1] x [r1, r2*k*k]
        let x0 = self.core.unfold(0); // [r1, r2*k*k]
        let w0 = self.first.matmul(&x0); // [C, r2*k*k]
        let w0 = Tensor::fold(&w0, 0, &[c, r2, k, k]);
        // mode-1 product with Vᵀ's transpose: rows are r2 -> s
        let x1 = w0.unfold(1); // [r2, C*k*k]
        let w1 = self.last.t().matmul(&x1); // [S, C*k*k]
        Tensor::fold(&w1, 1, &[c, s, k, k])
    }
}

/// Decompose a linear / 1×1 layer `w: [C, S]` at rank `r` (Eq. 2), splitting
/// Σ' symmetrically between the factors.
pub fn svd_linear(w: &Tensor, r: usize) -> LinearFactors {
    assert_eq!(w.ndim(), 2);
    let r = r.max(1).min(w.shape()[0].min(w.shape()[1]));
    let d: Svd = svd_truncated(w, r);
    let (c, s) = (w.shape()[0], w.shape()[1]);
    let mut a = Tensor::zeros(&[c, r]);
    let mut b = Tensor::zeros(&[r, s]);
    for j in 0..r {
        let sq = d.s[j].max(0.0).sqrt();
        for i in 0..c {
            a.set2(i, j, d.u.at2(i, j) * sq);
        }
        for i in 0..s {
            b.set2(j, i, d.v.at2(i, j) * sq);
        }
    }
    LinearFactors { a, b }
}

/// Tucker2 decomposition of `w: [C, S, k, k]` with ranks (r1, r2) via HOSVD:
/// factor matrices from the mode-0/mode-1 unfoldings' left singular vectors,
/// core `X = W ×₀ Uᵀ ×₁ Vᵀ`.
pub fn tucker2_conv(w: &Tensor, r1: usize, r2: usize) -> TuckerFactors {
    assert_eq!(w.ndim(), 4);
    let (c, s, k, k2) = (w.shape()[0], w.shape()[1], w.shape()[2], w.shape()[3]);
    assert_eq!(k, k2, "square kernels only");
    let r1 = r1.max(1).min(c);
    let r2 = r2.max(1).min(s);

    // Mode-0: U [C, r1] from SVD of the [C, S*k*k] unfolding.
    let u = svd_truncated(&w.unfold(0), r1).u; // [C, r1]
    // Mode-1: V [S, r2] from SVD of the [S, C*k*k] unfolding.
    let v = svd_truncated(&w.unfold(1), r2).u; // [S, r2]

    // Core X = W ×₀ Uᵀ ×₁ Vᵀ : contract both channel modes.
    let w0 = u.t().matmul(&w.unfold(0)); // [r1, S*k*k]
    let w0 = Tensor::fold(&w0, 0, &[r1, s, k, k]);
    let w1 = v.t().matmul(&w0.unfold(1)); // [r2, r1*k*k]
    let core = Tensor::fold(&w1, 1, &[r1, r2, k, k]);

    TuckerFactors { first: u, core, last: v.t() }
}

/// Eq. (3): reconstruction error ‖W − W'‖².
pub fn reconstruction_error(w: &Tensor, w_approx: &Tensor) -> f32 {
    w.dist2(w_approx)
}

/// SVD rank giving compression ratio α for a linear layer:
/// dense CS vs decomposed r(C+S) ⇒ r = CS / (α (C+S)).
pub fn svd_rank_for_compression(c: usize, s: usize, alpha: f64) -> usize {
    assert!(alpha > 0.0);
    let r = (c as f64 * s as f64) / (alpha * (c + s) as f64);
    (r.floor() as usize).max(1)
}

/// Eq. (5): Tucker2 rank r1 (with r2 = β·r1) achieving compression α on a
/// `C×S×k×k` conv. Derived from `β k² r1² + (C + βS) r1 − CSk²/α = 0`.
pub fn tucker_rank_eq5(c: usize, s: usize, k: usize, alpha: f64, beta: f64) -> usize {
    assert!(alpha > 0.0 && beta > 0.0 && k >= 1);
    let (cf, sf, kf) = (c as f64, s as f64, k as f64);
    let b_term = (cf + beta * sf) / (beta * kf * kf);
    let disc = b_term * b_term + 4.0 * cf * sf / (beta * alpha);
    let r1 = (-b_term + disc.sqrt()) / 2.0;
    (r1.floor() as usize).max(1)
}

/// Eq. (6): the sweep lower bound — the rank at which compression (α+1)
/// is reached.
pub fn tucker_rmin_eq6(c: usize, s: usize, k: usize, alpha: f64, beta: f64) -> usize {
    tucker_rank_eq5(c, s, k, alpha + 1.0, beta)
}

/// SVD analogue of Eq. (6) for linear layers.
pub fn svd_rmin(c: usize, s: usize, alpha: f64) -> usize {
    svd_rank_for_compression(c, s, alpha + 1.0)
}

/// Decomposed parameter count for a layer at the given rank(s).
pub fn decomposed_params(shape: &LayerShape, r1: usize, r2: usize) -> usize {
    if shape.is_linear() {
        debug_assert_eq!(r1, r2);
        shape.c * r1 + r1 * shape.s
    } else {
        shape.c * r1 + r1 * r2 * shape.k * shape.k + r2 * shape.s
    }
}

/// Achieved compression ratio at the given rank(s).
pub fn compression_ratio(shape: &LayerShape, r1: usize, r2: usize) -> f64 {
    shape.dense_params() as f64 / decomposed_params(shape, r1, r2) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn svd_linear_full_rank_is_exact() {
        let mut r = Rng::new(20);
        let w = Tensor::randn(&[10, 6], 1.0, &mut r);
        let f = svd_linear(&w, 6);
        assert!(w.max_abs_diff(&f.reconstruct()) < 1e-4);
    }

    #[test]
    fn svd_linear_truncated_error_bounded() {
        let mut rng = Rng::new(21);
        let w = Tensor::randn(&[16, 16], 1.0, &mut rng);
        let full = svd_linear(&w, 16);
        let half = svd_linear(&w, 8);
        let e_full = reconstruction_error(&w, &full.reconstruct());
        let e_half = reconstruction_error(&w, &half.reconstruct());
        assert!(e_full < 1e-6);
        assert!(e_half > e_full);
        // half-rank of a random gaussian retains > 50% energy
        assert!(e_half < w.norm().powi(2));
    }

    #[test]
    fn svd_factor_shapes_and_params() {
        let mut rng = Rng::new(22);
        let w = Tensor::randn(&[32, 48], 1.0, &mut rng);
        let f = svd_linear(&w, 5);
        assert_eq!(f.a.shape(), &[32, 5]);
        assert_eq!(f.b.shape(), &[5, 48]);
        assert_eq!(f.params(), 32 * 5 + 5 * 48);
        assert_eq!(f.rank(), 5);
    }

    #[test]
    fn symmetric_sigma_split_balances_factor_norms() {
        let mut rng = Rng::new(23);
        let w = Tensor::randn(&[24, 24], 1.0, &mut rng);
        let f = svd_linear(&w, 12);
        let ratio = f.a.norm() / f.b.norm();
        assert!(ratio > 0.5 && ratio < 2.0, "ratio {ratio}");
    }

    #[test]
    fn tucker_full_rank_reconstructs() {
        let mut rng = Rng::new(24);
        let w = Tensor::randn(&[6, 8, 3, 3], 1.0, &mut rng);
        let f = tucker2_conv(&w, 6, 8);
        let rec = f.reconstruct();
        assert_eq!(rec.shape(), w.shape());
        assert!(w.max_abs_diff(&rec) < 1e-3, "err {}", w.max_abs_diff(&rec));
    }

    #[test]
    fn tucker_truncated_shapes() {
        let mut rng = Rng::new(25);
        let w = Tensor::randn(&[8, 12, 3, 3], 1.0, &mut rng);
        let f = tucker2_conv(&w, 3, 4);
        assert_eq!(f.first.shape(), &[8, 3]);
        assert_eq!(f.core.shape(), &[3, 4, 3, 3]);
        assert_eq!(f.last.shape(), &[4, 12]);
        assert_eq!(f.params(), 8 * 3 + 3 * 4 * 9 + 4 * 12);
    }

    #[test]
    fn tucker_error_decreases_with_rank() {
        let mut rng = Rng::new(26);
        let w = Tensor::randn(&[10, 10, 3, 3], 1.0, &mut rng);
        let mut last_err = f32::INFINITY;
        for r in [2, 4, 6, 8, 10] {
            let f = tucker2_conv(&w, r, r);
            let err = reconstruction_error(&w, &f.reconstruct());
            assert!(err <= last_err + 1e-3, "r={r} err={err} last={last_err}");
            last_err = err;
        }
        assert!(last_err < 1e-3);
    }

    #[test]
    fn tucker_on_lowrank_tensor_is_exact() {
        // Build W with true multilinear rank (2, 3): Tucker at (2,3) must
        // reconstruct it exactly.
        let mut rng = Rng::new(27);
        let core = Tensor::randn(&[2, 3, 3, 3], 1.0, &mut rng);
        let u = Tensor::randn(&[7, 2], 1.0, &mut rng);
        let v = Tensor::randn(&[3, 9], 1.0, &mut rng);
        let w = TuckerFactors { first: u, core, last: v }.reconstruct();
        let f = tucker2_conv(&w, 2, 3);
        assert!(w.max_abs_diff(&f.reconstruct()) < 1e-3);
    }

    #[test]
    fn eq5_matches_paper_example() {
        // Paper §2.1: [512, 512, 3, 3] at 2x compression with β=1 → rank 309.
        let r = tucker_rank_eq5(512, 512, 3, 2.0, 1.0);
        assert!((308..=310).contains(&r), "r = {r}");
    }

    #[test]
    fn eq5_achieves_requested_compression() {
        for &(c, s, k) in &[(64usize, 64usize, 3usize), (128, 256, 3), (512, 512, 3)] {
            for &alpha in &[1.5f64, 2.0, 3.0, 4.0] {
                let r = tucker_rank_eq5(c, s, k, alpha, 1.0);
                let shape = LayerShape::conv(c, s, k);
                let achieved = compression_ratio(&shape, r, r);
                // floor() ⇒ achieved ratio is at least α (within 5% slack of
                // the integer rounding).
                assert!(
                    achieved >= alpha * 0.95,
                    "c={c} s={s} α={alpha} r={r} achieved={achieved}"
                );
            }
        }
    }

    #[test]
    fn eq6_below_eq5() {
        let r5 = tucker_rank_eq5(512, 512, 3, 2.0, 1.0);
        let r6 = tucker_rmin_eq6(512, 512, 3, 2.0, 1.0);
        assert!(r6 < r5);
        // 3x band for the paper's layer is around rank 242 (Fig. 2 sweep floor)
        assert!((240..=254).contains(&r6), "rmin = {r6}");
    }

    #[test]
    fn svd_rank_formula() {
        // dense CS = r(C+S) at α ⇒ r = CS/(α(C+S))
        let r = svd_rank_for_compression(512, 512, 2.0);
        assert_eq!(r, 128);
        assert_eq!(svd_rmin(512, 512, 2.0), 85); // α+1 = 3 ⇒ 512/6 ≈ 85
    }

    #[test]
    fn compression_ratio_accounting() {
        let shape = LayerShape::conv(512, 512, 3);
        assert_eq!(shape.dense_params(), 512 * 512 * 9);
        let r = 309;
        let dec = decomposed_params(&shape, r, r);
        assert_eq!(dec, 512 * 309 + 309 * 309 * 9 + 309 * 512);
        let ratio = compression_ratio(&shape, r, r);
        assert!((1.9..=2.1).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn beta_scales_second_rank() {
        let r_b1 = tucker_rank_eq5(256, 512, 3, 2.0, 1.0);
        let r_b2 = tucker_rank_eq5(256, 512, 3, 2.0, 2.0);
        // with β=2, r1 shrinks but r2=2·r1; total params still ≈ target
        assert!(r_b2 < r_b1);
        let shape = LayerShape::conv(256, 512, 3);
        let achieved = compression_ratio(&shape, r_b2, 2 * r_b2);
        assert!(achieved >= 1.85, "achieved {achieved}");
    }
}
