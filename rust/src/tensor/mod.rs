//! Dense row-major f32 tensor with exactly the operations the LRD engine
//! needs: matmul, transpose, mode-n unfolding/folding (for Tucker/HOSVD),
//! reshape, slicing, and norms. Built from scratch — no ndarray offline.

use crate::util::rng::Rng;

/// Dense row-major tensor of f32.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    // ---- construction ----------------------------------------------------

    pub fn new(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} != data len {}",
            shape,
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn ones(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![1.0; shape.iter().product()] }
    }

    /// Identity matrix n×n.
    pub fn eye(n: usize) -> Tensor {
        let mut t = Tensor::zeros(&[n, n]);
        for i in 0..n {
            t.data[i * n + i] = 1.0;
        }
        t
    }

    /// i.i.d. N(0, std²) entries.
    pub fn randn(shape: &[usize], std: f32, rng: &mut Rng) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: (0..n).map(|_| rng.normal() * std).collect() }
    }

    // ---- accessors ---------------------------------------------------------

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }
    pub fn ndim(&self) -> usize {
        self.shape.len()
    }
    pub fn len(&self) -> usize {
        self.data.len()
    }
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
    pub fn data(&self) -> &[f32] {
        &self.data
    }
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }
    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Element access for 2-D tensors.
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.ndim(), 2);
        self.data[i * self.shape[1] + j]
    }
    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        debug_assert_eq!(self.ndim(), 2);
        let cols = self.shape[1];
        self.data[i * cols + j] = v;
    }

    // ---- shape ops ---------------------------------------------------------

    pub fn reshape(&self, shape: &[usize]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        Tensor { shape: shape.to_vec(), data: self.data.clone() }
    }

    /// 2-D transpose.
    pub fn t(&self) -> Tensor {
        assert_eq!(self.ndim(), 2, "t() needs a matrix");
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor { shape: vec![n, m], data: out }
    }

    /// General axis permutation.
    pub fn permute(&self, perm: &[usize]) -> Tensor {
        assert_eq!(perm.len(), self.ndim());
        let nd = self.ndim();
        let out_shape: Vec<usize> = perm.iter().map(|&p| self.shape[p]).collect();
        let in_strides = strides(&self.shape);
        let out_strides = strides(&out_shape);
        let mut out = vec![0.0f32; self.data.len()];
        let mut idx = vec![0usize; nd];
        for (o, slot) in out.iter_mut().enumerate() {
            // decode output index
            let mut rem = o;
            for d in 0..nd {
                idx[d] = rem / out_strides[d];
                rem %= out_strides[d];
            }
            // map to input offset: out dim d == in dim perm[d]
            let mut src = 0;
            for d in 0..nd {
                src += idx[d] * in_strides[perm[d]];
            }
            *slot = self.data[src];
        }
        Tensor { shape: out_shape, data: out }
    }

    /// Mode-n unfolding: moves axis `mode` first, flattens the rest in
    /// natural order. Result is `[shape[mode], prod(other dims)]` (the
    /// standard Kolda-Bader unfolding up to column order, which is
    /// consistent between `unfold` and `fold`).
    pub fn unfold(&self, mode: usize) -> Tensor {
        assert!(mode < self.ndim());
        let nd = self.ndim();
        let mut perm: Vec<usize> = vec![mode];
        perm.extend((0..nd).filter(|&d| d != mode));
        let moved = self.permute(&perm);
        let rows = self.shape[mode];
        let cols = self.data.len() / rows;
        moved.reshape(&[rows, cols])
    }

    /// Inverse of [`unfold`]: fold a `[shape[mode], rest]` matrix back into
    /// `shape` along `mode`.
    pub fn fold(mat: &Tensor, mode: usize, shape: &[usize]) -> Tensor {
        assert_eq!(mat.ndim(), 2);
        let nd = shape.len();
        let mut moved_shape = vec![shape[mode]];
        moved_shape.extend((0..nd).filter(|&d| d != mode).map(|d| shape[d]));
        let moved = mat.reshape(&moved_shape);
        // inverse permutation of [mode, others...]
        let mut perm: Vec<usize> = vec![mode];
        perm.extend((0..nd).filter(|&d| d != mode));
        let mut inv = vec![0usize; nd];
        for (i, &p) in perm.iter().enumerate() {
            inv[p] = i;
        }
        moved.permute(&inv)
    }

    // ---- arithmetic ----------------------------------------------------------

    /// Matrix multiply (2-D × 2-D). Blocked i-k-j loop over the row-major
    /// layout; good cache behaviour without external BLAS.
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.ndim(), 2);
        assert_eq!(other.ndim(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (other.shape[0], other.shape[1]);
        assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
        let mut out = vec![0.0f32; m * n];
        let a = &self.data;
        let b = &other.data;
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for (kk, &aik) in arow.iter().enumerate() {
                if aik == 0.0 {
                    continue;
                }
                let brow = &b[kk * n..(kk + 1) * n];
                for j in 0..n {
                    orow[j] += aik * brow[j];
                }
            }
        }
        Tensor { shape: vec![m, n], data: out }
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Tensor { shape: self.shape.clone(), data }
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Tensor { shape: self.shape.clone(), data }
    }

    pub fn scale(&self, s: f32) -> Tensor {
        Tensor { shape: self.shape.clone(), data: self.data.iter().map(|a| a * s).collect() }
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt() as f32
    }

    /// Squared Frobenius distance ‖a − b‖² — Eq. (3)'s reconstruction error.
    pub fn dist2(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum::<f64>() as f32
    }

    /// Maximum absolute difference.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Column `j` of a matrix.
    pub fn col(&self, j: usize) -> Vec<f32> {
        assert_eq!(self.ndim(), 2);
        (0..self.shape[0]).map(|i| self.at2(i, j)).collect()
    }

    /// Keep only the first `k` columns of a matrix.
    pub fn first_cols(&self, k: usize) -> Tensor {
        assert_eq!(self.ndim(), 2);
        let (m, n) = (self.shape[0], self.shape[1]);
        assert!(k <= n);
        let mut out = vec![0.0f32; m * k];
        for i in 0..m {
            out[i * k..(i + 1) * k].copy_from_slice(&self.data[i * n..i * n + k]);
        }
        Tensor { shape: vec![m, k], data: out }
    }
}

/// Row-major strides for a shape.
pub fn strides(shape: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; shape.len()];
    for d in (0..shape.len().saturating_sub(1)).rev() {
        s[d] = s[d + 1] * shape[d + 1];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t2(rows: usize, cols: usize, v: &[f32]) -> Tensor {
        Tensor::new(&[rows, cols], v.to_vec())
    }

    #[test]
    fn matmul_known() {
        let a = t2(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let b = t2(2, 2, &[1.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.matmul(&b).data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_rectangular() {
        let a = t2(2, 3, &[1., 2., 3., 4., 5., 6.]);
        let b = t2(3, 1, &[1., 0., -1.]);
        let c = a.matmul(&b);
        assert_eq!(c.shape(), &[2, 1]);
        assert_eq!(c.data(), &[-2.0, -2.0]);
    }

    #[test]
    fn matmul_identity() {
        let mut r = Rng::new(1);
        let a = Tensor::randn(&[5, 7], 1.0, &mut r);
        let i = Tensor::eye(7);
        assert!(a.matmul(&i).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn transpose_involution() {
        let mut r = Rng::new(2);
        let a = Tensor::randn(&[4, 9], 1.0, &mut r);
        assert_eq!(a.t().t(), a);
        assert_eq!(a.t().shape(), &[9, 4]);
        assert_eq!(a.at2(1, 3), a.t().at2(3, 1));
    }

    #[test]
    fn permute_roundtrip() {
        let mut r = Rng::new(3);
        let a = Tensor::randn(&[2, 3, 4], 1.0, &mut r);
        let p = a.permute(&[2, 0, 1]);
        assert_eq!(p.shape(), &[4, 2, 3]);
        // inverse of [2,0,1] is [1,2,0]
        assert_eq!(p.permute(&[1, 2, 0]), a);
    }

    #[test]
    fn unfold_fold_roundtrip_all_modes() {
        let mut r = Rng::new(4);
        let a = Tensor::randn(&[3, 4, 5], 1.0, &mut r);
        for mode in 0..3 {
            let u = a.unfold(mode);
            assert_eq!(u.shape()[0], a.shape()[mode]);
            assert_eq!(u.shape()[1], 60 / a.shape()[mode]);
            let back = Tensor::fold(&u, mode, a.shape());
            assert_eq!(back, a, "mode {mode}");
        }
    }

    #[test]
    fn unfold_mode0_is_reshape() {
        // For mode 0 the unfolding is exactly the natural [d0, rest] view.
        let a = Tensor::new(&[2, 2, 2], (0..8).map(|i| i as f32).collect());
        let u = a.unfold(0);
        assert_eq!(u.data(), a.data());
    }

    #[test]
    fn norms_and_dist() {
        let a = t2(1, 3, &[3.0, 0.0, 4.0]);
        assert!((a.norm() - 5.0).abs() < 1e-6);
        let b = t2(1, 3, &[0.0, 0.0, 0.0]);
        assert!((a.dist2(&b) - 25.0).abs() < 1e-5);
        assert_eq!(a.max_abs_diff(&b), 4.0);
    }

    #[test]
    fn first_cols_slices() {
        let a = t2(2, 3, &[1., 2., 3., 4., 5., 6.]);
        let f = a.first_cols(2);
        assert_eq!(f.shape(), &[2, 2]);
        assert_eq!(f.data(), &[1., 2., 4., 5.]);
    }

    #[test]
    fn add_sub_scale() {
        let a = t2(1, 2, &[1.0, 2.0]);
        let b = t2(1, 2, &[3.0, 5.0]);
        assert_eq!(a.add(&b).data(), &[4.0, 7.0]);
        assert_eq!(b.sub(&a).data(), &[2.0, 3.0]);
        assert_eq!(a.scale(2.0).data(), &[2.0, 4.0]);
    }

    #[test]
    #[should_panic(expected = "matmul inner dims")]
    fn matmul_dim_mismatch_panics() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[2, 3]);
        a.matmul(&b);
    }

    #[test]
    fn strides_row_major() {
        assert_eq!(strides(&[2, 3, 4]), vec![12, 4, 1]);
        assert_eq!(strides(&[5]), vec![1]);
    }
}
