//! Binary checkpoint format, shared with the python writer
//! (`python/compile/ckpt.py`):
//!
//! ```text
//! magic b"LRTA" | version u32 (=1) | count u32
//! per tensor: name_len u32 | name utf-8 | ndim u32 | dims u32[ndim] | f32 LE data
//! ```
//!
//! Tensors are written in sorted-name order for deterministic files.

use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"LRTA";
const VERSION: u32 = 1;

/// Named parameter set (sorted by name).
pub type Params = BTreeMap<String, Tensor>;

/// Save params to `path`.
pub fn save(path: impl AsRef<Path>, params: &Params) -> Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).ok();
    }
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?,
    );
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(params.len() as u32).to_le_bytes())?;
    for (name, t) in params {
        let nb = name.as_bytes();
        f.write_all(&(nb.len() as u32).to_le_bytes())?;
        f.write_all(nb)?;
        f.write_all(&(t.ndim() as u32).to_le_bytes())?;
        for &d in t.shape() {
            f.write_all(&(d as u32).to_le_bytes())?;
        }
        // f32 LE; on all supported platforms this is a straight copy
        for &v in t.data() {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Load params from `path`.
pub fn load(path: impl AsRef<Path>) -> Result<Params> {
    let path = path.as_ref();
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?,
    );
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{}: bad magic {:?}", path.display(), magic);
    }
    let version = read_u32(&mut f)?;
    if version != VERSION {
        bail!("{}: unsupported version {version}", path.display());
    }
    let count = read_u32(&mut f)? as usize;
    let mut params = Params::new();
    for _ in 0..count {
        let nlen = read_u32(&mut f)? as usize;
        let mut nb = vec![0u8; nlen];
        f.read_exact(&mut nb)?;
        let name = String::from_utf8(nb).context("tensor name utf-8")?;
        let ndim = read_u32(&mut f)? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(read_u32(&mut f)? as usize);
        }
        let numel: usize = shape.iter().product::<usize>().max(1);
        let mut bytes = vec![0u8; 4 * numel];
        f.read_exact(&mut bytes)?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let shape = if shape.is_empty() { vec![1] } else { shape };
        params.insert(name, Tensor::new(&shape, data));
    }
    Ok(params)
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("lrta_ckpt_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(30);
        let mut p = Params::new();
        p.insert("w".into(), Tensor::randn(&[3, 4], 1.0, &mut rng));
        p.insert("a.b.c".into(), Tensor::randn(&[2, 2, 2, 2], 0.1, &mut rng));
        p.insert("bias".into(), Tensor::randn(&[7], 1.0, &mut rng));
        let path = tmp("roundtrip.bin");
        save(&path, &p).unwrap();
        let q = load(&path).unwrap();
        assert_eq!(p.len(), q.len());
        for (k, t) in &p {
            assert_eq!(q[k], *t, "{k}");
        }
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmp("bad_magic.bin");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn rejects_missing_file() {
        assert!(load(tmp("missing.bin")).is_err());
    }

    #[test]
    fn empty_params() {
        let path = tmp("empty.bin");
        save(&path, &Params::new()).unwrap();
        assert_eq!(load(&path).unwrap().len(), 0);
    }

    #[test]
    fn reads_python_written_layout() {
        // Byte-for-byte fixture matching python ckpt.save({"t": [[1.5, -2.0]]})
        let mut bytes: Vec<u8> = Vec::new();
        bytes.extend(b"LRTA");
        bytes.extend(1u32.to_le_bytes()); // version
        bytes.extend(1u32.to_le_bytes()); // count
        bytes.extend(1u32.to_le_bytes()); // name len
        bytes.extend(b"t");
        bytes.extend(2u32.to_le_bytes()); // ndim
        bytes.extend(1u32.to_le_bytes());
        bytes.extend(2u32.to_le_bytes());
        bytes.extend(1.5f32.to_le_bytes());
        bytes.extend((-2.0f32).to_le_bytes());
        let path = tmp("pyfixture.bin");
        std::fs::write(&path, &bytes).unwrap();
        let p = load(&path).unwrap();
        assert_eq!(p["t"].shape(), &[1, 2]);
        assert_eq!(p["t"].data(), &[1.5, -2.0]);
    }
}
