//! Binary checkpoint format, shared with the python writer
//! (`python/compile/ckpt.py`):
//!
//! ```text
//! magic b"LRTA" | version u32 (=1) | count u32
//! per tensor: name_len u32 | name utf-8 | ndim u32 | dims u32[ndim] | f32 LE data
//! ```
//!
//! Tensors are written in sorted-name order for deterministic files.
//!
//! The codec is split from the I/O: [`encode`]/[`decode`] map `Params`
//! to/from the byte format, and everything else is a thin shim over a
//! byte sink — [`save`]/[`load`] for bare filesystem paths (the legacy
//! layout, byte-identical to what this module always wrote) and
//! [`save_to`]/[`load_from`] for any [`crate::storage::Storage`] backend.
//! A checkpoint written through either route is the same bytes, so
//! producers and consumers can mix paths and storage URIs freely.

use crate::storage::Storage;
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"LRTA";
const VERSION: u32 = 1;

/// Named parameter set (sorted by name).
pub type Params = BTreeMap<String, Tensor>;

/// Serialize `params` into the checkpoint byte format (sorted-name
/// order — deterministic: equal params always encode to equal bytes).
pub fn encode(params: &Params) -> Vec<u8> {
    // magic + version + count + per-tensor headers and f32 payloads
    let payload: usize =
        params.values().map(|t| 8 + 4 * t.ndim() + 4 * t.data().len()).sum::<usize>()
            + params.keys().map(|n| n.len()).sum::<usize>();
    let mut out = Vec::with_capacity(12 + payload);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&(params.len() as u32).to_le_bytes());
    for (name, t) in params {
        let nb = name.as_bytes();
        out.extend_from_slice(&(nb.len() as u32).to_le_bytes());
        out.extend_from_slice(nb);
        out.extend_from_slice(&(t.ndim() as u32).to_le_bytes());
        for &d in t.shape() {
            out.extend_from_slice(&(d as u32).to_le_bytes());
        }
        // f32 LE; on all supported platforms this is a straight copy
        for &v in t.data() {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    out
}

/// Parse checkpoint bytes (inverse of [`encode`]).
pub fn decode(bytes: &[u8]) -> Result<Params> {
    let mut f = bytes;
    read(&mut f, "checkpoint bytes")
}

/// Save params to `path`.
pub fn save(path: impl AsRef<Path>, params: &Params) -> Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("create checkpoint dir {}", dir.display()))?;
    }
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?,
    );
    f.write_all(&encode(params))?;
    f.flush()?;
    Ok(())
}

/// Load params from `path`.
pub fn load(path: impl AsRef<Path>) -> Result<Params> {
    let path = path.as_ref();
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?,
    );
    read(&mut f, &path.display().to_string())
}

/// Save params under `key` on a storage backend. Byte-identical to
/// [`save`]'s file (same [`encode`] output), streamed through
/// [`Storage::put_streaming`] so large checkpoints never double-buffer in
/// backends that spool to disk.
pub fn save_to(store: &dyn Storage, key: &str, params: &Params) -> Result<()> {
    let bytes = encode(params);
    store
        .put_streaming(key, &mut &bytes[..])
        .with_context(|| format!("save checkpoint to storage key '{key}'"))?;
    Ok(())
}

/// Load params from `key` on a storage backend (inverse of [`save_to`]).
pub fn load_from(store: &dyn Storage, key: &str) -> Result<Params> {
    let bytes = store
        .get(key)
        .with_context(|| format!("load checkpoint from storage key '{key}'"))?;
    decode(&bytes).with_context(|| format!("decode checkpoint '{key}'"))
}

/// Decode the stream format from any reader; `what` labels errors.
fn read(f: &mut impl Read, what: &str) -> Result<Params> {
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{what}: bad magic {magic:?}");
    }
    let version = read_u32(f)?;
    if version != VERSION {
        bail!("{what}: unsupported version {version}");
    }
    let count = read_u32(f)? as usize;
    let mut params = Params::new();
    for _ in 0..count {
        let nlen = read_u32(f)? as usize;
        let mut nb = vec![0u8; nlen];
        f.read_exact(&mut nb)?;
        let name = String::from_utf8(nb).context("tensor name utf-8")?;
        let ndim = read_u32(f)? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(read_u32(f)? as usize);
        }
        let numel: usize = shape.iter().product::<usize>().max(1);
        let mut bytes = vec![0u8; 4 * numel];
        f.read_exact(&mut bytes)?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let shape = if shape.is_empty() { vec![1] } else { shape };
        params.insert(name, Tensor::new(&shape, data));
    }
    Ok(params)
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::{LocalFs, MemObject, Storage};
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("lrta_ckpt_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn some_params() -> Params {
        let mut rng = Rng::new(30);
        let mut p = Params::new();
        p.insert("w".into(), Tensor::randn(&[3, 4], 1.0, &mut rng));
        p.insert("a.b.c".into(), Tensor::randn(&[2, 2, 2, 2], 0.1, &mut rng));
        p.insert("bias".into(), Tensor::randn(&[7], 1.0, &mut rng));
        p
    }

    #[test]
    fn roundtrip() {
        let p = some_params();
        let path = tmp("roundtrip.bin");
        save(&path, &p).unwrap();
        let q = load(&path).unwrap();
        assert_eq!(p.len(), q.len());
        for (k, t) in &p {
            assert_eq!(q[k], *t, "{k}");
        }
    }

    #[test]
    fn encode_decode_roundtrip_without_io() {
        let p = some_params();
        let q = decode(&encode(&p)).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn file_save_equals_encode() {
        // `save` is a pure shim over `encode`: the file IS the codec bytes
        let p = some_params();
        let path = tmp("save_equals_encode.bin");
        save(&path, &p).unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), encode(&p));
    }

    #[test]
    fn storage_backends_write_byte_identical_checkpoints() {
        let p = some_params();
        let path = tmp("via_path.bin");
        save(&path, &p).unwrap();
        let file_bytes = std::fs::read(&path).unwrap();

        let mem = MemObject::new();
        save_to(&mem, "ckpts/x.bin", &p).unwrap();
        assert_eq!(mem.get("ckpts/x.bin").unwrap(), file_bytes);
        assert_eq!(load_from(&mem, "ckpts/x.bin").unwrap(), p);

        let root = std::env::temp_dir().join("lrta_ckpt_tests_localfs");
        let _ = std::fs::remove_dir_all(&root);
        let fs = LocalFs::open(root.clone()).unwrap();
        save_to(&fs, "ckpts/x.bin", &p).unwrap();
        assert_eq!(std::fs::read(root.join("ckpts/x.bin")).unwrap(), file_bytes);
        assert_eq!(load_from(&fs, "ckpts/x.bin").unwrap(), p);
    }

    #[test]
    fn save_into_file_parent_reports_mkdir_error() {
        // regression: the parent "directory" is a regular file, so the
        // mkdir itself must fail with context — not a confusing
        // `File::create` error further down
        let blocker = tmp("parent_blocker");
        let _ = std::fs::remove_file(&blocker);
        std::fs::write(&blocker, "file").unwrap();
        let err = save(blocker.join("sub/ckpt.bin"), &Params::new()).unwrap_err();
        assert!(
            format!("{err:#}").contains("create checkpoint dir"),
            "error must surface the mkdir failure: {err:#}"
        );
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmp("bad_magic.bin");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(load(&path).is_err());
        assert!(decode(b"NOPE....").is_err());
    }

    #[test]
    fn rejects_missing_file() {
        assert!(load(tmp("missing.bin")).is_err());
    }

    #[test]
    fn missing_storage_key_is_typed_not_found() {
        let mem = MemObject::new();
        let err = load_from(&mem, "nope.bin").unwrap_err();
        assert!(crate::storage::is_not_found(&err), "{err:#}");
    }

    #[test]
    fn empty_params() {
        let path = tmp("empty.bin");
        save(&path, &Params::new()).unwrap();
        assert_eq!(load(&path).unwrap().len(), 0);
    }

    #[test]
    fn reads_python_written_layout() {
        // Byte-for-byte fixture matching python ckpt.save({"t": [[1.5, -2.0]]})
        let mut bytes: Vec<u8> = Vec::new();
        bytes.extend(b"LRTA");
        bytes.extend(1u32.to_le_bytes()); // version
        bytes.extend(1u32.to_le_bytes()); // count
        bytes.extend(1u32.to_le_bytes()); // name len
        bytes.extend(b"t");
        bytes.extend(2u32.to_le_bytes()); // ndim
        bytes.extend(1u32.to_le_bytes());
        bytes.extend(2u32.to_le_bytes());
        bytes.extend(1.5f32.to_le_bytes());
        bytes.extend((-2.0f32).to_le_bytes());
        let path = tmp("pyfixture.bin");
        std::fs::write(&path, &bytes).unwrap();
        let p = load(&path).unwrap();
        assert_eq!(p["t"].shape(), &[1, 2]);
        assert_eq!(p["t"].data(), &[1.5, -2.0]);
        // and the codec reproduces the fixture exactly
        assert_eq!(encode(&p), bytes);
    }
}
