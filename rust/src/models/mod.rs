//! Model zoo.
//!
//! Two kinds of entries:
//! - **mini models** (`resnet_mini`, `vit_mini`): backed by real AOT
//!   artifacts; trained/evaluated end-to-end by the coordinator.
//! - **full-size shape tables** (ResNet-50/101/152, ViT-B): the paper's
//!   actual evaluation networks. We cannot train them on this host, but
//!   their exact layer shapes drive (a) the real decomposition-time
//!   benchmark (Table 2 — the SVD/Tucker cost is shape-true) and (b) the
//!   device-model throughput projections (Tables 1/4 at paper scale).

pub mod zoo;

pub use zoo::{resnet_full, vit_b16, ZooLayer, ZooModel};

/// Mini models with AOT artifacts.
pub const MINI_MODELS: [&str; 2] = ["resnet_mini", "vit_mini"];

/// Method rows of the paper's tables, in paper order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Original,
    Lrd,
    RankOpt,
    Freezing,
    Combined,
}

impl Method {
    pub const ALL: [Method; 5] =
        [Method::Original, Method::Lrd, Method::RankOpt, Method::Freezing, Method::Combined];

    pub fn label(&self) -> &'static str {
        match self {
            Method::Original => "Original",
            Method::Lrd => "LRD",
            Method::RankOpt => "Rank Opt.",
            Method::Freezing => "Freezing",
            Method::Combined => "Combined",
        }
    }

    /// Which artifact variant this method runs on.
    pub fn variant(&self) -> &'static str {
        match self {
            Method::Original => "orig",
            Method::Lrd | Method::Freezing => "lrd",
            Method::RankOpt | Method::Combined => "rankopt",
        }
    }

    /// Whether the method fine-tunes with the freezing schedule.
    pub fn uses_freezing(&self) -> bool {
        matches!(self, Method::Freezing | Method::Combined)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_table_matches_paper() {
        assert_eq!(Method::ALL.len(), 5);
        assert_eq!(Method::Lrd.variant(), "lrd");
        assert_eq!(Method::RankOpt.variant(), "rankopt");
        assert_eq!(Method::Combined.variant(), "rankopt");
        assert!(Method::Combined.uses_freezing());
        assert!(!Method::RankOpt.uses_freezing());
        assert_eq!(Method::Original.variant(), "orig");
    }
}
