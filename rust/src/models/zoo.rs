//! Full-size model shape tables (the paper's evaluation networks).
//!
//! Layer inventories carry, per decomposable layer, the [`LayerShape`] and
//! the spatial positions per image (`m_per_image` = H·W at that depth) so
//! device-model projections can compute per-layer matmul times for any
//! batch size.

use crate::devmodel::DeviceProfile;
use crate::lrd::plan::{ModelPlan, RankMode};
use crate::lrd::LayerShape;
use crate::runtime::builder::LayerBench;

/// One decomposable layer of a full-size network.
#[derive(Clone, Debug)]
pub struct ZooLayer {
    pub name: String,
    pub shape: LayerShape,
    /// Spatial positions per image at this layer (H·W for convs, token
    /// count for transformers, 1 for heads).
    pub m_per_image: usize,
}

/// A full-size network: its decomposable layers.
#[derive(Clone, Debug)]
pub struct ZooModel {
    pub name: String,
    pub layers: Vec<ZooLayer>,
}

impl ZooModel {
    pub fn total_dense_params(&self) -> usize {
        self.layers.iter().map(|l| l.shape.dense_params()).sum()
    }

    /// Decomposition plan at compression `alpha`.
    pub fn plan(&self, alpha: f64, mode: RankMode) -> ModelPlan {
        let named: Vec<(String, LayerShape)> =
            self.layers.iter().map(|l| (l.name.clone(), l.shape)).collect();
        ModelPlan::build(&named, alpha, 1.0, mode)
    }

    /// Device-model estimate of inference time per batch.
    /// `method_ranks`: None ⇒ dense; Some(plan) ⇒ per-layer decomposed.
    pub fn infer_time(
        &self,
        dev: &DeviceProfile,
        batch: usize,
        plan: Option<&ModelPlan>,
    ) -> f64 {
        self.layers
            .iter()
            .map(|l| {
                let bench = LayerBench {
                    m: batch * l.m_per_image,
                    c: l.shape.c,
                    s: l.shape.s,
                    k: l.shape.k,
                };
                match plan.and_then(|p| p.find(&l.name)).filter(|lp| lp.decompose) {
                    None => dev.dense_fwd(&bench),
                    Some(lp) => dev.decomposed_fwd(&bench, lp.r1, lp.r2),
                }
            })
            .sum()
    }

    /// Device-model estimate of one training step. `freeze_pattern`:
    /// `None` ⇒ all factors trainable; `Some(true)` ⇒ pattern A (train
    /// core / factor b), `Some(false)` ⇒ pattern B.
    pub fn train_time(
        &self,
        dev: &DeviceProfile,
        batch: usize,
        plan: Option<&ModelPlan>,
        freeze_pattern: Option<bool>,
    ) -> f64 {
        self.layers
            .iter()
            .map(|l| {
                let bench = LayerBench {
                    m: batch * l.m_per_image,
                    c: l.shape.c,
                    s: l.shape.s,
                    k: l.shape.k,
                };
                match plan.and_then(|p| p.find(&l.name)).filter(|lp| lp.decompose) {
                    None => dev.dense_step(&bench),
                    Some(lp) => {
                        let (tf, tc, tl) = match freeze_pattern {
                            None => (true, true, true),
                            // pattern A: freeze first/last (SVD `a`), train core (`b`)
                            Some(true) => (false, true, false),
                            // pattern B: complement
                            Some(false) => (true, false, true),
                        };
                        dev.decomposed_step(&bench, lp.r1, lp.r2, tf, tc, tl)
                    }
                }
            })
            .sum()
    }
}

/// ResNet-50/101/152 (bottleneck) layer tables, ImageNet geometry
/// (224×224 input; stem 7×7/2 + pool → 56², then 56/28/14/7).
pub fn resnet_full(depth: usize) -> ZooModel {
    let blocks: [usize; 4] = match depth {
        50 => [3, 4, 6, 3],
        101 => [3, 4, 23, 3],
        152 => [3, 8, 36, 3],
        other => panic!("unsupported resnet depth {other}"),
    };
    let mut layers = Vec::new();
    layers.push(ZooLayer {
        name: "stem".into(),
        shape: LayerShape::conv(3, 64, 7),
        m_per_image: 112 * 112,
    });
    let mut c_in = 64usize;
    let spatial = [56usize, 28, 14, 7];
    for (stage, (&nblocks, &hw)) in blocks.iter().zip(&spatial).enumerate() {
        let planes = 64 << stage; // 64,128,256,512
        let out = planes * 4;
        for b in 0..nblocks {
            let pre = format!("s{stage}.b{b}");
            let m = hw * hw;
            layers.push(ZooLayer {
                name: format!("{pre}.conv1"),
                shape: LayerShape::linear(c_in, planes),
                m_per_image: m,
            });
            layers.push(ZooLayer {
                name: format!("{pre}.conv2"),
                shape: LayerShape::conv(planes, planes, 3),
                m_per_image: m,
            });
            layers.push(ZooLayer {
                name: format!("{pre}.conv3"),
                shape: LayerShape::linear(planes, out),
                m_per_image: m,
            });
            if b == 0 {
                layers.push(ZooLayer {
                    name: format!("{pre}.down"),
                    shape: LayerShape::linear(c_in, out),
                    m_per_image: m,
                });
            }
            c_in = out;
        }
    }
    layers.push(ZooLayer {
        name: "fc".into(),
        shape: LayerShape::linear(2048, 1000),
        m_per_image: 1,
    });
    ZooModel { name: format!("resnet{depth}"), layers }
}

/// ViT-B/16 on 224² (the paper's 12-module ViT): 196 tokens, d=768,
/// FFN 3072. Decomposables: patch-embed FC, per-block FFN FCs (the paper
/// decomposes exactly these), plus attention projections listed dense.
pub fn vit_b16() -> ZooModel {
    let d = 768usize;
    let tokens = 14 * 14;
    let mut layers = Vec::new();
    layers.push(ZooLayer {
        name: "embed".into(),
        shape: LayerShape::linear(16 * 16 * 3, d),
        m_per_image: tokens,
    });
    for i in 0..12 {
        layers.push(ZooLayer {
            name: format!("b{i}.qkv"),
            shape: LayerShape::linear(d, 3 * d),
            m_per_image: tokens,
        });
        layers.push(ZooLayer {
            name: format!("b{i}.proj"),
            shape: LayerShape::linear(d, d),
            m_per_image: tokens,
        });
        layers.push(ZooLayer {
            name: format!("b{i}.fc1"),
            shape: LayerShape::linear(d, 4 * d),
            m_per_image: tokens,
        });
        layers.push(ZooLayer {
            name: format!("b{i}.fc2"),
            shape: LayerShape::linear(4 * d, d),
            m_per_image: tokens,
        });
    }
    layers.push(ZooLayer {
        name: "head".into(),
        shape: LayerShape::linear(d, 1000),
        m_per_image: 1,
    });
    ZooModel { name: "vit_b16".into(), layers }
}

/// The paper's per-model plan: ResNets decompose everything; ViT
/// decomposes embed + FFN FCs only (attention stays dense).
pub fn paper_plan(model: &ZooModel, alpha: f64, mode: RankMode) -> ModelPlan {
    let named: Vec<(String, LayerShape)> = model
        .layers
        .iter()
        .filter(|l| {
            if model.name == "vit_b16" {
                !(l.name.ends_with(".qkv") || l.name.ends_with(".proj"))
            } else {
                true
            }
        })
        .map(|l| (l.name.clone(), l.shape))
        .collect();
    ModelPlan::build(&named, alpha, 1.0, mode)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet50_param_count_close_to_reference() {
        // torchvision ResNet-50 has 25.56M params; conv+fc only (no norms)
        // is ~25.0M. Our table must land within a few percent.
        let m = resnet_full(50);
        let p = m.total_dense_params() as f64 / 1e6;
        assert!((23.0..27.0).contains(&p), "params {p}M");
    }

    #[test]
    fn deeper_resnets_are_larger_and_slower() {
        let d = DeviceProfile::v100();
        let (m50, m101, m152) = (resnet_full(50), resnet_full(101), resnet_full(152));
        assert!(m101.total_dense_params() > m50.total_dense_params());
        assert!(m152.total_dense_params() > m101.total_dense_params());
        let t50 = m50.infer_time(&d, 32, None);
        let t101 = m101.infer_time(&d, 32, None);
        let t152 = m152.infer_time(&d, 32, None);
        assert!(t50 < t101 && t101 < t152);
    }

    #[test]
    fn vanilla_lrd_speedup_is_modest_rankopt_larger() {
        // The paper's central Table-1 shape: vanilla LRD buys only a few
        // percent; rank quantization buys much more.
        let d = DeviceProfile::v100();
        let m = resnet_full(50);
        let dense = m.infer_time(&d, 32, None);
        let lrd = m.infer_time(&d, 32, Some(&paper_plan(&m, 2.0, RankMode::Vanilla)));
        let ropt =
            m.infer_time(&d, 32, Some(&paper_plan(&m, 2.0, RankMode::Quantized { tile: 64 })));
        assert!(lrd < dense, "LRD at 2x must not be slower overall");
        assert!(ropt < lrd, "rank-opt must beat vanilla LRD");
        let lrd_gain = dense / lrd - 1.0;
        let ropt_gain = dense / ropt - 1.0;
        assert!(
            ropt_gain > lrd_gain * 1.5,
            "rank-opt gain must dominate: lrd {lrd_gain:.3} vs ropt {ropt_gain:.3}"
        );
        assert!(lrd_gain < 0.5, "vanilla LRD gain should be modest, got {lrd_gain:.3}");
    }

    #[test]
    fn freezing_helps_training_not_inference() {
        let d = DeviceProfile::v100();
        let m = resnet_full(101);
        let plan = paper_plan(&m, 2.0, RankMode::Vanilla);
        let full = m.train_time(&d, 32, Some(&plan), None);
        let frozen = m.train_time(&d, 32, Some(&plan), Some(true));
        assert!(frozen < full);
        // inference path has no freeze dependence by construction
        let i1 = m.infer_time(&d, 32, Some(&plan));
        assert!(i1 > 0.0);
    }

    #[test]
    fn deeper_models_gain_more_from_freezing() {
        // Paper: "The improvement is larger for deeper models" — in our
        // model the per-depth gains are close (the paper's extra effect
        // comes from framework per-layer overheads we only partly model),
        // so assert the gain is material at every depth and within a small
        // factor of monotone.
        let d = DeviceProfile::v100();
        let gain = |depth: usize| {
            let m = resnet_full(depth);
            let plan = paper_plan(&m, 2.0, RankMode::Vanilla);
            let full = m.train_time(&d, 32, Some(&plan), None);
            let froz = m.train_time(&d, 32, Some(&plan), Some(true));
            full / froz
        };
        let (g50, g152) = (gain(50), gain(152));
        assert!(g50 > 1.05 && g152 > 1.05, "freezing gains must be material");
        assert!(g152 >= g50 * 0.95, "152 {g152} vs 50 {g50}");
    }

    #[test]
    fn vit_b16_geometry() {
        let m = vit_b16();
        assert_eq!(m.layers.len(), 2 + 12 * 4);
        let p = m.total_dense_params() as f64 / 1e6;
        // ViT-B conv/fc params ~ 85M; ours excludes norms/bias (~84M)
        assert!((80.0..90.0).contains(&p), "params {p}M");
    }

    #[test]
    fn vit_plan_keeps_attention_dense() {
        let m = vit_b16();
        let plan = paper_plan(&m, 2.0, RankMode::Vanilla);
        assert!(plan.find("b0.qkv").is_none());
        assert!(plan.find("b0.fc1").is_some());
    }

    #[test]
    #[should_panic(expected = "unsupported resnet depth")]
    fn bad_depth_panics() {
        resnet_full(34);
    }
}
