//! Deterministic fault injection: named seams at the system's chokepoints,
//! armed by a globally-installed [`Plan`], compiled down to a single atomic
//! load + branch per seam when disarmed (the same zero-cost-off contract as
//! [`crate::obs::Tracer`] — pinned by the chaos integration suite: with no
//! plan installed every existing bit-for-bit parity pin is byte-identical).
//!
//! A plan is parsed from a spec string (CLI `--faults` or the `LRTA_FAULTS`
//! environment variable):
//!
//! ```text
//!   directive[,directive...]
//!   directive := seam[@scope]:action[@stepN]
//!   seam      := batch_upload | dispatch | fetch | prefetch
//!              | barrier_send | barrier_recv | swap_ack | hedge
//!              | storage_get | storage_put
//!   scope     := site label, e.g. replica1 (train) or shard0 (serve);
//!                omitted = match any scope
//!   action    := panic | error | stall(DURATION)   e.g. stall(200ms)
//!   stepN     := fire on the N-th matching hit (1-based; default 1)
//! ```
//!
//! Examples: `barrier_send@replica1:panic@step7` kills replica 1 the 7th
//! time it reaches the barrier send; `dispatch:stall(200ms)` stalls the
//! first dispatch anywhere for 200 ms.
//!
//! **Determinism**: every seam site counts its matching hits through the
//! directive's own atomic ordinal, so a directive fires at exactly the
//! N-th matching hit of its seam+scope and fires **exactly once** — no
//! clocks, no RNG, reproducible across runs (module-level, not per-thread:
//! a wildcard-scope directive counts hits across all matching threads in
//! arrival order, so pin the scope when the fleet races). Injections are
//! counted ([`fired`]) and span-recorded (`faults/fault_injected` via
//! [`set_tracer`]) so chaos tests and traces can assert exactly which
//! faults fired.
//!
//! Seam sites call [`hit`], which returns `Err` for an `error` action,
//! sleeps for `stall`, and panics for `panic` — exercising, respectively,
//! the error-propagation, straggler/timeout, and unwind/supervision paths
//! of the surrounding machinery.

use crate::obs::{Counter, Tracer};
use anyhow::{anyhow, bail, Result};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Duration;

/// A named injection point. Each variant corresponds to one chokepoint in
/// the train or serve hot path (see the module docs for the seam ↔ code
/// map, and ARCHITECTURE.md §failure-modes for the full picture).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Seam {
    /// Training-batch host→device upload ([`crate::train::Engine`]).
    BatchUpload,
    /// Executable dispatch (train step or serve batch).
    Dispatch,
    /// Result fetch/demux (train step or serve batch).
    Fetch,
    /// Prefetcher worker producing a staged batch.
    Prefetch,
    /// Replica about to send its averaging contribution.
    BarrierSend,
    /// Replica about to block on the broadcast mean.
    BarrierRecv,
    /// Serve worker about to acknowledge a warm swap.
    SwapAck,
    /// Hedge governor about to re-dispatch a stalled batch's requests to a
    /// sibling shard (`hedge@shardN` scopes to the *stalled* shard).
    Hedge,
    /// Storage backend about to serve a read (`get`/`exists`); scoped by
    /// the backend label (`storage_get@mem:stall(…)`).
    StorageGet,
    /// Storage backend about to commit a write (`put`/`put_streaming`);
    /// scoped by the backend label.
    StoragePut,
}

impl Seam {
    /// Parse the spec spelling of a seam name.
    pub fn parse(s: &str) -> Option<Seam> {
        match s {
            "batch_upload" => Some(Seam::BatchUpload),
            "dispatch" => Some(Seam::Dispatch),
            "fetch" => Some(Seam::Fetch),
            "prefetch" => Some(Seam::Prefetch),
            "barrier_send" => Some(Seam::BarrierSend),
            "barrier_recv" => Some(Seam::BarrierRecv),
            "swap_ack" => Some(Seam::SwapAck),
            "hedge" => Some(Seam::Hedge),
            "storage_get" => Some(Seam::StorageGet),
            "storage_put" => Some(Seam::StoragePut),
            _ => None,
        }
    }

    /// The spec spelling (inverse of [`Seam::parse`]).
    pub fn label(&self) -> &'static str {
        match self {
            Seam::BatchUpload => "batch_upload",
            Seam::Dispatch => "dispatch",
            Seam::Fetch => "fetch",
            Seam::Prefetch => "prefetch",
            Seam::BarrierSend => "barrier_send",
            Seam::BarrierRecv => "barrier_recv",
            Seam::SwapAck => "swap_ack",
            Seam::Hedge => "hedge",
            Seam::StorageGet => "storage_get",
            Seam::StoragePut => "storage_put",
        }
    }
}

/// What an armed directive does at its seam.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Action {
    /// `panic!` at the seam — exercises unwind paths (replica
    /// `catch_unwind`, serve drop-guard drain, supervisor respawn).
    Panic,
    /// Return an `anyhow` error from the seam — exercises `Result`
    /// propagation without unwinding.
    Error,
    /// Sleep at the seam — exercises straggler/timeout paths (barrier
    /// eviction deadlines, swap-ack timeouts).
    Stall(Duration),
}

/// One parsed `seam[@scope]:action[@stepN]` directive plus its firing
/// state. Fires exactly once, at the `at`-th matching hit.
#[derive(Debug)]
struct Directive {
    seam: Seam,
    /// `None` matches any scope; `Some(s)` matches exactly.
    scope: Option<String>,
    action: Action,
    /// 1-based matching-hit ordinal at which to fire.
    at: u64,
    hits: AtomicU64,
    fired: AtomicBool,
}

impl Directive {
    fn matches(&self, seam: Seam, scope: &str) -> bool {
        self.seam == seam
            && match &self.scope {
                None => true,
                Some(s) => s == scope,
            }
    }
}

/// A set of fault directives. Parse once, [`install`] globally; seams
/// consult the installed plan through [`hit`].
#[derive(Debug, Default)]
pub struct Plan {
    directives: Vec<Directive>,
}

/// Parse a `stall(...)` duration: `200ms`, `2s`, `500us`, or a bare
/// number (milliseconds).
fn parse_duration(s: &str) -> Result<Duration> {
    let (num, mul_us) = if let Some(n) = s.strip_suffix("ms") {
        (n, 1_000u64)
    } else if let Some(n) = s.strip_suffix("us") {
        (n, 1u64)
    } else if let Some(n) = s.strip_suffix('s') {
        (n, 1_000_000u64)
    } else {
        (s, 1_000u64)
    };
    let v: u64 = num.trim().parse().map_err(|_| anyhow!("bad stall duration '{s}'"))?;
    Ok(Duration::from_micros(v.saturating_mul(mul_us)))
}

impl Plan {
    /// Parse a spec string (see the module docs for the grammar). An empty
    /// or whitespace-only spec is an empty plan (valid, injects nothing).
    pub fn parse(spec: &str) -> Result<Plan> {
        let mut directives = Vec::new();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (site, act) = part
                .split_once(':')
                .ok_or_else(|| anyhow!("fault directive '{part}': expected seam[@scope]:action"))?;
            let (seam_s, scope) = match site.split_once('@') {
                Some((s, sc)) => {
                    let sc = sc.trim();
                    if sc.is_empty() {
                        bail!("fault directive '{part}': empty scope after '@'");
                    }
                    (s.trim(), Some(sc.to_string()))
                }
                None => (site.trim(), None),
            };
            let seam = Seam::parse(seam_s).ok_or_else(|| {
                anyhow!(
                    "fault directive '{part}': unknown seam '{seam_s}' (expected one of \
                     batch_upload, dispatch, fetch, prefetch, barrier_send, barrier_recv, \
                     swap_ack, hedge, storage_get, storage_put)"
                )
            })?;
            let (action_s, at_s) = match act.split_once('@') {
                Some((a, n)) => (a.trim(), Some(n.trim())),
                None => (act.trim(), None),
            };
            let action = if action_s == "panic" {
                Action::Panic
            } else if action_s == "error" {
                Action::Error
            } else if let Some(rest) = action_s.strip_prefix("stall(") {
                let inner = rest.strip_suffix(')').ok_or_else(|| {
                    anyhow!("fault directive '{part}': unclosed stall(… duration")
                })?;
                Action::Stall(parse_duration(inner)?)
            } else {
                bail!(
                    "fault directive '{part}': unknown action '{action_s}' (expected panic, \
                     error, or stall(duration))"
                );
            };
            let at = match at_s {
                None => 1,
                Some(n) => {
                    let digits = n.strip_prefix("step").unwrap_or(n);
                    let v: u64 = digits
                        .parse()
                        .map_err(|_| anyhow!("fault directive '{part}': bad hit ordinal '{n}'"))?;
                    if v == 0 {
                        bail!("fault directive '{part}': hit ordinals are 1-based");
                    }
                    v
                }
            };
            directives.push(Directive {
                seam,
                scope,
                action,
                at,
                hits: AtomicU64::new(0),
                fired: AtomicBool::new(false),
            });
        }
        Ok(Plan { directives })
    }

    pub fn is_empty(&self) -> bool {
        self.directives.is_empty()
    }

    pub fn len(&self) -> usize {
        self.directives.len()
    }

    /// Record one hit of `seam`+`scope` against this plan and return the
    /// action to take, if any directive just reached its firing ordinal.
    /// Each directive fires at most once over the plan's lifetime.
    fn check(&self, seam: Seam, scope: &str) -> Option<(Action, String)> {
        for d in &self.directives {
            if !d.matches(seam, scope) {
                continue;
            }
            let hit = d.hits.fetch_add(1, Ordering::Relaxed) + 1;
            if hit == d.at && !d.fired.swap(true, Ordering::Relaxed) {
                let where_ = if scope.is_empty() {
                    seam.label().to_string()
                } else {
                    format!("{}@{}", seam.label(), scope)
                };
                return Some((d.action, format!("{where_} (hit {hit})")));
            }
        }
        None
    }
}

/// Installed-plan state behind the global handle.
struct Armed {
    plan: Plan,
    injected: Counter,
}

/// Fast-path arm flag: [`hit`] is one relaxed load + branch when this is
/// false — the whole injection plane compiled down to nothing.
static ARMED: AtomicBool = AtomicBool::new(false);

fn global() -> &'static RwLock<Option<Arc<Armed>>> {
    static GLOBAL: std::sync::OnceLock<RwLock<Option<Arc<Armed>>>> = std::sync::OnceLock::new();
    GLOBAL.get_or_init(|| RwLock::new(None))
}

/// Optional span recorder for fired injections, settable independently of
/// the plan (main wires it when both `--faults` and `--trace-out` are on).
fn global_tracer() -> &'static RwLock<Tracer> {
    static TRACER: std::sync::OnceLock<RwLock<Tracer>> = std::sync::OnceLock::new();
    TRACER.get_or_init(|| RwLock::new(Tracer::default()))
}

/// Install `plan` process-globally (replacing any previous plan and
/// resetting the fired-injection counter). An empty plan disarms the
/// seams entirely.
pub fn install(plan: Plan) {
    let mut g = global().write().expect("faults plan lock");
    if plan.is_empty() {
        ARMED.store(false, Ordering::Relaxed);
        *g = None;
    } else {
        *g = Some(Arc::new(Armed { plan, injected: Counter::new() }));
        ARMED.store(true, Ordering::Relaxed);
    }
}

/// Remove any installed plan; every seam returns to the disarmed
/// single-branch path.
pub fn clear() {
    let mut g = global().write().expect("faults plan lock");
    ARMED.store(false, Ordering::Relaxed);
    *g = None;
}

/// Install a plan parsed from the `LRTA_FAULTS` environment variable.
/// Returns `Ok(true)` if a non-empty plan was installed, `Ok(false)` when
/// the variable is unset/empty, `Err` on a malformed spec.
pub fn install_from_env() -> Result<bool> {
    match std::env::var("LRTA_FAULTS") {
        Ok(spec) if !spec.trim().is_empty() => {
            let plan = Plan::parse(&spec)?;
            let n = plan.len();
            install(plan);
            Ok(n > 0)
        }
        _ => Ok(false),
    }
}

/// Whether a non-empty plan is installed (the fast-path flag seams read).
#[inline]
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Attach a span recorder: every injection that fires records a
/// `faults/fault_injected` span (covering the stall duration for
/// [`Action::Stall`]). Independent of plan installation order.
pub fn set_tracer(tracer: Tracer) {
    *global_tracer().write().expect("faults tracer lock") = tracer;
}

/// Number of injections fired since the current plan was installed.
pub fn fired() -> u64 {
    global()
        .read()
        .expect("faults plan lock")
        .as_ref()
        .map(|a| a.injected.get())
        .unwrap_or(0)
}

/// Register the fired-injection counter under `faults/injected` so metric
/// exports carry the chaos accounting. No-op without an installed plan.
pub fn register_metrics(registry: &crate::obs::Registry) -> Result<()> {
    let g = global().read().expect("faults plan lock");
    if let Some(armed) = g.as_ref() {
        registry.register_counter("faults", "injected", &[], &armed.injected)?;
    }
    Ok(())
}

/// One seam hit: the single call sites thread through their chokepoints.
/// Disarmed cost is one relaxed atomic load and a branch. When a matching
/// directive reaches its ordinal this returns `Err` (action `error`),
/// sleeps (action `stall`), or panics (action `panic`).
#[inline]
pub fn hit(seam: Seam, scope: &str) -> Result<()> {
    if !ARMED.load(Ordering::Relaxed) {
        return Ok(());
    }
    hit_armed(seam, scope)
}

#[cold]
fn hit_armed(seam: Seam, scope: &str) -> Result<()> {
    let armed = {
        let g = global().read().expect("faults plan lock");
        match g.as_ref() {
            Some(a) => Arc::clone(a),
            None => return Ok(()),
        }
    };
    let Some((action, site)) = armed.plan.check(seam, scope) else {
        return Ok(());
    };
    armed.injected.inc();
    let tracer = global_tracer().read().expect("faults tracer lock").clone();
    let span = tracer.start();
    match action {
        Action::Stall(d) => {
            std::thread::sleep(d);
            tracer.end(span, "faults", "fault_injected");
            Ok(())
        }
        Action::Error => {
            tracer.end(span, "faults", "fault_injected");
            bail!("injected fault: error at {site}")
        }
        Action::Panic => {
            tracer.end(span, "faults", "fault_injected");
            panic!("injected fault: panic at {site}")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_grammar() {
        let p = Plan::parse("barrier_send@replica1:panic@step7,dispatch:stall(200ms)").unwrap();
        assert_eq!(p.len(), 2);
        let d = &p.directives[0];
        assert_eq!(d.seam, Seam::BarrierSend);
        assert_eq!(d.scope.as_deref(), Some("replica1"));
        assert_eq!(d.action, Action::Panic);
        assert_eq!(d.at, 7);
        let d = &p.directives[1];
        assert_eq!(d.seam, Seam::Dispatch);
        assert_eq!(d.scope, None);
        assert_eq!(d.action, Action::Stall(Duration::from_millis(200)));
        assert_eq!(d.at, 1);
    }

    #[test]
    fn parse_durations_and_ordinals() {
        let p = Plan::parse("fetch:stall(2s)@3, prefetch:stall(500us), swap_ack:stall(50)")
            .unwrap();
        assert_eq!(p.directives[0].action, Action::Stall(Duration::from_secs(2)));
        assert_eq!(p.directives[0].at, 3);
        assert_eq!(p.directives[1].action, Action::Stall(Duration::from_micros(500)));
        assert_eq!(p.directives[2].action, Action::Stall(Duration::from_millis(50)));
        // bare ordinal without the "step" prefix
        assert_eq!(Plan::parse("dispatch:error@4").unwrap().directives[0].at, 4);
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "dispatch",               // no action
            "nope:panic",             // unknown seam
            "dispatch:explode",       // unknown action
            "dispatch:stall(10ms",    // unclosed paren
            "dispatch:stall(x)",      // bad duration
            "dispatch:panic@step0",   // 0 ordinal (1-based)
            "dispatch:panic@stepx",   // bad ordinal
            "dispatch@:panic",        // empty scope
        ] {
            assert!(Plan::parse(bad).is_err(), "'{bad}' must be rejected");
        }
        assert!(Plan::parse("").unwrap().is_empty());
        assert!(Plan::parse(" , ,").unwrap().is_empty());
    }

    #[test]
    fn seam_labels_round_trip() {
        for seam in [
            Seam::BatchUpload,
            Seam::Dispatch,
            Seam::Fetch,
            Seam::Prefetch,
            Seam::BarrierSend,
            Seam::BarrierRecv,
            Seam::SwapAck,
            Seam::Hedge,
            Seam::StorageGet,
            Seam::StoragePut,
        ] {
            assert_eq!(Seam::parse(seam.label()), Some(seam));
        }
    }

    #[test]
    fn directive_fires_once_at_its_ordinal_with_scope_match() {
        let p = Plan::parse("dispatch@replica1:error@3").unwrap();
        // wrong scope never matches, and does not advance the ordinal
        for _ in 0..5 {
            assert!(p.check(Seam::Dispatch, "replica0").is_none());
        }
        assert!(p.check(Seam::Dispatch, "replica1").is_none()); // hit 1
        assert!(p.check(Seam::Fetch, "replica1").is_none()); // different seam
        assert!(p.check(Seam::Dispatch, "replica1").is_none()); // hit 2
        let (action, site) = p.check(Seam::Dispatch, "replica1").unwrap(); // hit 3
        assert_eq!(action, Action::Error);
        assert!(site.contains("dispatch@replica1"), "{site}");
        // exactly once: later hits never re-fire
        for _ in 0..5 {
            assert!(p.check(Seam::Dispatch, "replica1").is_none());
        }
    }

    #[test]
    fn wildcard_scope_matches_any() {
        let p = Plan::parse("prefetch:error@2").unwrap();
        assert!(p.check(Seam::Prefetch, "replica0").is_none());
        assert!(p.check(Seam::Prefetch, "replica1").is_some(), "2nd hit across scopes fires");
    }
}
