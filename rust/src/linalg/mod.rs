//! Numerical linear algebra built from scratch for the LRD engine:
//! one-sided Jacobi SVD (full and truncated) and Householder QR.
//!
//! Jacobi SVD was chosen over Golub-Kahan bidiagonalization because it is
//! simple, unconditionally stable, and accurate for the small-to-medium
//! matrices that appear as layer weights / Tucker unfoldings (up to a few
//! thousand on a side). Cost is O(m·n²) per sweep with ~6-10 sweeps.

use crate::tensor::Tensor;

/// Result of an SVD: `a ≈ u · diag(s) · vᵀ` with `u: [m, k]`, `s: [k]`,
/// `v: [n, k]`, singular values sorted descending.
#[derive(Clone, Debug)]
pub struct Svd {
    pub u: Tensor,
    pub s: Vec<f32>,
    pub v: Tensor,
}

impl Svd {
    /// Reconstruct u · diag(s) · vᵀ (optionally truncated to rank r).
    pub fn reconstruct(&self, r: usize) -> Tensor {
        let k = r.min(self.s.len());
        let m = self.u.shape()[0];
        let n = self.v.shape()[0];
        let mut out = Tensor::zeros(&[m, n]);
        for c in 0..k {
            let sv = self.s[c];
            if sv == 0.0 {
                continue;
            }
            for i in 0..m {
                let uis = self.u.at2(i, c) * sv;
                if uis == 0.0 {
                    continue;
                }
                for j in 0..n {
                    let cur = out.at2(i, j);
                    out.set2(i, j, cur + uis * self.v.at2(j, c));
                }
            }
        }
        out
    }

    /// Truncate to the leading r components: (U'·√Σ', √Σ'·V'ᵀ) is *not*
    /// what we return; we return the factors the paper uses:
    /// `u_r: [m, r]` (U'), `sv_r: [r]` (Σ'), `v_r: [n, r]` (V').
    pub fn truncate(&self, r: usize) -> Svd {
        let k = r.min(self.s.len());
        Svd {
            u: self.u.first_cols(k),
            s: self.s[..k].to_vec(),
            v: self.v.first_cols(k),
        }
    }
}

/// One-sided Jacobi SVD of `a: [m, n]`.
///
/// Works on columns of `a` (implicitly `aᵀa`), rotating column pairs until
/// orthogonal. For m < n we decompose the transpose and swap u/v.
pub fn svd(a: &Tensor) -> Svd {
    assert_eq!(a.ndim(), 2);
    let (m, n) = (a.shape()[0], a.shape()[1]);
    if m < n {
        let t = svd(&a.t());
        return Svd { u: t.v, s: t.s, v: t.u };
    }

    // Work in f64 for internal accuracy; weights are f32 but Gram-matrix
    // rotations accumulate error quickly in single precision.
    let mut u: Vec<f64> = a.data().iter().map(|&x| x as f64).collect(); // m×n, becomes U·Σ
    let mut v: Vec<f64> = vec![0.0; n * n]; // n×n accumulated rotations
    for i in 0..n {
        v[i * n + i] = 1.0;
    }

    let eps = 1e-12_f64;
    let max_sweeps = 30;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0_f64;
        for p in 0..n.saturating_sub(1) {
            for q in (p + 1)..n {
                // Gram entries for columns p, q
                let (mut app, mut aqq, mut apq) = (0.0_f64, 0.0_f64, 0.0_f64);
                for i in 0..m {
                    let up = u[i * n + p];
                    let uq = u[i * n + q];
                    app += up * up;
                    aqq += uq * uq;
                    apq += up * uq;
                }
                if apq.abs() <= eps * (app * aqq).sqrt() {
                    continue;
                }
                off = off.max(apq.abs() / (app * aqq).sqrt().max(1e-300));
                // Jacobi rotation zeroing the (p,q) Gram entry
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let up = u[i * n + p];
                    let uq = u[i * n + q];
                    u[i * n + p] = c * up - s * uq;
                    u[i * n + q] = s * up + c * uq;
                }
                for i in 0..n {
                    let vp = v[i * n + p];
                    let vq = v[i * n + q];
                    v[i * n + p] = c * vp - s * vq;
                    v[i * n + q] = s * vp + c * vq;
                }
            }
        }
        if off < 1e-10 {
            break;
        }
    }

    // Column norms of the rotated matrix are the singular values.
    let mut svals: Vec<(f64, usize)> = (0..n)
        .map(|j| {
            let norm2: f64 = (0..m).map(|i| u[i * n + j] * u[i * n + j]).sum();
            (norm2.sqrt(), j)
        })
        .collect();
    svals.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());

    let mut u_out = vec![0.0f32; m * n];
    let mut v_out = vec![0.0f32; n * n];
    let mut s_out = vec![0.0f32; n];
    for (newj, &(sv, oldj)) in svals.iter().enumerate() {
        s_out[newj] = sv as f32;
        let inv = if sv > 1e-30 { 1.0 / sv } else { 0.0 };
        for i in 0..m {
            u_out[i * n + newj] = (u[i * n + oldj] * inv) as f32;
        }
        for i in 0..n {
            v_out[i * n + newj] = v[i * n + oldj] as f32;
        }
    }

    Svd {
        u: Tensor::new(&[m, n], u_out),
        s: s_out,
        v: Tensor::new(&[n, n], v_out),
    }
}

/// Truncated SVD keeping the top-`r` components.
///
/// Dispatches on size:
/// - small matrices → one-sided Jacobi (most accurate),
/// - moderate, near-full-rank requests → Gram route (O(min(m,n)³)),
/// - large matrices with r ≪ min(m,n) → randomized range-finder SVD
///   (Halko-Martinsson-Tropp), which is what makes decomposing
///   ResNet-152-scale unfoldings take seconds, not minutes, on one core
///   (paper Table 2 reports 232 s for the whole model).
pub fn svd_truncated(a: &Tensor, r: usize) -> Svd {
    let (m, n) = (a.shape()[0], a.shape()[1]);
    let small = m.min(n);
    if small <= 48 {
        svd(a).truncate(r)
    } else if r + RSVD_OVERSAMPLE < small * 3 / 4 {
        svd_randomized(a, r, RSVD_OVERSAMPLE, 2)
    } else {
        svd_gram(a).truncate(r)
    }
}

/// Oversampling columns for the randomized range finder.
pub const RSVD_OVERSAMPLE: usize = 8;

/// Randomized truncated SVD (Halko et al. 2011, Algorithm 4.4/5.1):
/// range-finder `Y = (A Aᵀ)^q A Ω`, orthonormalize, project `B = Qᵀ A`,
/// exact SVD of the small `B`, lift back. Deterministic: the test matrix
/// Ω is seeded from the shape.
pub fn svd_randomized(a: &Tensor, r: usize, oversample: usize, power_iters: usize) -> Svd {
    use crate::util::rng::Rng;
    assert_eq!(a.ndim(), 2);
    let (m, n) = (a.shape()[0], a.shape()[1]);
    if m < n {
        let t = svd_randomized(&a.t(), r, oversample, power_iters);
        return Svd { u: t.v, s: t.s, v: t.u };
    }
    let k = (r + oversample).min(n).min(m);
    let mut rng = Rng::new(0x5EED ^ ((m as u64) << 32) ^ n as u64);
    let omega = Tensor::randn(&[n, k], 1.0, &mut rng);
    // Y = A Ω, with power iterations for spectral sharpening
    let mut y = a.matmul(&omega); // [m, k]
    for _ in 0..power_iters {
        // re-orthonormalize between powers for stability
        let (q, _) = qr(&y);
        let z = a.t().matmul(&q); // [n, k]
        let (qz, _) = qr(&z);
        y = a.matmul(&qz); // [m, k]
    }
    let (q, _) = qr(&y); // [m, k] orthonormal
    let b = q.t().matmul(a); // [k, n]
    // exact SVD of the small k×n matrix via the Gram route (k ≤ r+p)
    let bs = svd_gram(&b);
    let u = q.matmul(&bs.u); // [m, k]
    let svd_full = Svd { u, s: bs.s, v: bs.v };
    svd_full.truncate(r)
}

/// SVD via the Gram matrix of the smaller side.
///
/// For m ≤ n: `W·Wᵀ = U Λ Uᵀ`, `σᵢ = √λᵢ`, `V = Wᵀ U Σ⁻¹`.
/// Numerically this squares the condition number, which is fine for weight
/// matrices (condition numbers of trained layers are modest) and is the
/// standard trick every LRD implementation uses.
pub fn svd_gram(a: &Tensor) -> Svd {
    assert_eq!(a.ndim(), 2);
    let (m, n) = (a.shape()[0], a.shape()[1]);
    if m > n {
        let t = svd_gram(&a.t());
        return Svd { u: t.v, s: t.s, v: t.u };
    }
    // gram = a · aᵀ (m×m), in f64
    let ad = a.data();
    let mut gram = vec![0.0f64; m * m];
    for i in 0..m {
        for j in i..m {
            let mut acc = 0.0f64;
            let (ri, rj) = (&ad[i * n..(i + 1) * n], &ad[j * n..(j + 1) * n]);
            for k in 0..n {
                acc += ri[k] as f64 * rj[k] as f64;
            }
            gram[i * m + j] = acc;
            gram[j * m + i] = acc;
        }
    }
    let (mut evals, evecs) = sym_eig_jacobi(&gram, m);
    // eigenvalues of a Gram matrix are ≥ 0 up to roundoff
    for l in evals.iter_mut() {
        *l = l.max(0.0);
    }
    let mut u = vec![0.0f32; m * m];
    let mut s = vec![0.0f32; m];
    for j in 0..m {
        s[j] = (evals[j].sqrt()) as f32;
        for i in 0..m {
            u[i * m + j] = evecs[i * m + j] as f32;
        }
    }
    let u = Tensor::new(&[m, m], u);
    // V = aᵀ · U · Σ⁻¹  (n×m)
    let atu = a.t().matmul(&u); // [n, m]
    let mut v = vec![0.0f32; n * m];
    for j in 0..m {
        let inv = if s[j] > 1e-20 { 1.0 / s[j] } else { 0.0 };
        for i in 0..n {
            v[i * m + j] = atu.at2(i, j) * inv;
        }
    }
    Svd { u, s: s.to_vec(), v: Tensor::new(&[n, m], v) }
}

/// Cyclic Jacobi eigendecomposition of a symmetric matrix (f64, row-major
/// n×n). Returns (eigenvalues descending, eigenvectors as columns).
pub fn sym_eig_jacobi(a: &[f64], n: usize) -> (Vec<f64>, Vec<f64>) {
    assert_eq!(a.len(), n * n);
    let mut m = a.to_vec();
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    let max_sweeps = 30;
    for _ in 0..max_sweeps {
        // off-diagonal magnitude
        let mut off = 0.0f64;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[i * n + j] * m[i * n + j];
            }
        }
        let scale: f64 = (0..n).map(|i| m[i * n + i] * m[i * n + i]).sum::<f64>().max(1e-300);
        if off / scale < 1e-22 {
            break;
        }
        for p in 0..n - 1 {
            for q in (p + 1)..n {
                let apq = m[p * n + q];
                let app = m[p * n + p];
                let aqq = m[q * n + q];
                // threshold strategy: skip rotations that no longer matter —
                // cuts late sweeps to near-zero work
                if apq * apq <= 1e-24 * app.abs().max(1e-300) * aqq.abs().max(1e-300) {
                    continue;
                }
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                // rows/cols p and q
                for k in 0..n {
                    let mkp = m[k * n + p];
                    let mkq = m[k * n + q];
                    m[k * n + p] = c * mkp - s * mkq;
                    m[k * n + q] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[p * n + k];
                    let mqk = m[q * n + k];
                    m[p * n + k] = c * mpk - s * mqk;
                    m[q * n + k] = s * mpk + c * mqk;
                }
                for k in 0..n {
                    let vkp = v[k * n + p];
                    let vkq = v[k * n + q];
                    v[k * n + p] = c * vkp - s * vkq;
                    v[k * n + q] = s * vkp + c * vkq;
                }
            }
        }
    }
    // sort descending by eigenvalue
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| m[j * n + j].partial_cmp(&m[i * n + i]).unwrap());
    let evals: Vec<f64> = order.iter().map(|&i| m[i * n + i]).collect();
    let mut evecs = vec![0.0f64; n * n];
    for (newj, &oldj) in order.iter().enumerate() {
        for i in 0..n {
            evecs[i * n + newj] = v[i * n + oldj];
        }
    }
    (evals, evecs)
}

/// Householder QR: `a = q · r` with `q: [m, k]` orthonormal columns,
/// `r: [k, n]` upper triangular, k = min(m, n).
///
/// Thin form throughout: reflectors are stored and then applied to the
/// first k identity columns, so cost is O(m·n·k) with no m×m Q — this is
/// on the randomized-SVD hot path for [4608, r] panels.
pub fn qr(a: &Tensor) -> (Tensor, Tensor) {
    assert_eq!(a.ndim(), 2);
    let (m, n) = (a.shape()[0], a.shape()[1]);
    let k = m.min(n);
    // Column-major working copy: every reflector touches contiguous column
    // slices (the row-major variant walks stride-n and is ~20x slower on
    // the tall panels the randomized SVD feeds us).
    let ad = a.data();
    let mut cols: Vec<Vec<f64>> = (0..n)
        .map(|j| (0..m).map(|i| ad[i * n + j] as f64).collect())
        .collect();
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(k);
    let mut vnorm2s: Vec<f64> = Vec::with_capacity(k);

    for col in 0..k {
        let norm2: f64 = cols[col][col..].iter().map(|x| x * x).sum();
        let norm = norm2.sqrt();
        if norm < 1e-300 {
            vs.push(Vec::new());
            vnorm2s.push(0.0);
            continue;
        }
        let alpha = if cols[col][col] > 0.0 { -norm } else { norm };
        let mut v: Vec<f64> = cols[col][col..].to_vec();
        v[0] -= alpha;
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 < 1e-300 {
            vs.push(Vec::new());
            vnorm2s.push(0.0);
            continue;
        }
        for c in cols.iter_mut().skip(col) {
            let seg = &mut c[col..];
            let mut dot = 0.0_f64;
            for (x, vi) in seg.iter().zip(&v) {
                dot += x * vi;
            }
            let f = 2.0 * dot / vnorm2;
            for (x, vi) in seg.iter_mut().zip(&v) {
                *x -= f * vi;
            }
        }
        vs.push(v);
        vnorm2s.push(vnorm2);
    }

    // Q_thin = H_0 · H_1 ··· H_{k-1} · [I_k; 0], applied in reverse,
    // also column-major.
    let mut qcols: Vec<Vec<f64>> = (0..k)
        .map(|j| {
            let mut c = vec![0.0f64; m];
            c[j] = 1.0;
            c
        })
        .collect();
    for col in (0..k).rev() {
        let v = &vs[col];
        let vnorm2 = vnorm2s[col];
        if v.is_empty() || vnorm2 == 0.0 {
            continue;
        }
        for qc in qcols.iter_mut() {
            let seg = &mut qc[col..];
            let mut dot = 0.0_f64;
            for (x, vi) in seg.iter().zip(v) {
                dot += x * vi;
            }
            let f = 2.0 * dot / vnorm2;
            for (x, vi) in seg.iter_mut().zip(v) {
                *x -= f * vi;
            }
        }
    }

    let mut q_out = vec![0.0f32; m * k];
    for (j, qc) in qcols.iter().enumerate() {
        for i in 0..m {
            q_out[i * k + j] = qc[i] as f32;
        }
    }
    let mut r_out = vec![0.0f32; k * n];
    for (j, c) in cols.iter().enumerate() {
        for i in 0..k.min(j + 1) {
            r_out[i * n + j] = c[i] as f32;
        }
    }
    (Tensor::new(&[m, k], q_out), Tensor::new(&[k, n], r_out))
}

/// ‖aᵀa − I‖∞ over the columns of `a` — orthogonality defect, used in tests.
pub fn orthogonality_defect(a: &Tensor) -> f32 {
    let g = a.t().matmul(a);
    let n = g.shape()[0];
    let mut worst = 0.0f32;
    for i in 0..n {
        for j in 0..n {
            let target = if i == j { 1.0 } else { 0.0 };
            worst = worst.max((g.at2(i, j) - target).abs());
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn svd_reconstructs_random_matrix() {
        let mut r = Rng::new(10);
        let a = Tensor::randn(&[12, 8], 1.0, &mut r);
        let d = svd(&a);
        let rec = d.reconstruct(8);
        assert!(a.max_abs_diff(&rec) < 1e-4, "err {}", a.max_abs_diff(&rec));
    }

    #[test]
    fn svd_wide_matrix() {
        let mut r = Rng::new(11);
        let a = Tensor::randn(&[6, 14], 1.0, &mut r);
        let d = svd(&a);
        assert_eq!(d.u.shape(), &[6, 6]);
        assert_eq!(d.v.shape(), &[14, 6]);
        assert!(a.max_abs_diff(&d.reconstruct(6)) < 1e-4);
    }

    #[test]
    fn singular_values_sorted_nonnegative() {
        let mut r = Rng::new(12);
        let a = Tensor::randn(&[10, 10], 1.0, &mut r);
        let d = svd(&a);
        for w in d.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-6);
        }
        assert!(d.s.iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn factors_are_orthonormal() {
        let mut r = Rng::new(13);
        let a = Tensor::randn(&[15, 9], 1.0, &mut r);
        let d = svd(&a);
        assert!(orthogonality_defect(&d.u) < 1e-4);
        assert!(orthogonality_defect(&d.v) < 1e-4);
    }

    #[test]
    fn svd_of_known_diagonal() {
        let a = Tensor::new(&[2, 2], vec![3.0, 0.0, 0.0, 2.0]);
        let d = svd(&a);
        assert!((d.s[0] - 3.0).abs() < 1e-5);
        assert!((d.s[1] - 2.0).abs() < 1e-5);
    }

    #[test]
    fn truncation_error_equals_tail_energy() {
        // For the best rank-r approximation, ‖A - A_r‖²_F = Σ_{i>r} σ_i²
        // (Eckart-Young). Verifies both the SVD and reconstruct().
        let mut rng = Rng::new(14);
        let a = Tensor::randn(&[10, 6], 1.0, &mut rng);
        let d = svd(&a);
        for r in 1..6 {
            let rec = d.reconstruct(r);
            let err = a.dist2(&rec) as f64;
            let tail: f64 = d.s[r..].iter().map(|&s| (s as f64) * (s as f64)).sum();
            assert!(
                (err - tail).abs() < 1e-3 * tail.max(1e-6),
                "r={r} err={err} tail={tail}"
            );
        }
    }

    #[test]
    fn rank_deficient_matrix() {
        // outer product has rank 1: second singular value ~ 0
        let u = Tensor::new(&[4, 1], vec![1.0, 2.0, 3.0, 4.0]);
        let v = Tensor::new(&[1, 3], vec![1.0, -1.0, 0.5]);
        let a = u.matmul(&v);
        let d = svd(&a);
        assert!(d.s[0] > 1.0);
        assert!(d.s[1].abs() < 1e-5);
        assert!(a.max_abs_diff(&d.reconstruct(1)) < 1e-5);
    }

    #[test]
    fn truncate_shapes() {
        let mut r = Rng::new(15);
        let a = Tensor::randn(&[8, 8], 1.0, &mut r);
        let d = svd_truncated(&a, 3);
        assert_eq!(d.u.shape(), &[8, 3]);
        assert_eq!(d.s.len(), 3);
        assert_eq!(d.v.shape(), &[8, 3]);
    }

    #[test]
    fn qr_reconstructs_and_q_orthonormal() {
        let mut rng = Rng::new(16);
        let a = Tensor::randn(&[10, 6], 1.0, &mut rng);
        let (q, r) = qr(&a);
        assert_eq!(q.shape(), &[10, 6]);
        assert_eq!(r.shape(), &[6, 6]);
        assert!(orthogonality_defect(&q) < 1e-5);
        assert!(a.max_abs_diff(&q.matmul(&r)) < 1e-4);
        // R upper triangular
        for i in 0..6 {
            for j in 0..i {
                assert!(r.at2(i, j).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn gram_svd_matches_jacobi() {
        let mut rng = Rng::new(18);
        let a = Tensor::randn(&[60, 90], 1.0, &mut rng);
        let g = svd_gram(&a);
        let j = svd(&a);
        for (x, y) in g.s.iter().zip(&j.s) {
            assert!((x - y).abs() < 1e-3 * y.max(1.0), "{x} vs {y}");
        }
        assert!(a.max_abs_diff(&g.reconstruct(60)) < 1e-3);
        assert!(orthogonality_defect(&g.u) < 1e-3);
    }

    #[test]
    fn gram_svd_tall_matrix() {
        let mut rng = Rng::new(19);
        let a = Tensor::randn(&[100, 50], 1.0, &mut rng);
        let g = svd_gram(&a);
        assert_eq!(g.u.shape(), &[100, 50]);
        assert_eq!(g.v.shape(), &[50, 50]);
        assert!(a.max_abs_diff(&g.reconstruct(50)) < 1e-3);
    }

    #[test]
    fn svd_truncated_dispatches_consistently() {
        // verify both code paths approximate equally well at rank r
        let mut rng = Rng::new(28);
        let small = Tensor::randn(&[30, 40], 1.0, &mut rng); // jacobi path
        let large = Tensor::randn(&[64, 200], 1.0, &mut rng); // gram path
        for (a, r) in [(&small, 10usize), (&large, 20usize)] {
            let d = svd_truncated(a, r);
            assert_eq!(d.u.shape()[1], r);
            let err = a.dist2(&d.reconstruct(r));
            // must track the Eckart-Young tail (rSVD on a flat random
            // spectrum — its worst case — lands within a few percent)
            let full = svd(a);
            let tail: f32 = full.s[r..].iter().map(|s| s * s).sum();
            assert!(err <= tail * 1.06 && err >= tail * 0.99, "err {err} tail {tail}");
        }
    }

    #[test]
    fn randomized_svd_matches_exact_on_lowrank() {
        // A with true rank 10: rSVD at r=10 must reconstruct ~exactly.
        let mut rng = Rng::new(31);
        let u = Tensor::randn(&[120, 10], 1.0, &mut rng);
        let v = Tensor::randn(&[10, 80], 1.0, &mut rng);
        let a = u.matmul(&v);
        let d = svd_randomized(&a, 10, 8, 2);
        assert!(a.max_abs_diff(&d.reconstruct(10)) < 1e-2 * a.norm());
    }

    #[test]
    fn randomized_svd_near_eckart_young() {
        let mut rng = Rng::new(32);
        let a = Tensor::randn(&[100, 140], 1.0, &mut rng);
        let exact = svd_gram(&a);
        let r = 20;
        let rd = svd_randomized(&a, r, 8, 2);
        let err_rand = a.dist2(&rd.reconstruct(r)) as f64;
        let tail: f64 = exact.s[r..].iter().map(|&s| (s as f64) * (s as f64)).sum();
        // random gaussian spectra are flat — rSVD overshoots the optimum a
        // bit; must stay within a modest factor
        assert!(err_rand <= tail * 1.25, "err {err_rand} vs tail {tail}");
        // top singular values agree closely
        for j in 0..5 {
            assert!((rd.s[j] - exact.s[j]).abs() < 0.05 * exact.s[j], "σ{j}");
        }
    }

    #[test]
    fn randomized_svd_wide_matrix() {
        let mut rng = Rng::new(33);
        let a = Tensor::randn(&[60, 200], 1.0, &mut rng);
        let d = svd_randomized(&a, 12, 8, 1);
        assert_eq!(d.u.shape(), &[60, 12]);
        assert_eq!(d.v.shape(), &[200, 12]);
        assert!(orthogonality_defect(&d.u) < 1e-3);
        assert!(orthogonality_defect(&d.v) < 1e-3);
    }

    #[test]
    fn sym_eig_identity_and_diag() {
        let (evals, _) = sym_eig_jacobi(&[2.0, 0.0, 0.0, 1.0], 2);
        assert!((evals[0] - 2.0).abs() < 1e-12);
        assert!((evals[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn sym_eig_reconstructs() {
        let mut rng = Rng::new(29);
        let n = 12;
        let b = Tensor::randn(&[n, n], 1.0, &mut rng);
        let sym = b.t().matmul(&b); // SPD
        let a: Vec<f64> = sym.data().iter().map(|&x| x as f64).collect();
        let (evals, evecs) = sym_eig_jacobi(&a, n);
        // A ≈ V Λ Vᵀ
        for i in 0..n {
            for j in 0..n {
                let mut acc = 0.0;
                for k in 0..n {
                    acc += evecs[i * n + k] * evals[k] * evecs[j * n + k];
                }
                assert!((acc - a[i * n + j]).abs() < 1e-6 * evals[0].max(1.0));
            }
        }
        // descending, nonnegative for SPD
        for w in evals.windows(2) {
            assert!(w[0] >= w[1] - 1e-9);
        }
        assert!(evals[n - 1] >= -1e-6);
    }

    #[test]
    fn qr_wide() {
        let mut rng = Rng::new(17);
        let a = Tensor::randn(&[4, 7], 1.0, &mut rng);
        let (q, r) = qr(&a);
        assert_eq!(q.shape(), &[4, 4]);
        assert_eq!(r.shape(), &[4, 7]);
        assert!(a.max_abs_diff(&q.matmul(&r)) < 1e-4);
    }
}
