//! Run metrics: throughput meters (the paper reports fps = images/second),
//! per-epoch training records, and report assembly helpers.
//!
//! [`ThroughputMeter`] is the shared timing primitive — training engines
//! record one sample per step, serving benches one per batch — and reports
//! both the paper-style median-based fps (robust to warmup/straggler
//! outliers) and a mean-based fps that pays for them. [`RunRecord`] /
//! [`EpochRecord`] carry a fine-tune's loss/accuracy trajectory, power the
//! Fig.-3 convergence comparison (`epochs_to_reach`) and serialize to the
//! CSV curves under `results/fig3_curves/`. Multi-replica runs fold one
//! combined record out of per-shard stats (`train::replica`), so every
//! consumer of a [`RunRecord`] works unchanged at N replicas.

use crate::util::stats::Summary;
use std::time::Instant;

/// Accumulates per-step wall times and computes throughput.
#[derive(Clone, Debug, Default)]
pub struct ThroughputMeter {
    step_secs: Vec<f64>,
    items_per_step: usize,
}

impl ThroughputMeter {
    pub fn new(items_per_step: usize) -> Self {
        ThroughputMeter { step_secs: Vec::new(), items_per_step }
    }

    pub fn record(&mut self, secs: f64) {
        self.step_secs.push(secs);
    }

    /// Time a closure as one step.
    pub fn timed<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record(t0.elapsed().as_secs_f64());
        out
    }

    pub fn steps(&self) -> usize {
        self.step_secs.len()
    }

    /// Median step time in seconds (robust to warmup outliers).
    pub fn median_step(&self) -> f64 {
        if self.step_secs.is_empty() {
            return f64::NAN;
        }
        Summary::of(&self.step_secs).median
    }

    /// Throughput in items/second, paper-style "Speed (fps)", computed
    /// from the median step time. An empty (or zero-duration) meter reports
    /// 0.0 rather than NaN/∞ so report columns stay finite.
    pub fn fps(&self) -> f64 {
        if self.step_secs.is_empty() {
            return 0.0;
        }
        let med = self.median_step();
        if med > 0.0 {
            self.items_per_step as f64 / med
        } else {
            0.0
        }
    }

    /// Mean fps over the whole run (paper: "average time per step over an
    /// epoch as a measure of throughput"). 0.0 on an empty meter (no
    /// division by a zero total).
    pub fn mean_fps(&self) -> f64 {
        let total: f64 = self.step_secs.iter().sum();
        if total > 0.0 {
            (self.steps() * self.items_per_step) as f64 / total
        } else {
            0.0
        }
    }

    pub fn summary(&self) -> Summary {
        Summary::of(&self.step_secs)
    }
}

/// One epoch of a training run.
#[derive(Clone, Debug)]
pub struct EpochRecord {
    pub epoch: usize,
    pub loss: f64,
    pub train_acc: f64,
    pub test_acc: f64,
    /// Median train-step time this epoch (s).
    pub step_secs: f64,
    pub freeze_pattern: String,
}

/// One replica eviction performed by the data-parallel coordinator — the
/// exact degraded-membership accounting a [`RunRecord`] carries when a
/// run finished on fewer replicas than it started with.
#[derive(Clone, Debug)]
pub struct EvictionRecord {
    /// Evicted replica index — also its shard index: that shard's
    /// remaining batches are lost for the rest of the run.
    pub replica: usize,
    /// Global averaging-event ordinal the fleet was blocked on when the
    /// eviction happened (0 = outside any open barrier).
    pub event: u64,
    /// Last liveness beacon received: the epoch the replica had
    /// definitely reached.
    pub last_epoch: usize,
    /// Step within `last_epoch` of that last beacon.
    pub last_step: usize,
    /// Why the coordinator evicted: the replica's own death report, or
    /// the barrier-deadline diagnosis for a straggler.
    pub reason: String,
    /// Live replicas remaining after this eviction.
    pub survivors: usize,
}

/// A full training run record (powers Fig. 3 / Tables 3-4 rows).
#[derive(Clone, Debug, Default)]
pub struct RunRecord {
    pub name: String,
    pub epochs: Vec<EpochRecord>,
    /// Replica evictions, in order — empty for a healthy run. Epoch rows
    /// after an eviction fold survivor shards only.
    pub evictions: Vec<EvictionRecord>,
}

impl RunRecord {
    pub fn new(name: impl Into<String>) -> Self {
        RunRecord { name: name.into(), epochs: Vec::new(), evictions: Vec::new() }
    }

    /// Whether the run finished on degraded membership.
    pub fn degraded(&self) -> bool {
        !self.evictions.is_empty()
    }

    pub fn final_test_acc(&self) -> f64 {
        self.epochs.last().map(|e| e.test_acc).unwrap_or(f64::NAN)
    }

    pub fn best_test_acc(&self) -> f64 {
        self.epochs.iter().map(|e| e.test_acc).fold(f64::NAN, f64::max)
    }

    /// First epoch reaching `acc`, or None (paper's convergence-speed
    /// comparison in Fig. 3).
    pub fn epochs_to_reach(&self, acc: f64) -> Option<usize> {
        self.epochs.iter().find(|e| e.test_acc >= acc).map(|e| e.epoch)
    }

    /// Median train fps across epochs (items = batch).
    pub fn median_step_secs(&self) -> f64 {
        let xs: Vec<f64> = self.epochs.iter().map(|e| e.step_secs).collect();
        if xs.is_empty() {
            return f64::NAN;
        }
        Summary::of(&xs).median
    }

    /// CSV of the accuracy curve (for figures).
    pub fn curve_csv(&self) -> String {
        let mut s = String::from("epoch,loss,train_acc,test_acc,step_secs,pattern\n");
        for e in &self.epochs {
            s.push_str(&format!(
                "{},{:.5},{:.4},{:.4},{:.6},{}\n",
                e.epoch, e.loss, e.train_acc, e.test_acc, e.step_secs, e.freeze_pattern
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fps_is_items_over_median_step() {
        let mut m = ThroughputMeter::new(64);
        for t in [0.1, 0.1, 0.1, 0.5] {
            m.record(t);
        }
        assert!((m.median_step() - 0.1).abs() < 1e-12);
        assert!((m.fps() - 640.0).abs() < 1e-9);
        assert_eq!(m.steps(), 4);
    }

    #[test]
    fn mean_fps_accounts_total_time() {
        let mut m = ThroughputMeter::new(10);
        m.record(1.0);
        m.record(3.0);
        assert!((m.mean_fps() - 5.0).abs() < 1e-12); // 20 items / 4 s
    }

    #[test]
    fn empty_meter_reports_zero_not_nan() {
        let m = ThroughputMeter::new(64);
        assert_eq!(m.steps(), 0);
        assert_eq!(m.fps(), 0.0);
        assert_eq!(m.mean_fps(), 0.0);
        assert!(m.median_step().is_nan()); // documented empty sentinel
    }

    #[test]
    fn single_step_meter() {
        let mut m = ThroughputMeter::new(32);
        m.record(0.5);
        assert!((m.fps() - 64.0).abs() < 1e-12);
        assert!((m.mean_fps() - 64.0).abs() < 1e-12);
        assert!((m.median_step() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn outlier_heavy_meter_stays_robust() {
        let mut m = ThroughputMeter::new(10);
        for _ in 0..9 {
            m.record(0.01);
        }
        m.record(10.0); // pathological straggler
        // median-based fps ignores the outlier ...
        assert!((m.fps() - 1000.0).abs() < 1e-9);
        // ... mean-based fps pays for it
        assert!(m.mean_fps() < 10.0);
        // zero-duration steps must not produce ∞
        let mut z = ThroughputMeter::new(10);
        z.record(0.0);
        assert_eq!(z.fps(), 0.0);
        assert_eq!(z.mean_fps(), 0.0);
    }

    #[test]
    fn timed_records() {
        let mut m = ThroughputMeter::new(1);
        let v = m.timed(|| 42);
        assert_eq!(v, 42);
        assert_eq!(m.steps(), 1);
        assert!(m.median_step() >= 0.0);
    }

    fn rec(epoch: usize, acc: f64) -> EpochRecord {
        EpochRecord {
            epoch,
            loss: 1.0,
            train_acc: acc,
            test_acc: acc,
            step_secs: 0.1,
            freeze_pattern: "a".into(),
        }
    }

    #[test]
    fn run_record_queries() {
        let mut r = RunRecord::new("x");
        r.epochs.push(rec(0, 0.5));
        r.epochs.push(rec(1, 0.8));
        r.epochs.push(rec(2, 0.75));
        assert_eq!(r.final_test_acc(), 0.75);
        assert_eq!(r.best_test_acc(), 0.8);
        assert_eq!(r.epochs_to_reach(0.8), Some(1));
        assert_eq!(r.epochs_to_reach(0.9), None);
        assert!((r.median_step_secs() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn curve_csv_has_header_and_rows() {
        let mut r = RunRecord::new("x");
        r.epochs.push(rec(0, 0.5));
        let csv = r.curve_csv();
        assert!(csv.starts_with("epoch,"));
        assert_eq!(csv.lines().count(), 2);
    }
}
