//! Typed view over `artifacts/manifest.json` (written by python/compile/aot.py).
//!
//! The manifest is the contract between the build-time python layer and the
//! rust runtime: artifact names → HLO files, ordered parameter signatures
//! (trainable / frozen), data shapes, and each variant's decomposition
//! config (layer kinds + ranks) so the rust LRD engine factorizes with
//! exactly the ranks the artifacts were lowered for.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// A named tensor slot in an artifact signature.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSlot {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSlot {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-lowered executable.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    /// HLO text file, relative to the manifest directory.
    pub path: PathBuf,
    pub model: String,
    pub variant: String,
    /// "train" | "infer"
    pub kind: String,
    /// freeze pattern this step was lowered for: "none" | "a" | "b"
    pub freeze: String,
    pub batch: usize,
    pub trainable: Vec<ParamSlot>,
    pub frozen: Vec<ParamSlot>,
    /// data input shapes: x always, y for train artifacts.
    pub x_shape: Vec<usize>,
    pub y_shape: Option<Vec<usize>>,
}

impl ArtifactMeta {
    pub fn is_train(&self) -> bool {
        self.kind == "train"
    }
    /// Total number of executable inputs (params [+frozen+momenta] + data).
    pub fn input_arity(&self) -> usize {
        if self.is_train() {
            // trainable + frozen + momenta + x + y + lr
            2 * self.trainable.len() + self.frozen.len() + 3
        } else {
            self.trainable.len() + self.frozen.len() + 1
        }
    }
}

/// Decomposition config for one layer of a variant (mirrors python).
#[derive(Clone, Debug, PartialEq)]
pub enum LayerCfg {
    Dense,
    Svd { rank: usize, r_min: usize },
    Tucker { r1: usize, r2: usize, r_min: usize },
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub alpha: f64,
    pub tile: usize,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
    /// `{model}_{variant}` → per-layer config.
    pub configs: BTreeMap<String, BTreeMap<String, LayerCfg>>,
    /// model → init checkpoint path (relative to `dir`).
    pub init_checkpoints: BTreeMap<String, PathBuf>,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        let dir = path.parent().unwrap_or(Path::new(".")).to_path_buf();
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let root = Json::parse(text).map_err(|e| anyhow!("manifest json: {e}"))?;
        if root.get("version").as_i64() != Some(1) {
            bail!("unsupported manifest version");
        }
        let mut artifacts = BTreeMap::new();
        for a in root.get("artifacts").as_arr().unwrap_or(&[]) {
            let meta = parse_artifact(a)?;
            artifacts.insert(meta.name.clone(), meta);
        }
        let mut configs = BTreeMap::new();
        if let Some(obj) = root.get("configs").as_obj() {
            for (key, cfg) in obj {
                configs.insert(key.clone(), parse_config(cfg)?);
            }
        }
        let mut init_checkpoints = BTreeMap::new();
        if let Some(obj) = root.get("init_checkpoints").as_obj() {
            for (model, p) in obj {
                let rel = p.as_str().ok_or_else(|| anyhow!("bad init ckpt"))?;
                init_checkpoints.insert(model.clone(), PathBuf::from(rel));
            }
        }
        Ok(Manifest {
            dir,
            alpha: root.get("alpha").as_f64().unwrap_or(2.0),
            tile: root.get("tile").as_usize().unwrap_or(16),
            artifacts,
            configs,
            init_checkpoints,
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))
    }

    /// Absolute path of an artifact's HLO file.
    pub fn hlo_path(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.path)
    }

    pub fn config(&self, model: &str, variant: &str) -> Result<&BTreeMap<String, LayerCfg>> {
        let key = format!("{model}_{variant}");
        self.configs
            .get(&key)
            .ok_or_else(|| anyhow!("config '{key}' not in manifest"))
    }

    pub fn init_checkpoint(&self, model: &str) -> Result<PathBuf> {
        self.init_checkpoints
            .get(model)
            .map(|p| self.dir.join(p))
            .ok_or_else(|| anyhow!("no init checkpoint for '{model}'"))
    }

    /// Artifact naming convention helper.
    pub fn name_of(model: &str, variant: &str, kind: &str, freeze: &str) -> String {
        match kind {
            "infer" => format!("{model}_{variant}_infer"),
            _ => format!("{model}_{variant}_train_{freeze}"),
        }
    }
}

fn parse_slots(j: &Json) -> Result<Vec<ParamSlot>> {
    let mut out = Vec::new();
    for e in j.as_arr().unwrap_or(&[]) {
        let name = e.get("name").as_str().ok_or_else(|| anyhow!("slot name"))?;
        let shape = e
            .get("shape")
            .as_arr()
            .ok_or_else(|| anyhow!("slot shape"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        out.push(ParamSlot { name: name.to_string(), shape });
    }
    Ok(out)
}

fn parse_artifact(a: &Json) -> Result<ArtifactMeta> {
    let name = a.get("name").as_str().ok_or_else(|| anyhow!("artifact name"))?;
    let x_shape = a
        .get("data")
        .get("x")
        .as_arr()
        .ok_or_else(|| anyhow!("artifact {name}: data.x"))?
        .iter()
        .filter_map(|d| d.as_usize())
        .collect();
    let y_shape = a
        .get("data")
        .get("y")
        .as_arr()
        .map(|arr| arr.iter().filter_map(|d| d.as_usize()).collect());
    Ok(ArtifactMeta {
        name: name.to_string(),
        path: PathBuf::from(
            a.get("path").as_str().ok_or_else(|| anyhow!("artifact path"))?,
        ),
        model: a.get("model").as_str().unwrap_or("").to_string(),
        variant: a.get("variant").as_str().unwrap_or("").to_string(),
        kind: a.get("kind").as_str().unwrap_or("").to_string(),
        freeze: a.get("freeze").as_str().unwrap_or("none").to_string(),
        batch: a.get("batch").as_usize().unwrap_or(0),
        trainable: parse_slots(a.get("trainable"))?,
        frozen: parse_slots(a.get("frozen"))?,
        x_shape,
        y_shape,
    })
}

fn parse_config(cfg: &Json) -> Result<BTreeMap<String, LayerCfg>> {
    let mut out = BTreeMap::new();
    let obj = cfg.as_obj().ok_or_else(|| anyhow!("config not an object"))?;
    for (layer, lcfg) in obj {
        let kind = lcfg.get("kind").as_str().unwrap_or("dense");
        let parsed = match kind {
            "dense" => LayerCfg::Dense,
            "svd" => LayerCfg::Svd {
                rank: lcfg.get("rank").as_usize().ok_or_else(|| anyhow!("svd rank"))?,
                r_min: lcfg.get("r_min").as_usize().unwrap_or(1),
            },
            "tucker" => LayerCfg::Tucker {
                r1: lcfg.get("r1").as_usize().ok_or_else(|| anyhow!("tucker r1"))?,
                r2: lcfg.get("r2").as_usize().ok_or_else(|| anyhow!("tucker r2"))?,
                r_min: lcfg.get("r_min").as_usize().unwrap_or(1),
            },
            other => bail!("unknown layer kind {other}"),
        };
        out.insert(layer.clone(), parsed);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "alpha": 2.0, "tile": 16,
      "artifacts": [
        {"name": "m_lrd_train_a", "path": "m_lrd_train_a.hlo.txt",
         "model": "m", "variant": "lrd", "kind": "train", "freeze": "a",
         "batch": 64,
         "trainable": [{"name": "l.b", "shape": [4, 8]}],
         "frozen": [{"name": "l.a", "shape": [16, 4]}],
         "data": {"x": [64, 32, 32, 3], "y": [64]},
         "outputs": []},
        {"name": "m_lrd_infer", "path": "m_lrd_infer.hlo.txt",
         "model": "m", "variant": "lrd", "kind": "infer", "freeze": "none",
         "batch": 128,
         "trainable": [{"name": "l.a", "shape": [16, 4]},
                        {"name": "l.b", "shape": [4, 8]}],
         "frozen": [],
         "data": {"x": [128, 32, 32, 3]},
         "outputs": []}
      ],
      "configs": {
        "m_lrd": {"l": {"kind": "svd", "rank": 4, "r_min": 2},
                   "c": {"kind": "tucker", "r1": 3, "r2": 3, "r_min": 2},
                   "d": {"kind": "dense"}}
      },
      "init_checkpoints": {"m": "m_init.bin"}
    }"#;

    #[test]
    fn parses_artifacts() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/arts")).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let a = m.artifact("m_lrd_train_a").unwrap();
        assert!(a.is_train());
        assert_eq!(a.trainable[0].name, "l.b");
        assert_eq!(a.frozen[0].shape, vec![16, 4]);
        assert_eq!(a.y_shape.as_deref(), Some(&[64usize][..]));
        // 1 trainable + 1 frozen + 1 momentum + x + y + lr
        assert_eq!(a.input_arity(), 6);
    }

    #[test]
    fn infer_arity() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/arts")).unwrap();
        let a = m.artifact("m_lrd_infer").unwrap();
        assert!(!a.is_train());
        assert_eq!(a.input_arity(), 3); // 2 params + x
    }

    #[test]
    fn parses_configs() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/arts")).unwrap();
        let cfg = m.config("m", "lrd").unwrap();
        assert_eq!(cfg["l"], LayerCfg::Svd { rank: 4, r_min: 2 });
        assert_eq!(cfg["c"], LayerCfg::Tucker { r1: 3, r2: 3, r_min: 2 });
        assert_eq!(cfg["d"], LayerCfg::Dense);
    }

    #[test]
    fn paths_resolve_against_dir() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/arts")).unwrap();
        let a = m.artifact("m_lrd_infer").unwrap();
        assert_eq!(m.hlo_path(a), PathBuf::from("/arts/m_lrd_infer.hlo.txt"));
        assert_eq!(m.init_checkpoint("m").unwrap(), PathBuf::from("/arts/m_init.bin"));
    }

    #[test]
    fn missing_artifact_errors() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/arts")).unwrap();
        assert!(m.artifact("nope").is_err());
        assert!(m.config("m", "nope").is_err());
        assert!(m.init_checkpoint("nope").is_err());
    }

    #[test]
    fn name_convention() {
        assert_eq!(Manifest::name_of("m", "lrd", "infer", "none"), "m_lrd_infer");
        assert_eq!(Manifest::name_of("m", "lrd", "train", "b"), "m_lrd_train_b");
    }

    #[test]
    fn numel() {
        let s = ParamSlot { name: "x".into(), shape: vec![2, 3, 4] };
        assert_eq!(s.numel(), 24);
    }
}
