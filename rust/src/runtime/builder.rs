//! XlaBuilder computation factory for the rank optimizer's layer
//! micro-benchmarks.
//!
//! Algorithm 1 times a layer at *every* rank in `[R_min, R]`; AOT-lowering a
//! python artifact per rank would be absurd, so the coordinator constructs
//! the layer computation directly with the `XlaBuilder` — no python anywhere
//! near the loop, which is also what makes the method platform-agnostic
//! (the same builder calls compile for CPU/GPU/TPU PJRT clients).
//!
//! Convs are expressed in their im2col matmul form (the builder API has no
//! conv op): a k×k conv over `[B,H,W,C]` is `[B·H·W, C·k²] × [C·k², S]`,
//! and the Tucker2 chain is three matmuls with the rank-r intermediates.
//! This preserves exactly the FLOP count and the tile/alignment structure
//! that rank quantization exploits.

use anyhow::Result;
use xla::ElementType;

/// The on-device metric-accumulation computation of the pipelined training
/// engine: `acc' = acc + loss·e_loss + correct·e_correct` over a resident
/// `[2]` accumulator (`[loss_sum, correct_sum]`).
///
/// `e_loss = [1, 0]` and `e_correct = [0, 1]` arrive as parameters uploaded
/// once (constants would need literal-embedding APIs this builder never
/// relies on), and the scalar×mask products broadcast implicitly (XLA binary
/// ops broadcast rank-0 operands). Because the masks are exactly 0/1, each
/// lane reduces to one IEEE f32 add of the raw scalar — the device-side
/// accumulation is bit-identical to summing the same scalars in f32 on the
/// host, which is what makes the pipelined epoch's metrics exactly
/// comparable to the serial engine's (pinned in
/// `integration_train_resident`).
///
/// Input order (shared with the AOT-lowered `metrics_acc` artifact from
/// `python/compile/aot.py`): `(acc[2], loss[], correct[], e_loss[2],
/// e_correct[2]) -> acc'[2]`.
pub fn metrics_accumulate_computation() -> Result<xla::XlaComputation> {
    let b = xla::XlaBuilder::new("metrics_acc");
    let acc = b.parameter(0, ElementType::F32, &[2], "acc")?;
    let loss = b.parameter(1, ElementType::F32, &[], "loss")?;
    let correct = b.parameter(2, ElementType::F32, &[], "correct")?;
    let e_loss = b.parameter(3, ElementType::F32, &[2], "e_loss")?;
    let e_correct = b.parameter(4, ElementType::F32, &[2], "e_correct")?;
    let out = acc.add_(&e_loss.mul_(&loss)?)?.add_(&e_correct.mul_(&correct)?)?;
    Ok(out.build()?)
}

/// A decomposable layer's micro-benchmark spec: spatial positions `m`
/// (batch·H·W), input channels `c`, output channels `s`, kernel `k`.
#[derive(Clone, Copy, Debug)]
pub struct LayerBench {
    pub m: usize,
    pub c: usize,
    pub s: usize,
    pub k: usize,
}

impl LayerBench {
    pub fn linear(m: usize, c: usize, s: usize) -> Self {
        LayerBench { m, c, s, k: 1 }
    }
    pub fn conv(m: usize, c: usize, s: usize, k: usize) -> Self {
        LayerBench { m, c, s, k }
    }

    /// Dense layer: `y[m, s] = x[m, c·k²] @ w[c·k², s]` (im2col form).
    pub fn dense_computation(&self) -> Result<xla::XlaComputation> {
        let b = xla::XlaBuilder::new(&format!("dense_{}x{}x{}k{}", self.m, self.c, self.s, self.k));
        let ck2 = (self.c * self.k * self.k) as i64;
        let x = b.parameter(0, ElementType::F32, &[self.m as i64, ck2], "x")?;
        let w = b.parameter(1, ElementType::F32, &[ck2, self.s as i64], "w")?;
        Ok(x.matmul(&w)?.build()?)
    }

    /// Decomposed layer at rank(s) (r1, r2):
    /// - k == 1 (SVD): `x[m,c] @ a[c,r1] @ bmat[r1,s]`
    /// - k > 1 (Tucker2): `x[m,c] @ u[c,r1]`, im2col to `[m, r1·k²]`,
    ///   `@ core[r1·k², r2]`, `@ v[r2, s]`.
    ///
    /// The im2col expansion between stage 1 and 2 is modeled by a reshape/
    /// broadcast-free matmul on a pre-expanded parameter (timing-equivalent;
    /// patch extraction is memory-bound identically for every rank, so it
    /// cancels in Δt(r), which is all Algorithm 1 consumes).
    pub fn decomposed_computation(&self, r1: usize, r2: usize) -> Result<xla::XlaComputation> {
        let b = xla::XlaBuilder::new(&format!(
            "lrd_{}x{}x{}k{}r{}x{}",
            self.m, self.c, self.s, self.k, r1, r2
        ));
        let m = self.m as i64;
        let x = b.parameter(0, ElementType::F32, &[m, self.c as i64], "x")?;
        let u = b.parameter(1, ElementType::F32, &[self.c as i64, r1 as i64], "u")?;
        let t = x.matmul(&u)?; // [m, r1]
        if self.k == 1 {
            let v = b.parameter(2, ElementType::F32, &[r1 as i64, self.s as i64], "v")?;
            return Ok(t.matmul(&v)?.build()?);
        }
        let r1k2 = (r1 * self.k * self.k) as i64;
        // im2col over the rank-r1 intermediate: [m, r1] -> [m, r1·k²].
        // Broadcast + reshape keeps the op memory-shaped like patch
        // extraction without a gather (unsupported cheaply here).
        let tk = t
            .broadcast_in_dim(&[m, (self.k * self.k) as i64, r1 as i64], &[0, 2])?
            .reshape(&[m, r1k2])?;
        let core = b.parameter(2, ElementType::F32, &[r1k2, r2 as i64], "core")?;
        let v = b.parameter(3, ElementType::F32, &[r2 as i64, self.s as i64], "v")?;
        Ok(tk.matmul(&core)?.matmul(&v)?.build()?)
    }

    /// FLOPs of the dense layer (2·m·n·k convention).
    pub fn dense_flops(&self) -> f64 {
        2.0 * self.m as f64 * (self.c * self.k * self.k) as f64 * self.s as f64
    }

    /// FLOPs of the decomposed layer.
    pub fn decomposed_flops(&self, r1: usize, r2: usize) -> f64 {
        let m = self.m as f64;
        if self.k == 1 {
            2.0 * m * self.c as f64 * r1 as f64 + 2.0 * m * r1 as f64 * self.s as f64
        } else {
            2.0 * m * self.c as f64 * r1 as f64
                + 2.0 * m * (r1 * self.k * self.k) as f64 * r2 as f64
                + 2.0 * m * r2 as f64 * self.s as f64
        }
    }

    /// Host-side input literals for the computation at the given ranks
    /// (`None` ⇒ dense). Contents are irrelevant for timing; zeros are fine
    /// and compress well in PJRT transfer.
    pub fn make_inputs(&self, ranks: Option<(usize, usize)>) -> Result<Vec<xla::Literal>> {
        fn zeros(rows: usize, cols: usize) -> Result<xla::Literal> {
            let lit = xla::Literal::vec1(&vec![0f32; rows * cols]);
            Ok(lit.reshape(&[rows as i64, cols as i64])?)
        }
        match ranks {
            None => Ok(vec![
                zeros(self.m, self.c * self.k * self.k)?,
                zeros(self.c * self.k * self.k, self.s)?,
            ]),
            Some((r1, r2)) => {
                if self.k == 1 {
                    Ok(vec![
                        zeros(self.m, self.c)?,
                        zeros(self.c, r1)?,
                        zeros(r1, self.s)?,
                    ])
                } else {
                    Ok(vec![
                        zeros(self.m, self.c)?,
                        zeros(self.c, r1)?,
                        zeros(r1 * self.k * self.k, r2)?,
                        zeros(r2, self.s)?,
                    ])
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flops_formulas() {
        let l = LayerBench::conv(1024, 64, 64, 3);
        assert_eq!(l.dense_flops(), 2.0 * 1024.0 * 64.0 * 9.0 * 64.0);
        let dec = l.decomposed_flops(32, 32);
        assert!(dec < l.dense_flops());
        let lin = LayerBench::linear(128, 256, 256);
        assert_eq!(
            lin.decomposed_flops(64, 64),
            2.0 * 128.0 * 256.0 * 64.0 * 2.0
        );
    }

    #[test]
    fn decomposed_flops_monotone_in_rank() {
        let l = LayerBench::conv(256, 128, 128, 3);
        let mut last = 0.0;
        for r in [8, 16, 32, 64, 128] {
            let f = l.decomposed_flops(r, r);
            assert!(f > last);
            last = f;
        }
    }
}
