//! PJRT runtime — loads the AOT HLO-text artifacts and executes them from
//! the rust hot path, plus an `XlaBuilder`-based micro-benchmark factory
//! used by the rank optimizer.
//!
//! Pattern (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! HLO *text* is the interchange format (jax ≥0.5 emits 64-bit instruction
//! ids that xla_extension 0.5.1 rejects in proto form).

pub mod builder;
pub mod manifest;

use crate::tensor::Tensor;
use anyhow::{anyhow, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

pub use manifest::{ArtifactMeta, LayerCfg, Manifest, ParamSlot};

/// Shared PJRT client + executable cache.
pub struct Runtime {
    client: Rc<xla::PjRtClient>,
    /// Identity executables used by [`Runtime::upload`], cached per shape so
    /// the compile cost is paid once per distinct tensor shape.
    upload_exes: RefCell<HashMap<Vec<i64>, Executable>>,
}

impl Runtime {
    /// CPU PJRT client (the only backend on this image; `gpu`/`tpu`
    /// constructors exist upstream and the rest of the crate is
    /// backend-agnostic, which is the paper's platform-agnosticity claim).
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client: Rc::new(client), upload_exes: RefCell::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file.
    pub fn load_hlo(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable {
            exe,
            name: path.file_stem().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default(),
            compile_secs: t0.elapsed().as_secs_f64(),
        })
    }

    /// Compile an in-memory `XlaComputation` (rank-opt microbenches).
    pub fn compile(&self, comp: &xla::XlaComputation, name: &str) -> Result<Executable> {
        let t0 = Instant::now();
        let exe = self.client.compile(comp).with_context(|| format!("compiling {name}"))?;
        Ok(Executable { exe, name: name.to_string(), compile_secs: t0.elapsed().as_secs_f64() })
    }

    /// Upload an f32 host literal to a device-resident buffer.
    ///
    /// The serving hot path keeps model parameters resident on device and
    /// passes them to [`Executable::run_buffers`] request after request,
    /// so upload cost is paid once instead of per request. The transfer is
    /// expressed as a compiled identity computation (parameter → root), the
    /// one host→device channel every PJRT backend supports; the executable
    /// is cached per shape.
    pub fn upload(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        let shape = lit.array_shape().context("upload expects an array literal")?;
        let dims: Vec<i64> = shape.dims().to_vec();
        if !self.upload_exes.borrow().contains_key(&dims) {
            let name = format!("upload_f32_{dims:?}");
            let b = xla::XlaBuilder::new(&name);
            let x = b.parameter(0, xla::ElementType::F32, &dims, "x")?;
            let exe = self.compile(&x.build()?, &name)?;
            self.upload_exes.borrow_mut().insert(dims.clone(), exe);
        }
        let cache = self.upload_exes.borrow();
        let mut bufs = cache[&dims].run_to_buffers(&[lit])?;
        Ok(bufs.swap_remove(0))
    }
}

/// A compiled executable plus metadata.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
    pub compile_secs: f64,
}

impl Executable {
    /// Execute with host literals; returns the flattened outputs.
    ///
    /// Artifacts are lowered with `return_tuple=True`, so the single output
    /// is a tuple that we decompose. Single-array computations (from the
    /// builder) come back as one literal.
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let bufs = self.exe.execute::<L>(inputs).context("execute")?;
        Self::buffer_to_literals(&bufs[0][0])
    }

    /// Execute with device-resident buffers (the hot path: parameters stay
    /// on device between steps). Accepts owned or borrowed buffers — the
    /// serving path uploads parameters once ([`Runtime::upload`]) and mixes
    /// in only the fresh batch input per request via `&[&PjRtBuffer]`.
    /// Returns the raw output buffers.
    pub fn run_buffers<B: std::borrow::Borrow<xla::PjRtBuffer>>(
        &self,
        inputs: &[B],
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let mut out = self.exe.execute_b(inputs).context("execute_b")?;
        Ok(out.swap_remove(0))
    }

    /// Execute with host literals but keep the outputs on device (used by
    /// [`Runtime::upload`] and pipelined serving).
    pub fn run_to_buffers<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let mut out = self.exe.execute::<L>(inputs).context("execute")?;
        Ok(out.swap_remove(0))
    }

    /// Sync one output buffer to host and flatten it, mirroring the output
    /// handling of [`Executable::run`] (tuple roots decompose, single arrays
    /// pass through).
    pub fn buffer_to_literals(buf: &xla::PjRtBuffer) -> Result<Vec<xla::Literal>> {
        let mut lit = buf.to_literal_sync().context("fetch output")?;
        match lit.shape()? {
            xla::Shape::Tuple(_) => Ok(lit.decompose_tuple()?),
            _ => Ok(vec![lit]),
        }
    }

    /// Time one synchronous execution (host literals in, host literal out).
    pub fn time_once<L: std::borrow::Borrow<xla::Literal>>(&self, inputs: &[L]) -> Result<f64> {
        let t0 = Instant::now();
        let bufs = self.exe.execute::<L>(inputs)?;
        // force completion by syncing the (first) output to host
        let _ = bufs[0][0].to_literal_sync()?;
        Ok(t0.elapsed().as_secs_f64())
    }
}

// ---------------------------------------------------------------------------
// tensor <-> literal conversion
// ---------------------------------------------------------------------------

/// f32 Tensor → Literal with shape.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(t.data()).reshape(&dims)?)
}

/// Literal → f32 Tensor (shape read from the literal).
pub fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<f32>()?;
    let dims = if dims.is_empty() { vec![1] } else { dims };
    Ok(Tensor::new(&dims, data))
}

/// i32 labels → Literal `[n]`.
pub fn labels_to_literal(labels: &[i32]) -> xla::Literal {
    xla::Literal::vec1(labels)
}

/// Scalar f32 literal (e.g. the learning rate input).
pub fn scalar_literal(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    // Runtime tests that need a PJRT client live in rust/tests/ (integration)
    // to keep `cargo test --lib` free of libxla state; conversion helpers are
    // testable here because literals don't need a client.

    #[test]
    fn tensor_literal_roundtrip() {
        let mut rng = Rng::new(40);
        let t = Tensor::randn(&[3, 4, 2], 1.0, &mut rng);
        let lit = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn scalar_and_labels() {
        let lit = scalar_literal(0.25);
        assert_eq!(lit.get_first_element::<f32>().unwrap(), 0.25);
        let lab = labels_to_literal(&[1, 2, 3]);
        assert_eq!(lab.to_vec::<i32>().unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn tensor_literal_1d() {
        let t = Tensor::new(&[5], vec![1., 2., 3., 4., 5.]);
        let back = literal_to_tensor(&tensor_to_literal(&t).unwrap()).unwrap();
        assert_eq!(back, t);
    }
}
