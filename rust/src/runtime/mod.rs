//! PJRT runtime — loads the AOT HLO-text artifacts and executes them from
//! the rust hot path, plus an `XlaBuilder`-based micro-benchmark factory
//! used by the rank optimizer.
//!
//! Pattern (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! HLO *text* is the interchange format (jax ≥0.5 emits 64-bit instruction
//! ids that xla_extension 0.5.1 rejects in proto form).

pub mod builder;
pub mod manifest;
pub mod pipeline;

use crate::obs;
use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

pub use manifest::{ArtifactMeta, LayerCfg, Manifest, ParamSlot};
pub use pipeline::{DoubleBuffered, InFlight};

/// Shared PJRT client + executable cache.
pub struct Runtime {
    client: Rc<xla::PjRtClient>,
    /// Identity executables used by [`Runtime::upload`], cached per
    /// (element type, shape) so the compile cost is paid once per distinct
    /// tensor signature.
    upload_exes: RefCell<HashMap<(u8, Vec<i64>), Executable>>,
    /// Times [`Executable::run_buffers_demux`] had to fall back to a host
    /// decompose + re-upload because the backend handed back a packed tuple
    /// buffer instead of per-leaf buffers. The buffer-chained training hot
    /// path is only zero-copy when this stays 0.
    ///
    /// The transfer counters are [`obs::Counter`] handles (shared atomics),
    /// so [`Runtime::register_metrics`] can index the *same* cells into a
    /// metrics registry — a registry snapshot reads exactly what the
    /// accessors below read.
    pub(crate) demux_fallbacks: obs::Counter,
    /// Total host→device transfers through [`Runtime::upload`] and friends
    /// — *every* upload flows through here, so tests can pin "only the
    /// per-step data crossed the boundary" exactly (see
    /// `integration_train_resident`).
    uploads: obs::Counter,
    /// Counted device→host syncs through [`Runtime::fetch_scalar`] /
    /// [`Runtime::fetch_f32s`] — the training hot path's semantically
    /// required host syncs route through these so tests can assert the
    /// pipelined engine really dropped from 2 scalar syncs per step to one
    /// metrics fetch per epoch. Syncs outside the step/metric path (eval
    /// logits, checkpoint downloads) intentionally do not count.
    fetches: obs::Counter,
}

impl Runtime {
    /// CPU PJRT client (the only backend on this image; `gpu`/`tpu`
    /// constructors exist upstream and the rest of the crate is
    /// backend-agnostic, which is the paper's platform-agnosticity claim).
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client: Rc::new(client),
            upload_exes: RefCell::new(HashMap::new()),
            demux_fallbacks: obs::Counter::new(),
            uploads: obs::Counter::new(),
            fetches: obs::Counter::new(),
        })
    }

    /// Index this runtime's transfer counters into `registry` under the
    /// `runtime` subsystem. The registry shares the counter atomics, so its
    /// snapshots equal [`Runtime::uploads`] / [`Runtime::fetches`] /
    /// [`Runtime::demux_fallbacks`] exactly.
    pub fn register_metrics(
        &self,
        registry: &obs::Registry,
        labels: &[(&str, &str)],
    ) -> Result<()> {
        registry.register_counter("runtime", "uploads", labels, &self.uploads)?;
        registry.register_counter("runtime", "fetches", labels, &self.fetches)?;
        registry.register_counter("runtime", "demux_fallbacks", labels, &self.demux_fallbacks)?;
        Ok(())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file.
    pub fn load_hlo(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable {
            exe,
            name: path.file_stem().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default(),
            compile_secs: t0.elapsed().as_secs_f64(),
        })
    }

    /// Compile an in-memory `XlaComputation` (rank-opt microbenches).
    pub fn compile(&self, comp: &xla::XlaComputation, name: &str) -> Result<Executable> {
        let t0 = Instant::now();
        let exe = self.client.compile(comp).with_context(|| format!("compiling {name}"))?;
        Ok(Executable { exe, name: name.to_string(), compile_secs: t0.elapsed().as_secs_f64() })
    }

    /// Upload an f32 host literal to a device-resident buffer.
    ///
    /// The serving and training hot paths keep model parameters resident on
    /// device and pass them to [`Executable::run_buffers`] request after
    /// request (step after step), so upload cost is paid once instead of
    /// per execution. The transfer is expressed as a compiled identity
    /// computation (parameter → root), the one host→device channel every
    /// PJRT backend supports; the executable is cached per signature.
    pub fn upload(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        self.upload_as(lit, xla::ElementType::F32)
    }

    /// Upload i32 class labels (`[n]`) to a device-resident buffer — the
    /// per-step `y` input of the resident training engine.
    pub fn upload_labels(&self, labels: &[i32]) -> Result<xla::PjRtBuffer> {
        self.upload_as(&labels_to_literal(labels), xla::ElementType::S32)
    }

    /// Upload a scalar f32 (the learning-rate input; the training engine
    /// caches the buffer per distinct value, so this runs once per epoch).
    pub fn upload_scalar(&self, v: f32) -> Result<xla::PjRtBuffer> {
        self.upload_as(&scalar_literal(v), xla::ElementType::F32)
    }

    fn upload_as(&self, lit: &xla::Literal, ty: xla::ElementType) -> Result<xla::PjRtBuffer> {
        let shape = lit.array_shape().context("upload expects an array literal")?;
        // sits on the per-step training hot path (x/y uploads), so the
        // warm-cache key is allocation-free apart from the dims vec
        let tag: u8 = match ty {
            xla::ElementType::F32 => 0,
            xla::ElementType::S32 => 1,
            _ => bail!("upload_as: unsupported element type {ty:?}"),
        };
        let key = (tag, shape.dims().to_vec());
        if !self.upload_exes.borrow().contains_key(&key) {
            let dims = &key.1;
            let name = format!("upload_{ty:?}_{dims:?}");
            let b = xla::XlaBuilder::new(&name);
            let x = b.parameter(0, ty, dims, "x")?;
            let exe = self.compile(&x.build()?, &name)?;
            self.upload_exes.borrow_mut().insert(key.clone(), exe);
        }
        let cache = self.upload_exes.borrow();
        let mut bufs = cache[&key].run_to_buffers(&[lit])?;
        self.uploads.inc();
        Ok(bufs.swap_remove(0))
    }

    /// How often [`Executable::run_buffers_demux`] fell back to a host
    /// round-trip — 0 means every demuxed execution stayed buffer-to-buffer.
    pub fn demux_fallbacks(&self) -> usize {
        self.demux_fallbacks.get() as usize
    }

    /// Total host→device transfers so far (all dtypes, data and parameters
    /// alike).
    pub fn uploads(&self) -> usize {
        self.uploads.get() as usize
    }

    /// Counted device→host syncs on the step/metric path so far (see the
    /// field docs: eval/checkpoint downloads are deliberately outside this).
    pub fn fetches(&self) -> usize {
        self.fetches.get() as usize
    }

    /// Sync a scalar f32 buffer to host, counting the fetch — the per-step
    /// loss/correct syncs of the serial resident engine go through here.
    pub fn fetch_scalar(&self, buf: &xla::PjRtBuffer) -> Result<f32> {
        self.fetches.inc();
        download_scalar(buf)
    }

    /// Sync a small f32 vector buffer to host, counting the fetch — the
    /// once-per-epoch metrics-accumulator download of the pipelined engine.
    pub fn fetch_f32s(&self, buf: &xla::PjRtBuffer) -> Result<Vec<f32>> {
        self.fetches.inc();
        let mut lits = Executable::buffer_to_literals(buf)?;
        if lits.len() != 1 {
            bail!("fetch_f32s expects a single-array buffer, got {} leaves", lits.len());
        }
        Ok(lits.swap_remove(0).to_vec::<f32>()?)
    }
}

/// A compiled executable plus metadata.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
    pub compile_secs: f64,
}

impl Executable {
    /// Execute with host literals; returns the flattened outputs.
    ///
    /// Artifacts are lowered with `return_tuple=True`. Depending on the
    /// backend's untupling behavior the tuple root comes back either as a
    /// single packed buffer (decomposed here) or as one buffer per leaf
    /// (synced leaf by leaf) — both flatten to the same output list.
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let bufs = self.exe.execute::<L>(inputs).context("execute")?;
        let outs = &bufs[0];
        if outs.len() == 1 {
            return Self::buffer_to_literals(&outs[0]);
        }
        let mut lits = Vec::with_capacity(outs.len());
        for buf in outs {
            lits.extend(Self::buffer_to_literals(buf)?);
        }
        Ok(lits)
    }

    /// Execute with device-resident buffers (the hot path: parameters stay
    /// on device between steps). Accepts owned or borrowed buffers — the
    /// serving path uploads parameters once ([`Runtime::upload`]) and mixes
    /// in only the fresh batch input per request via `&[&PjRtBuffer]`.
    /// Returns the raw output buffers.
    pub fn run_buffers<B: std::borrow::Borrow<xla::PjRtBuffer>>(
        &self,
        inputs: &[B],
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let mut out = self.exe.execute_b(inputs).context("execute_b")?;
        Ok(out.swap_remove(0))
    }

    /// Execute with host literals but keep the outputs on device (used by
    /// [`Runtime::upload`] and pipelined serving).
    pub fn run_to_buffers<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let mut out = self.exe.execute::<L>(inputs).context("execute")?;
        Ok(out.swap_remove(0))
    }

    /// Execute with device-resident buffers and return the `expected`
    /// outputs as *individual* device buffers — the buffer-chained training
    /// hot path: step N's output buffers (new params, new momenta) feed
    /// step N+1 with no host transfer.
    ///
    /// This is the fused form of the split pair
    /// [`Executable::dispatch_buffers`] → [`pipeline::InFlight::fetch`];
    /// engines that want to overlap work between the two halves call them
    /// directly (see [`pipeline`]). Demux semantics and the
    /// packed-tuple-fallback accounting live in `InFlight::fetch`.
    pub fn run_buffers_demux<B: std::borrow::Borrow<xla::PjRtBuffer>>(
        &self,
        rt: &Runtime,
        inputs: &[B],
        expected: usize,
    ) -> Result<Vec<xla::PjRtBuffer>> {
        self.dispatch_buffers(inputs, expected)?.fetch(rt)
    }

    /// Sync one output buffer to host and flatten it, mirroring the output
    /// handling of [`Executable::run`] (tuple roots decompose, single arrays
    /// pass through).
    pub fn buffer_to_literals(buf: &xla::PjRtBuffer) -> Result<Vec<xla::Literal>> {
        let mut lit = buf.to_literal_sync().context("fetch output")?;
        match lit.shape()? {
            xla::Shape::Tuple(_) => Ok(lit.decompose_tuple()?),
            _ => Ok(vec![lit]),
        }
    }

    /// Time one synchronous execution (host literals in, host literal out).
    pub fn time_once<L: std::borrow::Borrow<xla::Literal>>(&self, inputs: &[L]) -> Result<f64> {
        let t0 = Instant::now();
        let bufs = self.exe.execute::<L>(inputs)?;
        // force completion by syncing the (first) output to host
        let _ = bufs[0][0].to_literal_sync()?;
        Ok(t0.elapsed().as_secs_f64())
    }
}

// ---------------------------------------------------------------------------
// tensor <-> literal conversion
// ---------------------------------------------------------------------------

/// f32 Tensor → Literal with shape.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(t.data()).reshape(&dims)?)
}

/// Literal → f32 Tensor (shape read from the literal).
pub fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<f32>()?;
    let dims = if dims.is_empty() { vec![1] } else { dims };
    Ok(Tensor::new(&dims, data))
}

/// Sync a single-array device buffer back to a host tensor. The resident
/// training engine calls this only where host state is semantically
/// required: checkpointing and returning the final parameters.
pub fn download_tensor(buf: &xla::PjRtBuffer) -> Result<Tensor> {
    literal_to_tensor(&buf.to_literal_sync().context("download buffer")?)
}

/// Sync a scalar f32 device buffer (per-step loss / correct-count outputs).
pub fn download_scalar(buf: &xla::PjRtBuffer) -> Result<f32> {
    Ok(buf.to_literal_sync().context("download scalar")?.get_first_element::<f32>()?)
}

/// i32 labels → Literal `[n]`.
pub fn labels_to_literal(labels: &[i32]) -> xla::Literal {
    xla::Literal::vec1(labels)
}

/// Scalar f32 literal (e.g. the learning rate input).
pub fn scalar_literal(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    // Runtime tests that need a PJRT client live in rust/tests/ (integration)
    // to keep `cargo test --lib` free of libxla state; conversion helpers are
    // testable here because literals don't need a client.

    #[test]
    fn tensor_literal_roundtrip() {
        let mut rng = Rng::new(40);
        let t = Tensor::randn(&[3, 4, 2], 1.0, &mut rng);
        let lit = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn scalar_and_labels() {
        let lit = scalar_literal(0.25);
        assert_eq!(lit.get_first_element::<f32>().unwrap(), 0.25);
        let lab = labels_to_literal(&[1, 2, 3]);
        assert_eq!(lab.to_vec::<i32>().unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn tensor_literal_1d() {
        let t = Tensor::new(&[5], vec![1., 2., 3., 4., 5.]);
        let back = literal_to_tensor(&tensor_to_literal(&t).unwrap()).unwrap();
        assert_eq!(back, t);
    }
}
