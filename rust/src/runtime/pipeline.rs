//! Overlapped execution primitives: split dispatch/fetch and double-buffered
//! staging.
//!
//! PJRT executes asynchronously — `execute_b` enqueues the computation and
//! returns output buffer handles immediately; only a host sync
//! (`to_literal_sync`) blocks. The monolithic
//! [`Executable::run_buffers_demux`] hid that: callers got the output
//! buffers back only bundled with the demux bookkeeping, so every engine
//! loop was written dispatch-then-immediately-consume. This module splits
//! the call into its two halves so engines can put work *between* them:
//!
//! ```text
//!   let inflight = exe.dispatch_buffers(&inputs, n)?;  // non-blocking
//!   /* overlap window: upload batch N+1, coalesce requests, ... */
//!   let outs = inflight.fetch(&rt)?;                   // demux (+ fallback)
//! ```
//!
//! [`DoubleBuffered`] is the companion staging structure: a two-slot queue
//! holding the uploaded `x`/`y` buffers of the *next* batch while the
//! current one executes. XLA handles (`PjRtBuffer`, the client `Rc`) are not
//! `Send`, so there is no upload *thread*: the engine thread itself uploads
//! into the back slot right after dispatching the current step — the upload
//! is itself an async PJRT execution, so it proceeds concurrently with the
//! step on the device side while the host goes back to waiting on results.
//! (The host-side batch *assembly* does run on a real worker thread — see
//! [`crate::train::Prefetcher`] — because plain `Vec<f32>`s are `Send`.)
//!
//! Barriers compose with this overlap at step boundaries: the pipelined
//! train loop runs its per-step hook (the replica averaging barrier) only
//! after the in-flight step's outputs are fetched and absorbed, at which
//! point the [`DoubleBuffered`] slots hold nothing but host-prepared batch
//! uploads — no parameter state — so a hook may download, replace and
//! rebind resident parameters without draining or invalidating the staging
//! queue.

use super::{Executable, Runtime};
use anyhow::{bail, Result};

/// A dispatched-but-not-yet-consumed execution: the output buffer handles of
/// an asynchronous `execute_b` call, plus what the demux step will need.
/// Produced by [`Executable::dispatch_buffers`]; consumed by
/// [`InFlight::fetch`].
pub struct InFlight {
    outs: Vec<xla::PjRtBuffer>,
    expected: usize,
    exe_name: String,
}

impl Executable {
    /// Non-blocking half of [`Executable::run_buffers_demux`]: enqueue the
    /// execution and return the in-flight handle. The computation proceeds
    /// asynchronously; nothing blocks until [`InFlight::fetch`] (or a host
    /// sync on one of the output buffers).
    pub fn dispatch_buffers<B: std::borrow::Borrow<xla::PjRtBuffer>>(
        &self,
        inputs: &[B],
        expected: usize,
    ) -> Result<InFlight> {
        Ok(InFlight {
            outs: self.run_buffers(inputs)?,
            expected,
            exe_name: self.name.clone(),
        })
    }
}

impl InFlight {
    /// Blocking half: demux the outputs into exactly `expected` per-leaf
    /// device buffers.
    ///
    /// A PJRT backend that untuples tuple roots already handed back one
    /// buffer per leaf at dispatch time, so this is a pure hand-over (the
    /// buffers may still be materializing on device — only a later host
    /// sync blocks). If the backend returned a single packed tuple buffer
    /// instead, fall back to a host decompose + per-leaf re-upload (correct,
    /// but it round-trips the state) and count it on the [`Runtime`] so
    /// benches and tests can assert the fast path ran.
    pub fn fetch(self, rt: &Runtime) -> Result<Vec<xla::PjRtBuffer>> {
        let InFlight { outs, expected, exe_name } = self;
        if outs.len() == expected {
            return Ok(outs);
        }
        if outs.len() == 1 && expected > 1 {
            rt.demux_fallbacks.inc();
            let lits = Executable::buffer_to_literals(&outs[0])?;
            if lits.len() != expected {
                bail!("'{exe_name}' returned {} outputs, expected {expected}", lits.len());
            }
            let mut bufs = Vec::with_capacity(expected);
            for lit in &lits {
                bufs.push(rt.upload(lit)?);
            }
            return Ok(bufs);
        }
        bail!("'{exe_name}' returned {} output buffers, expected {expected}", outs.len())
    }
}

/// A two-slot FIFO: the "current" item (consumed by the step about to
/// dispatch) and the "staged" item (uploaded during the previous step's
/// overlap window). Generic so the train engine can stage `(x, y)` buffer
/// pairs and tests can exercise it with plain values.
pub struct DoubleBuffered<T> {
    slots: [Option<T>; 2],
    /// Index of the oldest occupied slot.
    head: usize,
    len: usize,
}

impl<T> Default for DoubleBuffered<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> DoubleBuffered<T> {
    pub fn new() -> Self {
        DoubleBuffered { slots: [None, None], head: 0, len: 0 }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Is there a free slot to stage into?
    pub fn has_room(&self) -> bool {
        self.len < 2
    }

    /// Stage an item into the back slot. Errors when both slots are
    /// occupied — the caller's pipeline depth is 2 by construction, so this
    /// firing means a bookkeeping bug, not load.
    pub fn stage(&mut self, item: T) -> Result<()> {
        if !self.has_room() {
            bail!("DoubleBuffered overflow: both slots occupied");
        }
        let back = (self.head + self.len) % 2;
        self.slots[back] = Some(item);
        self.len += 1;
        Ok(())
    }

    /// Take the oldest item (the one whose turn it is to dispatch).
    pub fn take(&mut self) -> Option<T> {
        if self.len == 0 {
            return None;
        }
        let item = self.slots[self.head].take();
        self.head = (self.head + 1) % 2;
        self.len -= 1;
        item
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn double_buffered_is_fifo() {
        let mut db = DoubleBuffered::new();
        assert!(db.is_empty());
        db.stage(1).unwrap();
        db.stage(2).unwrap();
        assert_eq!(db.len(), 2);
        assert!(!db.has_room());
        assert_eq!(db.take(), Some(1));
        db.stage(3).unwrap();
        assert_eq!(db.take(), Some(2));
        assert_eq!(db.take(), Some(3));
        assert_eq!(db.take(), None);
        assert!(db.is_empty());
    }

    #[test]
    fn double_buffered_rejects_third_stage() {
        let mut db = DoubleBuffered::new();
        db.stage("a").unwrap();
        db.stage("b").unwrap();
        assert!(db.stage("c").is_err());
        // the failed stage must not corrupt the queue
        assert_eq!(db.take(), Some("a"));
        assert_eq!(db.take(), Some("b"));
        assert_eq!(db.take(), None);
    }

    #[test]
    fn double_buffered_steady_state_alternates_slots() {
        // the pipelined epoch's steady state: one in flight, one staged
        let mut db = DoubleBuffered::new();
        db.stage(0).unwrap();
        for i in 1..10 {
            let cur = db.take().unwrap();
            assert_eq!(cur, i - 1);
            db.stage(i).unwrap();
            assert_eq!(db.len(), 1);
        }
    }
}
