//! Analytical device performance model — the substitute for the paper's
//! V100 GPUs and Ascend-910 NPUs (DESIGN.md §Substitutions).
//!
//! The paper's rank-quantization effect (Fig. 2's step-time staircase) is
//! caused by tile quantization: a matmul engine processes operands in
//! fixed tiles (tensor-core 16×16×16 on V100, cube 16³ on Ascend, MXU
//! 128×128 on TPU), so every dimension is padded up to the tile and step
//! time is flat between multiples. This module models exactly that:
//!
//! `t = overhead + max(padded_flops / peak, bytes / bandwidth)`
//!
//! and composes layer/ model/ training-step estimates from it. The rank
//! optimizer consumes it through the same `LayerTimer` trait as the real
//! PJRT backend, so Algorithm 1 is identical against simulated V100,
//! simulated Ascend, simulated TPU, or measured CPU.

use crate::runtime::builder::LayerBench;

/// A matmul-engine device profile.
#[derive(Clone, Debug)]
pub struct DeviceProfile {
    pub name: &'static str,
    /// Tile quantization per matmul dimension (M, K, N).
    pub tile_m: usize,
    pub tile_k: usize,
    pub tile_n: usize,
    /// Peak sustained f32 matmul throughput (FLOP/s).
    pub peak_flops: f64,
    /// HBM bandwidth (bytes/s).
    pub mem_bw: f64,
    /// Fixed per-kernel-launch overhead (s).
    pub launch_overhead: f64,
    /// Sustained-throughput multiplier when the contraction/output dims are
    /// NOT tile multiples (matmul engines fall back to slower generic
    /// kernels on misaligned leading dims — the other half of the Fig. 2
    /// staircase beyond pure padding; cuBLAS shows 1.2-2x swings).
    pub misalign_eff: f64,
    /// VMEM / shared-memory budget per core (bytes); 0 = unmodelled.
    pub sram_bytes: usize,
}

fn ceil_to(x: usize, tile: usize) -> usize {
    x.div_ceil(tile) * tile
}

impl DeviceProfile {
    /// NVIDIA V100-like: tensor-core tiles 16³ but cuBLAS wave quantization
    /// makes 8-multiples matter most; ~14 TFLOP/s sustained, 900 GB/s.
    pub fn v100() -> Self {
        DeviceProfile {
            name: "v100-sim",
            tile_m: 64,
            tile_k: 8,
            tile_n: 8,
            peak_flops: 14.0e12,
            mem_bw: 900.0e9,
            launch_overhead: 4.5e-6,
            misalign_eff: 0.68,
            sram_bytes: 0,
        }
    }

    /// Huawei Ascend-910-like: cube unit 16×16×16, ~16 TFLOP/s f32-ish
    /// sustained through the cube, 1.2 TB/s.
    pub fn ascend910() -> Self {
        DeviceProfile {
            name: "ascend910-sim",
            tile_m: 16,
            tile_k: 16,
            tile_n: 16,
            peak_flops: 16.0e12,
            mem_bw: 1200.0e9,
            launch_overhead: 6.0e-6,
            misalign_eff: 0.72,
            sram_bytes: 0,
        }
    }

    /// TPU-v4-like: 128×128 MXU, (8,128) vreg tiling, 16 MiB VMEM.
    /// Used for the L1 kernel's estimated-performance numbers.
    pub fn tpu_v4() -> Self {
        DeviceProfile {
            name: "tpuv4-sim",
            tile_m: 8,
            tile_k: 128,
            tile_n: 128,
            peak_flops: 137.0e12 / 2.0, // f32 via bf16 passes
            mem_bw: 1200.0e9,
            launch_overhead: 2.0e-6,
            misalign_eff: 0.45,
            sram_bytes: 16 << 20,
        }
    }

    /// This host's CPU, roughly: SIMD width 16 f32 lanes, measured-scale
    /// GEMM throughput. Used in tests to sanity-check model shapes.
    pub fn cpu_sim() -> Self {
        DeviceProfile {
            name: "cpu-sim",
            tile_m: 4,
            tile_k: 16,
            tile_n: 16,
            peak_flops: 1.0e11,
            mem_bw: 30.0e9,
            launch_overhead: 1.0e-6,
            misalign_eff: 0.85,
            sram_bytes: 0,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "v100" | "v100-sim" => Some(Self::v100()),
            "ascend910" | "ascend" | "ascend910-sim" => Some(Self::ascend910()),
            "tpu" | "tpuv4" | "tpuv4-sim" => Some(Self::tpu_v4()),
            "cpu-sim" => Some(Self::cpu_sim()),
            _ => None,
        }
    }

    /// Time of one `[m,k]×[k,n]` matmul. Both the compute term and the
    /// memory term use tile-padded dimensions — matmul engines allocate and
    /// stream padded buffers, which is what makes step time *flat* between
    /// tile multiples (the Fig. 2 staircase).
    pub fn matmul_time(&self, m: usize, k: usize, n: usize) -> f64 {
        let mp = ceil_to(m, self.tile_m) as f64;
        let kp = ceil_to(k, self.tile_k) as f64;
        let np = ceil_to(n, self.tile_n) as f64;
        let aligned = k % self.tile_k == 0 && n % self.tile_n == 0;
        let eff = if aligned { 1.0 } else { self.misalign_eff };
        let compute = 2.0 * mp * kp * np / (self.peak_flops * eff);
        let bytes = 4.0 * (mp * kp + kp * np + mp * np);
        self.launch_overhead + compute.max(bytes / self.mem_bw)
    }

    /// Forward time of a dense layer (im2col matmul form).
    pub fn dense_fwd(&self, l: &LayerBench) -> f64 {
        self.matmul_time(l.m, l.c * l.k * l.k, l.s)
    }

    /// Forward time of the decomposed layer at ranks (r1, r2). The core
    /// conv's im2col contraction dim is `pad(r1)·k²`: the rank-r1 channel
    /// dim is padded to the tile *before* the k² patch expansion (channels
    /// are the innermost layout dim on all three devices).
    pub fn decomposed_fwd(&self, l: &LayerBench, r1: usize, r2: usize) -> f64 {
        if l.k == 1 {
            self.matmul_time(l.m, l.c, r1) + self.matmul_time(l.m, r1, l.s)
        } else {
            self.matmul_time(l.m, l.c, r1)
                + self.matmul_time(l.m, ceil_to(r1, self.tile_k) * l.k * l.k, r2)
                + self.matmul_time(l.m, r2, l.s)
        }
    }

    /// Backward time of one matmul layer: dX (always, to keep propagating)
    /// + dW (only when the weight is trainable).
    fn matmul_bwd(&self, m: usize, k: usize, n: usize, trainable: bool) -> f64 {
        let dx = self.matmul_time(m, n, k);
        if trainable {
            dx + self.matmul_time(k, m, n)
        } else {
            dx
        }
    }

    /// Training-step time of a dense layer (fwd + full bwd).
    pub fn dense_step(&self, l: &LayerBench) -> f64 {
        self.dense_fwd(l) + self.matmul_bwd(l.m, l.c * l.k * l.k, l.s, true)
    }

    /// Training-step time of a decomposed layer under a freeze mask.
    /// `train_*` flags say which factors get a dW product this step —
    /// the paper's freezing saves exactly those products.
    pub fn decomposed_step(
        &self,
        l: &LayerBench,
        r1: usize,
        r2: usize,
        train_first: bool,
        train_core: bool,
        train_last: bool,
    ) -> f64 {
        if l.k == 1 {
            self.decomposed_fwd(l, r1, r2)
                + self.matmul_bwd(l.m, r1, l.s, train_last)
                + self.matmul_bwd(l.m, l.c, r1, train_first)
        } else {
            self.decomposed_fwd(l, r1, r2)
                + self.matmul_bwd(l.m, r2, l.s, train_last)
                + self.matmul_bwd(l.m, ceil_to(r1, self.tile_k) * l.k * l.k, r2, train_core)
                + self.matmul_bwd(l.m, l.c, r1, train_first)
        }
    }

    /// Does the fused low-rank kernel's working set fit SRAM/VMEM?
    /// (block_m × (C + r + S) + factor tiles; see kernels/lowrank.py.)
    pub fn lowrank_fits_sram(&self, block_m: usize, c: usize, r: usize, s: usize) -> bool {
        if self.sram_bytes == 0 {
            return true;
        }
        let floats = block_m * c + c * r + r * s + block_m * r + block_m * s;
        4 * floats <= self.sram_bytes
    }

    /// MXU/tile utilization of an `[m,k]×[k,n]` matmul: useful FLOPs over
    /// padded FLOPs. This is the "efficiency ratio" reported for L1.
    pub fn tile_utilization(&self, m: usize, k: usize, n: usize) -> f64 {
        let useful = (m * k) as f64 * n as f64;
        let padded = (ceil_to(m, self.tile_m) * ceil_to(k, self.tile_k)) as f64
            * ceil_to(n, self.tile_n) as f64;
        useful / padded
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn staircase_between_tile_multiples() {
        // Fig. 2 mechanism: time is flat within a tile band, jumps at the
        // boundary. (tile_m divides m so the m-padding is inert here.)
        let d = DeviceProfile::ascend910();
        let l = LayerBench::conv(4096, 512, 512, 3);
        let t256 = d.decomposed_fwd(&l, 256, 256);
        let t255 = d.decomposed_fwd(&l, 255, 255);
        let t249 = d.decomposed_fwd(&l, 249, 249);
        let t257 = d.decomposed_fwd(&l, 257, 257);
        assert!((t255 - t249).abs() < 1e-12, "flat inside the misaligned band");
        assert!(t256 < t255, "aligned 256 beats misaligned 255 (same pad)");
        assert!(t257 > t256 * 1.01, "jump past the boundary (paper's 257 vs 256)");
        // the paper reports ~15% for 257 -> 256 on this very layer
        let gain = t257 / t256 - 1.0;
        assert!(gain > 0.10, "gain {gain}");
    }

    #[test]
    fn rank_256_beats_257_like_paper() {
        // Paper §2.1: 257 -> 256 improves layer throughput ~15% while the
        // compression ratio changes <1%. Our model must show a material win.
        let d = DeviceProfile::v100();
        let l = LayerBench::conv(14 * 14 * 32, 512, 512, 3);
        let t257 = d.decomposed_fwd(&l, 257, 257);
        let t256 = d.decomposed_fwd(&l, 256, 256);
        let gain = t257 / t256 - 1.0;
        assert!(gain > 0.005, "gain {gain}");
    }

    #[test]
    fn dense_step_costs_about_3x_fwd() {
        let d = DeviceProfile::v100();
        let l = LayerBench::conv(4096, 256, 256, 3);
        let f = d.dense_fwd(&l);
        let s = d.dense_step(&l);
        assert!(s > 2.5 * f && s < 3.5 * f, "s/f = {}", s / f);
    }

    #[test]
    fn freezing_reduces_step_time() {
        let d = DeviceProfile::v100();
        let l = LayerBench::conv(4096, 256, 256, 3);
        let full = d.decomposed_step(&l, 128, 128, true, true, true);
        let frozen = d.decomposed_step(&l, 128, 128, false, true, false);
        assert!(frozen < full);
        // inference (fwd) unchanged by freezing — the paper's Table 1 point
        assert_eq!(d.decomposed_fwd(&l, 128, 128), d.decomposed_fwd(&l, 128, 128));
    }

    #[test]
    fn decomposition_helps_only_when_rank_small_enough() {
        // The paper's core observation: at mild ranks LRD may be *slower*
        // despite fewer params (more launches), so rank-opt may keep the
        // original layer. Large m ⇒ compute-bound regime.
        let d = DeviceProfile::v100();
        let l = LayerBench::conv(16384, 64, 64, 3);
        let dense = d.dense_fwd(&l);
        let big_rank = d.decomposed_fwd(&l, 60, 60);
        let small_rank = d.decomposed_fwd(&l, 8, 8);
        assert!(big_rank > dense, "near-full-rank decomposition is slower");
        assert!(small_rank < dense, "small-rank decomposition is faster");

        // and at tiny m everything is launch-bound: decomposition loses
        // even at small rank (3 launches vs 1)
        let tiny = LayerBench::conv(64, 64, 64, 3);
        assert!(d.decomposed_fwd(&tiny, 8, 8) > d.dense_fwd(&tiny));
    }

    #[test]
    fn tile_utilization_bounds() {
        let d = DeviceProfile::tpu_v4();
        let full = d.tile_utilization(128, 128, 128);
        assert!((full - 1.0).abs() < 1e-12);
        let poor = d.tile_utilization(128, 129, 129);
        assert!(poor < 0.6);
    }

    #[test]
    fn vmem_check() {
        let d = DeviceProfile::tpu_v4();
        assert!(d.lowrank_fits_sram(128, 512, 309, 512));
        assert!(!d.lowrank_fits_sram(4096, 4096, 4096, 4096));
        // devices without an SRAM model always pass
        assert!(DeviceProfile::v100().lowrank_fits_sram(1 << 20, 4096, 4096, 4096));
    }

    #[test]
    fn profiles_resolvable_by_name() {
        for n in ["v100", "ascend910", "tpuv4", "cpu-sim"] {
            assert!(DeviceProfile::by_name(n).is_some(), "{n}");
        }
        assert!(DeviceProfile::by_name("a100").is_none());
    }

    #[test]
    fn memory_bound_small_matmuls() {
        // tiny matmuls should be overhead/memory bound, not compute bound
        let d = DeviceProfile::v100();
        let t = d.matmul_time(8, 8, 8);
        assert!(t >= d.launch_overhead);
        assert!(t < 2.0 * d.launch_overhead + 1e-6);
    }
}
