//! Training/inference coordinator — the L3 orchestration loop.
//!
//! Owns the PJRT runtime, the data pipeline, the freeze scheduler, the
//! parameter/momentum state and the metrics. Python is nowhere in sight:
//! every epoch the scheduler picks a freeze pattern, the trainer selects
//! the matching AOT executable and streams batches through it.

pub mod decompose;

use crate::checkpoint::Params;
use crate::data::{BatchIter, Dataset};
use crate::freeze::{FreezeMode, FreezeScheduler, Pattern};
use crate::metrics::{EpochRecord, RunRecord, ThroughputMeter};
use crate::runtime::{
    labels_to_literal, literal_to_tensor, scalar_literal, tensor_to_literal, ArtifactMeta,
    Executable, Manifest, Runtime,
};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;

pub use decompose::{decompose_checkpoint, zero_momenta, DecomposeOutcome};

/// Learning-rate schedule (paper: cosine for ImageNet, fixed for CIFAR).
#[derive(Clone, Copy, Debug)]
pub enum LrSchedule {
    Fixed(f32),
    Cosine { base: f32, total_epochs: usize },
}

impl LrSchedule {
    pub fn lr_at(&self, epoch: usize) -> f32 {
        match *self {
            LrSchedule::Fixed(lr) => lr,
            LrSchedule::Cosine { base, total_epochs } => {
                let t = (epoch as f32 / total_epochs.max(1) as f32).min(1.0);
                0.5 * base * (1.0 + (std::f32::consts::PI * t).cos())
            }
        }
    }
}

/// Configuration of one fine-tuning (or pretraining) run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub model: String,
    pub variant: String,
    pub freeze: FreezeMode,
    pub epochs: usize,
    pub lr: LrSchedule,
    pub train_size: usize,
    pub test_size: usize,
    pub seed: u64,
    /// Print per-epoch progress lines.
    pub verbose: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "resnet_mini".into(),
            variant: "orig".into(),
            freeze: FreezeMode::None,
            epochs: 3,
            lr: LrSchedule::Fixed(1e-3),
            train_size: 2048,
            test_size: 512,
            seed: 0,
            verbose: false,
        }
    }
}

/// The trainer: drives train-step executables over epochs.
pub struct Trainer<'rt> {
    rt: &'rt Runtime,
    manifest: &'rt Manifest,
    cfg: TrainConfig,
    /// All model parameters by name (trainable ∪ frozen across patterns).
    pub params: Params,
    /// Momentum state for every parameter (persists across pattern swaps).
    pub momenta: Params,
    /// Executables per freeze pattern actually used by the schedule.
    train_exes: BTreeMap<&'static str, (Executable, ArtifactMeta)>,
    infer_exe: Executable,
    infer_meta: ArtifactMeta,
    scheduler: FreezeScheduler,
}

impl<'rt> Trainer<'rt> {
    /// Build a trainer; `params` must already match the variant (decompose
    /// the checkpoint first for lrd/rankopt variants).
    pub fn new(
        rt: &'rt Runtime,
        manifest: &'rt Manifest,
        cfg: TrainConfig,
        params: Params,
    ) -> Result<Trainer<'rt>> {
        let scheduler = FreezeScheduler::new(cfg.freeze);
        // Original model has no factors: every pattern degrades to "none".
        let effective = |p: Pattern| -> &'static str {
            if cfg.variant == "orig" {
                "none"
            } else {
                match p {
                    Pattern::NoFreeze => "none",
                    Pattern::A => "a",
                    Pattern::B => "b",
                }
            }
        };
        let mut needed: Vec<&'static str> = (0..cfg.epochs.max(1))
            .map(|e| effective(scheduler.pattern(e)))
            .collect();
        needed.sort_unstable();
        needed.dedup();

        let mut train_exes = BTreeMap::new();
        for suffix in needed {
            let name = Manifest::name_of(&cfg.model, &cfg.variant, "train", suffix);
            let meta = manifest.artifact(&name)?.clone();
            let exe = rt.load_hlo(manifest.hlo_path(&meta))?;
            train_exes.insert(suffix, (exe, meta));
        }
        let infer_name = Manifest::name_of(&cfg.model, &cfg.variant, "infer", "none");
        let infer_meta = manifest.artifact(&infer_name)?.clone();
        let infer_exe = rt.load_hlo(manifest.hlo_path(&infer_meta))?;

        let momenta = zero_momenta(&params);
        Ok(Trainer {
            rt,
            manifest,
            cfg,
            params,
            momenta,
            train_exes,
            infer_exe,
            infer_meta,
            scheduler,
        })
    }

    /// Run the configured number of epochs; returns the full record.
    pub fn run(&mut self) -> Result<RunRecord> {
        let train = Dataset::synthetic(self.cfg.train_size, self.cfg.seed);
        let test = Dataset::synthetic(self.cfg.test_size, self.cfg.seed ^ 0xDEAD_BEEF);
        let mut record = RunRecord::new(format!(
            "{}_{}_{:?}",
            self.cfg.model, self.cfg.variant, self.cfg.freeze
        ));

        for epoch in 0..self.cfg.epochs {
            let lr = self.cfg.lr.lr_at(epoch);
            let suffix = if self.cfg.variant == "orig" {
                "none"
            } else {
                self.scheduler.pattern(epoch).suffix()
            };
            // direct field access keeps the exe borrow disjoint from the
            // params/momenta mutations inside the step loop
            let (exe, meta) = self
                .train_exes
                .get(suffix)
                .ok_or_else(|| anyhow!("no train executable for pattern '{suffix}'"))?;
            let batch = meta.batch;
            let pattern = suffix.to_string();

            let mut meter = ThroughputMeter::new(batch);
            let mut loss_sum = 0.0f64;
            let mut correct_sum = 0.0f64;
            let mut samples = 0usize;
            let mut n_batches = 0usize;
            for (xs, ys) in BatchIter::new(&train, batch, self.cfg.seed ^ epoch as u64) {
                let t0 = std::time::Instant::now();
                let (loss, correct) =
                    run_train_step(exe, meta, &mut self.params, &mut self.momenta, &xs, &ys, lr)?;
                meter.record(t0.elapsed().as_secs_f64());
                loss_sum += loss as f64;
                correct_sum += correct as f64;
                samples += ys.len();
                n_batches += 1;
            }

            let test_acc = self.evaluate(&test)?;
            let rec = EpochRecord {
                epoch,
                loss: loss_sum / n_batches.max(1) as f64,
                train_acc: correct_sum / samples.max(1) as f64,
                test_acc,
                step_secs: meter.median_step(),
                freeze_pattern: pattern.clone(),
            };
            if self.cfg.verbose {
                println!(
                    "[{}] epoch {:>3} pattern={} lr={:.5} loss={:.4} train_acc={:.3} test_acc={:.3} step={:.1}ms fps={:.0}",
                    record.name, epoch, pattern, lr, rec.loss, rec.train_acc, rec.test_acc,
                    rec.step_secs * 1e3, meter.fps()
                );
            }
            record.epochs.push(rec);
        }
        Ok(record)
    }

    /// Accuracy of the current parameters on a dataset (drops the partial
    /// final batch — constant AOT batch shape).
    pub fn evaluate(&self, data: &Dataset) -> Result<f64> {
        evaluate_with(&self.infer_exe, &self.infer_meta, &self.params, data)
    }

    /// Measured inference throughput (fps) over `reps` batches.
    pub fn infer_fps(&self, reps: usize) -> Result<f64> {
        let batch = self.infer_meta.batch;
        let data = Dataset::synthetic(batch, 123);
        let (xs, _) = data.batch(0, batch);
        let mut inputs = Vec::new();
        for slot in &self.infer_meta.trainable {
            inputs.push(tensor_to_literal(&self.params[&slot.name])?);
        }
        let x_dims: Vec<i64> = self.infer_meta.x_shape.iter().map(|&d| d as i64).collect();
        inputs.push(xla::Literal::vec1(&xs).reshape(&x_dims)?);
        let input_refs: Vec<&xla::Literal> = inputs.iter().collect();
        let mut meter = ThroughputMeter::new(batch);
        // warmup
        self.infer_exe.run(&input_refs)?;
        for _ in 0..reps {
            let t0 = std::time::Instant::now();
            self.infer_exe.run(&input_refs)?;
            meter.record(t0.elapsed().as_secs_f64());
        }
        Ok(meter.fps())
    }

    pub fn manifest(&self) -> &Manifest {
        self.manifest
    }
    pub fn runtime(&self) -> &Runtime {
        self.rt
    }
    pub fn config(&self) -> &TrainConfig {
        &self.cfg
    }
}

/// Pretrain the dense model and cache the checkpoint under
/// `results/cache/` keyed by (model, epochs, train_size, seed) so examples
/// and benches share one pretraining run (the paper's "load ImageNet
/// pretrained weights" step, at our scale).
pub fn ensure_pretrained(
    rt: &Runtime,
    manifest: &Manifest,
    model: &str,
    epochs: usize,
    train_size: usize,
    seed: u64,
) -> Result<Params> {
    let cache = format!("results/cache/{model}_pre_e{epochs}_n{train_size}_s{seed}.bin");
    if std::path::Path::new(&cache).exists() {
        return crate::checkpoint::load(&cache);
    }
    let cfg = TrainConfig {
        model: model.to_string(),
        variant: "orig".into(),
        freeze: FreezeMode::None,
        epochs,
        lr: LrSchedule::Fixed(5e-3),
        train_size,
        test_size: 256,
        seed,
        verbose: true,
    };
    let init = crate::checkpoint::load(manifest.init_checkpoint(model)?)?;
    let mut trainer = Trainer::new(rt, manifest, cfg, init)?;
    trainer.run()?;
    crate::checkpoint::save(&cache, &trainer.params)?;
    Ok(trainer.params.clone())
}

/// One SGD train step through an AOT executable.
///
/// Input order (the AOT contract from `python/compile/aot.py`):
/// `[trainable…, frozen…, momenta(trainable)…, x, y, lr]`; output order:
/// `[new_trainable…, new_momenta…, loss, correct]`. Updates `params` and
/// `momenta` in place and returns `(loss, correct)`.
pub fn run_train_step(
    exe: &Executable,
    meta: &ArtifactMeta,
    params: &mut Params,
    momenta: &mut Params,
    xs: &[f32],
    ys: &[i32],
    lr: f32,
) -> Result<(f32, f32)> {
    let n_tr = meta.trainable.len();
    let mut inputs = Vec::with_capacity(meta.input_arity());
    for slot in &meta.trainable {
        let t = params
            .get(&slot.name)
            .ok_or_else(|| anyhow!("missing param {}", slot.name))?;
        inputs.push(tensor_to_literal(t)?);
    }
    for slot in &meta.frozen {
        let t = params
            .get(&slot.name)
            .ok_or_else(|| anyhow!("missing frozen param {}", slot.name))?;
        inputs.push(tensor_to_literal(t)?);
    }
    for slot in &meta.trainable {
        let m = momenta
            .get(&slot.name)
            .ok_or_else(|| anyhow!("missing momentum {}", slot.name))?;
        inputs.push(tensor_to_literal(m)?);
    }
    let x_dims: Vec<i64> = meta.x_shape.iter().map(|&d| d as i64).collect();
    inputs.push(xla::Literal::vec1(xs).reshape(&x_dims)?);
    inputs.push(labels_to_literal(ys));
    inputs.push(scalar_literal(lr));

    let outputs = exe.run(&inputs)?;
    if outputs.len() != 2 * n_tr + 2 {
        bail!(
            "train step '{}' returned {} outputs, expected {}",
            meta.name,
            outputs.len(),
            2 * n_tr + 2
        );
    }
    for (i, slot) in meta.trainable.iter().enumerate() {
        params.insert(slot.name.clone(), literal_to_tensor(&outputs[i])?);
        momenta.insert(slot.name.clone(), literal_to_tensor(&outputs[n_tr + i])?);
    }
    let loss = outputs[2 * n_tr].get_first_element::<f32>()?;
    let correct = outputs[2 * n_tr + 1].get_first_element::<f32>()?;
    Ok((loss, correct))
}

/// Evaluate `params` on `data` with an infer executable.
pub fn evaluate_with(
    exe: &Executable,
    meta: &ArtifactMeta,
    params: &Params,
    data: &Dataset,
) -> Result<f64> {
    let batch = meta.batch;
    let mut correct = 0usize;
    let mut total = 0usize;
    let mut param_lits = Vec::with_capacity(meta.trainable.len());
    for slot in &meta.trainable {
        let t = params
            .get(&slot.name)
            .ok_or_else(|| anyhow!("missing param {}", slot.name))?;
        param_lits.push(tensor_to_literal(t)?);
    }
    let x_dims: Vec<i64> = meta.x_shape.iter().map(|&d| d as i64).collect();
    let n_batches = data.len() / batch;
    for bi in 0..n_batches {
        let (xs, ys) = data.batch(bi * batch, batch);
        // borrow the cached parameter literals (uploaded once for the whole
        // evaluation) and only materialize the fresh batch input — §Perf:
        // avoids ~100 tensor↔literal round-trips per eval batch
        let x_lit = xla::Literal::vec1(&xs).reshape(&x_dims)?;
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(param_lits.len() + 1);
        inputs.extend(param_lits.iter());
        inputs.push(&x_lit);
        let out = exe.run(&inputs).context("infer batch")?;
        let logits = literal_to_tensor(&out[0])?;
        let classes = logits.shape()[1];
        for (i, &y) in ys.iter().enumerate() {
            let row = &logits.data()[i * classes..(i + 1) * classes];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(j, _)| j)
                .unwrap();
            if pred == y as usize {
                correct += 1;
            }
            total += 1;
        }
    }
    Ok(correct as f64 / total.max(1) as f64)
}


#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedules() {
        let f = LrSchedule::Fixed(0.001);
        assert_eq!(f.lr_at(0), 0.001);
        assert_eq!(f.lr_at(99), 0.001);
        let c = LrSchedule::Cosine { base: 1.0, total_epochs: 10 };
        assert!((c.lr_at(0) - 1.0).abs() < 1e-6);
        assert!((c.lr_at(10) - 0.0).abs() < 1e-6);
        let mid = c.lr_at(5);
        assert!((mid - 0.5).abs() < 1e-6);
        // monotone decreasing
        for e in 0..10 {
            assert!(c.lr_at(e + 1) <= c.lr_at(e) + 1e-9);
        }
    }

    #[test]
    fn default_config_sane() {
        let c = TrainConfig::default();
        assert_eq!(c.model, "resnet_mini");
        assert!(c.train_size >= c.test_size);
    }
}
