//! Training/inference coordinator — the L3 orchestration loop.
//!
//! Owns the PJRT runtime, the data pipeline, the freeze scheduler, the
//! parameter/momentum state and the metrics. Python is nowhere in sight:
//! every epoch the scheduler picks a freeze pattern, the trainer selects
//! the matching AOT executable and streams batches through it.
//!
//! Stepping itself is delegated: by default the trainer drives the
//! device-resident engine ([`crate::train::Engine`] — params/momenta
//! uploaded once, steps chained buffer-to-buffer, pattern swaps re-bound
//! in place) through its *pipelined* epoch driver (double-buffered batch
//! uploads, on-device metric accumulation, per-epoch eval overlapped on a
//! side thread whose results join at the next epoch boundary);
//! `TrainConfig::pipelined = false` falls back to the serial resident loop
//! and `TrainConfig::resident = false` to the original host-literal
//! round-trip loop ([`run_train_step`]) — both measurable baselines
//! (`lrta train --no-pipeline` / `--no-resident`, `bench_train_resident`).
//!
//! Scaling beyond one engine is delegated too: `lrta train --replicas N`
//! routes through [`crate::train::replica`] (N single-engine replicas on
//! disjoint shards with periodic buffer-level parameter averaging), which
//! reuses this module's schedule resolution ([`effective_pattern_suffix`])
//! so freeze swaps stay synchronized with the single-engine semantics.
//! The replica path honors `TrainConfig::pipelined` exactly like this
//! module: each replica drives the overlapped epoch loop with the
//! averaging barrier hooked in per step, or the serial loop under
//! `--no-pipeline`.
//! [`Trainer::checkpoint_epochs_to`] additionally persists each epoch's
//! snapshot asynchronously ([`train::CheckpointWriter`]).

pub mod decompose;

use crate::checkpoint::Params;
use crate::data::{DataSource, Dataset, Shard};
use crate::storage::Storage;
use crate::freeze::{FreezeMode, FreezeScheduler, Pattern};
use crate::metrics::{EpochRecord, RunRecord, ThroughputMeter};
use crate::obs::Tracer;
use crate::runtime::{
    labels_to_literal, literal_to_tensor, scalar_literal, tensor_to_literal, ArtifactMeta,
    Executable, Manifest, Runtime,
};
use crate::train;
use crate::util::stats::count_correct;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

pub use decompose::{decompose_checkpoint, zero_momenta, DecomposeOutcome};

/// Artifact-name suffix one epoch's schedule resolves to. The original
/// (undecomposed) model has no factor groups, so every pattern degrades to
/// `"none"`; decomposed variants use the pattern's own suffix. Shared by
/// [`Trainer`] and the data-parallel replicas
/// ([`crate::train::replica`]), which must resolve patterns identically
/// for their epoch-boundary swaps to stay synchronized.
pub fn effective_pattern_suffix(variant: &str, pattern: Pattern) -> &'static str {
    if variant == "orig" {
        "none"
    } else {
        pattern.suffix()
    }
}

/// Load one train executable per freeze pattern `cfg`'s schedule will
/// actually use. Shared by [`Trainer::new`] and each data-parallel replica
/// ([`crate::train::replica`]) — executables are client-local, so every
/// replica compiles its own set from the same schedule resolution.
pub fn load_schedule_executables(
    rt: &Runtime,
    manifest: &Manifest,
    cfg: &TrainConfig,
) -> Result<BTreeMap<&'static str, (Executable, ArtifactMeta)>> {
    let scheduler = FreezeScheduler::new(cfg.freeze);
    let mut needed: Vec<&'static str> = (0..cfg.epochs.max(1))
        .map(|e| effective_pattern_suffix(&cfg.variant, scheduler.pattern(e)))
        .collect();
    needed.sort_unstable();
    needed.dedup();
    let mut train_exes = BTreeMap::new();
    for suffix in needed {
        let name = Manifest::name_of(&cfg.model, &cfg.variant, "train", suffix);
        let meta = manifest.artifact(&name)?.clone();
        let exe = rt.load_hlo(manifest.hlo_path(&meta))?;
        train_exes.insert(suffix, (exe, meta));
    }
    Ok(train_exes)
}

/// Learning-rate schedule (paper: cosine for ImageNet, fixed for CIFAR).
#[derive(Clone, Copy, Debug)]
pub enum LrSchedule {
    Fixed(f32),
    Cosine { base: f32, total_epochs: usize },
}

impl LrSchedule {
    pub fn lr_at(&self, epoch: usize) -> f32 {
        match *self {
            LrSchedule::Fixed(lr) => lr,
            LrSchedule::Cosine { base, total_epochs } => {
                let t = (epoch as f32 / total_epochs.max(1) as f32).min(1.0);
                0.5 * base * (1.0 + (std::f32::consts::PI * t).cos())
            }
        }
    }
}

/// Configuration of one fine-tuning (or pretraining) run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub model: String,
    pub variant: String,
    pub freeze: FreezeMode,
    pub epochs: usize,
    pub lr: LrSchedule,
    pub train_size: usize,
    pub test_size: usize,
    pub seed: u64,
    /// Print per-epoch progress lines.
    pub verbose: bool,
    /// Step through the device-resident engine (`lrta::train`) — params
    /// and momenta uploaded once, steps chained buffer-to-buffer. `false`
    /// restores the literal round-trip baseline (`--no-resident`).
    pub resident: bool,
    /// Overlapped execution on the resident engine (`--no-pipeline` turns
    /// it off): double-buffered batch uploads + split dispatch/fetch,
    /// on-device epoch-metric accumulation (one host fetch per epoch), and
    /// per-epoch eval on a snapshot via a side thread while the next
    /// epoch's steps run. Ignored when `resident` is off.
    pub pipelined: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "resnet_mini".into(),
            variant: "orig".into(),
            freeze: FreezeMode::None,
            epochs: 3,
            lr: LrSchedule::Fixed(1e-3),
            train_size: 2048,
            test_size: 512,
            seed: 0,
            verbose: false,
            resident: true,
            pipelined: true,
        }
    }
}

/// The trainer: drives train-step executables over epochs.
pub struct Trainer<'rt> {
    rt: &'rt Runtime,
    manifest: &'rt Manifest,
    cfg: TrainConfig,
    /// All model parameters by name (trainable ∪ frozen across patterns).
    pub params: Params,
    /// Momentum state for every parameter (persists across pattern swaps).
    pub momenta: Params,
    /// Executables per freeze pattern actually used by the schedule.
    train_exes: BTreeMap<&'static str, (Executable, ArtifactMeta)>,
    infer_exe: Executable,
    infer_meta: ArtifactMeta,
    scheduler: FreezeScheduler,
    /// The device-resident engine (`None` on the `--no-resident` baseline).
    /// While it exists it holds the authoritative training state; `params`
    /// / `momenta` sync from it at the end of [`Trainer::run`].
    engine: Option<train::Engine<'rt>>,
    /// Demux fallbacks observed during the last [`Trainer::run`] — the
    /// runtime counter is cumulative, so the per-run delta is what
    /// [`Trainer::residency_report`] may honestly attribute to that run.
    last_run_fallbacks: usize,
    /// When set, each epoch's parameter snapshot also persists as
    /// `epoch_NNN.bin` on a side thread ([`train::CheckpointWriter`])
    /// while the next epoch trains — into a directory or any storage
    /// backend, per the sink.
    ckpt_sink: Option<CkptSink>,
    /// Where training batches come from: `None` synthesizes the corpus in
    /// memory (the classic path); see [`Trainer::train_from`].
    train_source: Option<DataSource>,
    /// Lifecycle span recorder (off by default); see [`Trainer::set_tracer`].
    tracer: Tracer,
}

/// Where epoch checkpoints land: the legacy directory layout, or a
/// key prefix on any [`Storage`] backend.
enum CkptSink {
    Dir(PathBuf),
    Store(Arc<dyn Storage>, String),
}

impl<'rt> Trainer<'rt> {
    /// Build a trainer; `params` must already match the variant (decompose
    /// the checkpoint first for lrd/rankopt variants).
    pub fn new(
        rt: &'rt Runtime,
        manifest: &'rt Manifest,
        cfg: TrainConfig,
        params: Params,
    ) -> Result<Trainer<'rt>> {
        let scheduler = FreezeScheduler::new(cfg.freeze);
        let train_exes = load_schedule_executables(rt, manifest, &cfg)?;
        let infer_name = Manifest::name_of(&cfg.model, &cfg.variant, "infer", "none");
        let infer_meta = manifest.artifact(&infer_name)?.clone();
        let infer_exe = rt.load_hlo(manifest.hlo_path(&infer_meta))?;

        let momenta = zero_momenta(&params);
        let engine = if cfg.resident {
            let mut engine = train::Engine::upload(rt, &params, &momenta)?;
            if cfg.pipelined {
                // prefer the AOT-lowered metrics_acc artifact when the
                // manifest carries one; the builder form is the fallback
                engine.attach_metrics(train::MetricsAccumulator::create(rt, Some(manifest))?);
            }
            Some(engine)
        } else {
            None
        };
        Ok(Trainer {
            rt,
            manifest,
            cfg,
            params,
            momenta,
            train_exes,
            infer_exe,
            infer_meta,
            scheduler,
            engine,
            last_run_fallbacks: 0,
            ckpt_sink: None,
            train_source: None,
            tracer: Tracer::default(),
        })
    }

    /// Record lifecycle spans of subsequent [`Trainer::run`]s into `tracer`
    /// (the `lrta train --trace-out` path): the engine's per-step
    /// prefetch_wait → upload → dispatch → fetch spans, the epoch-boundary
    /// `freeze_swap`, and the side-thread evaluator's `eval` spans. The
    /// default [`Tracer::noop`] records nothing.
    pub fn set_tracer(&mut self, tracer: Tracer) {
        if let Some(engine) = self.engine.as_mut() {
            engine.set_tracer(tracer.clone());
        }
        self.tracer = tracer;
    }

    /// Persist every epoch's parameters as `<dir>/epoch_NNN.bin`. The write
    /// happens on a side thread off the same per-epoch snapshot the
    /// overlapped evaluator consumes, so epoch N's checkpoint lands on disk
    /// while epoch N+1's steps already run (ROADMAP "checkpoint snapshot
    /// offload"); a failed write fails [`Trainer::run`] at its end-of-run
    /// join. Written files are byte-identical to an inline
    /// [`crate::checkpoint::save`] of the same epoch's state.
    pub fn checkpoint_epochs_to(&mut self, dir: impl Into<PathBuf>) {
        self.ckpt_sink = Some(CkptSink::Dir(dir.into()));
    }

    /// Like [`Trainer::checkpoint_epochs_to`], but uploading each epoch's
    /// checkpoint as `<prefix>/epoch_NNN.bin` through a storage backend
    /// (`lrta train --store URI`) — same side-thread overlap, same
    /// byte-identical [`crate::checkpoint::encode`] output, any backend
    /// [`crate::storage::open`] can name.
    pub fn checkpoint_epochs_to_store(&mut self, store: Arc<dyn Storage>, prefix: impl Into<String>) {
        self.ckpt_sink = Some(CkptSink::Store(store, prefix.into()));
    }

    /// Stream training batches from `source` instead of synthesizing the
    /// corpus in memory. Bit-identical batches by construction
    /// ([`crate::train::Prefetcher::start_source`]), so a streamed run's
    /// trajectory equals the in-memory run's — pinned in
    /// `rust/tests/integration_train.rs`.
    pub fn train_from(&mut self, source: DataSource) {
        self.train_source = Some(source);
    }

    /// Run the configured number of epochs; returns the full record.
    ///
    /// Both step paths (resident engine / literal baseline) consume the
    /// same batches in the same order and run the same executables, so
    /// their loss/accuracy trajectories match bit-for-bit (pinned by
    /// `rust/tests/integration_train_resident.rs`).
    pub fn run(&mut self) -> Result<RunRecord> {
        let fallbacks_before = self.rt.demux_fallbacks();
        let train_source = match &self.train_source {
            Some(source) => source.clone(),
            None => DataSource::memory(Arc::new(Dataset::synthetic(
                self.cfg.train_size,
                self.cfg.seed,
            ))),
        };
        let test = Arc::new(Dataset::synthetic(self.cfg.test_size, self.cfg.seed ^ 0xDEAD_BEEF));
        let mut record = RunRecord::new(format!(
            "{}_{}_{:?}",
            self.cfg.model, self.cfg.variant, self.cfg.freeze
        ));
        let pipelined = self.cfg.pipelined && self.engine.is_some();
        // overlapped eval: the worker owns its own PJRT client and compiles
        // the infer artifact on its thread — even that overlaps epoch 0
        let mut eval_worker = if pipelined {
            Some(train::EvalWorker::spawn(
                self.manifest.hlo_path(&self.infer_meta),
                self.infer_meta.clone(),
                Arc::clone(&test),
                self.tracer.clone(),
            ))
        } else {
            None
        };
        // async checkpointing rides the same per-epoch snapshot
        let mut ckpt_writer = self.ckpt_sink.as_ref().map(|sink| match sink {
            CkptSink::Dir(dir) => train::CheckpointWriter::spawn(dir.clone()),
            CkptSink::Store(store, prefix) => {
                train::CheckpointWriter::spawn_to(Arc::clone(store), prefix.clone())
            }
        });

        for epoch in 0..self.cfg.epochs {
            let lr = self.cfg.lr.lr_at(epoch);
            let suffix =
                effective_pattern_suffix(&self.cfg.variant, self.scheduler.pattern(epoch));
            // direct field access keeps the exe borrow disjoint from the
            // params/momenta/engine mutations inside the step loop
            let (exe, meta) = self
                .train_exes
                .get(suffix)
                .ok_or_else(|| anyhow!("no train executable for pattern '{suffix}'"))?;
            let batch = meta.batch;
            let pattern = suffix.to_string();
            let epoch_seed = self.cfg.seed ^ epoch as u64;

            let (meter, loss, train_acc) = if let Some(engine) = self.engine.as_mut() {
                // epoch boundary: Algorithm 2 may have swapped pattern a↔b
                // — re-bind the resident buffers to the new slot layout
                // (pure permutation; uploads nothing)
                let swap_span = self.tracer.start();
                engine.state().rebind_for(meta)?;
                self.tracer.end(swap_span, "train", "freeze_swap");
                let stats = if pipelined {
                    engine.run_epoch_pipelined_sharded(
                        exe,
                        meta,
                        &train_source,
                        epoch_seed,
                        lr,
                        Shard::full(),
                        &mut |_, _| Ok(()),
                    )?
                } else {
                    engine.run_epoch_sharded(
                        exe,
                        meta,
                        &train_source,
                        epoch_seed,
                        lr,
                        Shard::full(),
                        &mut |_, _| Ok(()),
                    )?
                };
                (stats.meter, stats.loss, stats.train_acc)
            } else {
                // the literal baseline consumes the same prefetcher the
                // engines do (identical batches, identical order), so it
                // too can train from a streamed source
                let mut meter = ThroughputMeter::new(batch);
                let mut loss_sum = 0.0f64;
                let mut correct_sum = 0.0f64;
                let mut samples = 0usize;
                let mut n_batches = 0usize;
                let mut pf =
                    train::Prefetcher::start_source(&train_source, batch, epoch_seed, Shard::full());
                while let Some((xs, ys)) = pf.next_batch() {
                    let t0 = std::time::Instant::now();
                    let (loss, correct) = run_train_step(
                        exe,
                        meta,
                        &mut self.params,
                        &mut self.momenta,
                        &xs,
                        &ys,
                        lr,
                    )?;
                    meter.record(t0.elapsed().as_secs_f64());
                    loss_sum += loss as f64;
                    correct_sum += correct as f64;
                    samples += ys.len();
                    n_batches += 1;
                }
                let loss = loss_sum / n_batches.max(1) as f64;
                (meter, loss, correct_sum / samples.max(1) as f64)
            };

            // one parameter snapshot per epoch serves both overlapped
            // consumers: the side-thread evaluator and the async checkpoint
            // writer — the download is the single synchronous cost here
            let mut snapshot = if eval_worker.is_some() || ckpt_writer.is_some() {
                Some(match &self.engine {
                    Some(engine) => engine.state().params.download()?,
                    None => self.params.clone(),
                })
            } else {
                None
            };
            if let Some(writer) = &mut ckpt_writer {
                let snap = snapshot.as_ref().expect("snapshot taken when a consumer exists");
                // clone only when the eval worker also needs the snapshot
                if eval_worker.is_some() {
                    writer.submit(epoch, snap.clone())?;
                } else {
                    writer.submit(epoch, snapshot.take().expect("checked above"))?;
                }
            }
            // eval is a semantically-required host sync point. Overlapped
            // mode hands the snapshot to the side-thread worker and keeps
            // going (the accuracy lands in the record at the next epoch
            // boundary / end-of-run join); the serial paths evaluate
            // inline as before.
            let test_acc = match (&mut eval_worker, &self.engine) {
                (Some(worker), Some(_)) => {
                    worker.submit(epoch, snapshot.take().expect("eval worker implies snapshot"))?;
                    f64::NAN // placeholder until the worker reports back
                }
                (_, Some(engine)) => {
                    engine.evaluate(&self.infer_exe, &self.infer_meta, &test)?
                }
                (_, None) => self.evaluate(&test)?,
            };
            let rec = EpochRecord {
                epoch,
                loss,
                train_acc,
                test_acc,
                step_secs: meter.median_step(),
                freeze_pattern: pattern.clone(),
            };
            if self.cfg.verbose {
                let acc_col = if test_acc.is_nan() {
                    "pending".to_string()
                } else {
                    format!("{test_acc:.3}")
                };
                println!(
                    "[{}] epoch {:>3} pattern={} lr={:.5} loss={:.4} train_acc={:.3} test_acc={} step={:.1}ms fps={:.0}",
                    record.name, epoch, pattern, lr, rec.loss, rec.train_acc, acc_col,
                    rec.step_secs * 1e3, meter.fps()
                );
            }
            record.epochs.push(rec);
            // join point: absorb whatever the eval worker finished while
            // this epoch ran (the "next freeze-pattern swap" boundary)
            if let Some(worker) = &mut eval_worker {
                for (e, acc) in worker.try_collect()? {
                    record.epochs[e].test_acc = acc;
                    if self.cfg.verbose {
                        println!(
                            "[{}] epoch {e:>3} test_acc={acc:.3} (overlapped eval)",
                            record.name
                        );
                    }
                }
            }
        }
        // end-of-run join: every submitted epoch must report before the
        // record leaves this function
        if let Some(worker) = &mut eval_worker {
            for (e, acc) in worker.drain()? {
                record.epochs[e].test_acc = acc;
                if self.cfg.verbose {
                    println!(
                        "[{}] epoch {e:>3} test_acc={acc:.3} (overlapped eval)",
                        record.name
                    );
                }
            }
        }

        // end-of-run join for the async checkpoints: every submitted epoch
        // must be durably on disk (or fail the run) before we return
        if let Some(writer) = &mut ckpt_writer {
            for (e, loc) in writer.drain()? {
                if self.cfg.verbose {
                    println!("[{}] epoch {e:>3} checkpoint {loc}", record.name);
                }
            }
        }

        // final host sync: the resident engine held the authoritative state
        // for the whole run — download it once so checkpointing and the
        // public `params`/`momenta` fields see the trained values
        if let Some(engine) = &self.engine {
            let (params, momenta) = engine.sync()?;
            self.params = params;
            self.momenta = momenta;
        }
        self.last_run_fallbacks = self.rt.demux_fallbacks() - fallbacks_before;
        Ok(record)
    }

    /// Accuracy of the current parameters on a dataset (drops the partial
    /// final batch — constant AOT batch shape).
    pub fn evaluate(&self, data: &Dataset) -> Result<f64> {
        evaluate_with(&self.infer_exe, &self.infer_meta, &self.params, data)
    }

    /// Measured inference throughput (fps) over `reps` batches, on the
    /// shared resident-params path (`train::ResidentParams`) — parameters
    /// upload once and every rep runs against the device buffers, exactly
    /// what the serving engines measure. The resident engine's buffers are
    /// reused when the trainer has one; the `--no-resident` baseline
    /// uploads a temporary set (still once, not per rep).
    pub fn infer_fps(&self, reps: usize) -> Result<f64> {
        let batch = self.infer_meta.batch;
        let data = Dataset::synthetic(batch, 123);
        let (xs, _) = data.batch(0, batch);
        let slots = || self.infer_meta.trainable.iter().chain(self.infer_meta.frozen.iter());
        let temp;
        let resident = match &self.engine {
            Some(engine) => &engine.state().params,
            None => {
                temp = train::ResidentParams::upload_for_slots(self.rt, &self.params, slots())?;
                &temp
            }
        };
        let x_dims: Vec<i64> = self.infer_meta.x_shape.iter().map(|&d| d as i64).collect();
        let x_buf = self.rt.upload(&xla::Literal::vec1(&xs).reshape(&x_dims)?)?;
        let mut inputs = resident.ordered(slots())?;
        inputs.push(&x_buf);
        let mut meter = ThroughputMeter::new(batch);
        // warmup
        self.infer_exe.run_buffers(&inputs)?;
        for _ in 0..reps {
            let t0 = std::time::Instant::now();
            let outs = self.infer_exe.run_buffers(&inputs)?;
            // force completion: the logits must actually reach the host
            let _ = Executable::buffer_to_literals(&outs[0])?;
            meter.record(t0.elapsed().as_secs_f64());
        }
        Ok(meter.fps())
    }

    /// Host→device parameter/momentum uploads performed by the resident
    /// engine (`None` on the literal baseline). Stays at the initial
    /// upload count for the whole run: steps chain buffer-to-buffer and
    /// pattern swaps re-bind — they never re-upload (pinned by
    /// `rust/tests/integration_train_resident.rs`).
    pub fn param_uploads(&self) -> Option<usize> {
        self.engine.as_ref().map(|e| e.param_uploads())
    }

    /// One-line transfer accounting for the last resident [`Trainer::run`]
    /// (`None` on the literal baseline). The single source of the
    /// "buffer-to-buffer" claim the CLI and examples print — it only makes
    /// the claim when this run's demux-fallback delta is actually zero.
    pub fn residency_report(&self) -> Option<String> {
        let uploads = self.param_uploads()?;
        Some(if self.last_run_fallbacks == 0 {
            format!(
                "resident engine: {uploads} parameter uploads total (steps + pattern swaps \
                 chained buffer-to-buffer)"
            )
        } else {
            format!(
                "resident engine: {uploads} parameter uploads, but {} demux fallbacks — the \
                 backend packed tuple outputs, steps round-tripped through the host",
                self.last_run_fallbacks
            )
        })
    }

    pub fn manifest(&self) -> &Manifest {
        self.manifest
    }
    pub fn runtime(&self) -> &Runtime {
        self.rt
    }
    pub fn config(&self) -> &TrainConfig {
        &self.cfg
    }
}

/// Pretrain the dense model and cache the checkpoint under
/// `results/cache/` keyed by (model, epochs, train_size, seed) so examples
/// and benches share one pretraining run (the paper's "load ImageNet
/// pretrained weights" step, at our scale).
pub fn ensure_pretrained(
    rt: &Runtime,
    manifest: &Manifest,
    model: &str,
    epochs: usize,
    train_size: usize,
    seed: u64,
) -> Result<Params> {
    let cache = format!("results/cache/{model}_pre_e{epochs}_n{train_size}_s{seed}.bin");
    if std::path::Path::new(&cache).exists() {
        return crate::checkpoint::load(&cache);
    }
    let cfg = TrainConfig {
        model: model.to_string(),
        variant: "orig".into(),
        freeze: FreezeMode::None,
        epochs,
        lr: LrSchedule::Fixed(5e-3),
        train_size,
        test_size: 256,
        seed,
        verbose: true,
        resident: true,
        pipelined: true,
    };
    let init = crate::checkpoint::load(manifest.init_checkpoint(model)?)?;
    let mut trainer = Trainer::new(rt, manifest, cfg, init)?;
    trainer.run()?;
    crate::checkpoint::save(&cache, &trainer.params)?;
    Ok(trainer.params.clone())
}

/// One SGD train step through an AOT executable.
///
/// Input order (the AOT contract from `python/compile/aot.py`):
/// `[trainable…, frozen…, momenta(trainable)…, x, y, lr]`; output order:
/// `[new_trainable…, new_momenta…, loss, correct]`. Updates `params` and
/// `momenta` in place and returns `(loss, correct)`.
pub fn run_train_step(
    exe: &Executable,
    meta: &ArtifactMeta,
    params: &mut Params,
    momenta: &mut Params,
    xs: &[f32],
    ys: &[i32],
    lr: f32,
) -> Result<(f32, f32)> {
    let n_tr = meta.trainable.len();
    let mut inputs = Vec::with_capacity(meta.input_arity());
    for slot in &meta.trainable {
        let t = params
            .get(&slot.name)
            .ok_or_else(|| anyhow!("missing param {}", slot.name))?;
        inputs.push(tensor_to_literal(t)?);
    }
    for slot in &meta.frozen {
        let t = params
            .get(&slot.name)
            .ok_or_else(|| anyhow!("missing frozen param {}", slot.name))?;
        inputs.push(tensor_to_literal(t)?);
    }
    for slot in &meta.trainable {
        let m = momenta
            .get(&slot.name)
            .ok_or_else(|| anyhow!("missing momentum {}", slot.name))?;
        inputs.push(tensor_to_literal(m)?);
    }
    let x_dims: Vec<i64> = meta.x_shape.iter().map(|&d| d as i64).collect();
    inputs.push(xla::Literal::vec1(xs).reshape(&x_dims)?);
    inputs.push(labels_to_literal(ys));
    inputs.push(scalar_literal(lr));

    let outputs = exe.run(&inputs)?;
    if outputs.len() != 2 * n_tr + 2 {
        bail!(
            "train step '{}' returned {} outputs, expected {}",
            meta.name,
            outputs.len(),
            2 * n_tr + 2
        );
    }
    for (i, slot) in meta.trainable.iter().enumerate() {
        params.insert(slot.name.clone(), literal_to_tensor(&outputs[i])?);
        momenta.insert(slot.name.clone(), literal_to_tensor(&outputs[n_tr + i])?);
    }
    let loss = outputs[2 * n_tr].get_first_element::<f32>()?;
    let correct = outputs[2 * n_tr + 1].get_first_element::<f32>()?;
    Ok((loss, correct))
}

/// Evaluate `params` on `data` with an infer executable.
pub fn evaluate_with(
    exe: &Executable,
    meta: &ArtifactMeta,
    params: &Params,
    data: &Dataset,
) -> Result<f64> {
    let batch = meta.batch;
    let mut correct = 0usize;
    let mut total = 0usize;
    let mut param_lits = Vec::with_capacity(meta.trainable.len());
    for slot in &meta.trainable {
        let t = params
            .get(&slot.name)
            .ok_or_else(|| anyhow!("missing param {}", slot.name))?;
        param_lits.push(tensor_to_literal(t)?);
    }
    let x_dims: Vec<i64> = meta.x_shape.iter().map(|&d| d as i64).collect();
    let n_batches = data.len() / batch;
    for bi in 0..n_batches {
        let (xs, ys) = data.batch(bi * batch, batch);
        // borrow the cached parameter literals (uploaded once for the whole
        // evaluation) and only materialize the fresh batch input — §Perf:
        // avoids ~100 tensor↔literal round-trips per eval batch
        let x_lit = xla::Literal::vec1(&xs).reshape(&x_dims)?;
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(param_lits.len() + 1);
        inputs.extend(param_lits.iter());
        inputs.push(&x_lit);
        let out = exe.run(&inputs).context("infer batch")?;
        let logits = literal_to_tensor(&out[0])?;
        let classes = logits.shape()[1];
        // NaN-safe: a single NaN logit used to panic the whole evaluation
        // through `partial_cmp().unwrap()` (now total_cmp in argmax_f32)
        correct += count_correct(logits.data(), classes, &ys);
        total += ys.len();
    }
    Ok(correct as f64 / total.max(1) as f64)
}


#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedules() {
        let f = LrSchedule::Fixed(0.001);
        assert_eq!(f.lr_at(0), 0.001);
        assert_eq!(f.lr_at(99), 0.001);
        let c = LrSchedule::Cosine { base: 1.0, total_epochs: 10 };
        assert!((c.lr_at(0) - 1.0).abs() < 1e-6);
        assert!((c.lr_at(10) - 0.0).abs() < 1e-6);
        let mid = c.lr_at(5);
        assert!((mid - 0.5).abs() < 1e-6);
        // monotone decreasing
        for e in 0..10 {
            assert!(c.lr_at(e + 1) <= c.lr_at(e) + 1e-9);
        }
    }

    #[test]
    fn default_config_sane() {
        let c = TrainConfig::default();
        assert_eq!(c.model, "resnet_mini");
        assert!(c.train_size >= c.test_size);
        // the resident engine is the default; --no-resident is the baseline
        assert!(c.resident);
        // overlapped execution is the default; --no-pipeline is the
        // serial-resident baseline
        assert!(c.pipelined);
    }
}
