//! Checkpoint decomposition: apply the closed-form LRD engine to a trained
//! dense checkpoint, producing the parameter set for a decomposed variant
//! with exactly the ranks the variant's AOT artifacts were lowered for.
//!
//! Layout bridging: python stores convs HWIO (`[k,k,C,S]`) while the LRD
//! math (paper Eq. 4) works on `[C,S,k,k]`; permutes happen here and only
//! here.

use crate::checkpoint::Params;
use crate::lrd::{svd_linear, tucker2_conv};
use crate::runtime::LayerCfg;
use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::time::Instant;

/// Result of decomposing a checkpoint.
pub struct DecomposeOutcome {
    pub params: Params,
    /// Wall time spent in factorization (Table 2's "decomposition time").
    pub secs: f64,
    /// Σ‖W − W'‖² across decomposed layers (Eq. 3).
    pub total_reconstruction_err: f64,
    pub layers_decomposed: usize,
}

/// Decompose `dense` according to `config` (from the manifest).
///
/// Non-decomposed entries (biases, norms, dense-kept layers) are copied
/// through unchanged — which is also what makes freezing sound: the copied
/// factors are the *optimal* closed-form reconstruction.
pub fn decompose_checkpoint(
    dense: &Params,
    config: &BTreeMap<String, LayerCfg>,
) -> Result<DecomposeOutcome> {
    let t0 = Instant::now();
    let mut out = Params::new();
    let mut err = 0.0f64;
    let mut count = 0usize;

    // copy everything first; decomposed layers then replace their `.w`
    for (name, t) in dense {
        out.insert(name.clone(), t.clone());
    }

    for (layer, cfg) in config {
        match cfg {
            LayerCfg::Dense => {}
            LayerCfg::Svd { rank, .. } => {
                let wname = format!("{layer}.w");
                let w = dense
                    .get(&wname)
                    .ok_or_else(|| anyhow!("missing dense weight {wname}"))?;
                if w.ndim() != 2 {
                    bail!("{wname}: SVD layer must be 2-D, got {:?}", w.shape());
                }
                let f = svd_linear(w, *rank);
                err += w.dist2(&f.reconstruct()) as f64;
                out.remove(&wname);
                out.insert(format!("{layer}.a"), f.a);
                out.insert(format!("{layer}.b"), f.b);
                count += 1;
            }
            LayerCfg::Tucker { r1, r2, .. } => {
                let wname = format!("{layer}.w");
                let w = dense
                    .get(&wname)
                    .ok_or_else(|| anyhow!("missing dense weight {wname}"))?;
                if w.ndim() != 4 {
                    bail!("{wname}: Tucker layer must be 4-D, got {:?}", w.shape());
                }
                // HWIO -> [C,S,k,k]
                let w_cs = w.permute(&[2, 3, 0, 1]);
                let f = tucker2_conv(&w_cs, *r1, *r2);
                err += w_cs.dist2(&f.reconstruct()) as f64;
                out.remove(&wname);
                out.insert(format!("{layer}.first"), f.first);
                // core [r1,r2,k,k] -> HWIO [k,k,r1,r2]
                out.insert(format!("{layer}.core"), f.core.permute(&[2, 3, 0, 1]));
                out.insert(format!("{layer}.last"), f.last);
                count += 1;
            }
        }
    }

    Ok(DecomposeOutcome {
        params: out,
        secs: t0.elapsed().as_secs_f64(),
        total_reconstruction_err: err,
        layers_decomposed: count,
    })
}

/// Fresh zero momenta matching a parameter set.
pub fn zero_momenta(params: &Params) -> Params {
    params.iter().map(|(k, t)| (k.clone(), Tensor::zeros(t.shape()))).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn cfg_svd(layer: &str, rank: usize) -> BTreeMap<String, LayerCfg> {
        let mut c = BTreeMap::new();
        c.insert(layer.to_string(), LayerCfg::Svd { rank, r_min: 1 });
        c
    }

    #[test]
    fn svd_layer_replaced_by_factors() {
        let mut rng = Rng::new(50);
        let mut dense = Params::new();
        dense.insert("fc.w".into(), Tensor::randn(&[16, 12], 1.0, &mut rng));
        dense.insert("fc.bias".into(), Tensor::zeros(&[12]));
        let out = decompose_checkpoint(&dense, &cfg_svd("fc", 4)).unwrap();
        assert!(!out.params.contains_key("fc.w"));
        assert_eq!(out.params["fc.a"].shape(), &[16, 4]);
        assert_eq!(out.params["fc.b"].shape(), &[4, 12]);
        assert_eq!(out.params["fc.bias"].shape(), &[12]);
        assert_eq!(out.layers_decomposed, 1);
        assert!(out.total_reconstruction_err > 0.0);
    }

    #[test]
    fn full_rank_svd_error_is_tiny() {
        let mut rng = Rng::new(51);
        let mut dense = Params::new();
        dense.insert("fc.w".into(), Tensor::randn(&[8, 8], 1.0, &mut rng));
        let out = decompose_checkpoint(&dense, &cfg_svd("fc", 8)).unwrap();
        assert!(out.total_reconstruction_err < 1e-6, "{}", out.total_reconstruction_err);
    }

    #[test]
    fn tucker_layer_layouts() {
        let mut rng = Rng::new(52);
        let mut dense = Params::new();
        // HWIO [3,3,C=8,S=10]
        dense.insert("conv.w".into(), Tensor::randn(&[3, 3, 8, 10], 1.0, &mut rng));
        let mut c = BTreeMap::new();
        c.insert("conv".to_string(), LayerCfg::Tucker { r1: 4, r2: 5, r_min: 1 });
        let out = decompose_checkpoint(&dense, &c).unwrap();
        assert_eq!(out.params["conv.first"].shape(), &[8, 4]);
        assert_eq!(out.params["conv.core"].shape(), &[3, 3, 4, 5]);
        assert_eq!(out.params["conv.last"].shape(), &[5, 10]);
    }

    #[test]
    fn tucker_full_rank_roundtrips_through_layouts() {
        // decompose at full rank, reconstruct, compare to the original
        // HWIO weight — catches permute-order mistakes.
        let mut rng = Rng::new(53);
        let w = Tensor::randn(&[3, 3, 6, 7], 1.0, &mut rng);
        let mut dense = Params::new();
        dense.insert("c.w".into(), w.clone());
        let mut c = BTreeMap::new();
        c.insert("c".to_string(), LayerCfg::Tucker { r1: 6, r2: 7, r_min: 1 });
        let out = decompose_checkpoint(&dense, &c).unwrap();
        assert!(out.total_reconstruction_err < 1e-4, "{}", out.total_reconstruction_err);
    }

    #[test]
    fn missing_weight_errors() {
        let dense = Params::new();
        assert!(decompose_checkpoint(&dense, &cfg_svd("ghost", 2)).is_err());
    }

    #[test]
    fn wrong_ndim_errors() {
        let mut dense = Params::new();
        dense.insert("fc.w".into(), Tensor::zeros(&[2, 2, 2]));
        assert!(decompose_checkpoint(&dense, &cfg_svd("fc", 2)).is_err());
    }

    #[test]
    fn zero_momenta_match_shapes() {
        let mut rng = Rng::new(54);
        let mut p = Params::new();
        p.insert("a".into(), Tensor::randn(&[3, 3], 1.0, &mut rng));
        let m = zero_momenta(&p);
        assert_eq!(m["a"].shape(), &[3, 3]);
        assert!(m["a"].data().iter().all(|&v| v == 0.0));
    }
}
