//! Property-based testing harness (proptest is unavailable offline).
//!
//! Provides deterministic random-case generation with seed reporting and
//! greedy input shrinking for integer-vector cases. Each property runs N
//! cases; on failure the harness re-runs with progressively smaller inputs
//! and reports the minimal failing case plus the seed to reproduce.

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 128, seed: 0xC0FFEE }
    }
}

/// Run `prop` against `cases` inputs drawn by `gen`. Panics with the seed
/// and case index on the first failure (after attempting to shrink via the
/// optional `shrink` function).
pub fn forall<T: Clone + std::fmt::Debug>(
    cfg: Config,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let mut case_rng = rng.fork(case as u64);
        let input = gen(&mut case_rng);
        if !prop(&input) {
            panic!(
                "property failed at case {case} (seed {:#x})\ninput: {:?}",
                cfg.seed, input
            );
        }
    }
}

/// Like [`forall`] but shrinks the failing input with `shrink` (which must
/// return strictly "smaller" candidates) before reporting.
pub fn forall_shrink<T: Clone + std::fmt::Debug>(
    cfg: Config,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> bool,
    shrink: impl Fn(&T) -> Vec<T>,
) {
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let mut case_rng = rng.fork(case as u64);
        let input = gen(&mut case_rng);
        if !prop(&input) {
            // Greedy shrink: repeatedly take the first smaller candidate
            // that still fails, until none do.
            let mut minimal = input.clone();
            'outer: loop {
                for cand in shrink(&minimal) {
                    if !prop(&cand) {
                        minimal = cand;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property failed at case {case} (seed {:#x})\noriginal: {:?}\nshrunk: {:?}",
                cfg.seed, input, minimal
            );
        }
    }
}

/// Shrinker for `Vec<T>`: drop halves, then single elements. Every
/// candidate is *strictly shorter* than the input — required for the
/// greedy loop in [`forall_shrink`] to terminate.
pub fn shrink_vec<T: Clone>(v: &[T]) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    let n = v.len();
    if n == 0 {
        return out;
    }
    let first = &v[..n / 2];
    let second = &v[n / 2..];
    if first.len() < n {
        out.push(first.to_vec());
    }
    if second.len() < n {
        out.push(second.to_vec());
    }
    if n <= 16 {
        for i in 0..n {
            let mut c = v.to_vec();
            c.remove(i);
            out.push(c);
        }
    }
    out
}

/// Shrinker for usize toward a lower bound.
pub fn shrink_usize(x: usize, lo: usize) -> Vec<usize> {
    let mut out = Vec::new();
    if x > lo {
        out.push(lo);
        out.push(lo + (x - lo) / 2);
        out.push(x - 1);
    }
    out.dedup();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        forall(
            Config::default(),
            |r| r.below(100),
            |&x| x < 100,
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        forall(
            Config { cases: 64, seed: 1 },
            |r| r.below(10),
            |&x| x < 5,
        );
    }

    #[test]
    fn shrinking_finds_small_case() {
        // Property "no vector contains a 7" fails; the shrunk case should
        // be small (a single-element or tiny vector containing 7).
        let result = std::panic::catch_unwind(|| {
            forall_shrink(
                Config { cases: 256, seed: 2 },
                |r| (0..r.below(20) + 1).map(|_| r.below(10)).collect::<Vec<_>>(),
                |v| !v.contains(&7),
                |v| shrink_vec(v),
            );
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("shrunk"), "{msg}");
        // extract the shrunk vector length: it should have shrunk to <= 2 elems
        let shrunk = msg.split("shrunk: ").nth(1).unwrap();
        let commas = shrunk.matches(',').count();
        assert!(commas <= 1, "shrunk case not minimal: {shrunk}");
    }

    #[test]
    fn shrink_usize_moves_toward_lo() {
        let c = shrink_usize(10, 2);
        assert!(c.contains(&2));
        assert!(c.iter().all(|&x| x < 10));
        assert!(shrink_usize(2, 2).is_empty());
    }

    #[test]
    fn deterministic_across_runs() {
        let mut firsts = Vec::new();
        for _ in 0..2 {
            let mut captured = Vec::new();
            forall(
                Config { cases: 5, seed: 99 },
                |r| r.below(1000),
                |&x| {
                    captured.push(x);
                    true
                },
            );
            firsts.push(captured);
        }
        assert_eq!(firsts[0], firsts[1]);
    }
}
