//! Foundational utilities built from scratch for the offline environment:
//! RNG, JSON, statistics, CLI parsing, property testing, and benchmarking.

pub mod bench;
pub mod check;
pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
