//! Deterministic pseudo-random number generation.
//!
//! The environment is fully offline and the experiment harness must be
//! reproducible bit-for-bit across runs, so we implement a small,
//! well-understood generator (xorshift64*; Vigna 2014) rather than pull in
//! `rand`. Quality is far beyond what synthetic-data generation and
//! property-test case generation require.

/// xorshift64* PRNG. Deterministic, seedable, `Copy`-cheap.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed. A zero seed is remapped (xorshift
    /// has an all-zero fixed point).
    pub fn new(seed: u64) -> Self {
        Rng { state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed } }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        // use the top 24 bits for a uniformly distributed mantissa
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // multiply-shift; bias is negligible for n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi].
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi >= lo);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.next_f32().max(1e-12);
        let u2 = self.next_f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Vector of uniforms in [lo, hi).
    pub fn uniform_vec(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.uniform(lo, hi)).collect()
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fork an independent stream (e.g. per-epoch, per-worker) without
    /// correlating with the parent sequence.
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn zero_seed_is_remapped() {
        let mut r = Rng::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x), "{x}");
        }
    }

    #[test]
    fn below_bounds_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "astronomically unlikely identity");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut parent = Rng::new(5);
        let mut a = parent.fork(0);
        let mut b = parent.fork(1);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
