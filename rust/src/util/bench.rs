//! Benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup + repeated timed runs with robust statistics, throughput
//! accounting, and aligned table printing used by every `rust/benches/*`
//! target to regenerate the paper's tables and figures.

use super::json::Json;
use super::stats::Summary;
use std::time::Instant;

/// Result of timing one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time in seconds.
    pub secs: Summary,
    /// Items processed per iteration (for throughput), if set.
    pub items_per_iter: Option<f64>,
}

impl BenchResult {
    /// Median throughput in items/second (e.g. images/s == fps).
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter.map(|ipi| ipi / self.secs.median)
    }
    pub fn median_ms(&self) -> f64 {
        self.secs.median * 1e3
    }
}

/// Timing configuration.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub measure_iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_iters: 3, measure_iters: 15 }
    }
}

/// Time `f` (one logical iteration per call).
pub fn bench(name: &str, cfg: &BenchConfig, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut samples = Vec::with_capacity(cfg.measure_iters);
    for _ in 0..cfg.measure_iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    BenchResult { name: name.to_string(), secs: Summary::of(&samples), items_per_iter: None }
}

/// Time `f` and attach a throughput denominator (items per iteration).
pub fn bench_throughput(
    name: &str,
    cfg: &BenchConfig,
    items_per_iter: f64,
    f: impl FnMut(),
) -> BenchResult {
    let mut r = bench(name, cfg, f);
    r.items_per_iter = Some(items_per_iter);
    r
}

/// Render a fixed-width text table. `rows` are cell strings; the first row
/// is the header. Columns are sized to content.
pub fn table(rows: &[Vec<String>]) -> String {
    if rows.is_empty() {
        return String::new();
    }
    let cols = rows.iter().map(|r| r.len()).max().unwrap();
    let mut widths = vec![0usize; cols];
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.chars().count());
        }
    }
    let mut out = String::new();
    for (ri, row) in rows.iter().enumerate() {
        out.push('|');
        for (i, w) in widths.iter().enumerate() {
            let cell = row.get(i).map(|s| s.as_str()).unwrap_or("");
            out.push(' ');
            out.push_str(cell);
            for _ in cell.chars().count()..*w {
                out.push(' ');
            }
            out.push_str(" |");
        }
        out.push('\n');
        if ri == 0 {
            out.push('|');
            for w in &widths {
                out.push_str(&"-".repeat(w + 2));
                out.push('|');
            }
            out.push('\n');
        }
    }
    out
}

/// Write a report file (creating parent directories) and echo the path.
/// Used by bench targets so every table/figure lands in a file. A report is
/// a side artifact: write failure (read-only fs, bad path) logs a warning
/// and returns `false` instead of killing a finished benchmark run.
pub fn write_report(path: &str, content: &str) -> bool {
    if let Some(dir) = std::path::Path::new(path).parent() {
        if let Err(e) = std::fs::create_dir_all(dir) {
            eprintln!("[report] WARN: cannot create {}: {e}", dir.display());
            return false;
        }
    }
    match std::fs::write(path, content) {
        Ok(()) => {
            println!("[report] wrote {path}");
            true
        }
        Err(e) => {
            eprintln!("[report] WARN: cannot write {path}: {e}");
            false
        }
    }
}

/// Merge `section` under `key` into the JSON report at `path`, preserving
/// every other top-level key — several bench targets append their results
/// to one perf-trajectory file (`BENCH_pipeline.json`) without clobbering
/// each other. A fresh/unreadable file starts from an empty object. Same
/// side-artifact contract as [`write_report`]: failures warn, return
/// `false`, and never kill a finished benchmark run.
pub fn write_json_section(path: &str, key: &str, section: Json) -> bool {
    let mut root = std::fs::read_to_string(path)
        .ok()
        .and_then(|s| Json::parse(&s).ok())
        .and_then(|j| match j {
            Json::Obj(o) => Some(o),
            _ => None,
        })
        .unwrap_or_default();
    root.insert(key.to_string(), section);
    // every JSON report carries provenance under "meta" (re-stamped on each
    // merge, so the timestamp/commit reflect the latest writer)
    root.insert("meta".to_string(), report_meta());
    write_report(path, &Json::Obj(root).emit())
}

/// Provenance stamp attached (as the top-level `"meta"` key) to every JSON
/// report written through [`write_json_section`]: a schema version for the
/// report layout, the git commit the binary was built from, and the
/// wall-clock write time — so a `results/BENCH_*.json` found on disk is
/// attributable without external context.
pub fn report_meta() -> Json {
    let git_commit = std::process::Command::new("git")
        .args(["rev-parse", "HEAD"])
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .unwrap_or_else(|| "unknown".to_string());
    let unix_time = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    Json::obj(vec![
        ("schema_version", Json::int(1)),
        ("git_commit", Json::str(&git_commit)),
        ("unix_time", Json::int(unix_time as i64)),
    ])
}

/// The transfer counters every runtime-backed bench surfaces in its JSON
/// report, so upload regressions and demux fallbacks are visible in the
/// perf trajectory (not just inside integration tests).
pub fn runtime_counters_json(rt: &crate::runtime::Runtime) -> Json {
    Json::obj(vec![
        ("uploads", Json::int(rt.uploads() as i64)),
        ("demux_fallbacks", Json::int(rt.demux_fallbacks() as i64)),
        ("fetches", Json::int(rt.fetches() as i64)),
    ])
}

/// Format a signed percentage delta the way the paper's tables do (+06.07).
pub fn fmt_delta_pct(base: f64, new: f64) -> String {
    let pct = (new / base - 1.0) * 100.0;
    format!("{}{:05.2}", if pct >= 0.0 { "+" } else { "-" }, pct.abs())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iterations() {
        let mut n = 0usize;
        let cfg = BenchConfig { warmup_iters: 2, measure_iters: 5 };
        let r = bench("count", &cfg, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(r.secs.n, 5);
    }

    #[test]
    fn throughput_is_items_over_median() {
        let cfg = BenchConfig { warmup_iters: 0, measure_iters: 3 };
        let r = bench_throughput("t", &cfg, 100.0, || {
            std::thread::sleep(std::time::Duration::from_millis(2))
        });
        let fps = r.throughput().unwrap();
        assert!(fps > 1_000.0 && fps < 60_000.0, "fps {fps}");
    }

    #[test]
    fn table_is_aligned() {
        let t = table(&[
            vec!["Method".into(), "fps".into()],
            vec!["LRD".into(), "367".into()],
            vec!["Combined".into(), "505".into()],
        ]);
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        let w = lines[0].len();
        assert!(lines.iter().all(|l| l.len() == w), "{t}");
    }

    #[test]
    fn delta_pct_matches_paper_format() {
        assert_eq!(fmt_delta_pct(346.0, 367.0), "+06.07");
        assert_eq!(fmt_delta_pct(100.0, 60.0), "-40.00");
    }

    #[test]
    fn write_report_creates_dirs() {
        let path = "/tmp/lrta_test_reports/sub/r.txt";
        let _ = std::fs::remove_dir_all("/tmp/lrta_test_reports");
        assert!(write_report(path, "hello"));
        assert_eq!(std::fs::read_to_string(path).unwrap(), "hello");
    }

    #[test]
    fn json_sections_merge_without_clobbering() {
        let path = "/tmp/lrta_test_reports/bench.json";
        let _ = std::fs::remove_file(path);
        assert!(write_json_section(path, "a", Json::obj(vec![("x", Json::int(1))])));
        assert!(write_json_section(path, "b", Json::obj(vec![("y", Json::int(2))])));
        // overwrite of one section keeps the other
        assert!(write_json_section(path, "a", Json::obj(vec![("x", Json::int(3))])));
        let root = Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
        assert_eq!(root.get("a").get("x").as_i64(), Some(3));
        assert_eq!(root.get("b").get("y").as_i64(), Some(2));
    }

    #[test]
    fn json_sections_are_stamped_with_provenance_meta() {
        let path = "/tmp/lrta_test_reports/meta.json";
        let _ = std::fs::remove_file(path);
        assert!(write_json_section(path, "results", Json::int(42)));
        let root = Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
        assert_eq!(root.get("results").as_i64(), Some(42));
        let meta = root.get("meta");
        assert_eq!(meta.get("schema_version").as_i64(), Some(1));
        // a real 40-hex sha in a git checkout, "unknown" otherwise — but
        // always present and non-empty
        let commit = meta.get("git_commit").as_str().unwrap();
        assert!(!commit.is_empty());
        assert!(commit == "unknown" || commit.len() == 40, "commit: {commit}");
        assert!(meta.get("unix_time").as_i64().unwrap() > 1_500_000_000);
    }

    #[test]
    fn json_section_recovers_from_corrupt_file() {
        let path = "/tmp/lrta_test_reports/corrupt.json";
        std::fs::create_dir_all("/tmp/lrta_test_reports").unwrap();
        std::fs::write(path, "not json at all").unwrap();
        assert!(write_json_section(path, "k", Json::int(7)));
        let root = Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
        assert_eq!(root.get("k").as_i64(), Some(7));
    }

    #[test]
    fn write_report_failure_warns_instead_of_panicking() {
        // parent "directory" is a regular file -> create_dir_all must fail
        let blocker = "/tmp/lrta_test_reports_blocker";
        let _ = std::fs::remove_dir_all(blocker);
        let _ = std::fs::remove_file(blocker);
        std::fs::write(blocker, "file").unwrap();
        assert!(!write_report(&format!("{blocker}/sub/r.txt"), "hello"));
        let _ = std::fs::remove_file(blocker);
    }
}
