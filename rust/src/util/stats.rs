//! Small statistics toolkit used by the timing/benchmark harness and the
//! rank-optimization sweep: robust location estimates (median, percentiles),
//! dispersion, and simple summaries for reporting.

/// Summary statistics over a sample of measurements (e.g. step times).
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p25: f64,
    pub median: f64,
    pub p75: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of on empty sample");
        let mut s = xs.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = s.len();
        let mean = s.iter().sum::<f64>() / n as f64;
        let var = s.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: s[0],
            p25: percentile_sorted(&s, 25.0),
            median: percentile_sorted(&s, 50.0),
            p75: percentile_sorted(&s, 75.0),
            p99: percentile_sorted(&s, 99.0),
            max: s[n - 1],
        }
    }
}

/// Linear-interpolated percentile of a *sorted* sample, p in [0, 100].
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0) * (n - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi.min(n - 1)] * frac
}

/// Median of an unsorted sample.
pub fn median(xs: &[f64]) -> f64 {
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&s, 50.0)
}

/// Arithmetic mean.
pub fn mean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// First discrete derivative Δy[i] = y[i+1] - y[i]; output len = len-1.
pub fn diff(ys: &[f64]) -> Vec<f64> {
    ys.windows(2).map(|w| w[1] - w[0]).collect()
}

/// NaN-safe argmax over f32 logits (IEEE total order). The evaluation
/// paths used `partial_cmp().unwrap()`, which panics the whole run on a
/// single NaN logit; under `total_cmp` a (positive) NaN simply ranks above
/// +∞, so a corrupted row yields a (wrong) prediction instead of a crash.
/// Last index wins ties — same as the `max_by` it replaces. 0 on empty.
pub fn argmax_f32(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Rows of flat `[n, classes]` logits whose [`argmax_f32`] equals the
/// label — the one accuracy-counting loop shared by the literal and
/// resident evaluation paths and the serving spot check.
pub fn count_correct(logits: &[f32], classes: usize, ys: &[i32]) -> usize {
    ys.iter()
        .enumerate()
        .filter(|&(i, &y)| argmax_f32(&logits[i * classes..(i + 1) * classes]) == y as usize)
        .count()
}

/// Index of the maximum value (first on ties). None on empty input.
pub fn argmax(xs: &[f64]) -> Option<usize> {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal).then(b.0.cmp(&a.0)))
        .map(|(i, _)| i)
}

/// Index of the minimum value (first on ties).
pub fn argmin(xs: &[f64]) -> Option<usize> {
    argmax(&xs.iter().map(|x| -x).collect::<Vec<_>>())
}

/// Ordinary least squares fit y = a + b x; returns (a, b).
pub fn linreg(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let mx = mean(xs);
    let my = mean(ys);
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    if sxx == 0.0 {
        return (my, 0.0);
    }
    let b = sxy / sxx;
    (my - b * mx, b)
}

/// Exponential moving average over a series.
pub fn ema(xs: &[f64], alpha: f64) -> Vec<f64> {
    let mut out = Vec::with_capacity(xs.len());
    let mut acc = f64::NAN;
    for &x in xs {
        acc = if acc.is_nan() { x } else { alpha * x + (1.0 - alpha) * acc };
        out.push(acc);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn percentile_interpolates() {
        let s = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile_sorted(&s, 0.0), 10.0);
        assert_eq!(percentile_sorted(&s, 100.0), 40.0);
        assert!((percentile_sorted(&s, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn median_even_odd() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 3.0, 2.0]), 2.5);
    }

    #[test]
    fn diff_and_argmax() {
        let ys = [5.0, 5.0, 3.0, 2.9, 2.9];
        let d = diff(&ys);
        assert_eq!(d.len(), 4);
        // ties at 0.0 (indices 0 and 3): first wins
        assert_eq!(argmax(&d), Some(0));
        assert_eq!(argmin(&d), Some(1)); // steepest drop
    }

    #[test]
    fn argmax_f32_basic() {
        assert_eq!(argmax_f32(&[0.1, 2.0, -1.0]), 1);
        assert_eq!(argmax_f32(&[-3.0]), 0);
        assert_eq!(argmax_f32(&[]), 0);
    }

    #[test]
    fn count_correct_rows() {
        // 3 rows × 2 classes; labels hit rows 0 and 2
        let logits = [1.0, 0.0, 1.0, 0.0, 0.0, 1.0];
        assert_eq!(count_correct(&logits, 2, &[0, 1, 1]), 3);
        assert_eq!(count_correct(&logits, 2, &[1, 0, 0]), 0);
        assert_eq!(count_correct(&logits, 2, &[0, 0, 0]), 2);
        assert_eq!(count_correct(&[], 2, &[]), 0);
    }

    #[test]
    fn argmax_f32_survives_nan_logits() {
        // regression: `partial_cmp().unwrap()` panicked here and took the
        // whole evaluation down with it
        assert_eq!(argmax_f32(&[f32::NAN, 1.0, 0.5]), 0); // +NaN tops the total order
        assert_eq!(argmax_f32(&[1.0, f32::NEG_INFINITY, 0.5]), 0);
        assert_eq!(argmax_f32(&[f32::NAN, f32::NAN]), 1); // all-NaN: no panic
    }

    #[test]
    fn argmax_first_on_ties() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), Some(1));
        assert_eq!(argmax(&[]), None);
    }

    #[test]
    fn linreg_recovers_line() {
        let xs: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 + 0.5 * x).collect();
        let (a, b) = linreg(&xs, &ys);
        assert!((a - 2.0).abs() < 1e-9);
        assert!((b - 0.5).abs() < 1e-9);
    }

    #[test]
    fn ema_converges() {
        let xs = vec![1.0; 50];
        let e = ema(&xs, 0.1);
        assert!((e[49] - 1.0).abs() < 1e-12);
    }
}
