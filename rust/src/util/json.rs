//! Minimal JSON parser and emitter.
//!
//! The AOT artifact manifest (`artifacts/manifest.json`) and experiment
//! configs are JSON; serde is not available offline, so this implements the
//! subset of RFC 8259 we need: objects, arrays, strings (with escapes),
//! numbers, booleans, null. Numbers are parsed as f64; integer accessors
//! round-trip exactly for |n| < 2^53.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are kept in a BTreeMap for deterministic
/// emission (stable diffs of generated manifests).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// `obj["key"]`-style access; returns Null for missing keys/non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }
    /// Array index access; Null when out of range.
    pub fn at(&self, idx: usize) -> &Json {
        static NULL: Json = Json::Null;
        self.as_arr().and_then(|a| a.get(idx)).unwrap_or(&NULL)
    }

    // ---- constructors ----------------------------------------------------

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
    pub fn int(n: i64) -> Json {
        Json::Num(n as f64)
    }
    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    // ---- emission ----------------------------------------------------------

    /// Compact single-line emission.
    pub fn emit(&self) -> String {
        let mut out = String::new();
        self.emit_into(&mut out);
        out
    }

    fn emit_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => emit_str(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.emit_into(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    emit_str(k, out);
                    out.push(':');
                    v.emit_into(out);
                }
                out.push('}');
            }
        }
    }
}

fn emit_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 sequence
                    let s = &self.b[self.i..];
                    let ch_len = utf8_len(s[0]);
                    let chunk = std::str::from_utf8(&s[..ch_len.min(s.len())])
                        .map_err(|_| "invalid utf-8")?;
                    out.push_str(chunk);
                    self.i += chunk.len();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number '{s}': {e}"))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").at(0).as_i64(), Some(1));
        assert_eq!(v.get("a").at(2).get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
    }

    #[test]
    fn missing_key_is_null() {
        let v = Json::parse("{}").unwrap();
        assert_eq!(v.get("nope"), &Json::Null);
        assert_eq!(v.get("nope").as_str(), None);
    }

    #[test]
    fn string_escapes_roundtrip() {
        let s = "line\nquote\"back\\slash\ttab";
        let j = Json::str(s);
        let emitted = j.emit();
        assert_eq!(Json::parse(&emitted).unwrap().as_str(), Some(s));
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap().as_str(), Some("A"));
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo ✓\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ✓"));
    }

    #[test]
    fn emit_roundtrip_structure() {
        let j = Json::obj(vec![
            ("ints", Json::arr(vec![Json::int(1), Json::int(-3)])),
            ("f", Json::num(0.25)),
            ("flag", Json::Bool(true)),
            ("nul", Json::Null),
        ]);
        assert_eq!(Json::parse(&j.emit()).unwrap(), j);
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(Json::int(1000).emit(), "1000");
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn deep_mixed_document() {
        let src = r#"
        {
          "artifacts": [
            {"name": "resnet_mini_orig_infer", "path": "artifacts/x.hlo.txt",
             "inputs": [{"name": "x", "shape": [64, 32, 32, 3]}]}
          ],
          "version": 1
        }"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("version").as_i64(), Some(1));
        let a = &v.get("artifacts").at(0);
        assert_eq!(a.get("name").as_str(), Some("resnet_mini_orig_infer"));
        assert_eq!(
            a.get("inputs").at(0).get("shape").as_arr().unwrap().len(),
            4
        );
    }
}
