//! Tiny command-line argument parser (clap is unavailable offline).
//!
//! Supports `subcommand --flag --key value --key=value positional` layouts,
//! typed accessors with defaults, and a usage printer. Unknown flags are an
//! error so typos fail loudly.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    /// Option names that are declared (for unknown-flag detection).
    declared: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (first token = subcommand if it
    /// does not start with '-'). `declared` lists accepted option names
    /// (without leading dashes); pass an empty slice to accept anything.
    pub fn parse_tokens(tokens: &[String], declared: &[&str]) -> Result<Args, String> {
        let mut a = Args {
            declared: declared.iter().map(|s| s.to_string()).collect(),
            ..Default::default()
        };
        let mut it = tokens.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                a.subcommand = Some(it.next().unwrap().clone());
            }
        }
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                if !a.declared.is_empty() && !a.declared.iter().any(|d| d == &key) {
                    return Err(format!("unknown option --{key}"));
                }
                let val = match inline_val {
                    Some(v) => v,
                    None => match it.peek() {
                        // A following token that is not another option is
                        // this option's value; otherwise it's a bare flag.
                        Some(next) if !next.starts_with("--") => it.next().unwrap().clone(),
                        _ => "true".to_string(),
                    },
                };
                a.flags.insert(key, val);
            } else {
                a.positional.push(tok.clone());
            }
        }
        Ok(a)
    }

    /// Parse from the process environment, skipping argv[0].
    pub fn from_env(declared: &[&str]) -> Result<Args, String> {
        let tokens: Vec<String> = std::env::args().skip(1).collect();
        Args::parse_tokens(&tokens, declared)
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Comma-separated list accessor (`--variants orig,lrd,rankopt`);
    /// entries are trimmed and empties dropped. `default` applies when the
    /// flag is absent.
    pub fn list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get(key) {
            Some(v) => v
                .split(',')
                .map(|s| s.trim())
                .filter(|s| !s.is_empty())
                .map(|s| s.to_string())
                .collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(_) => default,
            None => default,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = Args::parse_tokens(&toks("train --epochs 10 --model resnet_mini"), &["epochs", "model"]).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.usize_or("epochs", 0), 10);
        assert_eq!(a.str_or("model", ""), "resnet_mini");
    }

    #[test]
    fn equals_syntax() {
        let a = Args::parse_tokens(&toks("--lr=0.01"), &["lr"]).unwrap();
        assert_eq!(a.f64_or("lr", 0.0), 0.01);
    }

    #[test]
    fn bare_flag_is_true() {
        let a = Args::parse_tokens(&toks("run --verbose --n 3"), &["verbose", "n"]).unwrap();
        assert!(a.bool_or("verbose", false));
        assert_eq!(a.usize_or("n", 0), 3);
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(Args::parse_tokens(&toks("--oops 1"), &["ok"]).is_err());
    }

    #[test]
    fn empty_declared_accepts_all() {
        let a = Args::parse_tokens(&toks("--anything works"), &[]).unwrap();
        assert_eq!(a.get("anything"), Some("works"));
    }

    #[test]
    fn positional_args() {
        let a = Args::parse_tokens(&toks("report table1 table2 --out x.md"), &["out"]).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("report"));
        assert_eq!(a.positional, vec!["table1", "table2"]);
    }

    #[test]
    fn comma_lists() {
        let a = Args::parse_tokens(&toks("serve --variants orig,lrd, rankopt"), &["variants"])
            .unwrap();
        // note: " rankopt" arrives as a separate token in real argv only if
        // quoted; here the parser sees "orig,lrd," and trims/drops empties
        assert_eq!(a.list_or("variants", &[]), vec!["orig", "lrd"]);
        let b = Args::parse_tokens(&toks("serve"), &["variants"]).unwrap();
        assert_eq!(b.list_or("variants", &["orig", "lrd"]), vec!["orig", "lrd"]);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse_tokens(&[], &["k"]).unwrap();
        assert_eq!(a.usize_or("k", 7), 7);
        assert_eq!(a.f64_or("k", 1.5), 1.5);
        assert!(!a.bool_or("k", false));
    }
}
