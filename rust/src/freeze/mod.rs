//! Layer freezing — the paper's Algorithm 2 and §2.2.
//!
//! After LRD, the factor weights are already the closed-form minimizers of
//! the reconstruction error, so the paper freezes all but one factor per
//! decomposed layer during fine-tuning:
//!
//! - **Regular freezing**: pattern fixed for the whole fine-tune
//!   (SVD: freeze `L_r(0)` = factor `a`; Tucker: freeze the two 1×1s,
//!   train the core).
//! - **Sequential freezing** (Algorithm 2): alternate the pattern every
//!   epoch, so every factor gets fine-tuned while the *per-epoch* number of
//!   trainable layers matches the original model.
//!
//! In this system a freeze pattern is not a `requires_grad` bit — it
//! selects which AOT train-step executable runs (the frozen factors were
//! never differentiated in that artifact). The scheduler's only job is to
//! map `(mode, epoch) → pattern`, plus bookkeeping used by reports/tests.

/// Freezing mode for a fine-tuning run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FreezeMode {
    /// All factors trainable (vanilla LRD / original model).
    None,
    /// Paper §2.2 first form: pattern "a" every epoch.
    Regular,
    /// Paper Algorithm 2: alternate "a" (even epochs) / "b" (odd epochs).
    Sequential,
}

impl FreezeMode {
    pub fn parse(s: &str) -> Option<FreezeMode> {
        match s {
            "none" => Some(FreezeMode::None),
            "regular" => Some(FreezeMode::Regular),
            "sequential" | "seq" => Some(FreezeMode::Sequential),
            _ => None,
        }
    }
}

/// Which factor group is frozen this epoch. Matches the AOT artifact
/// naming: pattern "a" freezes SVD `a` / Tucker `first`+`last`; pattern
/// "b" freezes the complement; "none" freezes nothing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pattern {
    NoFreeze,
    A,
    B,
}

impl Pattern {
    /// Artifact-name suffix for this pattern.
    pub fn suffix(&self) -> &'static str {
        match self {
            Pattern::NoFreeze => "none",
            Pattern::A => "a",
            Pattern::B => "b",
        }
    }
}

/// The epoch scheduler (Algorithm 2).
#[derive(Clone, Debug)]
pub struct FreezeScheduler {
    pub mode: FreezeMode,
}

impl FreezeScheduler {
    pub fn new(mode: FreezeMode) -> Self {
        FreezeScheduler { mode }
    }

    /// Pattern for epoch `e` (0-based). Algorithm 2: `e % 2 == 0` → freeze
    /// group "a" (SVD `L_r(0)` / Tucker `L_r(0)`+`L_r(2)`), else group "b".
    pub fn pattern(&self, epoch: usize) -> Pattern {
        match self.mode {
            FreezeMode::None => Pattern::NoFreeze,
            FreezeMode::Regular => Pattern::A,
            FreezeMode::Sequential => {
                if epoch % 2 == 0 {
                    Pattern::A
                } else {
                    Pattern::B
                }
            }
        }
    }

    /// Does the scheduler ever train every factor? (Sequential: yes;
    /// Regular: no — pattern-a factors never thaw.)
    pub fn covers_all_factors(&self, epochs: usize) -> bool {
        match self.mode {
            FreezeMode::None => true,
            FreezeMode::Regular => false,
            FreezeMode::Sequential => epochs >= 2,
        }
    }
}

/// Bookkeeping: which factor parameter names are frozen under a pattern.
/// `layer_kinds` maps layer name → ("svd" | "tucker"). Mirrors
/// `python/compile/train.py::frozen_names_for_pattern` (pinned by tests).
pub fn frozen_param_names(
    layer_kinds: &[(String, String)],
    pattern: Pattern,
) -> Vec<String> {
    let mut out = Vec::new();
    for (layer, kind) in layer_kinds {
        match (kind.as_str(), pattern) {
            ("svd", Pattern::A) => out.push(format!("{layer}.a")),
            ("svd", Pattern::B) => out.push(format!("{layer}.b")),
            ("tucker", Pattern::A) => {
                out.push(format!("{layer}.first"));
                out.push(format!("{layer}.last"));
            }
            ("tucker", Pattern::B) => out.push(format!("{layer}.core")),
            _ => {}
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_never_freezes() {
        let s = FreezeScheduler::new(FreezeMode::None);
        for e in 0..10 {
            assert_eq!(s.pattern(e), Pattern::NoFreeze);
        }
    }

    #[test]
    fn regular_is_constant_a() {
        let s = FreezeScheduler::new(FreezeMode::Regular);
        for e in 0..10 {
            assert_eq!(s.pattern(e), Pattern::A);
        }
        assert!(!s.covers_all_factors(100));
    }

    #[test]
    fn sequential_alternates_per_algorithm2() {
        let s = FreezeScheduler::new(FreezeMode::Sequential);
        assert_eq!(s.pattern(0), Pattern::A); // e%2==0: freeze L_r(0)[,L_r(2)]
        assert_eq!(s.pattern(1), Pattern::B);
        assert_eq!(s.pattern(2), Pattern::A);
        assert_eq!(s.pattern(3), Pattern::B);
        assert!(s.covers_all_factors(2));
        assert!(!s.covers_all_factors(1));
    }

    #[test]
    fn pattern_suffixes_match_artifacts() {
        assert_eq!(Pattern::NoFreeze.suffix(), "none");
        assert_eq!(Pattern::A.suffix(), "a");
        assert_eq!(Pattern::B.suffix(), "b");
    }

    #[test]
    fn frozen_names_svd_and_tucker() {
        let kinds = vec![
            ("fc".to_string(), "svd".to_string()),
            ("conv".to_string(), "tucker".to_string()),
        ];
        let a = frozen_param_names(&kinds, Pattern::A);
        assert_eq!(a, vec!["fc.a", "conv.first", "conv.last"]);
        let b = frozen_param_names(&kinds, Pattern::B);
        assert_eq!(b, vec!["fc.b", "conv.core"]);
        assert!(frozen_param_names(&kinds, Pattern::NoFreeze).is_empty());
    }

    #[test]
    fn sequential_partitions_factors() {
        // every factor frozen in A is trainable in B and vice versa
        let kinds = vec![
            ("l1".to_string(), "svd".to_string()),
            ("l2".to_string(), "tucker".to_string()),
        ];
        let a: std::collections::BTreeSet<_> =
            frozen_param_names(&kinds, Pattern::A).into_iter().collect();
        let b: std::collections::BTreeSet<_> =
            frozen_param_names(&kinds, Pattern::B).into_iter().collect();
        assert!(a.is_disjoint(&b));
        // union = all factor params
        let all: std::collections::BTreeSet<_> = ["l1.a", "l1.b", "l2.first", "l2.core", "l2.last"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let union: std::collections::BTreeSet<_> = a.union(&b).cloned().collect();
        assert_eq!(union, all);
    }

    #[test]
    fn mode_parsing() {
        assert_eq!(FreezeMode::parse("none"), Some(FreezeMode::None));
        assert_eq!(FreezeMode::parse("regular"), Some(FreezeMode::Regular));
        assert_eq!(FreezeMode::parse("sequential"), Some(FreezeMode::Sequential));
        assert_eq!(FreezeMode::parse("seq"), Some(FreezeMode::Sequential));
        assert_eq!(FreezeMode::parse("x"), None);
    }
}
