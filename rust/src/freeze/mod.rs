//! Layer freezing — the paper's Algorithm 2 and §2.2.
//!
//! After LRD, the factor weights are already the closed-form minimizers of
//! the reconstruction error, so the paper freezes all but one factor per
//! decomposed layer during fine-tuning:
//!
//! - **Regular freezing**: pattern fixed for the whole fine-tune
//!   (SVD: freeze `L_r(0)` = factor `a`; Tucker: freeze the two 1×1s,
//!   train the core).
//! - **Sequential freezing** (Algorithm 2): alternate the pattern every
//!   epoch, so every factor gets fine-tuned while the *per-epoch* number of
//!   trainable layers matches the original model.
//!
//! In this system a freeze pattern is not a `requires_grad` bit — it
//! selects which AOT train-step executable runs (the frozen factors were
//! never differentiated in that artifact). The scheduler's only job is to
//! map `(mode, epoch) → pattern`, plus bookkeeping used by reports/tests.

/// Freezing mode for a fine-tuning run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FreezeMode {
    /// All factors trainable (vanilla LRD / original model).
    None,
    /// Paper §2.2 first form: pattern "a" every epoch.
    Regular,
    /// Paper Algorithm 2: alternate "a" (even epochs) / "b" (odd epochs).
    Sequential,
}

impl FreezeMode {
    pub fn parse(s: &str) -> Option<FreezeMode> {
        match s {
            "none" => Some(FreezeMode::None),
            "regular" => Some(FreezeMode::Regular),
            "sequential" | "seq" => Some(FreezeMode::Sequential),
            _ => None,
        }
    }
}

/// Which factor group is frozen this epoch. Matches the AOT artifact
/// naming: pattern "a" freezes SVD `a` / Tucker `first`+`last`; pattern
/// "b" freezes the complement; "none" freezes nothing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pattern {
    NoFreeze,
    A,
    B,
}

impl Pattern {
    /// Artifact-name suffix for this pattern.
    pub fn suffix(&self) -> &'static str {
        match self {
            Pattern::NoFreeze => "none",
            Pattern::A => "a",
            Pattern::B => "b",
        }
    }
}

/// The epoch scheduler (Algorithm 2).
#[derive(Clone, Debug)]
pub struct FreezeScheduler {
    pub mode: FreezeMode,
}

impl FreezeScheduler {
    pub fn new(mode: FreezeMode) -> Self {
        FreezeScheduler { mode }
    }

    /// Pattern for epoch `e` (0-based). Algorithm 2: `e % 2 == 0` → freeze
    /// group "a" (SVD `L_r(0)` / Tucker `L_r(0)`+`L_r(2)`), else group "b".
    pub fn pattern(&self, epoch: usize) -> Pattern {
        match self.mode {
            FreezeMode::None => Pattern::NoFreeze,
            FreezeMode::Regular => Pattern::A,
            FreezeMode::Sequential => {
                if epoch % 2 == 0 {
                    Pattern::A
                } else {
                    Pattern::B
                }
            }
        }
    }

    /// Does the scheduler ever train every factor? (Sequential: yes;
    /// Regular: no — pattern-a factors never thaw.)
    pub fn covers_all_factors(&self, epochs: usize) -> bool {
        match self.mode {
            FreezeMode::None => true,
            FreezeMode::Regular => false,
            FreezeMode::Sequential => epochs >= 2,
        }
    }
}

/// Role of one train-step input slot under a freeze pattern. Which *role*
/// a factor plays swaps between patterns a and b; the parameter itself
/// (and its device buffer) is the same either way.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SlotRole {
    Trainable,
    Frozen,
    /// Momentum of the trainable slot with the same name.
    Momentum,
}

/// One named input slot of a train-step executable.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SlotBinding<'a> {
    pub name: &'a str,
    pub role: SlotRole,
}

/// Ordered input-slot bindings of a train artifact — the AOT contract from
/// `python/compile/aot.py`: `[trainable…, frozen…, momenta(trainable)…]`,
/// followed by the per-step data/lr inputs. This map is the single source
/// of truth for "pattern → slot layout": the resident training engine
/// gathers device buffers in exactly this order, so an epoch-boundary
/// pattern swap is a pure re-permutation of the same buffers.
pub fn train_slot_bindings(meta: &crate::runtime::ArtifactMeta) -> Vec<SlotBinding<'_>> {
    let mut out = Vec::with_capacity(2 * meta.trainable.len() + meta.frozen.len());
    for s in &meta.trainable {
        out.push(SlotBinding { name: &s.name, role: SlotRole::Trainable });
    }
    for s in &meta.frozen {
        out.push(SlotBinding { name: &s.name, role: SlotRole::Frozen });
    }
    for s in &meta.trainable {
        out.push(SlotBinding { name: &s.name, role: SlotRole::Momentum });
    }
    out
}

/// Partition a train artifact's parameter slots by whether a data-parallel
/// averaging barrier must exchange them: **trainable** slots diverge across
/// replicas every step and must move; **frozen** slots are bit-identical on
/// every replica by construction (identical initial upload, never stepped
/// while frozen, and averaged while trainable before any thaw) and never
/// move. Momentum bindings are deliberately not returned — they mirror the
/// trainable list one-for-one and ride the caller's momentum policy.
///
/// Derived from [`train_slot_bindings`] (not from `meta.trainable` /
/// `meta.frozen` directly) so the sync plan and the executable input
/// contract can never disagree about a slot's role.
pub fn sync_slot_partition(
    meta: &crate::runtime::ArtifactMeta,
) -> (Vec<&crate::runtime::ParamSlot>, Vec<&crate::runtime::ParamSlot>) {
    let by_name: std::collections::BTreeMap<&str, &crate::runtime::ParamSlot> = meta
        .trainable
        .iter()
        .chain(meta.frozen.iter())
        .map(|s| (s.name.as_str(), s))
        .collect();
    let mut exchanged = Vec::with_capacity(meta.trainable.len());
    let mut skipped = Vec::with_capacity(meta.frozen.len());
    for b in train_slot_bindings(meta) {
        let slot = by_name.get(b.name).copied();
        match b.role {
            SlotRole::Trainable => exchanged.extend(slot),
            SlotRole::Frozen => skipped.extend(slot),
            SlotRole::Momentum => {}
        }
    }
    (exchanged, skipped)
}

/// Names a pattern swap `from → to` would have to upload fresh — i.e. slots
/// of `to` whose parameters are not covered by `from`. Patterns of the same
/// variant partition the same parameter universe, so this is empty and the
/// swap re-binds existing resident buffers without any host↔device traffic.
/// This is the pure-map form of the invariant, pinned by the unit tests
/// below; at run time `train::ResidentState::rebind_for` enforces the
/// equivalent check against the live buffer set.
pub fn rebind_upload_set(
    from: &crate::runtime::ArtifactMeta,
    to: &crate::runtime::ArtifactMeta,
) -> Vec<String> {
    let have: std::collections::BTreeSet<&str> = from
        .trainable
        .iter()
        .chain(from.frozen.iter())
        .map(|s| s.name.as_str())
        .collect();
    to.trainable
        .iter()
        .chain(to.frozen.iter())
        .filter(|s| !have.contains(s.name.as_str()))
        .map(|s| s.name.clone())
        .collect()
}

/// Bookkeeping: which factor parameter names are frozen under a pattern.
/// `layer_kinds` maps layer name → ("svd" | "tucker"). Mirrors
/// `python/compile/train.py::frozen_names_for_pattern` (pinned by tests).
pub fn frozen_param_names(
    layer_kinds: &[(String, String)],
    pattern: Pattern,
) -> Vec<String> {
    let mut out = Vec::new();
    for (layer, kind) in layer_kinds {
        match (kind.as_str(), pattern) {
            ("svd", Pattern::A) => out.push(format!("{layer}.a")),
            ("svd", Pattern::B) => out.push(format!("{layer}.b")),
            ("tucker", Pattern::A) => {
                out.push(format!("{layer}.first"));
                out.push(format!("{layer}.last"));
            }
            ("tucker", Pattern::B) => out.push(format!("{layer}.core")),
            _ => {}
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_never_freezes() {
        let s = FreezeScheduler::new(FreezeMode::None);
        for e in 0..10 {
            assert_eq!(s.pattern(e), Pattern::NoFreeze);
        }
    }

    #[test]
    fn regular_is_constant_a() {
        let s = FreezeScheduler::new(FreezeMode::Regular);
        for e in 0..10 {
            assert_eq!(s.pattern(e), Pattern::A);
        }
        assert!(!s.covers_all_factors(100));
    }

    #[test]
    fn sequential_alternates_per_algorithm2() {
        let s = FreezeScheduler::new(FreezeMode::Sequential);
        assert_eq!(s.pattern(0), Pattern::A); // e%2==0: freeze L_r(0)[,L_r(2)]
        assert_eq!(s.pattern(1), Pattern::B);
        assert_eq!(s.pattern(2), Pattern::A);
        assert_eq!(s.pattern(3), Pattern::B);
        assert!(s.covers_all_factors(2));
        assert!(!s.covers_all_factors(1));
    }

    #[test]
    fn pattern_suffixes_match_artifacts() {
        assert_eq!(Pattern::NoFreeze.suffix(), "none");
        assert_eq!(Pattern::A.suffix(), "a");
        assert_eq!(Pattern::B.suffix(), "b");
    }

    #[test]
    fn frozen_names_svd_and_tucker() {
        let kinds = vec![
            ("fc".to_string(), "svd".to_string()),
            ("conv".to_string(), "tucker".to_string()),
        ];
        let a = frozen_param_names(&kinds, Pattern::A);
        assert_eq!(a, vec!["fc.a", "conv.first", "conv.last"]);
        let b = frozen_param_names(&kinds, Pattern::B);
        assert_eq!(b, vec!["fc.b", "conv.core"]);
        assert!(frozen_param_names(&kinds, Pattern::NoFreeze).is_empty());
    }

    #[test]
    fn sequential_partitions_factors() {
        // every factor frozen in A is trainable in B and vice versa
        let kinds = vec![
            ("l1".to_string(), "svd".to_string()),
            ("l2".to_string(), "tucker".to_string()),
        ];
        let a: std::collections::BTreeSet<_> =
            frozen_param_names(&kinds, Pattern::A).into_iter().collect();
        let b: std::collections::BTreeSet<_> =
            frozen_param_names(&kinds, Pattern::B).into_iter().collect();
        assert!(a.is_disjoint(&b));
        // union = all factor params
        let all: std::collections::BTreeSet<_> = ["l1.a", "l1.b", "l2.first", "l2.core", "l2.last"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let union: std::collections::BTreeSet<_> = a.union(&b).cloned().collect();
        assert_eq!(union, all);
    }

    fn meta_of(trainable: &[&str], frozen: &[&str]) -> crate::runtime::ArtifactMeta {
        use crate::runtime::{ArtifactMeta, ParamSlot};
        let slot = |n: &&str| ParamSlot { name: n.to_string(), shape: vec![2, 2] };
        ArtifactMeta {
            name: "m_lrd_train_x".into(),
            path: std::path::PathBuf::from("x.hlo.txt"),
            model: "m".into(),
            variant: "lrd".into(),
            kind: "train".into(),
            freeze: "a".into(),
            batch: 4,
            trainable: trainable.iter().map(slot).collect(),
            frozen: frozen.iter().map(slot).collect(),
            x_shape: vec![4, 32, 32, 3],
            y_shape: Some(vec![4]),
        }
    }

    #[test]
    fn slot_bindings_follow_aot_contract() {
        let meta = meta_of(&["l.b", "fc.w"], &["l.a"]);
        let binds = train_slot_bindings(&meta);
        let got: Vec<(&str, SlotRole)> = binds.iter().map(|b| (b.name, b.role)).collect();
        assert_eq!(
            got,
            vec![
                ("l.b", SlotRole::Trainable),
                ("fc.w", SlotRole::Trainable),
                ("l.a", SlotRole::Frozen),
                ("l.b", SlotRole::Momentum),
                ("fc.w", SlotRole::Momentum),
            ]
        );
    }

    #[test]
    fn sync_partition_mirrors_slot_bindings() {
        let meta = meta_of(&["l.b", "fc.w"], &["l.a"]);
        let (exchanged, skipped) = sync_slot_partition(&meta);
        let names = |v: &[&crate::runtime::ParamSlot]| -> Vec<String> {
            v.iter().map(|s| s.name.clone()).collect()
        };
        assert_eq!(names(&exchanged), vec!["l.b".to_string(), "fc.w".to_string()]);
        assert_eq!(names(&skipped), vec!["l.a".to_string()]);
        // slots keep their shapes, so byte planning can trust the partition
        assert!(exchanged.iter().chain(&skipped).all(|s| s.shape == [2, 2]));
        // an all-trainable artifact (freeze none) skips nothing
        let (ex, sk) = sync_slot_partition(&meta_of(&["l.a", "l.b"], &[]));
        assert_eq!(ex.len(), 2);
        assert!(sk.is_empty());
    }

    #[test]
    fn pattern_swap_rebinds_without_uploads() {
        // a↔b swap the trainable/frozen roles of the factor groups; the
        // parameter universe is identical, so re-binding the *same* resident
        // buffers to the new slot layout needs zero uploads either way.
        let a = meta_of(&["l.b", "fc.w"], &["l.a"]);
        let b = meta_of(&["l.a", "fc.w"], &["l.b"]);
        assert!(rebind_upload_set(&a, &b).is_empty());
        assert!(rebind_upload_set(&b, &a).is_empty());
        // the binding maps are permutations of one name set
        let names = |m: &crate::runtime::ArtifactMeta| -> std::collections::BTreeSet<String> {
            train_slot_bindings(m).iter().map(|s| s.name.to_string()).collect()
        };
        assert_eq!(names(&a), names(&b));
    }

    #[test]
    fn rebind_to_foreign_artifact_reports_missing_buffers() {
        let a = meta_of(&["l.b"], &["l.a"]);
        let other = meta_of(&["new.w"], &["l.a"]);
        assert_eq!(rebind_upload_set(&a, &other), vec!["new.w".to_string()]);
    }

    #[test]
    fn mode_parsing() {
        assert_eq!(FreezeMode::parse("none"), Some(FreezeMode::None));
        assert_eq!(FreezeMode::parse("regular"), Some(FreezeMode::Regular));
        assert_eq!(FreezeMode::parse("sequential"), Some(FreezeMode::Sequential));
        assert_eq!(FreezeMode::parse("seq"), Some(FreezeMode::Sequential));
        assert_eq!(FreezeMode::parse("x"), None);
    }
}
