//! `lrta` — leader entrypoint / CLI.
//!
//! Subcommands:
//!   pretrain   train the original (dense) mini model, save a checkpoint
//!   decompose  apply closed-form LRD to a checkpoint (variant ranks)
//!   train      fine-tune a variant with a freezing schedule (optionally
//!              data-parallel across N engine replicas with buffer-level
//!              parameter averaging)
//!   infer      batched-inference throughput of a variant
//!   serve      production-style inference serving: dynamic batching,
//!              resident parameters, variant routing + synthetic load
//!   rank-opt   run Algorithm 1 for a layer shape on a timing backend
//!   pipeline   pretrain → decompose → fine-tune → evaluate, end to end
//!   info       print manifest / artifact inventory
//!
//! Everything runs on the PJRT CPU client against the AOT artifacts in
//! `artifacts/` — python is never invoked.

use anyhow::{anyhow, bail, Result};
use lrta::checkpoint;
use lrta::coordinator::{decompose_checkpoint, LrSchedule, TrainConfig, Trainer};
use lrta::data::Dataset;
use lrta::devmodel::DeviceProfile;
use lrta::faults;
use lrta::freeze::FreezeMode;
use lrta::lrd::LayerShape;
use lrta::obs::{Registry, Tracer};
use lrta::rankopt::{optimize_rank, ModelTimer, PjrtTimer, RankOptConfig};
use lrta::runtime::{Manifest, Runtime};
use lrta::serve as serve_load;
use lrta::serve::{
    Class, HedgeConfig, QosConfig, Server, ServerConfig, StatsSnapshot, VariantSpec,
};
use lrta::data::{DataSource, StreamingProvider};
use lrta::storage::{self, Storage};
use lrta::train::{run_replicas_sourced, MomentumPolicy, ReplicaConfig, SyncCompress};
use lrta::util::bench::table;
use lrta::util::cli::Args;
use std::sync::Arc;
use std::time::Duration;

const USAGE: &str = "\
lrta — Low-Rank Training Acceleration (sequential freezing + rank quantization)

USAGE: lrta <subcommand> [options]

SUBCOMMANDS
  info                                    manifest inventory
  pretrain  --model M --epochs N --out F  train dense model, save checkpoint
  decompose --model M --variant V --ckpt F --out F
  train     --model M --variant V --freeze {none|regular|sequential}
            --epochs N --ckpt F [--lr X] [--cosine] [--out F] [--no-resident]
            [--no-pipeline] [--replicas N] [--avg-every K]
            [--momenta {avg|reset}] [--sync-compress {exact|q8}]
            [--epoch-ckpts DIR] [--store URI] [--data-store URI]
            [--no-evict] [--barrier-timeout-ms D]
  infer     --model M --variant V --ckpt F [--reps N]
  serve     --model M [--variants orig,lrd,rankopt] [--ckpt F]
            [--requests N] [--concurrency C] [--depth D]
            [--max-wait-ms X] [--spot-check N] [--reupload] [--burst]
            [--no-pipeline] [--shards N] [--slo-ms D] [--no-supervise]
            [--classes SPEC] [--degrade SPEC] [--hedge-ms D] [--qos-check]
            [--swap-store URI] [--swap-key K] [--swap-variant V]
  rank-opt  --c C --s S --k K [--m M] [--alpha A]
            [--backend {v100|ascend910|tpuv4|pjrt}]
  pipeline  --model M --variant V --freeze MODE [--pretrain-epochs N]
            [--epochs N]

COMMON
  --manifest PATH   (default artifacts/manifest.json)
  --seed N          (default 0)
  --trace-out F     (train, serve) write the run's lifecycle spans as
                    Chrome/Perfetto trace-event JSON to F — serve records
                    submit → queue_wait → coalesce → upload → dispatch →
                    fetch → demux → reply, train records prefetch_wait →
                    upload → dispatch → fetch → freeze_swap → eval (plus
                    average_barrier with --replicas)
  --metrics-out F   (train, serve) write a Prometheus text-format snapshot
                    of the metrics registry (counters, gauges, latency
                    histogram) to F at the end of the run
  --faults SPEC     deterministic fault injection: comma list of
                    seam[@scope]:action[@stepN] directives, e.g.
                    \"barrier_send@replica1:panic@step7,dispatch:stall(200ms)\"
                    — seams: batch_upload dispatch fetch prefetch
                    barrier_send barrier_recv swap_ack hedge storage_get
                    storage_put; actions: panic, error, stall(DUR). Falls
                    back to the LRTA_FAULTS env var; unset means zero-cost
                    disarmed seams
  --no-resident     train through the host-literal round-trip baseline
                    instead of the device-resident buffer-chained engine
  --no-pipeline     disable overlapped execution (double-buffered batch
                    uploads, split dispatch/fetch, on-device epoch metrics,
                    side-thread eval / streaming admission) and run the
                    serial resident loops instead

TRAIN SCALING
  --replicas N      data-parallel training: N engine replicas (one PJRT
                    client + resident state each) step on disjoint batch
                    shards with buffer-level parameter averaging; the
                    barrier follows the freeze-derived sync plan (frozen
                    leaves never move, trainable leaves ship as deltas
                    against the last broadcast mean) and rides the
                    pipelined epoch driver unless --no-pipeline
  --avg-every K     average every K steps (0 = only at epoch boundaries;
                    boundaries always sync so freeze swaps stay aligned)
  --momenta P       momenta at an averaging event: avg (default) | reset
  --sync-compress C barrier delta codec: exact (default; lossless XOR
                    bit-deltas, bit-identical to full-tensor exchange) |
                    q8 (int8-quantized deltas with per-leaf scales; ~4x
                    smaller frames, lossy — bounded-divergence benched)
  --epoch-ckpts DIR persist every epoch's parameters as DIR/epoch_NNN.bin
                    on a side thread while the next epoch trains
                    (single-replica trainer only)

STORAGE (pluggable object-store boundary)
  URIs name a backend: a directory path opens a local filesystem store;
  \"mem:\" or \"mem:NAME\" opens a named in-process object store with
  remote-object semantics (atomic puts, no partial reads) shared by every
  opener of the same name — a training run and a serve swap in one
  process see the same objects, like two jobs sharing a bucket.
  --store URI       (train) upload each epoch's checkpoint as
                    ckpts/epoch_NNN.bin through the storage backend on a
                    side thread — byte-identical to --epoch-ckpts files;
                    single-replica trainer only, exclusive with
                    --epoch-ckpts
  --data-store URI  (train) stream training batches from the store: the
                    synthetic corpus is published once as content-addressed
                    chunks under data/ (re-publishing dedupes), then
                    batches assemble from a bounded chunk cache with
                    fetch-ahead — bit-identical trajectory to in-memory
                    runs; works with --replicas (shards share one cache)
  --swap-store URI  (serve) after startup, hot-swap a variant's checkpoint
                    from the store (zero dropped requests)
  --swap-key K      (serve) object key to swap from
                    (default ckpts/epoch_000.bin — what --store wrote)
  --swap-variant V  (serve) variant to swap (default: first of --variants)
  --barrier-timeout-ms D  averaging-barrier deadline per event (default
                    30000): a replica that misses it is evicted and the
                    barrier closes over the survivors with a rescaled mean
  --no-evict        fail the whole run when a replica dies or misses the
                    barrier deadline instead of evicting it

SERVE
  Starts one engine per variant (parameters uploaded once and kept
  device-resident; --reupload restores the old per-batch upload as a
  measurable baseline; streaming admission uploads batch N+1 while N
  executes unless --no-pipeline), drives a synthetic closed-loop load
  through the router (--burst switches to an open-loop burst that keeps
  batches full), and prints per-variant fps + latency percentiles.

SERVE SCALING
  --shards N        scale each variant out across N shard workers (own
                    PJRT client, resident params, queue and stats each);
                    the router fans out to the shallowest queue with
                    round-robin tie-break
  --slo-ms D        per-request admission deadline: work still queued D ms
                    after submission is shed at pop time (DeadlineExceeded)
                    instead of occupying a batch slot (0 = never shed)
  --no-supervise    disable per-shard supervision (a worker death then
                    leaves its shard down instead of draining, respawning
                    warm and rejoining the fanout)

SERVE QOS (rank-aware priority serving)
  --classes SPEC    enable QoS: per-class weighted admission queues and
                    per-class SLOs. SPEC is a comma list of
                    name:weight[:slo_ms] entries over interactive /
                    standard / batch, e.g.
                    \"interactive:4:250,standard:2:100,batch:1:5\";
                    unlisted classes keep weight 1 and no class SLO. The
                    load driver then cycles submissions across all three
                    classes and reports per-class latency
  --degrade SPEC    degrade-not-shed ladders: class=variant[+variant...]
                    comma list, e.g. \"batch=lrd+rankopt,standard=rankopt\"
                    — an expired request spills down its class ladder to a
                    cheaper-rank registered variant (fresh class deadline)
                    instead of being shed; requires --classes
  --hedge-ms D      hedge tail requests: when a shard's in-flight batch
                    exceeds the p99 latency budget (fallback D ms until the
                    histogram warms up), re-dispatch its requests to the
                    shallowest sibling shard — first answer wins, the loser
                    is cancelled and counted. Needs --shards >= 2; requires
                    --classes
  --qos-check       exit non-zero unless interactive p99 stayed within its
                    class SLO on every variant and at least one request
                    spilled down a degrade ladder; requires --classes
";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env(&[
        "model", "variant", "freeze", "epochs", "lr", "cosine", "out", "ckpt", "manifest",
        "seed", "reps", "c", "s", "k", "m", "alpha", "backend", "train-size", "test-size",
        "pretrain-epochs", "verbose", "stride", "variants", "requests", "concurrency",
        "depth", "max-wait-ms", "spot-check", "reupload", "burst", "no-resident",
        "no-pipeline", "replicas", "avg-every", "momenta", "sync-compress", "epoch-ckpts",
        "shards", "slo-ms", "trace-out", "metrics-out", "faults", "no-evict",
        "barrier-timeout-ms", "no-supervise", "classes", "degrade", "hedge-ms", "qos-check",
        "store", "data-store", "swap-store", "swap-key", "swap-variant",
    ])
    .map_err(|e| anyhow!("{e}\n\n{USAGE}"))?;

    let Some(cmd) = args.subcommand.clone() else {
        print!("{USAGE}");
        return Ok(());
    };

    // arm the process-global fault plan before any engine thread exists:
    // --faults wins, LRTA_FAULTS is the fallback, neither leaves every seam
    // a single relaxed atomic load
    if let Some(spec) = args.get("faults") {
        faults::install(faults::Plan::parse(spec)?);
    } else {
        faults::install_from_env()?;
    }

    match cmd.as_str() {
        "info" => info(&args),
        "pretrain" => pretrain(&args),
        "decompose" => decompose(&args),
        "train" => train(&args),
        "infer" => infer(&args),
        "serve" => serve(&args),
        "rank-opt" => rank_opt(&args),
        "pipeline" => pipeline(&args),
        other => bail!("unknown subcommand '{other}'\n\n{USAGE}"),
    }
}

fn load_manifest(args: &Args) -> Result<Manifest> {
    Manifest::load(args.str_or("manifest", "artifacts/manifest.json"))
}

/// Telemetry outputs requested on the command line: a live tracer when
/// `--trace-out` is present, a live registry when `--metrics-out` is, and
/// the no-op/absent forms otherwise (the hot paths then skip all recording).
struct ObsOutputs {
    tracer: Tracer,
    registry: Option<Registry>,
    trace_path: Option<String>,
    metrics_path: Option<String>,
}

fn obs_outputs(args: &Args) -> ObsOutputs {
    let trace_path = args.get("trace-out").map(str::to_string);
    let metrics_path = args.get("metrics-out").map(str::to_string);
    ObsOutputs {
        tracer: if trace_path.is_some() { Tracer::enabled() } else { Tracer::default() },
        registry: metrics_path.as_ref().map(|_| Registry::new()),
        trace_path,
        metrics_path,
    }
}

impl ObsOutputs {
    /// Export whatever was requested, at the end of the run.
    fn write(&self) -> Result<()> {
        if let Some(path) = &self.trace_path {
            std::fs::write(path, self.tracer.chrome_trace_json().emit())?;
            println!("wrote trace ({} spans) to {path}", self.tracer.len());
        }
        if let (Some(path), Some(reg)) = (&self.metrics_path, &self.registry) {
            std::fs::write(path, reg.snapshot().prometheus_text())?;
            println!("wrote metrics snapshot to {path}");
        }
        Ok(())
    }
}

/// Open a storage URI and wire it into the run's telemetry: spans record
/// into the tracer, counters register under `storage/*{backend=ROLE}` so
/// the Prometheus snapshot separates checkpoint traffic from data traffic.
/// `seen` dedupes by store identity — one store serving two roles (same
/// URI for `--store` and `--data-store`) wires up once.
fn open_store_for(
    uri: &str,
    role: &str,
    obs: &ObsOutputs,
    seen: &mut Vec<Arc<dyn Storage>>,
) -> Result<Arc<dyn Storage>> {
    let store = storage::open(uri)?;
    if !seen.iter().any(|s| Arc::ptr_eq(s, &store)) {
        store.set_tracer(obs.tracer.clone());
        if let Some(reg) = &obs.registry {
            store.metrics().register(reg, role)?;
        }
        seen.push(Arc::clone(&store));
    }
    Ok(store)
}

/// Resolve `--data-store`: publish the run's deterministic synthetic
/// corpus under `data/` (idempotent — content-addressed chunks dedupe, so
/// a second run uploads nothing) and open a streaming provider over it.
fn open_data_source(store: Arc<dyn Storage>, cfg: &TrainConfig) -> Result<DataSource> {
    let data = Dataset::synthetic(cfg.train_size, cfg.seed);
    let stats = lrta::data::publish(
        &store,
        "data",
        &data,
        lrta::data::stream::DEFAULT_SAMPLES_PER_CHUNK,
    )?;
    println!(
        "data store: {} samples in {} chunks ({} uploaded, {} deduped)",
        stats.samples,
        stats.chunks_total,
        stats.chunks_written,
        stats.chunks_total - stats.chunks_written
    );
    let provider = StreamingProvider::open(store, "data")?;
    Ok(DataSource::streamed(Arc::new(provider)))
}

fn info(args: &Args) -> Result<()> {
    let m = load_manifest(args)?;
    println!("manifest: alpha={} tile={} artifacts={}", m.alpha, m.tile, m.artifacts.len());
    for (name, a) in &m.artifacts {
        println!(
            "  {name:<34} kind={:<5} batch={:<4} trainable={:<3} frozen={}",
            a.kind,
            a.batch,
            a.trainable.len(),
            a.frozen.len()
        );
    }
    for (model, p) in &m.init_checkpoints {
        println!("  init[{model}] = {}", p.display());
    }
    Ok(())
}

fn base_config(args: &Args) -> TrainConfig {
    let epochs = args.usize_or("epochs", 5);
    TrainConfig {
        model: args.str_or("model", "resnet_mini"),
        variant: args.str_or("variant", "lrd"),
        freeze: FreezeMode::parse(&args.str_or("freeze", "none")).unwrap_or(FreezeMode::None),
        epochs,
        lr: if args.has("cosine") {
            LrSchedule::Cosine { base: args.f64_or("lr", 0.02) as f32, total_epochs: epochs }
        } else {
            LrSchedule::Fixed(args.f64_or("lr", 1e-3) as f32)
        },
        train_size: args.usize_or("train-size", 2048),
        test_size: args.usize_or("test-size", 512),
        seed: args.u64_or("seed", 0),
        verbose: args.bool_or("verbose", true),
        resident: !args.bool_or("no-resident", false),
        pipelined: !args.bool_or("no-pipeline", false),
    }
}

fn pretrain(args: &Args) -> Result<()> {
    let m = load_manifest(args)?;
    let rt = Runtime::cpu()?;
    let mut cfg = base_config(args);
    cfg.variant = "orig".into();
    cfg.freeze = FreezeMode::None;
    let model = cfg.model.clone();
    let params = checkpoint::load(m.init_checkpoint(&model)?)?;
    let mut trainer = Trainer::new(&rt, &m, cfg, params)?;
    let record = trainer.run()?;
    println!("pretrained {model}: final test acc {:.3}", record.final_test_acc());
    let out = args.str_or("out", &format!("results/{model}_pretrained.bin"));
    checkpoint::save(&out, &trainer.params)?;
    println!("saved {out}");
    Ok(())
}

fn decompose(args: &Args) -> Result<()> {
    let m = load_manifest(args)?;
    let model = args.str_or("model", "resnet_mini");
    let variant = args.str_or("variant", "lrd");
    let ckpt = args.str_or("ckpt", &format!("results/{model}_pretrained.bin"));
    let dense = checkpoint::load(&ckpt)?;
    let cfg = m.config(&model, &variant)?;
    let outcome = decompose_checkpoint(&dense, cfg)?;
    println!(
        "decomposed {model} ({variant}): {} layers in {:.2}s, Σ‖W−W'‖² = {:.4}",
        outcome.layers_decomposed, outcome.secs, outcome.total_reconstruction_err
    );
    let out = args.str_or("out", &format!("results/{model}_{variant}.bin"));
    checkpoint::save(&out, &outcome.params)?;
    println!("saved {out}");
    Ok(())
}

fn train(args: &Args) -> Result<()> {
    let m = load_manifest(args)?;
    let cfg = base_config(args);
    let default_ckpt = format!("results/{}_{}.bin", cfg.model, cfg.variant);
    let ckpt = args.str_or("ckpt", &default_ckpt);
    let params = checkpoint::load(&ckpt)?;
    let out = args.str_or("out", "");
    let obs = obs_outputs(args);
    faults::set_tracer(obs.tracer.clone());
    if let Some(reg) = &obs.registry {
        faults::register_metrics(reg)?;
    }

    // the storage boundary: --store routes epoch checkpoints through a
    // backend, --data-store streams batches from a published corpus
    if args.has("epoch-ckpts") && args.has("store") {
        bail!("--epoch-ckpts and --store both name a checkpoint sink; pick one");
    }
    let mut stores_seen: Vec<Arc<dyn Storage>> = Vec::new();
    let data_source = match args.get("data-store") {
        Some(uri) => {
            let store = open_store_for(uri, "data", &obs, &mut stores_seen)?;
            Some(open_data_source(store, &cfg)?)
        }
        None => None,
    };

    // data-parallel path: each replica owns its PJRT client on its own
    // thread, so no main-thread runtime is created here. Parse strictly —
    // a typo'd or zero count must not silently fall back to single-engine
    let replicas = match args.get("replicas") {
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| anyhow!("--replicas expects a positive integer, got '{v}'"))?,
        None => 1,
    };
    if replicas == 0 {
        bail!("--replicas must be at least 1");
    }
    if replicas > 1 {
        // fail loudly on flags the replica path would otherwise silently
        // ignore: replicas always step the resident engine (the literal
        // baseline has no buffers to average), and epoch checkpointing is
        // single-engine only. --no-pipeline is honored: replicas select
        // the same epoch driver as single-engine runs.
        if args.has("epoch-ckpts") {
            bail!("--epoch-ckpts is not supported with --replicas > 1 (single-engine trainer only)");
        }
        if args.has("store") {
            bail!("--store is not supported with --replicas > 1 (single-engine trainer only)");
        }
        if args.bool_or("no-resident", false) {
            bail!(
                "--no-resident does not apply with --replicas > 1: \
                 replicas always step the resident engine"
            );
        }
        let momenta_arg = args.str_or("momenta", "avg");
        let compress_arg = args.str_or("sync-compress", "exact");
        let rcfg = ReplicaConfig {
            replicas,
            avg_every: args.usize_or("avg-every", 0),
            momenta: MomentumPolicy::parse(&momenta_arg)
                .ok_or_else(|| anyhow!("unknown momentum policy '{momenta_arg}'"))?,
            compress: SyncCompress::parse(&compress_arg)
                .ok_or_else(|| anyhow!("unknown sync compression '{compress_arg}'"))?,
            identical_shards: false,
            evict: !args.bool_or("no-evict", false),
            barrier_timeout: Duration::from_secs_f64(
                args.f64_or("barrier-timeout-ms", 30_000.0) / 1e3,
            ),
        };
        let run = run_replicas_sourced(
            &m,
            &cfg,
            &rcfg,
            &params,
            obs.tracer.clone(),
            obs.registry.clone(),
            data_source,
        )?;
        println!(
            "final test acc {:.3}; median step {:.1} ms ({replicas} replicas, avg-every={}, \
             sync={})",
            run.record.final_test_acc(),
            run.record.median_step_secs() * 1e3,
            rcfg.avg_every,
            rcfg.compress.label()
        );
        for r in &run.reports {
            println!(
                "replica {} [{}]: {} initial uploads + {} averaging uploads over {} events \
                 ({} unaccounted), {} demux fallbacks, {} batches",
                r.replica,
                r.driver(),
                r.initial_param_uploads,
                r.avg_slot_uploads,
                r.avg_events,
                r.unaccounted_uploads(),
                r.demux_fallbacks,
                r.batches
            );
            println!(
                "replica {} barrier bytes: {} exchanged of {} full ({} skipped frozen, \
                 {} saved by delta)",
                r.replica,
                r.avg_bytes_exchanged,
                r.avg_bytes_full,
                r.avg_bytes_skipped,
                r.avg_bytes_saved_by_delta()
            );
        }
        if run.record.degraded() {
            println!(
                "DEGRADED run: finished on {} of {replicas} replicas",
                replicas - run.record.evictions.len()
            );
            for ev in &run.record.evictions {
                println!(
                    "  evicted replica {} at event {} (last heartbeat epoch {} step {}): {}",
                    ev.replica, ev.event, ev.last_epoch, ev.last_step, ev.reason
                );
            }
        }
        if faults::armed() {
            println!("faults: {} injected", faults::fired());
        }
        if !out.is_empty() {
            checkpoint::save(&out, &run.params)?;
            println!("saved {out}");
        }
        obs.write()?;
        return Ok(());
    }
    // the mirror-image guard: replica-only flags must not silently no-op
    // on the single-engine path
    if args.has("avg-every") || args.has("momenta") || args.has("sync-compress") {
        bail!("--avg-every / --momenta / --sync-compress require --replicas > 1");
    }

    let rt = Runtime::cpu()?;
    if let Some(reg) = &obs.registry {
        rt.register_metrics(reg, &[])?;
    }
    let mut trainer = Trainer::new(&rt, &m, cfg, params)?;
    trainer.set_tracer(obs.tracer.clone());
    if let Some(dir) = args.get("epoch-ckpts") {
        trainer.checkpoint_epochs_to(dir);
    }
    if let Some(uri) = args.get("store") {
        let store = open_store_for(uri, "ckpt", &obs, &mut stores_seen)?;
        trainer.checkpoint_epochs_to_store(store, "ckpts");
    }
    if let Some(source) = data_source {
        trainer.train_from(source);
    }
    let record = trainer.run()?;
    println!(
        "final test acc {:.3}; median step {:.1} ms",
        record.final_test_acc(),
        record.median_step_secs() * 1e3
    );
    if let Some(report) = trainer.residency_report() {
        println!("{report}");
    }
    if !out.is_empty() {
        checkpoint::save(&out, &trainer.params)?;
        println!("saved {out}");
    }
    obs.write()?;
    Ok(())
}

fn infer(args: &Args) -> Result<()> {
    let m = load_manifest(args)?;
    let rt = Runtime::cpu()?;
    let mut cfg = base_config(args);
    cfg.epochs = 1;
    // no training happens here: skip the engine's full params+momenta
    // upload — infer_fps uploads exactly the infer artifact's slots once
    cfg.resident = false;
    let default_ckpt = format!("results/{}_{}.bin", cfg.model, cfg.variant);
    let ckpt = args.str_or("ckpt", &default_ckpt);
    let params = checkpoint::load(&ckpt)?;
    let trainer = Trainer::new(&rt, &m, cfg, params)?;
    let fps = trainer.infer_fps(args.usize_or("reps", 20))?;
    println!("inference throughput: {fps:.0} fps");
    Ok(())
}

/// `lrta serve` — start the serving subsystem for every requested variant
/// of one model and drive a synthetic load through the router.
fn serve(args: &Args) -> Result<()> {
    if !args.positional.is_empty() {
        // e.g. `--variants orig, lrd` parses "lrd" as a positional — fail
        // loudly instead of silently serving fewer variants than asked
        bail!(
            "unexpected arguments {:?} (write comma lists without spaces: --variants orig,lrd)",
            args.positional
        );
    }
    let m = load_manifest(args)?;
    let model = args.str_or("model", "resnet_mini");
    let variants = args.list_or("variants", &["orig", "lrd", "rankopt"]);
    let requests = args.usize_or("requests", 256);
    let concurrency = args.usize_or("concurrency", 32);
    let seed = args.u64_or("seed", 0);
    let burst = args.bool_or("burst", false);
    // scale-out knobs: parse strictly — a typo'd shard count must not
    // silently fall back to a single engine (same contract as --replicas)
    let shards = match args.get("shards") {
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| anyhow!("--shards expects a positive integer, got '{v}'"))?,
        None => 1,
    };
    if shards == 0 {
        bail!("--shards must be at least 1");
    }
    let slo_ms = match args.get("slo-ms") {
        Some(v) => v
            .parse::<f64>()
            .ok()
            .filter(|ms| *ms >= 0.0)
            .ok_or_else(|| anyhow!("--slo-ms expects a non-negative number, got '{v}'"))?,
        None => 0.0,
    };
    let slo = if slo_ms > 0.0 { Some(Duration::from_secs_f64(slo_ms / 1e3)) } else { None };
    // rank-aware QoS: --classes switches the shard queues to per-class
    // weighted multi-queues; --degrade arms the spill ladders; --hedge-ms
    // arms the tail-latency hedge governor
    let qos = match args.get("classes") {
        Some(spec) => {
            let mut q = QosConfig {
                classes: QosConfig::parse_classes(spec)?,
                ..Default::default()
            };
            if let Some(dspec) = args.get("degrade") {
                q.degrade = QosConfig::parse_degrade(dspec)?;
            }
            if let Some(h) = args.get("hedge-ms") {
                let ms: f64 = h.parse().ok().filter(|v| *v > 0.0).ok_or_else(|| {
                    anyhow!("--hedge-ms expects a positive number, got '{h}'")
                })?;
                if shards < 2 {
                    bail!("--hedge-ms needs --shards >= 2 (hedging targets a sibling shard)");
                }
                q.hedge = Some(HedgeConfig {
                    fallback: Duration::from_secs_f64(ms / 1e3),
                    ..Default::default()
                });
            }
            Some(q)
        }
        None => {
            if args.has("degrade") || args.has("hedge-ms") || args.has("qos-check") {
                bail!("--degrade / --hedge-ms / --qos-check require --classes");
            }
            None
        }
    };

    // checkpoint: --ckpt, or the manifest's init checkpoint (same default
    // as the benches — serving speed does not depend on training state)
    let ckpt = args.str_or("ckpt", "");
    let dense = if ckpt.is_empty() {
        checkpoint::load(m.init_checkpoint(&model)?)?
    } else {
        checkpoint::load(&ckpt)?
    };

    let mut specs = Vec::new();
    for variant in &variants {
        specs.push(VariantSpec::from_dense(&m, &model, variant, &dense)?.with_shards(shards));
    }

    let obs = obs_outputs(args);
    faults::set_tracer(obs.tracer.clone());
    if let Some(reg) = &obs.registry {
        faults::register_metrics(reg)?;
    }
    let cfg = ServerConfig {
        queue_depth: args.usize_or("depth", 0),
        max_wait: Duration::from_secs_f64(args.f64_or("max-wait-ms", 2.0) / 1e3),
        reupload: args.bool_or("reupload", false),
        pipelined: !args.bool_or("no-pipeline", false),
        spot_check: args.usize_or("spot-check", 128),
        slo,
        registry: obs.registry.clone(),
        tracer: obs.tracer.clone(),
        supervise: !args.bool_or("no-supervise", false),
        qos: qos.clone(),
        ..Default::default()
    };
    println!(
        "serving {model} [{}] params={} shards={shards} slo={} qos={} requests={requests} {} ...",
        variants.join(", "),
        if cfg.reupload {
            "reupload-per-batch"
        } else if cfg.pipelined {
            "device-resident+pipelined"
        } else {
            "device-resident"
        },
        if slo_ms > 0.0 { format!("{slo_ms}ms") } else { "off".to_string() },
        if qos.is_some() { "on" } else { "off" },
        if burst || qos.is_some() {
            "burst".to_string()
        } else {
            format!("concurrency={concurrency}")
        },
    );
    let server = Server::start(&m, specs, &cfg)?;

    // storage-sourced warm swap: pick up a checkpoint a training run
    // published (e.g. `lrta train --store URI`) before driving load —
    // zero-downtime, every shard flips between batches
    if args.has("swap-key") || args.has("swap-variant") {
        if !args.has("swap-store") {
            bail!("--swap-key / --swap-variant require --swap-store");
        }
    }
    if let Some(uri) = args.get("swap-store") {
        let key = args.str_or("swap-key", "ckpts/epoch_000.bin");
        let target = args.str_or("swap-variant", &variants[0]);
        let mut stores_seen = Vec::new();
        let store = open_store_for(uri, "swap", &obs, &mut stores_seen)?;
        server
            .swap_variant_from_store(&model, &target, store.as_ref(), &key)
            .map_err(|e| anyhow!("swap {model}/{target} from {uri} key '{key}': {e}"))?;
        println!("swapped {model}/{target} from storage {uri} key {key}");
    }

    let data = Dataset::synthetic(512, seed ^ 0x5E12E);
    let timeout = Duration::from_secs(120);
    let mut rows = vec![StatsSnapshot::table_header()];
    let mut reports = Vec::new();
    let mut qos_reports: Vec<(String, [serve_load::LoadReport; 3])> = Vec::new();
    for variant in &variants {
        if qos.is_some() {
            // QoS driver: cycle every class through an open-loop burst so
            // the weighted queues, SLOs and ladders all see traffic
            let class_reports = serve_load::classed_burst_loop(
                &server,
                &model,
                variant,
                &data,
                requests,
                &Class::ALL,
                timeout,
            );
            let snap = server.stats(&model, variant).expect("registered variant");
            for (class, rep) in Class::ALL.iter().zip(&class_reports) {
                println!(
                    "{variant}/{class}: {} ok, {} shed, {} errors | p50 {:.2} ms p99 {:.2} ms",
                    rep.completed,
                    rep.shed,
                    rep.errors,
                    rep.latency_ms(50.0),
                    rep.latency_ms(99.0)
                );
            }
            println!(
                "{variant}: served={:?} shed={:?} spilled={:?} hedge fired/won/cancelled \
                 {}/{}/{}",
                snap.served_by_class,
                snap.shed_by_class,
                snap.spilled_by_class,
                snap.hedge_fired,
                snap.hedge_wins,
                snap.hedge_cancelled
            );
            rows.push(snap.table_row());
            qos_reports.push((variant.clone(), class_reports));
            continue;
        }
        let report = if burst {
            serve_load::burst_loop(&server, &model, variant, &data, requests, timeout)
        } else {
            serve_load::closed_loop(
                &server, &model, variant, &data, requests, concurrency, timeout,
            )
        };
        let snap = server.stats(&model, variant).expect("registered variant");
        println!(
            "{variant}: {:.0} fps observed ({} ok, {} rejected retries, {} shed, {} errors)",
            report.observed_fps(),
            report.completed,
            report.rejected,
            report.shed,
            report.errors
        );
        rows.push(snap.table_row());
        reports.push((variant.clone(), report));
    }
    println!("\n{}", table(&rows));
    for (variant, report) in &reports {
        println!(
            "{variant}: observed {:.0} fps | p50 {:.2} ms p95 {:.2} ms p99 {:.2} ms",
            report.observed_fps(),
            report.latency_ms(50.0),
            report.latency_ms(95.0),
            report.latency_ms(99.0)
        );
    }
    // --qos-check: the overload acceptance gate — interactive latency held
    // its SLO on every variant while at least one expired request degraded
    // down a ladder instead of shedding
    if args.has("qos-check") {
        let q = qos.as_ref().expect("checked above: --qos-check requires --classes");
        let islo = q.classes[Class::Interactive.index()].slo.ok_or_else(|| {
            anyhow!("--qos-check needs an interactive SLO in --classes (interactive:W:SLO)")
        })?;
        for (variant, class_reports) in &qos_reports {
            let p99_ms = class_reports[Class::Interactive.index()].latency_ms(99.0);
            let slo_ms = islo.as_secs_f64() * 1e3;
            if p99_ms > slo_ms {
                bail!(
                    "qos-check failed: {variant} interactive p99 {p99_ms:.2} ms \
                     exceeds SLO {slo_ms:.2} ms"
                );
            }
        }
        let spilled: u64 = variants
            .iter()
            .filter_map(|v| server.stats(&model, v))
            .map(|s| s.spilled)
            .sum();
        if spilled == 0 {
            bail!("qos-check failed: expected degrade-ladder spills under overload, saw none");
        }
        println!("qos-check passed: interactive p99 within SLO, {spilled} requests spilled");
    }
    let deaths: u64 = variants
        .iter()
        .filter_map(|v| server.stats(&model, v))
        .map(|s| s.worker_deaths)
        .sum();
    let respawned: u64 = variants
        .iter()
        .filter_map(|v| server.stats(&model, v))
        .map(|s| s.respawns)
        .sum();
    if deaths > 0 {
        println!("supervision: {deaths} worker deaths, {respawned} respawns");
    }
    if faults::armed() {
        println!("faults: {} injected", faults::fired());
    }
    server.shutdown();
    obs.write()?;
    Ok(())
}

fn rank_opt(args: &Args) -> Result<()> {
    let c = args.usize_or("c", 512);
    let s = args.usize_or("s", 512);
    let k = args.usize_or("k", 3);
    let shape = if k <= 1 { LayerShape::linear(c, s) } else { LayerShape::conv(c, s, k) };
    let cfg = RankOptConfig {
        alpha: args.f64_or("alpha", 2.0),
        m: args.usize_or("m", 4096),
        stride: args.usize_or("stride", 1),
        ..Default::default()
    };
    let backend = args.str_or("backend", "v100");
    let result = if backend == "pjrt" {
        let rt = Runtime::cpu()?;
        let mut t = PjrtTimer::new(&rt);
        optimize_rank(&mut t, shape, &cfg)?
    } else {
        let dev = DeviceProfile::by_name(&backend)
            .ok_or_else(|| anyhow!("unknown backend '{backend}'"))?;
        optimize_rank(&mut ModelTimer(dev), shape, &cfg)?
    };
    println!(
        "layer [{c},{s},{k}] backend={} | R(eq5)={} Rmin(eq6)={} -> R_opt={}",
        result.backend, result.r_nominal, result.r_min, result.r_opt
    );
    println!(
        "t_dense={:.3}ms t_nominal={:.3}ms t_opt={:.3}ms speedup_vs_lrd={:.2}x use_original={}",
        result.t_dense * 1e3,
        result.t_nominal * 1e3,
        result.t_opt * 1e3,
        result.speedup_vs_nominal(),
        result.use_original
    );
    println!("rank,time_ms,ratio");
    for p in &result.sweep {
        println!("{},{:.5},{:.3}", p.r, p.t * 1e3, p.ratio);
    }
    Ok(())
}

fn pipeline(args: &Args) -> Result<()> {
    let m = load_manifest(args)?;
    let rt = Runtime::cpu()?;
    let mut cfg = base_config(args);
    let model = cfg.model.clone();
    let variant = cfg.variant.clone();

    // 1. pretrain dense
    let mut pre_cfg = cfg.clone();
    pre_cfg.variant = "orig".into();
    pre_cfg.freeze = FreezeMode::None;
    pre_cfg.epochs = args.usize_or("pretrain-epochs", 3);
    let init = checkpoint::load(m.init_checkpoint(&model)?)?;
    println!("== pretrain {model} ({} epochs) ==", pre_cfg.epochs);
    let mut pre = Trainer::new(&rt, &m, pre_cfg, init)?;
    let pre_record = pre.run()?;
    println!("pretrain acc {:.3}", pre_record.final_test_acc());

    // 2. decompose
    let dense = pre.params.clone();
    let params = if variant == "orig" {
        dense
    } else {
        let outcome = decompose_checkpoint(&dense, m.config(&model, &variant)?)?;
        println!(
            "== decomposed {} layers in {:.2}s (err {:.3}) ==",
            outcome.layers_decomposed, outcome.secs, outcome.total_reconstruction_err
        );
        outcome.params
    };

    // 3. fine-tune with the freezing schedule
    println!("== fine-tune {model} {variant} freeze={:?} ==", cfg.freeze);
    cfg.verbose = true;
    let mut tr = Trainer::new(&rt, &m, cfg, params)?;
    let record = tr.run()?;

    // 4. report
    println!(
        "pipeline done: final acc {:.3} | median step {:.1} ms | infer {:.0} fps",
        record.final_test_acc(),
        record.median_step_secs() * 1e3,
        tr.infer_fps(10)?
    );
    Ok(())
}
