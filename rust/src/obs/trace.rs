//! Lifecycle span tracing: manual start/end spans into a bounded ring
//! buffer, exported as Chrome/Perfetto trace-event JSON.
//!
//! No external deps and no macro magic — a span is two calls around the
//! region of interest:
//!
//! ```
//! use lrta::obs::Tracer;
//! let tracer = Tracer::enabled();
//! let t0 = tracer.start();
//! // … the traced region …
//! tracer.end(t0, "serve", "fetch");
//! assert_eq!(tracer.len(), 1);
//! ```
//!
//! A disabled tracer ([`Tracer::noop`], the `Default`) is a `None` behind
//! the handle: `start` never reads the clock and `end` returns before
//! touching any lock, so telemetry-off overhead is one branch per span site
//! (pinned by the overhead-guard integration test). The handle is
//! `Clone + Send + Sync`, so serve shards, train replicas, and side workers
//! all record into the same ring; events carry a per-thread lane id.
//!
//! Export is the Chrome trace-event JSON array format (complete events,
//! `"ph": "X"`, microsecond timestamps relative to the tracer's creation),
//! loadable in `chrome://tracing` and [Perfetto](https://ui.perfetto.dev).

use crate::util::json::Json;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Ring capacity of [`Tracer::enabled`]: oldest spans evict first, so a
/// long-running server keeps the most recent window instead of growing
/// without bound (~65k spans ≈ a few MB).
pub const TRACE_CAP: usize = 65_536;

/// Process-wide lane ids: each thread gets one on its first recorded span.
static NEXT_LANE: AtomicU64 = AtomicU64::new(1);
thread_local! {
    static LANE: u64 = NEXT_LANE.fetch_add(1, Ordering::Relaxed);
}

/// One completed span.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    pub name: &'static str,
    /// Category — the subsystem ("serve", "train", …).
    pub cat: &'static str,
    /// Start, µs since the tracer was created.
    pub ts_us: u64,
    /// Duration in µs.
    pub dur_us: u64,
    /// Per-thread lane (Chrome `tid`).
    pub tid: u64,
}

/// Token returned by [`Tracer::start`]; `None` when tracing is off, so the
/// disabled path never reads the clock.
#[derive(Clone, Copy, Debug)]
pub struct SpanStart(Option<Instant>);

struct TraceInner {
    epoch: Instant,
    cap: usize,
    events: Mutex<VecDeque<TraceEvent>>,
}

/// The span recorder handle. `Default` is the no-op recorder.
#[derive(Clone, Default)]
pub struct Tracer {
    inner: Option<Arc<TraceInner>>,
}

impl std::fmt::Debug for Tracer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Tracer").field("enabled", &self.is_enabled()).finish()
    }
}

impl Tracer {
    /// An active tracer with the default ring capacity ([`TRACE_CAP`]).
    pub fn enabled() -> Tracer {
        Tracer::with_capacity(TRACE_CAP)
    }

    /// An active tracer keeping at most `cap` spans (oldest evicted).
    pub fn with_capacity(cap: usize) -> Tracer {
        Tracer {
            inner: Some(Arc::new(TraceInner {
                epoch: Instant::now(),
                cap: cap.max(1),
                events: Mutex::new(VecDeque::new()),
            })),
        }
    }

    /// The no-op recorder (same as `Default`): records nothing, costs one
    /// branch per span site.
    pub fn noop() -> Tracer {
        Tracer::default()
    }

    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Open a span. Reads the clock only when tracing is on.
    #[inline]
    pub fn start(&self) -> SpanStart {
        SpanStart(self.inner.as_ref().map(|_| Instant::now()))
    }

    /// Close a span opened by [`Tracer::start`] and record it under
    /// `cat`/`name`. No-op (and lock-free) when tracing is off.
    pub fn end(&self, start: SpanStart, cat: &'static str, name: &'static str) {
        let Some(inner) = &self.inner else { return };
        let Some(t0) = start.0 else { return };
        let ev = TraceEvent {
            name,
            cat,
            ts_us: t0.duration_since(inner.epoch).as_micros() as u64,
            dur_us: t0.elapsed().as_micros() as u64,
            tid: LANE.with(|l| *l),
        };
        let mut q = inner.events.lock().expect("trace ring lock");
        if q.len() == inner.cap {
            q.pop_front();
        }
        q.push_back(ev);
    }

    /// Spans currently held in the ring.
    pub fn len(&self) -> usize {
        match &self.inner {
            Some(inner) => inner.events.lock().expect("trace ring lock").len(),
            None => 0,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Copy of the recorded spans, oldest first.
    pub fn events(&self) -> Vec<TraceEvent> {
        match &self.inner {
            Some(inner) => {
                inner.events.lock().expect("trace ring lock").iter().cloned().collect()
            }
            None => Vec::new(),
        }
    }

    /// Export as a Chrome trace-event JSON document
    /// (`{"traceEvents": [{"ph": "X", …}, …]}`) — load in `chrome://tracing`
    /// or Perfetto. An empty/disabled tracer exports an empty event list.
    pub fn chrome_trace_json(&self) -> Json {
        let events = self
            .events()
            .into_iter()
            .map(|e| {
                Json::obj(vec![
                    ("name", Json::str(e.name)),
                    ("cat", Json::str(e.cat)),
                    ("ph", Json::str("X")),
                    ("ts", Json::int(e.ts_us as i64)),
                    ("dur", Json::int(e.dur_us as i64)),
                    ("pid", Json::int(1)),
                    ("tid", Json::int(e.tid as i64)),
                ])
            })
            .collect();
        Json::obj(vec![("traceEvents", Json::arr(events))])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noop_records_nothing_and_never_reads_the_clock() {
        let t = Tracer::noop();
        assert!(!t.is_enabled());
        let s = t.start();
        assert!(s.0.is_none(), "disabled start must not sample the clock");
        t.end(s, "serve", "fetch");
        assert!(t.is_empty());
        assert_eq!(t.chrome_trace_json().get("traceEvents").as_arr().unwrap().len(), 0);
    }

    #[test]
    fn spans_record_name_cat_and_ordering() {
        let t = Tracer::enabled();
        let a = t.start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        t.end(a, "train", "upload");
        let b = t.start();
        t.end(b, "train", "dispatch");
        let ev = t.events();
        assert_eq!(ev.len(), 2);
        assert_eq!((ev[0].cat, ev[0].name), ("train", "upload"));
        assert_eq!((ev[1].cat, ev[1].name), ("train", "dispatch"));
        assert!(ev[0].dur_us >= 1_000, "2ms sleep must show up: {}", ev[0].dur_us);
        assert!(ev[1].ts_us >= ev[0].ts_us, "ring is FIFO in start order per thread");
    }

    #[test]
    fn ring_evicts_oldest_beyond_capacity() {
        let t = Tracer::with_capacity(3);
        for name in ["a", "b", "c", "d"] {
            // distinct static names so eviction order is observable
            let s = t.start();
            match name {
                "a" => t.end(s, "x", "a"),
                "b" => t.end(s, "x", "b"),
                "c" => t.end(s, "x", "c"),
                _ => t.end(s, "x", "d"),
            }
        }
        let names: Vec<&str> = t.events().iter().map(|e| e.name).collect();
        assert_eq!(names, vec!["b", "c", "d"]);
    }

    #[test]
    fn chrome_export_is_valid_complete_events() {
        let t = Tracer::enabled();
        let s = t.start();
        t.end(s, "serve", "submit");
        let doc = t.chrome_trace_json();
        // the export must survive a parse round-trip and carry the complete-
        // event contract Chrome/Perfetto require
        let parsed = Json::parse(&doc.emit()).unwrap();
        let ev = parsed.get("traceEvents").at(0);
        assert_eq!(ev.get("ph").as_str(), Some("X"));
        assert_eq!(ev.get("name").as_str(), Some("submit"));
        assert_eq!(ev.get("cat").as_str(), Some("serve"));
        assert!(ev.get("ts").as_i64().is_some());
        assert!(ev.get("dur").as_i64().is_some());
        assert!(ev.get("tid").as_i64().is_some());
    }

    #[test]
    fn threads_record_into_one_ring_with_distinct_lanes() {
        let t = Tracer::enabled();
        let t2 = t.clone();
        let s = t.start();
        t.end(s, "serve", "submit");
        std::thread::spawn(move || {
            let s = t2.start();
            t2.end(s, "serve", "fetch");
        })
        .join()
        .unwrap();
        let ev = t.events();
        assert_eq!(ev.len(), 2);
        assert_ne!(ev[0].tid, ev[1].tid, "each thread gets its own lane");
    }
}
