//! Typed metrics behind a `(subsystem, name, labels)` registry with
//! Prometheus-text and JSON exporters.
//!
//! Handles first, registry second: a [`Counter`] / [`Gauge`] / [`Histogram`]
//! is a cheap cloneable atomic cell that lives wherever the hot path already
//! keeps its counter (the runtime's transfer channels, a serve shard's stats
//! sink). Registering a handle under a key makes the registry *index the
//! same atomic* — a [`Snapshot`] therefore reads exactly the value the
//! hand-rolled accessor reads, which is what lets the integration suites
//! assert `registry == legacy counter` with no double bookkeeping.
//!
//! There is deliberately **no process-global registry**: tests run
//! concurrently in one process, so a global would collide on keys and break
//! exact-match assertions. Every consumer threads an explicit (Arc-shared,
//! `Clone`) [`Registry`] instance instead.

use crate::util::json::Json;
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// A monotonically increasing atomic counter handle. Cloning shares the
/// underlying cell; `Send + Sync`, so one handle can live in a worker thread
/// while the registry snapshots it from another.
#[derive(Clone, Debug, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn new() -> Counter {
        Counter::default()
    }

    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A last-value-wins atomic gauge handle (queue depths, mirrored transfer
/// counters). Same sharing semantics as [`Counter`].
#[derive(Clone, Debug, Default)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn new() -> Gauge {
        Gauge::default()
    }

    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Buckets in a log₂ histogram: bucket `i` counts values whose bit width is
/// `i` (i.e. `v == 0` → bucket 0, otherwise `2^(i-1) <= v < 2^i`), so the
/// full `u64` range is covered with no configuration.
pub const LOG2_BUCKETS: usize = 65;

#[derive(Debug)]
struct HistInner {
    buckets: [AtomicU64; LOG2_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

/// A log₂ histogram handle over `u64` samples (latencies in µs, sizes in
/// bytes). Lock-free recording; same sharing semantics as [`Counter`].
#[derive(Clone, Debug)]
pub struct Histogram(Arc<HistInner>);

impl Default for Histogram {
    fn default() -> Self {
        Histogram(Arc::new(HistInner {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }))
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Bucket index of `v`: its bit width (0 for 0).
    pub fn bucket_of(v: u64) -> usize {
        (u64::BITS - v.leading_zeros()) as usize
    }

    pub fn record(&self, v: u64) {
        self.0.buckets[Self::bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.0.count.fetch_add(1, Ordering::Relaxed);
        self.0.sum.fetch_add(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.0.sum.load(Ordering::Relaxed)
    }

    /// Per-bucket (non-cumulative) counts.
    pub fn buckets(&self) -> Vec<u64> {
        self.0.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }
}

/// The identity of a registered metric.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MetricKey {
    pub subsystem: String,
    pub name: String,
    /// Sorted `(label, value)` pairs.
    pub labels: Vec<(String, String)>,
}

impl MetricKey {
    fn new(subsystem: &str, name: &str, labels: &[(&str, &str)]) -> MetricKey {
        let mut labels: Vec<(String, String)> =
            labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
        labels.sort();
        MetricKey { subsystem: subsystem.to_string(), name: name.to_string(), labels }
    }

    /// The Prometheus metric (family) name: `lrta_<subsystem>_<name>`.
    pub fn metric_name(&self) -> String {
        format!("lrta_{}_{}", self.subsystem, self.name)
    }

    /// The `{k="v",…}` label suffix (empty string when unlabeled), with an
    /// optional extra label appended (histogram `le` bounds).
    fn label_str(&self, extra: Option<(&str, &str)>) -> String {
        let mut pairs: Vec<String> = self
            .labels
            .iter()
            .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
            .collect();
        if let Some((k, v)) = extra {
            pairs.push(format!("{k}=\"{}\"", escape_label(v)));
        }
        if pairs.is_empty() {
            String::new()
        } else {
            format!("{{{}}}", pairs.join(","))
        }
    }

    /// Stable registry/sort key: family name first so exposition groups
    /// metric families, then labels.
    fn id(&self) -> String {
        format!("{}{}", self.metric_name(), self.label_str(None))
    }
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

#[derive(Clone, Debug)]
enum Metric {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

/// The metric index: `(subsystem, name, labels)` → shared handle. Cloning
/// shares the index (one registry per server/trainer, threaded explicitly).
#[derive(Clone, Debug, Default)]
pub struct Registry {
    inner: Arc<Mutex<BTreeMap<String, (MetricKey, Metric)>>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    fn register(&self, key: MetricKey, metric: Metric) -> Result<()> {
        let id = key.id();
        let mut map = self.inner.lock().expect("registry lock");
        if map.contains_key(&id) {
            bail!("metric '{id}' registered twice");
        }
        map.insert(id, (key, metric));
        Ok(())
    }

    /// Index `c` under the key; the registry reads the *same* atomic the
    /// caller keeps incrementing. Duplicate keys are an error.
    pub fn register_counter(
        &self,
        subsystem: &str,
        name: &str,
        labels: &[(&str, &str)],
        c: &Counter,
    ) -> Result<()> {
        self.register(MetricKey::new(subsystem, name, labels), Metric::Counter(c.clone()))
    }

    pub fn register_gauge(
        &self,
        subsystem: &str,
        name: &str,
        labels: &[(&str, &str)],
        g: &Gauge,
    ) -> Result<()> {
        self.register(MetricKey::new(subsystem, name, labels), Metric::Gauge(g.clone()))
    }

    pub fn register_histogram(
        &self,
        subsystem: &str,
        name: &str,
        labels: &[(&str, &str)],
        h: &Histogram,
    ) -> Result<()> {
        self.register(MetricKey::new(subsystem, name, labels), Metric::Histogram(h.clone()))
    }

    /// Point-in-time read of every registered metric. Values are read
    /// per-atomic (relaxed), so a snapshot taken while workers run is
    /// per-metric consistent, not cross-metric atomic.
    pub fn snapshot(&self) -> Snapshot {
        let map = self.inner.lock().expect("registry lock");
        let entries = map
            .values()
            .map(|(key, metric)| SnapEntry {
                key: key.clone(),
                value: match metric {
                    Metric::Counter(c) => SnapValue::Counter(c.get()),
                    Metric::Gauge(g) => SnapValue::Gauge(g.get()),
                    Metric::Histogram(h) => SnapValue::Histogram {
                        buckets: h.buckets(),
                        count: h.count(),
                        sum: h.sum(),
                    },
                },
            })
            .collect();
        Snapshot { entries }
    }
}

/// One metric's value at snapshot time.
#[derive(Clone, Debug, PartialEq)]
pub enum SnapValue {
    Counter(u64),
    Gauge(u64),
    Histogram { buckets: Vec<u64>, count: u64, sum: u64 },
}

/// One `(key, value)` pair of a [`Snapshot`].
#[derive(Clone, Debug)]
pub struct SnapEntry {
    pub key: MetricKey,
    pub value: SnapValue,
}

/// A point-in-time view over a registry, exportable as Prometheus text or
/// JSON.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub entries: Vec<SnapEntry>,
}

impl Snapshot {
    /// Scalar value (counter or gauge) under the key, if present. `labels`
    /// order-insensitive.
    pub fn scalar(&self, subsystem: &str, name: &str, labels: &[(&str, &str)]) -> Option<u64> {
        let key = MetricKey::new(subsystem, name, labels);
        self.entries.iter().find(|e| e.key == key).and_then(|e| match e.value {
            SnapValue::Counter(v) | SnapValue::Gauge(v) => Some(v),
            SnapValue::Histogram { .. } => None,
        })
    }

    /// Sum of every counter/gauge named `(subsystem, name)` across label
    /// sets — the per-shard → per-variant rollup.
    pub fn scalar_sum(&self, subsystem: &str, name: &str) -> u64 {
        self.entries
            .iter()
            .filter(|e| e.key.subsystem == subsystem && e.key.name == name)
            .filter_map(|e| match e.value {
                SnapValue::Counter(v) | SnapValue::Gauge(v) => Some(v),
                SnapValue::Histogram { .. } => None,
            })
            .sum()
    }

    /// Prometheus text exposition (one `# TYPE` line per metric family;
    /// histograms emit cumulative `_bucket{le=…}` series plus `_sum` and
    /// `_count`). Round-trips through [`parse_prometheus`].
    pub fn prometheus_text(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let mut last_family = String::new();
        for e in &self.entries {
            let family = e.key.metric_name();
            match &e.value {
                SnapValue::Counter(v) | SnapValue::Gauge(v) => {
                    if family != last_family {
                        let kind = if matches!(e.value, SnapValue::Counter(_)) {
                            "counter"
                        } else {
                            "gauge"
                        };
                        let _ = writeln!(out, "# TYPE {family} {kind}");
                        last_family = family.clone();
                    }
                    let _ = writeln!(out, "{family}{} {v}", e.key.label_str(None));
                }
                SnapValue::Histogram { buckets, count, sum } => {
                    if family != last_family {
                        let _ = writeln!(out, "# TYPE {family} histogram");
                        last_family = family.clone();
                    }
                    let mut cum = 0u64;
                    for (i, b) in buckets.iter().enumerate() {
                        cum += b;
                        // bucket i holds v < 2^i; skip interior zeros to keep
                        // the 65-bucket range readable, but always emit a
                        // first bound and +Inf
                        if *b == 0 && i > 0 && i + 1 < buckets.len() {
                            continue;
                        }
                        let le = if i + 1 == buckets.len() {
                            "+Inf".to_string()
                        } else {
                            format!("{}", 1u128 << i)
                        };
                        let _ = writeln!(
                            out,
                            "{family}_bucket{} {cum}",
                            e.key.label_str(Some(("le", &le)))
                        );
                    }
                    let _ = writeln!(out, "{family}_sum{} {sum}", e.key.label_str(None));
                    let _ = writeln!(out, "{family}_count{} {count}", e.key.label_str(None));
                }
            }
        }
        out
    }

    /// JSON dump: `{subsystem: {name{labels}: value | {count, sum}}}` via
    /// the crate's own [`Json`] (deterministic key order).
    pub fn to_json(&self) -> Json {
        let mut subsystems: BTreeMap<String, BTreeMap<String, Json>> = BTreeMap::new();
        for e in &self.entries {
            let slot = format!("{}{}", e.key.name, e.key.label_str(None));
            let value = match &e.value {
                SnapValue::Counter(v) | SnapValue::Gauge(v) => Json::int(*v as i64),
                SnapValue::Histogram { count, sum, .. } => Json::obj(vec![
                    ("count", Json::int(*count as i64)),
                    ("sum", Json::int(*sum as i64)),
                ]),
            };
            subsystems.entry(e.key.subsystem.clone()).or_default().insert(slot, value);
        }
        Json::Obj(
            subsystems
                .into_iter()
                .map(|(k, v)| (k, Json::Obj(v.into_iter().collect())))
                .collect(),
        )
    }
}

/// Parse Prometheus text exposition back into `series → value` (series =
/// `name{labels}` exactly as rendered). The inverse of
/// [`Snapshot::prometheus_text`] for round-trip validation; `# `-comment
/// and blank lines are skipped.
pub fn parse_prometheus(text: &str) -> Result<BTreeMap<String, f64>> {
    let mut out = BTreeMap::new();
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let Some((series, value)) = line.rsplit_once(' ') else {
            bail!("line {}: no value separator in '{line}'", ln + 1);
        };
        let v: f64 = match value {
            "+Inf" => f64::INFINITY,
            "-Inf" => f64::NEG_INFINITY,
            v => v.parse().map_err(|e| anyhow::anyhow!("line {}: bad value: {e}", ln + 1))?,
        };
        if out.insert(series.to_string(), v).is_some() {
            bail!("line {}: duplicate series '{series}'", ln + 1);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_reads_the_handles_it_registered() {
        let reg = Registry::new();
        let c = Counter::new();
        let g = Gauge::new();
        reg.register_counter("serve", "served", &[("shard", "0")], &c).unwrap();
        reg.register_gauge("serve", "queue_depth", &[("shard", "0")], &g).unwrap();
        c.add(41);
        c.inc();
        g.set(7);
        let snap = reg.snapshot();
        // the snapshot is the handle's value — same atomic, no copies
        assert_eq!(snap.scalar("serve", "served", &[("shard", "0")]), Some(42));
        assert_eq!(snap.scalar("serve", "queue_depth", &[("shard", "0")]), Some(7));
        assert_eq!(snap.scalar("serve", "served", &[]), None);
    }

    #[test]
    fn duplicate_keys_are_rejected_and_label_order_is_canonical() {
        let reg = Registry::new();
        let c = Counter::new();
        reg.register_counter("s", "n", &[("a", "1"), ("b", "2")], &c).unwrap();
        // same key, labels in the other order: still a duplicate
        let err = reg.register_counter("s", "n", &[("b", "2"), ("a", "1")], &c);
        assert!(err.is_err(), "label order must not create distinct keys");
        // different label value is a distinct series
        reg.register_counter("s", "n", &[("a", "1"), ("b", "3")], &c).unwrap();
    }

    #[test]
    fn scalar_sum_rolls_up_across_label_sets() {
        let reg = Registry::new();
        let (a, b) = (Counter::new(), Counter::new());
        reg.register_counter("serve", "served", &[("shard", "0")], &a).unwrap();
        reg.register_counter("serve", "served", &[("shard", "1")], &b).unwrap();
        a.add(3);
        b.add(4);
        assert_eq!(reg.snapshot().scalar_sum("serve", "served"), 7);
    }

    #[test]
    fn log2_bucket_boundaries() {
        assert_eq!(Histogram::bucket_of(0), 0);
        assert_eq!(Histogram::bucket_of(1), 1);
        assert_eq!(Histogram::bucket_of(2), 2);
        assert_eq!(Histogram::bucket_of(3), 2);
        assert_eq!(Histogram::bucket_of(4), 3);
        assert_eq!(Histogram::bucket_of(u64::MAX), 64);
        let h = Histogram::new();
        for v in [0u64, 1, 2, 3, 4, u64::MAX] {
            h.record(v);
        }
        let b = h.buckets();
        assert_eq!(b[0], 1);
        assert_eq!(b[1], 1);
        assert_eq!(b[2], 2);
        assert_eq!(b[3], 1);
        assert_eq!(b[64], 1);
        assert_eq!(h.count(), 6);
        assert_eq!(h.sum(), 10u64.wrapping_add(u64::MAX));
    }

    #[test]
    fn prometheus_text_round_trips() {
        let reg = Registry::new();
        let c = Counter::new();
        let g = Gauge::new();
        let h = Histogram::new();
        reg.register_counter("serve", "served", &[("variant", "lrd")], &c).unwrap();
        reg.register_gauge("runtime", "uploads", &[], &g).unwrap();
        reg.register_histogram("serve", "latency_us", &[("variant", "lrd")], &h).unwrap();
        c.add(12);
        g.set(99);
        h.record(3);
        h.record(1000);

        let text = reg.snapshot().prometheus_text();
        assert!(text.contains("# TYPE lrta_serve_served counter"), "{text}");
        assert!(text.contains("# TYPE lrta_runtime_uploads gauge"), "{text}");
        assert!(text.contains("# TYPE lrta_serve_latency_us histogram"), "{text}");

        let parsed = parse_prometheus(&text).unwrap();
        assert_eq!(parsed["lrta_serve_served{variant=\"lrd\"}"], 12.0);
        assert_eq!(parsed["lrta_runtime_uploads"], 99.0);
        assert_eq!(parsed["lrta_serve_latency_us_count{variant=\"lrd\"}"], 2.0);
        assert_eq!(parsed["lrta_serve_latency_us_sum{variant=\"lrd\"}"], 1003.0);
        // cumulative buckets: v=3 lands below 4, both land below +Inf
        assert_eq!(parsed["lrta_serve_latency_us_bucket{variant=\"lrd\",le=\"4\"}"], 1.0);
        assert_eq!(parsed["lrta_serve_latency_us_bucket{variant=\"lrd\",le=\"+Inf\"}"], 2.0);
    }

    #[test]
    fn json_dump_parses_and_groups_by_subsystem() {
        let reg = Registry::new();
        let c = Counter::new();
        let h = Histogram::new();
        reg.register_counter("runtime", "uploads", &[], &c).unwrap();
        reg.register_histogram("serve", "latency_us", &[], &h).unwrap();
        c.add(5);
        h.record(16);
        let j = reg.snapshot().to_json();
        let parsed = Json::parse(&j.emit()).unwrap();
        assert_eq!(parsed.get("runtime").get("uploads").as_i64(), Some(5));
        assert_eq!(parsed.get("serve").get("latency_us").get("count").as_i64(), Some(1));
        assert_eq!(parsed.get("serve").get("latency_us").get("sum").as_i64(), Some(16));
    }

    #[test]
    fn parse_prometheus_rejects_garbage() {
        assert!(parse_prometheus("lonely_token").is_err());
        assert!(parse_prometheus("a 1\na 2").is_err(), "duplicate series must fail");
        assert!(parse_prometheus("a not_a_number").is_err());
    }
}
