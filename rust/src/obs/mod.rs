//! Unified observability: a metrics registry and lifecycle span tracing,
//! shared by the serve, train, and runtime subsystems.
//!
//! Two halves, both dependency-free:
//!
//! - [`registry`] — typed atomic [`Counter`]/[`Gauge`]/[`Histogram`] handles
//!   indexed by a `(subsystem, name, labels)` [`Registry`], with a
//!   [`Snapshot`] API, Prometheus text exposition, and a JSON dump. The
//!   existing hand-rolled counters (runtime transfer channels, serve shard
//!   stats) *are* the registered handles — registering shares the atomic, so
//!   registry values match the legacy accessors bit-for-bit.
//! - [`trace`] — manual lifecycle spans ([`Tracer::start`] / [`Tracer::end`])
//!   into a bounded ring, exported as Chrome/Perfetto trace-event JSON
//!   (`lrta serve --trace-out FILE`, `lrta train --trace-out FILE`). The
//!   serve request path records submit → queue_wait → coalesce → upload →
//!   dispatch → fetch → demux → reply; the train step path records
//!   prefetch_wait → upload → dispatch → fetch plus freeze_swap,
//!   average_barrier, and eval.
//!
//! Everything defaults to *off*: [`Tracer::default`] is the no-op recorder
//! (one branch per span site, no clock reads, no locks) and nothing
//! registers into a registry unless a caller supplies one — there is no
//! process-global state.

pub mod registry;
pub mod trace;

pub use registry::{
    parse_prometheus, Counter, Gauge, Histogram, MetricKey, Registry, SnapEntry, SnapValue,
    Snapshot,
};
pub use trace::{SpanStart, TraceEvent, Tracer, TRACE_CAP};
