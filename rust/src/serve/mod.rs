//! `lrta::serve` — the production inference-serving subsystem (Table 1's
//! "Infer Speed" claim, turned into an actual serving layer).
//!
//! The paper's headline inference result — up to 37% faster serving from
//! rank-optimized LRD — only materializes in a server that exploits the
//! smaller parameter footprint: compressed weights stay **resident on
//! device** and requests are **batched** onto the compiled batch shape.
//! This module is that layer:
//!
//! ```text
//!  submit(model, variant, image)
//!        │
//!        ▼
//!  [router]──(model, variant)──▶ shard pick: min queue depth,
//!        │                       round-robin tie-break
//!        ├────────────┬──────────────┐
//!        ▼            ▼              ▼
//!     [queue 0]    [queue 1]  …  [queue N-1]   bounded, admission-
//!        │            │              │         controlled; per-request
//!        ▼            ▼              ▼         SLO deadlines
//!    [batcher]    [batcher]     [batcher]      coalesce ≤ compiled batch,
//!        │            │              │         max-wait deadline, zero-pad,
//!        ▼            ▼              ▼         shed expired work at pop
//!    [engine 0]   [engine 1]    [engine N-1]   one worker thread per shard:
//!        │            │              │         own PJRT client + executable,
//!        ▼            ▼              ▼         own resident parameter set
//!     demux rows ──────────────▶ per-request [`Response`]
//! ```
//!
//! `orig`, `lrd` and `rankopt` checkpoints of the same model register as
//! separate variants and serve side-by-side, so A/B throughput comparison
//! is a routing decision, not a redeploy. A variant scales out with
//! [`VariantSpec::with_shards`]: N identical workers behind one routing
//! key, requests fanned out to the shallowest queue (round-robin on ties),
//! with per-request logits bit-identical to the single-engine path.
//! Per-variant latency percentiles, queue-depth gauges, fps and
//! host↔device transfer counters live in [`stats`]; with shards the
//! variant-level snapshot merges the per-shard sinks.
//!
//! **SLO-aware shedding**: `ServerConfig::slo` stamps every admitted
//! request with a deadline; the batcher sheds work whose deadline has
//! passed *at pop time* (counted in stats, answered with
//! [`ServeError::DeadlineExceeded`]) so a backlogged engine stops burning
//! executable slots on answers nobody is waiting for.
//!
//! **Rank-aware QoS** ([`qos`]): with `ServerConfig::qos` set, requests
//! carry a priority class (`interactive`/`standard`/`batch`, tagged via
//! [`Server::submit_class`] or `lrta serve --classes`), each shard's queue
//! becomes a per-class multi-queue popped on a weighted-round-robin slot
//! schedule, and per-class SLOs replace the server-wide deadline. Under
//! pressure low-priority work *degrades instead of sheds*: the batcher
//! spills expired requests down a [`DegradePolicy`] ladder to a cheaper
//! registered variant of the same model (rank ⇄ latency as a live serving
//! policy). A hedge governor re-dispatches tail-slow in-flight batches to
//! the shallowest sibling shard — first answer wins, the loser is
//! cancelled, both are counted. With `qos: None` every path delegates to
//! the original single-class code, pinned bit-identical in
//! `integration_serve`.
//!
//! **Warm variant swap**: [`Server::swap_variant`] uploads a new
//! checkpoint's buffers beside the live set on every shard and flips
//! atomically between batches — a zero-downtime redeploy that loses no
//! in-flight request.
//!
//! **Streaming admission** (default): resident engines split execution into
//! dispatch/fetch halves ([`crate::runtime::pipeline`]) — while batch N
//! executes, the worker coalesces and uploads batch N+1 and dispatches it
//! before fetching N's logits, so under backlog the device never idles
//! between batches. With an empty queue the engine fetches immediately, so
//! low-traffic latency is unchanged (`ServerConfig::pipelined = false`
//! restores the lockstep loop as a baseline).
//!
//! **Observability** ([`crate::obs`]): `ServerConfig::registry` exposes
//! every shard's counters and queue-depth gauge through the metrics
//! registry — the same atomics the snapshots read, labelled
//! `model`/`variant`/`shard` — and `ServerConfig::tracer` records the
//! request lifecycle (submit → queue_wait → coalesce → upload → dispatch →
//! fetch → demux → reply) for `lrta serve --trace-out` Chrome/Perfetto
//! traces. Both default to off and cost nothing when unset.
//!
//! The PJRT client is not `Send` (it holds an `Rc`), so each engine worker
//! creates its *own* [`Runtime`](crate::runtime::Runtime) inside its thread;
//! requests and responses cross threads as plain `Send` data (`Vec<f32>` +
//! mpsc senders). Shutdown closes every queue, drains in-flight work and
//! joins the workers.
//!
//! Entry points: [`Server::start`], [`Server::submit`], the `lrta serve`
//! subcommand, and `examples/serve_infer.rs`.

pub mod batcher;
pub mod engine;
pub mod qos;
pub mod queue;
pub mod router;
pub mod stats;

pub use qos::{Class, ClassPolicy, ClassQueues, DegradePolicy, HedgeConfig, QosConfig};
pub use router::{Router, Server, ServerConfig, VariantSpec};
pub use stats::{LatencyHistogram, SharedStats, StatsSnapshot};

use crate::data::{Dataset, IMAGE_ELEMS};
use crate::util::stats::percentile_sorted;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// One enqueued inference request: a single sample (row-major `[32,32,3]`
/// image) plus the response channel it is demuxed back onto.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub x: Vec<f32>,
    pub enqueued: Instant,
    /// Admission deadline (`enqueued + slo`): work still queued past this
    /// instant is shed at pop time with [`ServeError::DeadlineExceeded`]
    /// instead of wasting an executable slot on an answer the client has
    /// already given up on. `None` = no SLO, never shed.
    pub deadline: Option<Instant>,
    pub tx: mpsc::Sender<Result<Response, ServeError>>,
    /// Priority class ([`qos::Class`]); `Standard` on the QoS-off path,
    /// where it is never consulted.
    pub class: Class,
    /// First-answer-wins guard shared between a hedged request and its
    /// re-dispatched copy. `None` (the QoS-off and unhedged case) means
    /// [`Request::respond`] sends unconditionally, exactly as before.
    pub hedge: Option<Arc<AtomicBool>>,
    /// True on the governor's re-dispatched copy of a hedged request —
    /// a copy that wins the race is counted as a hedge win.
    pub hedged_copy: bool,
}

/// What [`Request::respond`] actually did: hedged requests share one
/// response channel between two executions, and exactly one of them sends.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Delivery {
    /// The result was sent (a hung-up client is still `Sent`).
    Sent,
    /// A sibling execution answered first; this result was dropped.
    Cancelled,
}

impl Request {
    /// Deliver the result; a hung-up client is not an error. With a hedge
    /// guard installed, only the first of the racing executions sends —
    /// the loser reports [`Delivery::Cancelled`] so the engine can count
    /// it without double-replying.
    pub fn respond(self, r: Result<Response, ServeError>) -> Delivery {
        if let Some(guard) = &self.hedge {
            if guard.swap(true, Ordering::AcqRel) {
                return Delivery::Cancelled;
            }
        }
        let _ = self.tx.send(r);
        Delivery::Sent
    }

    /// Has this request's admission deadline passed?
    pub(crate) fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// Answer every request still sitting in a queue with
/// [`ServeError::Shutdown`]. Callers blocked on a [`Pending`] must always
/// receive a terminal response: the normal close path drains the queue
/// through the batcher, but a worker that died mid-run (or never came up)
/// leaves admitted requests behind — this is the backstop that unwedges
/// their submitters.
pub(crate) fn drain_shutdown(queue: &qos::ClassQueues) {
    for req in queue.drain() {
        req.respond(Err(ServeError::Shutdown));
    }
}

/// Per-request result demuxed out of a batched execution.
#[derive(Clone, Debug, PartialEq)]
pub struct Response {
    /// This request's logits row (`[num_classes]`).
    pub logits: Vec<f32>,
    /// End-to-end latency: enqueue → demux (includes queue wait).
    pub latency: Duration,
    /// Real requests in the executed batch (rest was padding).
    pub batch_fill: usize,
}

impl Response {
    /// NaN-safe argmax over the logits row (shared with the evaluation
    /// paths — see [`crate::util::stats::argmax_f32`]).
    pub fn predicted_class(&self) -> usize {
        crate::util::stats::argmax_f32(&self.logits)
    }
}

/// Serving-layer errors surfaced to clients.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// Admission control rejected the request (queue at capacity).
    QueueFull { depth: usize },
    /// The target engine is shut down.
    Closed,
    /// No response within the client's wait deadline.
    Timeout,
    /// The request's admission deadline (`--slo-ms`) passed while it was
    /// still queued; it was shed at pop time without executing.
    DeadlineExceeded,
    /// The server shut down before the request was served (terminal answer
    /// for work drained out of a closed queue).
    Shutdown,
    /// Every shard of the target variant is down (worker died; the
    /// supervisor is respawning it or has exhausted its respawn budget).
    /// Transient when supervision is on — retry after a short backoff.
    ShardDown,
    /// `(model, variant)` was never registered with the router.
    UnknownVariant(String),
    /// Payload length does not match the artifact's per-item element count.
    BadInput { expected: usize, got: usize },
    /// The engine failed executing the batch.
    Engine(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::QueueFull { depth } => write!(f, "queue full (depth {depth})"),
            ServeError::Closed => write!(f, "server closed"),
            ServeError::Timeout => write!(f, "timed out waiting for response"),
            ServeError::DeadlineExceeded => {
                write!(f, "admission deadline exceeded while queued (shed at pop)")
            }
            ServeError::Shutdown => write!(f, "server shut down before the request was served"),
            ServeError::ShardDown => {
                write!(f, "shard down (worker died; respawn pending or exhausted)")
            }
            ServeError::UnknownVariant(k) => write!(f, "unknown variant '{k}'"),
            ServeError::BadInput { expected, got } => {
                write!(f, "bad input: expected {expected} elements, got {got}")
            }
            ServeError::Engine(e) => write!(f, "engine error: {e}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Handle to an in-flight request.
#[derive(Debug)]
pub struct Pending {
    pub(crate) rx: mpsc::Receiver<Result<Response, ServeError>>,
}

impl Pending {
    /// Block until the engine responds (or `timeout` elapses).
    pub fn wait(&self, timeout: Duration) -> Result<Response, ServeError> {
        match self.rx.recv_timeout(timeout) {
            Ok(r) => r,
            Err(mpsc::RecvTimeoutError::Timeout) => Err(ServeError::Timeout),
            Err(mpsc::RecvTimeoutError::Disconnected) => Err(ServeError::Closed),
        }
    }
}

// ---------------------------------------------------------------------------
// synthetic load generation (shared by `lrta serve`, the example, the bench)
// ---------------------------------------------------------------------------

/// Outcome of one load-generation run against a single variant.
#[derive(Clone, Debug, Default)]
pub struct LoadReport {
    pub requests: usize,
    pub completed: usize,
    pub errors: usize,
    /// Requests shed for missing their admission deadline
    /// ([`ServeError::DeadlineExceeded`]) — SLO pressure, not failures.
    pub shed: usize,
    /// Admission-control rejections observed (each was retried).
    pub rejected: u64,
    pub wall_secs: f64,
    /// Sorted end-to-end request latencies in seconds.
    pub latencies: Vec<f64>,
}

impl LoadReport {
    /// Completed requests per second of wall time (goodput).
    pub fn observed_fps(&self) -> f64 {
        if self.wall_secs > 0.0 {
            self.completed as f64 / self.wall_secs
        } else {
            0.0
        }
    }

    /// Latency percentile in milliseconds (`p` in `[0, 100]`).
    pub fn latency_ms(&self, p: f64) -> f64 {
        if self.latencies.is_empty() {
            0.0
        } else {
            percentile_sorted(&self.latencies, p) * 1e3
        }
    }

    fn finish(mut self, t0: Instant) -> LoadReport {
        self.wall_secs = t0.elapsed().as_secs_f64();
        self.latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
        self.completed = self.latencies.len();
        self
    }
}

fn image_of(data: &Dataset, i: usize) -> Vec<f32> {
    assert!(!data.is_empty(), "load generator needs a non-empty dataset");
    let idx = i % data.len();
    data.images[idx * IMAGE_ELEMS..(idx + 1) * IMAGE_ELEMS].to_vec()
}

/// Closed-loop load: `concurrency` synthetic clients, each submitting its
/// next request only after the previous response arrives. Latency under
/// this load is what a real client would observe; queue-full rejections are
/// retried (and counted) so backpressure is visible in the report.
pub fn closed_loop(
    server: &Server,
    model: &str,
    variant: &str,
    data: &Dataset,
    requests: usize,
    concurrency: usize,
    timeout: Duration,
) -> LoadReport {
    use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
    use std::sync::Mutex;

    let next = AtomicUsize::new(0);
    let rejected = AtomicU64::new(0);
    let errors = AtomicUsize::new(0);
    let shed = AtomicUsize::new(0);
    let latencies: Mutex<Vec<f64>> = Mutex::new(Vec::with_capacity(requests));
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..concurrency.max(1) {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= requests {
                    break;
                }
                let outcome = loop {
                    match server.submit(model, variant, image_of(data, i)) {
                        Ok(p) => break Some(p),
                        Err(ServeError::QueueFull { .. }) => {
                            rejected.fetch_add(1, Ordering::Relaxed);
                            std::thread::sleep(Duration::from_micros(200));
                        }
                        Err(_) => break None,
                    }
                };
                match outcome.map(|p| p.wait(timeout)) {
                    Some(Ok(resp)) => {
                        latencies.lock().unwrap().push(resp.latency.as_secs_f64());
                    }
                    Some(Err(ServeError::DeadlineExceeded)) => {
                        shed.fetch_add(1, Ordering::Relaxed);
                    }
                    _ => {
                        errors.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }
    });
    let report = LoadReport {
        requests,
        completed: 0,
        errors: errors.into_inner(),
        shed: shed.into_inner(),
        rejected: rejected.into_inner(),
        wall_secs: 0.0,
        latencies: latencies.into_inner().unwrap(),
    };
    report.finish(t0)
}

/// Open-loop burst: submit all `requests` as fast as admission control
/// allows (retrying rejections), then await every response. Keeps batches
/// full without an army of client threads — the throughput-measuring mode.
pub fn burst_loop(
    server: &Server,
    model: &str,
    variant: &str,
    data: &Dataset,
    requests: usize,
    timeout: Duration,
) -> LoadReport {
    let mut report = LoadReport { requests, ..Default::default() };
    let mut pendings = Vec::with_capacity(requests);
    let t0 = Instant::now();
    for i in 0..requests {
        loop {
            match server.submit(model, variant, image_of(data, i)) {
                Ok(p) => {
                    pendings.push(p);
                    break;
                }
                Err(ServeError::QueueFull { .. }) => {
                    report.rejected += 1;
                    std::thread::sleep(Duration::from_micros(100));
                }
                Err(_) => {
                    report.errors += 1;
                    break;
                }
            }
        }
    }
    for p in &pendings {
        match p.wait(timeout) {
            Ok(resp) => report.latencies.push(resp.latency.as_secs_f64()),
            Err(ServeError::DeadlineExceeded) => report.shed += 1,
            Err(_) => report.errors += 1,
        }
    }
    report.finish(t0)
}

/// [`burst_loop`] with a class mix: request `i` is tagged
/// `mix[i % mix.len()]` via [`Server::submit_class`], and the outcome is
/// reported **per class** (indexed by [`Class::index`]) so per-class SLO
/// attainment, spill goodput and shed counts are separable. A spilled
/// request that a cheaper variant answers counts as completed for its
/// class — degrade-not-shed is visible as goodput, not as loss.
pub fn classed_burst_loop(
    server: &Server,
    model: &str,
    variant: &str,
    data: &Dataset,
    requests: usize,
    mix: &[Class],
    timeout: Duration,
) -> [LoadReport; 3] {
    assert!(!mix.is_empty(), "class mix must be non-empty");
    let mut reports: [LoadReport; 3] = Default::default();
    let mut pendings: Vec<(usize, Pending)> = Vec::with_capacity(requests);
    let t0 = Instant::now();
    for i in 0..requests {
        let class = mix[i % mix.len()];
        let c = class.index();
        reports[c].requests += 1;
        loop {
            match server.submit_class(model, variant, image_of(data, i), class) {
                Ok(p) => {
                    pendings.push((c, p));
                    break;
                }
                Err(ServeError::QueueFull { .. }) => {
                    reports[c].rejected += 1;
                    std::thread::sleep(Duration::from_micros(100));
                }
                Err(_) => {
                    reports[c].errors += 1;
                    break;
                }
            }
        }
    }
    for (c, p) in &pendings {
        match p.wait(timeout) {
            Ok(resp) => reports[*c].latencies.push(resp.latency.as_secs_f64()),
            Err(ServeError::DeadlineExceeded) => reports[*c].shed += 1,
            Err(_) => reports[*c].errors += 1,
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    reports.map(|mut r| {
        r.wall_secs = wall;
        r.latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
        r.completed = r.latencies.len();
        r
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_predicted_class() {
        let r = Response {
            logits: vec![0.1, 2.0, -1.0],
            latency: Duration::from_millis(1),
            batch_fill: 1,
        };
        assert_eq!(r.predicted_class(), 1);
    }

    #[test]
    fn serve_error_displays() {
        assert!(ServeError::QueueFull { depth: 8 }.to_string().contains("depth 8"));
        assert!(ServeError::BadInput { expected: 4, got: 2 }.to_string().contains("4"));
        assert!(ServeError::UnknownVariant("m/v".into()).to_string().contains("m/v"));
        assert!(ServeError::DeadlineExceeded.to_string().contains("deadline"));
        assert!(ServeError::Shutdown.to_string().contains("shut down"));
        assert!(ServeError::ShardDown.to_string().contains("worker died"));
    }

    #[test]
    fn request_expiry_is_deadline_gated() {
        let (tx, _rx) = mpsc::channel();
        let now = Instant::now();
        let mut r = Request {
            id: 0,
            x: vec![],
            enqueued: now,
            deadline: None,
            tx,
            class: Class::Standard,
            hedge: None,
            hedged_copy: false,
        };
        assert!(!r.expired(now), "no deadline: never expires");
        r.deadline = Some(now + Duration::from_secs(60));
        assert!(!r.expired(now));
        r.deadline = Some(now);
        assert!(r.expired(now), "deadline reached counts as expired");
    }

    #[test]
    fn hedged_respond_sends_exactly_once() {
        // two executions of the same request share one guard + channel;
        // the first respond sends, the second is cancelled without sending
        let (tx, rx) = mpsc::channel();
        let guard = Arc::new(AtomicBool::new(false));
        let mk = |copy: bool| Request {
            id: 9,
            x: vec![],
            enqueued: Instant::now(),
            deadline: None,
            tx: tx.clone(),
            class: Class::Interactive,
            hedge: Some(guard.clone()),
            hedged_copy: copy,
        };
        let resp = Response {
            logits: vec![1.0],
            latency: Duration::from_millis(1),
            batch_fill: 1,
        };
        assert_eq!(mk(true).respond(Ok(resp.clone())), Delivery::Sent);
        assert_eq!(mk(false).respond(Ok(resp)), Delivery::Cancelled);
        drop(tx);
        let p = Pending { rx };
        assert!(p.wait(Duration::from_millis(10)).is_ok());
        assert_eq!(p.wait(Duration::from_millis(10)), Err(ServeError::Closed), "one reply only");
    }

    #[test]
    fn drain_shutdown_answers_blocked_submitters() {
        // the shutdown-drain satellite: a worker that died leaves admitted
        // requests in its queue; drain must give each a terminal answer so
        // a caller blocked on `Pending::wait` unwedges immediately
        let q = qos::ClassQueues::single(4);
        let mut rxs = Vec::new();
        for id in 0..3 {
            let (tx, rx) = mpsc::channel();
            let req = Request {
                id,
                x: vec![],
                enqueued: Instant::now(),
                deadline: None,
                tx,
                class: Class::Standard,
                hedge: None,
                hedged_copy: false,
            };
            q.try_push(Class::Standard, req).unwrap();
            rxs.push(Pending { rx });
        }
        q.close();
        drain_shutdown(&q);
        assert!(q.is_empty());
        for p in &rxs {
            assert_eq!(p.wait(Duration::from_millis(50)), Err(ServeError::Shutdown));
        }
    }

    #[test]
    fn pending_times_out_and_disconnects() {
        let (tx, rx) = mpsc::channel();
        let p = Pending { rx };
        assert_eq!(p.wait(Duration::from_millis(5)), Err(ServeError::Timeout));
        drop(tx);
        assert_eq!(p.wait(Duration::from_millis(5)), Err(ServeError::Closed));
    }

    #[test]
    fn load_report_stats() {
        let r = LoadReport {
            requests: 3,
            completed: 3,
            errors: 0,
            shed: 0,
            rejected: 1,
            wall_secs: 2.0,
            latencies: vec![0.001, 0.002, 0.010],
        };
        assert!((r.observed_fps() - 1.5).abs() < 1e-12);
        assert!((r.latency_ms(50.0) - 2.0).abs() < 1e-9);
        assert_eq!(LoadReport::default().observed_fps(), 0.0);
        assert_eq!(LoadReport::default().latency_ms(99.0), 0.0);
    }
}
