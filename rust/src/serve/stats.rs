//! Per-variant serving telemetry: latency histograms (p50/p95/p99),
//! queue-depth gauges, batch-fill accounting, and fps built on
//! [`ThroughputMeter`](crate::metrics::ThroughputMeter).
//!
//! One [`SharedStats`] is cloned into the router's submit path and the
//! engine's worker thread. The monotonic counters (served, shed, swaps, …)
//! are [`obs::Counter`]/[`obs::Gauge`] atomics living *outside* the mutex —
//! [`SharedStats::register`] hands those same handles to an
//! [`obs::Registry`], so registry snapshots match [`SharedStats::snapshot`]
//! bit-for-bit by construction. The mutex only guards what genuinely needs
//! it (the sample-retaining histogram, the throughput meter, and the
//! dispatch/fetch time split), and snapshots clone the raw samples under
//! the lock but sort them *outside* it, so percentile cost never serializes
//! the submit path.

use super::qos::Class;
use crate::metrics::ThroughputMeter;
use crate::obs;
use crate::util::stats::percentile_sorted;
use anyhow::Result;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Number of doubling latency buckets, first edge at 0.25 ms — covers
/// 0.25 ms .. ~8 s.
pub const HIST_BUCKETS: usize = 16;

/// Cap on retained raw latency samples (percentiles are computed over the
/// first `SAMPLE_CAP` requests; the bucket counts keep accumulating).
const SAMPLE_CAP: usize = 1 << 18;

/// Log₂-bucketed latency histogram that also retains (capped) raw samples
/// for exact percentiles.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    buckets: [u64; HIST_BUCKETS],
    samples: Vec<f64>,
    count: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram { buckets: [0; HIST_BUCKETS], samples: Vec::new(), count: 0 }
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bucket index for a latency: bucket `i` holds `secs < 0.25ms · 2^i`
    /// (last bucket is open-ended).
    pub fn bucket_of(secs: f64) -> usize {
        let mut edge = 0.25e-3;
        let mut i = 0;
        while i + 1 < HIST_BUCKETS && secs >= edge {
            edge *= 2.0;
            i += 1;
        }
        i
    }

    pub fn record(&mut self, secs: f64) {
        self.buckets[Self::bucket_of(secs)] += 1;
        self.count += 1;
        if self.samples.len() < SAMPLE_CAP {
            self.samples.push(secs);
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// The retained raw samples (at most `SAMPLE_CAP` of them), unsorted.
    /// Snapshot paths clone this under the stats lock and sort the clone
    /// outside it.
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    /// Exact percentiles over the retained samples, one sort for all of
    /// them (zeros when empty). Convenience for standalone histograms; the
    /// [`SharedStats`] snapshot paths deliberately avoid calling this under
    /// the shared mutex — they clone [`LatencyHistogram::samples`] under
    /// the lock and sort outside instead.
    pub fn percentiles(&self, ps: &[f64]) -> Vec<f64> {
        if self.samples.is_empty() {
            return vec![0.0; ps.len()];
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ps.iter().map(|&p| percentile_sorted(&s, p)).collect()
    }

    /// Exact percentile over the retained samples (0.0 when empty).
    pub fn percentile(&self, p: f64) -> f64 {
        self.percentiles(&[p])[0]
    }

    /// ASCII rendering, one row per non-empty bucket.
    pub fn render(&self, width: usize) -> String {
        let max = self.buckets.iter().copied().max().unwrap_or(0);
        if max == 0 {
            return "(no samples)\n".to_string();
        }
        let mut out = String::new();
        let mut edge = 0.25e-3;
        for (i, &n) in self.buckets.iter().enumerate() {
            let upper = if i + 1 == HIST_BUCKETS { f64::INFINITY } else { edge };
            if n > 0 {
                let bar = "#".repeat(((n as f64 / max as f64) * width as f64).ceil() as usize);
                out.push_str(&format!("< {:>8.2} ms | {bar} {n}\n", upper * 1e3));
            }
            edge *= 2.0;
        }
        out
    }
}

/// The parts that genuinely need the mutex: the sample-retaining histogram,
/// the throughput meter, the executable-time accounting (split into its
/// dispatch and fetch halves), and the two non-monotonic scalars.
#[derive(Debug)]
struct Inner {
    hist: LatencyHistogram,
    /// One record per executable run; items = compiled batch size, so
    /// `fps()` is the paper-style full-batch device throughput.
    exec_meter: ThroughputMeter,
    exec_secs_total: f64,
    /// Host time spent enqueueing executions (non-blocking half). On the
    /// serial engine path the whole run counts as dispatch.
    dispatch_secs_total: f64,
    /// Host time spent waiting on / demuxing results (blocking half).
    fetch_secs_total: f64,
    max_queue_depth: usize,
    spot_check_acc: Option<f64>,
}

/// Thread-shared per-variant stats sink.
///
/// The monotonic counters are lock-free [`obs::Counter`]s (and the two
/// transfer gauges are [`obs::Gauge`]s) so [`SharedStats::register`] can
/// expose the *same* atomics through a registry — no double bookkeeping,
/// no drift.
#[derive(Clone)]
pub struct SharedStats {
    model: String,
    variant: String,
    batch: usize,
    requests_ok: obs::Counter,
    rejected: obs::Counter,
    /// Requests shed at pop time for missing their admission deadline.
    /// Always the exact sum of `shed_by_class` — [`SharedStats::on_shed`]
    /// bumps both, so class-level SLO misses are never invisible.
    shed: obs::Counter,
    /// Per-class shed split (indexed by [`Class::index`]). On the QoS-off
    /// path everything lands in `Standard`.
    shed_by_class: [obs::Counter; 3],
    /// Per-class served split; like `shed`, `served == sum(served_by_class)`.
    served_by_class: [obs::Counter; 3],
    /// Expired requests degraded *out of* this variant down their class
    /// ladder instead of shed (the target variant counts the admission).
    spilled: obs::Counter,
    spilled_by_class: [obs::Counter; 3],
    /// Hedge copies re-dispatched to a sibling shard on this shard's
    /// behalf (counted on the shard whose batch ran slow).
    hedge_fired: obs::Counter,
    /// Hedge copies that answered first (counted where the copy ran).
    hedge_wins: obs::Counter,
    /// Request executions whose reply lost the first-answer-wins race and
    /// was dropped (original or copy; never double-replied).
    hedge_cancelled: obs::Counter,
    /// Warm variant swaps applied by this engine worker.
    swaps: obs::Counter,
    /// Unexpected worker-thread exits (panic or death) the shard
    /// supervisor observed.
    worker_deaths: obs::Counter,
    /// Supervised worker respawns that came back up serving.
    respawns: obs::Counter,
    errors: obs::Counter,
    batches: obs::Counter,
    served: obs::Counter,
    padded_slots: obs::Counter,
    /// Host→device transfers on the engine's runtime (gauge, set by the
    /// worker after each batch) — upload regressions surface in every
    /// report instead of hiding inside the worker thread.
    uploads: obs::Gauge,
    /// Demux fallbacks on the engine's runtime (gauge; nonzero means the
    /// backend packed tuple outputs and executions round-tripped the host).
    demux_fallbacks: obs::Gauge,
    /// Log₂ end-to-end latency histogram in µs for the registry/Prometheus
    /// view (the exact-percentile sample histogram stays inside the mutex).
    latency_us: obs::Histogram,
    inner: Arc<Mutex<Inner>>,
}

impl SharedStats {
    pub fn new(model: &str, variant: &str, batch: usize) -> SharedStats {
        SharedStats {
            model: model.to_string(),
            variant: variant.to_string(),
            batch,
            requests_ok: obs::Counter::new(),
            rejected: obs::Counter::new(),
            shed: obs::Counter::new(),
            shed_by_class: std::array::from_fn(|_| obs::Counter::new()),
            served_by_class: std::array::from_fn(|_| obs::Counter::new()),
            spilled: obs::Counter::new(),
            spilled_by_class: std::array::from_fn(|_| obs::Counter::new()),
            hedge_fired: obs::Counter::new(),
            hedge_wins: obs::Counter::new(),
            hedge_cancelled: obs::Counter::new(),
            swaps: obs::Counter::new(),
            worker_deaths: obs::Counter::new(),
            respawns: obs::Counter::new(),
            errors: obs::Counter::new(),
            batches: obs::Counter::new(),
            served: obs::Counter::new(),
            padded_slots: obs::Counter::new(),
            uploads: obs::Gauge::new(),
            demux_fallbacks: obs::Gauge::new(),
            latency_us: obs::Histogram::new(),
            inner: Arc::new(Mutex::new(Inner {
                hist: LatencyHistogram::new(),
                exec_meter: ThroughputMeter::new(batch),
                exec_secs_total: 0.0,
                dispatch_secs_total: 0.0,
                fetch_secs_total: 0.0,
                max_queue_depth: 0,
                spot_check_acc: None,
            })),
        }
    }

    /// Register this sink's counters/gauges/latency histogram under the
    /// `serve` subsystem. The registry holds the *same* atomic handles this
    /// struct increments, so a registry snapshot and a
    /// [`SharedStats::snapshot`] taken at the same quiescent point agree
    /// exactly.
    pub fn register(&self, registry: &obs::Registry, labels: &[(&str, &str)]) -> Result<()> {
        registry.register_counter("serve", "requests_ok", labels, &self.requests_ok)?;
        registry.register_counter("serve", "rejected", labels, &self.rejected)?;
        registry.register_counter("serve", "shed", labels, &self.shed)?;
        registry.register_counter("serve", "swaps", labels, &self.swaps)?;
        registry.register_counter("serve", "worker_deaths", labels, &self.worker_deaths)?;
        registry.register_counter("serve", "respawns", labels, &self.respawns)?;
        registry.register_counter("serve", "errors", labels, &self.errors)?;
        registry.register_counter("serve", "batches", labels, &self.batches)?;
        registry.register_counter("serve", "served", labels, &self.served)?;
        registry.register_counter("serve", "padded_slots", labels, &self.padded_slots)?;
        registry.register_counter("serve", "spilled", labels, &self.spilled)?;
        registry.register_counter("serve", "hedge_fired", labels, &self.hedge_fired)?;
        registry.register_counter("serve", "hedge_wins", labels, &self.hedge_wins)?;
        registry.register_counter("serve", "hedge_cancelled", labels, &self.hedge_cancelled)?;
        registry.register_gauge("serve", "uploads", labels, &self.uploads)?;
        registry.register_gauge("serve", "demux_fallbacks", labels, &self.demux_fallbacks)?;
        registry.register_histogram("serve", "latency_us", labels, &self.latency_us)?;
        // per-class splits under {…, class} — distinct family names so the
        // aggregate families keep their exact pre-QoS label sets
        for class in Class::ALL {
            let mut cl: Vec<(&str, &str)> = labels.to_vec();
            cl.push(("class", class.label()));
            let i = class.index();
            registry.register_counter("serve", "class_shed", &cl, &self.shed_by_class[i])?;
            registry.register_counter("serve", "class_served", &cl, &self.served_by_class[i])?;
            registry.register_counter("serve", "class_spilled", &cl, &self.spilled_by_class[i])?;
        }
        Ok(())
    }

    /// Gauge sample from the submit path (`depth` = queue depth after push).
    pub fn on_enqueue(&self, depth: usize) {
        self.requests_ok.inc();
        let mut g = self.inner.lock().unwrap();
        g.max_queue_depth = g.max_queue_depth.max(depth);
    }

    pub fn on_reject(&self) {
        self.rejected.inc();
    }

    /// One request of `class` shed at pop time (admission deadline
    /// exceeded, no ladder target took it). Bumps the aggregate *and* the
    /// per-class counter, so `shed == sum(shed_by_class)` by construction.
    pub fn on_shed(&self, class: Class) {
        self.shed.inc();
        self.shed_by_class[class.index()].inc();
    }

    /// One expired request of `class` degraded out of this variant down
    /// its ladder (the target shard counts the admission separately).
    pub fn on_spill(&self, class: Class) {
        self.spilled.inc();
        self.spilled_by_class[class.index()].inc();
    }

    /// One served (reply actually sent) request of `class` — the
    /// per-class half of the `served` accounting in
    /// [`SharedStats::on_batch_timed`].
    pub fn on_served_class(&self, class: Class) {
        self.served_by_class[class.index()].inc();
    }

    /// One hedge copy re-dispatched on this shard's behalf.
    pub fn on_hedge_fired(&self) {
        self.hedge_fired.inc();
    }

    /// One hedge copy that answered before the original.
    pub fn on_hedge_win(&self) {
        self.hedge_wins.inc();
    }

    /// One execution whose reply lost the first-answer race.
    pub fn on_hedge_cancelled(&self) {
        self.hedge_cancelled.inc();
    }

    /// One warm variant swap applied between batches.
    pub fn on_swap(&self) {
        self.swaps.inc();
    }

    /// One unexpected worker-thread exit observed by the shard supervisor.
    pub fn on_worker_death(&self) {
        self.worker_deaths.inc();
    }

    /// One supervised respawn that came back up serving.
    pub fn on_respawn(&self) {
        self.respawns.inc();
    }

    pub fn on_error(&self, requests: usize) {
        self.errors.add(requests as u64);
    }

    /// Record one executed batch: `fill` real requests, `padded` zero rows,
    /// the executable wall time, and per-request end-to-end latencies.
    /// Paths that don't split their timing count the whole run as dispatch.
    pub fn on_batch(&self, fill: usize, padded: usize, exec_secs: f64, latencies: &[f64]) {
        self.on_batch_timed(fill, padded, exec_secs, 0.0, latencies);
    }

    /// Like [`SharedStats::on_batch`] but with the executable wall time
    /// split into its non-blocking dispatch half and its blocking
    /// fetch/demux half (`exec = dispatch + fetch`) — the overlap-aware
    /// device timing the pipelined engines report.
    pub fn on_batch_timed(
        &self,
        fill: usize,
        padded: usize,
        dispatch_secs: f64,
        fetch_secs: f64,
        latencies: &[f64],
    ) {
        self.batches.inc();
        self.served.add(fill as u64);
        self.padded_slots.add(padded as u64);
        for &l in latencies {
            self.latency_us.record((l * 1e6) as u64);
        }
        let exec_secs = dispatch_secs + fetch_secs;
        let mut g = self.inner.lock().unwrap();
        g.exec_meter.record(exec_secs);
        g.exec_secs_total += exec_secs;
        g.dispatch_secs_total += dispatch_secs;
        g.fetch_secs_total += fetch_secs;
        for &l in latencies {
            g.hist.record(l);
        }
    }

    pub fn set_spot_check(&self, acc: f64) {
        self.inner.lock().unwrap().spot_check_acc = Some(acc);
    }

    /// Gauge sample of the engine runtime's transfer counters
    /// ([`Runtime::uploads`](crate::runtime::Runtime::uploads) /
    /// [`Runtime::demux_fallbacks`](crate::runtime::Runtime::demux_fallbacks)),
    /// set by the worker thread — the only thread that can see its runtime.
    pub fn set_transfers(&self, uploads: u64, demux_fallbacks: u64) {
        self.uploads.set(uploads);
        self.demux_fallbacks.set(demux_fallbacks);
    }

    /// Point-in-time snapshot; `queue_depth` is sampled by the caller (the
    /// router owns the queue handle). The (up to `SAMPLE_CAP`-element)
    /// sample vector is cloned under the lock but sorted *outside* it, so a
    /// snapshot never stalls `on_batch`/`on_enqueue` for the sort.
    pub fn snapshot(&self, queue_depth: usize) -> StatsSnapshot {
        let (
            exec_fps,
            exec_secs_total,
            dispatch_secs_total,
            fetch_secs_total,
            max_queue_depth,
            spot_check_acc,
            mut samples,
        ) = {
            let g = self.inner.lock().unwrap();
            (
                g.exec_meter.fps(),
                g.exec_secs_total,
                g.dispatch_secs_total,
                g.fetch_secs_total,
                g.max_queue_depth,
                g.spot_check_acc,
                g.hist.samples.clone(),
            )
        };
        let batches = self.batches.get();
        let served = self.served.get();
        let mean_fill = if batches > 0 {
            served as f64 / (batches as f64 * self.batch as f64)
        } else {
            0.0
        };
        let request_fps =
            if exec_secs_total > 0.0 { served as f64 / exec_secs_total } else { 0.0 };
        let (p50, p95, p99) = if samples.is_empty() {
            (0.0, 0.0, 0.0)
        } else {
            samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
            (
                percentile_sorted(&samples, 50.0),
                percentile_sorted(&samples, 95.0),
                percentile_sorted(&samples, 99.0),
            )
        };
        StatsSnapshot {
            model: self.model.clone(),
            variant: self.variant.clone(),
            batch: self.batch,
            requests_ok: self.requests_ok.get(),
            rejected: self.rejected.get(),
            shed: self.shed.get(),
            shed_by_class: std::array::from_fn(|i| self.shed_by_class[i].get()),
            served_by_class: std::array::from_fn(|i| self.served_by_class[i].get()),
            spilled: self.spilled.get(),
            spilled_by_class: std::array::from_fn(|i| self.spilled_by_class[i].get()),
            hedge_fired: self.hedge_fired.get(),
            hedge_wins: self.hedge_wins.get(),
            hedge_cancelled: self.hedge_cancelled.get(),
            swaps: self.swaps.get(),
            worker_deaths: self.worker_deaths.get(),
            respawns: self.respawns.get(),
            errors: self.errors.get(),
            batches,
            served,
            padded_slots: self.padded_slots.get(),
            queue_depth,
            max_queue_depth,
            exec_fps,
            request_fps,
            mean_fill,
            dispatch_secs_total,
            fetch_secs_total,
            p50_ms: p50 * 1e3,
            p95_ms: p95 * 1e3,
            p99_ms: p99 * 1e3,
            spot_check_acc,
            uploads: self.uploads.get(),
            demux_fallbacks: self.demux_fallbacks.get(),
        }
    }

    /// Rendered latency histogram for operator output.
    pub fn histogram(&self, width: usize) -> String {
        self.inner.lock().unwrap().hist.render(width)
    }

    /// Upper-bound estimate of the `p`-th end-to-end latency percentile
    /// over a shard set, read lock-free from the log₂ µs registry
    /// histograms (65 atomic loads per shard — cheap enough for the hedge
    /// governor's millisecond poll; the exact sample-sorting percentiles
    /// stay on the snapshot path). `None` until the combined histograms
    /// hold at least `min_samples` observations.
    pub fn merged_latency_budget(
        parts: &[&SharedStats],
        p: f64,
        min_samples: u64,
    ) -> Option<Duration> {
        let mut total = 0u64;
        let mut buckets: Vec<u64> = Vec::new();
        for s in parts {
            total += s.latency_us.count();
            for (i, b) in s.latency_us.buckets().iter().enumerate() {
                if buckets.len() <= i {
                    buckets.resize(i + 1, 0);
                }
                buckets[i] += b;
            }
        }
        if total < min_samples.max(1) {
            return None;
        }
        let target = (((p / 100.0).clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, b) in buckets.iter().enumerate() {
            cum += b;
            if cum >= target {
                // log₂ bucket i holds v < 2^i µs (i = 0 → v == 0); clamp the
                // shift so the +Inf bucket maps to a finite, huge budget
                let upper_us = if i == 0 { 1 } else { 1u64 << i.min(40) };
                return Some(Duration::from_micros(upper_us));
            }
        }
        None
    }

    /// Variant-level snapshot over a shard set: counters sum, queue depth
    /// sums, max depth takes the max, throughputs add (shards run
    /// concurrently on independent clients), and percentiles are exact over
    /// the union of the shards' retained samples — gathered under each
    /// shard's lock in turn, sorted once outside all of them. Each
    /// `(stats, depth)` pair is one shard's sink plus its live queue depth;
    /// a single-shard set degenerates to the plain [`SharedStats::snapshot`].
    pub fn merged(parts: &[(&SharedStats, usize)]) -> StatsSnapshot {
        assert!(!parts.is_empty(), "merged snapshot needs at least one shard");
        if parts.len() == 1 {
            return parts[0].0.snapshot(parts[0].1);
        }
        let first = parts[0].0;
        let mut snap = StatsSnapshot {
            model: first.model.clone(),
            variant: first.variant.clone(),
            batch: first.batch,
            requests_ok: 0,
            rejected: 0,
            shed: 0,
            shed_by_class: [0; 3],
            served_by_class: [0; 3],
            spilled: 0,
            spilled_by_class: [0; 3],
            hedge_fired: 0,
            hedge_wins: 0,
            hedge_cancelled: 0,
            swaps: 0,
            worker_deaths: 0,
            respawns: 0,
            errors: 0,
            batches: 0,
            served: 0,
            padded_slots: 0,
            queue_depth: 0,
            max_queue_depth: 0,
            exec_fps: 0.0,
            request_fps: 0.0,
            mean_fill: 0.0,
            dispatch_secs_total: 0.0,
            fetch_secs_total: 0.0,
            p50_ms: 0.0,
            p95_ms: 0.0,
            p99_ms: 0.0,
            spot_check_acc: None,
            uploads: 0,
            demux_fallbacks: 0,
        };
        let mut samples: Vec<f64> = Vec::new();
        for (s, depth) in parts {
            snap.requests_ok += s.requests_ok.get();
            snap.rejected += s.rejected.get();
            snap.shed += s.shed.get();
            for i in 0..3 {
                snap.shed_by_class[i] += s.shed_by_class[i].get();
                snap.served_by_class[i] += s.served_by_class[i].get();
                snap.spilled_by_class[i] += s.spilled_by_class[i].get();
            }
            snap.spilled += s.spilled.get();
            snap.hedge_fired += s.hedge_fired.get();
            snap.hedge_wins += s.hedge_wins.get();
            snap.hedge_cancelled += s.hedge_cancelled.get();
            snap.swaps += s.swaps.get();
            snap.worker_deaths += s.worker_deaths.get();
            snap.respawns += s.respawns.get();
            snap.errors += s.errors.get();
            snap.batches += s.batches.get();
            snap.served += s.served.get();
            snap.padded_slots += s.padded_slots.get();
            snap.queue_depth += depth;
            snap.uploads += s.uploads.get();
            snap.demux_fallbacks += s.demux_fallbacks.get();
            let g = s.inner.lock().unwrap();
            snap.max_queue_depth = snap.max_queue_depth.max(g.max_queue_depth);
            snap.exec_fps += g.exec_meter.fps();
            // goodput adds like exec_fps: shards execute concurrently, so
            // per-shard served/exec-seconds rates sum (dividing the total
            // served by the *summed* exec seconds would erase the scaling)
            if g.exec_secs_total > 0.0 {
                snap.request_fps += s.served.get() as f64 / g.exec_secs_total;
            }
            snap.dispatch_secs_total += g.dispatch_secs_total;
            snap.fetch_secs_total += g.fetch_secs_total;
            if snap.spot_check_acc.is_none() {
                snap.spot_check_acc = g.spot_check_acc;
            }
            samples.extend_from_slice(&g.hist.samples);
        }
        if snap.batches > 0 {
            snap.mean_fill = snap.served as f64 / (snap.batches as f64 * snap.batch as f64);
        }
        if !samples.is_empty() {
            samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
            snap.p50_ms = percentile_sorted(&samples, 50.0) * 1e3;
            snap.p95_ms = percentile_sorted(&samples, 95.0) * 1e3;
            snap.p99_ms = percentile_sorted(&samples, 99.0) * 1e3;
        }
        snap
    }
}

/// Immutable stats snapshot for reporting.
#[derive(Clone, Debug)]
pub struct StatsSnapshot {
    pub model: String,
    pub variant: String,
    pub batch: usize,
    pub requests_ok: u64,
    pub rejected: u64,
    /// Requests shed at pop time for missing their admission deadline
    /// (`--slo-ms`); exactly the count answered `DeadlineExceeded`, and
    /// exactly `shed_by_class.iter().sum()`.
    pub shed: u64,
    /// Shed split by priority class (indexed by [`Class::index`]); the
    /// QoS-off path sheds everything as `Standard`.
    pub shed_by_class: [u64; 3],
    /// Served (reply sent) split by class; sums to `served`.
    pub served_by_class: [u64; 3],
    /// Expired requests degraded *out of* this variant down their class
    /// ladder instead of shed; `spilled == spilled_by_class.iter().sum()`.
    pub spilled: u64,
    pub spilled_by_class: [u64; 3],
    /// Hedge copies re-dispatched on this shard's behalf.
    pub hedge_fired: u64,
    /// Hedge copies that answered first (`hedge_wins <= hedge_fired`).
    pub hedge_wins: u64,
    /// Executions whose reply lost the first-answer race (dropped, never
    /// double-replied).
    pub hedge_cancelled: u64,
    /// Warm variant swaps applied (summed over shards when merged).
    pub swaps: u64,
    /// Worker-thread deaths the shard supervisor observed (summed over
    /// shards when merged).
    pub worker_deaths: u64,
    /// Supervised respawns that came back up serving.
    pub respawns: u64,
    pub errors: u64,
    pub batches: u64,
    pub served: u64,
    pub padded_slots: u64,
    pub queue_depth: usize,
    pub max_queue_depth: usize,
    /// Compiled-batch device throughput (batch / median exec time).
    pub exec_fps: f64,
    /// Goodput: real requests served per second of executable time.
    pub request_fps: f64,
    /// served / (batches · batch) — how full batches ran on average.
    pub mean_fill: f64,
    /// Host seconds enqueueing executions (the non-blocking dispatch half);
    /// serial engine paths count whole runs here.
    pub dispatch_secs_total: f64,
    /// Host seconds blocked on results (the fetch/demux half). With the
    /// pipeline on, fetch dominating dispatch means the host genuinely
    /// overlapped its own work with device compute.
    pub fetch_secs_total: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub spot_check_acc: Option<f64>,
    /// Engine-runtime host→device transfer count at snapshot time.
    pub uploads: u64,
    /// Engine-runtime demux-fallback count at snapshot time (0 = every
    /// execution stayed buffer-to-buffer).
    pub demux_fallbacks: u64,
}

impl StatsSnapshot {
    pub fn table_header() -> Vec<String> {
        [
            "variant", "served", "rej", "shed", "batches", "fill%", "exec fps", "p50 ms",
            "p95 ms", "p99 ms", "acc", "uploads",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect()
    }

    pub fn table_row(&self) -> Vec<String> {
        vec![
            self.variant.clone(),
            self.served.to_string(),
            self.rejected.to_string(),
            self.shed.to_string(),
            self.batches.to_string(),
            format!("{:.0}", self.mean_fill * 100.0),
            format!("{:.0}", self.exec_fps),
            format!("{:.2}", self.p50_ms),
            format!("{:.2}", self.p95_ms),
            format!("{:.2}", self.p99_ms),
            self.spot_check_acc.map(|a| format!("{a:.3}")).unwrap_or_else(|| "-".into()),
            self.uploads.to_string(),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_edges_are_monotone() {
        assert_eq!(LatencyHistogram::bucket_of(0.0), 0);
        assert_eq!(LatencyHistogram::bucket_of(0.1e-3), 0);
        assert_eq!(LatencyHistogram::bucket_of(0.3e-3), 1);
        let mut last = 0;
        for ms in [0.1, 0.3, 0.6, 1.5, 3.0, 10.0, 100.0, 1000.0, 20_000.0] {
            let b = LatencyHistogram::bucket_of(ms * 1e-3);
            assert!(b >= last, "bucket not monotone at {ms} ms");
            last = b;
        }
        assert!(last < HIST_BUCKETS);
    }

    #[test]
    fn bucket_of_exact_edges_and_extremes() {
        // anything below the first edge lands in bucket 0 — including 0 and
        // the smallest positive double
        assert_eq!(LatencyHistogram::bucket_of(0.0), 0);
        assert_eq!(LatencyHistogram::bucket_of(f64::MIN_POSITIVE), 0);
        assert_eq!(LatencyHistogram::bucket_of(0.24e-3), 0);
        // bucket i holds secs < 0.25ms·2^i, so an *exact* edge value rolls
        // into the next bucket (doubling an f64 is exact, so the edge
        // sequence — and these comparisons — are too)
        let mut edge = 0.25e-3;
        for i in 0..HIST_BUCKETS - 1 {
            assert_eq!(LatencyHistogram::bucket_of(edge), i + 1, "at edge {i}");
            assert_eq!(LatencyHistogram::bucket_of(edge * (1.0 - 1e-12)), i, "below edge {i}");
            edge *= 2.0;
        }
        // the last bucket is open-ended: the final edge (0.25ms·2^15), huge
        // values, and infinity all clamp to HIST_BUCKETS-1
        assert_eq!(LatencyHistogram::bucket_of(edge), HIST_BUCKETS - 1);
        assert_eq!(LatencyHistogram::bucket_of(1e9), HIST_BUCKETS - 1);
        assert_eq!(LatencyHistogram::bucket_of(f64::MAX), HIST_BUCKETS - 1);
        assert_eq!(LatencyHistogram::bucket_of(f64::INFINITY), HIST_BUCKETS - 1);
    }

    #[test]
    fn percentiles_freeze_after_sample_cap_but_count_does_not() {
        let mut h = LatencyHistogram::new();
        for _ in 0..SAMPLE_CAP {
            h.record(1e-3);
        }
        assert_eq!(h.count(), SAMPLE_CAP as u64);
        assert_eq!(h.samples().len(), SAMPLE_CAP);
        let p99_before = h.percentile(99.0);
        // a huge late tail: invisible to percentiles (the sample vec is
        // full)…
        for _ in 0..1000 {
            h.record(100.0);
        }
        assert_eq!(h.percentile(99.0), p99_before);
        assert_eq!(h.samples().len(), SAMPLE_CAP, "retained samples are capped");
        // …but the total count and the bucket counters keep accumulating
        assert_eq!(h.count(), SAMPLE_CAP as u64 + 1000);
        assert!(h.render(10).contains("1000"));
    }

    #[test]
    fn histogram_percentiles_and_render() {
        let mut h = LatencyHistogram::new();
        assert_eq!(h.percentile(50.0), 0.0);
        assert!(h.render(10).contains("no samples"));
        for i in 1..=100 {
            h.record(i as f64 * 1e-3);
        }
        assert_eq!(h.count(), 100);
        assert!((h.percentile(50.0) - 0.0505).abs() < 1e-3);
        assert!(h.percentile(99.0) > 0.098);
        let rendered = h.render(20);
        assert!(rendered.contains('#'));
    }

    #[test]
    fn snapshot_counts_and_fill() {
        let s = SharedStats::new("m", "lrd", 8);
        s.on_enqueue(3);
        s.on_enqueue(5);
        s.on_reject();
        s.on_batch(6, 2, 0.010, &[0.011, 0.012, 0.013, 0.014, 0.015, 0.016]);
        s.set_spot_check(0.9);
        let snap = s.snapshot(1);
        assert_eq!(snap.requests_ok, 2);
        assert_eq!(snap.rejected, 1);
        assert_eq!(snap.served, 6);
        assert_eq!(snap.padded_slots, 2);
        assert_eq!(snap.max_queue_depth, 5);
        assert_eq!(snap.queue_depth, 1);
        assert!((snap.mean_fill - 0.75).abs() < 1e-12);
        assert!((snap.exec_fps - 800.0).abs() < 1e-6); // 8 items / 10 ms
        assert!((snap.request_fps - 600.0).abs() < 1e-6); // 6 real / 10 ms
        assert_eq!(snap.spot_check_acc, Some(0.9));
        assert!(snap.p50_ms > 10.0 && snap.p99_ms < 17.0);
        // un-split timing counts the whole run as dispatch
        assert!((snap.dispatch_secs_total - 0.010).abs() < 1e-12);
        assert_eq!(snap.fetch_secs_total, 0.0);
    }

    #[test]
    fn timed_batches_split_dispatch_from_fetch() {
        let s = SharedStats::new("m", "lrd", 4);
        s.on_batch_timed(4, 0, 0.002, 0.008, &[0.011, 0.012, 0.013, 0.014]);
        s.on_batch_timed(4, 0, 0.001, 0.009, &[0.011, 0.012, 0.013, 0.014]);
        let snap = s.snapshot(0);
        assert!((snap.dispatch_secs_total - 0.003).abs() < 1e-12);
        assert!((snap.fetch_secs_total - 0.017).abs() < 1e-12);
        // fps/goodput see the *combined* exec time, same as before the split
        assert!((snap.exec_fps - 400.0).abs() < 1e-6); // 4 items / 10 ms
        assert!((snap.request_fps - 400.0).abs() < 1e-6);
    }

    #[test]
    fn registry_matches_snapshot_exactly() {
        let s = SharedStats::new("m", "lrd", 8);
        let reg = obs::Registry::new();
        s.register(&reg, &[("variant", "lrd"), ("shard", "0")]).unwrap();
        s.on_enqueue(2);
        s.on_reject();
        s.on_shed(Class::Batch);
        s.on_spill(Class::Batch);
        s.on_hedge_fired();
        s.on_hedge_win();
        s.on_hedge_cancelled();
        s.on_swap();
        s.on_error(3);
        s.on_batch(6, 2, 0.010, &[0.001, 0.002, 0.003, 0.004, 0.005, 0.006]);
        s.set_transfers(42, 1);
        let snap = s.snapshot(0);
        let rs = reg.snapshot();
        let labels = [("variant", "lrd"), ("shard", "0")];
        // same atomics → exact agreement, not approximate
        assert_eq!(rs.scalar("serve", "requests_ok", &labels), Some(snap.requests_ok));
        assert_eq!(rs.scalar("serve", "rejected", &labels), Some(snap.rejected));
        assert_eq!(rs.scalar("serve", "shed", &labels), Some(snap.shed));
        assert_eq!(rs.scalar("serve", "swaps", &labels), Some(snap.swaps));
        assert_eq!(rs.scalar("serve", "errors", &labels), Some(snap.errors));
        assert_eq!(rs.scalar("serve", "batches", &labels), Some(snap.batches));
        assert_eq!(rs.scalar("serve", "served", &labels), Some(snap.served));
        assert_eq!(rs.scalar("serve", "padded_slots", &labels), Some(snap.padded_slots));
        assert_eq!(rs.scalar("serve", "spilled", &labels), Some(snap.spilled));
        assert_eq!(rs.scalar("serve", "hedge_fired", &labels), Some(snap.hedge_fired));
        assert_eq!(rs.scalar("serve", "hedge_wins", &labels), Some(snap.hedge_wins));
        assert_eq!(rs.scalar("serve", "hedge_cancelled", &labels), Some(snap.hedge_cancelled));
        assert_eq!(rs.scalar("serve", "uploads", &labels), Some(snap.uploads));
        assert_eq!(rs.scalar("serve", "demux_fallbacks", &labels), Some(snap.demux_fallbacks));
        // per-class splits live under {…, class=…} with their own families
        let batch_labels = [("variant", "lrd"), ("shard", "0"), ("class", "batch")];
        let inter_labels = [("variant", "lrd"), ("shard", "0"), ("class", "interactive")];
        assert_eq!(rs.scalar("serve", "class_shed", &batch_labels), Some(1));
        assert_eq!(rs.scalar("serve", "class_spilled", &batch_labels), Some(1));
        assert_eq!(rs.scalar("serve", "class_shed", &inter_labels), Some(0));
        assert_eq!(rs.scalar_sum("serve", "class_shed"), snap.shed);
        // the registry-side latency histogram saw every served request
        let hist_count = rs
            .entries
            .iter()
            .find_map(|e| match (e.key.name.as_str(), &e.value) {
                ("latency_us", obs::SnapValue::Histogram { count, .. }) => Some(*count),
                _ => None,
            })
            .unwrap();
        assert_eq!(hist_count, snap.served);
    }

    #[test]
    fn shed_and_swap_counters() {
        let s = SharedStats::new("m", "rankopt", 8);
        s.on_shed(Class::Standard);
        s.on_shed(Class::Batch);
        s.on_swap();
        let snap = s.snapshot(0);
        assert_eq!(snap.shed, 2);
        assert_eq!(snap.shed_by_class, [0, 1, 1]);
        assert_eq!(snap.shed, snap.shed_by_class.iter().sum::<u64>());
        assert_eq!(snap.swaps, 1);
        assert_eq!(snap.errors, 0, "shed work is SLO pressure, not an engine error");
    }

    #[test]
    fn per_class_counters_partition_their_aggregates() {
        let s = SharedStats::new("m", "lrd", 4);
        s.on_shed(Class::Interactive);
        s.on_shed(Class::Batch);
        s.on_shed(Class::Batch);
        s.on_spill(Class::Batch);
        s.on_spill(Class::Standard);
        s.on_batch_timed(3, 1, 0.001, 0.001, &[0.001, 0.002, 0.003]);
        s.on_served_class(Class::Interactive);
        s.on_served_class(Class::Interactive);
        s.on_served_class(Class::Batch);
        let snap = s.snapshot(0);
        assert_eq!(snap.shed, 3);
        assert_eq!(snap.shed_by_class, [1, 0, 2]);
        assert_eq!(snap.spilled, 2);
        assert_eq!(snap.spilled_by_class, [0, 1, 1]);
        assert_eq!(snap.served, 3);
        assert_eq!(snap.served_by_class, [2, 0, 1]);
        assert_eq!(snap.served, snap.served_by_class.iter().sum::<u64>());
        assert_eq!(snap.spilled, snap.spilled_by_class.iter().sum::<u64>());
    }

    #[test]
    fn hedge_counters_count_and_merge() {
        let a = SharedStats::new("m", "lrd", 4);
        let b = SharedStats::new("m", "lrd", 4);
        a.on_hedge_fired();
        a.on_hedge_fired();
        a.on_hedge_cancelled();
        b.on_hedge_win();
        let merged = SharedStats::merged(&[(&a, 0), (&b, 0)]);
        assert_eq!(merged.hedge_fired, 2);
        assert_eq!(merged.hedge_wins, 1);
        assert_eq!(merged.hedge_cancelled, 1);
        assert!(merged.hedge_wins <= merged.hedge_fired);
    }

    #[test]
    fn merged_latency_budget_reads_the_log2_histogram() {
        let a = SharedStats::new("m", "lrd", 4);
        let b = SharedStats::new("m", "lrd", 4);
        // below min_samples: no budget yet
        assert_eq!(SharedStats::merged_latency_budget(&[&a, &b], 99.0, 4), None);
        // 3 fast samples on one shard, 1 slow on the other (1ms vs ~16ms)
        a.on_batch_timed(3, 0, 0.001, 0.0, &[0.001, 0.001, 0.001]);
        b.on_batch_timed(1, 0, 0.001, 0.0, &[0.016]);
        let p50 = SharedStats::merged_latency_budget(&[&a, &b], 50.0, 4).unwrap();
        let p99 = SharedStats::merged_latency_budget(&[&a, &b], 99.0, 4).unwrap();
        // log₂ upper bounds: 1000µs → <1024µs, 16000µs → <16384µs
        assert_eq!(p50, Duration::from_micros(1024));
        assert_eq!(p99, Duration::from_micros(16384));
        assert!(p50 <= p99);
    }

    #[test]
    fn supervision_counters_count_and_merge() {
        let a = SharedStats::new("m", "lrd", 4);
        let b = SharedStats::new("m", "lrd", 4);
        a.on_worker_death();
        a.on_respawn();
        a.on_worker_death();
        b.on_worker_death();
        b.on_respawn();
        let snap = a.snapshot(0);
        assert_eq!(snap.worker_deaths, 2);
        assert_eq!(snap.respawns, 1, "a death without a comeback is not a respawn");
        let merged = SharedStats::merged(&[(&a, 0), (&b, 0)]);
        assert_eq!(merged.worker_deaths, 3);
        assert_eq!(merged.respawns, 2);
        // registered under the same atomics as everything else
        let reg = obs::Registry::new();
        a.register(&reg, &[("shard", "0")]).unwrap();
        let rs = reg.snapshot();
        assert_eq!(rs.scalar("serve", "worker_deaths", &[("shard", "0")]), Some(2));
        assert_eq!(rs.scalar("serve", "respawns", &[("shard", "0")]), Some(1));
    }

    #[test]
    fn merged_snapshot_aggregates_shards() {
        let a = SharedStats::new("m", "lrd", 4);
        let b = SharedStats::new("m", "lrd", 4);
        a.on_enqueue(2);
        a.on_batch(4, 0, 0.010, &[0.001, 0.002, 0.003, 0.004]);
        a.on_shed(Class::Interactive);
        a.set_transfers(10, 0);
        b.on_enqueue(5);
        b.on_reject();
        b.on_batch_timed(2, 2, 0.004, 0.006, &[0.005, 0.006]);
        b.on_swap();
        b.set_transfers(7, 1);
        let merged = SharedStats::merged(&[(&a, 1), (&b, 3)]);
        assert_eq!(merged.variant, "lrd");
        assert_eq!(merged.requests_ok, 2);
        assert_eq!(merged.rejected, 1);
        assert_eq!(merged.shed, 1);
        assert_eq!(merged.shed_by_class, [1, 0, 0]);
        assert_eq!(merged.swaps, 1);
        assert_eq!(merged.served, 6);
        assert_eq!(merged.batches, 2);
        assert_eq!(merged.padded_slots, 2);
        assert_eq!(merged.queue_depth, 4);
        assert_eq!(merged.max_queue_depth, 5);
        assert_eq!(merged.uploads, 17);
        assert_eq!(merged.demux_fallbacks, 1);
        // dispatch/fetch totals sum across shards: 10ms+4ms / 0ms+6ms
        assert!((merged.dispatch_secs_total - 0.014).abs() < 1e-12);
        assert!((merged.fetch_secs_total - 0.006).abs() < 1e-12);
        // goodput adds across concurrent shards: 4/10ms + 2/10ms
        assert!((merged.request_fps - 600.0).abs() < 1e-6);
        // fill: 6 / (2 batches · 4)
        assert!((merged.mean_fill - 0.75).abs() < 1e-12);
        // percentiles over the union of samples (1..6 ms)
        assert!(merged.p50_ms > 3.0 && merged.p50_ms < 4.5);
        assert!(merged.p99_ms > 5.5 && merged.p99_ms < 6.5);
        // throughputs add across concurrently-running shards
        let single = SharedStats::merged(&[(&a, 1)]);
        assert!(merged.exec_fps > single.exec_fps);
        assert_eq!(merged.table_row().len(), StatsSnapshot::table_header().len());
    }

    #[test]
    fn transfer_counters_are_gauges() {
        let s = SharedStats::new("m", "lrd", 8);
        assert_eq!(s.snapshot(0).uploads, 0);
        s.set_transfers(41, 0);
        s.set_transfers(42, 1);
        let snap = s.snapshot(0);
        assert_eq!(snap.uploads, 42);
        assert_eq!(snap.demux_fallbacks, 1);
    }

    #[test]
    fn empty_snapshot_is_finite() {
        let s = SharedStats::new("m", "orig", 4);
        let snap = s.snapshot(0);
        assert_eq!(snap.exec_fps, 0.0);
        assert_eq!(snap.request_fps, 0.0);
        assert_eq!(snap.mean_fill, 0.0);
        assert_eq!(snap.p99_ms, 0.0);
        assert_eq!(snap.dispatch_secs_total, 0.0);
        assert_eq!(snap.fetch_secs_total, 0.0);
        assert!(snap.table_row().len() == StatsSnapshot::table_header().len());
    }
}
