//! Rank-aware QoS: priority classes, weighted multi-queue admission,
//! degrade-to-cheaper-rank spilling, and hedged tail requests.
//!
//! The paper's central knob — decomposition rank trades accuracy for
//! throughput — becomes a *live* serving policy here instead of a
//! build-time choice:
//!
//! * Every request carries a [`Class`] (`interactive` / `standard` /
//!   `batch`). With QoS enabled the per-shard admission queue becomes a
//!   per-class multi-queue ([`ClassQueues`]) popped on a smooth
//!   weighted-round-robin slot schedule, so a heavy batch tenant cannot
//!   starve interactive traffic.
//! * Per-class SLOs stamp per-class deadlines. When a low-priority
//!   request expires at pop time it is **degraded instead of shed**: the
//!   batcher spills it to a cheaper registered variant of the same model
//!   (the [`DegradePolicy`] ladder, e.g. `batch: lrd → rankopt`), with a
//!   fresh deadline — trading logit accuracy (rank) for an answer.
//! * Hedged requests attack tail latency: a per-shard [`HedgeBoard`]
//!   publishes the in-flight batch; a governor thread re-dispatches
//!   copies to the shallowest sibling shard once the in-flight age
//!   exceeds a percentile budget from the live latency histogram. The
//!   first answer wins; the loser's reply is cancelled via a shared
//!   [`AtomicBool`] guard (both outcomes counted).
//!
//! With QoS disabled ([`ClassQueues::single`], [`ShardQos::disabled`])
//! every path delegates directly to the pre-QoS single-queue code, which
//! is what lets `integration_serve` pin QoS-off bit-identical to the
//! original serve path.

use super::queue::{Bounded, Pop, PushError};
use super::stats::SharedStats;
use super::{Request, Response, ServeError};
use crate::obs;
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// How often a blocked multi-queue pop rescans the class queues. Small
/// enough that weighted pop adds no visible latency at serve batch sizes.
const MULTI_POLL: Duration = Duration::from_micros(200);

/// A request's priority class. Order encodes priority: `Interactive`
/// outranks `Standard` outranks `Batch` (used only for reporting — the
/// actual scheduling weight comes from [`ClassPolicy::weight`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Class {
    Interactive,
    Standard,
    Batch,
}

impl Class {
    /// Every class, priority-descending. Indexes match [`Class::index`].
    pub const ALL: [Class; 3] = [Class::Interactive, Class::Standard, Class::Batch];

    /// Dense index into per-class arrays (`[T; 3]`).
    pub fn index(self) -> usize {
        match self {
            Class::Interactive => 0,
            Class::Standard => 1,
            Class::Batch => 2,
        }
    }

    /// Inverse of [`Class::index`]; panics on `i >= 3`.
    pub fn from_index(i: usize) -> Class {
        Class::ALL[i]
    }

    /// Stable label used in metrics (`class="interactive"`) and the CLI.
    pub fn label(self) -> &'static str {
        match self {
            Class::Interactive => "interactive",
            Class::Standard => "standard",
            Class::Batch => "batch",
        }
    }

    /// Parse a CLI/metric label back into a class.
    pub fn parse(s: &str) -> Option<Class> {
        match s {
            "interactive" => Some(Class::Interactive),
            "standard" => Some(Class::Standard),
            "batch" => Some(Class::Batch),
            _ => None,
        }
    }
}

impl std::fmt::Display for Class {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// Per-class scheduling policy: WRR weight plus an optional per-class SLO
/// that overrides `ServerConfig::slo` when QoS is enabled.
#[derive(Clone, Debug)]
pub struct ClassPolicy {
    /// Weighted-round-robin share (slots per schedule cycle). Must be ≥ 1:
    /// a zero weight would starve the class outright, which is what the
    /// degrade ladder — not the scheduler — is for.
    pub weight: u32,
    /// Admission deadline for this class (`None` = inherit the server-wide
    /// SLO, which may itself be `None` = never shed).
    pub slo: Option<Duration>,
}

impl Default for ClassPolicy {
    fn default() -> Self {
        ClassPolicy { weight: 1, slo: None }
    }
}

/// Class → variant ladder: where expired work of a class may spill, in
/// order of preference (cheapest-acceptable last). An empty ladder means
/// the class sheds exactly as before.
#[derive(Clone, Debug, Default)]
pub struct DegradePolicy {
    ladders: [Vec<String>; 3],
}

impl DegradePolicy {
    pub fn new() -> DegradePolicy {
        DegradePolicy::default()
    }

    /// Replace `class`'s ladder (variant names, most-preferred first).
    pub fn set(&mut self, class: Class, ladder: Vec<String>) {
        self.ladders[class.index()] = ladder;
    }

    /// `class`'s ladder (possibly empty).
    pub fn ladder(&self, class: Class) -> &[String] {
        &self.ladders[class.index()]
    }

    /// True when no class has a ladder — degrade disabled entirely.
    pub fn is_empty(&self) -> bool {
        self.ladders.iter().all(|l| l.is_empty())
    }
}

/// Hedged-request policy for tail latency.
#[derive(Clone, Debug)]
pub struct HedgeConfig {
    /// Latency-histogram percentile that sets the in-flight age budget.
    pub percentile: f64,
    /// Minimum histogram samples before the percentile is trusted; below
    /// this the `fallback` budget applies.
    pub min_samples: u64,
    /// Budget used until the histogram has `min_samples` observations.
    pub fallback: Duration,
    /// Governor poll interval.
    pub poll: Duration,
}

impl Default for HedgeConfig {
    fn default() -> Self {
        HedgeConfig {
            percentile: 99.0,
            min_samples: 64,
            fallback: Duration::from_millis(50),
            poll: Duration::from_millis(1),
        }
    }
}

/// The full QoS policy handed to `ServerConfig::qos`. Present = QoS on
/// (per-class queues, per-class SLOs, degrade ladders, optional hedging).
#[derive(Clone, Debug, Default)]
pub struct QosConfig {
    /// Indexed by [`Class::index`].
    pub classes: [ClassPolicy; 3],
    pub degrade: DegradePolicy,
    /// `Some` arms the hedge governor on every variant with ≥ 2 shards.
    pub hedge: Option<HedgeConfig>,
}

impl QosConfig {
    pub fn weights(&self) -> [u32; 3] {
        [self.classes[0].weight, self.classes[1].weight, self.classes[2].weight]
    }

    /// The deadline-producing SLO for `class`: the class SLO if set, else
    /// the server-wide fallback.
    pub fn class_slo(&self, class: Class, server_slo: Option<Duration>) -> Option<Duration> {
        self.classes[class.index()].slo.or(server_slo)
    }

    /// Parse the CLI `--classes` spec: a comma list of
    /// `name:weight[:slo_ms]` entries (e.g.
    /// `interactive:4:250,standard:2:100,batch:1:5`). Unlisted classes
    /// keep weight 1 and no class SLO; `slo_ms` of 0 means no class SLO.
    pub fn parse_classes(spec: &str) -> Result<[ClassPolicy; 3]> {
        let mut classes: [ClassPolicy; 3] = std::array::from_fn(|_| ClassPolicy::default());
        let mut seen = [false; 3];
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let mut fields = part.split(':');
            let name = fields.next().unwrap_or_default().trim();
            let class = Class::parse(name).ok_or_else(|| {
                anyhow!(
                    "--classes: unknown class '{name}' in '{part}' \
                     (expected interactive, standard or batch)"
                )
            })?;
            if seen[class.index()] {
                bail!("--classes: class '{name}' listed twice");
            }
            seen[class.index()] = true;
            let weight_s = fields
                .next()
                .ok_or_else(|| anyhow!("--classes: '{part}' needs name:weight[:slo_ms]"))?
                .trim();
            let weight: u32 = weight_s.parse().ok().filter(|w| *w >= 1).ok_or_else(|| {
                anyhow!("--classes: weight in '{part}' must be a positive integer")
            })?;
            let slo = match fields.next() {
                None => None,
                Some(s) => {
                    let ms: f64 = s.trim().parse().ok().filter(|v| *v >= 0.0).ok_or_else(
                        || anyhow!("--classes: slo_ms in '{part}' must be non-negative"),
                    )?;
                    (ms > 0.0).then(|| Duration::from_secs_f64(ms / 1e3))
                }
            };
            if let Some(extra) = fields.next() {
                bail!("--classes: unexpected field '{extra}' in '{part}'");
            }
            classes[class.index()] = ClassPolicy { weight, slo };
        }
        Ok(classes)
    }

    /// Parse the CLI `--degrade` spec: a comma list of
    /// `class=variant[+variant...]` ladders (most-preferred first), e.g.
    /// `batch=lrd+rankopt,standard=rankopt`.
    pub fn parse_degrade(spec: &str) -> Result<DegradePolicy> {
        let mut policy = DegradePolicy::new();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (name, ladder_s) = part.split_once('=').ok_or_else(|| {
                anyhow!("--degrade: '{part}' needs class=variant[+variant...]")
            })?;
            let name = name.trim();
            let class = Class::parse(name)
                .ok_or_else(|| anyhow!("--degrade: unknown class '{name}' in '{part}'"))?;
            if !policy.ladder(class).is_empty() {
                bail!("--degrade: class '{name}' listed twice");
            }
            let ladder: Vec<String> = ladder_s
                .split('+')
                .map(str::trim)
                .filter(|v| !v.is_empty())
                .map(str::to_string)
                .collect();
            if ladder.is_empty() {
                bail!("--degrade: empty ladder in '{part}'");
            }
            policy.set(class, ladder);
        }
        Ok(policy)
    }
}

/// Smooth weighted-round-robin slot schedule: one cycle of
/// `sum(weights)` slots where class `c` owns exactly `weights[c]` slots,
/// spread as evenly as the largest-deficit rule allows (weights
/// `[4,2,1]` → `I S I B I S I`, not `I I I I S S B`).
fn build_schedule(weights: [u32; 3]) -> Vec<usize> {
    let total: u32 = weights.iter().sum();
    assert!(weights.iter().all(|&w| w > 0), "class weights must be >= 1, got {weights:?}");
    let mut given = [0u64; 3];
    let mut out = Vec::with_capacity(total as usize);
    for slot in 1..=u64::from(total) {
        // serve the class furthest behind its ideal cumulative share
        // w_c * slot / total (compared at the common scale `total`)
        let mut best = 0usize;
        let mut best_deficit = i128::MIN;
        for c in 0..3 {
            let deficit = i128::from(u64::from(weights[c]) * slot)
                - i128::from(given[c] * u64::from(total));
            if deficit > best_deficit {
                best_deficit = deficit;
                best = c;
            }
        }
        given[best] += 1;
        out.push(best);
    }
    out
}

enum QueuesInner {
    /// QoS off: exactly the pre-QoS single bounded queue; every call
    /// delegates so behavior (blocking, wakeups, ordering) is identical.
    Single(Bounded<Request>),
    /// QoS on: one bounded queue per class.
    Multi(Box<[Bounded<Request>; 3]>),
}

/// Per-shard admission queue(s). With QoS off this *is* the old
/// [`Bounded`] queue; with QoS on it is three of them popped on the WRR
/// slot schedule.
///
/// Starvation bound (property-tested in `prop_serve_qos`): over any `P`
/// consecutive successful pops during which class `c` stays non-empty,
/// `c` is served at least `floor(P / S) * w_c` times, where `S` is the
/// schedule cycle length (sum of weights). This holds because a pop scans
/// the cyclic schedule from the cursor and stops at the *first* slot
/// whose class is non-empty — the cursor can never cross a slot owned by
/// a non-empty class without serving it.
pub struct ClassQueues {
    inner: QueuesInner,
    schedule: Vec<usize>,
    cursor: AtomicUsize,
}

impl ClassQueues {
    /// QoS-off queue: single class-blind FIFO of `capacity` slots.
    pub fn single(capacity: usize) -> ClassQueues {
        ClassQueues {
            inner: QueuesInner::Single(Bounded::new(capacity)),
            schedule: vec![1], // Class::Standard — unused, but index-valid
            cursor: AtomicUsize::new(0),
        }
    }

    /// QoS-on queues: `capacity` slots *per class*, popped on the
    /// `weights` WRR schedule.
    pub fn multi(capacity: usize, weights: [u32; 3]) -> ClassQueues {
        ClassQueues {
            inner: QueuesInner::Multi(Box::new([
                Bounded::new(capacity),
                Bounded::new(capacity),
                Bounded::new(capacity),
            ])),
            schedule: build_schedule(weights),
            cursor: AtomicUsize::new(0),
        }
    }

    pub fn is_multi(&self) -> bool {
        matches!(self.inner, QueuesInner::Multi(_))
    }

    /// Admit `req` under `class` (class is ignored in single mode).
    pub fn try_push(&self, class: Class, req: Request) -> Result<usize, PushError<Request>> {
        match &self.inner {
            QueuesInner::Single(q) => q.try_push(req),
            QueuesInner::Multi(qs) => qs[class.index()].try_push(req),
        }
    }

    /// Blocking weighted pop with an absolute deadline. Single mode
    /// delegates to [`Bounded::pop_deadline`] unchanged; multi mode scans
    /// the slot schedule for the first non-empty class.
    pub fn pop_deadline(&self, deadline: Instant) -> Pop<Request> {
        let qs = match &self.inner {
            QueuesInner::Single(q) => return q.pop_deadline(deadline),
            QueuesInner::Multi(qs) => qs,
        };
        loop {
            let start = self.cursor.load(Ordering::Relaxed);
            let n = self.schedule.len();
            for off in 0..n {
                let slot = (start + off) % n;
                if let Some(req) = qs[self.schedule[slot]].try_pop() {
                    self.cursor.store((slot + 1) % n, Ordering::Relaxed);
                    return Pop::Item(req);
                }
            }
            if qs.iter().all(|q| q.is_closed()) {
                return Pop::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return Pop::TimedOut;
            }
            std::thread::sleep(deadline.saturating_duration_since(now).min(MULTI_POLL));
        }
    }

    /// [`ClassQueues::pop_deadline`] with a relative timeout.
    pub fn pop_timeout(&self, timeout: Duration) -> Pop<Request> {
        match &self.inner {
            QueuesInner::Single(q) => q.pop_timeout(timeout),
            QueuesInner::Multi(_) => self.pop_deadline(Instant::now() + timeout),
        }
    }

    /// Total queued requests across classes.
    pub fn len(&self) -> usize {
        match &self.inner {
            QueuesInner::Single(q) => q.len(),
            QueuesInner::Multi(qs) => qs.iter().map(|q| q.len()).sum(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Queued requests of one class (single mode: the whole queue).
    pub fn class_len(&self, class: Class) -> usize {
        match &self.inner {
            QueuesInner::Single(q) => q.len(),
            QueuesInner::Multi(qs) => qs[class.index()].len(),
        }
    }

    /// Capacity per class (single mode: the queue's capacity).
    pub fn capacity(&self) -> usize {
        match &self.inner {
            QueuesInner::Single(q) => q.capacity(),
            QueuesInner::Multi(qs) => qs[0].capacity(),
        }
    }

    pub fn is_closed(&self) -> bool {
        match &self.inner {
            QueuesInner::Single(q) => q.is_closed(),
            QueuesInner::Multi(qs) => qs.iter().all(|q| q.is_closed()),
        }
    }

    pub fn close(&self) {
        match &self.inner {
            QueuesInner::Single(q) => q.close(),
            QueuesInner::Multi(qs) => qs.iter().for_each(|q| q.close()),
        }
    }

    pub fn close_final(&self) {
        match &self.inner {
            QueuesInner::Single(q) => q.close_final(),
            QueuesInner::Multi(qs) => qs.iter().for_each(|q| q.close_final()),
        }
    }

    /// Reopen after a supervised respawn; `false` once finally closed.
    pub fn reopen(&self) -> bool {
        match &self.inner {
            QueuesInner::Single(q) => q.reopen(),
            QueuesInner::Multi(qs) => {
                let mut ok = true;
                for q in qs.iter() {
                    ok &= q.reopen();
                }
                ok
            }
        }
    }

    /// Remove and return everything still queued (all classes).
    pub fn drain(&self) -> Vec<Request> {
        match &self.inner {
            QueuesInner::Single(q) => q.drain(),
            QueuesInner::Multi(qs) => qs.iter().flat_map(|q| q.drain()).collect(),
        }
    }

    /// The (single-mode) depth gauge — the same gauge the pre-QoS queue
    /// exported. Multi mode returns the interactive queue's gauge; use
    /// [`ClassQueues::class_gauge`] for the per-class set.
    pub fn depth_gauge(&self) -> &obs::Gauge {
        match &self.inner {
            QueuesInner::Single(q) => q.depth_gauge(),
            QueuesInner::Multi(qs) => qs[0].depth_gauge(),
        }
    }

    /// Per-class depth gauge (single mode: the one shared gauge).
    pub fn class_gauge(&self, class: Class) -> &obs::Gauge {
        match &self.inner {
            QueuesInner::Single(q) => q.depth_gauge(),
            QueuesInner::Multi(qs) => qs[class.index()].depth_gauge(),
        }
    }
}

/// One spill destination shard: its admission queue and stats sink (the
/// sink counts the spilled request as a normal admission on the target).
#[derive(Clone)]
pub struct SpillShard {
    pub queue: Arc<ClassQueues>,
    pub stats: SharedStats,
}

/// `"model/variant"` → that variant's shards, shared by every shard's
/// batcher and populated by `Server::start` once all variants are up.
pub type SpillTable = Arc<Mutex<BTreeMap<String, Vec<SpillShard>>>>;

pub fn new_table() -> SpillTable {
    Arc::new(Mutex::new(BTreeMap::new()))
}

/// Per-shard QoS context handed to the batcher: answers "where may an
/// expired request of class `c` spill from *this* variant?".
#[derive(Clone)]
pub struct ShardQos {
    enabled: bool,
    model: String,
    variant: String,
    config: Arc<QosConfig>,
    server_slo: Option<Duration>,
    table: SpillTable,
}

impl ShardQos {
    pub fn new(
        model: &str,
        variant: &str,
        config: Arc<QosConfig>,
        server_slo: Option<Duration>,
        table: SpillTable,
    ) -> ShardQos {
        ShardQos {
            enabled: true,
            model: model.to_string(),
            variant: variant.to_string(),
            config,
            server_slo,
            table,
        }
    }

    /// QoS off: spills never happen, expired work sheds exactly as before.
    pub fn disabled() -> ShardQos {
        ShardQos {
            enabled: false,
            model: String::new(),
            variant: String::new(),
            config: Arc::new(QosConfig::default()),
            server_slo: None,
            table: new_table(),
        }
    }

    /// Try to degrade an expired request down its class ladder instead of
    /// shedding it. On success the request sits in a cheaper variant's
    /// queue with a fresh per-class deadline and the *target* shard has
    /// counted the admission; the caller must count the spill on the
    /// source stats. On failure the request comes back for shedding.
    ///
    /// The ladder walk starts *after* this variant's own position (or at
    /// the top if this variant is not on the ladder), always skipping
    /// this variant itself — so repeated spills strictly descend and
    /// terminate.
    pub fn spill(&self, req: Request) -> Result<(), Request> {
        if !self.enabled {
            return Err(req);
        }
        let ladder = self.config.degrade.ladder(req.class);
        if ladder.is_empty() {
            return Err(req);
        }
        let start =
            ladder.iter().position(|v| *v == self.variant).map(|p| p + 1).unwrap_or(0);
        let slo = self.config.class_slo(req.class, self.server_slo);
        let table = self.table.lock().expect("spill table lock");
        let mut req = req;
        for cand in ladder[start..].iter().filter(|v| **v != self.variant) {
            let key = format!("{}/{}", self.model, cand);
            let Some(shards) = table.get(&key) else { continue };
            let mut open: Vec<&SpillShard> =
                shards.iter().filter(|s| !s.queue.is_closed()).collect();
            open.sort_by_key(|s| s.queue.len());
            for shard in open {
                req.deadline = slo.map(|d| Instant::now() + d);
                match shard.queue.try_push(req.class, req) {
                    Ok(depth) => {
                        shard.stats.on_enqueue(depth);
                        return Ok(());
                    }
                    Err(PushError::Full(r)) | Err(PushError::Closed(r)) => req = r,
                }
            }
        }
        Err(req)
    }
}

// ---------------------------------------------------------------------------
// hedged requests
// ---------------------------------------------------------------------------

/// Everything the hedge governor needs to re-dispatch one in-flight
/// request on a sibling shard: the payload, the *same* response channel,
/// and the first-answer-wins guard shared with the original.
#[derive(Clone)]
pub struct HedgeTicket {
    pub id: u64,
    pub x: Vec<f32>,
    pub class: Class,
    pub tx: mpsc::Sender<Result<Response, ServeError>>,
    pub guard: Arc<AtomicBool>,
}

/// A shard's published in-flight batch. `started` is the dispatch
/// instant; `taken` latches once the governor has hedged this batch so a
/// slow batch is hedged at most once.
#[derive(Default)]
pub struct BoardState {
    pub started: Option<Instant>,
    pub tickets: Vec<HedgeTicket>,
    pub taken: bool,
}

/// Shared between one engine worker (publisher) and the variant's hedge
/// governor (consumer).
pub type HedgeBoard = Arc<Mutex<BoardState>>;

pub fn new_board() -> HedgeBoard {
    Arc::new(Mutex::new(BoardState::default()))
}

/// Publish a batch about to be dispatched: install a first-answer-wins
/// guard into every request (reusing the guard on requests that are
/// themselves hedge copies) and expose clone-able tickets. Called by the
/// engine only when hedging is configured — with QoS off no guard is
/// ever allocated and no payload cloned.
pub fn publish(board: &HedgeBoard, reqs: &mut [Request]) {
    let mut b = board.lock().expect("hedge board lock");
    b.tickets.clear();
    b.taken = false;
    for req in reqs.iter_mut() {
        let guard =
            req.hedge.get_or_insert_with(|| Arc::new(AtomicBool::new(false))).clone();
        b.tickets.push(HedgeTicket {
            id: req.id,
            x: req.x.clone(),
            class: req.class,
            tx: req.tx.clone(),
            guard,
        });
    }
    b.started = Some(Instant::now());
}

/// Retire the board once the batch has been answered.
pub fn clear(board: &HedgeBoard) {
    let mut b = board.lock().expect("hedge board lock");
    b.tickets.clear();
    b.started = None;
    b.taken = false;
}

/// Retire the board *iff* it still describes the batch led by `lead_id`.
/// In the pipelined engine, batch N+1 is published before batch N is
/// fetched, so N's retirement must not wipe N+1's freshly published
/// tickets — the id check makes retirement batch-scoped.
pub fn retire(board: &HedgeBoard, lead_id: u64) {
    let mut b = board.lock().expect("hedge board lock");
    if b.tickets.first().map(|t| t.id) == Some(lead_id) {
        b.tickets.clear();
        b.started = None;
        b.taken = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, class: Class) -> (Request, super::super::Pending) {
        let (tx, rx) = mpsc::channel();
        let r = Request {
            id,
            x: vec![id as f32],
            enqueued: Instant::now(),
            deadline: None,
            tx,
            class,
            hedge: None,
            hedged_copy: false,
        };
        (r, super::super::Pending { rx })
    }

    #[test]
    fn schedule_has_exact_weight_counts_and_interleaves() {
        let s = build_schedule([4, 2, 1]);
        assert_eq!(s.len(), 7);
        for c in 0..3 {
            assert_eq!(s.iter().filter(|&&x| x == c).count(), [4, 2, 1][c]);
        }
        // smooth: the heavy class never waits more than ceil(S/w) slots
        // between its own slots — for w=4, S=7 that is 2
        let heavy: Vec<usize> =
            s.iter().enumerate().filter(|(_, &c)| c == 0).map(|(i, _)| i).collect();
        for w in heavy.windows(2) {
            assert!(w[1] - w[0] <= 2, "bursty schedule: {s:?}");
        }
        assert_eq!(build_schedule([1, 1, 1]), vec![0, 1, 2]);
    }

    #[test]
    #[should_panic(expected = "class weights must be >= 1")]
    fn zero_weight_is_rejected() {
        build_schedule([1, 0, 1]);
    }

    #[test]
    fn single_mode_is_plain_fifo() {
        let q = ClassQueues::single(4);
        assert!(!q.is_multi());
        for id in 0..3 {
            let (r, _p) = req(id, Class::from_index(id as usize % 3));
            q.try_push(r.class, r).unwrap();
        }
        for want in 0..3 {
            match q.pop_timeout(Duration::from_millis(10)) {
                Pop::Item(r) => assert_eq!(r.id, want, "single mode must stay FIFO"),
                other => panic!("expected item, got {:?}", std::mem::discriminant(&other)),
            }
        }
        assert!(q.is_empty());
    }

    #[test]
    fn weighted_pop_follows_the_schedule_when_all_classes_backlogged() {
        let q = ClassQueues::multi(16, [4, 2, 1]);
        assert!(q.is_multi());
        for id in 0..7u64 {
            for class in Class::ALL {
                let (r, _p) = req(id, class);
                q.try_push(class, r).unwrap();
            }
        }
        // with every class non-empty, the pop order is exactly the schedule
        let mut popped = Vec::new();
        for _ in 0..7 {
            match q.pop_timeout(Duration::from_millis(10)) {
                Pop::Item(r) => popped.push(r.class.index()),
                _ => panic!("expected item"),
            }
        }
        assert_eq!(popped, build_schedule([4, 2, 1]));
    }

    #[test]
    fn weighted_pop_skips_empty_classes_and_drains_after_close() {
        let q = ClassQueues::multi(8, [4, 2, 1]);
        let (r, _p) = req(7, Class::Batch);
        q.try_push(Class::Batch, r).unwrap();
        match q.pop_timeout(Duration::from_millis(10)) {
            Pop::Item(r) => assert_eq!((r.id, r.class), (7, Class::Batch)),
            _ => panic!("expected the only queued item"),
        }
        assert!(matches!(q.pop_timeout(Duration::from_millis(5)), Pop::TimedOut));
        let (r, _p2) = req(8, Class::Interactive);
        q.try_push(Class::Interactive, r).unwrap();
        q.close();
        assert!(matches!(q.pop_timeout(Duration::from_millis(10)), Pop::Item(_)));
        assert!(matches!(q.pop_timeout(Duration::from_millis(10)), Pop::Closed));
    }

    #[test]
    fn spill_walks_the_ladder_from_below_own_variant() {
        let mut cfg = QosConfig::default();
        cfg.degrade.set(Class::Batch, vec!["lrd".into(), "rankopt".into()]);
        cfg.classes[Class::Batch.index()].slo = Some(Duration::from_millis(5));
        let cfg = Arc::new(cfg);
        let table = new_table();
        let target = Arc::new(ClassQueues::multi(4, [1, 1, 1]));
        let tstats = SharedStats::new("m", "rankopt", 4);
        table.lock().unwrap().insert(
            "m/rankopt".into(),
            vec![SpillShard { queue: target.clone(), stats: tstats.clone() }],
        );

        // from "lrd" (on the ladder), batch work spills to rankopt …
        let qos = ShardQos::new("m", "lrd", cfg.clone(), None, table.clone());
        let (r, _p) = req(1, Class::Batch);
        qos.spill(r).expect("ladder has a live target below lrd");
        assert_eq!(target.class_len(Class::Batch), 1, "class preserved on spill");
        assert_eq!(tstats.snapshot(0).requests_ok, 1, "target counts the admission");
        match target.pop_timeout(Duration::from_millis(10)) {
            Pop::Item(r) => {
                assert!(r.deadline.is_some(), "spill re-stamps the class deadline")
            }
            _ => panic!("expected spilled item"),
        }

        // … but from "rankopt" (ladder bottom) there is nowhere left to go
        let qos = ShardQos::new("m", "rankopt", cfg.clone(), None, table.clone());
        let (r, _p) = req(2, Class::Batch);
        assert!(qos.spill(r).is_err(), "bottom of the ladder must shed");

        // … and classes without a ladder always shed
        let qos = ShardQos::new("m", "lrd", cfg, None, table);
        let (r, _p) = req(3, Class::Interactive);
        assert!(qos.spill(r).is_err());
    }

    #[test]
    fn parse_classes_spec_round_trips_and_rejects_garbage() {
        let c = QosConfig::parse_classes("interactive:4:250,standard:2:100,batch:1:5").unwrap();
        assert_eq!([c[0].weight, c[1].weight, c[2].weight], [4, 2, 1]);
        assert_eq!(c[0].slo, Some(Duration::from_millis(250)));
        assert_eq!(c[2].slo, Some(Duration::from_millis(5)));
        // partial spec: unlisted classes keep defaults; slo 0 = none
        let c = QosConfig::parse_classes("interactive:3:0").unwrap();
        assert_eq!(c[0].weight, 3);
        assert!(c[0].slo.is_none());
        assert_eq!((c[1].weight, c[2].weight), (1, 1));
        for bad in [
            "vip:2",                   // unknown class
            "interactive",             // missing weight
            "interactive:0",           // zero weight
            "interactive:x",           // non-numeric weight
            "interactive:1:-5",        // negative slo
            "interactive:1:2:3",       // trailing field
            "interactive:1,interactive:2", // duplicate
        ] {
            assert!(QosConfig::parse_classes(bad).is_err(), "'{bad}' must be rejected");
        }
    }

    #[test]
    fn parse_degrade_spec_builds_ladders() {
        let d = QosConfig::parse_degrade("batch=lrd+rankopt,standard=rankopt").unwrap();
        assert_eq!(d.ladder(Class::Batch), ["lrd", "rankopt"]);
        assert_eq!(d.ladder(Class::Standard), ["rankopt"]);
        assert!(d.ladder(Class::Interactive).is_empty());
        for bad in ["batch", "vip=lrd", "batch=", "batch=lrd,batch=rankopt"] {
            assert!(QosConfig::parse_degrade(bad).is_err(), "'{bad}' must be rejected");
        }
    }

    #[test]
    fn disabled_qos_never_spills() {
        let qos = ShardQos::disabled();
        let (r, _p) = req(1, Class::Batch);
        assert!(qos.spill(r).is_err());
    }

    #[test]
    fn publish_installs_shared_guards_and_clear_retires_them() {
        let board = new_board();
        let (r, _p) = req(1, Class::Standard);
        let mut reqs = vec![r];
        publish(&board, &mut reqs);
        {
            let b = board.lock().unwrap();
            assert_eq!(b.tickets.len(), 1);
            assert!(b.started.is_some());
            assert!(!b.taken);
            // the ticket's guard IS the request's guard
            let g = reqs[0].hedge.as_ref().unwrap();
            assert!(Arc::ptr_eq(g, &b.tickets[0].guard));
        }
        // first respond wins, the copy is cancelled
        let guard = reqs[0].hedge.clone().unwrap();
        assert!(!guard.swap(true, Ordering::AcqRel), "first claim succeeds");
        assert!(guard.swap(true, Ordering::AcqRel), "second claim is cancelled");
        clear(&board);
        let b = board.lock().unwrap();
        assert!(b.tickets.is_empty() && b.started.is_none() && !b.taken);
    }

    #[test]
    fn retire_is_batch_scoped() {
        let board = new_board();
        let (r1, _p1) = req(1, Class::Standard);
        let mut batch_n = vec![r1];
        publish(&board, &mut batch_n);
        // pipelined engine publishes batch N+1 before fetching batch N …
        let (r2, _p2) = req(2, Class::Standard);
        let mut batch_n1 = vec![r2];
        publish(&board, &mut batch_n1);
        // … so retiring N must leave N+1's tickets on the board
        retire(&board, 1);
        assert_eq!(board.lock().unwrap().tickets.len(), 1);
        retire(&board, 2);
        assert!(board.lock().unwrap().tickets.is_empty());
    }
}
