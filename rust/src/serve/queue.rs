//! Bounded MPSC request queue with admission control.
//!
//! `try_push` never blocks: past the configured depth it rejects, which is
//! the server's backpressure signal (clients see
//! [`ServeError::QueueFull`](super::ServeError::QueueFull) and retry or shed
//! load). The consumer side is deadline-oriented — `pop_deadline` is what
//! lets the batcher wait "until the batch is full or the max-wait deadline
//! passes" without busy-polling.
//!
//! Built on `Mutex<VecDeque>` + `Condvar`: the std primitives are all the
//! offline image offers, and one uncontended lock per request is noise next
//! to a PJRT dispatch.

use crate::obs;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Rejected push, returning the item to the caller.
#[derive(Debug)]
pub enum PushError<T> {
    /// Queue at capacity (admission control).
    Full(T),
    /// Queue closed for shutdown.
    Closed(T),
}

/// Outcome of a deadline-bounded pop.
#[derive(Debug)]
pub enum Pop<T> {
    Item(T),
    /// Deadline passed with the queue still empty.
    TimedOut,
    /// Queue closed *and* drained.
    Closed,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
    /// Terminal close (server shutdown): [`Bounded::reopen`] refuses to
    /// clear it, so a supervised respawn racing shutdown cannot resurrect
    /// the queue after the drain backstop already ran.
    finished: bool,
}

/// Bounded multi-producer single-consumer queue.
pub struct Bounded<T> {
    capacity: usize,
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    /// Live depth mirror, updated on every push/pop — registrable via
    /// [`Bounded::depth_gauge`] so a metrics scrape never takes the queue
    /// lock.
    depth: obs::Gauge,
}

impl<T> Bounded<T> {
    pub fn new(capacity: usize) -> Bounded<T> {
        assert!(capacity > 0, "queue capacity must be positive");
        Bounded {
            capacity,
            inner: Mutex::new(Inner { items: VecDeque::new(), closed: false, finished: false }),
            not_empty: Condvar::new(),
            depth: obs::Gauge::new(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// The lock-free depth gauge (register it under a queue-depth metric;
    /// the queue keeps it in sync with `len()`).
    pub fn depth_gauge(&self) -> &obs::Gauge {
        &self.depth
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }

    /// Non-blocking admission-controlled push. On success returns the queue
    /// depth *after* the push (the stats layer's gauge sample).
    pub fn try_push(&self, item: T) -> Result<usize, PushError<T>> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(PushError::Closed(item));
        }
        if g.items.len() >= self.capacity {
            return Err(PushError::Full(item));
        }
        g.items.push_back(item);
        let depth = g.items.len();
        self.depth.set(depth as u64);
        drop(g);
        self.not_empty.notify_one();
        Ok(depth)
    }

    /// Pop, waiting until an item arrives, `deadline` passes, or the queue
    /// is closed and drained. Remaining items are still delivered after
    /// `close` so shutdown drains gracefully.
    pub fn pop_deadline(&self, deadline: Instant) -> Pop<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                self.depth.set(g.items.len() as u64);
                return Pop::Item(item);
            }
            if g.closed {
                return Pop::Closed;
            }
            // saturating: a deadline already in the past must time out, not
            // panic on `Duration` underflow (callers pass per-request
            // admission deadlines that are routinely expired by pop time)
            let wait = deadline.saturating_duration_since(Instant::now());
            if wait.is_zero() {
                return Pop::TimedOut;
            }
            let (g2, _timeout) = self.not_empty.wait_timeout(g, wait).unwrap();
            g = g2;
        }
    }

    /// Pop with a relative timeout.
    pub fn pop_timeout(&self, timeout: Duration) -> Pop<T> {
        self.pop_deadline(Instant::now() + timeout)
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        let item = g.items.pop_front();
        if item.is_some() {
            self.depth.set(g.items.len() as u64);
        }
        item
    }

    /// Take every queued item in FIFO order (one lock). Shutdown uses this
    /// to answer requests a dead worker left behind instead of wedging the
    /// callers blocked on them.
    pub fn drain(&self) -> Vec<T> {
        let mut g = self.inner.lock().unwrap();
        let items = g.items.drain(..).collect();
        self.depth.set(0);
        items
    }

    /// Close for shutdown: producers are rejected immediately, the consumer
    /// drains what is left and then sees [`Pop::Closed`].
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
    }

    /// Terminal close: like [`Bounded::close`], but a later
    /// [`Bounded::reopen`] is refused. Server shutdown uses this so a
    /// supervised worker respawn that races the shutdown cannot reopen a
    /// queue nobody will ever consume again.
    pub fn close_final(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        g.finished = true;
        drop(g);
        self.not_empty.notify_all();
    }

    /// Reopen a closed queue for a supervised worker respawn — the shard
    /// keeps its queue handle (and depth gauge registration) across worker
    /// generations, so admission just resumes. Returns `false` without
    /// reopening if the queue was closed terminally ([`Bounded::close_final`]).
    pub fn reopen(&self) -> bool {
        let mut g = self.inner.lock().unwrap();
        if g.finished {
            return false;
        }
        g.closed = false;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order_and_depth() {
        let q = Bounded::new(4);
        assert_eq!(q.try_push(1).unwrap(), 1);
        assert_eq!(q.try_push(2).unwrap(), 2);
        assert_eq!(q.len(), 2);
        assert!(matches!(q.pop_timeout(Duration::from_millis(1)), Pop::Item(1)));
        assert!(matches!(q.pop_timeout(Duration::from_millis(1)), Pop::Item(2)));
        assert!(q.is_empty());
    }

    #[test]
    fn rejects_past_capacity() {
        let q = Bounded::new(2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        match q.try_push(3) {
            Err(PushError::Full(item)) => assert_eq!(item, 3),
            other => panic!("expected Full, got {other:?}"),
        }
        // pop frees a slot, admission resumes
        assert!(matches!(q.try_pop(), Some(1)));
        assert_eq!(q.try_push(3).unwrap(), 2);
    }

    #[test]
    fn pop_times_out_when_empty() {
        let q: Bounded<u8> = Bounded::new(1);
        let t0 = Instant::now();
        assert!(matches!(q.pop_timeout(Duration::from_millis(20)), Pop::TimedOut));
        assert!(t0.elapsed() >= Duration::from_millis(18));
    }

    #[test]
    fn expired_deadline_times_out_without_panicking() {
        // regression: `pop_deadline` used raw `deadline - now`, which
        // panicked ("overflow when subtracting durations") once the
        // deadline was already in the past
        let q: Bounded<u8> = Bounded::new(1);
        let past = Instant::now();
        std::thread::sleep(Duration::from_millis(5));
        let t0 = Instant::now();
        assert!(matches!(q.pop_deadline(past), Pop::TimedOut));
        assert!(t0.elapsed() < Duration::from_millis(100), "expired deadline must not wait");
        // a queued item still beats an expired deadline
        q.try_push(9).unwrap();
        assert!(matches!(q.pop_deadline(past), Pop::Item(9)));
    }

    #[test]
    fn drain_takes_everything_in_fifo_order() {
        let q = Bounded::new(4);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        q.close();
        assert_eq!(q.drain(), vec![1, 2]);
        assert!(q.is_empty());
        assert!(q.drain().is_empty());
    }

    #[test]
    fn close_rejects_producers_and_drains_consumer() {
        let q = Bounded::new(4);
        q.try_push(7).unwrap();
        q.close();
        assert!(matches!(q.try_push(8), Err(PushError::Closed(8))));
        // drained item still delivered, then Closed without waiting
        assert!(matches!(q.pop_timeout(Duration::from_secs(5)), Pop::Item(7)));
        let t0 = Instant::now();
        assert!(matches!(q.pop_timeout(Duration::from_secs(5)), Pop::Closed));
        assert!(t0.elapsed() < Duration::from_secs(1));
    }

    #[test]
    fn depth_gauge_mirrors_len() {
        let q = Bounded::new(4);
        assert_eq!(q.depth_gauge().get(), 0);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        assert_eq!(q.depth_gauge().get(), 2);
        assert!(matches!(q.try_pop(), Some(1)));
        assert_eq!(q.depth_gauge().get(), 1);
        assert!(matches!(q.pop_timeout(Duration::from_millis(10)), Pop::Item(2)));
        assert_eq!(q.depth_gauge().get(), 0);
        q.try_push(3).unwrap();
        q.close();
        assert_eq!(q.drain(), vec![3]);
        assert_eq!(q.depth_gauge().get(), 0);
    }

    #[test]
    fn reopen_resumes_admission_but_not_after_final_close() {
        let q = Bounded::new(2);
        q.try_push(1).unwrap();
        q.close();
        assert!(matches!(q.try_push(2), Err(PushError::Closed(2))));
        // a supervised respawn reopens: the queue keeps working in place
        assert!(q.reopen());
        assert!(!q.is_closed());
        assert_eq!(q.try_push(2).unwrap(), 2);
        assert!(matches!(q.try_pop(), Some(1)));
        // terminal close wins any race with a reopen
        q.close_final();
        assert!(!q.reopen(), "reopen must refuse a finalized queue");
        assert!(q.is_closed());
        assert!(matches!(q.try_push(3), Err(PushError::Closed(3))));
        // drain still delivers what was admitted before the final close
        assert_eq!(q.drain(), vec![2]);
    }

    #[test]
    fn cross_thread_handoff() {
        let q = Arc::new(Bounded::new(8));
        let producer = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                for i in 0..100 {
                    loop {
                        match q.try_push(i) {
                            Ok(_) => break,
                            Err(PushError::Full(_)) => std::thread::yield_now(),
                            Err(PushError::Closed(_)) => panic!("closed early"),
                        }
                    }
                }
                q.close();
            })
        };
        let mut seen = Vec::new();
        loop {
            match q.pop_timeout(Duration::from_secs(5)) {
                Pop::Item(i) => seen.push(i),
                Pop::Closed => break,
                Pop::TimedOut => panic!("starved"),
            }
        }
        producer.join().unwrap();
        assert_eq!(seen, (0..100).collect::<Vec<_>>());
    }
}
