//! Serving engine: one worker thread per registered variant.
//!
//! The worker owns its own PJRT client (the client holds an `Rc` and is not
//! `Send`, so it must be created inside the thread), compiles the variant's
//! infer artifact once, and — the point of the subsystem — uploads every
//! parameter to a device-resident buffer **once** at startup. Each batch
//! then uploads only the fresh `x` and executes against the resident
//! buffers via [`Executable::run_buffers`], eliminating the per-request
//! parameter round-trip the old `serve_infer` example paid.
//!
//! `reupload: true` keeps the old behavior measurable as a baseline: every
//! batch rebuilds all parameter literals from the host tensors and executes
//! through the host-literal path (`bench_serve_throughput` quantifies the
//! gap per variant).

use super::batcher::{self, BatcherConfig, NextBatch};
use super::queue::Bounded;
use super::stats::SharedStats;
use super::{Request, Response, ServeError};
use crate::checkpoint::Params;
use crate::coordinator::evaluate_with;
use crate::data::Dataset;
use crate::runtime::{
    literal_to_tensor, tensor_to_literal, ArtifactMeta, Executable, Manifest, Runtime,
};
use crate::tensor::Tensor;
use crate::train::ResidentParams;
use anyhow::{Context, Result};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

/// Per-engine policy (the router clones the server-wide config into one of
/// these per variant).
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub model: String,
    pub variant: String,
    /// Hold a partial batch open this long after its first request.
    pub max_wait: Duration,
    /// Idle shutdown-check interval for a trafficless worker.
    pub idle_poll: Duration,
    /// Baseline mode: re-upload all parameters every batch.
    pub reupload: bool,
    /// If > 0, run a serving-side accuracy spot check over this many
    /// synthetic samples at startup (reuses the coordinator's
    /// [`evaluate_with`]) and record it in the stats.
    pub spot_check: usize,
}

/// Spawn the worker thread. `ready` receives `Ok(())` once the engine is
/// compiled, resident and serving (or the startup error); the router blocks
/// on it so `Server::start` fails fast.
/// Closes the queue when the worker exits for *any* reason — including a
/// panic unwinding the thread. Without this, producers would keep getting
/// `QueueFull` (never `Closed`) from a dead engine and retry forever.
struct CloseQueueOnExit(Arc<Bounded<Request>>);

impl Drop for CloseQueueOnExit {
    fn drop(&mut self) {
        self.0.close();
    }
}

pub fn spawn(
    manifest: Manifest,
    meta: ArtifactMeta,
    params: Params,
    cfg: EngineConfig,
    queue: Arc<Bounded<Request>>,
    stats: SharedStats,
    ready: mpsc::Sender<Result<(), String>>,
) -> thread::JoinHandle<()> {
    thread::Builder::new()
        .name(format!("lrta-serve-{}-{}", cfg.model, cfg.variant))
        .spawn(move || {
            let _guard = CloseQueueOnExit(Arc::clone(&queue));
            match Engine::init(&manifest, meta, params, &cfg, stats) {
                Ok(engine) => {
                    let _ = ready.send(Ok(()));
                    engine.run(&queue, &cfg);
                }
                Err(e) => {
                    let _ = ready.send(Err(format!("{e:#}")));
                }
            }
        })
        .expect("spawn serve engine thread")
}

struct Engine {
    rt: Runtime,
    exe: Executable,
    meta: ArtifactMeta,
    /// Host-side parameters, kept for the reupload baseline and spot checks.
    params: Params,
    /// Device-resident parameters, uploaded through the shared
    /// [`ResidentParams`] path and gathered once into artifact slot order —
    /// serving never re-binds, so the hot path indexes a dense Vec instead
    /// of a name-keyed map (`None` in reupload mode).
    resident: Option<Vec<xla::PjRtBuffer>>,
    x_dims: Vec<i64>,
    item_elems: usize,
    stats: SharedStats,
}

impl Engine {
    fn init(
        manifest: &Manifest,
        meta: ArtifactMeta,
        params: Params,
        cfg: &EngineConfig,
        stats: SharedStats,
    ) -> Result<Engine> {
        let rt = Runtime::cpu()?;
        let exe = rt
            .load_hlo(manifest.hlo_path(&meta))
            .with_context(|| format!("loading infer artifact {}", meta.name))?;
        let resident = if cfg.reupload {
            None
        } else {
            let slots = || meta.trainable.iter().chain(meta.frozen.iter());
            let bufs = ResidentParams::upload_for_slots(&rt, &params, slots())
                .and_then(|r| r.into_ordered(slots()))
                .with_context(|| format!("uploading resident params for {}", meta.name))?;
            Some(bufs)
        };
        if cfg.spot_check > 0 {
            // serving-side accuracy spot check through the same executable
            let n = cfg.spot_check.max(meta.batch);
            let eval = Dataset::synthetic(n, 0xACC);
            let acc = evaluate_with(&exe, &meta, &params, &eval)?;
            stats.set_spot_check(acc);
        }
        let x_dims: Vec<i64> = meta.x_shape.iter().map(|&d| d as i64).collect();
        let item_elems = meta.x_shape.iter().skip(1).product();
        Ok(Engine { rt, exe, meta, params, resident, x_dims, item_elems, stats })
    }

    fn run(&self, queue: &Bounded<Request>, cfg: &EngineConfig) {
        let bcfg = BatcherConfig {
            batch: self.meta.batch,
            item_elems: self.item_elems,
            max_wait: cfg.max_wait,
            idle_poll: cfg.idle_poll,
        };
        loop {
            match batcher::next_batch(queue, &bcfg) {
                NextBatch::Closed => break,
                NextBatch::Idle => continue,
                NextBatch::Batch(reqs) => self.serve_batch(reqs),
            }
        }
    }

    fn serve_batch(&self, reqs: Vec<Request>) {
        let (xs, padded) = batcher::assemble(&reqs, self.meta.batch, self.item_elems);
        let t0 = Instant::now();
        let result = self.execute(&xs);
        let exec_secs = t0.elapsed().as_secs_f64();
        match result {
            Ok(logits) => {
                let classes = logits.shape()[1];
                let fill = reqs.len();
                let done = Instant::now();
                let mut latencies = Vec::with_capacity(fill);
                for (i, req) in reqs.into_iter().enumerate() {
                    let row = logits.data()[i * classes..(i + 1) * classes].to_vec();
                    let latency = done.duration_since(req.enqueued);
                    latencies.push(latency.as_secs_f64());
                    req.respond(Ok(Response { logits: row, latency, batch_fill: fill }));
                }
                self.stats.on_batch(fill, padded, exec_secs, &latencies);
            }
            Err(e) => {
                let msg = format!("{e:#}");
                self.stats.on_error(reqs.len());
                for req in reqs {
                    req.respond(Err(ServeError::Engine(msg.clone())));
                }
            }
        }
    }

    /// Run one assembled batch; returns the `[batch, classes]` logits.
    fn execute(&self, xs: &[f32]) -> Result<Tensor> {
        let x_lit = xla::Literal::vec1(xs).reshape(&self.x_dims)?;
        let out = if let Some(bufs) = &self.resident {
            // hot path: resident parameters + freshly uploaded batch input
            let x_buf = self.rt.upload(&x_lit)?;
            let mut refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
            refs.push(&x_buf);
            let outs = self.exe.run_buffers(&refs)?;
            let mut lits = Executable::buffer_to_literals(&outs[0])?;
            lits.swap_remove(0)
        } else {
            // measured baseline: host→device upload of every parameter,
            // every batch (what examples/serve_infer.rs used to do
            // per request)
            let n = self.meta.trainable.len() + self.meta.frozen.len();
            let mut inputs = Vec::with_capacity(n + 1);
            for slot in self.meta.trainable.iter().chain(self.meta.frozen.iter()) {
                inputs.push(tensor_to_literal(&self.params[&slot.name])?);
            }
            inputs.push(x_lit);
            let mut lits = self.exe.run(&inputs)?;
            lits.swap_remove(0)
        };
        literal_to_tensor(&out)
    }
}
