//! Serving engine: one worker thread per registered variant.
//!
//! The worker owns its own PJRT client (the client holds an `Rc` and is not
//! `Send`, so it must be created inside the thread), compiles the variant's
//! infer artifact once, and — the point of the subsystem — uploads every
//! parameter to a device-resident buffer **once** at startup. Each batch
//! then uploads only the fresh `x` and executes against the resident
//! buffers, eliminating the per-request parameter round-trip the old
//! `serve_infer` example paid.
//!
//! **Streaming admission** (default resident mode): the engine splits each
//! execution into dispatch/fetch halves ([`Executable::dispatch_buffers`] /
//! [`InFlight::fetch`](crate::runtime::InFlight::fetch)). While batch N
//! executes asynchronously on the device, the worker goes back to the
//! batcher, coalesces batch N+1, assembles and uploads it, dispatches it,
//! and only then fetches N's logits — the queue drains continuously instead
//! of in lockstep. The overlap engages only when the queue actually has
//! backlog ([`batcher::has_backlog`]); with no queued work the engine
//! fetches immediately, so trickle-traffic latency is unchanged.
//!
//! `reupload: true` keeps the old behavior measurable as a baseline: every
//! batch rebuilds all parameter literals from the host tensors and executes
//! through the host-literal path (`bench_serve_throughput` quantifies the
//! gap per variant). `pipelined: false` keeps the serial resident loop as
//! the second baseline (the PR-2 behavior).
//!
//! **Warm variant swap**: the worker owns a control channel beside its
//! request queue. Between batches it applies any pending [`SwapMsg`]:
//! the new checkpoint's buffers are uploaded *beside* the live set (the
//! old buffers keep serving any in-flight batch), then the engine flips
//! its resident pointer atomically — no request is dropped, no batch sees
//! a half-swapped parameter set ([`Server::swap_variant`](super::Server)).

use super::batcher::{self, BatcherConfig, NextBatch};
use super::qos::{self, ClassQueues, ShardQos};
use super::stats::SharedStats;
use super::{Delivery, Request, Response, ServeError};
use crate::checkpoint::Params;
use crate::coordinator::evaluate_with;
use crate::data::Dataset;
use crate::faults::{self, Seam};
use crate::obs::Tracer;
use crate::runtime::{
    literal_to_tensor, tensor_to_literal, ArtifactMeta, Executable, InFlight, Manifest, Runtime,
};
use crate::tensor::Tensor;
use crate::train::ResidentParams;
use anyhow::{Context, Result};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

/// Per-engine policy (the router clones the server-wide config into one of
/// these per variant).
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub model: String,
    pub variant: String,
    /// Which shard of the variant this engine serves (0-based; a
    /// single-engine variant is shard 0 of 1).
    pub shard: usize,
    /// Hold a partial batch open this long after its first request.
    pub max_wait: Duration,
    /// Idle shutdown-check interval for a trafficless worker.
    pub idle_poll: Duration,
    /// Baseline mode: re-upload all parameters every batch.
    pub reupload: bool,
    /// Streaming admission: dispatch batch N, coalesce/upload batch N+1
    /// while N executes, then fetch N. Resident mode only (the reupload
    /// baseline stays lockstep by construction).
    pub pipelined: bool,
    /// If > 0, run a serving-side accuracy spot check over this many
    /// synthetic samples at startup (reuses the coordinator's
    /// [`evaluate_with`]) and record it in the stats.
    pub spot_check: usize,
}

/// Warm-swap control message: a full replacement checkpoint for the
/// engine's variant plus the ack channel [`Server::swap_variant`](super::Server)
/// blocks on. The worker applies it between batches.
pub struct SwapMsg {
    pub params: Params,
    pub ack: mpsc::Sender<Result<(), String>>,
}

/// Everything the router wires into one shard worker: its request queue,
/// its stats sink, its warm-swap control channel, and the startup ack.
pub struct ShardWiring {
    pub queue: Arc<ClassQueues>,
    pub stats: SharedStats,
    pub swap: mpsc::Receiver<SwapMsg>,
    pub ready: mpsc::Sender<Result<(), String>>,
    /// Span recorder for the request lifecycle (the no-op tracer when the
    /// server runs without `--trace-out`).
    pub tracer: Tracer,
    /// Where expired work of this shard may degrade to
    /// ([`ShardQos::disabled`] when the server runs without `--classes`).
    pub qos: ShardQos,
    /// In-flight batch board read by the variant's hedge governor; `None`
    /// when hedging is off or the variant has a single shard.
    pub hedge: Option<qos::HedgeBoard>,
}

/// Closes the queue when the worker exits for *any* reason — including a
/// panic unwinding the thread — and then answers whatever requests were
/// still queued with [`ServeError::Shutdown`]. Without the close, producers
/// would keep getting `QueueFull` (never `Closed`) from a dead engine and
/// retry forever; without the drain, callers already admitted would stay
/// blocked on a `Pending` nobody will ever answer.
struct CloseQueueOnExit(Arc<ClassQueues>);

impl Drop for CloseQueueOnExit {
    fn drop(&mut self) {
        self.0.close();
        super::drain_shutdown(&self.0);
    }
}

/// Spawn one shard's worker thread. `wiring.ready` receives `Ok(())` once
/// the engine is compiled, resident and serving (or the startup error); the
/// router blocks on it so `Server::start` fails fast.
pub fn spawn(
    manifest: Manifest,
    meta: ArtifactMeta,
    params: Params,
    cfg: EngineConfig,
    wiring: ShardWiring,
) -> thread::JoinHandle<()> {
    thread::Builder::new()
        .name(format!("lrta-serve-{}-{}-{}", cfg.model, cfg.variant, cfg.shard))
        .spawn(move || {
            let ShardWiring { queue, stats, swap, ready, tracer, qos, hedge } = wiring;
            let _guard = CloseQueueOnExit(Arc::clone(&queue));
            match Engine::init(&manifest, meta, params, &cfg, stats, tracer) {
                Ok(mut engine) => {
                    engine.qos = qos;
                    engine.hedge = hedge;
                    let _ = ready.send(Ok(()));
                    engine.run(&queue, &cfg, &swap);
                }
                Err(e) => {
                    let _ = ready.send(Err(format!("{e:#}")));
                }
            }
        })
        .expect("spawn serve engine thread")
}

/// One dispatched-but-unfetched batch of the streaming-admission loop: the
/// requests riding it, the in-flight execution handle, and the host time
/// already spent assembling/uploading/dispatching it.
struct InFlightBatch {
    reqs: Vec<Request>,
    padded: usize,
    pending: InFlight,
    dispatch_secs: f64,
    /// Lead request id of this batch on the hedge board (`None` when
    /// hedging is off) — used to retire the board batch-scoped.
    lead: Option<u64>,
}

struct Engine {
    rt: Runtime,
    exe: Executable,
    meta: ArtifactMeta,
    /// Host-side parameters, kept for the reupload baseline and spot checks.
    params: Params,
    /// Device-resident parameters, uploaded through the shared
    /// [`ResidentParams`] path and gathered once into artifact slot order —
    /// serving never re-binds, so the hot path indexes a dense Vec instead
    /// of a name-keyed map (`None` in reupload mode).
    resident: Option<Vec<xla::PjRtBuffer>>,
    x_dims: Vec<i64>,
    item_elems: usize,
    stats: SharedStats,
    tracer: Tracer,
    /// Spot-check sample count from the config (0 = off); kept so a warm
    /// swap can refresh the accuracy gauge for the new checkpoint.
    spot_check: usize,
    /// Fault-seam scope label (`shard{N}`) so a `--faults` directive can
    /// target one shard of a fanout ([`crate::faults`]).
    fault_scope: String,
    /// Degrade-ladder context for the batcher (disabled without QoS).
    qos: ShardQos,
    /// In-flight batch board for the hedge governor (`None` = no hedging).
    hedge: Option<qos::HedgeBoard>,
}

impl Engine {
    fn init(
        manifest: &Manifest,
        meta: ArtifactMeta,
        params: Params,
        cfg: &EngineConfig,
        stats: SharedStats,
        tracer: Tracer,
    ) -> Result<Engine> {
        let rt = Runtime::cpu()?;
        let exe = rt
            .load_hlo(manifest.hlo_path(&meta))
            .with_context(|| format!("loading infer artifact {}", meta.name))?;
        let resident = if cfg.reupload {
            None
        } else {
            let slots = || meta.trainable.iter().chain(meta.frozen.iter());
            let bufs = ResidentParams::upload_for_slots(&rt, &params, slots())
                .and_then(|r| r.into_ordered(slots()))
                .with_context(|| format!("uploading resident params for {}", meta.name))?;
            Some(bufs)
        };
        let x_dims: Vec<i64> = meta.x_shape.iter().map(|&d| d as i64).collect();
        let item_elems = meta.x_shape.iter().skip(1).product();
        let engine = Engine {
            rt,
            exe,
            meta,
            params,
            resident,
            x_dims,
            item_elems,
            stats,
            tracer,
            spot_check: cfg.spot_check,
            fault_scope: format!("shard{}", cfg.shard),
            qos: ShardQos::disabled(),
            hedge: None,
        };
        engine.run_spot_check()?;
        Ok(engine)
    }

    /// Serving-side accuracy spot check through the engine's own
    /// executable (no-op when disabled). Runs at startup and again after a
    /// warm swap, so the stats gauge always describes the live checkpoint.
    fn run_spot_check(&self) -> Result<()> {
        if self.spot_check == 0 {
            return Ok(());
        }
        let n = self.spot_check.max(self.meta.batch);
        let eval = Dataset::synthetic(n, 0xACC);
        let acc = evaluate_with(&self.exe, &self.meta, &self.params, &eval)?;
        self.stats.set_spot_check(acc);
        Ok(())
    }

    fn run(
        &mut self,
        queue: &ClassQueues,
        cfg: &EngineConfig,
        swap_rx: &mpsc::Receiver<SwapMsg>,
    ) {
        let bcfg = BatcherConfig {
            batch: self.meta.batch,
            item_elems: self.item_elems,
            max_wait: cfg.max_wait,
            idle_poll: cfg.idle_poll,
        };
        // streaming admission needs resident buffers to dispatch against;
        // the reupload baseline stays lockstep by construction
        let pipelined = cfg.pipelined && self.resident.is_some();
        self.stats.set_transfers(self.rt.uploads() as u64, self.rt.demux_fallbacks() as u64);
        // at most one batch in flight: the second half of the double buffer
        // is the batch being coalesced/uploaded in the batcher right now
        let mut inflight: Option<InFlightBatch> = None;
        loop {
            // warm swap: applied strictly *between* batches. The in-flight
            // batch was dispatched against the old buffers, so fetch it
            // first; the new set uploads beside the old one, then the
            // resident pointer flips — no batch ever sees a mixed set.
            while let Ok(msg) = swap_rx.try_recv() {
                if let Some(p) = inflight.take() {
                    self.finish_batch(p);
                }
                let outcome = self.apply_swap(msg.params);
                // fault seam: a panic/stall here models a worker dying or
                // hanging before acknowledging — the router's bounded ack
                // wait must surface it instead of blocking forever
                if let Err(e) = faults::hit(Seam::SwapAck, &self.fault_scope) {
                    let _ = msg.ack.send(Err(format!("{e:#}")));
                    continue;
                }
                let _ = msg.ack.send(outcome);
            }
            match batcher::next_batch(queue, &bcfg, &self.stats, &self.tracer, &self.qos) {
                NextBatch::Closed => {
                    if let Some(p) = inflight.take() {
                        self.finish_batch(p);
                    }
                    break;
                }
                NextBatch::Idle => {
                    // no traffic: never hold finished results hostage
                    if let Some(p) = inflight.take() {
                        self.finish_batch(p);
                    }
                }
                NextBatch::Batch(mut reqs) => {
                    if !pipelined {
                        self.serve_batch(reqs);
                        continue;
                    }
                    let (xs, padded) =
                        batcher::assemble(&reqs, self.meta.batch, self.item_elems);
                    // publish *before* dispatch: a stalled dispatch is
                    // exactly the batch the governor must be able to hedge
                    let lead = self.publish_hedge(&mut reqs);
                    let t0 = Instant::now();
                    match self.dispatch(&xs) {
                        Ok(pending) => {
                            // batch N+1 is dispatched (and its x uploaded)
                            // *before* batch N's results are fetched — the
                            // device never waits on the host between batches
                            let staged = InFlightBatch {
                                reqs,
                                padded,
                                pending,
                                dispatch_secs: t0.elapsed().as_secs_f64(),
                                lead,
                            };
                            if let Some(prev) = inflight.replace(staged) {
                                self.finish_batch(prev);
                            }
                            if !batcher::has_backlog(queue) {
                                // queue drained: respond now instead of
                                // waiting for the next arrival / idle poll
                                if let Some(p) = inflight.take() {
                                    self.finish_batch(p);
                                }
                            }
                        }
                        Err(e) => {
                            if let Some(p) = inflight.take() {
                                self.finish_batch(p);
                            }
                            self.respond_batch(reqs, padded, 0.0, 0.0, Err(e));
                            self.retire_hedge(lead);
                        }
                    }
                }
            }
        }
    }

    /// Warm swap: validate the replacement checkpoint against the
    /// artifact's slot signature, upload its buffers beside the live set,
    /// then flip the resident pointer. On any error the old set keeps
    /// serving untouched (the swap is all-or-nothing per shard).
    fn apply_swap(&mut self, params: Params) -> Result<(), String> {
        for slot in self.meta.trainable.iter().chain(self.meta.frozen.iter()) {
            match params.get(&slot.name) {
                None => return Err(format!("swap checkpoint missing param '{}'", slot.name)),
                Some(t) if t.shape() != &slot.shape[..] => {
                    return Err(format!(
                        "swap checkpoint shape mismatch for '{}': artifact {:?}, got {:?}",
                        slot.name,
                        slot.shape,
                        t.shape()
                    ));
                }
                Some(_) => {}
            }
        }
        if self.resident.is_some() {
            let slots = || self.meta.trainable.iter().chain(self.meta.frozen.iter());
            // upload beside the live set — `self.resident` still holds the
            // old buffers until the assignment below flips them
            let bufs = ResidentParams::upload_for_slots(&self.rt, &params, slots())
                .and_then(|r| r.into_ordered(slots()))
                .map_err(|e| format!("uploading swap buffers: {e:#}"))?;
            self.resident = Some(bufs);
        }
        self.params = params;
        self.stats.set_transfers(self.rt.uploads() as u64, self.rt.demux_fallbacks() as u64);
        self.stats.on_swap();
        // refresh the accuracy gauge for the new checkpoint. Non-fatal:
        // the flip already happened, so a failed re-check must not report
        // the swap itself as failed (the previous gauge value persists).
        let _ = self.run_spot_check();
        Ok(())
    }

    /// Serial (lockstep) batch service — the reupload baseline and the
    /// `pipelined: false` resident baseline. The whole run is one blocking
    /// call, so its time all counts as dispatch in the split.
    fn serve_batch(&self, mut reqs: Vec<Request>) {
        let (xs, padded) = batcher::assemble(&reqs, self.meta.batch, self.item_elems);
        let lead = self.publish_hedge(&mut reqs);
        let t0 = Instant::now();
        let result = self.execute(&xs);
        let exec_secs = t0.elapsed().as_secs_f64();
        self.respond_batch(reqs, padded, exec_secs, 0.0, result);
        self.retire_hedge(lead);
    }

    /// Publish a batch on the hedge board (no-op without a board — QoS-off
    /// paths allocate no guard and clone no payload). Returns the batch's
    /// lead request id for [`Engine::retire_hedge`].
    fn publish_hedge(&self, reqs: &mut [Request]) -> Option<u64> {
        let board = self.hedge.as_ref()?;
        qos::publish(board, reqs);
        reqs.first().map(|r| r.id)
    }

    /// Retire the hedge board entry for the batch led by `lead` (no-op
    /// when hedging is off or a newer batch already owns the board).
    fn retire_hedge(&self, lead: Option<u64>) {
        if let (Some(board), Some(id)) = (self.hedge.as_ref(), lead) {
            qos::retire(board, id);
        }
    }

    /// Dispatch one assembled batch against the resident buffers without
    /// blocking (upload `x`, enqueue the execution).
    fn dispatch(&self, xs: &[f32]) -> Result<InFlight> {
        let bufs = self.resident.as_ref().expect("dispatch requires resident buffers");
        faults::hit(Seam::BatchUpload, &self.fault_scope)?;
        let up_t0 = self.tracer.start();
        let x_lit = xla::Literal::vec1(xs).reshape(&self.x_dims)?;
        let x_buf = self.rt.upload(&x_lit)?;
        self.tracer.end(up_t0, "serve", "upload");
        faults::hit(Seam::Dispatch, &self.fault_scope)?;
        let d_t0 = self.tracer.start();
        let mut refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        refs.push(&x_buf);
        let pending = self.exe.dispatch_buffers(&refs, 1);
        self.tracer.end(d_t0, "serve", "dispatch");
        pending
    }

    /// Fetch a dispatched batch's logits and respond to its requests.
    fn finish_batch(&self, b: InFlightBatch) {
        let InFlightBatch { reqs, padded, pending, dispatch_secs, lead } = b;
        let t0 = Instant::now();
        let fetch_t0 = self.tracer.start();
        let fetched =
            faults::hit(Seam::Fetch, &self.fault_scope).and_then(|()| pending.fetch(&self.rt));
        self.tracer.end(fetch_t0, "serve", "fetch");
        let demux_t0 = self.tracer.start();
        let result = fetched
            .and_then(|outs| Executable::buffer_to_literals(&outs[0]))
            .and_then(|mut lits| literal_to_tensor(&lits.swap_remove(0)));
        self.tracer.end(demux_t0, "serve", "demux");
        // host-side occupancy split into its halves: the non-blocking
        // dispatch (assemble/upload/enqueue) vs the blocking fetch+demux.
        // In overlapped mode the device time between the halves belongs to
        // no single batch, so end-to-end throughput is the load report's
        // number, not dispatch+fetch.
        let fetch_secs = t0.elapsed().as_secs_f64();
        self.respond_batch(reqs, padded, dispatch_secs, fetch_secs, result);
        self.retire_hedge(lead);
    }

    /// Demux per-request rows out of a batch result (or fail every request)
    /// and update the stats — shared tail of the serial and pipelined paths.
    /// `dispatch_secs`/`fetch_secs` are the two halves of the executable
    /// wall time (serial paths pass the whole run as dispatch).
    fn respond_batch(
        &self,
        reqs: Vec<Request>,
        padded: usize,
        dispatch_secs: f64,
        fetch_secs: f64,
        result: Result<Tensor>,
    ) {
        match result {
            Ok(logits) => {
                let reply_t0 = self.tracer.start();
                let classes = logits.shape()[1];
                let fill = reqs.len();
                let done = Instant::now();
                let mut latencies = Vec::with_capacity(fill);
                let mut sent = 0usize;
                for (i, req) in reqs.into_iter().enumerate() {
                    let row = logits.data()[i * classes..(i + 1) * classes].to_vec();
                    let latency = done.duration_since(req.enqueued);
                    let class = req.class;
                    let hedged_copy = req.hedged_copy;
                    // first-answer-wins: a hedged request replies exactly
                    // once — the loser's reply is dropped and counted, and
                    // its latency never pollutes the histogram
                    match req.respond(Ok(Response { logits: row, latency, batch_fill: fill })) {
                        Delivery::Sent => {
                            sent += 1;
                            latencies.push(latency.as_secs_f64());
                            self.stats.on_served_class(class);
                            if hedged_copy {
                                self.stats.on_hedge_win();
                            }
                        }
                        Delivery::Cancelled => self.stats.on_hedge_cancelled(),
                    }
                }
                self.tracer.end(reply_t0, "serve", "reply");
                self.stats.on_batch_timed(sent, padded, dispatch_secs, fetch_secs, &latencies);
            }
            Err(e) => {
                let msg = format!("{e:#}");
                let mut failed = 0usize;
                for req in reqs {
                    match req.respond(Err(ServeError::Engine(msg.clone()))) {
                        Delivery::Sent => failed += 1,
                        Delivery::Cancelled => self.stats.on_hedge_cancelled(),
                    }
                }
                self.stats.on_error(failed);
            }
        }
        self.stats.set_transfers(self.rt.uploads() as u64, self.rt.demux_fallbacks() as u64);
    }

    /// Run one assembled batch; returns the `[batch, classes]` logits.
    fn execute(&self, xs: &[f32]) -> Result<Tensor> {
        let out = if self.resident.is_some() {
            // hot path: the same dispatch→fetch sequence the streaming
            // loop uses, just with the two halves back to back — the
            // serial baseline can never diverge from the pipelined path
            let pending = self.dispatch(xs)?;
            faults::hit(Seam::Fetch, &self.fault_scope)?;
            let outs = pending.fetch(&self.rt)?;
            let mut lits = Executable::buffer_to_literals(&outs[0])?;
            lits.swap_remove(0)
        } else {
            // measured baseline: host→device upload of every parameter,
            // every batch (what examples/serve_infer.rs used to do
            // per request)
            faults::hit(Seam::BatchUpload, &self.fault_scope)?;
            let n = self.meta.trainable.len() + self.meta.frozen.len();
            let mut inputs = Vec::with_capacity(n + 1);
            for slot in self.meta.trainable.iter().chain(self.meta.frozen.iter()) {
                inputs.push(tensor_to_literal(&self.params[&slot.name])?);
            }
            inputs.push(xla::Literal::vec1(xs).reshape(&self.x_dims)?);
            let mut lits = self.exe.run(&inputs)?;
            lits.swap_remove(0)
        };
        literal_to_tensor(&out)
    }
}
