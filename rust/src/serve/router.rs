//! Variant registry and router: `(model, variant)` → shard set → engine.
//!
//! `orig`, `lrd` and `rankopt` checkpoints of the same model register as
//! independent variants and serve side-by-side, so A/B throughput
//! comparison — the Table-1 experiment — is just two `submit` targets. A
//! variant additionally scales out across `shards` identical workers (each
//! with its own PJRT client, resident parameter set, queue and stats); the
//! router fans requests out to the shallowest queue, breaking ties
//! round-robin so idle shards share trickle traffic evenly. The router is
//! the only thread-shared entry point; it validates payloads, stamps
//! admission deadlines (`ServerConfig::slo`), applies admission control via
//! the bounded queues, brokers warm variant swaps, and exposes per-variant
//! (shard-merged) stats snapshots.
//!
//! **Supervision** (`ServerConfig::supervise`, default on): each shard
//! worker runs under a supervisor thread that joins it, and — if the worker
//! died rather than shut down — answers its stranded requests, respawns a
//! fresh worker warm from the shard's last-applied checkpoint, re-installs
//! its swap channel and reopens its queue. The respawn budget is
//! `max_respawns` per shard; past it the shard stays down and `submit`
//! (after a bounded [`ServeError::ShardDown`] retry window) steers traffic
//! to surviving shards.

use super::engine::{self, EngineConfig, ShardWiring, SwapMsg};
use super::qos::{self, Class, ClassQueues, HedgeConfig, QosConfig, ShardQos, SpillShard};
use super::queue::PushError;
use super::stats::{SharedStats, StatsSnapshot};
use super::{drain_shutdown, Pending, Request, ServeError};
use crate::checkpoint::Params;
use crate::faults::{self, Seam};
use crate::obs::{Registry, Tracer};
use crate::runtime::{ArtifactMeta, Manifest};
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Server-wide serving policy (applied to every registered variant).
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Queue depth per shard; `0` means `4 × compiled batch`.
    pub queue_depth: usize,
    /// Batcher max-wait: how long a partial batch stays open.
    pub max_wait: Duration,
    /// Idle worker poll interval (shutdown latency bound when trafficless).
    pub idle_poll: Duration,
    /// Re-upload parameters every batch (the measurable old baseline)
    /// instead of keeping them device-resident.
    pub reupload: bool,
    /// Streaming admission (default): engines dispatch batch N, coalesce
    /// and upload batch N+1 while N executes, then fetch N. `false` keeps
    /// the serial lockstep loop as a measurable baseline. Only effective in
    /// resident mode.
    pub pipelined: bool,
    /// Startup accuracy spot-check sample count (0 = off).
    pub spot_check: usize,
    /// Per-request admission deadline: a request still queued `slo` after
    /// submission is shed at pop time with [`ServeError::DeadlineExceeded`]
    /// instead of occupying a batch slot. `None` (default) never sheds.
    pub slo: Option<Duration>,
    /// Metrics registry to expose every shard's counters through (the same
    /// atomic handles the stats snapshots read, labelled
    /// `model`/`variant`/`shard`). `None` (default) registers nothing.
    pub registry: Option<Registry>,
    /// Request-lifecycle span recorder, cloned into every shard worker and
    /// the submit path. The default no-op tracer records nothing.
    pub tracer: Tracer,
    /// Run each shard worker under a supervisor thread that respawns it
    /// (warm, from the shard's last-applied checkpoint) if it dies.
    pub supervise: bool,
    /// Respawn budget per shard: after this many respawns the shard stays
    /// down and traffic steers to the survivors.
    pub max_respawns: usize,
    /// Upper bound on waiting for a shard's warm-swap ack — a wedged worker
    /// must not hang [`Server::swap_variant`] forever.
    pub swap_timeout: Duration,
    /// How long `submit` retries a shard whose queue is closed by a worker
    /// death (the respawn usually lands within this window) before
    /// answering [`ServeError::ShardDown`].
    pub shard_down_retry: Duration,
    /// Rank-aware QoS policy. `Some` turns every shard queue into a
    /// per-class weighted multi-queue, stamps per-class SLO deadlines,
    /// arms the degrade ladders ([`qos::DegradePolicy`]) and — when its
    /// `hedge` field is set — the per-variant hedge governors. `None`
    /// (default) keeps the pre-QoS single-queue path bit-identical.
    pub qos: Option<QosConfig>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            queue_depth: 0,
            max_wait: Duration::from_millis(2),
            idle_poll: Duration::from_millis(25),
            reupload: false,
            pipelined: true,
            spot_check: 0,
            slo: None,
            registry: None,
            tracer: Tracer::default(),
            supervise: true,
            max_respawns: 2,
            swap_timeout: Duration::from_secs(10),
            shard_down_retry: Duration::from_millis(500),
            qos: None,
        }
    }
}

/// One variant to register: the checkpoint must already match the variant
/// (decompose first for `lrd` / `rankopt`).
pub struct VariantSpec {
    pub model: String,
    pub variant: String,
    pub params: Params,
    /// How many identical shard workers serve this variant (each with its
    /// own PJRT client, resident parameter set, queue and stats). Must be
    /// at least 1.
    pub shards: usize,
}

impl VariantSpec {
    pub fn new(model: &str, variant: &str, params: Params) -> VariantSpec {
        VariantSpec { model: model.to_string(), variant: variant.to_string(), params, shards: 1 }
    }

    /// Scale this variant out across `shards` workers.
    pub fn with_shards(mut self, shards: usize) -> VariantSpec {
        self.shards = shards;
        self
    }

    /// Spec for `variant` derived from a dense checkpoint: identity for
    /// `orig`, closed-form LRD at the manifest's configured ranks otherwise
    /// (the one construction every serve entry point shares).
    pub fn from_dense(
        manifest: &Manifest,
        model: &str,
        variant: &str,
        dense: &Params,
    ) -> Result<VariantSpec> {
        let params = if variant == "orig" {
            dense.clone()
        } else {
            crate::coordinator::decompose_checkpoint(dense, manifest.config(model, variant)?)?
                .params
        };
        Ok(VariantSpec::new(model, variant, params))
    }
}

/// One live shard worker of a variant.
struct ShardHandle {
    queue: Arc<ClassQueues>,
    stats: SharedStats,
    /// Warm-swap control channel into the worker. Shared with the shard's
    /// supervisor, which installs a fresh sender on respawn (the Mutex also
    /// keeps `Server: Sync`; swaps are a cold path).
    swap: Arc<Mutex<mpsc::Sender<SwapMsg>>>,
    /// The checkpoint this shard last successfully applied (its start
    /// params, replaced on every acked swap) — the warm state a supervised
    /// respawn re-uploads.
    checkpoint: Arc<Mutex<Params>>,
    /// The shard's supervisor thread when supervision is on, otherwise the
    /// worker thread itself.
    join: Option<JoinHandle<()>>,
}

/// Live engine registration: the shard set behind one `(model, variant)`.
struct EngineHandle {
    shards: Vec<ShardHandle>,
    /// Round-robin cursor for tie-breaking equal queue depths.
    rr: AtomicUsize,
    /// Serializes warm swaps for this variant: two racing `swap_variant`
    /// calls must not interleave their per-shard fanouts, or shards could
    /// apply the swaps in opposite orders and end up serving different
    /// checkpoints.
    swap_gate: Mutex<()>,
    item_elems: usize,
    batch: usize,
}

impl EngineHandle {
    /// Effective routing depth of one shard: a closed queue (dead worker
    /// awaiting respawn, or respawn budget exhausted) must lose every
    /// comparison so traffic steers to live shards.
    fn route_depth(s: &ShardHandle) -> usize {
        if s.queue.is_closed() {
            usize::MAX
        } else {
            s.queue.len()
        }
    }

    /// Fanout decision: the shard with the shallowest queue, scanning from
    /// a rotating start so exact ties are broken round-robin (idle shards
    /// then share trickle traffic evenly instead of shard 0 taking it all).
    fn pick_shard(&self) -> usize {
        if self.shards.len() == 1 {
            return 0;
        }
        let start = self.rr.fetch_add(1, Ordering::Relaxed) % self.shards.len();
        let mut best = start;
        let mut best_depth = Self::route_depth(&self.shards[start]);
        for off in 1..self.shards.len() {
            let i = (start + off) % self.shards.len();
            let depth = Self::route_depth(&self.shards[i]);
            // strictly-less keeps the rotating start on ties
            if depth < best_depth {
                best = i;
                best_depth = depth;
            }
        }
        best
    }

    /// Variant-level stats: the single shard's snapshot, or the merged view
    /// over all shards.
    fn snapshot(&self) -> StatsSnapshot {
        let parts: Vec<(&SharedStats, usize)> =
            self.shards.iter().map(|s| (&s.stats, s.queue.len())).collect();
        SharedStats::merged(&parts)
    }
}

/// `(model, variant)` → engine lookup table.
#[derive(Default)]
pub struct Router {
    engines: BTreeMap<String, EngineHandle>,
}

impl Router {
    /// Routing key convention.
    pub fn key(model: &str, variant: &str) -> String {
        format!("{model}/{variant}")
    }

    fn get(&self, model: &str, variant: &str) -> Option<&EngineHandle> {
        self.engines.get(&Self::key(model, variant))
    }

    /// Registered keys in deterministic order.
    pub fn keys(&self) -> Vec<String> {
        self.engines.keys().cloned().collect()
    }

    /// Register a constructed engine handle. A duplicate `(model, variant)`
    /// key is an error — a silent overwrite would leak the old handle's
    /// shard workers and stats.
    fn register(&mut self, key: String, handle: EngineHandle) -> Result<()> {
        use std::collections::btree_map::Entry;
        match self.engines.entry(key) {
            Entry::Occupied(e) => Err(anyhow!("variant '{}' registered twice", e.key())),
            Entry::Vacant(v) => {
                v.insert(handle);
                Ok(())
            }
        }
    }

    /// Close every queue, join every worker (or its supervisor), then
    /// answer any requests a dead worker left queued with
    /// [`ServeError::Shutdown`] (idempotent). The close is terminal
    /// ([`ClassQueues::close_final`]) so a supervised respawn racing this
    /// shutdown cannot reopen a queue nobody will consume again.
    fn close_and_join(&mut self) {
        for h in self.engines.values() {
            for s in &h.shards {
                s.queue.close_final();
            }
        }
        for h in self.engines.values_mut() {
            for s in &mut h.shards {
                if let Some(join) = s.join.take() {
                    let _ = join.join();
                }
                // a healthy worker drained its queue through the batcher
                // before exiting; this catches requests stranded by a
                // worker that died (see `drain_shutdown`)
                drain_shutdown(&s.queue);
            }
        }
    }
}

/// Everything a shard supervisor needs to resurrect its worker: spawn
/// inputs (manifest / artifact / engine config), the shard's shared wiring
/// (queue, stats, swap slot, checkpoint), and the server-wide shutdown
/// flag.
struct SupervisorCtx {
    manifest: Manifest,
    meta: ArtifactMeta,
    ecfg: EngineConfig,
    queue: Arc<ClassQueues>,
    stats: SharedStats,
    swap: Arc<Mutex<mpsc::Sender<SwapMsg>>>,
    checkpoint: Arc<Mutex<Params>>,
    tracer: Tracer,
    closing: Arc<AtomicBool>,
    max_respawns: usize,
    /// QoS context re-wired into every respawned worker generation.
    qos: ShardQos,
    /// Hedge board re-wired into every respawned worker generation.
    hedge: Option<qos::HedgeBoard>,
}

/// Shard supervisor loop: join the worker; if it died (rather than shut
/// down), answer its stranded requests, respawn it warm from the shard's
/// last-applied checkpoint, re-install the swap channel and reopen the
/// queue — up to `max_respawns` times. Returns when the server is closing,
/// the budget is exhausted, or a shutdown finalizes the queue mid-respawn.
fn supervise_shard(ctx: SupervisorCtx, mut worker: JoinHandle<()>) {
    let mut respawns = 0;
    loop {
        // a worker exit is either orderly shutdown (queue closed by the
        // server) or a death (panic / init failure); `closing` is set
        // *before* the shutdown close, so checking it after the join
        // distinguishes the two without a race
        let _ = worker.join();
        if ctx.closing.load(Ordering::SeqCst) {
            return;
        }
        ctx.stats.on_worker_death();
        // the dying worker's queue guard already closed the queue; drain
        // again here so requests admitted between its drain and the close
        // still get a terminal answer before the respawn reopens admission
        drain_shutdown(&ctx.queue);
        if respawns >= ctx.max_respawns {
            eprintln!(
                "[serve] shard {}/{}#{} died; respawn budget ({}) exhausted, shard stays down",
                ctx.ecfg.model, ctx.ecfg.variant, ctx.ecfg.shard, ctx.max_respawns
            );
            return;
        }
        respawns += 1;
        eprintln!(
            "[serve] shard {}/{}#{} died; respawning warm ({respawns}/{})",
            ctx.ecfg.model, ctx.ecfg.variant, ctx.ecfg.shard, ctx.max_respawns
        );
        let params = ctx.checkpoint.lock().unwrap().clone();
        let (ready_tx, ready_rx) = mpsc::channel();
        let (swap_tx, swap_rx) = mpsc::channel();
        let next = engine::spawn(
            ctx.manifest.clone(),
            ctx.meta.clone(),
            params,
            ctx.ecfg.clone(),
            ShardWiring {
                queue: Arc::clone(&ctx.queue),
                stats: ctx.stats.clone(),
                swap: swap_rx,
                ready: ready_tx,
                tracer: ctx.tracer.clone(),
                qos: ctx.qos.clone(),
                hedge: ctx.hedge.clone(),
            },
        );
        match ready_rx.recv() {
            Ok(Ok(())) => {
                *ctx.swap.lock().unwrap() = swap_tx;
                if ctx.queue.reopen() {
                    ctx.stats.on_respawn();
                    worker = next;
                } else {
                    // shutdown finalized the queue mid-respawn: the fresh
                    // worker sees it closed and exits; join it and stand down
                    let _ = next.join();
                    return;
                }
            }
            // the respawn failed to come up (compile/upload error or a
            // startup panic): loop back so the join counts it as another
            // death against the budget
            Ok(Err(_)) | Err(_) => worker = next,
        }
    }
}

/// Everything one variant's hedge governor watches: the per-shard boards
/// its engines publish in-flight batches on, the sibling queues it may
/// re-dispatch to, and the shard stats that feed the percentile budget.
struct HedgeCtx {
    cfg: HedgeConfig,
    boards: Vec<qos::HedgeBoard>,
    queues: Vec<Arc<ClassQueues>>,
    stats: Vec<SharedStats>,
    closing: Arc<AtomicBool>,
}

/// Hedge governor loop (one thread per variant with ≥ 2 shards when
/// `QosConfig::hedge` is set): every poll it derives the in-flight age
/// budget from the variant's merged latency histogram (`percentile`,
/// falling back to `fallback` until `min_samples` observations exist) and
/// scans the shard boards. A batch whose dispatch has been in flight past
/// the budget is hedged **once**: clones of its still-unanswered requests
/// are re-dispatched to the shallowest open sibling shard, carrying the
/// *same* response channel and first-answer-wins guard — whichever shard
/// answers first wins, the loser's reply is cancelled and counted.
fn hedge_governor(ctx: HedgeCtx) {
    loop {
        if ctx.closing.load(Ordering::SeqCst) {
            return;
        }
        std::thread::sleep(ctx.cfg.poll);
        let parts: Vec<&SharedStats> = ctx.stats.iter().collect();
        let budget =
            SharedStats::merged_latency_budget(&parts, ctx.cfg.percentile, ctx.cfg.min_samples)
                .unwrap_or(ctx.cfg.fallback);
        for (i, board) in ctx.boards.iter().enumerate() {
            let tickets = {
                let mut b = board.lock().expect("hedge board lock");
                let stalled = !b.taken
                    && !b.tickets.is_empty()
                    && b.started.is_some_and(|t| t.elapsed() >= budget);
                if !stalled {
                    continue;
                }
                // latch before dispatching: a slow batch is hedged at most
                // once even if the copies themselves crawl
                b.taken = true;
                b.tickets.clone()
            };
            // fault seam: `hedge@shardN:fail` suppresses (and `:stall`
            // delays) the governor's reaction to shard N's stalled batch
            if faults::hit(Seam::Hedge, &format!("shard{i}")).is_err() {
                continue;
            }
            // shallowest open sibling takes every copy of this batch
            let mut sib: Option<usize> = None;
            let mut best = usize::MAX;
            for (j, q) in ctx.queues.iter().enumerate() {
                if j != i && !q.is_closed() && q.len() < best {
                    best = q.len();
                    sib = Some(j);
                }
            }
            let Some(sib) = sib else { continue };
            for t in tickets {
                // skip requests the stalled shard already answered
                if t.guard.load(Ordering::Acquire) {
                    continue;
                }
                let copy = Request {
                    id: t.id,
                    x: t.x.clone(),
                    enqueued: Instant::now(),
                    deadline: None,
                    tx: t.tx.clone(),
                    class: t.class,
                    hedge: Some(Arc::clone(&t.guard)),
                    hedged_copy: true,
                };
                if let Ok(depth) = ctx.queues[sib].try_push(t.class, copy) {
                    ctx.stats[sib].on_enqueue(depth);
                    ctx.stats[i].on_hedge_fired();
                }
            }
        }
    }
}

/// The serving subsystem's front door: a router over per-variant shard sets
/// plus lifecycle management. `Sync` — share it by reference across client
/// threads.
pub struct Server {
    router: Router,
    next_id: AtomicU64,
    slo: Option<Duration>,
    /// Warm-swap ack deadline (see [`ServerConfig::swap_timeout`]).
    swap_timeout: Duration,
    /// `submit` retry window for a dead shard's closed queue.
    shard_down_retry: Duration,
    /// Set (before the queues close) on shutdown, so supervisors stand down
    /// and `submit` answers [`ServeError::Closed`] instead of retrying.
    closing: Arc<AtomicBool>,
    tracer: Tracer,
    /// QoS policy (`None` = pre-QoS behavior, bit-identical).
    qos: Option<Arc<QosConfig>>,
    /// Per-variant hedge governor threads, joined on shutdown.
    governors: Vec<JoinHandle<()>>,
}

impl Server {
    /// Start every shard worker of every spec — all in parallel, since each
    /// worker owns an independent PJRT client — then block until every one
    /// reports compiled-and-resident. Fails fast (and tears the partial
    /// fleet down) if any artifact is missing or won't load.
    pub fn start(
        manifest: &Manifest,
        specs: Vec<VariantSpec>,
        cfg: &ServerConfig,
    ) -> Result<Server> {
        let mut router = Router::default();
        let mut pending = Vec::new();
        let closing = Arc::new(AtomicBool::new(false));
        // supervisor contexts staged per shard; the threads only spawn
        // after every shard reports ready (startup failures keep the
        // simple fail-fast teardown of the unsupervised path)
        let mut supervisors: Vec<(String, usize, SupervisorCtx)> = Vec::new();
        // QoS plumbing: the spill table maps every registered variant to
        // its shard queues so any shard's batcher can degrade expired work
        // down a ladder; hedge contexts stage one governor per variant
        let qos_cfg: Option<Arc<QosConfig>> = cfg.qos.clone().map(Arc::new);
        let spill_table = qos::new_table();
        let mut hedge_ctxs: Vec<(String, HedgeCtx)> = Vec::new();
        let mut model_elems: BTreeMap<String, usize> = BTreeMap::new();
        for spec in specs {
            if spec.shards == 0 {
                router.close_and_join();
                bail!("variant '{}/{}' needs at least 1 shard", spec.model, spec.variant);
            }
            let name = Manifest::name_of(&spec.model, &spec.variant, "infer", "none");
            let meta = match manifest.artifact(&name) {
                Ok(m) => m.clone(),
                Err(e) => {
                    router.close_and_join();
                    return Err(e);
                }
            };
            let batch = meta.batch;
            let item_elems: usize = meta.x_shape.iter().skip(1).product();
            let depth = if cfg.queue_depth == 0 { batch * 4 } else { cfg.queue_depth };
            let key = Router::key(&spec.model, &spec.variant);
            // duplicate check *before* spawning: workers started for a
            // doomed spec would outlive the error (register would catch
            // the duplicate too, but only after the leak)
            if router.engines.contains_key(&key) {
                router.close_and_join();
                bail!("variant '{key}' registered twice");
            }
            model_elems.entry(spec.model.clone()).or_insert(item_elems);
            let shard_qos = match &qos_cfg {
                Some(q) => ShardQos::new(
                    &spec.model,
                    &spec.variant,
                    Arc::clone(q),
                    cfg.slo,
                    Arc::clone(&spill_table),
                ),
                None => ShardQos::disabled(),
            };
            // per-shard hedge boards only when there is a sibling to hedge to
            let boards: Option<Vec<qos::HedgeBoard>> = qos_cfg
                .as_ref()
                .and_then(|q| q.hedge.as_ref())
                .filter(|_| spec.shards >= 2)
                .map(|_| (0..spec.shards).map(|_| qos::new_board()).collect());
            let mut shards = Vec::with_capacity(spec.shards);
            for shard in 0..spec.shards {
                let queue = Arc::new(match &qos_cfg {
                    Some(q) => ClassQueues::multi(depth, q.weights()),
                    None => ClassQueues::single(depth),
                });
                let stats = SharedStats::new(&spec.model, &spec.variant, batch);
                if qos_cfg.is_some() {
                    spill_table
                        .lock()
                        .expect("spill table lock")
                        .entry(key.clone())
                        .or_default()
                        .push(SpillShard { queue: Arc::clone(&queue), stats: stats.clone() });
                }
                if let Some(reg) = &cfg.registry {
                    let shard_label = shard.to_string();
                    let labels = [
                        ("model", spec.model.as_str()),
                        ("variant", spec.variant.as_str()),
                        ("shard", shard_label.as_str()),
                    ];
                    // the registry gets the very atomics the stats/queue
                    // mutate — a registration failure (duplicate labels)
                    // is a config error, so fail startup loudly
                    let mut registered = stats.register(reg, &labels).and_then(|()| {
                        reg.register_gauge("serve", "queue_depth", &labels, queue.depth_gauge())
                    });
                    if registered.is_ok() && queue.is_multi() {
                        for class in Class::ALL {
                            let mut cl: Vec<(&str, &str)> = labels.to_vec();
                            cl.push(("class", class.label()));
                            registered = reg.register_gauge(
                                "serve",
                                "class_queue_depth",
                                &cl,
                                queue.class_gauge(class),
                            );
                            if registered.is_err() {
                                break;
                            }
                        }
                    }
                    if let Err(e) = registered {
                        router.close_and_join();
                        return Err(e);
                    }
                }
                let ecfg = EngineConfig {
                    model: spec.model.clone(),
                    variant: spec.variant.clone(),
                    shard,
                    max_wait: cfg.max_wait,
                    idle_poll: cfg.idle_poll,
                    reupload: cfg.reupload,
                    pipelined: cfg.pipelined,
                    // every shard serves the same checkpoint through the
                    // same artifact: one spot check answers for all of them
                    spot_check: if shard == 0 { cfg.spot_check } else { 0 },
                };
                let (ready_tx, ready_rx) = mpsc::channel();
                let (swap_tx, swap_rx) = mpsc::channel();
                let board = boards.as_ref().map(|b| Arc::clone(&b[shard]));
                let join = engine::spawn(
                    manifest.clone(),
                    meta.clone(),
                    spec.params.clone(),
                    ecfg.clone(),
                    ShardWiring {
                        queue: Arc::clone(&queue),
                        stats: stats.clone(),
                        swap: swap_rx,
                        ready: ready_tx,
                        tracer: cfg.tracer.clone(),
                        qos: shard_qos.clone(),
                        hedge: board.clone(),
                    },
                );
                let swap = Arc::new(Mutex::new(swap_tx));
                let checkpoint = Arc::new(Mutex::new(spec.params.clone()));
                if cfg.supervise {
                    supervisors.push((
                        key.clone(),
                        shard,
                        SupervisorCtx {
                            manifest: manifest.clone(),
                            meta: meta.clone(),
                            // the startup spot-check already answered for
                            // this checkpoint; a respawn skips it
                            ecfg: EngineConfig { spot_check: 0, ..ecfg },
                            queue: Arc::clone(&queue),
                            stats: stats.clone(),
                            swap: Arc::clone(&swap),
                            checkpoint: Arc::clone(&checkpoint),
                            tracer: cfg.tracer.clone(),
                            closing: Arc::clone(&closing),
                            max_respawns: cfg.max_respawns,
                            qos: shard_qos.clone(),
                            hedge: board,
                        },
                    ));
                }
                shards.push(ShardHandle { queue, stats, swap, checkpoint, join: Some(join) });
                pending.push((format!("{key}#{shard}"), ready_rx));
            }
            if let Some(boards) = boards {
                let hcfg = qos_cfg
                    .as_ref()
                    .and_then(|q| q.hedge.clone())
                    .expect("boards exist only with a hedge config");
                let name = format!("lrta-serve-hedge-{}-{}", spec.model, spec.variant);
                hedge_ctxs.push((
                    name,
                    HedgeCtx {
                        cfg: hcfg,
                        boards,
                        queues: shards.iter().map(|s| Arc::clone(&s.queue)).collect(),
                        stats: shards.iter().map(|s| s.stats.clone()).collect(),
                        closing: Arc::clone(&closing),
                    },
                ));
            }
            let handle = EngineHandle {
                shards,
                rr: AtomicUsize::new(0),
                swap_gate: Mutex::new(()),
                item_elems,
                batch,
            };
            // vacancy is guaranteed by the pre-spawn duplicate check above;
            // a panic here means that invariant broke (better loud than a
            // silent leak of the just-spawned workers)
            router
                .register(key, handle)
                .expect("duplicate registration must be caught before spawning");
        }
        // collect startup results; on any failure don't leak the engines
        // that did come up (threads + their resident device buffers)
        for (key, ready_rx) in pending {
            let startup = match ready_rx.recv() {
                Ok(Ok(())) => Ok(()),
                Ok(Err(e)) => Err(anyhow!("engine {key} failed to start: {e}")),
                Err(_) => Err(anyhow!("engine {key} died during startup")),
            };
            if let Err(e) = startup {
                router.close_and_join();
                return Err(e);
            }
        }
        // degrade ladders must point at live, shape-compatible spill
        // targets — a typo'd variant name should fail startup, not
        // silently shed everything the ladder was meant to save
        if let Some(q) = &qos_cfg {
            for class in Class::ALL {
                for cand in q.degrade.ladder(class) {
                    for (model, elems) in &model_elems {
                        let lkey = Router::key(model, cand);
                        let Some(h) = router.engines.get(&lkey) else {
                            router.close_and_join();
                            bail!(
                                "degrade ladder for class '{class}' names \
                                 unregistered variant '{lkey}'"
                            );
                        };
                        if h.item_elems != *elems {
                            router.close_and_join();
                            bail!(
                                "degrade ladder target '{lkey}' expects {} input elems, \
                                 model '{model}' serves {elems}",
                                h.item_elems
                            );
                        }
                    }
                }
            }
        }
        // every shard is compiled-and-resident: hand each worker handle to
        // its supervisor (the shard's `join` becomes the supervisor's, so
        // `close_and_join` waits for the whole supervision loop to stand
        // down, never just the current worker generation)
        for (key, shard, ctx) in supervisors {
            let h = router.engines.get_mut(&key).expect("supervised shard was registered above");
            let worker = h.shards[shard].join.take().expect("worker handle present at startup");
            let name = format!("lrta-serve-sup-{}-{shard}", key.replace('/', "-"));
            let sup = std::thread::Builder::new()
                .name(name)
                .spawn(move || supervise_shard(ctx, worker))
                .expect("failed to spawn shard supervisor thread");
            h.shards[shard].join = Some(sup);
        }
        // hedge governors spawn last: every queue they may re-dispatch to
        // is live, and a startup failure above never leaks one
        let mut governors = Vec::with_capacity(hedge_ctxs.len());
        for (name, ctx) in hedge_ctxs {
            let gov = std::thread::Builder::new()
                .name(name)
                .spawn(move || hedge_governor(ctx))
                .expect("failed to spawn hedge governor thread");
            governors.push(gov);
        }
        Ok(Server {
            router,
            next_id: AtomicU64::new(0),
            slo: cfg.slo,
            swap_timeout: cfg.swap_timeout,
            shard_down_retry: cfg.shard_down_retry,
            closing,
            tracer: cfg.tracer.clone(),
            qos: qos_cfg,
            governors,
        })
    }

    /// Enqueue one sample for `(model, variant)`. Returns immediately with
    /// a [`Pending`] handle, or an admission-control / routing error. With
    /// shards the request lands on the shallowest queue (round-robin on
    /// ties, closed queues lose to any live shard); with an SLO configured
    /// it carries an admission deadline. A queue closed by a worker death
    /// (not shutdown) is retried with a short backoff for up to
    /// `shard_down_retry` — the supervised respawn usually lands inside the
    /// window — before answering [`ServeError::ShardDown`].
    pub fn submit(&self, model: &str, variant: &str, x: Vec<f32>) -> Result<Pending, ServeError> {
        self.submit_class(model, variant, x, Class::Standard)
    }

    /// [`Server::submit`] with an explicit priority class. With QoS off
    /// the class is carried but ignored (single queue, server-wide SLO) —
    /// the path is bit-identical to `submit`. With QoS on the request
    /// lands in its class queue and carries that class's SLO deadline.
    pub fn submit_class(
        &self,
        model: &str,
        variant: &str,
        x: Vec<f32>,
        class: Class,
    ) -> Result<Pending, ServeError> {
        let span_t0 = self.tracer.start();
        let h = self
            .router
            .get(model, variant)
            .ok_or_else(|| ServeError::UnknownVariant(Router::key(model, variant)))?;
        if x.len() != h.item_elems {
            return Err(ServeError::BadInput { expected: h.item_elems, got: x.len() });
        }
        let (tx, rx) = mpsc::channel();
        let enqueued = Instant::now();
        let retry_until = enqueued + self.shard_down_retry;
        let slo = match &self.qos {
            Some(q) => q.class_slo(class, self.slo),
            None => self.slo,
        };
        let mut req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            x,
            enqueued,
            deadline: slo.map(|slo| enqueued + slo),
            tx,
            class,
            hedge: None,
            hedged_copy: false,
        };
        let outcome = loop {
            let shard = &h.shards[h.pick_shard()];
            match shard.queue.try_push(req.class, req) {
                Ok(depth) => {
                    shard.stats.on_enqueue(depth);
                    break Ok(Pending { rx });
                }
                // the pick already steered to the shallowest queue: if that
                // one is at capacity, every shard is — reject (backpressure)
                Err(PushError::Full(_)) => {
                    shard.stats.on_reject();
                    break Err(ServeError::QueueFull { depth: shard.queue.capacity() });
                }
                Err(PushError::Closed(r)) => {
                    if self.closing.load(Ordering::SeqCst) {
                        break Err(ServeError::Closed);
                    }
                    if Instant::now() >= retry_until {
                        break Err(ServeError::ShardDown);
                    }
                    // every live shard outranks a closed queue in the pick,
                    // so landing here means the whole shard set is down —
                    // wait out the respawn
                    req = r;
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
        };
        self.tracer.end(span_t0, "serve", "submit");
        outcome
    }

    /// Warm variant swap: replace `(model, variant)`'s checkpoint on every
    /// shard with zero downtime. Each shard uploads the new buffers beside
    /// its live set and flips atomically between batches; requests keep
    /// flowing throughout and none is dropped. Blocks until every shard has
    /// flipped (or reports the first failure — on error the fleet may be
    /// mid-swap: healthy shards flipped, failed ones kept the old set).
    /// Each ack wait is bounded by `swap_timeout`, so a wedged worker
    /// surfaces as an error instead of hanging the caller forever.
    pub fn swap_variant(
        &self,
        model: &str,
        variant: &str,
        params: &Params,
    ) -> Result<(), ServeError> {
        let h = self
            .router
            .get(model, variant)
            .ok_or_else(|| ServeError::UnknownVariant(Router::key(model, variant)))?;
        // one swap at a time per variant: racing fanouts could reach the
        // shards in opposite orders and split the fleet across checkpoints
        let _gate = h.swap_gate.lock().unwrap();
        // fan the swap out to every shard first so uploads overlap …
        let mut acks = Vec::with_capacity(h.shards.len());
        for (i, shard) in h.shards.iter().enumerate() {
            let (ack_tx, ack_rx) = mpsc::channel();
            let msg = SwapMsg { params: params.clone(), ack: ack_tx };
            if shard.swap.lock().unwrap().send(msg).is_err() {
                return Err(self.down_error());
            }
            acks.push((i, ack_rx));
        }
        // … then collect every ack, each wait deadline-bounded
        for (i, ack) in acks {
            match ack.recv_timeout(self.swap_timeout) {
                Ok(Ok(())) => {
                    // remember the applied checkpoint so a supervised
                    // respawn of this shard comes back warm with it
                    *h.shards[i].checkpoint.lock().unwrap() = params.clone();
                }
                Ok(Err(e)) => return Err(ServeError::Engine(e)),
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    return Err(ServeError::Engine(format!(
                        "shard {i} swap ack timed out after {:?}",
                        self.swap_timeout
                    )))
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => return Err(self.down_error()),
            }
        }
        Ok(())
    }

    /// [`Server::swap_variant`] with the checkpoint fetched from a storage
    /// backend: load + decode the object at `key`, then run the normal
    /// zero-downtime fanout. This is how a serve process picks up what a
    /// training run published (`lrta serve --swap-store URI --swap-key K`,
    /// or a `mem:` store shared in-process with the trainer — the CI
    /// smoke); a missing or corrupt checkpoint surfaces as
    /// [`ServeError::Engine`] before any shard is touched.
    pub fn swap_variant_from_store(
        &self,
        model: &str,
        variant: &str,
        store: &dyn crate::storage::Storage,
        key: &str,
    ) -> Result<(), ServeError> {
        let params = crate::checkpoint::load_from(store, key)
            .map_err(|e| ServeError::Engine(format!("{e:#}")))?;
        self.swap_variant(model, variant, &params)
    }

    /// A shard's control channel went away: [`ServeError::Closed`] when the
    /// server is shutting down, [`ServeError::ShardDown`] when its worker
    /// died.
    fn down_error(&self) -> ServeError {
        if self.closing.load(Ordering::SeqCst) {
            ServeError::Closed
        } else {
            ServeError::ShardDown
        }
    }

    /// Compiled batch size of a registered variant.
    pub fn batch_of(&self, model: &str, variant: &str) -> Option<usize> {
        self.router.get(model, variant).map(|h| h.batch)
    }

    /// Shard count of a registered variant.
    pub fn shards_of(&self, model: &str, variant: &str) -> Option<usize> {
        self.router.get(model, variant).map(|h| h.shards.len())
    }

    /// Registered routing keys (`model/variant`).
    pub fn keys(&self) -> Vec<String> {
        self.router.keys()
    }

    /// Stats snapshot for one variant (queue depths sampled live; shard
    /// counters merged, percentiles exact over the union of samples).
    pub fn stats(&self, model: &str, variant: &str) -> Option<StatsSnapshot> {
        self.router.get(model, variant).map(|h| h.snapshot())
    }

    /// Per-shard stats snapshots for one variant, in shard order.
    pub fn shard_stats(&self, model: &str, variant: &str) -> Option<Vec<StatsSnapshot>> {
        let h = self.router.get(model, variant)?;
        Some(h.shards.iter().map(|s| s.stats.snapshot(s.queue.len())).collect())
    }

    /// Rendered latency histogram for one variant (one section per shard
    /// when scaled out).
    pub fn histogram(&self, model: &str, variant: &str, width: usize) -> Option<String> {
        let h = self.router.get(model, variant)?;
        if h.shards.len() == 1 {
            return Some(h.shards[0].stats.histogram(width));
        }
        let mut out = String::new();
        for (i, s) in h.shards.iter().enumerate() {
            out.push_str(&format!("shard {i}:\n"));
            out.push_str(&s.stats.histogram(width));
        }
        Some(out)
    }

    /// Snapshots for every variant, in key order.
    pub fn snapshots(&self) -> Vec<StatsSnapshot> {
        self.router.engines.values().map(|h| h.snapshot()).collect()
    }

    /// Close every queue, drain in-flight work, join the workers.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        // order matters: supervisors (and `submit` retries) check `closing`
        // after a queue closes, so the flag must already read true when the
        // terminal close lands
        self.closing.store(true, Ordering::SeqCst);
        self.router.close_and_join();
        // governors poll `closing`, so they stand down within one interval
        for gov in self.governors.drain(..) {
            let _ = gov.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_key_convention() {
        assert_eq!(Router::key("resnet_mini", "lrd"), "resnet_mini/lrd");
    }

    #[test]
    fn empty_router_has_no_engines() {
        let r = Router::default();
        assert!(r.keys().is_empty());
        assert!(r.get("m", "v").is_none());
    }

    #[test]
    fn default_config_is_resident_pipelined_mode() {
        let c = ServerConfig::default();
        assert!(!c.reupload);
        assert!(c.pipelined);
        assert_eq!(c.queue_depth, 0);
        assert!(c.max_wait >= Duration::from_millis(1));
        assert!(c.slo.is_none(), "no SLO by default: nothing sheds");
        assert!(c.registry.is_none(), "no registry by default: nothing registers");
        assert!(!c.tracer.is_enabled(), "tracing off by default");
        assert!(c.supervise, "supervised respawn on by default");
        assert_eq!(c.max_respawns, 2);
        assert!(c.swap_timeout >= Duration::from_secs(1), "swap ack wait is generous but finite");
        assert!(c.shard_down_retry >= Duration::from_millis(100));
        assert!(c.qos.is_none(), "QoS off by default: pre-QoS serve path");
    }

    #[test]
    fn variant_spec_defaults_to_one_shard() {
        let spec = VariantSpec::new("m", "lrd", Params::new());
        assert_eq!(spec.shards, 1);
        assert_eq!(spec.with_shards(4).shards, 4);
    }

    /// A worker-less engine handle for routing-logic tests (queues and
    /// stats are real; the swap channel's receiver is simply dropped).
    fn dummy_handle(shards: usize, depth: usize) -> EngineHandle {
        let shards: Vec<ShardHandle> = (0..shards)
            .map(|_| {
                let (swap_tx, _swap_rx) = mpsc::channel();
                ShardHandle {
                    queue: Arc::new(ClassQueues::single(depth)),
                    stats: SharedStats::new("m", "v", 4),
                    swap: Arc::new(Mutex::new(swap_tx)),
                    checkpoint: Arc::new(Mutex::new(Params::new())),
                    join: None,
                }
            })
            .collect();
        EngineHandle {
            shards,
            rr: AtomicUsize::new(0),
            swap_gate: Mutex::new(()),
            item_elems: 4,
            batch: 4,
        }
    }

    fn push_dummy(h: &EngineHandle, shard: usize) {
        let (tx, _rx) = mpsc::channel();
        let req = Request {
            id: 0,
            x: vec![],
            enqueued: Instant::now(),
            deadline: None,
            tx,
            class: Class::Standard,
            hedge: None,
            hedged_copy: false,
        };
        h.shards[shard].queue.try_push(req.class, req).unwrap();
        // _rx dropped: the engine side treats a hung-up client as non-fatal
    }

    #[test]
    fn duplicate_registration_is_an_error() {
        let mut r = Router::default();
        r.register("m/lrd".into(), dummy_handle(1, 4)).expect("first registration");
        let err = r.register("m/lrd".into(), dummy_handle(1, 4)).unwrap_err();
        assert!(err.to_string().contains("registered twice"), "got: {err}");
        // the original registration is untouched
        assert_eq!(r.keys(), vec!["m/lrd".to_string()]);
    }

    #[test]
    fn pick_shard_prefers_shallowest_queue() {
        let h = dummy_handle(3, 8);
        push_dummy(&h, 0);
        push_dummy(&h, 0);
        push_dummy(&h, 2);
        // shard 1 is empty: every pick must land there regardless of the
        // round-robin cursor position
        for _ in 0..6 {
            assert_eq!(h.pick_shard(), 1);
        }
    }

    #[test]
    fn pick_shard_round_robins_on_ties() {
        let h = dummy_handle(3, 8);
        // all queues empty → pure round-robin from the rotating cursor
        let picks: Vec<usize> = (0..6).map(|_| h.pick_shard()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn pick_shard_steers_around_closed_queues() {
        let h = dummy_handle(3, 8);
        // shard 1 is the *deepest* live queue, but 0 and 2 are closed (dead
        // workers awaiting respawn): every pick must still land on 1
        push_dummy(&h, 1);
        h.shards[0].queue.close();
        h.shards[2].queue.close();
        for _ in 0..6 {
            assert_eq!(h.pick_shard(), 1, "closed queues must lose to any live shard");
        }
    }

    #[test]
    fn single_shard_pick_is_free() {
        let h = dummy_handle(1, 8);
        assert_eq!(h.pick_shard(), 0);
        // the round-robin cursor is untouched on the 1-shard fast path
        assert_eq!(h.rr.load(Ordering::Relaxed), 0);
    }
}
