//! Variant registry and router: `(model, variant)` → engine.
//!
//! `orig`, `lrd` and `rankopt` checkpoints of the same model register as
//! independent engines (own queue, own worker, own stats) and serve
//! side-by-side, so A/B throughput comparison — the Table-1 experiment — is
//! just two `submit` targets. The router is the only thread-shared entry
//! point; it validates payloads, applies admission control via the bounded
//! queue, and exposes per-variant stats snapshots.

use super::engine::{self, EngineConfig};
use super::queue::{Bounded, PushError};
use super::stats::{SharedStats, StatsSnapshot};
use super::{Pending, Request, ServeError};
use crate::checkpoint::Params;
use crate::runtime::Manifest;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Server-wide serving policy (applied to every registered variant).
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Queue depth per variant; `0` means `4 × compiled batch`.
    pub queue_depth: usize,
    /// Batcher max-wait: how long a partial batch stays open.
    pub max_wait: Duration,
    /// Idle worker poll interval (shutdown latency bound when trafficless).
    pub idle_poll: Duration,
    /// Re-upload parameters every batch (the measurable old baseline)
    /// instead of keeping them device-resident.
    pub reupload: bool,
    /// Streaming admission (default): engines dispatch batch N, coalesce
    /// and upload batch N+1 while N executes, then fetch N. `false` keeps
    /// the serial lockstep loop as a measurable baseline. Only effective in
    /// resident mode.
    pub pipelined: bool,
    /// Startup accuracy spot-check sample count (0 = off).
    pub spot_check: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            queue_depth: 0,
            max_wait: Duration::from_millis(2),
            idle_poll: Duration::from_millis(25),
            reupload: false,
            pipelined: true,
            spot_check: 0,
        }
    }
}

/// One variant to register: the checkpoint must already match the variant
/// (decompose first for `lrd` / `rankopt`).
pub struct VariantSpec {
    pub model: String,
    pub variant: String,
    pub params: Params,
}

impl VariantSpec {
    pub fn new(model: &str, variant: &str, params: Params) -> VariantSpec {
        VariantSpec { model: model.to_string(), variant: variant.to_string(), params }
    }

    /// Spec for `variant` derived from a dense checkpoint: identity for
    /// `orig`, closed-form LRD at the manifest's configured ranks otherwise
    /// (the one construction every serve entry point shares).
    pub fn from_dense(
        manifest: &Manifest,
        model: &str,
        variant: &str,
        dense: &Params,
    ) -> Result<VariantSpec> {
        let params = if variant == "orig" {
            dense.clone()
        } else {
            crate::coordinator::decompose_checkpoint(dense, manifest.config(model, variant)?)?
                .params
        };
        Ok(VariantSpec::new(model, variant, params))
    }
}

/// Live engine registration.
struct EngineHandle {
    queue: Arc<Bounded<Request>>,
    stats: SharedStats,
    item_elems: usize,
    batch: usize,
    join: Option<JoinHandle<()>>,
}

/// `(model, variant)` → engine lookup table.
#[derive(Default)]
pub struct Router {
    engines: BTreeMap<String, EngineHandle>,
}

impl Router {
    /// Routing key convention.
    pub fn key(model: &str, variant: &str) -> String {
        format!("{model}/{variant}")
    }

    fn get(&self, model: &str, variant: &str) -> Option<&EngineHandle> {
        self.engines.get(&Self::key(model, variant))
    }

    /// Registered keys in deterministic order.
    pub fn keys(&self) -> Vec<String> {
        self.engines.keys().cloned().collect()
    }

    /// Close every queue and join every worker (idempotent).
    fn close_and_join(&mut self) {
        for h in self.engines.values() {
            h.queue.close();
        }
        for h in self.engines.values_mut() {
            if let Some(join) = h.join.take() {
                let _ = join.join();
            }
        }
    }
}

/// The serving subsystem's front door: a router over per-variant engines
/// plus lifecycle management. `Sync` — share it by reference across client
/// threads.
pub struct Server {
    router: Router,
    next_id: AtomicU64,
}

impl Server {
    /// Start one engine per spec — all in parallel, since each worker owns
    /// an independent PJRT client — then block until every engine reports
    /// compiled-and-resident. Fails fast (and tears the partial fleet down)
    /// if any artifact is missing or won't load.
    pub fn start(
        manifest: &Manifest,
        specs: Vec<VariantSpec>,
        cfg: &ServerConfig,
    ) -> Result<Server> {
        let mut router = Router::default();
        let mut pending = Vec::with_capacity(specs.len());
        for spec in specs {
            let name = Manifest::name_of(&spec.model, &spec.variant, "infer", "none");
            let meta = match manifest.artifact(&name) {
                Ok(m) => m.clone(),
                Err(e) => {
                    router.close_and_join();
                    return Err(e);
                }
            };
            let batch = meta.batch;
            let item_elems: usize = meta.x_shape.iter().skip(1).product();
            let depth = if cfg.queue_depth == 0 { batch * 4 } else { cfg.queue_depth };
            let queue = Arc::new(Bounded::new(depth));
            let stats = SharedStats::new(&spec.model, &spec.variant, batch);
            let ecfg = EngineConfig {
                model: spec.model.clone(),
                variant: spec.variant.clone(),
                max_wait: cfg.max_wait,
                idle_poll: cfg.idle_poll,
                reupload: cfg.reupload,
                pipelined: cfg.pipelined,
                spot_check: cfg.spot_check,
            };
            let (ready_tx, ready_rx) = mpsc::channel();
            let key = Router::key(&spec.model, &spec.variant);
            if router.engines.contains_key(&key) {
                // a silent overwrite would leak the first engine's worker
                router.close_and_join();
                return Err(anyhow!("variant '{key}' registered twice"));
            }
            let join = engine::spawn(
                manifest.clone(),
                meta,
                spec.params,
                ecfg,
                Arc::clone(&queue),
                stats.clone(),
                ready_tx,
            );
            router.engines.insert(
                key.clone(),
                EngineHandle { queue, stats, item_elems, batch, join: Some(join) },
            );
            pending.push((key, ready_rx));
        }
        // collect startup results; on any failure don't leak the engines
        // that did come up (threads + their resident device buffers)
        for (key, ready_rx) in pending {
            let startup = match ready_rx.recv() {
                Ok(Ok(())) => Ok(()),
                Ok(Err(e)) => Err(anyhow!("engine {key} failed to start: {e}")),
                Err(_) => Err(anyhow!("engine {key} died during startup")),
            };
            if let Err(e) = startup {
                router.close_and_join();
                return Err(e);
            }
        }
        Ok(Server { router, next_id: AtomicU64::new(0) })
    }

    /// Enqueue one sample for `(model, variant)`. Returns immediately with
    /// a [`Pending`] handle, or an admission-control / routing error.
    pub fn submit(&self, model: &str, variant: &str, x: Vec<f32>) -> Result<Pending, ServeError> {
        let h = self
            .router
            .get(model, variant)
            .ok_or_else(|| ServeError::UnknownVariant(Router::key(model, variant)))?;
        if x.len() != h.item_elems {
            return Err(ServeError::BadInput { expected: h.item_elems, got: x.len() });
        }
        let (tx, rx) = mpsc::channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            x,
            enqueued: Instant::now(),
            tx,
        };
        match h.queue.try_push(req) {
            Ok(depth) => {
                h.stats.on_enqueue(depth);
                Ok(Pending { rx })
            }
            Err(PushError::Full(_)) => {
                h.stats.on_reject();
                Err(ServeError::QueueFull { depth: h.queue.capacity() })
            }
            Err(PushError::Closed(_)) => Err(ServeError::Closed),
        }
    }

    /// Compiled batch size of a registered variant.
    pub fn batch_of(&self, model: &str, variant: &str) -> Option<usize> {
        self.router.get(model, variant).map(|h| h.batch)
    }

    /// Registered routing keys (`model/variant`).
    pub fn keys(&self) -> Vec<String> {
        self.router.keys()
    }

    /// Stats snapshot for one variant (queue depth sampled live).
    pub fn stats(&self, model: &str, variant: &str) -> Option<StatsSnapshot> {
        self.router.get(model, variant).map(|h| h.stats.snapshot(h.queue.len()))
    }

    /// Rendered latency histogram for one variant.
    pub fn histogram(&self, model: &str, variant: &str, width: usize) -> Option<String> {
        self.router.get(model, variant).map(|h| h.stats.histogram(width))
    }

    /// Snapshots for every variant, in key order.
    pub fn snapshots(&self) -> Vec<StatsSnapshot> {
        self.router.engines.values().map(|h| h.stats.snapshot(h.queue.len())).collect()
    }

    /// Close every queue, drain in-flight work, join the workers.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.router.close_and_join();
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_key_convention() {
        assert_eq!(Router::key("resnet_mini", "lrd"), "resnet_mini/lrd");
    }

    #[test]
    fn empty_router_has_no_engines() {
        let r = Router::default();
        assert!(r.keys().is_empty());
        assert!(r.get("m", "v").is_none());
    }

    #[test]
    fn default_config_is_resident_pipelined_mode() {
        let c = ServerConfig::default();
        assert!(!c.reupload);
        assert!(c.pipelined);
        assert_eq!(c.queue_depth, 0);
        assert!(c.max_wait >= Duration::from_millis(1));
    }
}
