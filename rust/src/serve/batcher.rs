//! Dynamic batcher: coalesce queued requests onto the artifact's compiled
//! batch shape.
//!
//! AOT artifacts are lowered for one constant batch size, so the batcher's
//! contract is simple: deliver *up to* `batch` requests per executable run,
//! waiting at most `max_wait` past the first request before shipping a
//! partial (zero-padded) batch. GroupNorm/LayerNorm in the mini models
//! normalize per sample, so padded rows never perturb real rows — the demux
//! in the engine returns each request exactly the logits row its image
//! produced.

use super::queue::{Bounded, Pop};
use super::Request;
use std::time::{Duration, Instant};

/// Batching policy for one engine.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Compiled batch size of the artifact (coalescing ceiling).
    pub batch: usize,
    /// Elements per request payload (e.g. `32·32·3`).
    pub item_elems: usize,
    /// How long to hold a partial batch open after its first request.
    pub max_wait: Duration,
    /// Idle poll interval: how often a sleeping worker re-checks for
    /// shutdown when no traffic arrives.
    pub idle_poll: Duration,
}

/// What the worker loop should do next.
pub enum NextBatch {
    /// One coalesced batch, `1 ..= batch` requests in FIFO order.
    Batch(Vec<Request>),
    /// No traffic within the idle poll window.
    Idle,
    /// Queue closed and drained — worker exits.
    Closed,
}

/// Block for the next batch: wait (bounded) for a first request, then
/// coalesce until the batch is full or `max_wait` expires.
pub fn next_batch(queue: &Bounded<Request>, cfg: &BatcherConfig) -> NextBatch {
    let first = match queue.pop_timeout(cfg.idle_poll) {
        Pop::Item(r) => r,
        Pop::TimedOut => return NextBatch::Idle,
        Pop::Closed => return NextBatch::Closed,
    };
    let mut reqs = vec![first];
    let deadline = Instant::now() + cfg.max_wait;
    while reqs.len() < cfg.batch {
        match queue.pop_deadline(deadline) {
            Pop::Item(r) => reqs.push(r),
            // Closed still ships the in-hand partial batch; the *next*
            // next_batch call observes Closed and exits the worker.
            Pop::TimedOut | Pop::Closed => break,
        }
    }
    NextBatch::Batch(reqs)
}

/// Streaming-admission decision point: should the engine keep the current
/// batch's results in flight and go coalesce the next batch first?
///
/// Overlap only pays when there is actually queued work — with an empty
/// queue the pipelined engine fetches and responds immediately instead of
/// holding finished results hostage until the next arrival (or the idle
/// poll). This is the whole latency story of the overlapped engine: burst
/// traffic pipelines, trickle traffic behaves exactly like the serial loop.
pub fn has_backlog(queue: &Bounded<Request>) -> bool {
    !queue.is_empty()
}

/// Flatten request payloads into one `[batch · item_elems]` buffer in FIFO
/// order, zero-padding unfilled rows. Returns `(xs, padded_slots)`.
pub fn assemble(reqs: &[Request], batch: usize, item_elems: usize) -> (Vec<f32>, usize) {
    debug_assert!(reqs.len() <= batch, "batcher over-coalesced");
    let mut xs = vec![0.0f32; batch * item_elems];
    for (i, r) in reqs.iter().enumerate() {
        xs[i * item_elems..(i + 1) * item_elems].copy_from_slice(&r.x);
    }
    (xs, batch - reqs.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{Response, ServeError};
    use std::sync::mpsc;

    const ELEMS: usize = 4;

    fn req(fill: f32) -> (Request, mpsc::Receiver<Result<Response, ServeError>>) {
        let (tx, rx) = mpsc::channel();
        let r = Request { id: 0, x: vec![fill; ELEMS], enqueued: Instant::now(), tx };
        (r, rx)
    }

    fn cfg(batch: usize, max_wait_ms: u64) -> BatcherConfig {
        BatcherConfig {
            batch,
            item_elems: ELEMS,
            max_wait: Duration::from_millis(max_wait_ms),
            idle_poll: Duration::from_millis(10),
        }
    }

    #[test]
    fn coalesces_full_batch_without_waiting_out_deadline() {
        let q = Bounded::new(8);
        for i in 0..4 {
            q.try_push(req(i as f32).0).unwrap();
        }
        let t0 = Instant::now();
        match next_batch(&q, &cfg(4, 5_000)) {
            NextBatch::Batch(reqs) => {
                assert_eq!(reqs.len(), 4);
                // FIFO order preserved
                for (i, r) in reqs.iter().enumerate() {
                    assert_eq!(r.x[0], i as f32);
                }
            }
            _ => panic!("expected a batch"),
        }
        // a full batch must not wait for the 5 s deadline
        assert!(t0.elapsed() < Duration::from_secs(1));
    }

    #[test]
    fn partial_batch_ships_at_deadline() {
        let q = Bounded::new(8);
        q.try_push(req(1.0).0).unwrap();
        q.try_push(req(2.0).0).unwrap();
        let t0 = Instant::now();
        match next_batch(&q, &cfg(4, 30)) {
            NextBatch::Batch(reqs) => assert_eq!(reqs.len(), 2),
            _ => panic!("expected a partial batch"),
        }
        let waited = t0.elapsed();
        assert!(waited >= Duration::from_millis(25), "shipped too early: {waited:?}");
        assert!(waited < Duration::from_secs(2), "deadline ignored: {waited:?}");
    }

    #[test]
    fn idle_then_closed() {
        let q: Bounded<Request> = Bounded::new(2);
        assert!(matches!(next_batch(&q, &cfg(4, 1)), NextBatch::Idle));
        q.close();
        assert!(matches!(next_batch(&q, &cfg(4, 1)), NextBatch::Closed));
    }

    #[test]
    fn close_ships_drained_partial_then_closed() {
        let q = Bounded::new(4);
        q.try_push(req(3.0).0).unwrap();
        q.close();
        match next_batch(&q, &cfg(4, 5_000)) {
            NextBatch::Batch(reqs) => assert_eq!(reqs.len(), 1),
            _ => panic!("expected drained partial batch"),
        }
        assert!(matches!(next_batch(&q, &cfg(4, 1)), NextBatch::Closed));
    }

    #[test]
    fn assemble_pads_with_zeros_in_fifo_order() {
        let (r1, _k1) = req(1.0);
        let (r2, _k2) = req(2.0);
        let (xs, padded) = assemble(&[r1, r2], 4, ELEMS);
        assert_eq!(padded, 2);
        assert_eq!(xs.len(), 4 * ELEMS);
        assert!(xs[0..ELEMS].iter().all(|&v| v == 1.0));
        assert!(xs[ELEMS..2 * ELEMS].iter().all(|&v| v == 2.0));
        assert!(xs[2 * ELEMS..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn backlog_reflects_queue_depth() {
        let q = Bounded::new(4);
        assert!(!has_backlog(&q));
        q.try_push(req(1.0).0).unwrap();
        assert!(has_backlog(&q));
        let _ = q.try_pop();
        assert!(!has_backlog(&q));
    }

    #[test]
    fn assemble_full_batch_has_no_padding() {
        let reqs: Vec<Request> = (0..3).map(|i| req(i as f32).0).collect();
        let (xs, padded) = assemble(&reqs, 3, ELEMS);
        assert_eq!(padded, 0);
        assert_eq!(xs.len(), 3 * ELEMS);
    }
}
