//! Dynamic batcher: coalesce queued requests onto the artifact's compiled
//! batch shape.
//!
//! AOT artifacts are lowered for one constant batch size, so the batcher's
//! contract is simple: deliver *up to* `batch` requests per executable run,
//! waiting at most `max_wait` past the first request before shipping a
//! partial (zero-padded) batch. GroupNorm/LayerNorm in the mini models
//! normalize per sample, so padded rows never perturb real rows — the demux
//! in the engine returns each request exactly the logits row its image
//! produced.
//!
//! **SLO-aware shedding** happens here, at pop time: a request whose
//! admission deadline has already passed is answered
//! [`ServeError::DeadlineExceeded`](super::ServeError::DeadlineExceeded)
//! and counted ([`SharedStats::on_shed`]) instead of riding a batch — under
//! backlog the engine spends its executable slots only on answers someone
//! is still waiting for. Shedding at admission time would be wrong twice
//! over: the queue wait *is* the latency being guarded, and rejecting early
//! would shed work that might still make its deadline.
//!
//! **Degrade-not-shed** (QoS): before shedding, an expired request is
//! offered to [`ShardQos::spill`] — with a [`DegradePolicy`] ladder
//! configured, low-priority work that missed its deadline moves to a
//! cheaper registered variant of the same model (with a fresh per-class
//! deadline) instead of being dropped, trading decomposition rank for an
//! answer. The spill is counted on this variant
//! ([`SharedStats::on_spill`]) and admitted on the target; only work with
//! no live ladder target below it is shed.
//!
//! [`DegradePolicy`]: super::qos::DegradePolicy

use super::qos::{ClassQueues, ShardQos};
use super::queue::Pop;
use super::stats::SharedStats;
use super::{Request, ServeError};
use crate::obs::Tracer;
use std::time::{Duration, Instant};

/// Batching policy for one engine.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Compiled batch size of the artifact (coalescing ceiling).
    pub batch: usize,
    /// Elements per request payload (e.g. `32·32·3`).
    pub item_elems: usize,
    /// How long to hold a partial batch open after its first request.
    pub max_wait: Duration,
    /// Idle poll interval: how often a sleeping worker re-checks for
    /// shutdown when no traffic arrives.
    pub idle_poll: Duration,
}

/// What the worker loop should do next.
pub enum NextBatch {
    /// One coalesced batch, `1 ..= batch` requests in FIFO order.
    Batch(Vec<Request>),
    /// No traffic within the idle poll window.
    Idle,
    /// Queue closed and drained — worker exits.
    Closed,
}

/// Pop-time disposition of an expired request: first offer it to the
/// degrade ladder ([`ShardQos::spill`], counted as a spill on this
/// variant), and only if no ladder target takes it answer
/// `DeadlineExceeded` (counted as a per-class shed). Live requests pass
/// through untouched.
fn resolve_expired(req: Request, stats: &SharedStats, qos: &ShardQos) -> Option<Request> {
    if !req.expired(Instant::now()) {
        return Some(req);
    }
    let class = req.class;
    match qos.spill(req) {
        Ok(()) => {
            stats.on_spill(class);
            None
        }
        Err(req) => {
            stats.on_shed(class);
            req.respond(Err(ServeError::DeadlineExceeded));
            None
        }
    }
}

/// Block for the next batch: wait (bounded) for a first request, then
/// coalesce until the batch is full or `max_wait` expires. Requests whose
/// admission deadline has already passed are spilled down their class
/// ladder or shed here — at pop time — and never occupy a batch slot.
///
/// When tracing is on, each shipped batch records a `queue_wait` span (the
/// idle wait for the batch's first live request; idle polls that time out
/// record nothing) and a `coalesce` span (the hold-open window gathering
/// the rest of the batch).
pub fn next_batch(
    queue: &ClassQueues,
    cfg: &BatcherConfig,
    stats: &SharedStats,
    tracer: &Tracer,
    qos: &ShardQos,
) -> NextBatch {
    let wait_t0 = tracer.start();
    let first = loop {
        match queue.pop_timeout(cfg.idle_poll) {
            Pop::Item(r) => match resolve_expired(r, stats, qos) {
                Some(r) => break r,
                // expired request spilled/shed; keep waiting for a live one
                // (each one restarts a bounded idle-poll window, so
                // shutdown latency stays bounded)
                None => continue,
            },
            Pop::TimedOut => return NextBatch::Idle,
            Pop::Closed => return NextBatch::Closed,
        }
    };
    tracer.end(wait_t0, "serve", "queue_wait");
    let coalesce_t0 = tracer.start();
    let mut reqs = vec![first];
    let deadline = Instant::now() + cfg.max_wait;
    while reqs.len() < cfg.batch {
        match queue.pop_deadline(deadline) {
            Pop::Item(r) => {
                if let Some(r) = resolve_expired(r, stats, qos) {
                    reqs.push(r);
                }
            }
            // Closed still ships the in-hand partial batch; the *next*
            // next_batch call observes Closed and exits the worker.
            Pop::TimedOut | Pop::Closed => break,
        }
    }
    tracer.end(coalesce_t0, "serve", "coalesce");
    NextBatch::Batch(reqs)
}

/// Streaming-admission decision point: should the engine keep the current
/// batch's results in flight and go coalesce the next batch first?
///
/// Overlap only pays when there is actually queued work — with an empty
/// queue the pipelined engine fetches and responds immediately instead of
/// holding finished results hostage until the next arrival (or the idle
/// poll). This is the whole latency story of the overlapped engine: burst
/// traffic pipelines, trickle traffic behaves exactly like the serial loop.
pub fn has_backlog(queue: &ClassQueues) -> bool {
    !queue.is_empty()
}

/// Flatten request payloads into one `[batch · item_elems]` buffer in FIFO
/// order, zero-padding unfilled rows. Returns `(xs, padded_slots)`.
pub fn assemble(reqs: &[Request], batch: usize, item_elems: usize) -> (Vec<f32>, usize) {
    debug_assert!(reqs.len() <= batch, "batcher over-coalesced");
    let mut xs = vec![0.0f32; batch * item_elems];
    for (i, r) in reqs.iter().enumerate() {
        xs[i * item_elems..(i + 1) * item_elems].copy_from_slice(&r.x);
    }
    (xs, batch - reqs.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::qos::{self, Class, QosConfig, SpillShard};
    use crate::serve::{Response, ServeError};
    use std::sync::{mpsc, Arc};

    const ELEMS: usize = 4;

    fn req(fill: f32) -> (Request, mpsc::Receiver<Result<Response, ServeError>>) {
        let (tx, rx) = mpsc::channel();
        let r = Request {
            id: 0,
            x: vec![fill; ELEMS],
            enqueued: Instant::now(),
            deadline: None,
            tx,
            class: Class::Standard,
            hedge: None,
            hedged_copy: false,
        };
        (r, rx)
    }

    fn expired_req(fill: f32) -> (Request, mpsc::Receiver<Result<Response, ServeError>>) {
        let (mut r, rx) = req(fill);
        r.deadline = Some(r.enqueued);
        (r, rx)
    }

    fn stats() -> SharedStats {
        SharedStats::new("m", "v", 4)
    }

    fn cfg(batch: usize, max_wait_ms: u64) -> BatcherConfig {
        BatcherConfig {
            batch,
            item_elems: ELEMS,
            max_wait: Duration::from_millis(max_wait_ms),
            idle_poll: Duration::from_millis(10),
        }
    }

    #[test]
    fn coalesces_full_batch_without_waiting_out_deadline() {
        let q = ClassQueues::single(8);
        for i in 0..4 {
            q.try_push(Class::Standard, req(i as f32).0).unwrap();
        }
        let t0 = Instant::now();
        match next_batch(&q, &cfg(4, 5_000), &stats(), &Tracer::noop(), &ShardQos::disabled()) {
            NextBatch::Batch(reqs) => {
                assert_eq!(reqs.len(), 4);
                // FIFO order preserved
                for (i, r) in reqs.iter().enumerate() {
                    assert_eq!(r.x[0], i as f32);
                }
            }
            _ => panic!("expected a batch"),
        }
        // a full batch must not wait for the 5 s deadline
        assert!(t0.elapsed() < Duration::from_secs(1));
    }

    #[test]
    fn partial_batch_ships_at_deadline() {
        let q = ClassQueues::single(8);
        q.try_push(Class::Standard, req(1.0).0).unwrap();
        q.try_push(Class::Standard, req(2.0).0).unwrap();
        let t0 = Instant::now();
        match next_batch(&q, &cfg(4, 30), &stats(), &Tracer::noop(), &ShardQos::disabled()) {
            NextBatch::Batch(reqs) => assert_eq!(reqs.len(), 2),
            _ => panic!("expected a partial batch"),
        }
        let waited = t0.elapsed();
        assert!(waited >= Duration::from_millis(25), "shipped too early: {waited:?}");
        assert!(waited < Duration::from_secs(2), "deadline ignored: {waited:?}");
    }

    #[test]
    fn idle_then_closed() {
        let q = ClassQueues::single(2);
        assert!(matches!(
            next_batch(&q, &cfg(4, 1), &stats(), &Tracer::noop(), &ShardQos::disabled()),
            NextBatch::Idle
        ));
        q.close();
        assert!(matches!(
            next_batch(&q, &cfg(4, 1), &stats(), &Tracer::noop(), &ShardQos::disabled()),
            NextBatch::Closed
        ));
    }

    #[test]
    fn close_ships_drained_partial_then_closed() {
        let q = ClassQueues::single(4);
        q.try_push(Class::Standard, req(3.0).0).unwrap();
        q.close();
        match next_batch(&q, &cfg(4, 5_000), &stats(), &Tracer::noop(), &ShardQos::disabled()) {
            NextBatch::Batch(reqs) => assert_eq!(reqs.len(), 1),
            _ => panic!("expected drained partial batch"),
        }
        assert!(matches!(
            next_batch(&q, &cfg(4, 1), &stats(), &Tracer::noop(), &ShardQos::disabled()),
            NextBatch::Closed
        ));
    }

    #[test]
    fn expired_requests_shed_at_pop_not_batched() {
        let q = ClassQueues::single(8);
        let s = stats();
        let (r1, rx1) = expired_req(1.0);
        let (r2, rx2) = req(2.0);
        let (r3, rx3) = expired_req(3.0);
        q.try_push(r1.class, r1).unwrap();
        q.try_push(r2.class, r2).unwrap();
        q.try_push(r3.class, r3).unwrap();
        match next_batch(&q, &cfg(4, 20), &s, &Tracer::noop(), &ShardQos::disabled()) {
            NextBatch::Batch(reqs) => {
                // only the live request rides the batch
                assert_eq!(reqs.len(), 1);
                assert_eq!(reqs[0].x[0], 2.0);
            }
            _ => panic!("expected a batch"),
        }
        // shed requests got a terminal DeadlineExceeded, counted exactly
        assert_eq!(rx1.try_recv().unwrap(), Err(ServeError::DeadlineExceeded));
        assert_eq!(rx3.try_recv().unwrap(), Err(ServeError::DeadlineExceeded));
        assert!(rx2.try_recv().is_err(), "live request must not be answered by the batcher");
        assert_eq!(s.snapshot(0).shed, 2);
    }

    #[test]
    fn all_expired_queue_drains_to_idle() {
        let q = ClassQueues::single(8);
        let s = stats();
        let mut rxs = Vec::new();
        for i in 0..3 {
            let (r, rx) = expired_req(i as f32);
            q.try_push(r.class, r).unwrap();
            rxs.push(rx);
        }
        // every queued request is expired: the batcher sheds them all and
        // reports Idle instead of shipping an empty batch
        assert!(matches!(
            next_batch(&q, &cfg(4, 20), &s, &Tracer::noop(), &ShardQos::disabled()),
            NextBatch::Idle
        ));
        for rx in &rxs {
            assert_eq!(rx.try_recv().unwrap(), Err(ServeError::DeadlineExceeded));
        }
        assert_eq!(s.snapshot(0).shed, 3);
    }

    #[test]
    fn shipped_batches_record_queue_wait_and_coalesce_spans() {
        let q = ClassQueues::single(8);
        q.try_push(Class::Standard, req(1.0).0).unwrap();
        q.try_push(Class::Standard, req(2.0).0).unwrap();
        let tracer = Tracer::enabled();
        match next_batch(&q, &cfg(2, 50), &stats(), &tracer, &ShardQos::disabled()) {
            NextBatch::Batch(reqs) => assert_eq!(reqs.len(), 2),
            _ => panic!("expected a batch"),
        }
        let names: Vec<&str> = tracer.events().iter().map(|e| e.name).collect();
        assert_eq!(names, vec!["queue_wait", "coalesce"]);
        // an idle poll records no spans — a quiet server doesn't fill the
        // trace ring with waiting
        assert!(matches!(
            next_batch(&q, &cfg(2, 1), &stats(), &tracer, &ShardQos::disabled()),
            NextBatch::Idle
        ));
        assert_eq!(tracer.len(), 2);
    }

    #[test]
    fn expired_batch_work_spills_down_the_ladder_instead_of_shedding() {
        // source shard of variant "v" with a ladder batch → cheap; the
        // expired batch-class request must land in cheap's queue (class
        // preserved, admission counted there) and be counted as a spill —
        // not a shed — here, while the expired *interactive* request (no
        // ladder) still sheds
        let q = ClassQueues::multi(8, [1, 1, 1]);
        let s = stats();
        let mut qcfg = QosConfig::default();
        qcfg.degrade.set(Class::Batch, vec!["cheap".into()]);
        let table = qos::new_table();
        let target = Arc::new(ClassQueues::multi(8, [1, 1, 1]));
        let tstats = SharedStats::new("m", "cheap", 4);
        table.lock().unwrap().insert(
            "m/cheap".into(),
            vec![SpillShard { queue: target.clone(), stats: tstats.clone() }],
        );
        let shard_qos = ShardQos::new("m", "v", Arc::new(qcfg), None, table);

        let (mut rb, rxb) = expired_req(1.0);
        rb.class = Class::Batch;
        let (mut ri, rxi) = expired_req(2.0);
        ri.class = Class::Interactive;
        let (live, _rx_live) = req(3.0);
        q.try_push(rb.class, rb).unwrap();
        q.try_push(ri.class, ri).unwrap();
        q.try_push(live.class, live).unwrap();

        match next_batch(&q, &cfg(4, 20), &s, &Tracer::noop(), &shard_qos) {
            NextBatch::Batch(reqs) => {
                assert_eq!(reqs.len(), 1, "only the live request rides the batch");
                assert_eq!(reqs[0].x[0], 3.0);
            }
            _ => panic!("expected a batch"),
        }
        // the batch-class request was spilled, not answered
        assert!(rxb.try_recv().is_err(), "spilled request must not be answered yet");
        assert_eq!(target.class_len(Class::Batch), 1, "spill lands in the target's batch queue");
        assert_eq!(tstats.snapshot(0).requests_ok, 1, "target counts the admission");
        // the interactive request had no ladder: shed as before
        assert_eq!(rxi.try_recv().unwrap(), Err(ServeError::DeadlineExceeded));
        let snap = s.snapshot(0);
        assert_eq!(snap.spilled, 1);
        assert_eq!(snap.spilled_by_class, [0, 0, 1]);
        assert_eq!(snap.shed, 1);
        assert_eq!(snap.shed_by_class, [1, 0, 0]);
    }

    #[test]
    fn assemble_pads_with_zeros_in_fifo_order() {
        let (r1, _k1) = req(1.0);
        let (r2, _k2) = req(2.0);
        let (xs, padded) = assemble(&[r1, r2], 4, ELEMS);
        assert_eq!(padded, 2);
        assert_eq!(xs.len(), 4 * ELEMS);
        assert!(xs[0..ELEMS].iter().all(|&v| v == 1.0));
        assert!(xs[ELEMS..2 * ELEMS].iter().all(|&v| v == 2.0));
        assert!(xs[2 * ELEMS..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn backlog_reflects_queue_depth() {
        let q = ClassQueues::single(4);
        assert!(!has_backlog(&q));
        q.try_push(Class::Standard, req(1.0).0).unwrap();
        assert!(has_backlog(&q));
        let _ = q.pop_timeout(Duration::from_millis(5));
        assert!(!has_backlog(&q));
    }

    #[test]
    fn assemble_full_batch_has_no_padding() {
        let reqs: Vec<Request> = (0..3).map(|i| req(i as f32).0).collect();
        let (xs, padded) = assemble(&reqs, 3, ELEMS);
        assert_eq!(padded, 0);
        assert_eq!(xs.len(), 3 * ELEMS);
    }
}
