//! Streaming corpus: the dataset lives in an object store as
//! content-addressed chunks; training fetches a bounded window of it.
//!
//! Layout on the store (all through [`crate::storage::ChunkStore`], so
//! identical chunks dedupe across rank variants and re-publishes):
//!
//! ```text
//!   chunks/<hash>            contiguous sample ranges, each sample
//!                            encoded as label i32 LE + 32×32×3 f32 LE
//!   <prefix>/manifest.json   {"format":1, "n":N, "samples_per_chunk":S,
//!                             "image_elems":3072,
//!                             "chunks":[{"key":…, "samples":k, "len":L},…]}
//! ```
//!
//! [`publish`] writes a [`Dataset`] into that layout; a
//! [`StreamingProvider`] opens the manifest and serves samples through a
//! bounded LRU cache of decoded chunks, so resident memory is
//! `cache_chunks × chunk size` regardless of corpus size. The f32 pixels
//! round-trip through `to_le_bytes`/`from_le_bytes`, i.e. bit-exactly:
//! a batch assembled from the stream equals the in-memory batch
//! bit-for-bit — which is what lets
//! [`crate::train::Prefetcher::start_streaming`] pin streamed training
//! runs against in-memory runs.
//!
//! The epoch permutation shuffles *samples* globally (the exact
//! [`crate::data::BatchIter`] order), so consecutive samples of a batch
//! land in arbitrary chunks. The cache therefore wants to be sized near
//! the chunk count of the working set; a locality-preserving shuffle
//! (shuffle chunks, then within) trades bit-identity for cache hits and
//! is left as the ROADMAP's cache-eviction follow-on.

use super::{Dataset, IMAGE_ELEMS};
use crate::storage::{ChunkStore, Storage};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Manifest schema version.
const FORMAT: i64 = 1;

/// Default samples per chunk (≈ 768 KiB of f32 pixels each).
pub const DEFAULT_SAMPLES_PER_CHUNK: usize = 64;

/// Default decoded-chunk cache capacity (chunks).
pub const DEFAULT_CACHE_CHUNKS: usize = 32;

/// Default fetch-ahead window (batches) for the streaming prefetcher.
pub const DEFAULT_FETCH_AHEAD: usize = 2;

/// Bytes of one encoded sample: i32 label + f32 pixels.
const SAMPLE_BYTES: usize = 4 + 4 * IMAGE_ELEMS;

/// Exact accounting of one [`publish`].
#[derive(Clone, Copy, Debug, Default)]
pub struct PublishStats {
    pub samples: usize,
    pub chunks_total: usize,
    /// Chunks actually uploaded (the rest were content-dedupe hits).
    pub chunks_written: usize,
    pub bytes_written: u64,
    pub bytes_deduped: u64,
}

/// Write `data` to `store` under `prefix` as content-addressed chunks of
/// `samples_per_chunk` samples plus a manifest at `<prefix>/manifest.json`.
/// Re-publishing an identical corpus writes only the manifest (every
/// chunk dedupes); overlapping corpora share their common chunks.
pub fn publish(
    store: &Arc<dyn Storage>,
    prefix: &str,
    data: &Dataset,
    samples_per_chunk: usize,
) -> Result<PublishStats> {
    if samples_per_chunk == 0 {
        bail!("samples_per_chunk must be positive");
    }
    let cs = ChunkStore::new(Arc::clone(store));
    let n = data.len();
    let mut stats = PublishStats { samples: n, ..PublishStats::default() };
    let mut entries = Vec::new();
    let mut start = 0usize;
    while start < n {
        let count = samples_per_chunk.min(n - start);
        let mut bytes = Vec::with_capacity(count * SAMPLE_BYTES);
        for i in start..start + count {
            bytes.extend_from_slice(&data.labels[i].to_le_bytes());
            for &v in &data.images[i * IMAGE_ELEMS..(i + 1) * IMAGE_ELEMS] {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
        }
        let (key, written) = cs.put_chunk(&bytes)?;
        stats.chunks_total += 1;
        if written {
            stats.chunks_written += 1;
            stats.bytes_written += bytes.len() as u64;
        } else {
            stats.bytes_deduped += bytes.len() as u64;
        }
        entries.push(Json::obj(vec![
            ("key", Json::str(key)),
            ("samples", Json::int(count as i64)),
            ("len", Json::int(bytes.len() as i64)),
        ]));
        start += count;
    }
    let manifest = Json::obj(vec![
        ("format", Json::int(FORMAT)),
        ("n", Json::int(n as i64)),
        ("samples_per_chunk", Json::int(samples_per_chunk as i64)),
        ("image_elems", Json::int(IMAGE_ELEMS as i64)),
        ("chunks", Json::arr(entries)),
    ]);
    store
        .put(&manifest_key(prefix), manifest.emit().as_bytes())
        .with_context(|| format!("write dataset manifest under '{prefix}'"))?;
    Ok(stats)
}

/// `<prefix>/manifest.json` (bare `manifest.json` for an empty prefix).
pub fn manifest_key(prefix: &str) -> String {
    if prefix.is_empty() {
        "manifest.json".to_string()
    } else {
        format!("{prefix}/manifest.json")
    }
}

/// One chunk's manifest entry.
#[derive(Clone, Debug)]
struct ChunkRef {
    key: String,
    /// First sample index this chunk holds.
    start: usize,
    samples: usize,
    len: usize,
}

/// A decoded chunk resident in the cache.
struct DecodedChunk {
    labels: Vec<i32>,
    images: Vec<f32>,
}

/// Bounded LRU of decoded chunks (by chunk index).
struct ChunkCache {
    cap: usize,
    tick: u64,
    map: HashMap<usize, (u64, Arc<DecodedChunk>)>,
}

impl ChunkCache {
    fn get(&mut self, ci: usize) -> Option<Arc<DecodedChunk>> {
        self.tick += 1;
        let tick = self.tick;
        self.map.get_mut(&ci).map(|slot| {
            slot.0 = tick;
            Arc::clone(&slot.1)
        })
    }

    fn insert(&mut self, ci: usize, chunk: Arc<DecodedChunk>) {
        while self.map.len() >= self.cap.max(1) {
            // evict the least-recently-used entry
            let oldest = self.map.iter().min_by_key(|(_, (t, _))| *t).map(|(k, _)| *k);
            match oldest {
                Some(k) => self.map.remove(&k),
                None => break,
            };
        }
        self.tick += 1;
        self.map.insert(ci, (self.tick, chunk));
    }
}

/// Read-side of a published corpus: samples on demand through a bounded
/// decoded-chunk cache. Shareable across threads (`Arc<StreamingProvider>`
/// — replicas pulling disjoint shards share one provider and its cache).
pub struct StreamingProvider {
    chunks: ChunkStore,
    refs: Vec<ChunkRef>,
    n: usize,
    samples_per_chunk: usize,
    cache: Mutex<ChunkCache>,
    fetch_ahead: usize,
}

impl StreamingProvider {
    /// Open the corpus published under `prefix`.
    pub fn open(store: Arc<dyn Storage>, prefix: &str) -> Result<StreamingProvider> {
        let key = manifest_key(prefix);
        let bytes = store
            .get(&key)
            .with_context(|| format!("open dataset manifest '{key}'"))?;
        let text = std::str::from_utf8(&bytes)
            .with_context(|| format!("dataset manifest '{key}': not utf-8"))?;
        let manifest =
            Json::parse(text).map_err(|e| anyhow::anyhow!("dataset manifest '{key}': {e}"))?;
        if manifest.get("format").as_i64() != Some(FORMAT) {
            bail!("dataset manifest '{key}': unsupported format {:?}", manifest.get("format"));
        }
        if manifest.get("image_elems").as_usize() != Some(IMAGE_ELEMS) {
            bail!(
                "dataset manifest '{key}': image_elems {:?} does not match this build's {}",
                manifest.get("image_elems"),
                IMAGE_ELEMS
            );
        }
        let n = manifest
            .get("n")
            .as_usize()
            .with_context(|| format!("dataset manifest '{key}': missing n"))?;
        let samples_per_chunk = manifest
            .get("samples_per_chunk")
            .as_usize()
            .filter(|&s| s > 0)
            .with_context(|| format!("dataset manifest '{key}': missing samples_per_chunk"))?;
        let entries = manifest
            .get("chunks")
            .as_arr()
            .with_context(|| format!("dataset manifest '{key}': missing chunks"))?;
        let mut refs = Vec::with_capacity(entries.len());
        let mut start = 0usize;
        for (i, e) in entries.iter().enumerate() {
            let ckey = e
                .get("key")
                .as_str()
                .with_context(|| format!("dataset manifest '{key}': chunk {i} missing key"))?;
            let samples = e
                .get("samples")
                .as_usize()
                .with_context(|| format!("dataset manifest '{key}': chunk {i} missing samples"))?;
            let len = e
                .get("len")
                .as_usize()
                .with_context(|| format!("dataset manifest '{key}': chunk {i} missing len"))?;
            if samples == 0 || len != samples * SAMPLE_BYTES {
                bail!(
                    "dataset manifest '{key}': chunk {i} declares {samples} samples / {len} bytes \
                     (expected {} bytes per sample)",
                    SAMPLE_BYTES
                );
            }
            refs.push(ChunkRef { key: ckey.to_string(), start, samples, len });
            start += samples;
        }
        if start != n {
            bail!("dataset manifest '{key}': chunks cover {start} samples, manifest says {n}");
        }
        Ok(StreamingProvider {
            chunks: ChunkStore::new(store),
            refs,
            n,
            samples_per_chunk,
            cache: Mutex::new(ChunkCache {
                cap: DEFAULT_CACHE_CHUNKS,
                tick: 0,
                map: HashMap::new(),
            }),
            fetch_ahead: DEFAULT_FETCH_AHEAD,
        })
    }

    /// Cap the decoded-chunk cache (chunks). Resident memory is bounded by
    /// `cap × samples_per_chunk × sample size` regardless of corpus size.
    pub fn with_cache_chunks(self, cap: usize) -> StreamingProvider {
        self.cache.lock().expect("chunk cache lock").cap = cap.max(1);
        self
    }

    /// Batches of fetch-ahead the streaming prefetcher applies
    /// ([`crate::train::Prefetcher::start_streaming`]).
    pub fn with_fetch_ahead(mut self, batches: usize) -> StreamingProvider {
        self.fetch_ahead = batches;
        self
    }

    /// Total samples in the corpus.
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Chunks the corpus splits into.
    pub fn num_chunks(&self) -> usize {
        self.refs.len()
    }

    pub fn fetch_ahead(&self) -> usize {
        self.fetch_ahead
    }

    /// Which chunk holds sample `idx`.
    pub fn chunk_of(&self, idx: usize) -> usize {
        idx / self.samples_per_chunk
    }

    /// Ensure the chunk holding sample ranges around `ci` is resident —
    /// the fetch-ahead entry point (errors on a failed fetch; a warm
    /// chunk is a no-op).
    pub fn prefetch_chunk(&self, ci: usize) -> Result<()> {
        self.chunk(ci).map(|_| ())
    }

    /// Append sample `idx` (pixels, label) to a batch under assembly —
    /// bit-exactly the values [`Dataset`] holds in memory.
    pub fn append_sample(&self, idx: usize, xs: &mut Vec<f32>, ys: &mut Vec<i32>) -> Result<()> {
        if idx >= self.n {
            bail!("sample {idx} out of range 0..{}", self.n);
        }
        let ci = self.chunk_of(idx);
        let chunk = self.chunk(ci)?;
        let local = idx - self.refs[ci].start;
        xs.extend_from_slice(&chunk.images[local * IMAGE_ELEMS..(local + 1) * IMAGE_ELEMS]);
        ys.push(chunk.labels[local]);
        Ok(())
    }

    /// Materialize the whole corpus as an in-memory [`Dataset`] (test
    /// helper / small-corpus escape hatch — defeats the bounded-RAM point
    /// for large ones).
    pub fn to_dataset(&self) -> Result<Dataset> {
        let mut xs = Vec::with_capacity(self.n * IMAGE_ELEMS);
        let mut ys = Vec::with_capacity(self.n);
        for idx in 0..self.n {
            self.append_sample(idx, &mut xs, &mut ys)?;
        }
        Ok(Dataset { images: xs, labels: ys })
    }

    /// The decoded chunk `ci`, from cache or fetched + verified + decoded.
    fn chunk(&self, ci: usize) -> Result<Arc<DecodedChunk>> {
        if ci >= self.refs.len() {
            bail!("chunk {ci} out of range 0..{}", self.refs.len());
        }
        if let Some(hit) = self.cache.lock().expect("chunk cache lock").get(ci) {
            return Ok(hit);
        }
        // fetch outside the cache lock: a slow (or stalled) object fetch
        // must not block readers hitting warm chunks
        let r = &self.refs[ci];
        let bytes = self.chunks.get_chunk(&r.key)?;
        if bytes.len() != r.len {
            bail!("chunk {ci} ({}) is {} bytes, manifest says {}", r.key, bytes.len(), r.len);
        }
        let decoded = Arc::new(decode_chunk(&bytes, r.samples));
        self.cache.lock().expect("chunk cache lock").insert(ci, Arc::clone(&decoded));
        Ok(decoded)
    }
}

/// Decode `samples` encoded samples (length already validated).
fn decode_chunk(bytes: &[u8], samples: usize) -> DecodedChunk {
    let mut labels = Vec::with_capacity(samples);
    let mut images = Vec::with_capacity(samples * IMAGE_ELEMS);
    for s in 0..samples {
        let base = s * SAMPLE_BYTES;
        labels.push(i32::from_le_bytes(bytes[base..base + 4].try_into().expect("4 bytes")));
        let px = &bytes[base + 4..base + SAMPLE_BYTES];
        images.extend(
            px.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])),
        );
    }
    DecodedChunk { labels, images }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::MemObject;

    fn mem() -> Arc<dyn Storage> {
        Arc::new(MemObject::new())
    }

    #[test]
    fn publish_then_stream_roundtrips_bit_exactly() {
        let store = mem();
        let data = Dataset::synthetic(100, 7);
        let stats = publish(&store, "corpus", &data, 16).unwrap();
        assert_eq!(stats.samples, 100);
        assert_eq!(stats.chunks_total, 7); // 6×16 + one 4-sample tail
        assert_eq!(stats.chunks_written, 7);
        let p = StreamingProvider::open(Arc::clone(&store), "corpus").unwrap();
        assert_eq!(p.len(), 100);
        assert_eq!(p.num_chunks(), 7);
        let back = p.to_dataset().unwrap();
        assert_eq!(back.images, data.images);
        assert_eq!(back.labels, data.labels);
    }

    #[test]
    fn republish_dedupes_every_chunk() {
        let store = mem();
        let data = Dataset::synthetic(64, 3);
        publish(&store, "a", &data, 16).unwrap();
        let again = publish(&store, "b", &data, 16).unwrap();
        assert_eq!(again.chunks_written, 0);
        assert_eq!(again.bytes_written, 0);
        assert!(again.bytes_deduped > 0);
    }

    #[test]
    fn tiny_cache_still_serves_random_access() {
        let store = mem();
        let data = Dataset::synthetic(80, 9);
        publish(&store, "c", &data, 8).unwrap();
        let p = StreamingProvider::open(Arc::clone(&store), "c")
            .unwrap()
            .with_cache_chunks(2);
        // stride across chunks so the 2-chunk cache must evict constantly
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for idx in (0..80).rev() {
            xs.clear();
            ys.clear();
            p.append_sample(idx, &mut xs, &mut ys).unwrap();
            assert_eq!(ys[0], data.labels[idx], "sample {idx}");
            assert_eq!(xs[..], data.images[idx * IMAGE_ELEMS..(idx + 1) * IMAGE_ELEMS]);
        }
    }

    #[test]
    fn cache_bounds_refetches_not_correctness() {
        let store = mem();
        let data = Dataset::synthetic(32, 1);
        publish(&store, "d", &data, 8).unwrap();
        let p = StreamingProvider::open(Arc::clone(&store), "d").unwrap();
        let gets_cold = p.chunks.store().metrics().get_ops.get();
        let _ = p.to_dataset().unwrap();
        let gets_after_one_pass = p.chunks.store().metrics().get_ops.get();
        // 4 chunks, default cache holds them all: exactly one fetch each
        assert_eq!(gets_after_one_pass - gets_cold, 4);
        let _ = p.to_dataset().unwrap();
        assert_eq!(p.chunks.store().metrics().get_ops.get(), gets_after_one_pass);
    }

    #[test]
    fn corrupt_manifest_is_rejected() {
        let store = mem();
        let data = Dataset::synthetic(16, 2);
        publish(&store, "e", &data, 8).unwrap();
        store.put("e/manifest.json", b"{\"format\": 99}").unwrap();
        assert!(StreamingProvider::open(Arc::clone(&store), "e").is_err());
    }

    #[test]
    fn missing_manifest_is_typed_not_found() {
        let err = StreamingProvider::open(mem(), "nope").unwrap_err();
        assert!(crate::storage::is_not_found(&err), "{err:#}");
    }
}
