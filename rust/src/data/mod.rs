//! Synthetic image-classification corpus — the stand-in for CIFAR-10 /
//! ImageNet (DESIGN.md §Substitutions).
//!
//! Ten classes of 32×32×3 images built from class-conditional structure:
//! each class owns a 2-D sinusoidal frequency pair and a color phase, and
//! samples add random spatial shifts, amplitude jitter and pixel noise.
//! The task is learnable (a linear probe gets well above chance; the mini
//! ResNet reaches >90%) but not trivial, so convergence-speed differences
//! between freezing schedules (Fig. 3) are visible.
//!
//! Everything is deterministic in the seed: the same (seed, split) always
//! produces the same corpus on every host — experiments are reproducible
//! bit-for-bit.

use crate::util::rng::Rng;
use std::sync::Arc;

pub mod stream;

pub use stream::{publish, PublishStats, StreamingProvider};

pub const IMAGE_H: usize = 32;
pub const IMAGE_W: usize = 32;
pub const IMAGE_C: usize = 3;
pub const NUM_CLASSES: usize = 10;
pub const IMAGE_ELEMS: usize = IMAGE_H * IMAGE_W * IMAGE_C;

/// An in-memory dataset split (NHWC images + labels).
#[derive(Clone, Debug)]
pub struct Dataset {
    /// `[n, 32, 32, 3]` flattened row-major.
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Generate `n` samples with a balanced class distribution.
    pub fn synthetic(n: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let mut images = Vec::with_capacity(n * IMAGE_ELEMS);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let class = (i % NUM_CLASSES) as i32;
            let mut sample_rng = rng.fork(i as u64);
            gen_image(class, &mut sample_rng, &mut images);
            labels.push(class);
        }
        // deterministic shuffle so batches are class-mixed
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let mut shuffled_images = vec![0.0f32; images.len()];
        let mut shuffled_labels = vec![0i32; n];
        for (dst, &src) in order.iter().enumerate() {
            shuffled_images[dst * IMAGE_ELEMS..(dst + 1) * IMAGE_ELEMS]
                .copy_from_slice(&images[src * IMAGE_ELEMS..(src + 1) * IMAGE_ELEMS]);
            shuffled_labels[dst] = labels[src];
        }
        Dataset { images: shuffled_images, labels: shuffled_labels }
    }

    /// Slice a batch (wrapping at the end).
    pub fn batch(&self, start: usize, batch: usize) -> (Vec<f32>, Vec<i32>) {
        let n = self.len();
        let mut xs = Vec::with_capacity(batch * IMAGE_ELEMS);
        let mut ys = Vec::with_capacity(batch);
        for i in 0..batch {
            let idx = (start + i) % n;
            xs.extend_from_slice(&self.images[idx * IMAGE_ELEMS..(idx + 1) * IMAGE_ELEMS]);
            ys.push(self.labels[idx]);
        }
        (xs, ys)
    }
}

/// One class-conditional image appended to `out`.
fn gen_image(class: i32, rng: &mut Rng, out: &mut Vec<f32>) {
    let c = class as f32;
    // class-specific structure
    let fx = 1.0 + (class % 5) as f32; // horizontal frequency
    let fy = 1.0 + (class / 5) as f32 * 2.0; // vertical frequency
    let color_phase = c * std::f32::consts::PI / 5.0;
    // sample-specific nuisance
    let shift_x = rng.uniform(0.0, std::f32::consts::TAU);
    let shift_y = rng.uniform(0.0, std::f32::consts::TAU);
    let amp = rng.uniform(0.7, 1.3);
    let noise_std = 0.25;

    for y in 0..IMAGE_H {
        for x in 0..IMAGE_W {
            let u = x as f32 / IMAGE_W as f32 * std::f32::consts::TAU;
            let v = y as f32 / IMAGE_H as f32 * std::f32::consts::TAU;
            let base = amp * ((fx * u + shift_x).sin() * (fy * v + shift_y).cos());
            for ch in 0..IMAGE_C {
                let chf = ch as f32;
                let tint = (color_phase + chf * std::f32::consts::FRAC_PI_3).cos();
                let val = base * (0.6 + 0.4 * tint) + noise_std * rng.normal();
                out.push(val);
            }
        }
    }
}

/// Where an epoch's samples come from: resident in memory, or streamed
/// from an object store through a bounded chunk cache.
///
/// The training drivers ([`crate::train::Engine`],
/// [`crate::train::Prefetcher`]) consume this instead of a concrete
/// [`Dataset`], which is what makes the storage boundary pluggable under
/// the prefetcher. Both variants yield **bit-identical batches** for the
/// same `(epoch_seed, batch, shard)` — the streamed corpus round-trips
/// f32 values exactly ([`stream`]) and both paths index one global
/// permutation — so switching a run to streaming cannot change its
/// trajectory (pinned in `rust/tests/integration_train.rs`).
#[derive(Clone)]
pub enum DataSource {
    /// The whole corpus resident in host memory.
    Memory(Arc<Dataset>),
    /// Samples fetched on demand from a published corpus
    /// ([`stream::publish`]) with bounded resident memory.
    Streamed(Arc<StreamingProvider>),
}

impl DataSource {
    pub fn memory(data: Arc<Dataset>) -> DataSource {
        DataSource::Memory(data)
    }

    pub fn streamed(provider: Arc<StreamingProvider>) -> DataSource {
        DataSource::Streamed(provider)
    }

    /// Total samples.
    pub fn len(&self) -> usize {
        match self {
            DataSource::Memory(d) => d.len(),
            DataSource::Streamed(p) => p.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// One-line description for logs (`memory(2048)` / `streamed(2048, 32 chunks)`).
    pub fn describe(&self) -> String {
        match self {
            DataSource::Memory(d) => format!("memory({})", d.len()),
            DataSource::Streamed(p) => {
                format!("streamed({}, {} chunks)", p.len(), p.num_chunks())
            }
        }
    }
}

/// One replica's slice of an epoch's batch stream — the data-parallel
/// sharding contract of `train::replica`.
///
/// All shards derive the epoch permutation from the epoch seed alone, so
/// every replica sees the *same* shuffled batch sequence and the full
/// batches are dealt round-robin: batch `b` belongs to the shard with
/// `b % count == index`. That makes shards **disjoint by construction**
/// and **equal-length**: the trailing `B mod count` batches of an epoch
/// are dropped (exactly like the partial final batch already is), so every
/// replica runs the same number of steps between data-parallel averaging
/// barriers — no replica ever waits on a barrier its peers will not reach.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Shard {
    /// This shard's position in `0..count`.
    pub index: usize,
    /// Total number of shards the batch stream is dealt across.
    pub count: usize,
}

impl Shard {
    /// The degenerate single-shard view: the whole batch stream.
    pub fn full() -> Shard {
        Shard { index: 0, count: 1 }
    }

    /// Shard `index` of `count`.
    ///
    /// # Panics
    /// If `count` is zero or `index` is out of range.
    pub fn of(index: usize, count: usize) -> Shard {
        assert!(count > 0, "shard count must be positive");
        assert!(index < count, "shard index {index} out of range 0..{count}");
        Shard { index, count }
    }

    /// How many of `total_batches` full batches this shard receives. Equal
    /// for every shard of the same `count` (ragged tails are dropped).
    pub fn num_batches(&self, total_batches: usize) -> usize {
        total_batches / self.count
    }
}

/// The epoch's global sample permutation — the *single* source of truth
/// for batch order, shared by [`BatchIter`] (in-memory assembly) and
/// [`crate::train::Prefetcher::start_streaming`] (storage-backed
/// assembly). Global batch `b` is `order[b*batch..(b+1)*batch]`; any
/// consumer that indexes this permutation the same way yields
/// bit-identical batches.
pub fn epoch_order(n: usize, epoch_seed: u64) -> Vec<usize> {
    let mut order: Vec<usize> = (0..n).collect();
    Rng::new(epoch_seed ^ 0x5EED_BA7C).shuffle(&mut order);
    order
}

/// Epoch iterator: shuffled batch starts over a dataset (optionally one
/// shard of the epoch's batch stream — see [`Shard`]).
pub struct BatchIter<'a> {
    data: &'a Dataset,
    order: Vec<usize>,
    batch: usize,
    /// Shard-local batch index (`0..num_batches()`).
    cursor: usize,
    shard: Shard,
}

impl<'a> BatchIter<'a> {
    /// Batches of `batch` samples in a per-epoch shuffled order. The final
    /// partial batch is dropped (constant AOT batch shape).
    pub fn new(data: &'a Dataset, batch: usize, epoch_seed: u64) -> Self {
        Self::new_sharded(data, batch, epoch_seed, Shard::full())
    }

    /// Like [`BatchIter::new`], but yielding only `shard`'s round-robin
    /// slice of the epoch's batches. The shuffle depends on `epoch_seed`
    /// alone, so shards of the same epoch partition one batch sequence.
    pub fn new_sharded(data: &'a Dataset, batch: usize, epoch_seed: u64, shard: Shard) -> Self {
        BatchIter { data, order: epoch_order(data.len(), epoch_seed), batch, cursor: 0, shard }
    }

    /// Batches this iterator will yield (the shard's equal-length slice).
    pub fn num_batches(&self) -> usize {
        self.shard.num_batches(self.data.len() / self.batch)
    }
}

impl Iterator for BatchIter<'_> {
    type Item = (Vec<f32>, Vec<i32>);

    fn next(&mut self) -> Option<Self::Item> {
        if self.cursor >= self.num_batches() {
            return None;
        }
        let global = self.cursor * self.shard.count + self.shard.index;
        let start = global * self.batch;
        let mut xs = Vec::with_capacity(self.batch * IMAGE_ELEMS);
        let mut ys = Vec::with_capacity(self.batch);
        for &idx in &self.order[start..start + self.batch] {
            xs.extend_from_slice(
                &self.data.images[idx * IMAGE_ELEMS..(idx + 1) * IMAGE_ELEMS],
            );
            ys.push(self.data.labels[idx]);
        }
        self.cursor += 1;
        Some((xs, ys))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_in_seed() {
        let a = Dataset::synthetic(50, 7);
        let b = Dataset::synthetic(50, 7);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
        let c = Dataset::synthetic(50, 8);
        assert_ne!(a.images, c.images);
    }

    #[test]
    fn balanced_classes() {
        let d = Dataset::synthetic(100, 1);
        let mut counts = [0usize; NUM_CLASSES];
        for &l in &d.labels {
            counts[l as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10), "{counts:?}");
    }

    #[test]
    fn images_are_bounded_and_finite() {
        let d = Dataset::synthetic(30, 2);
        assert_eq!(d.images.len(), 30 * IMAGE_ELEMS);
        for &v in &d.images {
            assert!(v.is_finite());
            assert!(v.abs() < 6.0, "{v}");
        }
    }

    #[test]
    fn classes_are_distinguishable() {
        // nearest-class-mean classification on raw pixels must beat chance
        // by a wide margin — otherwise the corpus can't power Fig. 3.
        let train = Dataset::synthetic(400, 3);
        let test = Dataset::synthetic(100, 4);
        let mut means = vec![vec![0.0f32; IMAGE_ELEMS]; NUM_CLASSES];
        let mut counts = vec![0usize; NUM_CLASSES];
        for i in 0..train.len() {
            let cls = train.labels[i] as usize;
            counts[cls] += 1;
            for (m, &v) in means[cls]
                .iter_mut()
                .zip(&train.images[i * IMAGE_ELEMS..(i + 1) * IMAGE_ELEMS])
            {
                *m += v;
            }
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= c as f32;
            }
        }
        let mut correct = 0;
        for i in 0..test.len() {
            let img = &test.images[i * IMAGE_ELEMS..(i + 1) * IMAGE_ELEMS];
            let best = (0..NUM_CLASSES)
                .min_by(|&a, &b| {
                    let da: f32 = means[a].iter().zip(img).map(|(m, v)| (m - v) * (m - v)).sum();
                    let db: f32 = means[b].iter().zip(img).map(|(m, v)| (m - v) * (m - v)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == test.labels[i] as usize {
                correct += 1;
            }
        }
        // phase shifts make raw-pixel means weak but still >> 10% chance
        assert!(correct >= 20, "nearest-mean acc {correct}/100");
    }

    #[test]
    fn batch_wraps() {
        let d = Dataset::synthetic(10, 5);
        let (xs, ys) = d.batch(8, 4);
        assert_eq!(xs.len(), 4 * IMAGE_ELEMS);
        assert_eq!(ys.len(), 4);
        assert_eq!(ys[2], d.labels[0]); // wrapped
    }

    #[test]
    fn batch_iter_covers_epoch_without_repeats() {
        let d = Dataset::synthetic(64, 6);
        let it = BatchIter::new(&d, 16, 0);
        assert_eq!(it.num_batches(), 4);
        let mut seen = 0;
        for (xs, ys) in it {
            assert_eq!(xs.len(), 16 * IMAGE_ELEMS);
            seen += ys.len();
        }
        assert_eq!(seen, 64);
    }

    #[test]
    fn batch_iter_epoch_seeds_differ() {
        let d = Dataset::synthetic(64, 6);
        let a: Vec<i32> = BatchIter::new(&d, 16, 0).flat_map(|(_, y)| y).collect();
        let b: Vec<i32> = BatchIter::new(&d, 16, 1).flat_map(|(_, y)| y).collect();
        assert_ne!(a, b, "different epochs shuffle differently");
    }

    #[test]
    fn partial_batch_dropped() {
        let d = Dataset::synthetic(70, 9);
        let it = BatchIter::new(&d, 32, 0);
        assert_eq!(it.count(), 2); // 70/32 = 2 full batches
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn shard_index_must_be_in_range() {
        Shard::of(2, 2);
    }

    /// Shards of one epoch must partition the unsharded batch stream:
    /// round-robin interleave, pairwise disjoint, nothing invented.
    fn assert_shards_partition(n_samples: usize, batch: usize, count: usize) {
        let d = Dataset::synthetic(n_samples, 21);
        let full: Vec<(Vec<f32>, Vec<i32>)> = BatchIter::new(&d, batch, 7).collect();
        let per_shard = full.len() / count;
        let mut seen = 0usize;
        for index in 0..count {
            let got: Vec<(Vec<f32>, Vec<i32>)> =
                BatchIter::new_sharded(&d, batch, 7, Shard::of(index, count)).collect();
            assert_eq!(got.len(), per_shard, "shard {index}/{count} length");
            for (j, b) in got.iter().enumerate() {
                // shard-local batch j is exactly global batch j*count+index
                assert_eq!(b, &full[j * count + index], "shard {index} batch {j}");
                seen += 1;
            }
        }
        // coverage: together the shards yield every batch of the truncated
        // equal-length prefix, and only those
        assert_eq!(seen, per_shard * count);
    }

    #[test]
    fn shards_partition_even_dataset() {
        // 64 samples / batch 16 = 4 batches; 2 shards * 2 batches, no drop
        assert_shards_partition(64, 16, 2);
    }

    #[test]
    fn shards_partition_ragged_dataset() {
        // 70 samples / batch 16 = 4 full batches; 3 shards * 1 batch — the
        // ragged tail (1 batch + the partial) is dropped for equal lengths
        assert_shards_partition(70, 16, 3);
        let d = Dataset::synthetic(70, 21);
        let it = BatchIter::new_sharded(&d, 16, 7, Shard::of(0, 3));
        assert_eq!(it.num_batches(), 1);
    }

    #[test]
    fn sharded_batches_are_sample_disjoint() {
        let d = Dataset::synthetic(96, 13);
        let mut labels_seen = 0usize;
        let mut used = vec![0usize; 96];
        for index in 0..3 {
            for (xs, ys) in BatchIter::new_sharded(&d, 16, 9, Shard::of(index, 3)) {
                labels_seen += ys.len();
                // recover each sample's identity by matching its pixels
                for s in 0..ys.len() {
                    let img = &xs[s * IMAGE_ELEMS..(s + 1) * IMAGE_ELEMS];
                    let idx = (0..d.len())
                        .find(|&i| {
                            d.images[i * IMAGE_ELEMS..(i + 1) * IMAGE_ELEMS] == *img
                        })
                        .expect("sample must come from the dataset");
                    used[idx] += 1;
                }
            }
        }
        assert_eq!(labels_seen, 96);
        // every sample appears exactly once across all shards
        assert!(used.iter().all(|&c| c == 1), "{used:?}");
    }
}
