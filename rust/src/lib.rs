//! # LRTA — Low-Rank Training Acceleration
//!
//! Rust + JAX + Pallas reproduction of *"Training Acceleration of Low-Rank
//! Decomposed Networks using Sequential Freezing and Rank Quantization"*
//! (Hajimolahoseini, Ahmed, Liu; 2023).
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//! - **L1** (build-time python): Pallas kernel for the fused low-rank
//!   product, `python/compile/kernels/`.
//! - **L2** (build-time python): JAX ResNet/ViT models + SGD train steps,
//!   AOT-lowered to HLO text artifacts by `python/compile/aot.py`.
//! - **L3** (this crate): the paper's contribution — closed-form LRD of
//!   checkpoints ([`lrd`]), rank optimization / quantization ([`rankopt`],
//!   Algorithm 1), the sequential-freezing training scheduler ([`freeze`],
//!   Algorithm 2), and the training/inference orchestration that runs the
//!   AOT artifacts via PJRT ([`runtime`], [`coordinator`]).
//!
//! On top of L3 sit two device-residency subsystems:
//! - the **serving layer** ([`serve`]): a production-style inference
//!   server — bounded admission-controlled queues, a dynamic batcher onto
//!   the compiled batch shape, per-variant engines with parameters
//!   uploaded once and kept device-resident, and a router that serves
//!   `orig` / `lrd` / `rankopt` checkpoints side-by-side for A/B
//!   throughput comparison (the Table-1 "Infer Speed" claim as a running
//!   system; `lrta serve`, `examples/serve_infer.rs`);
//! - the **training engine** ([`train`]): parameters *and* momenta are
//!   uploaded once, steps chain buffer-to-buffer (step N's output buffers
//!   are step N+1's inputs), epoch-boundary freeze-pattern swaps re-bind
//!   the same buffers to the new slot layout, and batches prefetch while
//!   the current step executes — the Table-1 "Train Speed" claim as a
//!   running system (`lrta train`, `bench_train_resident`; the literal
//!   round-trip loop survives as the `--no-resident` baseline). Scaling
//!   past one device is [`train::replica`]: N engine replicas (one PJRT
//!   client and resident state each) step on disjoint batch shards
//!   ([`data::Shard`]) with periodic buffer-level parameter averaging and
//!   freeze swaps synchronized at epoch boundaries (`lrta train
//!   --replicas N`, `bench_train_replicas`).
//!
//! Both subsystems execute through the **overlapped pipeline layer**
//! ([`runtime::pipeline`], default; `--no-pipeline` restores the serial
//! loops): executions split into non-blocking dispatch + demuxing fetch so
//! batch N+1's data uploads while batch N computes, training epoch metrics
//! accumulate in a device-resident buffer (one host fetch per epoch instead
//! of two scalars per step), per-epoch eval runs on a parameter snapshot on
//! a side thread, and serving admits/uploads the next batch while the
//! current one executes — all bit-identical to the serial paths by
//! construction, asserted in the integration suites.
//!
//! Cross-cutting both subsystems is the **observability layer** ([`obs`]):
//! a `(subsystem, name, labels)` metrics registry whose atomic handles *are*
//! the hand-rolled counters the tests pin (registered by identity, so
//! registry snapshots match the legacy accessors bit-for-bit), plus
//! lifecycle span tracing over the serve request path and the train step
//! path with Chrome/Perfetto trace export (`--trace-out`) and Prometheus
//! text exposition (`--metrics-out`). Telemetry is off by default and the
//! no-op recorder costs one branch per span site.
//!
//! Robustness is its own layer ([`faults`] + the supervision machinery in
//! [`train::replica`] and [`serve`]): a deterministic fault-injection
//! plane with named seams at every chokepoint (armed via `--faults` /
//! `LRTA_FAULTS`, a single branch per seam when off), train-side barrier
//! timeouts that *evict* dead or straggling replicas and keep averaging
//! over the survivors, and serve-side shard supervisors that drain,
//! respawn, and re-register crashed workers — so long multi-epoch runs and
//! live serving survive worker death instead of deadlocking.
//!
//! Python never runs on the training/inference path: `make artifacts`
//! lowers everything once, and the `lrta` binary is self-contained.
//!
//! `ARCHITECTURE.md` at the repository root is the top-to-bottom map of
//! all of this — lowering → runtime/pipeline → train/serve → coordinator/
//! CLI → benches/CI — including the data + buffer lifecycle (residency,
//! demux chaining, freeze rebinding) that the module docs above assume.

pub mod checkpoint;
pub mod coordinator;
pub mod data;
pub mod devmodel;
pub mod faults;
pub mod freeze;
pub mod linalg;
pub mod lrd;
pub mod metrics;
pub mod models;
pub mod obs;
pub mod rankopt;
pub mod runtime;
pub mod serve;
pub mod storage;
pub mod tensor;
pub mod train;
pub mod util;
