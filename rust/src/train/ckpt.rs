//! Asynchronous end-of-epoch checkpointing: epoch N's checkpoint persists
//! on a side thread while epoch N+1's steps already run.
//!
//! The overlapped trainer already downloads one parameter snapshot per
//! epoch for the side-thread evaluator ([`crate::train::EvalWorker`]) —
//! that download is the single synchronous cost on the engine thread, and
//! this module makes it pay twice: [`crate::coordinator::Trainer`] hands
//! the *same* snapshot to a [`CheckpointWriter`], whose worker serializes
//! it with [`crate::checkpoint::save`] off the hot path (the ROADMAP's
//! "checkpoint snapshot offload" item). `Params` is plain `Send` host
//! data, so unlike PJRT handles it can cross threads freely.
//!
//! Files land as `<dir>/epoch_NNN.bin` in the shared binary checkpoint
//! format. Determinism: `save` writes tensors in sorted-name order, so a
//! checkpoint written asynchronously here is byte-identical to one written
//! inline from the same state — pinned against the serial path in
//! `rust/tests/integration_train_resident.rs`.
//!
//! Join points mirror [`crate::train::EvalWorker`]: submission never
//! blocks; [`CheckpointWriter::drain`] (the end-of-run join) surfaces
//! every outcome, so a failed write fails the run instead of vanishing.

use crate::checkpoint::{self, Params};
use anyhow::{anyhow, bail, Result};
use std::path::PathBuf;
use std::sync::mpsc;
use std::thread;

/// One write request: the epoch index plus the snapshot to persist.
struct Job {
    epoch: usize,
    params: Params,
}

/// A finished (or failed) checkpoint write.
type Outcome = (usize, Result<PathBuf, String>);

/// Side-thread checkpoint persister over per-epoch parameter snapshots.
pub struct CheckpointWriter {
    tx: Option<mpsc::Sender<Job>>,
    rx: mpsc::Receiver<Outcome>,
    join: Option<thread::JoinHandle<()>>,
    /// Submitted but not yet collected epochs.
    pending: usize,
}

impl CheckpointWriter {
    /// Spawn the writer; checkpoints land as `dir/epoch_NNN.bin`.
    pub fn spawn(dir: PathBuf) -> CheckpointWriter {
        let (job_tx, job_rx) = mpsc::channel::<Job>();
        let (out_tx, out_rx) = mpsc::channel::<Outcome>();
        let join = thread::Builder::new()
            .name("lrta-train-ckpt".into())
            .spawn(move || {
                while let Ok(job) = job_rx.recv() {
                    let path = dir.join(format!("epoch_{:03}.bin", job.epoch));
                    let outcome = checkpoint::save(&path, &job.params)
                        .map(|()| path)
                        .map_err(|e| format!("{e:#}"));
                    if out_tx.send((job.epoch, outcome)).is_err() {
                        break; // trainer gone — nothing left to report to
                    }
                }
            })
            .expect("spawn checkpoint writer thread");
        CheckpointWriter { tx: Some(job_tx), rx: out_rx, join: Some(join), pending: 0 }
    }

    /// Queue one epoch's snapshot for persistence (non-blocking — the
    /// write proceeds while the next epoch trains).
    pub fn submit(&mut self, epoch: usize, params: Params) -> Result<()> {
        let tx = self.tx.as_ref().ok_or_else(|| anyhow!("checkpoint writer shut down"))?;
        tx.send(Job { epoch, params }).map_err(|_| anyhow!("checkpoint writer died"))?;
        self.pending += 1;
        Ok(())
    }

    /// Block until every submitted epoch has been written — the end-of-run
    /// join point. Returns `(epoch, path)` pairs; any failed write fails
    /// the drain (and with it the run that submitted it).
    pub fn drain(&mut self) -> Result<Vec<(usize, PathBuf)>> {
        let mut out = Vec::new();
        while self.pending > 0 {
            match self.rx.recv() {
                Ok((epoch, outcome)) => {
                    self.pending -= 1;
                    let path = outcome
                        .map_err(|e| anyhow!("epoch {epoch} checkpoint failed: {e}"))?;
                    out.push((epoch, path));
                }
                Err(_) => {
                    bail!("checkpoint writer died with {} writes pending", self.pending)
                }
            }
        }
        out.sort_by_key(|(e, _)| *e);
        Ok(out)
    }
}

impl Drop for CheckpointWriter {
    fn drop(&mut self) {
        // closing the job channel ends the worker loop; join so the thread
        // never outlives the trainer run that spawned it
        self.tx.take();
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("lrta_ckpt_writer_tests").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn some_params(seed: u64) -> Params {
        let mut rng = Rng::new(seed);
        let mut p = Params::new();
        p.insert("w".into(), Tensor::randn(&[3, 4], 1.0, &mut rng));
        p.insert("b".into(), Tensor::randn(&[4], 0.1, &mut rng));
        p
    }

    #[test]
    fn async_writes_match_inline_saves_byte_for_byte() {
        let dir = tmp("match_inline");
        let mut w = CheckpointWriter::spawn(dir.clone());
        let snapshots = [some_params(1), some_params(2)];
        for (e, p) in snapshots.iter().enumerate() {
            w.submit(e, p.clone()).unwrap();
        }
        let written = w.drain().unwrap();
        assert_eq!(written.len(), 2);
        for (e, path) in &written {
            assert_eq!(*path, dir.join(format!("epoch_{e:03}.bin")));
            let inline = dir.join(format!("inline_{e}.bin"));
            checkpoint::save(&inline, &snapshots[*e]).unwrap();
            assert_eq!(
                std::fs::read(path).unwrap(),
                std::fs::read(&inline).unwrap(),
                "epoch {e}: async checkpoint must be byte-identical to an inline save"
            );
        }
    }

    #[test]
    fn drain_with_nothing_pending_is_empty() {
        let mut w = CheckpointWriter::spawn(tmp("empty"));
        assert!(w.drain().unwrap().is_empty());
    }

    #[test]
    fn failed_write_surfaces_in_drain() {
        // a directory path that is actually a file → save must fail
        let dir = tmp("failing");
        let blocker = dir.join("blocker");
        std::fs::write(&blocker, "file").unwrap();
        let mut w = CheckpointWriter::spawn(blocker.join("sub"));
        w.submit(0, some_params(3)).unwrap();
        assert!(w.drain().is_err());
    }
}
