//! Asynchronous end-of-epoch checkpointing: epoch N's checkpoint persists
//! on a side thread while epoch N+1's steps already run.
//!
//! The overlapped trainer already downloads one parameter snapshot per
//! epoch for the side-thread evaluator ([`crate::train::EvalWorker`]) —
//! that download is the single synchronous cost on the engine thread, and
//! this module makes it pay twice: [`crate::coordinator::Trainer`] hands
//! the *same* snapshot to a [`CheckpointWriter`], whose worker uploads it
//! through the storage boundary off the hot path (the ROADMAP's
//! "checkpoint snapshot offload" item). `Params` is plain `Send` host
//! data, so unlike PJRT handles it can cross threads freely.
//!
//! The worker writes through [`crate::storage::Storage`]:
//! [`CheckpointWriter::spawn_to`] streams `<prefix>/epoch_NNN.bin` objects
//! into any backend via `put_streaming` (so `--store mem:` uploads ride
//! the side thread exactly like local files do), and
//! [`CheckpointWriter::spawn`] keeps the legacy directory layout by
//! opening a [`crate::storage::LocalFs`] at the directory. Determinism:
//! the codec ([`crate::checkpoint::encode`]) writes tensors in
//! sorted-name order, so a checkpoint written asynchronously here is
//! byte-identical to one written inline from the same state — pinned
//! against the serial path in `rust/tests/integration_train_resident.rs`.
//!
//! Join points mirror [`crate::train::EvalWorker`]: submission never
//! blocks; [`CheckpointWriter::drain`] (the end-of-run join) surfaces
//! every outcome — a failed write fails the run instead of vanishing, and
//! a *dead* worker surfaces its panic payload, not just the fact of
//! death.

use crate::checkpoint::{self, Params};
use crate::storage::{LocalFs, Storage};
use anyhow::{anyhow, bail, Result};
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;

/// One write request: the epoch index plus the snapshot to persist.
struct Job {
    epoch: usize,
    params: Params,
}

/// A finished (or failed) checkpoint write; `Ok` carries where it landed
/// (a filesystem path or a storage key, per the spawn mode).
type Outcome = (usize, Result<String, String>);

/// Side-thread checkpoint persister over per-epoch parameter snapshots.
pub struct CheckpointWriter {
    tx: Option<mpsc::Sender<Job>>,
    rx: mpsc::Receiver<Outcome>,
    join: Option<thread::JoinHandle<()>>,
    /// Submitted but not yet collected epochs.
    pending: usize,
}

impl CheckpointWriter {
    /// Spawn the writer over a directory; checkpoints land as
    /// `dir/epoch_NNN.bin` (a [`LocalFs`] opened on the worker thread, so
    /// an unusable directory surfaces at [`CheckpointWriter::drain`] —
    /// same failure path as any other write error).
    pub fn spawn(dir: PathBuf) -> CheckpointWriter {
        Self::spawn_with(move |epoch, params| {
            let store = LocalFs::open(dir.clone())?;
            let key = epoch_key("", epoch);
            checkpoint::save_to(&store, &key, params)?;
            Ok(dir.join(&key).display().to_string())
        })
    }

    /// Spawn the writer over any storage backend; checkpoints upload as
    /// `<prefix>/epoch_NNN.bin` objects through
    /// [`Storage::put_streaming`] while the next epoch trains.
    pub fn spawn_to(store: Arc<dyn Storage>, prefix: impl Into<String>) -> CheckpointWriter {
        let prefix = prefix.into();
        Self::spawn_with(move |epoch, params| {
            let key = epoch_key(&prefix, epoch);
            checkpoint::save_to(store.as_ref(), &key, params)?;
            Ok(key)
        })
    }

    /// The worker loop shared by both spawn modes: `write` persists one
    /// snapshot and reports where it landed.
    fn spawn_with(
        write: impl Fn(usize, &Params) -> Result<String> + Send + 'static,
    ) -> CheckpointWriter {
        let (job_tx, job_rx) = mpsc::channel::<Job>();
        let (out_tx, out_rx) = mpsc::channel::<Outcome>();
        let join = thread::Builder::new()
            .name("lrta-train-ckpt".into())
            .spawn(move || {
                while let Ok(job) = job_rx.recv() {
                    let outcome = write(job.epoch, &job.params).map_err(|e| format!("{e:#}"));
                    if out_tx.send((job.epoch, outcome)).is_err() {
                        break; // trainer gone — nothing left to report to
                    }
                }
            })
            .expect("spawn checkpoint writer thread");
        CheckpointWriter { tx: Some(job_tx), rx: out_rx, join: Some(join), pending: 0 }
    }

    /// Queue one epoch's snapshot for persistence (non-blocking — the
    /// write proceeds while the next epoch trains).
    pub fn submit(&mut self, epoch: usize, params: Params) -> Result<()> {
        let tx = self.tx.as_ref().ok_or_else(|| anyhow!("checkpoint writer shut down"))?;
        tx.send(Job { epoch, params }).map_err(|_| anyhow!("checkpoint writer died"))?;
        self.pending += 1;
        Ok(())
    }

    /// Block until every submitted epoch has been written — the end-of-run
    /// join point. Returns `(epoch, location)` pairs; any failed write
    /// fails the drain (and with it the run that submitted it).
    pub fn drain(&mut self) -> Result<Vec<(usize, String)>> {
        let mut out = Vec::new();
        while self.pending > 0 {
            match self.rx.recv() {
                Ok((epoch, outcome)) => {
                    self.pending -= 1;
                    let loc = outcome
                        .map_err(|e| anyhow!("epoch {epoch} checkpoint failed: {e}"))?;
                    out.push((epoch, loc));
                }
                Err(_) => {
                    // the worker died without reporting: join it and
                    // surface *why* (its panic payload), not just that it
                    // happened
                    match self.worker_panic_payload() {
                        Some(cause) => bail!(
                            "checkpoint writer died with {} writes pending: {cause}",
                            self.pending
                        ),
                        None => bail!(
                            "checkpoint writer died with {} writes pending",
                            self.pending
                        ),
                    }
                }
            }
        }
        out.sort_by_key(|(e, _)| *e);
        Ok(out)
    }

    /// Join the (already-dead) worker and render its panic payload.
    fn worker_panic_payload(&mut self) -> Option<String> {
        let join = self.join.take()?;
        match join.join() {
            Ok(()) => None,
            Err(payload) => Some(
                payload
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| payload.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "worker panicked with a non-string payload".into()),
            ),
        }
    }
}

/// `<prefix>/epoch_NNN.bin` (bare `epoch_NNN.bin` for an empty prefix).
fn epoch_key(prefix: &str, epoch: usize) -> String {
    if prefix.is_empty() {
        format!("epoch_{epoch:03}.bin")
    } else {
        format!("{prefix}/epoch_{epoch:03}.bin")
    }
}

impl Drop for CheckpointWriter {
    fn drop(&mut self) {
        // closing the job channel ends the worker loop; join so the thread
        // never outlives the trainer run that spawned it
        self.tx.take();
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::MemObject;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("lrta_ckpt_writer_tests").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn some_params(seed: u64) -> Params {
        let mut rng = Rng::new(seed);
        let mut p = Params::new();
        p.insert("w".into(), Tensor::randn(&[3, 4], 1.0, &mut rng));
        p.insert("b".into(), Tensor::randn(&[4], 0.1, &mut rng));
        p
    }

    #[test]
    fn async_writes_match_inline_saves_byte_for_byte() {
        let dir = tmp("match_inline");
        let mut w = CheckpointWriter::spawn(dir.clone());
        let snapshots = [some_params(1), some_params(2)];
        for (e, p) in snapshots.iter().enumerate() {
            w.submit(e, p.clone()).unwrap();
        }
        let written = w.drain().unwrap();
        assert_eq!(written.len(), 2);
        for (e, loc) in &written {
            assert_eq!(*loc, dir.join(format!("epoch_{e:03}.bin")).display().to_string());
            let inline = dir.join(format!("inline_{e}.bin"));
            checkpoint::save(&inline, &snapshots[*e]).unwrap();
            assert_eq!(
                std::fs::read(loc).unwrap(),
                std::fs::read(&inline).unwrap(),
                "epoch {e}: async checkpoint must be byte-identical to an inline save"
            );
        }
    }

    #[test]
    fn storage_uploads_match_file_saves_byte_for_byte() {
        let store = Arc::new(MemObject::new());
        let mut w = CheckpointWriter::spawn_to(Arc::clone(&store) as Arc<dyn Storage>, "ckpts");
        let p = some_params(5);
        w.submit(0, p.clone()).unwrap();
        let written = w.drain().unwrap();
        assert_eq!(written, vec![(0, "ckpts/epoch_000.bin".to_string())]);
        assert_eq!(store.get("ckpts/epoch_000.bin").unwrap(), checkpoint::encode(&p));
    }

    #[test]
    fn drain_with_nothing_pending_is_empty() {
        let mut w = CheckpointWriter::spawn(tmp("empty"));
        assert!(w.drain().unwrap().is_empty());
    }

    #[test]
    fn failed_write_surfaces_in_drain() {
        // a directory path that is actually a file → save must fail
        let dir = tmp("failing");
        let blocker = dir.join("blocker");
        std::fs::write(&blocker, "file").unwrap();
        let mut w = CheckpointWriter::spawn(blocker.join("sub"));
        w.submit(0, some_params(3)).unwrap();
        assert!(w.drain().is_err());
    }

    #[test]
    fn dead_worker_surfaces_its_panic_payload() {
        // regression: drain used to report only "writer died with N writes
        // pending" — the cause (the worker's panic payload) was dropped
        let mut w = CheckpointWriter::spawn_with(|_, _| panic!("disk controller exploded"));
        w.submit(0, some_params(4)).unwrap();
        let err = w.drain().unwrap_err().to_string();
        assert!(err.contains("1 writes pending"), "{err}");
        assert!(err.contains("disk controller exploded"), "{err}");
    }
}
